"""Model / quantization / calibration configuration shared across the
compile path (L1 kernels, L2 model, AOT) and exported to the Rust runtime
through artifacts/manifest.json.

The `tiny` config is the in-repo "small real model": a byte-level
Mixtral-architecture MoE transformer (SwiGLU experts, top-2 routing, RoPE,
RMSNorm) trained from scratch by train.py.  `wide` is a second architecture
used to show the paper's sensitivity claims generalize (paper Appendix D/E).
"""

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "tiny"
    vocab: int = 256          # byte-level
    d_model: int = 64
    n_layers: int = 4
    n_heads: int = 4
    head_dim: int = 16
    d_ff: int = 128           # expert intermediate dim (f)
    n_experts: int = 8
    top_k: int = 2
    max_seq: int = 512
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5
    # router aux-loss weight (Mixtral-style load balancing)
    aux_loss_coef: float = 0.02

    def validate(self) -> None:
        assert self.d_model == self.n_heads * self.head_dim
        assert self.d_ff % 4 == 0, "int2 packing packs 4 values per byte"
        assert self.d_model % QuantConfig().group_size == 0 or True


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """HQQ-style group-wise affine quantization of the up projection.

    Weights W_up[d, f] are quantized along the input (d) axis in groups of
    `group_size`; each (group, column) pair gets a float scale and zero.
    INT2 values are packed 4-per-byte along d.
    """
    bits: int = 2
    group_size: int = 32
    # HQQ half-quadratic solver
    hqq_iters: int = 20
    hqq_lp_norm: float = 0.7
    hqq_beta: float = 10.0
    hqq_kappa: float = 1.01


# sparsity levels calibrated offline (paper sweeps 50%..90%)
SPARSITY_LEVELS = (0.5, 0.6, 0.7, 0.8, 0.9)

CONFIGS: Dict[str, ModelConfig] = {
    "tiny": ModelConfig(),
    "wide": ModelConfig(
        name="wide", d_model=64, n_layers=3, n_heads=4, head_dim=16,
        d_ff=256, n_experts=4, top_k=2,
    ),
    # used only by unit tests (fast init, no training)
    "test": ModelConfig(
        name="test", d_model=32, n_layers=2, n_heads=2, head_dim=16,
        d_ff=64, n_experts=4, top_k=2, max_seq=64,
    ),
}


def get_config(name: str) -> ModelConfig:
    cfg = CONFIGS[name]
    cfg.validate()
    return cfg

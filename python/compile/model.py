"""L2: Mixtral-architecture MoE transformer in JAX.

Two faces of the same model:

  * `forward_train` — full-sequence training forward (dense expert dispatch,
    Mixtral top-2 routing + load-balancing aux loss) used by train.py;
  * graph builders (`attn_step_fn`, `expert_*_fn`, `logits_fn`) — the
    decode-time computations AOT-lowered to HLO text for the Rust runtime.
    All weights are *arguments* so one compiled executable serves every
    (layer, expert) pair and Rust decides which bytes are "VRAM-resident".

The FloE expert graphs call the L1 Pallas kernels (interpret=True) so the
kernels lower into the same HLO the Rust coordinator executes.
"""

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels import ref
from .kernels.sparse_expert import sparse_expert_pallas, floe_expert_pallas


Params = Dict[str, jnp.ndarray]


# ------------------------------------------------------------------ init

def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    rng = np.random.default_rng(seed)

    def randn(*shape, scale):
        return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)

    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    p: Params = {
        "embed": randn(cfg.vocab, d, scale=0.02),
        "final_norm": jnp.ones((d,), jnp.float32),
        "lm_head": randn(d, cfg.vocab, scale=0.02),
    }
    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        p[pre + "norm1"] = jnp.ones((d,), jnp.float32)
        p[pre + "norm2"] = jnp.ones((d,), jnp.float32)
        for w in ("wq", "wk", "wv", "wo"):
            p[pre + w] = randn(d, d, scale=d ** -0.5)
        p[pre + "router"] = randn(d, e, scale=0.02)
        # experts stacked on a leading E axis for vmapped training dispatch
        p[pre + "wg"] = randn(e, d, f, scale=d ** -0.5)
        p[pre + "wu"] = randn(e, d, f, scale=d ** -0.5)
        p[pre + "wd"] = randn(e, f, d, scale=f ** -0.5)
    return p


# -------------------------------------------------------------- training

def _attn_full(x, wq, wk, wv, wo, cfg: ModelConfig):
    """Full-sequence causal attention with RoPE. x: [B, S, d]."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = (x @ wq).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = (x @ wk).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = (x @ wv).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    pos = jnp.arange(s)
    q = ref.rope(q, pos[None, None, :], cfg.rope_theta)
    k = ref.rope(k, pos[None, None, :], cfg.rope_theta)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(causal[None, None], scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    return out.transpose(0, 2, 1, 3).reshape(b, s, d) @ wo


def _moe_block(h, router_w, wg, wu, wd, cfg: ModelConfig):
    """Top-k MoE with dense dispatch (fine at this scale).

    h: [B, S, d]; wg/wu: [E, d, f]; wd: [E, f, d].
    Returns (out [B, S, d], aux_loss scalar).
    """
    logits = h @ router_w                              # [B, S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(logits, cfg.top_k)
    top_w = jax.nn.softmax(top_w, axis=-1)             # renormalize over top-k
    # dense per-token expert weights [B, S, E]
    weights = jnp.zeros_like(probs)
    weights = jnp.take_along_axis(weights, top_i, axis=-1)  # dummy to get shape
    onehot = jax.nn.one_hot(top_i, cfg.n_experts, dtype=h.dtype)  # [B,S,K,E]
    weights = jnp.einsum("bsk,bske->bse", top_w, onehot)
    # all-expert forward, vmapped over the E axis
    outs = jax.vmap(lambda g, u, dn: ref.dense_expert(h, g, u, dn))(wg, wu, wd)
    out = jnp.einsum("bse,ebsd->bsd", weights, outs)
    # Mixtral-style load-balancing loss
    frac = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))       # tokens per expert
    mean_p = jnp.mean(probs, axis=(0, 1))
    aux = cfg.n_experts * jnp.sum(frac / cfg.top_k * mean_p)
    return out, aux


def forward_train(params: Params, tokens, cfg: ModelConfig):
    """tokens: int32 [B, S]. Returns (logits [B, S, V], aux_loss)."""
    x = params["embed"][tokens]
    aux_total = 0.0
    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        hn = ref.rmsnorm(x, params[pre + "norm1"], cfg.rms_eps)
        x = x + _attn_full(hn, params[pre + "wq"], params[pre + "wk"],
                           params[pre + "wv"], params[pre + "wo"], cfg)
        h = ref.rmsnorm(x, params[pre + "norm2"], cfg.rms_eps)
        mo, aux = _moe_block(h, params[pre + "router"], params[pre + "wg"],
                             params[pre + "wu"], params[pre + "wd"], cfg)
        x = x + mo
        aux_total = aux_total + aux
    x = ref.rmsnorm(x, params["final_norm"], cfg.rms_eps)
    return x @ params["lm_head"], aux_total / cfg.n_layers


def loss_fn(params: Params, tokens, cfg: ModelConfig):
    """Next-byte cross entropy (nats) + aux loss. tokens: [B, S+1]."""
    logits, aux = forward_train(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()
    return nll + cfg.aux_loss_coef * aux, nll


# ------------------------------------------------- decode-step AOT graphs

def attn_step_fn(cfg: ModelConfig):
    """Per-layer decode step: norm → attention(+cache) → residual → norm →
    router logits.  One executable serves all layers (weights are inputs).

    Signature: (x[B,d], kc[B,H,S,hd], vc[B,H,S,hd], pos i32,
                wq, wk, wv, wo [d,d], norm1[d], norm2[d], router[d,E])
      → (x_resid[B,d], h_mid[B,d], router_logits[B,E], kc', vc')
    """
    def fn(x, kc, vc, pos, wq, wk, wv, wo, n1, n2, wr):
        hn = ref.rmsnorm(x, n1, cfg.rms_eps)
        attn, kc2, vc2 = ref.attn_decode_step(
            hn, kc, vc, pos, wq, wk, wv, wo,
            cfg.n_heads, cfg.head_dim, cfg.rope_theta)
        x2 = x + attn
        h = ref.rmsnorm(x2, n2, cfg.rms_eps)
        return x2, h, h @ wr, kc2, vc2
    return fn


def expert_dense_fn(cfg: ModelConfig):
    """(x, wg[d,f], wu[d,f], wd[f,d]) → y[B,d] — paper Eq. (1)."""
    def fn(x, wg, wu, wd):
        return (ref.dense_expert(x, wg, wu, wd),)
    return fn


def expert_sparse_fn(cfg: ModelConfig):
    """(x, wg, wu, wd, t) → y — paper Eq. (11), fp up projection."""
    def fn(x, wg, wu, wd, t):
        return (ref.sparse_expert(x, wg, wu, wd, t),)
    return fn


def expert_sparse_pallas_fn(cfg: ModelConfig):
    """Same as expert_sparse_fn but through the L1 Pallas kernel."""
    def fn(x, wg, wu, wd, t):
        return (sparse_expert_pallas(x, wg, wu, wd, t,
                                     block_f=min(32, cfg.d_ff)),)
    return fn


def expert_floe_fn(cfg: ModelConfig, group_size: int):
    """FloE hybrid expert: in-graph INT2 dequant + contextual sparsity."""
    def fn(x, wg, packed, scale, zero, wd, t):
        return (ref.floe_expert(x, wg, packed, scale, zero, wd, t, group_size),)
    return fn


def expert_floe_pallas_fn(cfg: ModelConfig, group_size: int):
    """FloE hybrid expert through the fused L1 Pallas kernel."""
    def fn(x, wg, packed, scale, zero, wd, t):
        return (floe_expert_pallas(x, wg, packed, scale, zero, wd, t,
                                   group_size=group_size,
                                   block_f=min(32, cfg.d_ff)),)
    return fn


def expert_dequant_fn(cfg: ModelConfig, group_size: int):
    """Uniform-quantized expert (baseline: Mixtral-Offloading INT3/INT2).

    All three matrices arrive as u8 codes + per-group scale/zero; dequant
    happens in-graph, then the dense Eq. (1) forward.
    """
    def fn(x, gq, gs, gz, uq, us, uz, dq, ds, dz):
        wg = ref.dequant_groupwise(gq.astype(jnp.float32), gs, gz, group_size)
        wu = ref.dequant_groupwise(uq.astype(jnp.float32), us, uz, group_size)
        wd = ref.dequant_groupwise(dq.astype(jnp.float32), ds, dz, group_size)
        return (ref.dense_expert(x, wg, wu, wd),)
    return fn


def logits_fn(cfg: ModelConfig):
    """(x[B,d], final_norm[d], lm_head[d,V]) → logits[B,V]."""
    def fn(x, nw, wlm):
        return (ref.rmsnorm(x, nw, cfg.rms_eps) @ wlm,)
    return fn


def up_probe_fn(cfg: ModelConfig, group_size: int):
    """Intra-expert reuse predictor (§3.3.2): |h_prev · W_up_q| per channel.

    (h[B,d], packed, scale, zero) → |v|[B,f] — Rust compares against t to
    build the prefetch mask.
    """
    def fn(h, packed, scale, zero):
        v = ref.int2_matmul(h, packed, scale, zero, group_size)
        return (jnp.abs(v),)
    return fn


# ----------------------------------------------------- eval-time forward
# (python-side oracle used by calibrate.py and cross-checks; the production
#  path is the Rust engine over the AOT artifacts)

def forward_collect(params: Params, tokens, cfg: ModelConfig):
    """Training-style forward that also returns per-layer traces:

    hidden[i]   = hidden state entering layer i (pre-norm residual stream)
    router[i]   = router logits at layer i
    a_up[i]     = up-projection activations for the top-k experts, gathered
                  as [B, S, K, f] (the channels FloE thresholds)
    a_gate/a_down similarly.
    """
    x = params["embed"][tokens]
    hidden, hmid, router_l = [], [], []
    a_up, a_gate, a_down, top_idx = [], [], [], []
    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        hidden.append(x)
        hn = ref.rmsnorm(x, params[pre + "norm1"], cfg.rms_eps)
        x = x + _attn_full(hn, params[pre + "wq"], params[pre + "wk"],
                           params[pre + "wv"], params[pre + "wo"], cfg)
        h = ref.rmsnorm(x, params[pre + "norm2"], cfg.rms_eps)
        hmid.append(h)
        logits = h @ params[pre + "router"]
        router_l.append(logits)
        top_w, top_i = jax.lax.top_k(logits, cfg.top_k)
        top_w = jax.nn.softmax(top_w, axis=-1)
        wg, wu, wd = params[pre + "wg"], params[pre + "wu"], params[pre + "wd"]
        # gather per-token expert weights [B,S,K,d,f]: too big — loop experts
        outs = jax.vmap(lambda g, u, dn: ref.dense_expert(h, g, u, dn))(wg, wu, wd)
        gates = jax.vmap(lambda g: ref.silu(h @ g))(wg)          # [E,B,S,f]
        ups = jax.vmap(lambda u: h @ u)(wu)                      # [E,B,S,f]
        onehot = jax.nn.one_hot(top_i, cfg.n_experts, dtype=h.dtype)
        weights = jnp.einsum("bsk,bske->bse", top_w, onehot)
        x = x + jnp.einsum("bse,ebsd->bsd", weights, outs)
        # gather top-k activations: [B,S,K,f]
        gat = jnp.einsum("bske,ebsf->bskf", onehot, gates)
        upt = jnp.einsum("bske,ebsf->bskf", onehot, ups)
        a_gate.append(gat)
        a_up.append(upt)
        a_down.append(gat * upt)
        top_idx.append(top_i)
    x = ref.rmsnorm(x, params["final_norm"], cfg.rms_eps)
    logits = x @ params["lm_head"]
    return logits, dict(hidden=hidden, hmid=hmid, router=router_l, a_up=a_up,
                        a_gate=a_gate, a_down=a_down, top_idx=top_idx)


# parameter count helper
def param_count(params: Params) -> int:
    return int(sum(np.prod(v.shape) for v in params.values()))

"""Export trained weights + calibration to artifacts/ for the Rust runtime.

Format (DESIGN.md §2): `weights.bin` is a concatenation of raw
little-endian tensors (f32 or u8), 8-byte aligned; `manifest.json` maps
tensor names to (dtype, shape, offset, nbytes) and embeds the model config,
quantization config, thresholds, predictor metadata and analysis blobs.
Rust parses the JSON with its own in-repo parser (no serde offline).
"""

import json
import os
from typing import Dict, Tuple

import numpy as np

from .configs import ModelConfig, QuantConfig
from .hqq import QuantizedTensor, quantize
from .model import Params

UNIFORM_BITS = (8, 4, 3, 2, 1)


class BinWriter:
    def __init__(self):
        self.buf = bytearray()
        self.index: Dict[str, dict] = {}

    def add(self, name: str, arr: np.ndarray):
        assert name not in self.index, name
        if arr.dtype == np.float32:
            dtype = "f32"
        elif arr.dtype == np.uint8:
            dtype = "u8"
        elif arr.dtype == np.int32:
            dtype = "i32"
        else:
            raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
        pad = (-len(self.buf)) % 8
        self.buf.extend(b"\0" * pad)
        off = len(self.buf)
        raw = np.ascontiguousarray(arr).tobytes()
        self.buf.extend(raw)
        self.index[name] = {"dtype": dtype, "shape": list(arr.shape),
                            "offset": off, "nbytes": len(raw)}


def _add_quant(w: BinWriter, name: str, qt: QuantizedTensor,
               packed: bool = False):
    if packed:
        w.add(name, qt.packed_int2())
    else:
        w.add(name, qt.codes)
    w.add(name + "_scale", qt.scale)
    w.add(name + "_zero", qt.zero)


def export_artifacts(out_dir: str, params: Params, cfg: ModelConfig,
                     qcfg: QuantConfig, calib: Dict,
                     train_meta: Dict = None) -> Tuple[str, str]:
    w = BinWriter()
    p = {k: np.asarray(v) for k, v in params.items()}

    w.add("embed", p["embed"])
    w.add("final_norm", p["final_norm"])
    w.add("lm_head", p["lm_head"])
    for l in range(cfg.n_layers):
        pre = f"layer{l}."
        for t in ("norm1", "norm2", "wq", "wk", "wv", "wo", "router"):
            w.add(pre + t, p[pre + t])
        for e in range(cfg.n_experts):
            epre = f"{pre}expert{e}."
            wg, wu, wd = p[pre + "wg"][e], p[pre + "wu"][e], p[pre + "wd"][e]
            w.add(epre + "wg", wg)
            w.add(epre + "wu", wu)
            w.add(epre + "wd", wd)
            # FloE INT2 up projection (HQQ), 4 codes/byte
            _add_quant(w, epre + "up_q", calib["up_q"][(l, e)], packed=True)
            # uniform-quant variants for baselines + Table 7 sweeps
            for bits in UNIFORM_BITS:
                for proj, mat in (("wg", wg), ("wu", wu), ("wd", wd)):
                    qt = quantize(mat, bits=bits, qcfg=qcfg)
                    _add_quant(w, f"{epre}q{bits}.{proj}", qt)
    for l, (pw, pb) in enumerate(zip(calib["predictor"]["weights"],
                                     calib["predictor"]["biases"])):
        w.add(f"pred{l}.w", pw.astype(np.float32))
        w.add(f"pred{l}.b", pb.astype(np.float32))

    os.makedirs(out_dir, exist_ok=True)
    bin_path = os.path.join(out_dir, "weights.bin")
    with open(bin_path, "wb") as f:
        f.write(bytes(w.buf))

    manifest = {
        "config": {
            "name": cfg.name, "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "head_dim": cfg.head_dim, "d_ff": cfg.d_ff,
            "n_experts": cfg.n_experts, "top_k": cfg.top_k,
            "max_seq": cfg.max_seq, "rope_theta": cfg.rope_theta,
            "rms_eps": cfg.rms_eps,
        },
        "quant": {"bits": qcfg.bits, "group_size": qcfg.group_size,
                  "uniform_bits": list(UNIFORM_BITS)},
        "thresholds": calib["thresholds"],
        "predictor": {"hit_rate": calib["predictor"]["hit_rate"]},
        "analysis": calib["analysis"],
        "train_meta": train_meta or {},
        "tensors": w.index,
    }
    man_path = os.path.join(out_dir, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f)
    return bin_path, man_path

"""AOT entrypoint: train → calibrate → quantize → export → lower to HLO.

`make artifacts` runs this once; Python never runs on the request path.
HLO *text* (not serialized HloModuleProto) is the interchange format: jax
≥0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import calibrate as calibrate_mod
from . import model as model_mod
from . import train as train_mod
from .configs import QuantConfig, get_config
from .export import export_artifacts
from .kernels import ref


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower(fn, *args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*args))


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def u8(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.uint8)


def i32():
    return jax.ShapeDtypeStruct((), jnp.int32)


def lower_all(cfg, qcfg, out_dir: str, batch_sizes=(1, 4)) -> dict:
    d, f, e_, v = cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.vocab
    h, hd, s = cfg.n_heads, cfg.head_dim, cfg.max_seq
    g = qcfg.group_size
    graphs = {}

    for b in batch_sizes:
        graphs[f"attn_step_b{b}"] = lower(
            model_mod.attn_step_fn(cfg),
            f32(b, d), f32(b, h, s, hd), f32(b, h, s, hd), i32(),
            f32(d, d), f32(d, d), f32(d, d), f32(d, d),
            f32(d), f32(d), f32(d, e_))
        graphs[f"expert_dense_b{b}"] = lower(
            model_mod.expert_dense_fn(cfg),
            f32(b, d), f32(d, f), f32(d, f), f32(f, d))
        graphs[f"expert_sparse_b{b}"] = lower(
            model_mod.expert_sparse_fn(cfg),
            f32(b, d), f32(d, f), f32(d, f), f32(f, d), f32())
        graphs[f"expert_floe_b{b}"] = lower(
            model_mod.expert_floe_fn(cfg, g),
            f32(b, d), f32(d, f), u8(d // 4, f), f32(d // g, f),
            f32(d // g, f), f32(f, d), f32())
        graphs[f"logits_b{b}"] = lower(
            model_mod.logits_fn(cfg), f32(b, d), f32(d), f32(d, v))

    # L1 Pallas variants (B=1 hot path) — same math through the fused kernel
    graphs["expert_sparse_pallas_b1"] = lower(
        model_mod.expert_sparse_pallas_fn(cfg),
        f32(1, d), f32(d, f), f32(d, f), f32(f, d), f32())
    graphs["expert_floe_pallas_b1"] = lower(
        model_mod.expert_floe_pallas_fn(cfg, g),
        f32(1, d), f32(d, f), u8(d // 4, f), f32(d // g, f),
        f32(d // g, f), f32(f, d), f32())
    # uniform-quant expert (baselines: Mixtral-Offloading INT3/INT2)
    graphs["expert_q_b1"] = lower(
        model_mod.expert_dequant_fn(cfg, g),
        f32(1, d),
        u8(d, f), f32(d // g, f), f32(d // g, f),
        u8(d, f), f32(d // g, f), f32(d // g, f),
        u8(f, d), f32(f // g, d), f32(f // g, d))
    # intra-expert reuse predictor probe (§3.3.2)
    graphs["up_probe_b1"] = lower(
        model_mod.up_probe_fn(cfg, g),
        f32(1, d), u8(d // 4, f), f32(d // g, f), f32(d // g, f))

    paths = {}
    for name, text in graphs.items():
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        paths[name] = os.path.basename(path)
    return paths


def make_test_vectors(params, cfg, qcfg, calib) -> dict:
    """Deterministic input→output vectors the Rust integration tests check
    against the compiled HLO executables (oracle = ref.py numerics)."""
    d, f = cfg.d_model, cfg.d_ff
    g = qcfg.group_size
    rng = np.random.default_rng(42)
    x = rng.standard_normal((1, d)).astype(np.float32)
    p = {k: np.asarray(v) for k, v in params.items()}
    wg = p["layer0.wg"][0]
    wu = p["layer0.wu"][0]
    wd = p["layer0.wd"][0]
    qt = calib["up_q"][(0, 0)]
    t = float(calib["thresholds"]["up"][0][0][2])    # level 0.7

    xd = jnp.asarray(x)
    dense = np.asarray(ref.dense_expert(xd, wg, wu, wd))
    sparse = np.asarray(ref.sparse_expert(xd, wg, wu, wd, t))
    floe = np.asarray(ref.floe_expert(
        xd, jnp.asarray(wg), jnp.asarray(qt.packed_int2()),
        jnp.asarray(qt.scale), jnp.asarray(qt.zero), jnp.asarray(wd),
        t, g))
    # attention step at pos=0 with zero caches, layer 0 weights
    kc = np.zeros((1, cfg.n_heads, cfg.max_seq, cfg.head_dim), np.float32)
    x2, hmid, rl, _, _ = model_mod.attn_step_fn(cfg)(
        xd, jnp.asarray(kc), jnp.asarray(kc), jnp.int32(0),
        p["layer0.wq"], p["layer0.wk"], p["layer0.wv"], p["layer0.wo"],
        p["layer0.norm1"], p["layer0.norm2"], p["layer0.router"])
    logits = np.asarray(model_mod.logits_fn(cfg)(
        xd, p["final_norm"], p["lm_head"])[0])
    return {
        "x": x.reshape(-1).tolist(),
        "threshold": t,
        "expert_dense": dense.reshape(-1).tolist(),
        "expert_sparse": sparse.reshape(-1).tolist(),
        "expert_floe": floe.reshape(-1).tolist(),
        "attn_x2": np.asarray(x2).reshape(-1).tolist(),
        "attn_hmid": np.asarray(hmid).reshape(-1).tolist(),
        "attn_router_logits": np.asarray(rl).reshape(-1).tolist(),
        "logits_head": logits.reshape(-1)[:32].tolist(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="tiny")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--calib-chunks", type=int, default=4)
    ap.add_argument("--retrain", action="store_true")
    a = ap.parse_args()

    cfg = get_config(a.config)
    qcfg = QuantConfig()
    os.makedirs(a.out_dir, exist_ok=True)
    params_path = os.path.join(a.out_dir, f"params_{cfg.name}.npz")

    train_meta = {}
    if a.retrain or not os.path.exists(params_path):
        print(f"[aot] training {cfg.name} for {a.steps} steps ...")
        params, train_meta = train_mod.train(cfg, steps=a.steps)
        train_mod.save_params(params, params_path, train_meta)
    else:
        print(f"[aot] reusing {params_path}")
        params = train_mod.load_params(params_path)

    print("[aot] calibrating (thresholds, predictors, HQQ INT2) ...")
    calib = calibrate_mod.calibrate(params, cfg, qcfg,
                                    n_chunks=a.calib_chunks)
    print("  inter-predictor hit-rate:",
          [round(h, 3) for h in calib["predictor"]["hit_rate"]])
    print("  next-layer cosine sim:   ",
          [round(s, 3) for s in calib["analysis"]["fig4_cosine_similarity"]])
    print("  intra-reuse recall:      ",
          [round(r, 3) for r in calib["analysis"]["fig4_intra_predictor_recall"]])

    print("[aot] exporting weights.bin + manifest.json ...")
    export_artifacts(a.out_dir, params, cfg, qcfg, calib, train_meta)

    print("[aot] lowering HLO graphs ...")
    paths = lower_all(cfg, qcfg, a.out_dir)
    print(f"  wrote {len(paths)} HLO modules")

    tv = make_test_vectors(params, cfg, qcfg, calib)
    with open(os.path.join(a.out_dir, "testvec.json"), "w") as fh:
        json.dump(tv, fh)
    # eval corpus + probe instances for the Rust efficacy experiments
    from . import corpus as corpus_mod
    _, eval_data = corpus_mod.train_eval_split()
    with open(os.path.join(a.out_dir, "eval.txt"), "wb") as fh:
        fh.write(eval_data)
    probes = {task: corpus_mod.probe_instances(task, 40, seed=7000 + i)
              for i, task in enumerate(sorted(corpus_mod.PROBES))}
    with open(os.path.join(a.out_dir, "probes.json"), "w") as fh:
        json.dump(probes, fh)
    with open(os.path.join(a.out_dir, "graphs.json"), "w") as fh:
        json.dump(paths, fh)
    print("[aot] done")


if __name__ == "__main__":
    main()

"""Half-Quadratic Quantization (HQQ, Badri & Shaji 2023) — calibration-free
group-wise affine weight quantization with a proximal solver for the
zero-point.

The paper quantizes the expert up projection to INT2 with HQQ (§3.2.2) and
sweeps INT8..INT1 per projection for the sensitivity study (Fig 3b,
Table 7).  This is a from-scratch JAX/numpy implementation of the official
`optimize_weights_proximal` loop:

    minimize_{W_e, z}  ||W_e||_p^p + beta/2 ||W_e - (W - W_dq(z))||_2^2

alternating a generalized soft-threshold (shrinkage) on W_e with a
closed-form zero-point update, beta annealed by kappa each iteration.
"""

import dataclasses
from typing import Tuple

import numpy as np

from .configs import QuantConfig


@dataclasses.dataclass
class QuantizedTensor:
    """Group-wise affine quantized matrix (codes in [0, 2^bits - 1]).

    dequant: w[i, j] = (codes[i, j] - zero[i // g, j]) * scale[i // g, j]
    """
    codes: np.ndarray       # u8 [d, f]
    scale: np.ndarray       # f32 [d / g, f]
    zero: np.ndarray        # f32 [d / g, f]
    bits: int
    group_size: int

    def dequant(self) -> np.ndarray:
        d, f = self.codes.shape
        g = self.group_size
        c = self.codes.astype(np.float32).reshape(d // g, g, f)
        return ((c - self.zero[:, None, :]) * self.scale[:, None, :]
                ).reshape(d, f)

    def packed_int2(self) -> np.ndarray:
        """4 codes per byte along the input axis (bits must be 2)."""
        assert self.bits == 2
        d, f = self.codes.shape
        q = self.codes.reshape(d // 4, 4, f).astype(np.uint8)
        return (q[:, 0] | (q[:, 1] << 2) | (q[:, 2] << 4) | (q[:, 3] << 6))

    def nbytes_transfer(self) -> int:
        """Bytes moved over PCIe for this tensor (codes at `bits` wide +
        fp16 scale/zero), matching the paper's compression accounting."""
        return (self.codes.size * self.bits + 7) // 8 + 2 * 2 * self.scale.size


def _shrink_lp(x: np.ndarray, beta: float, p: float) -> np.ndarray:
    """Generalized soft-threshold: prox of the l_p quasi-norm (0<p<1)."""
    return np.sign(x) * np.maximum(
        np.abs(x) - (1.0 / beta) * np.power(np.abs(x) + 1e-8, p - 1.0), 0.0)


def quantize(w: np.ndarray, bits: int, qcfg: QuantConfig = QuantConfig()
             ) -> QuantizedTensor:
    """HQQ-quantize w[d, f] group-wise along axis 0."""
    d, f = w.shape
    g = qcfg.group_size
    assert d % g == 0, (d, g)
    wg = w.astype(np.float32).reshape(d // g, g, f)
    qmax = float(2 ** bits - 1)

    wmin = wg.min(axis=1, keepdims=True)                    # [d/g, 1, f]
    wmax = wg.max(axis=1, keepdims=True)
    rng = np.maximum(wmax - wmin, 1e-8)
    s = qmax / rng                                          # quant scale
    z = -wmin * s                                           # zero point

    beta = qcfg.hqq_beta
    best_err = np.inf
    best = None
    for _ in range(qcfg.hqq_iters):
        q = np.clip(np.round(wg * s + z), 0, qmax)
        w_r = (q - z) / s
        w_e = _shrink_lp(wg - w_r, beta, qcfg.hqq_lp_norm)
        z = np.mean(q - (wg - w_e) * s, axis=1, keepdims=True)
        beta *= qcfg.hqq_kappa
        err = float(np.mean(np.abs(wg - w_r) ** qcfg.hqq_lp_norm))
        if err < best_err:
            best_err = err
            best = (q.copy(), s.copy(), z.copy())
    q, s, z = best
    return QuantizedTensor(
        codes=q.reshape(d, f).astype(np.uint8),
        scale=(1.0 / s).reshape(d // g, f).astype(np.float32),
        zero=z.repeat(1, axis=1).reshape(d // g, f).astype(np.float32),
        bits=bits, group_size=g)


def quant_error(w: np.ndarray, qt: QuantizedTensor) -> Tuple[float, float]:
    """(relative fro error, max abs error) of the dequantized matrix."""
    dq = qt.dequant()
    rel = float(np.linalg.norm(dq - w) / (np.linalg.norm(w) + 1e-12))
    return rel, float(np.abs(dq - w).max())

"""Pallas kernels for INT2 group-wise dequantization (HQQ weight layout).

The intra-expert reuse predictor (paper §3.3.2) multiplies the *previous*
layer's hidden state with the next layer's VRAM-resident INT2 up projection
to precompute the channel mask.  That multiply is this kernel: a fused
unpack→dequant→GEMV, tiled over the output (f) dimension so each grid step
stages one [d/4, F_T] packed tile in VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _int2_matmul_kernel(group_size, x_ref, up_ref, sc_ref, zp_ref, o_ref):
    x = x_ref[...]                        # [B, d]
    packed = up_ref[...]                  # u8 [d/4, F_T]
    parts = [(packed >> s) & 3 for s in (0, 2, 4, 6)]
    codes = jnp.stack(parts, axis=1)      # [d/4, 4, F_T]
    d4, _, ft = codes.shape
    codes = codes.reshape(d4 * 4, ft).astype(jnp.float32)
    d = d4 * 4
    g = group_size
    w = ((codes.reshape(d // g, g, ft) - zp_ref[...][:, None, :])
         * sc_ref[...][:, None, :]).reshape(d, ft)
    o_ref[...] = x @ w


def int2_matmul_pallas(x, packed, scale, zero, *, group_size: int = 32,
                       block_f: int = 32):
    """x[B, d] @ dequant(packed u8[d/4, f]) with per-(group, column) affine."""
    b, d = x.shape
    f = packed.shape[1]
    assert f % block_f == 0
    grid = (f // block_f,)
    kern = functools.partial(_int2_matmul_kernel, group_size)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, d), lambda j: (0, 0)),
            pl.BlockSpec((d // 4, block_f), lambda j: (0, j)),
            pl.BlockSpec((d // group_size, block_f), lambda j: (0, j)),
            pl.BlockSpec((d // group_size, block_f), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((b, block_f), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((b, f), x.dtype),
        interpret=True,
    )(x, packed, scale, zero)


def _dequant_kernel(group_size, up_ref, sc_ref, zp_ref, o_ref):
    packed = up_ref[...]
    parts = [(packed >> s) & 3 for s in (0, 2, 4, 6)]
    codes = jnp.stack(parts, axis=1)
    d4, _, ft = codes.shape
    codes = codes.reshape(d4 * 4, ft).astype(jnp.float32)
    d = d4 * 4
    g = group_size
    o_ref[...] = ((codes.reshape(d // g, g, ft) - zp_ref[...][:, None, :])
                  * sc_ref[...][:, None, :]).reshape(d, ft)


def dequant_int2_pallas(packed, scale, zero, *, group_size: int = 32,
                        block_f: int = 32):
    """Materialize f32 weights from an INT2-packed matrix (tile-wise)."""
    d4, f = packed.shape
    d = d4 * 4
    assert f % block_f == 0
    grid = (f // block_f,)
    kern = functools.partial(_dequant_kernel, group_size)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d // 4, block_f), lambda j: (0, j)),
            pl.BlockSpec((d // group_size, block_f), lambda j: (0, j)),
            pl.BlockSpec((d // group_size, block_f), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((d, block_f), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((d, f), jnp.float32),
        interpret=True,
    )(packed, scale, zero)

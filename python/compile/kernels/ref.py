"""Pure-jnp reference oracles for every L1 kernel and L2 building block.

These are the correctness ground truth: pytest checks the Pallas kernels
(sparse_expert.py, quant.py) against these, and the Rust integration tests
check the compiled HLO artifacts against values exported from these.
"""

import jax
import jax.numpy as jnp


def silu(x):
    return x * jax.nn.sigmoid(x)


def rmsnorm(x, w, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


# ---------------------------------------------------------------- experts

def dense_expert(x, wg, wu, wd):
    """Paper Eq. (1): a_E(x) = (SiLU(x Wg) ⊙ (x Wu)) Wd."""
    return (silu(x @ wg) * (x @ wu)) @ wd


def sparse_expert(x, wg, wu, wd, t):
    """Paper Eq. (11) / Algorithm 1: contextual sparsity from |x Wu| >= t.

    Numerically identical to the column-skipping kernel: channels with
    |v| < t contribute exactly zero to the down projection.
    """
    v = x @ wu
    mask = (jnp.abs(v) >= t).astype(x.dtype)
    h = silu(x @ wg) * v * mask
    return h @ wd


def sparsify(a, t):
    """Paper Eq. (5): magnitude thresholding S_t."""
    return jnp.where(jnp.abs(a) >= t, a, jnp.zeros_like(a))


def gate_sparse_expert(x, wg, wu, wd, t):
    """CATS-style: threshold on SiLU(x Wg) (paper's L_gate variant)."""
    g = sparsify(silu(x @ wg), t)
    return (g * (x @ wu)) @ wd


def down_sparse_expert(x, wg, wu, wd, t):
    """Threshold on the down-projection input (paper's L_down variant)."""
    h = sparsify(silu(x @ wg) * (x @ wu), t)
    return h @ wd


# ------------------------------------------------------------ quantization

def pack_int2(q):
    """Pack int2 codes q[d, f] (values 0..3) 4-per-byte along axis 0."""
    d, f = q.shape
    assert d % 4 == 0
    q = q.astype(jnp.uint8).reshape(d // 4, 4, f)
    return (q[:, 0] | (q[:, 1] << 2) | (q[:, 2] << 4) | (q[:, 3] << 6)).astype(jnp.uint8)


def unpack_int2(packed):
    """Inverse of pack_int2: u8[d/4, f] -> int codes [d, f]."""
    parts = [(packed >> s) & 3 for s in (0, 2, 4, 6)]
    stacked = jnp.stack(parts, axis=1)          # [d/4, 4, f]
    d4, _, f = stacked.shape
    return stacked.reshape(d4 * 4, f)


def dequant_groupwise(codes, scale, zero, group_size: int):
    """w[i, j] = (codes[i, j] - zero[i//g, j]) * scale[i//g, j]."""
    d, f = codes.shape
    g = group_size
    c = codes.astype(jnp.float32).reshape(d // g, g, f)
    return ((c - zero[:, None, :]) * scale[:, None, :]).reshape(d, f)


def int2_matmul(x, packed, scale, zero, group_size: int):
    """x[B, d] @ dequant(int2-packed W[d, f])."""
    w = dequant_groupwise(unpack_int2(packed).astype(jnp.float32), scale, zero, group_size)
    return x @ w


def floe_expert(x, wg, packed_up, scale, zero, wd, t, group_size: int):
    """FloE hybrid expert: INT2 up projection + contextual sparse gate/down."""
    v = int2_matmul(x, packed_up, scale, zero, group_size)
    mask = (jnp.abs(v) >= t).astype(x.dtype)
    h = silu(x @ wg) * v * mask
    return h @ wd


# ---------------------------------------------------------------- routing

def router_topk(logits, k: int):
    """Mixtral routing: softmax over the top-k logits only.

    Returns (weights[B, k], indices[B, k]); weights sum to 1.
    """
    vals, idx = jax.lax.top_k(logits, k)
    w = jax.nn.softmax(vals, axis=-1)
    return w, idx


# -------------------------------------------------------------- attention

def rope(x, pos, theta: float = 10000.0):
    """Rotary embedding over the last axis. x: [..., hd]; pos broadcastable."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = jnp.asarray(pos, jnp.float32)[..., None] * freqs      # [..., half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def attn_decode_step(x, k_cache, v_cache, pos, wq, wk, wv, wo,
                     n_heads: int, head_dim: int, theta: float = 10000.0):
    """Single-token causal attention with KV cache.

    x: [B, d]; caches: [B, H, S, hd]; pos: scalar int32 (0-based position).
    Returns (attn_out[B, d], k_cache', v_cache').
    """
    b, d = x.shape
    s = k_cache.shape[2]
    q = (x @ wq).reshape(b, n_heads, head_dim)
    k = (x @ wk).reshape(b, n_heads, head_dim)
    v = (x @ wv).reshape(b, n_heads, head_dim)
    q = rope(q, pos, theta)
    k = rope(k, pos, theta)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k[:, :, None, :], (0, 0, pos, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v[:, :, None, :], (0, 0, pos, 0))
    scores = jnp.einsum("bhd,bhsd->bhs", q, k_cache) / jnp.sqrt(float(head_dim))
    mask = jnp.arange(s) <= pos
    scores = jnp.where(mask[None, None, :], scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bhsd->bhd", probs, v_cache).reshape(b, d)
    return out @ wo, k_cache, v_cache

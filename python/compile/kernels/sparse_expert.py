"""Pallas implementation of the paper's Algorithm 1 (Efficient Sparse Kernel).

The paper's Triton kernel is a GPU GEMV that (1) computes v = x W_up,
(2) builds mask = |v| > t, (3) loads only the surviving columns of W_gate
and rows of W_down^T, fusing SiLU and the Hadamard product into the gate
block.  Hardware adaptation for TPU/Pallas (DESIGN.md §Hardware-Adaptation):

  * the intermediate (f) dimension is tiled by the grid; each step stages a
    [d, F_T] tile of W_up/W_gate and a [F_T, d] tile of W_down in VMEM —
    the BlockSpec index maps express the HBM↔VMEM schedule the paper wrote
    with threadblocks;
  * XLA's static shapes cannot gather a data-dependent number of columns,
    so the mask is applied multiplicatively inside the tile (numerically
    identical to column skipping); wall-clock savings from skipping are
    realized in the Rust native path and modeled in hwsim for GPUs;
  * SiLU ⊙ v is fused into the gate tile exactly as the paper fuses it into
    the gate block, and the partial down-projection products accumulate
    into the output block across grid steps (sequential TPU grid).

Kernels MUST run with interpret=True: real-TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sparse_expert_kernel(x_ref, wg_ref, wu_ref, wd_ref, t_ref, o_ref):
    """One grid step: process an F_T-wide slice of the intermediate dim."""
    j = pl.program_id(0)
    x = x_ref[...]                       # [B, d]
    v = x @ wu_ref[...]                  # [B, F_T]   up-projection tile
    t = t_ref[0]
    mask = (jnp.abs(v) >= t).astype(v.dtype)
    g = x @ wg_ref[...]                  # gate tile
    h = (g * jax.nn.sigmoid(g)) * v * mask   # fused SiLU ⊙ v ⊙ mask
    part = h @ wd_ref[...]               # [B, d]     partial down projection

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part


def sparse_expert_pallas(x, wg, wu, wd, t, *, block_f: int = 32):
    """Algorithm-1 expert forward, f-tiled. Shapes: x[B,d] wg,wu[d,f] wd[f,d]."""
    b, d = x.shape
    f = wu.shape[1]
    assert f % block_f == 0, (f, block_f)
    t_arr = jnp.asarray(t, jnp.float32).reshape(1)
    grid = (f // block_f,)
    return pl.pallas_call(
        _sparse_expert_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, d), lambda j: (0, 0)),          # x: whole
            pl.BlockSpec((d, block_f), lambda j: (0, j)),    # W_gate tile
            pl.BlockSpec((d, block_f), lambda j: (0, j)),    # W_up tile
            pl.BlockSpec((block_f, d), lambda j: (j, 0)),    # W_down tile
            pl.BlockSpec((1,), lambda j: (0,)),              # threshold
        ],
        out_specs=pl.BlockSpec((b, d), lambda j: (0, 0)),    # accumulate
        out_shape=jax.ShapeDtypeStruct((b, d), x.dtype),
        interpret=True,
    )(x, wg, wu, wd, t_arr)


def _floe_expert_kernel(group_size, x_ref, wg_ref, up_ref, sc_ref, zp_ref,
                        wd_ref, t_ref, o_ref):
    """FloE hybrid tile: in-register INT2 dequant of the up tile + Algorithm 1."""
    j = pl.program_id(0)
    x = x_ref[...]                       # [B, d]
    packed = up_ref[...]                 # u8 [d/4, F_T]
    # unpack 4 int2 codes per byte along d (matches ref.unpack_int2)
    parts = [(packed >> s) & 3 for s in (0, 2, 4, 6)]
    codes = jnp.stack(parts, axis=1)     # [d/4, 4, F_T]
    d4 = codes.shape[0]
    ft = codes.shape[2]
    codes = codes.reshape(d4 * 4, ft).astype(jnp.float32)
    d = d4 * 4
    g = group_size
    sc = sc_ref[...]                     # [d/g, F_T]
    zp = zp_ref[...]
    w_up = ((codes.reshape(d // g, g, ft) - zp[:, None, :]) * sc[:, None, :]
            ).reshape(d, ft)
    v = x @ w_up
    t = t_ref[0]
    mask = (jnp.abs(v) >= t).astype(v.dtype)
    gt = x @ wg_ref[...]
    h = (gt * jax.nn.sigmoid(gt)) * v * mask
    part = h @ wd_ref[...]

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part


def floe_expert_pallas(x, wg, packed_up, scale, zero, wd, t, *,
                       group_size: int = 32, block_f: int = 32):
    """FloE hybrid expert (INT2 up + contextual sparse gate/down), f-tiled.

    packed_up: u8[d/4, f]; scale/zero: f32[d/group_size, f].
    """
    b, d = x.shape
    f = wg.shape[1]
    assert f % block_f == 0
    t_arr = jnp.asarray(t, jnp.float32).reshape(1)
    grid = (f // block_f,)
    kern = functools.partial(_floe_expert_kernel, group_size)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, d), lambda j: (0, 0)),
            pl.BlockSpec((d, block_f), lambda j: (0, j)),
            pl.BlockSpec((d // 4, block_f), lambda j: (0, j)),
            pl.BlockSpec((d // group_size, block_f), lambda j: (0, j)),
            pl.BlockSpec((d // group_size, block_f), lambda j: (0, j)),
            pl.BlockSpec((block_f, d), lambda j: (j, 0)),
            pl.BlockSpec((1,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((b, d), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), x.dtype),
        interpret=True,
    )(x, wg, packed_up, scale, zero, wd, t_arr)

"""Train the in-repo byte-level MoE model on the synthetic corpus.

Build-time only (invoked by aot.py / `make artifacts`).  Hand-rolled AdamW
(no optax in this environment).  On the 1-core CPU box the default
(tiny config, 300 steps, batch 8 x seq 96) finishes in a couple of minutes
and reaches ~1.1-1.4 nats/byte from a ~5.55 uniform start, which is plenty
of structure for the compression-sensitivity experiments to be graded.
"""

import functools
import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .configs import ModelConfig, get_config
from .model import Params, init_params, loss_fn


def batches(data: bytes, batch: int, seq: int, steps: int, seed: int = 7):
    arr = np.frombuffer(data, dtype=np.uint8).astype(np.int32)
    rng = np.random.default_rng(seed)
    n = len(arr) - seq - 1
    for _ in range(steps):
        starts = rng.integers(0, n, size=batch)
        yield np.stack([arr[s:s + seq + 1] for s in starts])


def adamw_init(params: Params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adamw_update(params: Params, grads, state, lr: float,
                 b1=0.9, b2=0.99, eps=1e-8, wd=1e-4):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                               state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** t), v)
    new = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p),
        params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


def train(cfg: ModelConfig, steps: int = 300, batch: int = 8, seq: int = 96,
          lr: float = 3e-3, seed: int = 0, log_every: int = 25,
          corpus_bytes: int = 220_000) -> Tuple[Params, Dict]:
    train_data, eval_data = corpus.train_eval_split(corpus_bytes)
    params = init_params(cfg, seed)

    @jax.jit
    def step(params, opt, tokens, lr):
        (loss, nll), grads = jax.value_and_grad(
            lambda p: loss_fn(p, tokens, cfg), has_aux=True)(params)
        params, opt = adamw_update(params, grads, opt, lr)
        return params, opt, loss, nll

    opt = adamw_init(params)
    history = []
    t0 = time.time()
    for i, tok in enumerate(batches(train_data, batch, seq, steps)):
        # cosine-ish decay with warmup
        warm = min(1.0, (i + 1) / 20.0)
        cur_lr = lr * warm * (0.5 * (1 + np.cos(np.pi * i / max(steps, 1))))
        params, opt, loss, nll = step(params, opt, jnp.asarray(tok),
                                      jnp.float32(cur_lr))
        if i % log_every == 0 or i == steps - 1:
            history.append((i, float(nll)))
            print(f"step {i:4d}  nll/byte {float(nll):.4f}  "
                  f"({time.time() - t0:.0f}s)", flush=True)

    ev = eval_nll(params, cfg, eval_data)
    print(f"eval nll/byte {ev:.4f}")
    return params, {"history": history, "eval_nll": ev,
                    "train_seconds": time.time() - t0}


def eval_nll(params: Params, cfg: ModelConfig, data: bytes,
             seq: int = 96, max_chunks: int = 24) -> float:
    """Held-out next-byte NLL (nats/byte) — the repo's 'perplexity' metric."""
    arr = np.frombuffer(data, dtype=np.uint8).astype(np.int32)
    chunks = []
    for s in range(0, min(len(arr) - seq - 1, max_chunks * seq), seq):
        chunks.append(arr[s:s + seq + 1])
    tok = jnp.asarray(np.stack(chunks))

    @jax.jit
    def nll(params, tok):
        return loss_fn(params, tok, cfg)[1]
    return float(nll(params, tok))


def save_params(params: Params, path: str, meta: Dict = None):
    np.savez(path, **{k: np.asarray(v) for k, v in params.items()},
             __meta__=np.array(repr(meta or {})))


def load_params(path: str) -> Params:
    z = np.load(path, allow_pickle=False)
    return {k: jnp.asarray(z[k]) for k in z.files if k != "__meta__"}


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="tiny")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--out", default="../artifacts/params.npz")
    a = ap.parse_args()
    cfg = get_config(a.config)
    params, meta = train(cfg, steps=a.steps)
    save_params(params, a.out, meta)
    print("saved", a.out)

"""Deterministic synthetic training corpus + probe-task generators.

The paper evaluates on WikiText-2 / C4 / ShareGPT and seven downstream
tasks.  Those need Mixtral-8x7B; this reproduction trains its own small
model, so the corpus is synthesized in-repo: English-like template text
mixed with four *probe tasks* whose completions can be scored exactly:

  arith    "3+4=7."                         (single-digit addition)
  fact     "the capital of albor is toma."  (fixed synthetic gazetteer)
  bracket  "([{}])" style balanced strings  (structural prediction)
  copy     "say bead: bead."                (short-range copying)

Downstream "task accuracy" for the efficacy experiments (paper Fig 9a/10,
Tables 3-5) = exact-match accuracy of greedy completions on held-out probe
instances; "perplexity" = bits-per-byte on held-out template text.
Everything is seeded, so Python and Rust evaluate the same instances.
"""

from typing import List, Tuple

import numpy as np

SUBJECTS = ["the miller", "a sailor", "the old fox", "my neighbor", "the clerk",
            "a young scribe", "the gardener", "our captain", "the baker", "a trader"]
VERBS = ["carried", "found", "mended", "sold", "painted", "borrowed",
         "buried", "counted", "weighed", "gathered"]
OBJECTS = ["a copper kettle", "three silver coins", "the torn map", "a bundle of reeds",
           "the broken oar", "two clay jars", "a sack of grain", "the iron key",
           "a length of rope", "the small lantern"]
PLACES = ["by the river", "near the gate", "under the bridge", "at the market",
          "behind the mill", "on the hill", "in the cellar", "along the shore",
          "beside the well", "past the orchard"]

# fixed synthetic gazetteer for the `fact` probe
CITIES = ["albor", "brint", "calor", "doven", "elim", "farro", "gresk", "holm",
          "ister", "jorvik", "kleth", "lunde", "marn", "nivel", "ostra", "pryne"]
CAPS = ["toma", "ruke", "sella", "vard", "wenn", "ylva", "zorn", "quil",
        "pell", "onna", "nim", "moss", "lorn", "kip", "jess", "ivo"]

BRACKET_PAIRS = [("(", ")"), ("[", "]"), ("{", "}")]
COPY_WORDS = ["bead", "mast", "fern", "grove", "latch", "plume", "crag", "dune",
              "helm", "inlet", "knoll", "ledge", "marsh", "notch", "prow", "quay"]


def _sentence(rng: np.random.Generator) -> str:
    return (f"{SUBJECTS[rng.integers(len(SUBJECTS))]} "
            f"{VERBS[rng.integers(len(VERBS))]} "
            f"{OBJECTS[rng.integers(len(OBJECTS))]} "
            f"{PLACES[rng.integers(len(PLACES))]}. ")


def gen_arith(rng: np.random.Generator) -> Tuple[str, str]:
    a, b = int(rng.integers(0, 10)), int(rng.integers(0, 10))
    return f"{a}+{b}=", f"{a + b}."


def gen_fact(rng: np.random.Generator) -> Tuple[str, str]:
    i = int(rng.integers(len(CITIES)))
    return f"the capital of {CITIES[i]} is ", f"{CAPS[i]}."


def gen_bracket(rng: np.random.Generator) -> Tuple[str, str]:
    """Balanced bracket string; prompt ends mid-way, completion closes it."""
    depth_types: List[int] = []
    s = ""
    for _ in range(int(rng.integers(2, 5))):
        t = int(rng.integers(3))
        depth_types.append(t)
        s += BRACKET_PAIRS[t][0]
    closing = "".join(BRACKET_PAIRS[t][1] for t in reversed(depth_types))
    return "match " + s, closing + "."


def gen_copy(rng: np.random.Generator) -> Tuple[str, str]:
    w = COPY_WORDS[rng.integers(len(COPY_WORDS))]
    return f"say {w}: ", f"{w}."


PROBES = {"arith": gen_arith, "fact": gen_fact, "bracket": gen_bracket, "copy": gen_copy}


def probe_instances(task: str, n: int, seed: int) -> List[Tuple[str, str]]:
    rng = np.random.default_rng(seed)
    return [PROBES[task](rng) for _ in range(n)]


def build_corpus(n_bytes: int = 220_000, seed: int = 1234) -> bytes:
    """Training text: 60% template prose, 40% probe-task lines."""
    rng = np.random.default_rng(seed)
    parts: List[str] = []
    size = 0
    while size < n_bytes:
        r = rng.random()
        if r < 0.6:
            s = _sentence(rng)
        else:
            task = ("arith", "fact", "bracket", "copy")[int(rng.integers(4))]
            p, c = PROBES[task](rng)
            s = p + c + " "
        parts.append(s)
        size += len(s)
    return "".join(parts).encode("ascii")


def train_eval_split(n_bytes: int = 220_000, seed: int = 1234) -> Tuple[bytes, bytes]:
    data = build_corpus(n_bytes, seed)
    cut = int(len(data) * 0.9)
    return data[:cut], data[cut:]

"""Offline calibration (paper §3.2.1, §3.3) run once at build time.

Produces, from activation traces of the trained model over held-out text:

  * per-(layer, expert, projection) magnitude thresholds at each target
    sparsity level — paper Eq. (6): t = min{t' : F(t') >= k} with F the
    empirical CDF of |activation| (projections: up / gate / down, plus
    CHESS-style per-channel gate thresholds for the baseline);
  * the inter-expert predictor (§3.3.1): per layer i, a linear probe
    h_mid(i) -> top-k experts of layer i+1, trained with BCE;
  * Fig-2/Fig-4 analysis data: activation histograms, next-layer cosine
    similarity, inter-predictor hit rate, intra-predictor (reuse) recall.
"""

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .configs import ModelConfig, QuantConfig, SPARSITY_LEVELS
from .hqq import QuantizedTensor, quantize
from .model import Params, forward_collect
from .kernels import ref


def collect_traces(params: Params, cfg: ModelConfig, data: bytes,
                   batch: int = 4, seq: int = 96, n_chunks: int = 4):
    """Run forward_collect over `n_chunks` batches; concat numpy traces."""
    arr = np.frombuffer(data, np.uint8).astype(np.int32)
    fwd = jax.jit(lambda t: forward_collect(params, t, cfg))
    acc: Dict[str, List] = {}
    per_tok = batch * seq
    for c in range(n_chunks):
        base = c * per_tok
        tok = np.stack([arr[base + i * seq: base + i * seq + seq]
                        for i in range(batch)])
        _, tr = fwd(jnp.asarray(tok))
        for k, v in tr.items():
            acc.setdefault(k, [])
            acc[k].append([np.asarray(x) for x in v])
    # merge: traces[k][layer] = concat over chunks, flattened over B,S
    out = {}
    for k, chunks in acc.items():
        nl = len(chunks[0])
        out[k] = [np.concatenate([ch[l].reshape(-1, *ch[l].shape[2:])
                                  for ch in chunks], axis=0)
                  for l in range(nl)]
    return out


def _expert_samples(tr, layer: int, key: str, expert: int, cfg: ModelConfig):
    """|activation| samples of `expert` at `layer` from gathered top-k trace."""
    a = tr[key][layer]                       # [N, K, f]
    idx = tr["top_idx"][layer]               # [N, K]
    sel = (idx == expert)
    return np.abs(a[sel])                    # [n_sel, f]


def thresholds_from_traces(tr, cfg: ModelConfig,
                           levels=SPARSITY_LEVELS) -> Dict:
    """Empirical-CDF thresholds per layer/expert/projection/level."""
    th = {"up": [], "gate": [], "down": [], "chess_gate": []}
    for l in range(cfg.n_layers):
        for key, out_key in (("a_up", "up"), ("a_gate", "gate"),
                             ("a_down", "down")):
            per_expert = []
            for e in range(cfg.n_experts):
                s = _expert_samples(tr, l, key, e, cfg)
                flat = s.reshape(-1)
                if flat.size == 0:
                    per_expert.append([0.0] * len(levels))
                    continue
                per_expert.append([float(np.quantile(flat, k)) for k in levels])
            th[out_key].append(per_expert)
        # CHESS: per-channel thresholds on the gate activations
        per_expert_ch = []
        for e in range(cfg.n_experts):
            s = _expert_samples(tr, l, "a_gate", e, cfg)   # [n, f]
            if s.shape[0] == 0:
                per_expert_ch.append([[0.0] * cfg.d_ff for _ in levels])
                continue
            per_expert_ch.append(
                [np.quantile(s, k, axis=0).astype(float).tolist()
                 for k in levels])
        th["chess_gate"].append(per_expert_ch)
    th["levels"] = list(levels)
    return th


# ------------------------------------------------- inter-expert predictor

def train_inter_predictor(tr, cfg: ModelConfig, steps: int = 300,
                          lr: float = 0.05, seed: int = 3):
    """Per layer i in [0, L-2]: linear probe h_mid(i) -> layer i+1 top-k.

    Returns (weights [L-1][d, E], biases [L-1][E], hit_rate per layer).
    The paper scales predictor capacity with depth (32K..2M params); at our
    scale a linear probe already reaches the paper's ~0.9 hit-rate regime.
    """
    rng = np.random.default_rng(seed)
    ws, bs, hits = [], [], []
    for l in range(cfg.n_layers - 1):
        X = tr["hmid"][l]                                  # [N, d]
        idx = tr["top_idx"][l + 1]                         # [N, K]
        Y = np.zeros((X.shape[0], cfg.n_experts), np.float32)
        np.put_along_axis(Y, idx, 1.0, axis=1)
        Xj, Yj = jnp.asarray(X), jnp.asarray(Y)
        w = jnp.asarray(rng.standard_normal((cfg.d_model, cfg.n_experts))
                        * 0.01, jnp.float32)
        b = jnp.zeros((cfg.n_experts,), jnp.float32)

        def bce(wb):
            w, b = wb
            logits = Xj @ w + b
            return jnp.mean(jnp.clip(logits, 0) - logits * Yj
                            + jnp.log1p(jnp.exp(-jnp.abs(logits))))

        grad = jax.jit(jax.value_and_grad(bce))
        m = (jnp.zeros_like(w), jnp.zeros_like(b))
        v = (jnp.zeros_like(w), jnp.zeros_like(b))
        wb = (w, b)
        for t in range(1, steps + 1):
            _, g = grad(wb)
            m = tuple(0.9 * mi + 0.1 * gi for mi, gi in zip(m, g))
            v = tuple(0.99 * vi + 0.01 * gi * gi for vi, gi in zip(v, g))
            wb = tuple(p - lr * (mi / (1 - 0.9 ** t))
                       / (jnp.sqrt(vi / (1 - 0.99 ** t)) + 1e-8)
                       for p, mi, vi in zip(wb, m, v))
        w, b = wb
        scores = np.asarray(Xj @ w + b)
        pred = np.argsort(-scores, axis=1)[:, :cfg.top_k]
        hit = np.mean([len(set(pred[i]) & set(idx[i])) / cfg.top_k
                       for i in range(len(pred))])
        ws.append(np.asarray(w))
        bs.append(np.asarray(b))
        hits.append(float(hit))
    return ws, bs, hits


# --------------------------------------------------- analysis (Fig 2 / 4)

def cosine_similarity(tr, cfg: ModelConfig) -> List[float]:
    """Mean cos(h_mid(i), h_mid(i+1)) per layer — paper Fig 4 blue line."""
    sims = []
    for l in range(cfg.n_layers - 1):
        a, b = tr["hmid"][l], tr["hmid"][l + 1]
        num = np.sum(a * b, axis=1)
        den = np.linalg.norm(a, axis=1) * np.linalg.norm(b, axis=1) + 1e-9
        sims.append(float(np.mean(num / den)))
    return sims


def intra_predictor_recall(tr, params: Params, cfg: ModelConfig,
                           up_q: Dict, qcfg: QuantConfig,
                           level: float = 0.7,
                           levels=SPARSITY_LEVELS) -> List[float]:
    """Recall of the reuse predictor (§3.3.2), per predicted layer i>=1.

    Predicted mask: |h_mid(i-1) · W_up_q(i, e)| >= t(i, e)
    True mask:      |h_mid(i)   · W_up(i, e)|   >= t(i, e)
    averaged over tokens and their routed experts.
    """
    recalls = []
    for l in range(1, cfg.n_layers):
        h_prev = tr["hmid"][l - 1]
        h_true = tr["hmid"][l]
        idx = tr["top_idx"][l]
        wu = np.asarray(params[f"layer{l}.wu"])            # [E, d, f]
        tot_hit, tot_true = 0, 0
        for e in range(cfg.n_experts):
            sel = np.any(idx == e, axis=1)
            if not sel.any():
                continue
            qt: QuantizedTensor = up_q[(l, e)]
            v_pred = np.abs(h_prev[sel] @ qt.dequant())
            v_true = np.abs(h_true[sel] @ wu[e])
            # threshold from the true distribution at `level`
            tq = np.quantile(v_true, level)
            pred = v_pred >= tq
            true = v_true >= tq
            tot_hit += int(np.logical_and(pred, true).sum())
            tot_true += int(true.sum())
        recalls.append(tot_hit / max(tot_true, 1))
    return recalls


def activation_histograms(tr, cfg: ModelConfig, bins: int = 41,
                          lo: float = -2.0, hi: float = 2.0) -> Dict:
    """Fig-2 analog: per-layer histograms of gate/up/down activations for
    the expert with most samples (shallow/middle/deep layers all stored)."""
    edges = np.linspace(lo, hi, bins + 1)
    out = {"edges": edges.tolist(), "layers": {}}
    for l in range(cfg.n_layers):
        idx = tr["top_idx"][l]
        e = int(np.bincount(idx.reshape(-1), minlength=cfg.n_experts).argmax())
        entry = {"expert": e}
        for key in ("a_gate", "a_up", "a_down"):
            a = tr[key][l]
            sel = (idx == e)
            vals = a[sel].reshape(-1)
            hist, _ = np.histogram(vals, bins=edges)
            entry[key] = hist.astype(int).tolist()
        out["layers"][str(l)] = entry
    return out


def quantize_all_up(params: Params, cfg: ModelConfig,
                    qcfg: QuantConfig) -> Dict:
    """HQQ-INT2 quantize every expert's up projection."""
    up_q = {}
    for l in range(cfg.n_layers):
        wu = np.asarray(params[f"layer{l}.wu"])
        for e in range(cfg.n_experts):
            up_q[(l, e)] = quantize(wu[e], bits=qcfg.bits, qcfg=qcfg)
    return up_q


def calibrate(params: Params, cfg: ModelConfig, qcfg: QuantConfig,
              n_chunks: int = 4) -> Dict:
    """Full calibration pass; returns everything export.py needs."""
    _, eval_data = corpus.train_eval_split()
    tr = collect_traces(params, cfg, eval_data, n_chunks=n_chunks)
    th = thresholds_from_traces(tr, cfg)
    ws, bs, hits = train_inter_predictor(tr, cfg)
    up_q = quantize_all_up(params, cfg, qcfg)
    sims = cosine_similarity(tr, cfg)
    recalls = intra_predictor_recall(tr, params, cfg, up_q, qcfg)
    hists = activation_histograms(tr, cfg)
    return {
        "thresholds": th,
        "predictor": {"weights": ws, "biases": bs, "hit_rate": hits},
        "up_q": up_q,
        "analysis": {
            "fig4_cosine_similarity": sims,
            "fig4_inter_predictor_precision": hits,
            "fig4_intra_predictor_recall": recalls,
            "fig2_histograms": hists,
        },
    }

"""L2 model correctness: shapes, training signal, and — critically — the
equivalence between the AOT decode-step graphs and the full-sequence
training forward (the Rust engine is built on the former)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import get_config
from compile.kernels import ref
from compile import model as M
from compile.train import adamw_init, adamw_update

CFG = get_config("test")


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


def test_forward_shapes(params):
    tok = jnp.zeros((2, 16), jnp.int32)
    logits, aux = M.forward_train(params, tok, CFG)
    assert logits.shape == (2, 16, CFG.vocab)
    assert np.isfinite(float(aux))
    assert np.isfinite(np.asarray(logits)).all()


def test_loss_decreases(params):
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(97, 110, (4, 33)), jnp.int32)

    @jax.jit
    def step(p, o):
        (l, n), g = jax.value_and_grad(
            lambda p: M.loss_fn(p, tok, CFG), has_aux=True)(p)
        p, o = adamw_update(p, g, o, 1e-2)
        return p, o, n

    p, o = params, adamw_init(params)
    p, o, first = step(p, o)
    for _ in range(15):
        p, o, last = step(p, o)
    assert float(last) < float(first) - 0.3


def test_rope_preserves_norm():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((3, 16)), jnp.float32)
    y = ref.rope(x, jnp.asarray([5.0, 9.0, 0.0]))
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)


def test_rope_pos0_identity():
    x = jnp.asarray(np.random.default_rng(2).standard_normal((16,)), jnp.float32)
    y = ref.rope(x, 0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)


def test_decode_steps_match_full_forward(params):
    """Run the AOT decode-step graphs token by token and compare the final
    logits against forward_train on the same sequence.  This is the exact
    computation the Rust engine performs."""
    rng = np.random.default_rng(3)
    seq = 12
    tok = rng.integers(97, 122, seq).astype(np.int32)
    full_logits, _ = M.forward_train(params, jnp.asarray(tok[None]), CFG)

    attn = M.attn_step_fn(CFG)
    d = CFG.d_model
    kcs = [jnp.zeros((1, CFG.n_heads, CFG.max_seq, CFG.head_dim), jnp.float32)
           for _ in range(CFG.n_layers)]
    vcs = [jnp.zeros_like(kcs[0]) for _ in range(CFG.n_layers)]
    outs = []
    for pos in range(seq):
        x = params["embed"][tok[pos]][None, :]
        for l in range(CFG.n_layers):
            pre = f"layer{l}."
            x2, h, rl, kcs[l], vcs[l] = attn(
                x, kcs[l], vcs[l], jnp.int32(pos),
                params[pre + "wq"], params[pre + "wk"],
                params[pre + "wv"], params[pre + "wo"],
                params[pre + "norm1"], params[pre + "norm2"],
                params[pre + "router"])
            w, idx = ref.router_topk(rl, CFG.top_k)
            moe = jnp.zeros_like(x2)
            for k in range(CFG.top_k):
                e = int(idx[0, k])
                y = ref.dense_expert(h, params[pre + "wg"][e],
                                     params[pre + "wu"][e],
                                     params[pre + "wd"][e])
                moe = moe + w[0, k] * y
            x = x2 + moe
        logits = M.logits_fn(CFG)(x, params["final_norm"], params["lm_head"])[0]
        outs.append(np.asarray(logits)[0])
    np.testing.assert_allclose(np.stack(outs), np.asarray(full_logits[0]),
                               rtol=2e-4, atol=2e-4)


def test_expert_graph_variants_consistent(params):
    """expert_sparse(t=0) == expert_dense == pallas variant."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((1, CFG.d_model)), jnp.float32)
    wg = params["layer0.wg"][0]
    wu = params["layer0.wu"][0]
    wd = params["layer0.wd"][0]
    dense = M.expert_dense_fn(CFG)(x, wg, wu, wd)[0]
    sparse0 = M.expert_sparse_fn(CFG)(x, wg, wu, wd, jnp.float32(0.0))[0]
    pallas0 = M.expert_sparse_pallas_fn(CFG)(x, wg, wu, wd, jnp.float32(0.0))[0]
    np.testing.assert_allclose(np.asarray(dense), np.asarray(sparse0),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(pallas0),
                               rtol=1e-5, atol=1e-5)


def test_param_count(params):
    n = M.param_count(params)
    assert 50_000 < n < 5_000_000

"""Calibration properties: CDF thresholds (paper Eq. 6), predictor quality
above chance, analysis outputs well-formed."""

import numpy as np
import pytest

from compile import corpus
from compile.configs import QuantConfig, SPARSITY_LEVELS, get_config
from compile import calibrate as C
from compile.model import init_params

CFG = get_config("test")
QCFG = QuantConfig()


@pytest.fixture(scope="module")
def traces():
    params = init_params(CFG, seed=0)
    _, eval_data = corpus.train_eval_split(60_000)
    tr = C.collect_traces(params, CFG, eval_data, batch=2, seq=48, n_chunks=2)
    return params, tr


def test_trace_shapes(traces):
    params, tr = traces
    n = 2 * 48 * 2
    assert tr["hmid"][0].shape == (n, CFG.d_model)
    assert tr["top_idx"][0].shape == (n, CFG.top_k)
    assert tr["a_up"][0].shape == (n, CFG.top_k, CFG.d_ff)
    assert len(tr["hmid"]) == CFG.n_layers


def test_thresholds_monotonic_and_quantile(traces):
    params, tr = traces
    th = C.thresholds_from_traces(tr, CFG)
    for proj in ("up", "gate", "down"):
        for l in range(CFG.n_layers):
            for e in range(CFG.n_experts):
                ts = th[proj][l][e]
                assert all(b >= a - 1e-9 for a, b in zip(ts, ts[1:])), \
                    (proj, l, e, ts)
    # quantile property: fraction of |a_up| below t(0.7) ≈ 0.7
    l, e = 0, int(np.bincount(tr["top_idx"][0].reshape(-1),
                              minlength=CFG.n_experts).argmax())
    s = C._expert_samples(tr, l, "a_up", e, CFG).reshape(-1)
    t = th["up"][l][e][SPARSITY_LEVELS.index(0.7)]
    frac = float((s < t).mean())
    assert abs(frac - 0.7) < 0.05


def test_chess_thresholds_per_channel(traces):
    params, tr = traces
    th = C.thresholds_from_traces(tr, CFG)
    ch = th["chess_gate"][0][0]
    assert len(ch) == len(SPARSITY_LEVELS)
    assert len(ch[0]) == CFG.d_ff


def test_inter_predictor_beats_chance(traces):
    params, tr = traces
    ws, bs, hits = C.train_inter_predictor(tr, CFG, steps=150)
    assert len(ws) == CFG.n_layers - 1
    chance = CFG.top_k / CFG.n_experts
    for h in hits:
        assert h > chance + 0.1, hits


def test_cosine_sims_valid(traces):
    params, tr = traces
    sims = C.cosine_similarity(tr, CFG)
    assert len(sims) == CFG.n_layers - 1
    assert all(-1.0 <= s <= 1.0 for s in sims)


def test_intra_recall_in_range(traces):
    params, tr = traces
    up_q = C.quantize_all_up(params, CFG, QCFG)
    rec = C.intra_predictor_recall(tr, params, CFG, up_q, QCFG)
    assert len(rec) == CFG.n_layers - 1
    assert all(0.0 <= r <= 1.0 for r in rec)


def test_histograms_counts(traces):
    params, tr = traces
    h = C.activation_histograms(tr, CFG)
    assert len(h["edges"]) == 42
    for l, entry in h["layers"].items():
        for k in ("a_gate", "a_up", "a_down"):
            assert len(entry[k]) == 41
            assert sum(entry[k]) > 0

"""AOT path: HLO text well-formedness and export/manifest integrity on a
small config (full-size artifacts are produced by `make artifacts`)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, calibrate as C, corpus
from compile.configs import QuantConfig, get_config
from compile.export import export_artifacts
from compile.model import attn_step_fn, expert_sparse_fn, init_params

CFG = get_config("test")
QCFG = QuantConfig()


def test_lower_expert_hlo_text():
    d, f = CFG.d_model, CFG.d_ff
    text = aot.lower(expert_sparse_fn(CFG),
                     aot.f32(1, d), aot.f32(d, f), aot.f32(d, f),
                     aot.f32(f, d), aot.f32())
    assert "ENTRY" in text
    assert "HloModule" in text


def test_lower_attn_hlo_text():
    d, h, hd, s, e = (CFG.d_model, CFG.n_heads, CFG.head_dim,
                      CFG.max_seq, CFG.n_experts)
    text = aot.lower(attn_step_fn(CFG),
                     aot.f32(1, d), aot.f32(1, h, s, hd), aot.f32(1, h, s, hd),
                     aot.i32(), aot.f32(d, d), aot.f32(d, d), aot.f32(d, d),
                     aot.f32(d, d), aot.f32(d), aot.f32(d), aot.f32(d, e))
    assert "ENTRY" in text
    # the tuple return convention the Rust loader relies on
    assert "tuple" in text


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("art"))
    params = init_params(CFG, seed=0)
    _, ev = corpus.train_eval_split(60_000)
    tr = C.collect_traces(params, CFG, ev, batch=2, seq=48, n_chunks=1)
    th = C.thresholds_from_traces(tr, CFG)
    ws, bs, hits = C.train_inter_predictor(tr, CFG, steps=50)
    up_q = C.quantize_all_up(params, CFG, QCFG)
    calib = {"thresholds": th,
             "predictor": {"weights": ws, "biases": bs, "hit_rate": hits},
             "up_q": up_q,
             "analysis": {"fig4_cosine_similarity": C.cosine_similarity(tr, CFG),
                          "fig4_inter_predictor_precision": hits,
                          "fig4_intra_predictor_recall": [],
                          "fig2_histograms": {}}}
    bin_path, man_path = export_artifacts(out, params, CFG, QCFG, calib)
    return params, bin_path, man_path


def test_manifest_tensor_index(exported):
    params, bin_path, man_path = exported
    man = json.load(open(man_path))
    blob = open(bin_path, "rb").read()
    assert man["config"]["d_model"] == CFG.d_model
    # every tensor's extent lies inside the blob and offsets are 8-aligned
    for name, t in man["tensors"].items():
        assert t["offset"] % 8 == 0, name
        assert t["offset"] + t["nbytes"] <= len(blob), name
    # spot-check round trip of a tensor
    t = man["tensors"]["layer0.expert0.wg"]
    arr = np.frombuffer(blob, np.float32,
                        count=t["nbytes"] // 4, offset=t["offset"]
                        ).reshape(t["shape"])
    np.testing.assert_array_equal(arr, np.asarray(params["layer0.wg"][0]))


def test_manifest_has_all_quant_variants(exported):
    _, _, man_path = exported
    man = json.load(open(man_path))
    names = man["tensors"]
    for bits in (8, 4, 3, 2, 1):
        for proj in ("wg", "wu", "wd"):
            key = f"layer0.expert0.q{bits}.{proj}"
            assert key in names and key + "_scale" in names, key
    assert "layer0.expert0.up_q" in names
    # packed int2: d/4 rows
    assert names["layer0.expert0.up_q"]["shape"] == [CFG.d_model // 4, CFG.d_ff]


def test_thresholds_json_shape(exported):
    _, _, man_path = exported
    man = json.load(open(man_path))
    th = man["thresholds"]
    assert len(th["up"]) == CFG.n_layers
    assert len(th["up"][0]) == CFG.n_experts
    assert len(th["up"][0][0]) == len(th["levels"])

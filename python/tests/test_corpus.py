"""Corpus determinism + probe-task well-formedness (Rust evaluates the same
seeded instances, so determinism across runs is load-bearing)."""

import numpy as np

from compile import corpus


def test_corpus_deterministic():
    a = corpus.build_corpus(30_000, seed=1234)
    b = corpus.build_corpus(30_000, seed=1234)
    assert a == b
    c = corpus.build_corpus(30_000, seed=99)
    assert a != c


def test_corpus_ascii_printable():
    data = corpus.build_corpus(20_000)
    assert all(32 <= b < 127 for b in data)


def test_split_sizes():
    tr, ev = corpus.train_eval_split(50_000)
    assert len(ev) > 3_000
    assert abs(len(tr) / (len(tr) + len(ev)) - 0.9) < 0.01


def test_probe_instances_deterministic_and_scored():
    for task in corpus.PROBES:
        a = corpus.probe_instances(task, 20, seed=7)
        b = corpus.probe_instances(task, 20, seed=7)
        assert a == b
        for prompt, completion in a:
            assert completion.endswith(".")
            assert 0 < len(completion) <= 16


def test_fact_consistency():
    """Every occurrence of a city maps to the same capital."""
    for p, c in corpus.probe_instances("fact", 50, seed=3):
        city = p.split("of ")[1].split(" is")[0]
        i = corpus.CITIES.index(city)
        assert c == corpus.CAPS[i] + "."


def test_bracket_balanced():
    for p, c in corpus.probe_instances("bracket", 50, seed=4):
        s = p.replace("match ", "") + c[:-1]
        stack = []
        pairs = {")": "(", "]": "[", "}": "{"}
        for ch in s:
            if ch in "([{":
                stack.append(ch)
            else:
                assert stack and stack.pop() == pairs[ch]
        assert not stack

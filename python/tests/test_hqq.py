"""HQQ quantizer properties: code range, reconstruction quality vs bits,
packing layout, and the transfer-size accounting the paper's 9.3x
compression claim rests on."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import QuantConfig
from compile.hqq import QuantizedTensor, quant_error, quantize


@settings(max_examples=8, deadline=None)
@given(d=st.sampled_from([32, 64]),
       f=st.sampled_from([32, 128]),
       bits=st.sampled_from([8, 4, 3, 2, 1]),
       seed=st.integers(0, 2 ** 16))
def test_codes_in_range(d, f, bits, seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((d, f)).astype(np.float32) * 0.1
    qt = quantize(w, bits)
    assert qt.codes.min() >= 0
    assert qt.codes.max() <= 2 ** bits - 1
    assert qt.codes.shape == (d, f)
    assert qt.scale.shape == (d // qt.group_size, f)


def test_error_monotonic_in_bits():
    rng = np.random.default_rng(0)
    w = rng.standard_normal((64, 128)).astype(np.float32) * 0.2
    errs = [quant_error(w, quantize(w, b))[0] for b in (8, 4, 3, 2, 1)]
    assert errs == sorted(errs), errs
    assert errs[0] < 0.01            # INT8 is near-lossless
    assert errs[3] < 0.55            # INT2 with HQQ stays usable


def test_hqq_beats_roundtrip_minmax_int2():
    """The proximal solver should not be worse than naive min-max init."""
    rng = np.random.default_rng(1)
    w = rng.standard_normal((64, 128)).astype(np.float32) * 0.3
    qcfg = QuantConfig()
    hqq = quant_error(w, quantize(w, 2, qcfg))[0]

    # naive min/max affine INT2, same grouping
    g = qcfg.group_size
    wg = w.reshape(-1, g, w.shape[1])
    wmin, wmax = wg.min(1, keepdims=True), wg.max(1, keepdims=True)
    s = 3.0 / np.maximum(wmax - wmin, 1e-8)
    z = -wmin * s
    q = np.clip(np.round(wg * s + z), 0, 3)
    naive_dq = ((q - z) / s).reshape(w.shape)
    naive = float(np.linalg.norm(naive_dq - w) / np.linalg.norm(w))
    assert hqq <= naive * 1.02


def test_packed_int2_layout():
    rng = np.random.default_rng(2)
    w = rng.standard_normal((32, 16)).astype(np.float32)
    qt = quantize(w, 2)
    packed = qt.packed_int2()
    assert packed.shape == (8, 16)
    # unpack manually and compare
    un = np.zeros((32, 16), np.uint8)
    for k, s in enumerate((0, 2, 4, 6)):
        un[k::4] = (packed >> s) & 3
    np.testing.assert_array_equal(un, qt.codes)


def test_transfer_bytes_accounting():
    qt = quantize(np.ones((64, 128), np.float32), 2)
    # 64*128 int2 = 2048 B codes + 2 * (2 groups * 128) fp16 = 1024 B
    assert qt.nbytes_transfer() == 64 * 128 // 4 + 2 * 2 * (64 // 32) * 128


def test_compression_ratio_vs_fp16():
    """Paper §1: ~9.3x per-expert compression (INT2 up + 90%-sparse
    gate/down vs 3 fp16 matrices).  Check the arithmetic at our scale."""
    d, f = 64, 128
    fp16 = 3 * d * f * 2
    qt = quantize(np.random.default_rng(3).standard_normal((d, f))
                  .astype(np.float32), 2)
    sparse_gd = 2 * int(0.1 * f) * d * 2          # 10% of channels, fp16
    floe = qt.nbytes_transfer() + sparse_gd
    ratio = fp16 / floe
    assert ratio > 6.0, ratio

"""L1 correctness: Pallas kernels vs pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, thresholds and data; every kernel must match the
reference to float32 tolerance for all of them.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.quant import dequant_int2_pallas, int2_matmul_pallas
from compile.kernels.sparse_expert import floe_expert_pallas, sparse_expert_pallas

TOL = dict(rtol=2e-5, atol=2e-5)


def rand_expert(rng, b, d, f):
    x = jnp.asarray(rng.standard_normal((b, d)), jnp.float32)
    wg = jnp.asarray(rng.standard_normal((d, f)) * 0.2, jnp.float32)
    wu = jnp.asarray(rng.standard_normal((d, f)) * 0.2, jnp.float32)
    wd = jnp.asarray(rng.standard_normal((f, d)) * 0.2, jnp.float32)
    return x, wg, wu, wd


@settings(max_examples=12, deadline=None)
@given(b=st.sampled_from([1, 2, 4]),
       d=st.sampled_from([32, 64]),
       f=st.sampled_from([64, 128]),
       block_f=st.sampled_from([16, 32]),
       t=st.floats(0.0, 3.0),
       seed=st.integers(0, 2 ** 16))
def test_sparse_expert_matches_ref(b, d, f, block_f, t, seed):
    rng = np.random.default_rng(seed)
    x, wg, wu, wd = rand_expert(rng, b, d, f)
    out = sparse_expert_pallas(x, wg, wu, wd, t, block_f=block_f)
    exp = ref.sparse_expert(x, wg, wu, wd, t)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), **TOL)


def test_sparse_expert_t0_equals_dense():
    rng = np.random.default_rng(0)
    x, wg, wu, wd = rand_expert(rng, 2, 64, 128)
    out = sparse_expert_pallas(x, wg, wu, wd, 0.0)
    exp = ref.dense_expert(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), **TOL)


def test_sparse_expert_huge_t_is_zero():
    rng = np.random.default_rng(1)
    x, wg, wu, wd = rand_expert(rng, 1, 32, 64)
    out = sparse_expert_pallas(x, wg, wu, wd, 1e9)
    assert float(jnp.abs(out).max()) == 0.0


@settings(max_examples=10, deadline=None)
@given(d=st.sampled_from([32, 64]),
       f=st.sampled_from([64, 128]),
       g=st.sampled_from([16, 32]),
       seed=st.integers(0, 2 ** 16))
def test_int2_pack_unpack_roundtrip(d, f, g, seed):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 4, (d, f)), jnp.uint8)
    packed = ref.pack_int2(codes)
    assert packed.shape == (d // 4, f)
    un = ref.unpack_int2(packed)
    np.testing.assert_array_equal(np.asarray(un), np.asarray(codes))


@settings(max_examples=10, deadline=None)
@given(b=st.sampled_from([1, 3]),
       d=st.sampled_from([32, 64]),
       f=st.sampled_from([64, 128]),
       g=st.sampled_from([16, 32]),
       seed=st.integers(0, 2 ** 16))
def test_int2_matmul_pallas_matches_ref(b, d, f, g, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((b, d)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, 4, (d, f)), jnp.uint8)
    packed = ref.pack_int2(codes)
    scale = jnp.asarray(rng.random((d // g, f)) * 0.2 + 0.01, jnp.float32)
    zero = jnp.asarray(rng.random((d // g, f)) * 3, jnp.float32)
    out = int2_matmul_pallas(x, packed, scale, zero, group_size=g)
    exp = ref.int2_matmul(x, packed, scale, zero, g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


def test_dequant_pallas_exact():
    rng = np.random.default_rng(5)
    d, f, g = 64, 96, 32
    codes = jnp.asarray(rng.integers(0, 4, (d, f)), jnp.uint8)
    packed = ref.pack_int2(codes)
    scale = jnp.asarray(rng.random((d // g, f)) + 0.01, jnp.float32)
    zero = jnp.asarray(rng.random((d // g, f)), jnp.float32)
    out = dequant_int2_pallas(packed, scale, zero, group_size=g)
    exp = ref.dequant_groupwise(ref.unpack_int2(packed).astype(jnp.float32),
                                scale, zero, g)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@settings(max_examples=8, deadline=None)
@given(b=st.sampled_from([1, 2]),
       d=st.sampled_from([32, 64]),
       f=st.sampled_from([64, 128]),
       t=st.floats(0.0, 2.0),
       seed=st.integers(0, 2 ** 16))
def test_floe_expert_pallas_matches_ref(b, d, f, t, seed):
    g = 32
    rng = np.random.default_rng(seed)
    x, wg, _, wd = rand_expert(rng, b, d, f)
    codes = jnp.asarray(rng.integers(0, 4, (d, f)), jnp.uint8)
    packed = ref.pack_int2(codes)
    scale = jnp.asarray(rng.random((d // g, f)) * 0.1 + 0.01, jnp.float32)
    zero = jnp.asarray(rng.random((d // g, f)) * 3, jnp.float32)
    out = floe_expert_pallas(x, wg, packed, scale, zero, wd, t, group_size=g)
    exp = ref.floe_expert(x, wg, packed, scale, zero, wd, t, g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), **TOL)


def test_sparsify_matches_masking():
    """Eq. (11) (mask form) == Eq. (5) composition (sparsify form)."""
    rng = np.random.default_rng(9)
    x, wg, wu, wd = rand_expert(rng, 2, 32, 64)
    t = 0.4
    a = ref.silu(x @ wg) * ref.sparsify(x @ wu, t)
    exp = a @ wd
    out = ref.sparse_expert(x, wg, wu, wd, t)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), **TOL)


def test_router_topk_weights():
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.standard_normal((5, 8)), jnp.float32)
    w, idx = ref.router_topk(logits, 2)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), np.ones(5), rtol=1e-6)
    # indices are the argmax-2
    order = np.argsort(-np.asarray(logits), axis=1)[:, :2]
    np.testing.assert_array_equal(np.sort(np.asarray(idx), 1), np.sort(order, 1))

#!/usr/bin/env python3
"""Python replay of rust/src/coordinator/sim.rs (post-placement-redesign).

No Rust toolchain exists in the authoring container, so deterministic
test margins are validated by replaying the exact seeded RNG / store /
roofline pipeline here before the assertions are committed. This mirrors
the REDESIGNED code (placement-aware store, transfer plans, coalescing,
sparsity admission filter); bit-for-bit equivalence of the single-device
path against the pre-redesign semantics is pinned in Rust itself by
tests/shard_store.rs (simulate vs simulate_scalar_reference), which
needs no cross-language float reasoning.

Checks replayed here (see main()):
  * tests in experiments/shard.rs: coalesced vs independent at 2 devices
    (equal bytes, fewer bus transactions, tps), 2-device vs 1-device tps
  * popularity-placement margins (PR 4): balanced re-homing + top-k
    replication + per-device compute streams vs static hash at 2 devices
    (tps ratio, max-device bus busy), and streams-on vs streams-off FLOP
    scaling for the same config
  * coordinator/sim.rs::sparsity_policy_hit_rate_not_worse_at_tight_vram
    under the new admission filter
  * sanity: fig6 ordering relations (replay fidelity check against the
    long-standing assertions)
"""

MASK = (1 << 64) - 1


def rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    def __init__(self, seed):
        st = seed & MASK
        s = []
        for _ in range(4):
            st = (st + 0x9E3779B97F4A7C15) & MASK
            z = st
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
            s.append(z ^ (z >> 31))
        self.s = s

    def next_u64(self):
        s = self.s
        r = (rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = rotl(s[3], 45)
        return r

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n):
        return int(self.f64() * n) % n


# ---- hwsim constants (RTX3090 / PCIE4 / P2P / EPYC64 / Mixtral dims) ----
HBM, EFF, LAUNCH, DISPATCH, FP16_TF = 936.0, 0.70, 9.0, 12.0, 71.0
PCIE_GBPS, PCIE_API = 25.6, 12.0
P2P_GBPS, P2P_API = 50.0, 6.0
NET_GBPS, NET_API = 1.6, 150.0  # hwsim::NET_LINK (latency-dominated)
CPU_GFLOPS = 95.0
DM, DFF, NL, NE, TOPK = 4096, 14336, 32, 8, 2


def bw():
    return HBM * EFF * 1e3


def expert_bytes_fp16():
    return 3.0 * DM * DFF * 2.0


def up_int2_bytes():
    n = float(DM) * DFF
    return n / 4.0 + 2.0 * 2.0 * (n / 64.0)


def floe_transfer_bytes(level):
    return 2.0 * (1.0 - level) * DM * DFF * 2.0


def expert_bytes_quant(bits):
    return 3.0 * DM * DFF * bits / 8.0 + 3.0 * 2.0 * 2.0 * (DM * DFF / 64.0)


def attn_bytes_fp16():
    return 2.5 * DM * DM * 2.0


def expert_dense_us():
    return expert_bytes_fp16() / bw() + 4.0 * LAUNCH + DISPATCH


def expert_floe_us(s):
    up = up_int2_bytes()
    gd = 2.0 * (1.0 - s) * DM * DFF * 2.0
    return (up + gd) / bw() + 3.0 * LAUNCH + DISPATCH


def expert_quant_us(bits):
    return expert_bytes_quant(bits) / bw() + 4.0 * LAUNCH + DISPATCH


def attn_layer_us(kv_len):
    kv_bytes = 2.0 * kv_len * DM * 2.0
    return (attn_bytes_fp16() + kv_bytes) / bw() + 6.0 * LAUNCH


def cpu_expert_us():
    return 2.0 * 3.0 * DM * DFF / (CPU_GFLOPS * 1e3)


def pcie_copy_us(bytes_):
    return bytes_ / (PCIE_GBPS * 1e3) + PCIE_API


def p2p_copy_us(bytes_):
    return bytes_ / (P2P_GBPS * 1e3) + P2P_API


def net_copy_us(bytes_):
    return bytes_ / (NET_GBPS * 1e3) + NET_API


# ---------------------------------------------------------------- systems
FLOE, NAIVE, ADV, FIDDLER, GPU = "floe", "naive", "adv", "fiddler", "gpu"

# --overlap: refuse speculative prefetch once the bus queue is this deep
# (store/prefetch.rs::PREFETCH_BACKLOG_US)
PREFETCH_BACKLOG_US = 2000.0


class System:
    def __init__(self, kind, residency="lru", devices=1, shard="layer",
                 coalesce=None, spill=None, replicate_top=0, compute_streams=False,
                 overlap=False, little_frac=0.0):
        self.kind = kind
        self.sparsity = 0.9
        self.quant_bits = 3
        self.intra_margin = 0.15
        self.residency = residency
        self.devices = devices
        self.shard = shard
        self.coalesce = (devices > 1) if coalesce is None else coalesce
        self.spill = (devices > 1) if spill is None else spill
        self.replicate_top = replicate_top if devices > 1 else 0
        self.compute_streams = compute_streams and devices > 1
        # event-driven compute/transfer overlap (PR 6): a layer's experts
        # resolve upfront, GEMVs dispatch in transfer-readiness order
        self.overlap = overlap
        # quality-elastic fallback (PR 9): fraction of each device budget
        # carved into the always-resident little-tier pool
        self.little_frac = little_frac


class Params:
    def __init__(self, system, vram_gb, zipf_s=0.6, stickiness=0.35, seed=7):
        self.system = system
        self.vram_gb = vram_gb
        self.inter_hit = 0.88
        self.intra_recall = 0.95
        self.adv_prefetch_hit = 0.75
        self.zipf_s = zipf_s
        self.stickiness = stickiness
        self.seed = seed


def transfer_bytes(p):
    k = p.system.kind
    if k == FLOE:
        return floe_transfer_bytes(p.system.sparsity) * (1.0 + p.system.intra_margin)
    if k == NAIVE:
        return expert_bytes_fp16()
    if k == ADV:
        return expert_bytes_quant(float(p.system.quant_bits))
    return 0.0


def cached_bytes(p):
    k = p.system.kind
    if k == FLOE:
        return int(floe_transfer_bytes(p.system.sparsity))
    if k == NAIVE:
        return int(expert_bytes_fp16())
    if k == ADV:
        return int(expert_bytes_quant(float(p.system.quant_bits)))
    if k == FIDDLER:
        return int(expert_bytes_fp16())
    return int(expert_bytes_quant(2.0))


def expert_compute_us(p):
    k = p.system.kind
    if k == FLOE:
        return expert_floe_us(p.system.sparsity)
    if k == NAIVE:
        return expert_dense_us()
    if k == ADV:
        return expert_quant_us(float(p.system.quant_bits))
    if k == FIDDLER:
        return expert_dense_us()
    return expert_quant_us(2.0)


def cache_budget_bytes(p, kv_tokens):
    attn = NL * attn_bytes_fp16()
    embed = 2.0 * 32000.0 * DM * 2.0
    kv = NL * 2.0 * kv_tokens * DM * 2.0
    resident = attn + embed + kv + 1e9
    if p.system.kind == FLOE:
        resident += NL * NE * up_int2_bytes()
    return max(p.vram_gb * 1e9 - resident, 0.0)


def zipf_cdf(n, s):
    w = [1.0 / ((k + 1) ** s) for k in range(n)]
    for i in range(1, n):
        w[i] += w[i - 1]
    return w


def partition_point(w, r):
    # w.partition_point(|x| *x < r): count of leading elements < r
    lo, hi = 0, len(w)
    while lo < hi:
        mid = (lo + hi) // 2
        if w[mid] < r:
            lo = mid + 1
        else:
            hi = mid
    return lo


def sample_routing(p, rng, prev, weights):
    out = []
    for l in range(NL):
        chosen = []
        for slot in range(TOPK):
            if prev[l] and rng.f64() < p.stickiness:
                e = prev[l][slot]
            else:
                while True:
                    r = rng.f64() * weights[NE - 1]
                    e = min(partition_point(weights, r), NE - 1)
                    if e not in chosen:
                        break
            if e in chosen:
                alt = (e + 1 + rng.below(NE - 1)) % NE
                chosen.append(alt)
            else:
                chosen.append(e)
        prev[l] = list(chosen)
        out.append(chosen)
    return out


# ------------------------------------------------------------ policies
class LruPolicy:
    def __init__(self):
        self.last_use = {}

    def on_activation(self, key, now):
        pass

    def on_hit(self, key, now):
        self.last_use[key] = now

    def on_insert(self, key, now):
        self.last_use[key] = now

    def on_remove(self, key):
        self.last_use.pop(key, None)

    def victim(self, candidates):
        if not candidates:
            return None
        return min(candidates, key=lambda k: self.last_use.get(k, 0))

    def admits(self, key):
        return True


class SparsityPolicy:
    def __init__(self, decay=0.999, min_admit=1.5):
        self.decay = decay
        self.min_admit = min_admit
        self.step = 0
        self.ema = {}
        self.stamp = {}
        self.last_use = {}

    def score(self, key):
        if key not in self.ema:
            return 0.0
        return self.ema[key] * (self.decay ** float(self.step - self.stamp[key]))

    def on_activation(self, key, now):
        self.step += 1
        self.ema[key] = self.score(key) + 1.0
        self.stamp[key] = self.step

    def on_hit(self, key, now):
        self.last_use[key] = now

    def on_insert(self, key, now):
        self.last_use[key] = now

    def on_remove(self, key):
        self.last_use.pop(key, None)

    def victim(self, candidates):
        if not candidates:
            return None
        return min(candidates, key=lambda k: (self.score(k), self.last_use.get(k, 0)))

    def admits(self, key):
        return self.score(key) >= self.min_admit


class ResidentSet:
    def __init__(self, budget, policy):
        self.budget = budget
        self.used = 0
        self.clock = 0
        self.entries = {}  # key -> [bytes, pinned]
        self.policy = policy
        self.hits = 0
        self.misses = 0

    def contains(self, key):
        return key in self.entries

    def bytes_of(self, key):
        return self.entries[key][0] if key in self.entries else None

    def free_bytes(self):
        return self.budget - self.used

    def note_activation(self, key):
        self.policy.on_activation(key, self.clock)

    def access(self, key):
        self.clock += 1
        if key in self.entries:
            self.policy.on_hit(key, self.clock)
            self.hits += 1
            return True
        self.misses += 1
        return False

    def insert_evicting(self, key, bytes_):
        self.clock += 1
        evicted = []
        if key in self.entries:
            self.used -= self.entries.pop(key)[0]
            self.policy.on_remove(key)
        if bytes_ > self.budget:
            return False, evicted
        while self.used + bytes_ > self.budget:
            cands = [k for k, e in self.entries.items() if not e[1]]
            v = self.policy.victim(cands)
            if v is None:
                return False, evicted
            vb = self.entries.pop(v)[0]
            self.used -= vb
            self.policy.on_remove(v)
            evicted.append((v, vb))
        self.used += bytes_
        self.entries[key] = [bytes_, False]
        self.policy.on_insert(key, self.clock)
        return True, evicted

    def remove(self, key):
        if key not in self.entries:
            return None
        b = self.entries.pop(key)[0]
        self.used -= b
        self.policy.on_remove(key)
        return b

    def set_pinned(self, key, pinned):
        if key in self.entries:
            self.entries[key][1] = pinned


def make_policy(kind):
    return SparsityPolicy() if kind == "sparsity" else LruPolicy()


class Store:
    """Placement-aware store mirror (virtual clock)."""

    def __init__(self, system, budget_per_device):
        n = max(system.devices, 1)
        self.system = system
        # PR 8 satellite: replicas are carved OUT of the cache budget —
        # with replication on the resident set runs on budget - replica
        # pool, so resident + replica bytes never exceed the device budget
        self.replica_budget = int(budget_per_device * 0.05)
        # PR 9: the little tier is carved out of the budget too, so
        # resident + replica + little bytes never exceed the device budget
        self.little_budget = (int(budget_per_device * system.little_frac)
                              if system.little_frac > 0.0 else 0)
        resident_budget = (budget_per_device - self.replica_budget
                           if system.replicate_top > 0 else budget_per_device)
        resident_budget = max(resident_budget - self.little_budget, 0)
        self.devices = [ResidentSet(resident_budget, make_policy(system.residency))
                        for _ in range(n)]
        self.little_pools = [set() for _ in range(n)]
        self.little_bytes = [0] * n
        self.degraded_hits = 0
        self.degraded_bytes = 0.0
        self.bus_free = [0.0] * n
        self.bus_busy = [0.0] * n
        self.inflight = {}
        self.now = 0.0
        self.stall_us = 0.0
        # attributed split (StoreStats::stall_demand_us / stall_prefetch_us)
        self.stall_demand = 0.0
        self.stall_prefetch = 0.0
        self.demand_fetches = 0
        # priority demand lane (--overlap): critical copies serialize
        # among themselves here instead of queueing behind speculative
        # prefetch traffic on bus_free
        self.demand_free = [0.0] * n
        self.prefetches = 0
        self.bus_transactions = 0
        self.transferred_bytes = 0.0
        # popularity machinery (PR 4): store-wide decayed activation mass,
        # balanced home overlay, hot-expert replicas
        self.pop_decay = 0.999
        self.pop_step = 0
        self.pop_ema = {}
        self.pop_stamp = {}
        self.home_map = {}
        self.replicas = {}
        self.replica_bytes = [0] * n
        self.boundary_ticks = 0
        self.rebalances = 0
        self.writebacks = 0
        # cluster member dimension (PR 8): this store is node `node_id`
        # of an `n_nodes` cluster with one local host-RAM expert pool
        self.n_nodes = 1
        self.node_id = 0
        self.host_pool = set()
        self.host_bytes = 0
        self.host_budget = int(64e9)
        self.net_pulls = 0
        self.net_bytes = 0.0
        # fault schedule (PR 10, mirror of store/mod.rs §12): link
        # bandwidth windows, bounded-backoff retry, per-requester fault
        # causes and the dead-device mask. All default to the fault-free
        # identity so PR 9 traces reprice bit-exactly.
        self.link_windows = []      # (link, factor, t0_us, t1_us)
        self.retry_policy = None    # (max_attempts, backoff_base_us)
        self.retries = 0
        self.fault_causes = {}      # rid -> cause string (first wins)
        self.dead = [False] * n

    def pop_note(self, key):
        self.pop_step += 1
        self.pop_ema[key] = self.pop_mass(key) + 1.0
        self.pop_stamp[key] = self.pop_step

    def pop_mass(self, key):
        if key not in self.pop_ema:
            return 0.0
        return self.pop_ema[key] * (self.pop_decay
                                    ** float(self.pop_step - self.pop_stamp[key]))

    def masses(self):
        out = [(k, self.pop_mass(k)) for k in sorted(self.pop_ema)]
        out.sort(key=lambda kv: (-kv[1], kv[0]))
        return out

    def home(self, key):
        n = len(self.devices)
        if n <= 1:
            return 0
        l, e = key
        # the overlay is written by Balanced re-homing and by replica
        # write-back promotion (any placement with replication on)
        if self.system.shard == "balanced" or self.system.replicate_top > 0:
            if key in self.home_map:
                return self.live_home(self.home_map[key])
        if self.system.shard == "balanced":
            return self.live_home(e % n)  # cold-start seed (expert-style)
        if self.system.shard == "layer":
            return self.live_home(l % n)
        if self.system.shard == "expert":
            return self.live_home(e % n)
        return self.live_home(((l * 0x9E3779B1) + e * 0x85EBCA77) % n)

    def live_home(self, dev):
        """ExpertStore::live_home: a key whose assigned home dropped
        resolves to the next alive device in id order — the identity
        with no faults (the dead mask is all-false)."""
        if not self.dead[dev]:
            return dev
        n = len(self.devices)
        for step in range(1, n):
            d = (dev + step) % n
            if not self.dead[d]:
                return d
        return dev

    def is_pinned(self, dev, key):
        e = self.devices[dev].entries.get(key)
        return bool(e and e[1])

    def copy_batch(self, dev, items, coalesce):
        if not items:
            return self.now
        if not coalesce:
            done = self.now
            for bytes_, dur, _ in items:
                done = self.bus_copy_to(dev, dur, bytes_)
            return done
        ovh = max(it[2] for it in items)
        start = max(self.now, self.bus_free[dev])
        t = start + ovh
        self.bus_transactions += 1
        self.bus_busy[dev] += ovh
        for bytes_, dur, o in items:
            net = max(dur - o, 0.0)
            t += net
            self.transferred_bytes += bytes_
            self.bus_busy[dev] += net
        self.bus_free[dev] = t
        return t

    def rebalance_tick(self):
        if self.system.shard != "balanced" and self.system.replicate_top == 0:
            return
        self.boundary_ticks += 1
        if self.boundary_ticks % 128 != 0 or not self.pop_ema:
            return
        self.rebalances += 1
        if self.system.shard == "balanced":
            self.rebalance_homes()
        if self.system.replicate_top > 0:
            self.refresh_replicas()

    def rebalance_homes(self):
        n = len(self.devices)
        if n <= 1:
            return
        masses = self.masses()
        total = sum(m for _, m in masses)
        if total <= 0.0:
            return
        load = [0.0] * n
        homes = []
        for key, mass in masses:
            h = self.home(key)
            homes.append(h)
            load[h] += mass
        moves = []
        for _ in range(len(masses)):
            hi = lo = 0
            for d in range(1, n):
                if load[d] > load[hi]:
                    hi = d
                if load[d] < load[lo]:
                    lo = d
            gap = load[hi] - load[lo]
            if gap <= total * 0.02:
                break
            movable = lambda key: (not self.is_pinned(hi, key)
                                   and (hi, key) not in self.inflight)
            pick = None
            for i, (key, mass) in enumerate(masses):
                if homes[i] == hi and mass <= gap * 0.5 and movable(key):
                    pick = i
                    break
            if pick is None:
                for i in range(len(masses) - 1, -1, -1):
                    key, mass = masses[i]
                    if homes[i] == hi and mass < gap and movable(key):
                        pick = i
                        break
            if pick is None:
                break
            key, mass = masses[pick]
            homes[pick] = lo
            load[hi] -= mass
            load[lo] += mass
            self.home_map[key] = lo
            self.replicas.pop(key, None)
            if self.devices[hi].contains(key):
                moves.append((key, hi, lo))
        per_dst = [[] for _ in range(n)]
        for key, old, new in moves:
            bytes_ = self.devices[old].bytes_of(key)
            if bytes_ is None:
                continue
            if self.devices[new].free_bytes() < bytes_:
                continue
            self.devices[old].remove(key)
            self.devices[new].insert_evicting(key, bytes_)
            b = max(float(bytes_), 1.0)
            per_dst[new].append((float(bytes_), p2p_copy_us(b), P2P_API))
        for dst, items in enumerate(per_dst):
            if items:
                self.copy_batch(dst, items, self.system.coalesce)

    def refresh_replicas(self):
        n = len(self.devices)
        if n <= 1:
            return
        top = self.masses()[: self.system.replicate_top]
        total_mass = sum(m for _, m in top)
        old = self.replicas
        self.replicas = {}
        self.replica_bytes = [0] * n
        if total_mass <= 0.0:
            return
        pool = float(self.replica_budget) * n
        per_dst = [[] for _ in range(n)]
        for key, mass in top:
            home = self.home(key)
            bytes_ = self.devices[home].bytes_of(key)
            if bytes_ is None or bytes_ == 0 or bytes_ > self.replica_budget:
                continue
            copies = min(int(pool * (mass / total_mass) / bytes_), n - 1)
            if copies == 0:
                continue
            peers = sorted((d for d in range(n) if d != home),
                           key=lambda d: (self.replica_bytes[d], d))
            placed = []
            for d in peers[:copies]:
                if self.replica_bytes[d] + bytes_ > self.replica_budget:
                    continue
                self.replica_bytes[d] += bytes_
                if not (key in old and d in old[key][1]):
                    b = max(float(bytes_), 1.0)
                    per_dst[d].append((float(bytes_), p2p_copy_us(b), P2P_API))
                placed.append(d)
            if placed:
                self.replicas[key] = (bytes_, placed)
        for dst, items in enumerate(per_dst):
            if items:
                self.copy_batch(dst, items, self.system.coalesce)

    def tick(self, us):
        self.now += us

    def advance_to(self, t):
        if t > self.now:
            self.now = t

    def stall_until(self, t, cause="demand"):
        if t > self.now:
            d = t - self.now
            self.stall_us += d
            if cause == "prefetch":
                self.stall_prefetch += d
            else:
                self.stall_demand += d
            self.now = t

    def charge_stall(self, cause, d):
        """Stream-path stall (no clock advance) with attribution."""
        self.stall_us += d
        if cause == "prefetch":
            self.stall_prefetch += d
        else:
            self.stall_demand += d

    def lookup(self, key):
        home = self.home(key)
        if self.system.shard == "balanced" or self.system.replicate_top > 0:
            self.pop_note(key)
        self.devices[home].note_activation(key)
        home_resident = self.devices[home].contains(key)
        if self.system.replicate_top > 0:
            holders = []
            if home_resident:
                holders.append(home)
            for d in self.replicas.get(key, (0, []))[1]:
                if d != home:
                    holders.append(d)
            if holders:
                best = holders[0]
                for d in holders[1:]:
                    if self.bus_free[d] < self.bus_free[best]:
                        best = d
                if best == home:
                    self.devices[home].access(key)
                else:
                    if home_resident:
                        # replica served the access: keep the home copy's
                        # policy recency fresh (mirror ResidentSet::touch)
                        dh = self.devices[home]
                        dh.clock += 1
                        dh.policy.on_hit(key, dh.clock)
                    self.devices[best].hits += 1
                return ("local", best)
        if home_resident:
            self.devices[home].access(key)
            return ("local", home)
        for d in range(len(self.devices)):
            if d != home and self.devices[d].contains(key):
                self.devices[d].access(key)
                return ("remote", d)
        self.devices[home].access(key)
        return ("miss", None)

    def bus_copy_to(self, dev, dur, bytes_):
        self.transferred_bytes += bytes_
        self.bus_transactions += 1
        self.bus_busy[dev] += dur
        start = max(self.now, self.bus_free[dev])
        done = start + dur
        self.bus_free[dev] = done
        return done

    def priority_copy_to(self, dev, dur, bytes_):
        # demand lane: jumps the queued speculative prefetch traffic but
        # serializes with other critical copies; the bus time it occupies
        # still pushes the prefetch queue back by `dur`
        self.transferred_bytes += bytes_
        self.bus_transactions += 1
        self.bus_busy[dev] += dur
        start = max(self.now, self.demand_free[dev])
        done = start + dur
        self.demand_free[dev] = done
        self.bus_free[dev] = max(self.bus_free[dev], self.now) + dur
        return done

    def critical_copy_to(self, dev, dur, bytes_):
        """On-critical-path copy (demand fetch / intra top-up): under
        --overlap it rides the priority lane, preempting queued
        speculative prefetch; otherwise FIFO with everything else."""
        if self.system.overlap:
            return self.priority_copy_to(dev, dur, bytes_)
        return self.bus_copy_to(dev, dur, bytes_)

    def demand_to(self, dev, dur, bytes_):
        self.demand_fetches += 1
        return self.critical_copy_to(dev, dur, bytes_)

    def submit(self, dst, mode, items):
        # items: (key, bytes, dur, ovh)
        if mode == "overlapped":
            for key, b, dur, _ in items:
                if (self.system.overlap
                        and self.bus_free[dst] - self.now > PREFETCH_BACKLOG_US):
                    # bounded speculative backlog (--overlap): prefetch is
                    # best-effort; refusing copies once the queue is this
                    # deep breaks the evict-before-use reissue storm at
                    # thrash-depth VRAM
                    continue
                self.prefetches += 1
                done = self.bus_copy_to(dst, dur, b)
                self.inflight[(dst, key)] = done
                self.devices[dst].set_pinned(key, True)
        elif mode == "coalesced":
            ovh = max(it[3] for it in items)
            start = max(self.now, self.bus_free[dst])
            t = start + ovh
            self.bus_transactions += 1
            self.bus_busy[dst] += ovh
            for key, b, dur, o in items:
                net = max(dur - o, 0.0)
                t += net
                self.prefetches += 1
                self.transferred_bytes += b
                self.bus_busy[dst] += net
                self.inflight[(dst, key)] = t
            self.bus_free[dst] = t
            for key, _, _, _ in items:
                self.devices[dst].set_pinned(key, True)
        else:  # blocking
            for key, b, dur, _ in items:
                self.prefetches += 1
                self.transferred_bytes += b
                self.bus_transactions += 1
                self.bus_busy[dst] += dur
                done = self.now + dur
                self.bus_free[dst] = done
                self.inflight[(dst, key)] = done
                self.stall_until(done, "prefetch")

    def take_inflight(self, key):
        dev = self.home(key)
        done = self.inflight.pop((dev, key), None)
        if done is not None:
            self.devices[dev].set_pinned(key, False)
        return done

    def contains(self, key):
        return any(d.contains(key) for d in self.devices)

    def inflight_home(self, key):
        return (self.home(key), key) in self.inflight

    def admit(self, key, bytes_):
        home = self.home(key)
        if not self.devices[home].policy.admits(key):
            return False
        return self.admit_on(home, key, bytes_)

    def warm_admit(self, key, bytes_):
        return self.admit_on(self.home(key), key, bytes_)

    def admit_on(self, dev, key, bytes_):
        ok, evicted = self.devices[dev].insert_evicting(key, bytes_)
        for v in evicted:
            self.rescue_victim(dev, v)
        return ok

    def rescue_victim(self, dev, victim):
        # mirror of ExpertStore::rescue_victim: replica write-back first
        # (home copy with live replicas promotes a holder), then spill
        if self.writeback_from(dev, victim[0]):
            return
        if self.system.spill:
            self.spill_from(dev, victim)

    def writeback_from(self, dev, key):
        if self.home(key) != dev:
            return False  # a spilled copy died, not the home copy
        if key not in self.replicas:
            return False
        rep_bytes, holders = self.replicas.pop(key)
        best = holders[0]
        for d in holders[1:]:
            if self.bus_free[d] < self.bus_free[best]:
                best = d
        prev_home = self.home_map.get(key)
        self.home_map[key] = best
        self.replica_bytes[best] = max(self.replica_bytes[best] - rep_bytes, 0)
        rest = [d for d in holders if d != best]
        if rest:
            self.replicas[key] = (rep_bytes, rest)
        ok, evicted = self.devices[best].insert_evicting(key, rep_bytes)
        for v in evicted:
            self.rescue_victim(best, v)
        if not ok:
            if prev_home is None:
                self.home_map.pop(key, None)
            else:
                self.home_map[key] = prev_home
        else:
            self.writebacks += 1
        return ok

    def spill_from(self, frm, victim):
        key, bytes_ = victim
        if any(d.contains(key) for d in self.devices):
            return
        cands = [d for d in range(len(self.devices))
                 if d != frm and self.devices[d].free_bytes() >= bytes_]
        if not cands:
            return
        to = max(cands, key=lambda d: self.devices[d].free_bytes())
        self.bus_copy_to(to, p2p_copy_us(max(float(bytes_), 1.0)), float(bytes_))
        self.devices[to].insert_evicting(key, bytes_)

    def peer_fetch(self, key, frm):
        b = self.devices[frm].bytes_of(key)
        if b is None:
            return self.now
        home = self.home(key)
        done = self.demand_to(home, p2p_copy_us(max(float(b), 1.0)), float(b))
        if self.devices[home].policy.admits(key):
            self.devices[frm].remove(key)
            ok, evicted = self.devices[home].insert_evicting(key, b)
            for v in evicted:
                self.rescue_victim(home, v)
        return done

    def hit_rate(self):
        h = sum(d.hits for d in self.devices)
        m = sum(d.misses for d in self.devices)
        return h / (h + m) if h + m else 0.0

    # -------- little tier (PR 9, mirror of store/mod.rs little tier)

    def seed_little_pool(self, keys, bytes_per_key):
        if self.little_budget == 0:
            return
        for key in keys:
            dev = self.home(key)
            if key in self.little_pools[dev]:
                continue
            if self.little_bytes[dev] + bytes_per_key > self.little_budget:
                continue
            self.little_pools[dev].add(key)
            self.little_bytes[dev] += bytes_per_key

    def little_resident(self, key):
        return key in self.little_pools[self.home(key)]

    def degraded_hit(self, key, avoided_bytes):
        self.degraded_hits += 1
        self.degraded_bytes += avoided_bytes

    def predict_demand_ready(self, key, dur):
        """PrefetchPipeline::predict_ready: critical_copy's start rule,
        read-only — priority lane under overlap, FIFO bus otherwise."""
        dev = self.home(key)
        lane = self.demand_free[dev] if self.system.overlap else self.bus_free[dev]
        return max(self.now, lane) + dur

    def peek_demand_link_us(self, key, bytes_):
        """demand_link_us without the counters/adoption side effects."""
        if self.n_nodes <= 1:
            return self.link_scaled("pcie", pcie_copy_us(bytes_))
        if key in self.host_pool:
            return self.link_scaled("pcie", pcie_copy_us(bytes_))
        return self.link_scaled("net", net_copy_us(bytes_))

    # ---------------- cluster tier (mirror of store/mod.rs cluster tier)

    def seed_host_pool(self, keys, bytes_per_key):
        for key in keys:
            if key in self.host_pool:
                continue
            if self.host_bytes + bytes_per_key > self.host_budget:
                break
            self.host_pool.add(key)
            self.host_bytes += bytes_per_key

    def host_adopt(self, key, bytes_):
        if self.host_bytes + bytes_ <= self.host_budget and key not in self.host_pool:
            self.host_pool.add(key)
            self.host_bytes += bytes_

    def demand_link_us(self, key, bytes_):
        """ExpertStore::demand_link_us: host PCIe when the home node's
        pool stages the key (or the topology is unclustered), else the
        network link with first-touch host adoption. Either duration
        stretches under a covering link-degrade window."""
        if self.n_nodes <= 1:
            return self.link_scaled("pcie", pcie_copy_us(bytes_))
        if key in self.host_pool:
            return self.link_scaled("pcie", pcie_copy_us(bytes_))
        dur = self.link_scaled("net", net_copy_us(bytes_))
        self.net_pulls += 1
        self.net_bytes += bytes_
        self.host_adopt(key, int(bytes_))
        return dur

    def net_restore(self, keys, bytes_per_key):
        """ExpertStore::net_restore: coalesced Net-link plans per home
        device; host-resident keys cost only the api handshake."""
        n = len(self.devices)
        plans = [[] for _ in range(n)]
        for key in keys:
            dev = self.home(key)
            if key in self.host_pool:
                plans[dev].append((0.0, NET_API, NET_API))
            else:
                b = max(float(bytes_per_key), 1.0)
                plans[dev].append((float(bytes_per_key), net_copy_us(b), NET_API))
                self.host_adopt(key, bytes_per_key)
        done = self.now
        for dev, items in enumerate(plans):
            if not items:
                continue
            self.net_pulls += len(items)
            self.net_bytes += sum(it[0] for it in items)
            done = max(done, self.copy_batch(dev, items, True))
        return done

    # ------------------- faults (PR 10, mirror of store/mod.rs §12)

    def link_factor_at(self, link, t):
        """Product of every covering window's factor (1.0 = identity)."""
        f = 1.0
        for lk, fac, t0, t1 in self.link_windows:
            if lk == link and t0 <= t < t1:
                f *= fac
        return f

    def outage_until(self, link, t):
        """Latest end among covering zero-factor windows, else None."""
        end = None
        for lk, fac, t0, t1 in self.link_windows:
            if lk == link and fac == 0.0 and t0 <= t < t1:
                end = t1 if end is None else max(end, t1)
        return end

    def link_scaled(self, link, dur):
        f = self.link_factor_at(link, self.now)
        return dur / f if 0.0 < f < 1.0 else dur

    def demand_link_of(self, key):
        """Which link a demand fetch of `key` would ride (read-only)."""
        if self.n_nodes <= 1:
            return "pcie"
        return "pcie" if key in self.host_pool else "net"

    def device_down(self, dev):
        """ExpertStore::device_down: tear down the device's in-flight
        transfers, little pool, replicas and overlay homes, then re-home
        its resident set hottest-first (mass desc, key asc) into the
        surviving peers' free capacity only. Returns (moved, dropped)."""
        if self.dead[dev]:
            return 0, 0
        self.dead[dev] = True
        for dk in [k for k in self.inflight if k[0] == dev]:
            del self.inflight[dk]
        self.little_pools[dev].clear()
        self.little_bytes[dev] = 0
        for key in list(self.replicas):
            b, holders = self.replicas[key]
            holders = [d for d in holders if d != dev]
            if holders:
                self.replicas[key] = (b, holders)
            else:
                del self.replicas[key]
        self.replica_bytes[dev] = 0
        self.home_map = {k: d for k, d in self.home_map.items() if d != dev}
        keys = [(k, self.devices[dev].bytes_of(k) or 0, self.pop_mass(k))
                for k in list(self.devices[dev].entries)]
        keys.sort(key=lambda kv: (-kv[2], kv[0]))
        per_dst = [[] for _ in self.devices]
        moved = dropped = 0
        for key, bytes_, _mass in keys:
            self.devices[dev].remove(key)
            target = self.home(key)  # remapped off the dead device
            if (target != dev and not self.devices[target].contains(key)
                    and self.devices[target].free_bytes() >= bytes_):
                self.devices[target].insert_evicting(key, bytes_)
                b = max(float(bytes_), 1.0)
                per_dst[target].append((float(bytes_), p2p_copy_us(b), P2P_API))
                moved += 1
            else:
                dropped += 1
        for dst, items in enumerate(per_dst):
            if items:
                self.copy_batch(dst, items, self.system.coalesce)
        return moved, dropped

    def wipe_for_rejoin(self):
        """ExpertStore::wipe_for_rejoin: a rejoining node lost its
        memory — clear every pool so the driver re-seeds from scratch;
        the clock and movement ledgers carry across."""
        for d in self.devices:
            for key in list(d.entries):
                d.remove(key)
        self.host_pool.clear()
        self.host_bytes = 0
        for p in self.little_pools:
            p.clear()
        self.little_bytes = [0] * len(self.devices)
        self.replicas.clear()
        self.replica_bytes = [0] * len(self.devices)
        self.home_map.clear()


def simulate(p, input_len, output_len):
    rng = Rng(p.seed)
    prev = [[] for _ in range(NL)]
    budget = cache_budget_bytes(p, input_len + output_len)
    store = Store(p.system, int(budget))
    weights = zipf_cdf(NE, p.zipf_s)
    per_cached = cached_bytes(p)
    per_bytes = transfer_bytes(p)
    exp_c = expert_compute_us(p)
    resident_fits = (p.system.kind == GPU
                     and budget * max(p.system.devices, 1)
                     >= NL * NE * per_cached)

    # ---- prefill ----
    for l in range(NL):
        flops = 12.0 * input_len * float(DM) ** 2
        store.tick(flops / (FP16_TF * 1e6) + 4.0 * LAUNCH)
        if p.system.kind == GPU and resident_fits:
            store.tick(exp_c * NE * 0.5)
        elif p.system.kind == FIDDLER:
            _prefill_stream(p, store, l, expert_bytes_fp16())
            store.tick(exp_c * NE * 0.5)
        else:
            per = max(per_bytes, expert_bytes_quant(2.0) if p.system.kind == GPU else 0.0)
            if per > 0.0:
                _prefill_stream(p, store, l, per)
            store.tick(exp_c * NE * 0.5)

    # ---- warm ----
    order = sorted([(l, e) for l in range(NL) for e in range(NE)], key=lambda k: k[1])
    full = [False] * len(store.devices)
    for key in order:
        dev = store.home(key)
        if full[dev]:
            continue
        if not store.warm_admit(key, per_cached):
            full[dev] = True
            if all(full):
                break

    # ---- decode ----
    compute_us = 0.0
    streams = ([0.0] * len(store.devices)) if p.system.compute_streams else None
    for tok in range(output_len):
        kv_len = input_len + tok
        routing = sample_routing(p, rng, prev, weights)
        for l in range(NL):
            store.rebalance_tick()

            def resolve(e):
                # mirror of sim.rs::resolve_expert — returns
                # (ready, cause, key, resident, exec_dev) or None (Fiddler
                # computed inline on CPU)
                nonlocal compute_us
                key = (l, e)
                looked = ("local", 0) if resident_fits else store.lookup(key)
                resident = looked[0] != "miss"
                if looked[0] == "local":
                    return (store.now, "demand", key, resident, looked[1])
                if looked[0] == "remote":
                    ready = store.peer_fetch(key, looked[1])
                    return (ready, "demand", key, resident, store.home(key))
                done = store.take_inflight(key)
                if done is not None:
                    store.admit(key, per_cached)
                    return (done, "prefetch", key, resident, store.home(key))
                if p.system.kind == FIDDLER:
                    t = cpu_expert_us()
                    store.tick(t)
                    compute_us += t
                    return None
                dur = store.demand_link_us(key, max(per_bytes, 1.0))
                ready = store.demand_to(store.home(key), dur, per_bytes)
                store.admit(key, per_cached)
                return (ready, "demand", key, resident, store.home(key))

            def exec_one(w):
                # mirror of sim.rs::exec_expert
                nonlocal compute_us, layer_end
                ready, cause, key, resident, exec_dev = w
                if streams is not None:
                    start = max(streams[exec_dev], store.now)
                    if ready > start:
                        store.charge_stall(cause, ready - start)
                        start = ready
                    if p.system.kind == FLOE and not resident:
                        miss = max(1.0 - p.intra_recall, 0.0)
                        if miss > 0.0:
                            extra = per_bytes * miss * 0.5
                            done = store.critical_copy_to(
                                store.home(key), pcie_copy_us(extra), extra)
                            if done > start:
                                store.charge_stall("demand", done - start)
                                start = done
                    end = start + exp_c  # gemv_scale 1.0 (uniform fleet)
                    streams[exec_dev] = end
                    layer_end = max(layer_end, end)
                    compute_us += exp_c
                else:
                    store.stall_until(ready, cause)
                    if p.system.kind == FLOE and not resident:
                        miss = max(1.0 - p.intra_recall, 0.0)
                        if miss > 0.0:
                            extra = per_bytes * miss * 0.5
                            done = store.critical_copy_to(
                                store.home(key), pcie_copy_us(extra), extra)
                            store.stall_until(done)
                    store.tick(exp_c)
                    compute_us += exp_c

            if p.system.overlap:
                # overlap: resolve the layer's experts *before* attention —
                # demand fetches take bus priority over the next layer's
                # speculative prefetch and stream under attention compute
                # (resolve_expert consumes no RNG, so draw order holds)
                work = [w for w in (resolve(e) for e in routing[l]) if w is not None]
            attn = attn_layer_us(kv_len)
            store.tick(attn)
            compute_us += attn
            if l + 1 < NL and per_bytes > 0.0:
                hit_rate, ov_pf = 0.0, False
                if p.system.kind == FLOE:
                    hit_rate, ov_pf = p.inter_hit, True
                elif p.system.kind == ADV:
                    hit_rate, ov_pf = p.adv_prefetch_hit, False
                if hit_rate > 0.0:
                    mode = ("blocking" if not ov_pf else
                            ("coalesced" if p.system.coalesce else "overlapped"))
                    plans = [[] for _ in store.devices]
                    for e in routing[l + 1]:
                        key = (l + 1, e)
                        predicted = rng.f64() < hit_rate
                        if predicted and not store.contains(key):
                            dur = pcie_copy_us(per_bytes)
                            plans[store.home(key)].append((key, per_bytes, dur, PCIE_API))
                    for dst, plan in enumerate(plans):
                        if plan:
                            store.submit(dst, mode, plan)
            layer_end = store.now
            if not p.system.overlap:
                # lockstep: resolve → execute in routing order (the frozen
                # busy-until op sequence)
                for e in routing[l]:
                    w = resolve(e)
                    if w is not None:
                        exec_one(w)
            else:
                # dispatch GEMVs in readiness order — ties keep routing
                # order (stable sort mirrors the event heap's
                # time-then-sequence ordering)
                for w in sorted(work, key=lambda w: w[0]):
                    exec_one(w)
            if streams is not None:
                store.advance_to(layer_end)
    total = store.now
    return {
        "tps": output_len / (total / 1e6),
        "stall_us": store.stall_us,
        "stall_demand": store.stall_demand,
        "stall_prefetch": store.stall_prefetch,
        "bytes": store.transferred_bytes,
        "bus_tx": store.bus_transactions,
        "hit": store.hit_rate(),
        "max_busy": max(store.bus_busy),
        "rebalances": store.rebalances,
        "writebacks": store.writebacks,
    }


def _prefill_stream(p, store, layer, per_expert):
    counts = [0] * len(store.devices)
    for e in range(NE):
        counts[store.home((layer, e))] += 1
    slowest = float("-inf")
    for dev, count in enumerate(counts):
        if count == 0:
            continue
        b = count * per_expert
        slowest = max(slowest, store.bus_copy_to(dev, pcie_copy_us(b), b))
    store.advance_to(slowest)


# ------------------------------------------------- batched serving (PR 5)
# Mirror of coordinator/sched.rs::Scheduler + sim.rs::SimServeBackend /
# simulate_serving under the boundary-synchronous step: admissions at each
# token boundary (FIFO, capped), one decode per active seq in admission
# order, same-boundary expert repeats at the CALIBRATED reuse ratio
# (sim.rs::boundary_compute_reuse, which replaced the flat 0.15).


def boundary_compute_reuse(p):
    full = expert_compute_us(p)
    if p.system.kind == FLOE:
        flops = 2.0 * DM * DFF * (1.0 + 2.0 * (1.0 - p.system.sparsity))
    else:
        flops = 2.0 * 3.0 * DM * DFF
    flops_us = flops / (FP16_TF * 1e6)
    act_bytes = (2 * DM + 2 * DFF) * 2.0
    act_us = act_bytes / (HBM * EFF * 1e3)
    r = (flops_us + act_us + LAUNCH) / full
    return min(max(r, 0.02), 1.0)


class TimedReq:
    def __init__(self, arrival_us, rid, plen, max_tokens, seed):
        self.arrival_us = arrival_us
        self.rid = rid
        self.plen = plen
        self.max_tokens = max_tokens
        self.seed = seed


def gen_workload(n_requests, rate_hz, prompt_lo, prompt_hi, out_lo, out_hi, seed):
    """Mirror of workload.rs::generate (draw order is load-bearing)."""
    import math
    rng = Rng(seed)
    t_us = 0.0
    out = []
    for i in range(n_requests):
        t_us += -math.log(1.0 - rng.f64()) / rate_hz * 1e6
        plen = prompt_lo + rng.below(prompt_hi - prompt_lo)
        for _ in range(plen):
            rng.below(26)  # prompt bytes (content unused, draws consumed)
        max_tokens = out_lo + rng.below(out_hi - out_lo)
        rseed = seed ^ ((i * 0x9E3779B97F4A7C15) & MASK)
        out.append(TimedReq(t_us, i, plen, max_tokens, rseed))
    return out


def workload_at(rate_hz, n_requests, seed):
    return gen_workload(n_requests, rate_hz, 8, 24, 16, 48, seed)


def _serving_prefill(p, store, per_bytes, exp_c, input_len):
    for l in range(NL):
        flops = 12.0 * input_len * float(DM) ** 2
        store.tick(flops / (FP16_TF * 1e6) + 4.0 * LAUNCH)
        if per_bytes > 0.0:
            _prefill_stream(p, store, l, per_bytes)
        store.tick(exp_c * NE * 0.5)


class _SimSeq:
    def __init__(self, req):
        self.rid = req.rid
        self.rng = Rng(req.seed)
        self.prev = [[] for _ in range(NL)]
        self.input_len = max(req.plen, 1)
        self.emitted = 0
        self.max_tokens = max(req.max_tokens, 1)
        # PR 9: SLO deadline (admission + budget; inf = no budget) and
        # the per-request degraded ledger
        self.arrival_us = req.arrival_us
        self.deadline = float("inf")
        self.degraded_hits = 0
        self.degraded_bytes = 0.0


def _degrade_or_fetch(p, store, seq, key, per_bytes, per_cached):
    """resolve_expert's Miss/no-inflight branch: the quality-elastic
    decision first (side-effect-free prediction vs the SLO deadline),
    then the outage/retry gate (PR 10, sim.rs §12), then the demand
    fetch. Returns (ready, cause, degraded); ready is None on a
    fail-fast transfer fault (the request errors at the boundary)."""
    if (p.system.little_frac > 0.0
            and seq.deadline != float("inf")
            and store.little_resident(key)
            and store.predict_demand_ready(
                key, store.peek_demand_link_us(key, max(per_bytes, 1.0)))
            > seq.deadline):
        store.degraded_hit(key, per_bytes)
        seq.degraded_hits += 1
        seq.degraded_bytes += per_bytes
        return store.now, "demand", True
    # a full outage on the fetch's link gates the start through the
    # bounded-backoff retry loop: probe k waits base*2^k after the
    # block; the first probe past every outage window issues the fetch
    # with the wait folded into its duration. No policy = fail-fast.
    now = store.now
    link = store.demand_link_of(key)
    extra_wait = 0.0
    end = store.outage_until(link, now)
    if end is not None:
        if store.retry_policy is None:
            store.fault_causes.setdefault(seq.rid, "link-outage")
            return None, "demand", False
        max_attempts, base = store.retry_policy
        cleared = None
        for k in range(max_attempts):
            t_k = now + base * (2.0 ** k)
            if store.outage_until(link, t_k) is None:
                cleared = (k + 1, t_k)
                break
        if cleared is not None:
            store.retries += cleared[0]
            extra_wait = cleared[1] - now
        else:
            store.retries += max_attempts
            store.fault_causes.setdefault(seq.rid, "retry-exhausted")
            if p.system.little_frac > 0.0 and store.little_resident(key):
                store.degraded_hit(key, per_bytes)
                seq.degraded_hits += 1
                seq.degraded_bytes += per_bytes
                return store.now, "demand", True
            extra_wait = end - now
    dur = store.demand_link_us(key, max(per_bytes, 1.0))
    ready = store.demand_to(store.home(key), extra_wait + dur, per_bytes)
    store.admit(key, per_cached)
    return ready, "demand", False


def _serving_decode_token(p, store, seq, per_bytes, per_cached, exp_c, reuse,
                          weights, boundary_seen, counters):
    """sim.rs::sim_decode_token with a BoundaryShare (serving mode):
    single device, dedup_inflight on, no compute streams."""
    routing = sample_routing(p, seq.rng, seq.prev, weights)
    kv_len = seq.input_len + seq.emitted
    compute = 0.0
    for l in range(NL):
        store.rebalance_tick()
        def resolve(e):
            # (ready, cause, key, resident, t_exp) — boundary-share visit
            # happens at resolve time, in routing order (resolve_expert)
            key = (l, e)
            looked = store.lookup(key)
            resident = looked[0] != "miss"
            if looked[0] == "local":
                ready, cause = store.now, "demand"
            elif looked[0] == "remote":
                ready, cause = store.peer_fetch(key, looked[1]), "demand"
            else:
                done = store.take_inflight(key)
                if done is not None:
                    store.admit(key, per_cached)
                    ready, cause = done, "prefetch"
                else:
                    ready, cause, degraded = _degrade_or_fetch(
                        p, store, seq, key, per_bytes, per_cached)
                    if ready is None:
                        # fail-fast transfer fault: no GEMV, no boundary
                        # visit — the recorded cause errors the request
                        return None
                    if degraded:
                        # the little variant is pinned on-device: no
                        # intra-predictor top-up applies
                        resident = True
            if key not in boundary_seen:
                boundary_seen.add(key)
                counters["full"] += 1
                t_exp = exp_c
            else:
                counters["reused"] += 1
                t_exp = exp_c * reuse
            return (ready, cause, key, resident, t_exp)

        def exec_one(w):
            nonlocal compute
            if w is None:
                return
            ready, cause, key, resident, t_exp = w
            store.stall_until(ready, cause)
            if not resident:
                miss = max(1.0 - p.intra_recall, 0.0)
                if miss > 0.0:
                    extra = per_bytes * miss * 0.5
                    done = store.critical_copy_to(store.home(key), pcie_copy_us(extra), extra)
                    store.stall_until(done)
            store.tick(t_exp)
            compute += t_exp

        attn = attn_layer_us(kv_len)
        store.tick(attn)
        compute += attn
        if l + 1 < NL and per_bytes > 0.0:
            plans = [[] for _ in store.devices]
            for e in routing[l + 1]:
                key = (l + 1, e)
                predicted = seq.rng.f64() < p.inter_hit
                if (predicted and not store.contains(key)
                        and not store.inflight_home(key)):  # dedup_inflight
                    dur = pcie_copy_us(per_bytes)
                    plans[store.home(key)].append((key, per_bytes, dur, PCIE_API))
            for dst, plan in enumerate(plans):
                if plan:
                    store.submit(dst, "overlapped", plan)
        for e in routing[l]:
            exec_one(resolve(e))
    return compute


def _serving_decode_boundary(p, store, seqs, per_bytes, per_cached, exp_c, reuse,
                             weights, boundary_seen, counters):
    """sim.rs::sim_decode_boundary (SimServeBackend::step_batch under
    --overlap): layer-synchronous batch decode. Each layer resolves the
    whole batch's experts first (demand fetches hit the bus before the
    next layer's speculative prefetch), runs every sequence's attention,
    then releases GEMVs across the *batch* in readiness order — one
    sequence's in-flight transfer hides under the other sequences'
    compute instead of stalling its own lane. Per-sequence RNG streams
    see the exact lockstep draw order (routing at token start, prefetch
    draws in layer order), so routing is identical to the per-seq path."""
    routings = [sample_routing(p, s.rng, s.prev, weights) for s in seqs]
    kv_lens = [s.input_len + s.emitted for s in seqs]
    computes = [0.0] * len(seqs)
    for l in range(NL):
        store.rebalance_tick()
        work = []
        for si in range(len(seqs)):
            for e in routings[si][l]:
                key = (l, e)
                looked = store.lookup(key)
                resident = looked[0] != "miss"
                if looked[0] == "local":
                    ready, cause = store.now, "demand"
                elif looked[0] == "remote":
                    ready, cause = store.peer_fetch(key, looked[1]), "demand"
                else:
                    done = store.take_inflight(key)
                    if done is not None:
                        store.admit(key, per_cached)
                        ready, cause = done, "prefetch"
                    else:
                        ready, cause, degraded = _degrade_or_fetch(
                            p, store, seqs[si], key, per_bytes, per_cached)
                        if ready is None:
                            continue  # fail-fast fault: no GEMV, no visit
                        if degraded:
                            resident = True
                if key not in boundary_seen:
                    boundary_seen.add(key)
                    counters["full"] += 1
                    t_exp = exp_c
                else:
                    counters["reused"] += 1
                    t_exp = exp_c * reuse
                work.append((ready, cause, key, resident, t_exp, si))
        for si in range(len(seqs)):
            attn = attn_layer_us(kv_lens[si])
            store.tick(attn)
            computes[si] += attn
        if l + 1 < NL and per_bytes > 0.0:
            plans = [[] for _ in store.devices]
            for si, s in enumerate(seqs):
                for e in routings[si][l + 1]:
                    key = (l + 1, e)
                    predicted = s.rng.f64() < p.inter_hit
                    if (predicted and not store.contains(key)
                            and not store.inflight_home(key)):
                        dur = pcie_copy_us(per_bytes)
                        plans[store.home(key)].append((key, per_bytes, dur, PCIE_API))
            for dst, plan in enumerate(plans):
                if plan:
                    store.submit(dst, "overlapped", plan)
        # stable sort by readiness = the event heap's time-then-sequence
        # order; ties keep (seq, routing) push order
        for w in sorted(work, key=lambda w: w[0]):
            ready, cause, key, resident, t_exp, si = w
            store.stall_until(ready, cause)
            if not resident:
                miss = max(1.0 - p.intra_recall, 0.0)
                if miss > 0.0:
                    extra = per_bytes * miss * 0.5
                    done = store.critical_copy_to(
                        store.home(key), pcie_copy_us(extra), extra)
                    store.stall_until(done)
            store.tick(t_exp)
            computes[si] += t_exp
    return computes


def simulate_serving(p, wl, cap, per_boundary_check=False, slo_us=None):
    import math
    max_ctx = max(t.plen + t.max_tokens for t in wl)
    kv_tokens = max(cap, 1) * max_ctx
    budget = cache_budget_bytes(p, kv_tokens)
    store = Store(p.system, int(budget))
    weights = zipf_cdf(NE, p.zipf_s)
    per_cached = cached_bytes(p)
    per_bytes = transfer_bytes(p)
    exp_c = expert_compute_us(p)
    reuse = boundary_compute_reuse(p)
    # warm at construction (SimServeBackend::new)
    order = sorted([(l, e) for l in range(NL) for e in range(NE)], key=lambda k: k[1])
    full_flags = [False] * len(store.devices)
    for key in order:
        dev = store.home(key)
        if full_flags[dev]:
            continue
        if not store.warm_admit(key, per_cached):
            full_flags[dev] = True
            if all(full_flags):
                break
    # PR 9: little-tier seeding after warm (seed_little_pools)
    if p.system.little_frac > 0.0:
        keys = [(l, e) for l in range(NL) for e in range(NE)]
        sketch = int(max(math.ceil(per_bytes / 20.0), 1.0))
        store.seed_little_pool(keys, sketch)

    pending, active, completions = [], [], []
    next_i, tokens = 0, 0
    counters = {"full": 0, "reused": 0}
    saw_batch, saw_reuse, checks_ok = False, False, True
    while True:
        while next_i < len(wl) and wl[next_i].arrival_us <= store.now:
            pending.append(wl[next_i])
            next_i += 1
        if not pending and not active:
            if next_i >= len(wl):
                break
            store.advance_to(wl[next_i].arrival_us)
            continue
        # scheduler step: admit FIFO up to cap (prefill at admission) ...
        while len(active) < max(cap, 1) and pending:
            req = pending.pop(0)
            t0 = store.now  # admission stamp, BEFORE prefill (sim.rs start)
            _serving_prefill(p, store, per_bytes, exp_c, max(req.plen, 1))
            s = _SimSeq(req)
            if slo_us is not None:
                s.deadline = t0 + slo_us
            active.append(s)
        # ... then one boundary-synchronous batch step
        boundary_seen = set()
        full_before = counters["full"]
        pairs_before = counters["full"] + counters["reused"]
        if len(active) > 1:
            saw_batch = True
        if p.system.overlap:
            # step_batch override: layer-synchronous event dispatch across
            # the whole boundary (mid-boundary GEMV release)
            _serving_decode_boundary(
                p, store, active, per_bytes, per_cached, exp_c, reuse,
                weights, boundary_seen, counters)
            for s in active:
                s.emitted += 1
                tokens += 1
        else:
            for s in active:
                _serving_decode_token(
                    p, store, s, per_bytes, per_cached, exp_c, reuse,
                    weights, boundary_seen, counters)
                s.emitted += 1
                tokens += 1
        if per_boundary_check:
            full_d = counters["full"] - full_before
            pair_d = counters["full"] + counters["reused"] - pairs_before
            if full_d != len(boundary_seen) or full_d > pair_d:
                checks_ok = False
            if pair_d > full_d:
                saw_reuse = True
        still = []
        for s in active:
            if s.emitted < s.max_tokens:
                still.append(s)
            else:
                # retirement: finished_us stamped after the whole batch
                # stepped (the boundary barrier, sched.rs::step)
                completions.append({
                    "rid": s.rid,
                    "latency_us": store.now - s.arrival_us,
                    "degraded_hits": s.degraded_hits,
                    "degraded_bytes": s.degraded_bytes,
                })
        active = still
    lat = sorted(c["latency_us"] for c in completions)

    def quantile(q):  # ServeSimReport::latency_quantile (round half up)
        if not lat:
            return 0.0
        return lat[int((len(lat) - 1) * q + 0.5)]

    return {
        "tps": tokens / (store.now / 1e6),
        "tokens": tokens,
        "total_us": store.now,
        "stall_us": store.stall_us,
        "stall_demand": store.stall_demand,
        "stall_prefetch": store.stall_prefetch,
        "full": counters["full"],
        "reused": counters["reused"],
        "saw_batch": saw_batch,
        "saw_reuse": saw_reuse,
        "per_boundary_ok": checks_ok,
        "completions": completions,
        "p95": quantile(0.95),
        "p99": quantile(0.99),
        "degraded_hits": store.degraded_hits,
        "degraded_bytes": store.degraded_bytes,
        "degraded_req_share": (
            sum(1 for c in completions if c["degraded_hits"] > 0)
            / len(completions) if completions else 0.0),
    }


def serving_params(overlap=False):
    # experiments/serveload.rs::sweep_params (Floe, lru, skewed routing)
    return Params(System(FLOE, "lru", overlap=overlap), 14.25,
                  zipf_s=1.2, stickiness=0.5, seed=7)


# ------------------------------------------------------------- cluster (PR 8)
# Mirror of coordinator/cluster.rs::simulate_cluster: N member nodes,
# each a simulate_serving-shaped backend over a cluster-member store,
# joined on the deterministic cluster clock.


def predicted_first_expert(zipf_s, seed):
    # sim.rs::predicted_first_expert (exact first routing draw)
    w = zipf_cdf(NE, zipf_s)
    rng = Rng(seed)
    r = rng.f64() * w[NE - 1]
    return min(partition_point(w, r), NE - 1)


def member_params(base, devices, shard, vram_gb):
    # SystemConfig::with_devices + per-device VRAM slice
    s = System(base.system.kind, base.system.residency, devices=devices,
               shard=shard, overlap=base.system.overlap)
    p = Params(s, vram_gb, zipf_s=base.zipf_s, stickiness=base.stickiness,
               seed=base.seed)
    p.inter_hit = base.inter_hit
    p.intra_recall = base.intra_recall
    return p


class _ClusterNode:
    """One node coordinator: Scheduler<SimServeBackend> as a member."""

    def __init__(self, p, kv_tokens, cap, node_id, n_nodes, host_ram_gb):
        self.p = p
        self.cap = max(cap, 1)
        budget = cache_budget_bytes(p, kv_tokens)
        store = Store(p.system, int(budget))
        store.n_nodes = n_nodes
        store.node_id = node_id
        store.host_budget = int(host_ram_gb * 1e9)
        self.store = store
        self.weights = zipf_cdf(NE, p.zipf_s)
        self.per_cached = cached_bytes(p)
        self.per_bytes = transfer_bytes(p)
        self.exp_c = expert_compute_us(p)
        self.reuse = boundary_compute_reuse(p)
        self.counters = {"full": 0, "reused": 0}
        # warm at construction (SimServeBackend::new)
        order = sorted([(l, e) for l in range(NL) for e in range(NE)],
                       key=lambda k: k[1])
        full_flags = [False] * len(store.devices)
        for key in order:
            dev = store.home(key)
            if full_flags[dev]:
                continue
            if not store.warm_admit(key, self.per_cached):
                full_flags[dev] = True
                if all(full_flags):
                    break
        # stage the host pools (sim.rs::seed_cluster_host_pools): own
        # expert-mod shard first, then the rest, until host RAM fills
        if n_nodes > 1:
            b = int(max(self.per_bytes, 1.0))
            own, rest = [], []
            for l in range(NL):
                for e in range(NE):
                    (own if e % n_nodes == node_id % n_nodes else rest).append((l, e))
            store.seed_host_pool(own, b)
            store.seed_host_pool(rest, b)
        self.pending = []  # (TimedReq, arrival stamp)
        self.active = []
        self.completions = []  # {id, tokens, error, finished_us}
        self.tokens = 0
        self.alive = True

    def has_work(self):
        return bool(self.pending or self.active)

    def enqueue_at(self, req, stamp):
        self.pending.append((req, stamp))

    def step(self):
        # sched.rs::step: idle to the head arrival when empty, admit the
        # ripe FIFO prefix (prefill clock advance cannot pull later
        # arrivals into the same boundary), one boundary batch, retire
        store = self.store
        if not self.active and self.pending and self.pending[0][1] > store.now:
            store.advance_to(self.pending[0][1])
        ripe = store.now
        while (len(self.active) < self.cap and self.pending
               and self.pending[0][1] <= ripe):
            req, _stamp = self.pending.pop(0)
            _serving_prefill(self.p, store, self.per_bytes, self.exp_c,
                             max(req.plen, 1))
            self.active.append(_SimSeq(req))
        boundary_seen = set()
        if self.p.system.overlap:
            _serving_decode_boundary(
                self.p, store, self.active, self.per_bytes, self.per_cached,
                self.exp_c, self.reuse, self.weights, boundary_seen,
                self.counters)
        else:
            for s in self.active:
                _serving_decode_token(
                    self.p, store, s, self.per_bytes, self.per_cached,
                    self.exp_c, self.reuse, self.weights, boundary_seen,
                    self.counters)
        # retire in batch order: a recorded fault errors the sequence
        # with its pre-fault tokens and the structured cause (sched.rs
        # step + take_fault_cause); clean steps emit and retire at max
        still = []
        for s in self.active:
            cause = store.fault_causes.pop(s.rid, None)
            if cause is not None:
                self.completions.append({
                    "id": s.rid, "tokens": s.emitted,
                    "error": "transfer fault: " + cause,
                    "fault_cause": cause, "finished_us": store.now})
                continue
            s.emitted += 1
            self.tokens += 1
            if s.emitted >= s.max_tokens:
                self.completions.append({"id": s.rid, "tokens": s.emitted,
                                         "error": None,
                                         "finished_us": store.now})
            else:
                still.append(s)
        self.active = still

    def fail_active(self, msg, cause="node-down"):
        n = len(self.active)
        for s in self.active:
            self.completions.append({"id": s.rid, "tokens": s.emitted,
                                     "error": msg, "fault_cause":
                                     self.store.fault_causes.pop(s.rid, cause),
                                     "finished_us": self.store.now})
        self.active = []
        return n

    def abort_active(self):
        """sched.rs::abort_active: release in-flight sequences WITHOUT
        completions — the cluster driver re-dispatches the originals to
        survivors, where they restart value-idempotently. Per-request
        fault causes drain with the aborted run."""
        ids = [s.rid for s in self.active]
        for s in self.active:
            self.store.fault_causes.pop(s.rid, None)
        self.active = []
        return ids

    def drain_pending(self):
        out = self.pending
        self.pending = []
        return out

    def rejoin_restock(self):
        """SimServeBackend::rejoin_restock: wipe every pool, re-pin the
        little tier locally, restock the own-shard-first host roster
        over the network as full pulls, truncated to the host budget."""
        import math
        store = self.store
        store.wipe_for_rejoin()
        if self.p.system.little_frac > 0.0 and store.little_budget > 0:
            keys = [(l, e) for l in range(NL) for e in range(NE)]
            sketch = int(max(math.ceil(self.per_bytes / 20.0), 1.0))
            store.seed_little_pool(keys, sketch)
        total = max(store.n_nodes, 1)
        own, rest = [], []
        for l in range(NL):
            for e in range(NE):
                (own if e % total == store.node_id % total
                 else rest).append((l, e))
        own.extend(rest)
        b = int(max(self.per_bytes, 1.0))
        used, take = 0, []
        for key in own:
            if used + b > store.host_budget:
                break
            used += b
            take.append(key)
        store.net_restore(take, b)


def simulate_cluster(base, n_nodes, devices_per_node, vram_total, wl,
                     placement="round-robin", host_ram_gb=64.0, cap=4,
                     failure=None, shard="layer", faults=None, retry=None):
    """cluster.rs::simulate_cluster. `failure` is the legacy (node, t_us)
    single drop; `faults` is the PR 10 schedule, a list of
    ("node-down", node, t) / ("node-rejoin", node, t) /
    ("dev-down", dev, t) / ("link", link, factor, t0, t1) tuples;
    `retry` is (max_attempts, backoff_base_us) or None (fail-fast)."""
    n = max(n_nodes, 1)
    max_ctx = max(t.plen + t.max_tokens for t in wl)
    kv_tokens = max(cap, 1) * max_ctx
    vram_per_device = vram_total / (n * devices_per_node)
    nodes = [_ClusterNode(
        member_params(base, devices_per_node, shard, vram_per_device),
        kv_tokens, cap, j, n, host_ram_gb) for j in range(n)]
    # merge the legacy failure into the schedule, stable-sorted by
    # activation time (validate_faults); install link windows and the
    # retry policy into every node's store up front — pricing is a pure
    # function of (schedule, clock)
    sched_faults = []
    if failure is not None:
        sched_faults.append(("node-down", failure[0], failure[1]))
    sched_faults.extend(faults or [])
    fault_t = lambda f: f[3] if f[0] == "link" else f[2]
    sched_faults.sort(key=fault_t)
    for nd in nodes:
        nd.store.retry_policy = retry
        for f in sched_faults:
            if f[0] == "link":
                nd.store.link_windows.append((f[1], f[2], f[3], f[4]))
    req_by_id = {t.rid: t for t in wl}
    rr = [0]
    assignments = {}
    rehomed = 0
    redispatched = 0
    rejoins = 0
    dev_moved = 0
    dev_dropped = 0
    fi = 0
    idx = 0

    def load(j):
        return len(nodes[j].active) + len(nodes[j].pending)

    def place(t):
        survivors = [j for j in range(n) if nodes[j].alive]
        if placement == "round-robin":
            j = survivors[rr[0] % len(survivors)]
            rr[0] += 1
            return j
        if placement == "least-loaded":
            best = survivors[0]
            for j in survivors[1:]:
                if load(j) < load(best):
                    best = j
            return best
        # expert-affinity: the node hottest for the predicted first
        # expert, ties toward least-loaded then lowest id
        e = predicted_first_expert(base.zipf_s, t.seed)
        best = survivors[0]
        best_m = sum(nodes[best].store.pop_mass((l, e)) for l in range(NL))
        for j in survivors[1:]:
            m = sum(nodes[j].store.pop_mass((l, e)) for l in range(NL))
            if m > best_m or (m == best_m and load(j) < load(best)):
                best, best_m = j, m
        return best

    while True:
        t_arr = wl[idx].arrival_us if idx < len(wl) else None
        t_fault = fault_t(sched_faults[fi]) if fi < len(sched_faults) else None
        if t_arr is None and t_fault is None:
            horizon = float("inf")
        else:
            horizon = min(t for t in (t_arr, t_fault) if t is not None)
        # advance every working alive node to the horizon (earliest
        # clock first, ties toward the lowest id)
        while True:
            cands = [j for j in range(n) if nodes[j].alive
                     and nodes[j].has_work() and nodes[j].store.now < horizon]
            if not cands:
                break
            nodes[min(cands, key=lambda j: (nodes[j].store.now, j))].step()
        if t_arr is None and t_fault is None:
            break
        # the fault wins exact ties (the tied arrival then routes
        # around the new topology), matching cluster.rs
        if t_fault is not None and (t_arr is None or t_fault <= t_arr):
            f = sched_faults[fi]
            fi += 1
            if f[0] == "node-down":
                fnode, ft = f[1], f[2]
                if not nodes[fnode].alive:
                    continue
                dead = nodes[fnode]
                dead.store.advance_to(ft)
                dead.alive = False
                survivors = [j for j in range(n) if nodes[j].alive]
                if not survivors:
                    dead.fail_active("node %d down" % fnode)
                    continue
                # in-flight requests abort WITHOUT completions and
                # re-dispatch from the originals (value-idempotent:
                # per-request seeds — every id retires exactly once)
                for rid in dead.abort_active():
                    t = req_by_id[rid]
                    j = survivors[rr[0] % len(survivors)]
                    rr[0] += 1
                    assignments[rid] = j
                    nodes[j].enqueue_at(t, t.arrival_us)
                    redispatched += 1
                for req, stamp in dead.drain_pending():
                    j = survivors[rr[0] % len(survivors)]
                    rr[0] += 1
                    assignments[req.rid] = j
                    nodes[j].enqueue_at(req, stamp)
                keys = sorted(dead.store.host_pool)
                rehomed += len(keys)
                b = int(max(dead.per_bytes, 1.0))
                shares = [[] for _ in survivors]
                for i, key in enumerate(keys):
                    shares[i % len(survivors)].append(key)
                for j, share in zip(survivors, shares):
                    nodes[j].store.net_restore(share, b)
            elif f[0] == "node-rejoin":
                fnode, ft = f[1], f[2]
                if nodes[fnode].alive:
                    continue
                nodes[fnode].store.advance_to(ft)
                nodes[fnode].rejoin_restock()
                nodes[fnode].alive = True
                rejoins += 1
            elif f[0] == "dev-down":
                dev, ft = f[1], f[2]
                fnode = dev // devices_per_node
                if not nodes[fnode].alive:
                    continue
                nodes[fnode].store.advance_to(ft)
                m, d = nodes[fnode].store.device_down(dev % devices_per_node)
                dev_moved += m
                dev_dropped += d
            else:  # link window: pricing was installed at setup — the
                # activation only advances every alive node's clock (the
                # note_link_degrade event-log stamp)
                for j in range(n):
                    if nodes[j].alive:
                        nodes[j].store.advance_to(f[3])
        else:
            t = wl[idx]
            idx += 1
            j = place(t)
            assignments[t.rid] = j
            nodes[j].enqueue_at(t, t.arrival_us)

    total_us = max((nd.store.now for nd in nodes if nd.alive), default=0.0)
    tokens = sum(c["tokens"] for nd in nodes for c in nd.completions)
    clean = sum(c["tokens"] for nd in nodes for c in nd.completions
                if c["error"] is None)
    errored = sum(1 for nd in nodes for c in nd.completions
                  if c["error"] is not None)
    return {
        "tps": tokens / (total_us / 1e6) if total_us > 0 else 0.0,
        "goodput_tps": clean / (total_us / 1e6) if total_us > 0 else 0.0,
        "tokens": tokens,
        "total_us": total_us,
        "node_us": [nd.store.now for nd in nodes],
        "errored": errored,
        "rehomed": rehomed,
        "redispatched": redispatched,
        "rejoins": rejoins,
        "dev_moved": dev_moved,
        "dev_dropped": dev_dropped,
        "retries": sum(nd.store.retries for nd in nodes),
        "net_pulls": sum(nd.store.net_pulls for nd in nodes),
        "net_bytes": sum(nd.store.net_bytes for nd in nodes),
        "served": sum(len(nd.completions) for nd in nodes),
        "errors": errored,
        "served_ids": sorted(c["id"] for nd in nodes for c in nd.completions),
        "assignments": assignments,
        "alive": [nd.alive for nd in nodes],
        "node_finishes": [[c["finished_us"] for c in nd.completions]
                          for nd in nodes],
        "per_pull": [nd.store.net_bytes / nd.store.net_pulls
                     for nd in nodes if nd.store.net_pulls > 0],
        "node0_net_pulls": nodes[0].store.net_pulls,
    }


def main():
    print("== shard.rs acceptance margins (Floe lru, zipf 1.2, stick 0.5, 11 GB/dev) ==")
    mk = lambda dev, coal, spill: Params(
        System(FLOE, "lru", devices=dev, coalesce=coal, spill=spill),
        11.0, zipf_s=1.2, stickiness=0.5, seed=7)
    indep = simulate(mk(2, False, False), 64, 256)
    coal = simulate(mk(2, True, False), 64, 256)
    one = simulate(mk(1, False, False), 64, 256)
    coop = simulate(mk(2, True, True), 64, 256)
    print(f"  1 dev indep : {one}")
    print(f"  2 dev indep : {indep}")
    print(f"  2 dev coal  : {coal}")
    print(f"  2 dev coop  : {coop}")
    print(f"  bytes equal (indep vs coal): {indep['bytes'] == coal['bytes']}")
    print(f"  bus tx fewer: {coal['bus_tx']} < {indep['bus_tx']}: "
          f"{coal['bus_tx'] < indep['bus_tx']}")
    print(f"  tps coal/indep = {coal['tps']/indep['tps']:.4f} (assert >= 0.999)")
    print(f"  tps 2dev/1dev  = {coal['tps']/one['tps']:.4f} (assert > 1.02)")

    print("== PR 4 popularity margins (Floe lru, zipf 1.2, stick 0.5, 11 GB/dev, 2 dev) ==")
    mkp = lambda shard, rep, streams: Params(
        System(FLOE, "lru", devices=2, shard=shard,
               replicate_top=rep, compute_streams=streams),
        11.0, zipf_s=1.2, stickiness=0.5, seed=7)
    hash_coop = simulate(mkp("hash", 0, False), 64, 256)
    bal_coop = simulate(mkp("balanced", 0, False), 64, 256)
    bal_pop = simulate(mkp("balanced", 2, True), 64, 256)
    bal_rep_only = simulate(mkp("balanced", 2, False), 64, 256)
    print(f"  hash coop     : {hash_coop}")
    print(f"  balanced coop : {bal_coop}")
    print(f"  balanced rep  : {bal_rep_only}")
    print(f"  balanced pop  : {bal_pop}")
    print(f"  tps pop/hash       = {bal_pop['tps']/hash_coop['tps']:.4f} "
          f"(shard.rs asserts > 1.02 at 2 dev, > 1.10 at 4)")
    print(f"  tps streams-on/off = {bal_pop['tps']/bal_rep_only['tps']:.4f} "
          f"(FLOP scaling, shard.rs asserts > 1.03)")
    print(f"  max busy bal/hash  = {bal_coop['max_busy']:.0f}/{hash_coop['max_busy']:.0f} "
          f"= {bal_coop['max_busy']/hash_coop['max_busy']:.4f} "
          f"(hash is already balanced on this trace at n=2; the balanced<hash "
          f"max-busy property is pinned on a hash-colliding trace in "
          f"tests/shard_store.rs)")
    print(f"  rebalances: bal_coop {bal_coop['rebalances']} pop {bal_pop['rebalances']}")
    hc4 = simulate(Params(System(FLOE, 'lru', devices=4, shard='hash'),
                          11.0, zipf_s=1.2, stickiness=0.5, seed=7), 64, 256)
    bp4 = simulate(Params(System(FLOE, 'lru', devices=4, shard='balanced',
                                 replicate_top=2, compute_streams=True),
                          11.0, zipf_s=1.2, stickiness=0.5, seed=7), 64, 256)
    print(f"  4-dev tps pop/hash = {bp4['tps']/hc4['tps']:.4f}")

    print("== sim.rs sparsity_policy_hit_rate_not_worse_at_tight_vram (Naive 14GB) ==")
    lru = simulate(Params(System(NAIVE, "lru"), 14.0), 64, 128)
    spa = simulate(Params(System(NAIVE, "sparsity"), 14.0), 64, 128)
    print(f"  lru hit {lru['hit']:.4f}  sparsity hit {spa['hit']:.4f} "
          f"(assert sparsity >= lru - 0.02): {spa['hit'] >= lru['hit'] - 0.02}")

    print("== replay fidelity: fig6 ordering relations (12 GB, 64/128) ==")
    floe = simulate(Params(System(FLOE), 24.0), 64, 128)
    naive = simulate(Params(System(NAIVE), 24.0), 64, 128)
    adv = simulate(Params(System(ADV), 24.0), 64, 128)
    fid = simulate(Params(System(FIDDLER), 24.0), 64, 128)
    gpu = simulate(Params(System(GPU), 24.0), 64, 128)
    print(f"  floe {floe['tps']:.2f} adv {adv['tps']:.2f} fid {fid['tps']:.2f} "
          f"naive {naive['tps']:.2f} gpu {gpu['tps']:.2f}")
    print(f"  floe>adv {floe['tps']>adv['tps']}  floe>fid {floe['tps']>fid['tps']}  "
          f"adv>naive {adv['tps']>naive['tps']}  "
          f"floe>10x naive {floe['tps']>10*naive['tps']}  "
          f"floe>0.5 gpu {floe['tps']>0.5*gpu['tps']}")

    print("== more vram helps floe (12 vs 24) ==")
    lo = simulate(Params(System(FLOE), 12.0), 64, 128)
    hi = simulate(Params(System(FLOE), 24.0), 64, 128)
    print(f"  lo {lo['tps']:.2f} hi {hi['tps']:.2f} (assert hi >= lo*0.99): "
          f"{hi['tps'] >= lo['tps']*0.99}")

    print("== PR 5 boundary-synchronous batching (calibrated reuse) ==")
    pf = serving_params()
    rf = boundary_compute_reuse(pf)
    rn = boundary_compute_reuse(Params(System(NAIVE), 14.0))
    print(f"  reuse floe/3090 = {rf:.4f} (sim.rs asserts |r-0.108| < 0.02): "
          f"{abs(rf - 0.108) < 0.02}")
    print(f"  reuse naive/3090 = {rn:.4f} (asserts 0 < naive < floe): "
          f"{0.0 < rn < rf}")
    wl = workload_at(8.0, 12, 23)
    r1 = simulate_serving(pf, wl, 1)
    r4 = simulate_serving(pf, wl, 4)
    r8 = simulate_serving(pf, wl, 8)
    print(f"  cap1 tps {r1['tps']:.2f}  cap4 {r4['tps']:.2f}  cap8 {r8['tps']:.2f}")
    print(f"  cap4/cap1 = {r4['tps']/r1['tps']:.4f} (sim.rs asserts > 1.05): "
          f"{r4['tps'] > 1.05 * r1['tps']}")
    print(f"  cap8/cap1 = {r8['tps']/r1['tps']:.4f} (sim.rs asserts > 1.05): "
          f"{r8['tps'] > 1.05 * r1['tps']}")
    print(f"  cap1 reused {r1['reused']} (must be 0: one seq per boundary): "
          f"{r1['reused'] == 0}")
    print(f"  cap4 reused {r4['reused']} of {r4['full'] + r4['reused']} pair visits")
    wl2 = workload_at(8.0, 12, 7)
    s1 = simulate_serving(pf, wl2, 1)
    s8 = simulate_serving(pf, wl2, 8)
    print(f"  serveload test point cap8/cap1 = {s8['tps']/s1['tps']:.4f} "
          f"(asserts > 1): {s8['tps'] > s1['tps']}")
    wl3 = workload_at(16.0, 8, 11)
    vis = simulate_serving(pf, wl3, 4, per_boundary_check=True)
    print(f"  visits test (16 Hz, 8 req, cap 4): per-boundary full==distinct "
          f"{vis['per_boundary_ok']}, saw_batch {vis['saw_batch']}, "
          f"saw_reuse {vis['saw_reuse']}")

    print("== PR 6 event-core overlap (serve op point: Floe lru 14.25 GB, "
          "8 Hz x 12 req, seed 23) ==")
    po = serving_params(overlap=True)
    for cap, base in ((1, r1), (4, r4), (8, r8)):
        ov = simulate_serving(po, wl, cap)
        share_b = base["stall_demand"] / base["total_us"]
        share_o = ov["stall_demand"] / ov["total_us"]
        ratio = ov["tps"] / base["tps"]
        print(f"  cap{cap}: tps {base['tps']:.2f} -> {ov['tps']:.2f} "
              f"({ratio:.4f}x, sim.rs asserts >= 1.03 at cap 4), demand-stall "
              f"share {share_b:.4f} -> {share_o:.4f} "
              f"(strict decrease: {share_o < share_b})")

    print("== PR 6 single-shot overlap (Floe lru 11 GB, 64/256) ==")
    base1 = simulate(Params(System(FLOE, "lru"), 11.0,
                            zipf_s=1.2, stickiness=0.5, seed=7), 64, 256)
    ov1 = simulate(Params(System(FLOE, "lru", overlap=True), 11.0,
                          zipf_s=1.2, stickiness=0.5, seed=7), 64, 256)
    print(f"  tps {base1['tps']:.2f} -> {ov1['tps']:.2f} "
          f"({ov1['tps']/base1['tps']:.4f}x), demand stall "
          f"{base1['stall_demand']:.0f} -> {ov1['stall_demand']:.0f} us "
          f"(decrease: {ov1['stall_demand'] < base1['stall_demand']})")

    print("== PR 6 replica write-back (pop margins re-verified under the carve) ==")
    bal_pop2 = simulate(mkp("balanced", 2, True), 64, 256)
    print(f"  2-dev pop writebacks {bal_pop2['writebacks']} "
          f"(the write-back path itself is pinned by a forced-eviction "
          f"test in tests/shard_store.rs)")
    print(f"  2-dev tps pop/hash = {bal_pop2['tps']/hash_coop['tps']:.4f} "
          f"(floor 1.02), 4-dev = {bp4['tps']/hc4['tps']:.4f} (floor 1.10), "
          f"4-dev writebacks {bp4['writebacks']}")

    print("== PR 8 cluster tier (coordinator/cluster.rs mirror) ==")
    # 1-node cluster == simulate_serving, bit-exact (the cluster driver
    # must degenerate to the flat serving loop)
    pc = Params(System(FLOE), 14.25)  # cluster.rs::base_params
    wl_eq = gen_workload(10, 4.0, 8, 32, 16, 64, 23)
    one_c = simulate_cluster(pc, 1, 1, 14.25, wl_eq)
    flat = simulate_serving(member_params(pc, 1, "layer", 14.25), wl_eq, 4)
    print(f"  1-node cluster total_us {one_c['total_us']:.4f} == flat "
          f"{flat['total_us']:.4f}: {one_c['total_us'] == flat['total_us']}, "
          f"tokens {one_c['tokens']} == {flat['tokens']}: "
          f"{one_c['tokens'] == flat['tokens']}, net pulls "
          f"{one_c['net_pulls']} (must be 0)")
    # the acceptance margin: 2 nodes beat 1 at fixed 28.5 GB aggregate
    wl_m = gen_workload(24, 16.0, 8, 32, 16, 64, 7)
    m1 = simulate_cluster(pc, 1, 1, 28.5, wl_m)
    m2 = simulate_cluster(pc, 2, 1, 28.5, wl_m)
    print(f"  margin: 1 node {m1['tps']:.2f} tok/s, 2 nodes {m2['tps']:.2f} "
          f"tok/s, ratio {m2['tps']/m1['tps']:.4f} "
          f"(cluster.rs asserts > 1.4), errored {m1['errored']+m2['errored']}, "
          f"2-node served {m2['served']} of {len(wl_m)}")
    # corpus point: 2x1 round-robin @ 2x14.25 vs the lockstep artifact
    wl_c = workload_at(8.0, 12, 23)
    cc = simulate_cluster(serving_params(), 2, 1, 28.5, wl_c)
    print(f"  corpus: 2-node {cc['tps']:.2f} tok/s vs 1-node lockstep cap4 "
          f"{r4['tps']:.2f} ({cc['tps']/r4['tps']:.4f}x, replay_corpus "
          f"asserts > 1.5), errored {cc['errored']}, served {cc['served']}")
    # placements all serve everything; tight host RAM forces whole-expert
    # network pulls whose per-pull payload is identical across placements
    wl_b = gen_workload(10, 8.0, 8, 32, 16, 64, 19)
    pulls = []
    for pl in ("round-robin", "least-loaded", "expert-affinity"):
        r = simulate_cluster(pc, 2, 1, 28.5, wl_b, placement=pl,
                             host_ram_gb=4.0)
        pulls.extend(r["per_pull"])
        print(f"  {pl:>15}: served {r['served']}/{len(wl_b)} errored "
              f"{r['errored']} net pulls {r['net_pulls']} "
              f"({r['net_bytes']/1e6:.1f} MB)")
    print(f"  per-pull payloads identical: {len(set(pulls)) == 1} "
          f"({pulls[0]/1e6:.3f} MB each, {len(pulls)} pulls), nonzero: "
          f"{len(pulls) > 0}")
    # failure scenario: node 1 down mid-trace, tight host RAM. PR 10
    # re-dispatches the dead node's in-flight batch to survivors, so a
    # drop with survivors errors nothing and every id retires once
    wl_f = gen_workload(14, 8.0, 8, 32, 16, 64, 77)
    t_fail = wl_f[6].arrival_us + 1.0
    rf_ = simulate_cluster(pc, 2, 1, 28.5, wl_f, host_ram_gb=4.0,
                           failure=(1, t_fail))
    print(f"  failure @ {t_fail:.0f} us: errored {rf_['errored']} "
          f"(re-dispatch: must be 0), redispatched {rf_['redispatched']}, "
          f"rehomed {rf_['rehomed']}, served ids complete: "
          f"{rf_['served_ids'] == list(range(len(wl_f)))}, node1 clock "
          f"{rf_['node_us'][1]:.0f} >= t_fail: "
          f"{rf_['node_us'][1] >= t_fail}, survivor outlived: "
          f"{rf_['total_us'] > rf_['node_us'][1]}, node0 pulls "
          f"{rf_['node0_net_pulls']} >= rehomed: "
          f"{rf_['node0_net_pulls'] >= rf_['rehomed']}")
    assert rf_["errored"] == 0
    assert rf_["served_ids"] == list(range(len(wl_f)))
    # exp-cluster-sweep smoke cell (2x2 @ 28.5, serve-load shape)
    wl_s = workload_at(8.0, 8, 7)
    for pl in ("round-robin", "least-loaded", "expert-affinity"):
        r = simulate_cluster(serving_params(), 2, 2, 28.5, wl_s, placement=pl)
        print(f"  smoke 2x2 {pl:>15}: tokens {r['tokens']} errored "
              f"{r['errored']} served {r['served']}/{len(wl_s)}")

    print("== PR 9 quality-elastic fallback (exp-quality-latency mirror: "
          "cap 8, overlap, little carve 10%) ==")
    mkq = lambda vram, lf: Params(
        System(FLOE, "lru", overlap=True, little_frac=lf),
        vram, zipf_s=1.2, stickiness=0.5, seed=7)
    wl_q = workload_at(8.0, 12, 23)
    base_q = simulate_serving(mkq(11.0, 0.0), wl_q, 8)
    pin = simulate_serving(mkq(11.0, 0.10), wl_q, 8, slo_us=2.0e6)
    tpsx = pin["tps"] / base_q["tps"]
    p99x = base_q["p99"] / pin["p99"]
    share_b = base_q["stall_demand"] / base_q["total_us"]
    share_p = pin["stall_demand"] / pin["total_us"]
    print(f"  pin cell (11 GB, slo 2s): tps {base_q['tps']:.4f} -> "
          f"{pin['tps']:.4f} ({tpsx:.4f}x, quality.rs asserts > 1.0), p99 "
          f"{base_q['p99']:.1f} -> {pin['p99']:.1f} us ({p99x:.4f}x, asserts "
          f">= 1.10), demand share {share_b:.4f} -> {share_p:.4f} "
          f"(strict decrease: {share_p < share_b})")
    print(f"  degraded boundaries {pin['degraded_hits']} (asserts > 5000), "
          f"request share {pin['degraded_req_share']:.2f} (asserts >= 0.9), "
          f"stall-only degraded {base_q['degraded_hits']} (must be 0)")
    assert tpsx > 1.0 and p99x >= 1.10 and share_p < share_b
    assert pin["degraded_hits"] > 5000 and pin["degraded_req_share"] >= 0.9
    assert base_q["degraded_hits"] == 0
    # the frontier (quality.rs frontier_is_monotone_in_slo): looser SLO ->
    # p99 no lower, degraded-request share no higher, at every cap;
    # boundary counts strictly decrease only at the thrash-depth pin cap
    for vram in (11.0, 12.5, 14.25):
        prev_p99, prev_share, prev_hits = float("-inf"), float("inf"), None
        row = []
        for slo in (1.0e6, 2.0e6, 4.0e6, 8.0e6):
            r = simulate_serving(mkq(vram, 0.10), wl_q, 8, slo_us=slo)
            row.append(f"{slo/1e6:.0f}s: p99 {r['p99']/1e6:.2f} "
                       f"hits {r['degraded_hits']} "
                       f"req {r['degraded_req_share']:.2f}")
            assert r["p99"] >= prev_p99, f"p99 not monotone @ {vram}/{slo}"
            assert r["degraded_req_share"] <= prev_share
            if vram == 11.0 and prev_hits is not None:
                assert r["degraded_hits"] < prev_hits
            prev_p99, prev_share = r["p99"], r["degraded_req_share"]
            prev_hits = r["degraded_hits"]
        print(f"  {vram:>5} GB frontier: " + "; ".join(row))
    # an SLO budget without the carve never degrades, never moves a bit
    slo_only = simulate_serving(mkq(11.0, 0.0), wl_q, 8, slo_us=2.0e6)
    print(f"  slo-without-carve bit-exact: total_us "
          f"{slo_only['total_us'] == base_q['total_us']}, demand stall "
          f"{slo_only['stall_demand'] == base_q['stall_demand']}, degraded "
          f"{slo_only['degraded_hits']} (must be 0)")
    assert slo_only["total_us"] == base_q["total_us"]
    assert slo_only["stall_demand"] == base_q["stall_demand"]
    assert slo_only["degraded_hits"] == 0

    print("== PR 10 deterministic fault schedules (exp-chaos-sweep mirror: "
          "2 nodes x 2 dev, host 4 GB; 57 GB full / 28.5 GB thin) ==")
    ps = serving_params()
    # fault-free identity: a retry policy with no outage windows never
    # fires — bit-identical clocks, zero retries (cluster.rs
    # retry_policy_without_outages_is_bit_identical)
    wl_k = workload_at(8.0, 12, 7)
    plain = simulate_cluster(ps, 2, 2, 28.5, wl_k, host_ram_gb=4.0)
    armed = simulate_cluster(ps, 2, 2, 28.5, wl_k, host_ram_gb=4.0,
                             retry=(8, 10_000.0))
    print(f"  retry-without-outages bit-exact: total_us "
          f"{plain['total_us'] == armed['total_us']}, retries "
          f"{armed['retries']} (must be 0)")
    assert plain["total_us"] == armed["total_us"]
    assert armed["retries"] == 0
    # pinned drop+rejoin cell (chaos.rs smoke + timeline replay): node 1
    # drops after the first quartile arrival, rejoins before the last —
    # zero errors, exactly-once retirement, restock pulls real bytes.
    # 57 GB aggregate = 14.25 GB/device, the serveload default, so the
    # devices hold real resident sets worth tearing down
    nq = len(wl_k)
    q1 = wl_k[nq // 4].arrival_us
    mid = wl_k[nq // 2].arrival_us
    q3 = wl_k[(3 * nq) // 4].arrival_us
    dr = simulate_cluster(ps, 2, 2, 57.0, wl_k, host_ram_gb=4.0,
                          faults=[("node-down", 1, q1 + 1.0),
                                  ("node-rejoin", 1, q3 - 1.0)])
    print(f"  drop+rejoin: errored {dr['errored']} (must be 0), "
          f"redispatched {dr['redispatched']}, rehomed {dr['rehomed']}, "
          f"rejoins {dr['rejoins']}, served ids complete: "
          f"{dr['served_ids'] == list(range(nq))}, node1 alive at end: "
          f"{dr['alive'][1]}, net {dr['net_bytes']/1e6:.1f} MB")
    assert dr["errored"] == 0
    assert dr["served_ids"] == list(range(nq))
    assert dr["rejoins"] == 1
    assert dr["redispatched"] > 0 or dr["rehomed"] > 0
    # the rejoined node re-enters placement: it retires at least one
    # completion after its rejoin stamp (post-rejoin share > 0)
    n1_post = sum(1 for f in dr["node_finishes"][1] if f >= q3 - 1.0)
    print(f"  drop+rejoin: node1 completions after rejoin {n1_post} "
          f"(must be > 0)")
    assert n1_post > 0
    # device drop: the dead device's residents re-home hottest-first
    # into surviving free capacity; requests keep retiring cleanly
    dd = simulate_cluster(ps, 2, 2, 57.0, wl_k, host_ram_gb=4.0,
                          faults=[("dev-down", 1, mid + 1.0)])
    print(f"  dev-drop: errored {dd['errored']} (must be 0), moved "
          f"{dd['dev_moved']}, dropped {dd['dev_dropped']}, served ids "
          f"complete: {dd['served_ids'] == list(range(nq))}")
    assert dd["errored"] == 0
    assert dd["served_ids"] == list(range(nq))
    assert dd["dev_moved"] + dd["dev_dropped"] > 0
    # pinned link-flap cell (chaos.rs margin test): a full cross-node
    # NET outage across the middle half of a 16-request trace, at the
    # thin-cache point (28.5 GB aggregate -> zero cache budget, every
    # access demand-fetches; keys past the 4 GB host pool ride NET).
    # Fail-fast errors the requests whose demand fetches land in the
    # window; 8 x 10 ms bounded backoff outlasts every window and
    # converts the losses into stall — the goodput margin the Rust
    # test pins at >= 1.10x
    wl_g = workload_at(8.0, 16, 7)
    ng = len(wl_g)
    flap = [("link", "net", 0.0, wl_g[ng // 4].arrival_us + 1.0,
             wl_g[(3 * ng) // 4].arrival_us + 1.0)]
    ff = simulate_cluster(ps, 2, 2, 28.5, wl_g, host_ram_gb=4.0,
                          faults=flap)
    rt = simulate_cluster(ps, 2, 2, 28.5, wl_g, host_ram_gb=4.0,
                          faults=flap, retry=(8, 10_000.0))
    ratio = (rt["goodput_tps"] / ff["goodput_tps"]
             if ff["goodput_tps"] > 0 else float("inf"))
    print(f"  flap fail-fast: errored {ff['errored']} (must be > 0), "
          f"goodput {ff['goodput_tps']:.2f} tok/s, retries {ff['retries']} "
          f"(must be 0)")
    print(f"  flap+retry   : errored {rt['errored']} (must be 0), "
          f"goodput {rt['goodput_tps']:.2f} tok/s, retries {rt['retries']} "
          f"(must be > 0), served ids complete: "
          f"{rt['served_ids'] == list(range(ng))}")
    print(f"  retry/fail-fast goodput = {ratio:.4f} "
          f"(chaos.rs asserts >= 1.10)")
    assert ff["errored"] > 0
    assert ff["retries"] == 0
    assert rt["errored"] == 0
    assert rt["retries"] > 0
    assert rt["served_ids"] == list(range(ng))
    assert ratio >= 1.10


if __name__ == "__main__":
    main()

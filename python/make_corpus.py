#!/usr/bin/env python3
"""Generate the committed replay-corpus artifacts (rust/tests/replay_corpus/).

Writes three *spec-only* timeline artifacts (format v1, see DESIGN.md S9 and
rust/src/coordinator/timeline.rs) at the serve-load operating point the
regression pin uses: FloE on a simulated RTX-3090 at 14.25 GB, skewed sticky
routing, batch cap 4, 12 requests at 8 req/s (seed 23) -- once lockstep, once
with `--overlap`, and once as a 2-node x 1-device round-robin *cluster*
session at the same aggregate VRAM (2 x 14.25 GB, the FLAG_CLUSTER
extension of DESIGN.md S10). The artifacts carry no observation section: the
replayer re-drives the session from the spec and the in-tree test
(rust/tests/replay_corpus.rs) asserts both that these bytes are exactly what
the Rust encoder would emit and that the replayed tok/s ratios hold.

Spec-only artifacts are committed (instead of full recordings) so the corpus
stays a few hundred bytes and never embeds floats computed by a second
implementation of the simulator: every observation byte is re-derived by the
replayer itself.
"""

import os
import struct

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "rust", "tests", "replay_corpus")

MAGIC = b"FLTL"
VERSION = 1
FLAG_REPLAYABLE = 1 << 1  # no observations section: bit 0 stays clear
FLAG_CLUSTER = 1 << 2  # ClusterExt section appended after the spec


def u8(v):
    return struct.pack("<B", v)


def u32(v):
    return struct.pack("<I", v)


def u64(v):
    return struct.pack("<Q", v)


def f64(v):
    return struct.pack("<d", v)


def spec_bytes(overlap):
    """SessionSpec at exp::serveload::sweep_params(Lru, 14.25), cap 4."""
    b = b""
    b += u8(0)  # hw: Rtx3090
    # SystemConfig (defaults of SystemConfig::new(Floe), overlap toggled)
    b += u8(0)  # kind: Floe (SystemKind::ALL[0])
    b += f64(0.9)  # sparsity
    b += u8(3)  # quant_bits
    b += f64(0.15)  # intra_margin
    b += u64(50)  # chunk_channels
    b += u8(0)  # residency: Lru (ResidencyKind::ALL[0])
    b += f64(0.999)  # sparsity_decay (store::DEFAULT_SPARSITY_DECAY)
    b += u64(1)  # devices
    b += u8(0)  # shard: Layer (ShardPolicy::ALL[0])
    b += u8(0)  # coalesce
    b += u8(0)  # spill
    b += u64(0)  # replicate_top
    b += u8(0)  # compute_streams
    b += u8(1 if overlap else 0)  # overlap
    b += u8(0)  # hetero_fleet
    b += f64(14.25)  # vram_gb (serveload::DEFAULT_VRAM_GB)
    # RoutingModel (serveload::sweep_params)
    b += f64(1.2)  # zipf_s
    b += f64(0.5)  # stickiness
    b += u64(7)  # seed
    # predictor hit rates (SimParams::mixtral_on defaults)
    b += f64(0.88)  # inter_hit
    b += f64(0.95)  # intra_recall
    b += f64(0.75)  # adv_prefetch_hit
    b += u64(4)  # max_batch
    # workload: Spec (serveload::workload_at(8.0, 12, 23) shape)
    b += u8(0)
    b += u64(12)  # n_requests
    b += f64(8.0)  # arrival_rate_hz
    b += u64(8) + u64(24)  # prompt_len
    b += u64(16) + u64(48)  # output_tokens
    b += u64(23)  # seed
    return b


def cluster_bytes():
    """ClusterExt: 2 nodes x 1 device, round-robin, 28.5 GB aggregate,
    64 GB host pools, no failure, no observation section (spec-only)."""
    b = b""
    b += u32(2)  # n_nodes
    b += u32(1)  # devices_per_node
    b += u8(0)  # shard: Layer (ShardPolicy::ALL[0])
    b += u8(0)  # placement: RoundRobin (ClusterPlacement::tag)
    b += f64(2.0 * 14.25)  # vram_gb_total (fixed aggregate)
    b += f64(64.0)  # host_ram_gb
    b += u8(0)  # failure: absent
    b += u8(0)  # obs: absent
    return b


def artifact(overlap, cluster=False):
    flags = FLAG_REPLAYABLE | (FLAG_CLUSTER if cluster else 0)
    b = MAGIC + u32(VERSION) + u32(flags) + spec_bytes(overlap)
    if cluster:
        b += cluster_bytes()
    return b


def main():
    os.makedirs(OUT_DIR, exist_ok=True)
    for overlap, cluster, name in [
        (False, False, "serveload_cap4_lockstep.fltl"),
        (True, False, "serveload_cap4_overlap.fltl"),
        (False, True, "cluster_2x1_rr.fltl"),
    ]:
        path = os.path.join(OUT_DIR, name)
        data = artifact(overlap, cluster)
        with open(path, "wb") as f:
            f.write(data)
        print(f"wrote {path} ({len(data)} bytes)")


if __name__ == "__main__":
    main()

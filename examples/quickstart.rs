//! Quickstart: load the AOT-compiled model and generate text under dense
//! and FloE-compressed experts.
//!
//!   make artifacts && cargo run --release --example quickstart

use floe::config::ExpertMode;
use floe::engine::{Engine, NoObserver};
use floe::model::tokenizer::ByteTokenizer;

fn main() -> anyhow::Result<()> {
    let art = floe::artifacts_dir();
    println!("loading artifacts from {} ...", art.display());
    let mut eng = Engine::load(&art)?;
    let c = eng.cfg().clone();
    println!(
        "model: {} — d={} layers={} experts={} (top-{}), vocab {}",
        c.name, c.d_model, c.n_layers, c.n_experts, c.top_k, c.vocab
    );

    for (name, mode) in [
        ("dense fp32", ExpertMode::Dense),
        ("FloE 70% + INT2 up", ExpertMode::Floe { level: 0.7 }),
        ("FloE 90% + INT2 up", ExpertMode::Floe { level: 0.9 }),
    ] {
        let prompt = b"the capital of albor is ";
        let t0 = std::time::Instant::now();
        let out = eng.generate(prompt, 32, mode, 0.0, 0, &mut NoObserver)?;
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "\n[{name}] {:.1} tok/s\n  {}{}",
            (prompt.len() + out.len()) as f64 / dt,
            String::from_utf8_lossy(prompt),
            ByteTokenizer::decode(&out)
        );
    }
    Ok(())
}

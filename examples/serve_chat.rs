//! Serve + client demo of the line-JSON TCP protocol: spawns the server
//! with a request cap, then a client thread that sends three requests and
//! prints the streamed responses.
//!
//!   make artifacts && cargo run --release --example serve_chat
//!
//! Two serving features ride on the same protocol (DESIGN.md §9):
//! `{"cmd":"stats"}` on any connection returns the per-request inspector
//! report (queue-wait p50/p95/p99, demand-vs-prefetch stall split, batch
//! occupancy, per-device bus busy share), and `ServerOpts::record` (CLI:
//! `floe serve --record session.fltl`) writes the whole session as a
//! timeline artifact at exit — `floe replay --artifact session.fltl`
//! re-derives the same report offline, bit-for-bit.
//!
//! Requests may carry a per-request latency budget: `"slo_us":2e6` is
//! echoed back on the response along with `degraded_hits`, the number
//! of expert resolutions the quality-elastic fallback (DESIGN.md §11)
//! served from the always-resident little tier to stay inside the
//! budget. The fallback only fires when the store carves a little-tier
//! pool (CLI: `floe serve --little-frac 0.1 --backend sim`); without
//! the carve the field is accounting-inert and runs stay bit-exact.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use floe::coordinator::policy::{SystemConfig, SystemKind};
use floe::server::{serve, ServerOpts};

fn main() -> anyhow::Result<()> {
    let art = floe::artifacts_dir();
    let port = 7399u16;

    let client = std::thread::spawn(move || -> anyhow::Result<()> {
        // wait for the server socket
        let mut tries = 0;
        let stream = loop {
            match TcpStream::connect(("127.0.0.1", port)) {
                Ok(s) => break s,
                Err(e) => {
                    tries += 1;
                    if tries > 100 {
                        return Err(e.into());
                    }
                    std::thread::sleep(std::time::Duration::from_millis(200));
                }
            }
        };
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);
        for prompt in [
            "the capital of elim is ",
            "say crag: ",
            "7+2=",
        ] {
            writeln!(
                writer,
                "{{\"prompt\":\"{prompt}\",\"max_tokens\":16,\"temperature\":0.0}}"
            )?;
            let mut line = String::new();
            reader.read_line(&mut line)?;
            println!("<- {}", line.trim());
        }
        Ok(())
    });

    // server runs on the main thread (PJRT engine is not Send); exits
    // after one connection's worth of requests. The expert store is
    // placement-aware: `with_devices(n, shard)` shards residency across
    // n GPUs with coalesced prefetch plans (the `serve` CLI exposes this
    // as `--devices N --shard-policy layer|expert|hash|balanced`, plus
    // `--sparsity-decay` for the sparsity policy's EMA constant); one
    // device reproduces the classic single-GPU pipeline exactly.
    // At `--devices > 1` the popularity machinery is opt-in:
    // `balanced` re-homes experts by measured activation mass,
    // `.with_replication(k)` / `--replicate-top k --compute-streams`
    // replicates the k hottest experts across devices and runs
    // per-device compute streams so added devices scale FLOPs too,
    // `--hetero-fleet` gives the devices descending GEMV throughput,
    // and `--overlap` lets transfer completions release waiting expert
    // GEMVs mid-boundary. The generation engine side takes
    // `--kernel-threads N` (native kernel pool; 1 is bit-exact with
    // single-threaded). `.with_little_frac(f)` / `--little-frac f`
    // carves the little tier that backs the `slo_us` fallback above,
    // and `exp-cluster-sweep --nodes N --devices D` lifts the same
    // store placement to a multi-node fleet.
    let mut system = SystemConfig::new(SystemKind::Floe)
        .with_devices(1, floe::config::ShardPolicy::Layer);
    system.sparsity = 0.8;
    serve(
        &art,
        ServerOpts {
            port,
            system,
            vram_budget_bytes: 512 * 1024,
            max_requests: 3,
            ..ServerOpts::default()
        },
    )?;
    client.join().unwrap()?;
    Ok(())
}

//! End-to-end driver (EXPERIMENTS.md §E2E): load the trained model, serve
//! a batch of real requests through the full FloE coordinator — dual
//! predictors, expert cache, compact transfers — and compare against the
//! offloading baselines on latency, throughput and output quality.
//!
//!   make artifacts && cargo run --release --example end_to_end

use floe::coordinator::policy::{SystemConfig, SystemKind};
use floe::coordinator::serve::{Coordinator, Request};
use floe::model::tokenizer::ByteTokenizer;
use floe::util::table::{f2, f3, Table};

fn main() -> anyhow::Result<()> {
    let art = floe::artifacts_dir();
    let prompts = [
        "the capital of albor is ",
        "the capital of jorvik is ",
        "say plume: ",
        "3+4=",
        "the miller carried a copper kettle ",
        "match ([{",
    ];

    let mut table = Table::new(
        "end-to-end serving: 6 requests x 24 tokens per system",
        &["system", "prefill ms/req", "compute TPS", "effective TPS",
          "stall ms/tok", "cache hit", "inter hit"],
    );

    for kind in [
        SystemKind::Floe,
        SystemKind::AdvancedOffload,
        SystemKind::NaiveOffload,
        SystemKind::GpuResident,
    ] {
        let mut sys = SystemConfig::new(kind);
        sys.sparsity = 0.8;
        let budget = if kind == SystemKind::GpuResident {
            usize::MAX / 2
        } else {
            512 * 1024
        };
        let mut coord = Coordinator::new(&art, sys, budget)?;
        coord.calibrate_layer_time()?;
        let reqs: Vec<Request> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| Request {
                id: i as u64,
                prompt: p.as_bytes().to_vec(),
                max_tokens: 24,
                temperature: 0.0,
                seed: i as u64,
                slo_us: None,
            })
            .collect();
        let t0 = std::time::Instant::now();
        let done = coord.run_batch(&reqs)?;
        let wall = t0.elapsed().as_secs_f64();

        if kind == SystemKind::Floe {
            println!("FloE completions ({} requests in {:.2}s wall):", done.len(), wall);
            for c in &done {
                println!(
                    "  [{}] {}{}",
                    c.id,
                    prompts[c.id as usize],
                    ByteTokenizer::decode(&c.text).replace('\n', " ")
                );
            }
            println!();
        }

        let tokens: usize = done.iter().map(|c| c.tokens).sum();
        let decode_s: f64 = done.iter().map(|c| c.decode_s).sum();
        let stall_s: f64 = done.iter().map(|c| c.stall_virtual_s).sum();
        let prefill_ms: f64 =
            1e3 * done.iter().map(|c| c.prefill_s).sum::<f64>() / done.len() as f64;
        let st = coord.pipeline.stats();
        table.row(vec![
            kind.name().to_string(),
            f2(prefill_ms),
            f2(tokens as f64 / decode_s.max(1e-9)),
            f2(tokens as f64 / (decode_s + stall_s).max(1e-9)),
            f3(1e3 * stall_s / tokens as f64),
            f2(st.cache_hit_rate()),
            if kind == SystemKind::Floe {
                f2(st.inter_hit_rate())
            } else {
                "-".into()
            },
        ]);
    }
    table.print();
    println!(
        "\n(compute TPS is real PJRT wall-clock; effective TPS adds the \
         modeled PCIe stall time — DESIGN.md §2 substitutions)"
    );
    Ok(())
}

//! VRAM-budget sweep on the *real* coordinator (tiny-scale Fig 8 analog):
//! shrink the expert-cache budget and watch cache hit rate, demand
//! fetches and modeled stall time respond.
//!
//!   make artifacts && cargo run --release --example offload_sweep

use floe::coordinator::policy::{SystemConfig, SystemKind};
use floe::coordinator::serve::{Coordinator, Request};
use floe::util::table::{f2, f3, Table};

fn main() -> anyhow::Result<()> {
    let art = floe::artifacts_dir();
    let mut t = Table::new(
        "FloE on shrinking expert-cache budgets (3 requests x 32 tokens)",
        &["cache budget KB", "cache hit", "demand fetches", "prefetches",
          "stall ms/tok", "effective TPS"],
    );
    for budget_kb in [32usize, 64, 128, 256, 512, 1024] {
        let mut sys = SystemConfig::new(SystemKind::Floe);
        sys.sparsity = 0.8;
        let mut coord = Coordinator::new(&art, sys, budget_kb * 1024)?;
        coord.calibrate_layer_time()?;
        let reqs: Vec<Request> = (0..3)
            .map(|i| Request {
                id: i,
                prompt: b"the sailor mended the torn map by the river. ".to_vec(),
                max_tokens: 32,
                temperature: 0.0,
                seed: i,
                slo_us: None,
            })
            .collect();
        let done = coord.run_batch(&reqs)?;
        let tokens: usize = done.iter().map(|c| c.tokens).sum();
        let decode_s: f64 = done.iter().map(|c| c.decode_s).sum();
        let stall_s: f64 = done.iter().map(|c| c.stall_virtual_s).sum();
        let st = coord.pipeline.stats();
        t.row(vec![
            budget_kb.to_string(),
            f2(st.cache_hit_rate()),
            st.demand_fetches.to_string(),
            st.prefetches.to_string(),
            f3(1e3 * stall_s / tokens as f64),
            f2(tokens as f64 / (decode_s + stall_s).max(1e-9)),
        ]);
    }
    t.print();
    println!("\n(paper Fig 8 shape: more VRAM -> fewer reloads -> higher TPS)");
    Ok(())
}

//! Line-JSON TCP serving front-end with a concurrent admission queue and
//! continuous batching.
//!
//! Protocol: one JSON object per line on the socket —
//!   request:  {"prompt": "...", "max_tokens": 32, "temperature": 0.0,
//!              "seed": 0, "tag": <any JSON, echoed back>}
//!   response: {"id": n, "tag": ..., "text": "...", "tokens": n,
//!              "compute_tps": x, "effective_tps": y, "prefill_us": us,
//!              "queue_wait_us": us, "stall_us": us, "stall_demand_us": us,
//!              "stall_prefetch_us": us, "batch_size": n}
//!   error:    {"error": "..."} for a malformed request line, or
//!             {"id": n, "error": "...", "text": "...", "tokens": n,
//!             "tag": ...} when an admitted request fails in the backend
//!             — the partial `text`/`tokens` are whatever the request
//!             produced before the failure, and an injected fault adds
//!             "fault_cause": "node-down" | "link-outage" |
//!             "retry-exhausted" | "device-down" (DESIGN.md §12) so
//!             callers can tell infrastructure faults from bad requests;
//!             either way the connection (and the server) keeps serving
//!   stats:    {"cmd": "stats", "tag": ...} → one JSON object with the
//!             per-request inspector report over everything served so
//!             far (queue-wait p50/p95/p99, demand-vs-prefetch stall
//!             split, batch occupancy, per-device bus busy share,
//!             transfer retry count —
//!             `coordinator::timeline::InspectorReport`); a stats reply
//!             counts toward `--max-requests`
//!   shutdown: {"cmd": "shutdown", "tag": ...} → graceful drain: the
//!             server acks {"shutdown": "draining", "active": n} at
//!             once, stops admitting (late requests get {"error":
//!             "server draining"}), finishes the in-flight batch and
//!             everything already queued, flushes any recording, and
//!             exits 0 — `--max-requests` rides the same drain path
//!
//! Recording: with `ServerOpts::record` set (CLI `--record <path>`), the
//! session is captured through `coordinator::timeline::RecordingBackend`
//! — scheduler arrivals/admissions/retirements, the sim backend's event
//! log, per-request accounting and the final store stats — and written at
//! exit as an inspect-only timeline artifact (`floe replay` reports live
//! recordings as not replayable: wall-clock arrival interleaving is not a
//! pure function of the spec).
//!
//! Response fields: `id` is the server-assigned arrival index;
//! `queue_wait_us` is time from arrival to admission into the decode
//! batch; `stall_us` is the request's attributed transfer-stall time,
//! decomposed into `stall_demand_us` (nothing was in flight) and
//! `stall_prefetch_us` (a predicted transfer landed late); `batch_size`
//! is the largest decode batch the request was part of.
//!
//! Read robustness: each reader thread runs under a per-connection read
//! timeout (`ServerOpts::read_timeout_ms`) and a hard 64 KiB frame cap,
//! so a client that stalls mid-frame or streams an unterminated line
//! cannot pin a reader thread or grow its buffer without bound — the
//! oversized frame gets one error reply, the stalled connection is
//! dropped, and the rest of the server never notices.
//!
//! Concurrency model: the accept loop and one reader thread per
//! connection parse request lines into a shared mpsc admission queue.
//! The single coordinator thread (the PJRT engine is not `Send`) drains
//! the queue with the continuous-batching `Scheduler` — new arrivals join
//! the in-flight decode batch at token boundaries, FIFO up to
//! `--max-batch`; finished sequences retire and are answered immediately.
//! Responses on a pipelined connection can therefore complete out of
//! order: correlate with the echoed `tag`. Each connection also owns a
//! *writer thread* fed by a channel: the coordinator and the reader
//! thread (inline error replies) enqueue lines and never touch the
//! socket, so a slow or stalled client can no longer block a token
//! boundary — it only backs up its own connection's queue. Line order on
//! one connection is the channel order (single writer drains it).

use std::collections::HashMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::policy::SystemConfig;
use crate::coordinator::sched::{Scheduler, SeqBackend, ServeCompletion};
use crate::coordinator::serve::{Coordinator, Request};
use crate::coordinator::sim::{SimParams, SimServeBackend};
use crate::coordinator::timeline::{
    self, CompletionRecord, InspectorReport, RecordingBackend, SessionRecording, StatsRecord,
};
use crate::model::tokenizer::ByteTokenizer;
use crate::util::json::{parse, write as jwrite, Json};

pub struct ServerOpts {
    pub port: u16,
    pub system: SystemConfig,
    pub vram_budget_bytes: usize,
    /// exit after this many responses — request completions plus `stats`
    /// replies (0 = run forever)
    pub max_requests: usize,
    /// continuous-batching cap: at most this many sequences decode
    /// concurrently (admission stays FIFO)
    pub max_batch: usize,
    /// batch-formation window: when the batch is idle, wait this long
    /// after the first arrival so near-simultaneous requests decode
    /// together (0 = admit immediately)
    pub gather_ms: u64,
    /// write the session as a timeline artifact here at exit (sim
    /// backend: includes the event-core log)
    pub record: Option<PathBuf>,
    /// per-connection socket read timeout: a client that goes silent
    /// (including mid-frame) for this long has its connection dropped
    /// by the reader thread (0 = wait forever); queued responses still
    /// flow — only the read half dies
    pub read_timeout_ms: u64,
}

impl Default for ServerOpts {
    fn default() -> Self {
        ServerOpts {
            port: 7399,
            system: SystemConfig::new(crate::coordinator::policy::SystemKind::Floe),
            vram_budget_bytes: 512 * 1024,
            max_requests: 0,
            max_batch: 8,
            gather_ms: 0,
            record: None,
            read_timeout_ms: 30_000,
        }
    }
}

/// Handle to a connection's writer thread, shared by the reader thread
/// (inline error replies) and the coordinator (responses). `send_line`
/// only enqueues — the socket write happens on the connection's own
/// writer thread, so the coordinator never blocks on a slow client. The
/// pending counter + condvar let the server drain queued responses
/// before a `--max-requests` exit.
#[derive(Clone)]
struct ConnTx {
    tx: mpsc::Sender<String>,
    pending: Arc<(Mutex<usize>, Condvar)>,
}

impl ConnTx {
    /// Spawn the connection's writer thread over its own clone of the
    /// stream.
    fn spawn(stream: TcpStream) -> ConnTx {
        let (tx, rx) = mpsc::channel::<String>();
        let pending: Arc<(Mutex<usize>, Condvar)> =
            Arc::new((Mutex::new(0), Condvar::new()));
        let counter = Arc::clone(&pending);
        thread::spawn(move || {
            let mut out = BufWriter::new(stream);
            let mut dead = false;
            // exits when every sender (reader thread + response routes)
            // has dropped its handle
            while let Ok(line) = rx.recv() {
                if !dead {
                    if let Err(e) = writeln!(out, "{line}").and_then(|_| out.flush()) {
                        eprintln!("response write failed: {e}");
                        dead = true; // keep draining so pending counts settle
                    }
                }
                let (lock, cv) = &*counter;
                *lock.lock().unwrap() -= 1;
                cv.notify_all();
            }
        });
        ConnTx { tx, pending }
    }

    /// Stable identity of the connection this handle writes to (clones
    /// share one pending counter) — the drain set's dedup key.
    fn key(&self) -> usize {
        Arc::as_ptr(&self.pending) as *const () as usize
    }

    /// Queue one response line; never blocks on the socket.
    fn send_line(&self, line: String) {
        let (lock, cv) = &*self.pending;
        *lock.lock().unwrap() += 1;
        if self.tx.send(line).is_err() {
            // writer thread gone (only possible once all senders dropped
            // — defensive): roll the count back
            *lock.lock().unwrap() -= 1;
            cv.notify_all();
        }
    }

    /// Block (bounded) until the writer has drained everything queued so
    /// far — used before a `--max-requests` exit so final responses are
    /// on the wire before the process goes away.
    fn drain(&self, timeout: Duration) {
        let (lock, cv) = &*self.pending;
        let deadline = Instant::now() + timeout;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return;
            }
            let (guard, _) = cv.wait_timeout(n, left).unwrap();
            n = guard;
        }
    }
}

/// A parsed request en route from a reader thread to the coordinator.
struct InboundReq {
    req: Request,
    tag: Option<Json>,
    conn: ConnTx,
    /// reader-side arrival stamp: queue wait includes time spent in the
    /// mpsc channel and the gather window, not just the scheduler queue
    arrival: Instant,
}

/// One message from a reader thread to the coordinator.
enum Inbound {
    Req(InboundReq),
    /// `{"cmd":"stats"}` — answered inline from the running accounting
    Stats { tag: Option<Json>, conn: ConnTx },
    /// `{"cmd":"shutdown"}` — ack, stop admission, drain the in-flight
    /// batch, flush any recording, exit 0
    Shutdown { tag: Option<Json>, conn: ConnTx },
}

/// What the coordinator loop hands back at exit: the backend plus the
/// session recording (scheduler timeline entries, arrival trace,
/// per-request accounting, event log and final store snapshot).
pub struct ServeOutcome<B> {
    pub backend: B,
    pub recording: SessionRecording,
}

/// Serve over the real engine (requires artifacts + the `pjrt` feature
/// at runtime). The coordinator runs on the calling thread.
pub fn serve(art_dir: &Path, opts: ServerOpts) -> Result<()> {
    let mut coord = Coordinator::new(art_dir, opts.system.clone(), opts.vram_budget_bytes)?;
    coord.calibrate_layer_time()?;
    let listener = TcpListener::bind(("127.0.0.1", opts.port))
        .with_context(|| format!("bind 127.0.0.1:{}", opts.port))?;
    serve_on(listener, coord, &opts).map(|_| ())
}

/// Serve over the discrete-event simulated coordinator — the same
/// scheduler and protocol with roofline latencies on a virtual timeline,
/// so the full TCP path runs without artifacts or the `pjrt` feature.
pub fn serve_sim(params: SimParams, opts: ServerOpts) -> Result<()> {
    let listener = TcpListener::bind(("127.0.0.1", opts.port))
        .with_context(|| format!("bind 127.0.0.1:{}", opts.port))?;
    serve_sim_listener(listener, params, opts).map(|_| ())
}

/// `serve_sim` over a pre-bound listener (tests bind port 0 and read the
/// ephemeral address back). Returns the backend + session recording at
/// exit so callers can inspect the store's final accounting — the
/// loopback integration test asserts the attribution ledger retired down
/// to the in-flight batch. With `opts.record` set, the backend logs
/// event-core pops and the session is written as a timeline artifact.
pub fn serve_sim_listener(
    listener: TcpListener,
    params: SimParams,
    opts: ServerOpts,
) -> Result<ServeOutcome<SimServeBackend>> {
    // KV reservation for the largest context the protocol admits
    let kv_tokens = opts.max_batch.max(1) * (MAX_TOKENS_CAP + 256);
    let backend = if opts.record.is_some() {
        SimServeBackend::new_traced(params.clone(), kv_tokens)
    } else {
        SimServeBackend::new(params.clone(), kv_tokens)
    };
    let out = serve_on(listener, backend, &opts)?;
    if let Some(path) = &opts.record {
        let tl = timeline::server_timeline(&params, opts.max_batch, &out.recording);
        std::fs::write(path, tl.to_bytes())
            .with_context(|| format!("write timeline artifact {}", path.display()))?;
        println!("recorded session timeline to {}", path.display());
    }
    Ok(out)
}

/// The coordinator loop over any `SeqBackend`. Returns the backend and
/// the session recording after `opts.max_requests` responses or a
/// `{"cmd":"shutdown"}` drain — both exit through the same path: stop
/// admitting, finish the in-flight batch, flush the writer threads (the
/// accept thread exits with the process; its listener keeps the port
/// until then).
pub fn serve_on<B: SeqBackend>(
    listener: TcpListener,
    backend: B,
    opts: &ServerOpts,
) -> Result<ServeOutcome<B>> {
    let addr = listener.local_addr()?;
    println!("floe serving on {addr} (max-batch {})", opts.max_batch.max(1));
    let (tx, rx) = mpsc::channel::<Inbound>();
    let read_timeout_ms = opts.read_timeout_ms;
    thread::spawn(move || accept_loop(listener, tx, read_timeout_ms));

    let mut sched = Scheduler::new(RecordingBackend::new(backend), opts.max_batch);
    // per-request accounting history, in retirement order — feeds the
    // `stats` command live and the recorded artifact at exit
    let mut history: Vec<CompletionRecord> = Vec::new();
    // per-request response route: connection + echoed tag
    let mut routes: HashMap<u64, (ConnTx, Option<Json>)> = HashMap::new();
    // connections with responses in flight, drained before a capped or
    // shutdown exit (keyed per connection, not per request — a capped
    // run over many short-lived connections must not retain one sender
    // clone, and so one live writer thread, per served request)
    let mut to_drain: HashMap<usize, ConnTx> = HashMap::new();
    let mut served = 0usize;
    // `{"cmd":"shutdown"}` or reaching `--max-requests` flips this: stop
    // admitting, finish what's in flight, exit through the writer drain
    let mut draining = false;
    loop {
        if !sched.has_work() {
            if draining {
                break;
            }
            // idle: block for the next arrival, then optionally hold the
            // batch-formation window so co-arrivals decode together
            match rx.recv_timeout(Duration::from_millis(100)) {
                Ok(Inbound::Req(inb)) => {
                    if opts.gather_ms > 0 {
                        thread::sleep(Duration::from_millis(opts.gather_ms));
                    }
                    admit(&mut sched, &mut routes, inb);
                }
                Ok(Inbound::Stats { tag, conn }) => {
                    handle_stats(&sched, &history, tag, conn, opts, &mut to_drain, &mut served);
                }
                Ok(Inbound::Shutdown { tag, conn }) => {
                    begin_drain(&sched, tag, conn, &mut to_drain, &mut draining);
                }
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => return Ok(finish(sched, history)),
            }
        }
        // token boundary: drain whatever arrived while decoding
        while let Ok(inb) = rx.try_recv() {
            match inb {
                Inbound::Req(inb) if draining => {
                    // admission is closed: answer and drop
                    let err = Json::Obj(
                        [("error".to_string(), Json::Str("server draining".to_string()))]
                            .into(),
                    );
                    inb.conn.send_line(jwrite(&err));
                }
                Inbound::Req(inb) => admit(&mut sched, &mut routes, inb),
                Inbound::Stats { tag, conn } => {
                    handle_stats(&sched, &history, tag, conn, opts, &mut to_drain, &mut served);
                }
                Inbound::Shutdown { tag, conn } => {
                    begin_drain(&sched, tag, conn, &mut to_drain, &mut draining);
                }
            }
        }
        for done in sched.step() {
            history.push(CompletionRecord::of(&done));
            if let Some(conn) = respond(&mut routes, &done) {
                if opts.max_requests > 0 || draining {
                    to_drain.insert(conn.key(), conn);
                }
            }
            served += 1;
        }
        if opts.max_requests > 0 && served >= opts.max_requests {
            draining = true;
        }
        if draining && !sched.has_work() {
            break;
        }
    }
    // let the writer threads flush the final responses before the
    // recording is written and the process exits
    for conn in to_drain.values() {
        conn.drain(Duration::from_secs(2));
    }
    Ok(finish(sched, history))
}

/// Ack a `shutdown` command and close admission; the main loop finishes
/// the in-flight batch before exiting through the writer drain.
fn begin_drain<B: SeqBackend>(
    sched: &Scheduler<RecordingBackend<B>>,
    tag: Option<Json>,
    conn: ConnTx,
    to_drain: &mut HashMap<usize, ConnTx>,
    draining: &mut bool,
) {
    let mut fields = vec![
        ("shutdown".to_string(), Json::Str("draining".to_string())),
        ("active".to_string(), Json::Num(sched.active_len() as f64)),
    ];
    if let Some(tag) = tag {
        fields.push(("tag".to_string(), tag));
    }
    conn.send_line(jwrite(&Json::Obj(fields.into_iter().collect())));
    to_drain.insert(conn.key(), conn);
    *draining = true;
}

/// Tear the scheduler down into the exit outcome.
fn finish<B: SeqBackend>(
    sched: Scheduler<RecordingBackend<B>>,
    completions: Vec<CompletionRecord>,
) -> ServeOutcome<B> {
    let total_us = sched.backend().now_us();
    let max_batch_seen = sched.max_batch_seen() as u64;
    let (backend, entries, trace) = sched.into_backend().finish();
    let event_log = backend.event_log_bytes().to_vec();
    let snapshot = backend.snapshot();
    ServeOutcome {
        backend,
        recording: SessionRecording {
            entries,
            trace,
            completions,
            event_log,
            snapshot,
            total_us,
            max_batch_seen,
        },
    }
}

/// The live inspector report: same per-request fold and store snapshot
/// the recorded artifact captures, through the same `inspect_parts`
/// path, so a `stats` reply and an offline inspection of the artifact
/// agree bit-for-bit on a quiescent server.
fn live_report<B: SeqBackend>(
    sched: &Scheduler<RecordingBackend<B>>,
    history: &[CompletionRecord],
) -> InspectorReport {
    let snap = sched.backend().snapshot();
    let stats = snap.as_ref().map(|s| StatsRecord::of(&s.stats));
    timeline::inspect_parts(
        history,
        stats.as_ref(),
        snap.as_ref().map(|s| s.cache_hit_rate).unwrap_or(0.0),
        sched.backend().now_us(),
        sched.max_batch_seen() as u64,
    )
}

fn handle_stats<B: SeqBackend>(
    sched: &Scheduler<RecordingBackend<B>>,
    history: &[CompletionRecord],
    tag: Option<Json>,
    conn: ConnTx,
    opts: &ServerOpts,
    to_drain: &mut HashMap<usize, ConnTx>,
    served: &mut usize,
) {
    let mut j = live_report(sched, history).to_json();
    if let (Json::Obj(m), Some(tag)) = (&mut j, tag) {
        m.insert("tag".to_string(), tag);
    }
    conn.send_line(jwrite(&j));
    if opts.max_requests > 0 {
        to_drain.insert(conn.key(), conn);
    }
    *served += 1;
}

fn admit<B: SeqBackend>(
    sched: &mut Scheduler<RecordingBackend<B>>,
    routes: &mut HashMap<u64, (ConnTx, Option<Json>)>,
    inb: InboundReq,
) {
    routes.insert(inb.req.id, (inb.conn, inb.tag));
    // arrival in the backend's time base: now minus the wall time the
    // request already spent between the reader thread and this drain
    let dwell_us = inb.arrival.elapsed().as_secs_f64() * 1e6;
    let arrival_us = (sched.backend().now_us() - dwell_us).max(0.0);
    sched.backend_mut().note_arrival(arrival_us, &inb.req);
    sched.enqueue_at(inb.req, arrival_us);
}

/// Queue the response (or per-request error) line on the connection's
/// writer thread; a dead or slow client must not block the coordinator.
/// Returns the connection handle so a capped server can drain it.
fn respond(
    routes: &mut HashMap<u64, (ConnTx, Option<Json>)>,
    c: &ServeCompletion,
) -> Option<ConnTx> {
    let Some((conn, tag)) = routes.remove(&c.id) else {
        return None;
    };
    let resp = match &c.error {
        Some(msg) => {
            eprintln!("request {} failed: {msg}", c.id);
            error_json(c, msg, tag)
        }
        None => response_json(c, tag),
    };
    conn.send_line(jwrite(&resp));
    Some(conn)
}

/// Error reply for a request that retired without finishing: alongside
/// the error it carries whatever output the request produced before the
/// failure, and — when the failure was an injected fault — the
/// structured cause, so a caller can resume from the partial text and
/// tell a node drop from a bad prompt.
fn error_json(c: &ServeCompletion, msg: &str, tag: Option<Json>) -> Json {
    let mut fields = vec![
        ("id".to_string(), Json::Num(c.id as f64)),
        ("error".to_string(), Json::Str(msg.to_string())),
        ("text".to_string(), Json::Str(ByteTokenizer::decode(&c.text))),
        ("tokens".to_string(), Json::Num(c.tokens as f64)),
    ];
    if let Some(cause) = c.fault_cause {
        fields.push(("fault_cause".to_string(), Json::Str(cause.as_str().to_string())));
    }
    if let Some(tag) = tag {
        fields.push(("tag".to_string(), tag));
    }
    Json::Obj(fields.into_iter().collect())
}

fn response_json(c: &ServeCompletion, tag: Option<Json>) -> Json {
    let mut fields = vec![
        ("id".to_string(), Json::Num(c.id as f64)),
        ("text".to_string(), Json::Str(ByteTokenizer::decode(&c.text))),
        ("tokens".to_string(), Json::Num(c.tokens as f64)),
        ("compute_tps".to_string(), Json::Num(c.compute_tps())),
        ("effective_tps".to_string(), Json::Num(c.effective_tps())),
        ("prefill_us".to_string(), Json::Num(c.prefill_us)),
        ("queue_wait_us".to_string(), Json::Num(c.queue_wait_us)),
        ("stall_us".to_string(), Json::Num(c.stall.total_us())),
        ("stall_demand_us".to_string(), Json::Num(c.stall.demand_us)),
        ("stall_prefetch_us".to_string(), Json::Num(c.stall.prefetch_us)),
        ("degraded_boundaries".to_string(), Json::Num(c.degraded.hits as f64)),
        ("degraded_bytes".to_string(), Json::Num(c.degraded.bytes)),
        ("batch_size".to_string(), Json::Num(c.batch_peak as f64)),
    ];
    if let Some(s) = c.slo_us {
        fields.push(("slo_us".to_string(), Json::Num(s)));
    }
    if let Some(tag) = tag {
        fields.push(("tag".to_string(), tag));
    }
    Json::Obj(fields.into_iter().collect())
}

fn accept_loop(listener: TcpListener, tx: mpsc::Sender<Inbound>, read_timeout_ms: u64) {
    let next_id = Arc::new(AtomicU64::new(0));
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        let tx = tx.clone();
        let ids = Arc::clone(&next_id);
        thread::spawn(move || reader_loop(stream, tx, ids, read_timeout_ms));
    }
}

/// Hard cap on one protocol frame (a newline-terminated request line):
/// a client streaming an unterminated line is cut off here instead of
/// growing the reader's buffer without bound. Generous next to
/// `MAX_PROMPT_BYTES` — the cap bounds memory *before* parsing, the
/// prompt limit rejects oversized prompts *after*.
const MAX_FRAME_BYTES: usize = 64 * 1024;

/// Read one newline-terminated frame under the connection's read
/// timeout. `Ok(Some(line))` is a frame (terminator stripped),
/// `Ok(None)` is clean EOF; `InvalidData` means the frame ran past
/// `MAX_FRAME_BYTES`, `WouldBlock`/`TimedOut` means the client went
/// silent for the whole timeout window.
fn read_frame(
    reader: &mut BufReader<TcpStream>,
    buf: &mut Vec<u8>,
) -> std::io::Result<Option<String>> {
    buf.clear();
    loop {
        let chunk = reader.fill_buf()?;
        if chunk.is_empty() {
            // EOF: yield a trailing unterminated frame, then None
            return Ok(if buf.is_empty() {
                None
            } else {
                Some(String::from_utf8_lossy(buf).into_owned())
            });
        }
        let (used, done) = match chunk.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                buf.extend_from_slice(&chunk[..pos]);
                (pos + 1, true)
            }
            None => {
                buf.extend_from_slice(chunk);
                (chunk.len(), false)
            }
        };
        reader.consume(used);
        if buf.len() > MAX_FRAME_BYTES {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("frame exceeds {MAX_FRAME_BYTES} bytes"),
            ));
        }
        if done {
            return Ok(Some(String::from_utf8_lossy(buf).into_owned()));
        }
    }
}

/// Per-connection reader: parse request lines into the admission queue;
/// answer malformed lines inline with an error object (ordered with the
/// coordinator's responses by the connection's writer-thread channel).
/// Frames are read through `read_frame` under `read_timeout_ms`, so a
/// stalled or hostile client costs one bounded buffer and then its
/// connection — dropping the read half leaves the writer thread's clone
/// of the socket open, so responses already queued still flow.
fn reader_loop(
    stream: TcpStream,
    tx: mpsc::Sender<Inbound>,
    ids: Arc<AtomicU64>,
    read_timeout_ms: u64,
) {
    let Ok(write_half) = stream.try_clone() else { return };
    let writer = ConnTx::spawn(write_half);
    if read_timeout_ms > 0 {
        let _ = stream.set_read_timeout(Some(Duration::from_millis(read_timeout_ms)));
    }
    let mut reader = BufReader::new(stream);
    let mut buf = Vec::new();
    loop {
        let line = match read_frame(&mut reader, &mut buf) {
            Ok(Some(line)) => line,
            Ok(None) => break, // clean EOF
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                // oversized frame: one error reply, then the connection
                // is done — the client has already proven it won't frame
                let err = Json::Obj(
                    [("error".to_string(), Json::Str(format!("{e}")))].into(),
                );
                writer.send_line(jwrite(&err));
                break;
            }
            // read timeout (WouldBlock on unix, TimedOut on windows) or
            // any socket error: drop the connection's read half
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        if let Ok(j) = parse(&line) {
            match j.get("cmd").and_then(Json::as_str) {
                Some("stats") => {
                    let inb = Inbound::Stats {
                        tag: j.get("tag").cloned(),
                        conn: writer.clone(),
                    };
                    if tx.send(inb).is_err() {
                        break; // coordinator exited
                    }
                    continue;
                }
                Some("shutdown") => {
                    let inb = Inbound::Shutdown {
                        tag: j.get("tag").cloned(),
                        conn: writer.clone(),
                    };
                    if tx.send(inb).is_err() {
                        break; // coordinator exited
                    }
                    continue;
                }
                _ => {}
            }
        }
        let id = ids.fetch_add(1, Ordering::Relaxed);
        match parse_request(&line, id) {
            Ok((req, tag)) => {
                let inb = Inbound::Req(InboundReq {
                    req,
                    tag,
                    conn: writer.clone(),
                    arrival: Instant::now(),
                });
                if tx.send(inb).is_err() {
                    break; // coordinator exited
                }
            }
            Err(e) => {
                let err = Json::Obj(
                    [("error".to_string(), Json::Str(format!("{e:#}")))].into(),
                );
                writer.send_line(jwrite(&err));
            }
        }
    }
}

const MAX_TOKENS_CAP: usize = 400;
const MAX_PROMPT_BYTES: usize = 4096;

fn parse_request(line: &str, id: u64) -> Result<(Request, Option<Json>)> {
    let j = parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    let prompt = j
        .get("prompt")
        .and_then(Json::as_str)
        .context("missing 'prompt'")?;
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    anyhow::ensure!(
        prompt.len() <= MAX_PROMPT_BYTES,
        "prompt too long ({} bytes, max {MAX_PROMPT_BYTES})",
        prompt.len()
    );
    let req = Request {
        id,
        prompt: prompt.as_bytes().to_vec(),
        max_tokens: j
            .get("max_tokens")
            .and_then(Json::as_usize)
            .unwrap_or(32)
            .min(MAX_TOKENS_CAP),
        temperature: j
            .get("temperature")
            .and_then(Json::as_f64)
            .unwrap_or(0.0) as f32,
        seed: j.get("seed").and_then(Json::as_usize).unwrap_or(0) as u64,
        slo_us: j.get("slo_us").and_then(Json::as_f64).filter(|s| *s > 0.0),
    };
    Ok((req, j.get("tag").cloned()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_request_line() {
        let (r, tag) = parse_request(
            r#"{"prompt":"3+4=","max_tokens":4,"temperature":0.5,"tag":9}"#,
            7,
        )
        .unwrap();
        assert_eq!(r.prompt, b"3+4=");
        assert_eq!(r.max_tokens, 4);
        assert_eq!(r.id, 7);
        assert!((r.temperature - 0.5).abs() < 1e-6);
        assert_eq!(tag, Some(Json::Num(9.0)));
    }

    #[test]
    fn rejects_bad_request() {
        assert!(parse_request("{}", 0).is_err());
        assert!(parse_request("not json", 0).is_err());
        assert!(parse_request(r#"{"prompt":""}"#, 0).is_err());
    }

    #[test]
    fn clamps_max_tokens() {
        let (r, tag) = parse_request(r#"{"prompt":"x","max_tokens":100000}"#, 0).unwrap();
        assert_eq!(r.max_tokens, 400);
        assert_eq!(tag, None);
    }

    #[test]
    fn response_carries_accounting_fields() {
        let c = ServeCompletion {
            id: 3,
            text: b"ok".to_vec(),
            tokens: 2,
            arrival_us: 10.0,
            queue_wait_us: 5.0,
            prefill_us: 100.0,
            decode_us: 200.0,
            stall: crate::store::StallSplit { demand_us: 30.0, prefetch_us: 10.0 },
            degraded: crate::store::DegradeCount { hits: 2, bytes: 64.0 },
            slo_us: Some(5000.0),
            batch_peak: 4,
            finished_us: 400.0,
            error: None,
            fault_cause: None,
        };
        let j = response_json(&c, Some(Json::Str("t".into())));
        assert_eq!(j.get("id").and_then(Json::as_usize), Some(3));
        assert_eq!(j.get("queue_wait_us").and_then(Json::as_f64), Some(5.0));
        assert_eq!(j.get("stall_us").and_then(Json::as_f64), Some(40.0));
        assert_eq!(j.get("stall_demand_us").and_then(Json::as_f64), Some(30.0));
        assert_eq!(j.get("batch_size").and_then(Json::as_usize), Some(4));
        assert_eq!(j.get("tag").and_then(Json::as_str), Some("t"));
        // round-trips through the wire format
        let wire = jwrite(&j);
        assert_eq!(parse(&wire).unwrap(), j);
    }

    #[test]
    fn error_response_carries_partial_output_and_fault_cause() {
        let c = ServeCompletion {
            id: 5,
            text: b"part".to_vec(),
            tokens: 4,
            arrival_us: 10.0,
            queue_wait_us: 5.0,
            prefill_us: 100.0,
            decode_us: 200.0,
            stall: crate::store::StallSplit::default(),
            degraded: crate::store::DegradeCount::default(),
            slo_us: None,
            batch_peak: 1,
            finished_us: 400.0,
            error: Some("node 1 down".to_string()),
            fault_cause: Some(crate::store::FaultCause::NodeDown),
        };
        let j = error_json(&c, c.error.as_deref().unwrap(), Some(Json::Num(7.0)));
        assert_eq!(j.get("error").and_then(Json::as_str), Some("node 1 down"));
        // the partial output produced before the fault rides along
        assert_eq!(j.get("text").and_then(Json::as_str), Some("part"));
        assert_eq!(j.get("tokens").and_then(Json::as_usize), Some(4));
        assert_eq!(j.get("fault_cause").and_then(Json::as_str), Some("node-down"));
        assert_eq!(j.get("tag").and_then(Json::as_usize), Some(7));
        let wire = jwrite(&j);
        assert_eq!(parse(&wire).unwrap(), j);
        // a plain backend failure has no fault_cause field at all
        let plain = ServeCompletion { fault_cause: None, ..c };
        let j = error_json(&plain, "bad prompt", None);
        assert!(j.get("fault_cause").is_none());
        assert!(j.get("tag").is_none());
    }
}

//! Line-JSON TCP serving front-end.
//!
//! Protocol: one JSON object per line on the socket —
//!   request:  {"prompt": "...", "max_tokens": 32, "temperature": 0.0}
//!   response: {"id": n, "text": "...", "compute_tps": x, "effective_tps": y}
//!
//! The PJRT engine is not Send, so the listener and the coordinator run on
//! one thread; concurrent connections are accepted and their requests
//! gathered into a batch, which the coordinator decodes with interleaved
//! continuous batching (the paper's single-batch latency regime).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::policy::SystemConfig;
use crate::coordinator::serve::{Coordinator, Request};
use crate::model::tokenizer::ByteTokenizer;
use crate::util::json::{parse, write as jwrite, Json};

pub struct ServerOpts {
    pub port: u16,
    pub system: SystemConfig,
    pub vram_budget_bytes: usize,
    /// exit after serving this many requests (0 = run forever)
    pub max_requests: usize,
}

pub fn serve(art_dir: &Path, opts: ServerOpts) -> Result<()> {
    let mut coord = Coordinator::new(art_dir, opts.system, opts.vram_budget_bytes)?;
    coord.calibrate_layer_time()?;
    let listener = TcpListener::bind(("127.0.0.1", opts.port))
        .with_context(|| format!("bind 127.0.0.1:{}", opts.port))?;
    println!("floe serving on 127.0.0.1:{}", opts.port);
    let mut served = 0u64;
    for stream in listener.incoming() {
        let stream = stream?;
        match handle_conn(&mut coord, stream, &mut served) {
            Ok(()) => {}
            Err(e) => eprintln!("connection error: {e:#}"),
        }
        if opts.max_requests > 0 && served >= opts.max_requests as u64 {
            break;
        }
    }
    Ok(())
}

fn handle_conn(coord: &mut Coordinator, stream: TcpStream, served: &mut u64) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let req = match parse_request(&line, *served) {
            Ok(r) => r,
            Err(e) => {
                let err = Json::Obj(
                    [("error".to_string(), Json::Str(format!("{e:#}")))].into(),
                );
                writeln!(writer, "{}", jwrite(&err))?;
                continue;
            }
        };
        *served += 1;
        let done = coord.run_batch(std::slice::from_ref(&req))?;
        let c = &done[0];
        let resp = Json::Obj(
            [
                ("id".to_string(), Json::Num(c.id as f64)),
                (
                    "text".to_string(),
                    Json::Str(ByteTokenizer::decode(&c.text)),
                ),
                ("tokens".to_string(), Json::Num(c.tokens as f64)),
                ("compute_tps".to_string(), Json::Num(c.compute_tps())),
                ("effective_tps".to_string(), Json::Num(c.effective_tps())),
                ("prefill_s".to_string(), Json::Num(c.prefill_s)),
            ]
            .into(),
        );
        writeln!(writer, "{}", jwrite(&resp))?;
    }
    let _ = peer;
    Ok(())
}

fn parse_request(line: &str, id: u64) -> Result<Request> {
    let j = parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    let prompt = j
        .get("prompt")
        .and_then(Json::as_str)
        .context("missing 'prompt'")?;
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    Ok(Request {
        id,
        prompt: prompt.as_bytes().to_vec(),
        max_tokens: j
            .get("max_tokens")
            .and_then(Json::as_usize)
            .unwrap_or(32)
            .min(400),
        temperature: j
            .get("temperature")
            .and_then(Json::as_f64)
            .unwrap_or(0.0) as f32,
        seed: j.get("seed").and_then(Json::as_usize).unwrap_or(0) as u64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_request_line() {
        let r = parse_request(
            r#"{"prompt":"3+4=","max_tokens":4,"temperature":0.5}"#,
            7,
        )
        .unwrap();
        assert_eq!(r.prompt, b"3+4=");
        assert_eq!(r.max_tokens, 4);
        assert_eq!(r.id, 7);
        assert!((r.temperature - 0.5).abs() < 1e-6);
    }

    #[test]
    fn rejects_bad_request() {
        assert!(parse_request("{}", 0).is_err());
        assert!(parse_request("not json", 0).is_err());
        assert!(parse_request(r#"{"prompt":""}"#, 0).is_err());
    }

    #[test]
    fn clamps_max_tokens() {
        let r = parse_request(r#"{"prompt":"x","max_tokens":100000}"#, 0).unwrap();
        assert_eq!(r.max_tokens, 400);
    }
}

//! GPU / PCIe / CPU hardware model (substitution substrate — DESIGN.md §2).
//!
//! The paper measures on H100 / A100 / A6000 / RTX 3090 over PCIe 4.0 x16.
//! None of that hardware exists here, so Table 1 and Figures 6/8 are
//! regenerated through this roofline-style analytical model:
//!
//!   GEMV latency  =  bytes_touched / (HBM_bw * efficiency)
//!                    + n_kernels * launch_overhead + dispatch_overhead
//!
//! Decode GEMVs are memory-bound (arithmetic intensity ~1 flop/byte), so
//! latency is dominated by weight-byte movement — which is exactly why the
//! paper's sparsity translates to wall-clock and why high-throughput GPUs
//! saturate on launch overhead (their Table-1 observation for H100/A100).
//! Constants are calibrated to public spec sheets; ratios, not absolutes,
//! are the reproduction target.

#[derive(Clone, Debug)]
pub struct GpuSpec {
    pub name: &'static str,
    /// HBM bandwidth, GB/s
    pub hbm_gbps: f64,
    /// sustained fraction of peak bandwidth for GEMV kernels
    pub efficiency: f64,
    /// per-kernel launch overhead, microseconds
    pub launch_us: f64,
    /// fixed per-expert dispatch overhead (framework + sync), microseconds
    pub dispatch_us: f64,
    /// fp16 compute peak, TFLOPS (used for prefill/attention estimates)
    pub fp16_tflops: f64,
    /// VRAM capacity in GB
    pub vram_gb: f64,
}

pub const H100: GpuSpec = GpuSpec {
    name: "H100",
    hbm_gbps: 3350.0,
    efficiency: 0.62,
    launch_us: 18.0,
    dispatch_us: 28.0,
    fp16_tflops: 989.0,
    vram_gb: 80.0,
};
pub const A100: GpuSpec = GpuSpec {
    name: "A100",
    hbm_gbps: 2039.0,
    efficiency: 0.65,
    launch_us: 14.0,
    dispatch_us: 22.0,
    fp16_tflops: 312.0,
    vram_gb: 80.0,
};
pub const A6000: GpuSpec = GpuSpec {
    name: "A6000",
    hbm_gbps: 768.0,
    efficiency: 0.72,
    launch_us: 9.0,
    dispatch_us: 12.0,
    fp16_tflops: 155.0,
    vram_gb: 48.0,
};
pub const RTX3090: GpuSpec = GpuSpec {
    name: "RTX-3090",
    hbm_gbps: 936.0,
    efficiency: 0.70,
    launch_us: 9.0,
    dispatch_us: 12.0,
    fp16_tflops: 71.0,
    vram_gb: 24.0,
};

pub const ALL_GPUS: [&GpuSpec; 4] = [&H100, &A100, &A6000, &RTX3090];

#[derive(Clone, Debug)]
pub struct PcieSpec {
    /// effective peak bandwidth for pinned, large-chunk copies, GB/s
    pub gbps: f64,
    /// per-copy API + launch overhead, microseconds
    pub api_us: f64,
    /// bandwidth when source is non-pinned pageable memory, GB/s
    pub pageable_gbps: f64,
}

/// PCIe 4.0 x16: 32 GB/s theoretical, ~25.6 achievable (paper Fig 7 plots
/// utilization relative to the *actual* peak).
pub const PCIE4: PcieSpec = PcieSpec {
    gbps: 25.6,
    api_us: 12.0,
    pageable_gbps: 2.6,
};

/// GPU↔GPU peer link (PCIe switch P2P / NVLink-class): roughly twice the
/// host-link bandwidth and lower per-copy overhead, since peer copies skip
/// the host staging + pinning path. Used by the placement-aware
/// `ExpertStore` for cross-device expert movement (spill + remote hits).
pub const P2P_LINK: PcieSpec = PcieSpec {
    gbps: 50.0,
    api_us: 6.0,
    pageable_gbps: 50.0,
};

/// Node ↔ node network link (datacenter Ethernet / commodity RDMA class):
/// 10–100x slower than PCIe and *latency-dominated* — the per-message
/// setup cost (`api_us`, the Fig-7 treatment) is two orders of magnitude
/// above a PCIe copy's, so cross-node expert pulls only pay off when the
/// pulled expert amortizes over many tokens. Used by the cluster tier for
/// cross-node resolution (`Lookup::RemoteNode`) and failure re-homing.
pub const NET_LINK: PcieSpec = PcieSpec {
    gbps: 1.6,
    api_us: 150.0,
    pageable_gbps: 1.6,
};

/// Multi-device transfer topology for the placement-aware `ExpertStore`
/// (DESIGN.md §3, §10): `n_devices` GPUs, each with its own dedicated
/// host→device link (`h2d`, independent busy-until timelines), joined by
/// a shared-spec peer link (`p2p`) for GPU↔GPU copies. The cluster tier
/// adds a node dimension above the device one: a topology either *spans*
/// several nodes (`span_nodes > 1` — one store whose devices partition
/// into node groups joined by `net`) or is a *member* of an N-node
/// cluster (`n_nodes > 1`, `node_id` = which one — one store per node,
/// cross-node traffic charged by the cluster router). Every constructor
/// defaults to the single-node world, so nothing changes until a caller
/// opts in.
#[derive(Clone, Debug)]
pub struct TopologySpec {
    pub n_devices: usize,
    /// host → device link each device owns (dedicated PCIe lanes)
    pub h2d: PcieSpec,
    /// device ↔ device peer link (P2P through the switch / NVLink-class)
    pub p2p: PcieSpec,
    /// node ↔ node network link (latency-dominated; `NET_LINK` default)
    pub net: PcieSpec,
    /// how many cluster nodes exist (1 = the single-node world)
    pub n_nodes: usize,
    /// which node this topology's devices live on (member topologies)
    pub node_id: usize,
    /// how many nodes this topology's own devices span (spanning
    /// topologies partition `n_devices` evenly into `span_nodes` groups)
    pub span_nodes: usize,
    /// per-node host RAM pool for expert residency decoupled from the
    /// serving node, GB (sized so the default holds the full roster)
    pub host_ram_gb: f64,
    /// per-device GEMV throughput relative to the run's `GpuSpec` (1.0 =
    /// that spec; heterogeneous fleets scale each compute stream). Only
    /// consulted when per-device compute streams are on — the legacy
    /// single-timeline path never reads it.
    pub gemv_scale: Vec<f64>,
}

impl TopologySpec {
    /// The pre-placement world: one device behind one host link.
    pub fn single(h2d: PcieSpec) -> Self {
        Self::uniform(1, h2d)
    }

    /// `n` identical devices, each with its own `h2d` link, fully
    /// connected over `P2P_LINK`.
    pub fn uniform(n: usize, h2d: PcieSpec) -> Self {
        let n = n.max(1);
        TopologySpec {
            n_devices: n,
            h2d,
            p2p: P2P_LINK,
            net: NET_LINK,
            n_nodes: 1,
            node_id: 0,
            span_nodes: 1,
            host_ram_gb: 64.0,
            gemv_scale: vec![1.0; n],
        }
    }

    /// A heterogeneous fleet: device 0 runs at the run's `GpuSpec`
    /// throughput and each later device descends linearly to 65% of it
    /// (a flagship + mixed older cards — the common scavenged-fleet
    /// shape). Transfer links stay uniform; only GEMV throughput varies,
    /// so the effect is confined to per-device compute streams.
    pub fn heterogeneous(n: usize, h2d: PcieSpec) -> Self {
        let n = n.max(1);
        let mut t = Self::uniform(n, h2d);
        if n > 1 {
            for (i, s) in t.gemv_scale.iter_mut().enumerate() {
                *s = 1.0 - 0.35 * i as f64 / (n - 1) as f64;
            }
        }
        t
    }

    /// Spanning form: this store's `n_devices` partition evenly into
    /// `span` node groups over the `net` link (`span` is clamped to a
    /// divisor-friendly range; `span = 1` is a no-op). Peer hits inside a
    /// group stay on `p2p`; across groups they resolve as
    /// `Lookup::RemoteNode` and move over `net`.
    pub fn with_cluster_span(mut self, span: usize) -> Self {
        let span = span.clamp(1, self.n_devices.max(1));
        self.span_nodes = span;
        self.n_nodes = self.n_nodes.max(span);
        self
    }

    /// Member form: this store is node `node_id` of an `n_nodes` cluster
    /// with `host_ram_gb` of host RAM for its expert pool. Its own
    /// devices stay single-node (`span_nodes = 1`); cross-node costs are
    /// charged by the cluster router through the `net` spec.
    pub fn as_member(mut self, node_id: usize, n_nodes: usize, host_ram_gb: f64) -> Self {
        let n_nodes = n_nodes.max(1);
        self.n_nodes = n_nodes;
        self.node_id = node_id.min(n_nodes - 1);
        self.host_ram_gb = host_ram_gb;
        self
    }

    /// Which node device `dev` lives on. Spanning topologies partition
    /// devices into contiguous equal groups; member topologies put every
    /// device on `node_id`.
    pub fn node_of(&self, dev: usize) -> usize {
        if self.span_nodes > 1 {
            let per = (self.n_devices / self.span_nodes).max(1);
            self.node_id + (dev / per).min(self.span_nodes - 1)
        } else {
            self.node_id
        }
    }

    /// True once any cluster dimension is active (spanning or member).
    pub fn clustered(&self) -> bool {
        self.span_nodes > 1 || self.n_nodes > 1
    }

    /// Expert GEMV latency on device `dev` given the homogeneous-spec
    /// latency `base_us` (per-device compute streams divide by the
    /// device's relative throughput).
    pub fn gemv_us(&self, dev: usize, base_us: f64) -> f64 {
        base_us / self.gemv_scale[dev]
    }
}

#[derive(Clone, Debug)]
pub struct CpuSpec {
    pub name: &'static str,
    /// sustained GEMV GFLOPs across cores (Fiddler-style expert-on-CPU)
    pub gemv_gflops: f64,
    /// DRAM pack/copy bandwidth per thread, GB/s
    pub pack_gbps_per_thread: f64,
    pub threads: usize,
}

/// Paper testbed: 64-core 2.3 GHz + 256 GB DRAM.
pub const EPYC64: CpuSpec = CpuSpec {
    name: "epyc-64c",
    gemv_gflops: 95.0,
    pack_gbps_per_thread: 7.5,
    threads: 16,
};

/// Transformer dimensions at an arbitrary scale (the simulator runs both
/// the in-repo tiny model and Mixtral-8x7B dims through the same code).
#[derive(Clone, Debug)]
pub struct ModelDims {
    pub name: &'static str,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub n_experts: usize,
    pub top_k: usize,
}

pub const MIXTRAL_8X7B: ModelDims = ModelDims {
    name: "mixtral-8x7b",
    d_model: 4096,
    d_ff: 14336,
    n_layers: 32,
    n_experts: 8,
    top_k: 2,
};

impl ModelDims {
    /// fp16 bytes of one expert's three projection matrices.
    pub fn expert_bytes_fp16(&self) -> f64 {
        3.0 * self.d_model as f64 * self.d_ff as f64 * 2.0
    }
    /// INT2-packed up projection + fp16 group scales/zeros (group 64).
    pub fn up_int2_bytes(&self) -> f64 {
        let n = self.d_model as f64 * self.d_ff as f64;
        n / 4.0 + 2.0 * 2.0 * (n / 64.0)
    }
    /// FloE compressed transfer bytes at `level` sparsity: surviving gate
    /// columns + down rows in fp16 (up is resident INT2, never moved).
    pub fn floe_transfer_bytes(&self, level: f64) -> f64 {
        2.0 * (1.0 - level) * self.d_model as f64 * self.d_ff as f64 * 2.0
    }
    /// Uniform `bits` quantized expert bytes (all three matrices).
    pub fn expert_bytes_quant(&self, bits: f64) -> f64 {
        3.0 * self.d_model as f64 * self.d_ff as f64 * bits / 8.0
            + 3.0 * 2.0 * 2.0 * (self.d_model as f64 * self.d_ff as f64 / 64.0)
    }
    /// fp16 bytes of the per-layer attention weights (q,k,v,o).
    /// Mixtral uses GQA with 8 KV heads vs 32 query heads, so k/v
    /// projections are d x d/4: total 2.5 d^2 weights.
    pub fn attn_bytes_fp16(&self) -> f64 {
        2.5 * self.d_model as f64 * self.d_model as f64 * 2.0
    }
    /// decode-step GEMV flops for one expert.
    pub fn expert_flops(&self) -> f64 {
        2.0 * 3.0 * self.d_model as f64 * self.d_ff as f64
    }
}

impl GpuSpec {
    fn bw_bytes_per_us(&self) -> f64 {
        self.hbm_gbps * self.efficiency * 1e3 // bytes per microsecond
    }

    /// Dense expert GEMV latency, microseconds (paper Table 1 "0%" column):
    /// 3 GEMVs + separate SiLU/Hadamard elementwise kernel = 4 launches.
    pub fn expert_dense_us(&self, m: &ModelDims) -> f64 {
        m.expert_bytes_fp16() / self.bw_bytes_per_us()
            + 4.0 * self.launch_us
            + self.dispatch_us
    }

    /// Algorithm-1 sparse kernel latency at `sparsity`, microseconds:
    /// dense up GEMV + fused SiLU⊙ sparse gate GEMV + sparse down GEMV
    /// (3 launches; only surviving channel bytes touched).
    pub fn expert_sparse_us(&self, m: &ModelDims, sparsity: f64) -> f64 {
        let up = m.d_model as f64 * m.d_ff as f64 * 2.0;
        let gd = 2.0 * (1.0 - sparsity) * m.d_model as f64 * m.d_ff as f64 * 2.0;
        (up + gd) / self.bw_bytes_per_us() + 3.0 * self.launch_us + self.dispatch_us
    }

    /// FloE expert: INT2 up bytes + sparse fp16 gate/down.
    pub fn expert_floe_us(&self, m: &ModelDims, sparsity: f64) -> f64 {
        let up = m.up_int2_bytes();
        let gd = 2.0 * (1.0 - sparsity) * m.d_model as f64 * m.d_ff as f64 * 2.0;
        (up + gd) / self.bw_bytes_per_us() + 3.0 * self.launch_us + self.dispatch_us
    }

    /// Uniform-quantized dense expert (dequant fused into GEMV).
    pub fn expert_quant_us(&self, m: &ModelDims, bits: f64) -> f64 {
        m.expert_bytes_quant(bits) / self.bw_bytes_per_us()
            + 4.0 * self.launch_us
            + self.dispatch_us
    }

    /// Per-layer attention + norms + router for one decode token.
    pub fn attn_layer_us(&self, m: &ModelDims, kv_len: usize) -> f64 {
        let kv_bytes = 2.0 * kv_len as f64 * m.d_model as f64 * 2.0;
        (m.attn_bytes_fp16() + kv_bytes) / self.bw_bytes_per_us()
            + 6.0 * self.launch_us
    }
}

impl PcieSpec {
    /// Time to move `bytes` in one pinned chunked copy, microseconds.
    pub fn copy_us(&self, bytes: f64) -> f64 {
        bytes / (self.gbps * 1e3) + self.api_us
    }
    /// Pageable (non-pinned) copy — the PyTorch-naive baseline.
    pub fn copy_pageable_us(&self, bytes: f64) -> f64 {
        bytes / (self.pageable_gbps * 1e3) + 2.0 * self.api_us
    }
}

impl CpuSpec {
    /// Fiddler-style on-CPU expert GEMV, microseconds.
    pub fn expert_us(&self, m: &ModelDims) -> f64 {
        m.expert_flops() / (self.gemv_gflops * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixtral_expert_size_matches_paper() {
        // paper §3.1: "over 300MB of FP16 parameters" per expert
        let mb = MIXTRAL_8X7B.expert_bytes_fp16() / 1e6;
        assert!(mb > 300.0 && mb < 400.0, "{mb}");
        // ~15ms over PCIe 4.0 (paper §3.1)
        let ms = PCIE4.copy_us(MIXTRAL_8X7B.expert_bytes_fp16()) / 1e3;
        assert!(ms > 10.0 && ms < 18.0, "{ms}");
    }

    #[test]
    fn sparse_kernel_speedup_shape() {
        // speedup grows with sparsity everywhere; consumer GPUs gain more
        // at 90% than datacenter GPUs (paper Table 1 observation)
        for gpu in ALL_GPUS {
            let dense = gpu.expert_dense_us(&MIXTRAL_8X7B);
            let mut last = dense;
            for s in [0.5, 0.7, 0.9] {
                let t = gpu.expert_sparse_us(&MIXTRAL_8X7B, s);
                assert!(t < last, "{} s={}", gpu.name, s);
                last = t;
            }
        }
        let s90_3090 = RTX3090.expert_dense_us(&MIXTRAL_8X7B)
            / RTX3090.expert_sparse_us(&MIXTRAL_8X7B, 0.9);
        let s90_h100 =
            H100.expert_dense_us(&MIXTRAL_8X7B) / H100.expert_sparse_us(&MIXTRAL_8X7B, 0.9);
        assert!(s90_3090 > s90_h100, "3090 {s90_3090} vs H100 {s90_h100}");
        assert!(s90_3090 > 1.7 && s90_3090 < 2.6, "{s90_3090}");
    }

    #[test]
    fn floe_compression_ratio() {
        // paper §1: 9.3x per-expert compression at 90% sparsity
        let m = &MIXTRAL_8X7B;
        let full = m.expert_bytes_fp16();
        let floe = m.up_int2_bytes() + m.floe_transfer_bytes(0.9);
        let ratio = full / floe;
        assert!(ratio > 7.0 && ratio < 11.0, "{ratio}");
    }

    #[test]
    fn pageable_slower_than_pinned() {
        let b = 1e8;
        assert!(PCIE4.copy_pageable_us(b) > 3.0 * PCIE4.copy_us(b));
    }

    #[test]
    fn topology_peer_link_beats_host_link() {
        let t = TopologySpec::uniform(4, PCIE4);
        assert_eq!(t.n_devices, 4);
        let b = 2e7;
        assert!(t.p2p.copy_us(b) < t.h2d.copy_us(b));
        // degenerate spec is clamped to one device
        assert_eq!(TopologySpec::uniform(0, PCIE4).n_devices, 1);
        assert_eq!(TopologySpec::single(PCIE4).n_devices, 1);
        // uniform fleets run every compute stream at spec throughput; a
        // downscaled device slows its own stream only
        assert_eq!(t.gemv_scale, vec![1.0; 4]);
        assert_eq!(t.gemv_us(2, 120.0), 120.0);
        let mut het = TopologySpec::uniform(2, PCIE4);
        het.gemv_scale[1] = 0.5;
        assert_eq!(het.gemv_us(0, 120.0), 120.0);
        assert_eq!(het.gemv_us(1, 120.0), 240.0);
    }

    #[test]
    fn heterogeneous_fleet_descends_from_spec_throughput() {
        let het = TopologySpec::heterogeneous(4, PCIE4);
        assert_eq!(het.gemv_scale[0], 1.0, "device 0 runs at spec");
        for w in het.gemv_scale.windows(2) {
            assert!(w[1] < w[0], "scales must strictly descend: {:?}", het.gemv_scale);
        }
        assert!(
            (het.gemv_scale[3] - 0.65).abs() < 1e-12,
            "slowest device bottoms at 65%: {}",
            het.gemv_scale[3]
        );
        // every device is no faster than the uniform fleet
        for (dev, _) in het.gemv_scale.iter().enumerate() {
            assert!(het.gemv_us(dev, 100.0) >= 100.0);
        }
        // degenerate fleets collapse to uniform
        assert_eq!(TopologySpec::heterogeneous(1, PCIE4).gemv_scale, vec![1.0]);
    }

    #[test]
    fn net_link_is_latency_dominated_and_much_slower_than_pcie() {
        // 10-100x slower than PCIe on bandwidth, with a per-message setup
        // cost an order of magnitude above the PCIe api overhead — the
        // Fig-7 treatment applied to the node link
        assert!(PCIE4.gbps / NET_LINK.gbps >= 10.0 && PCIE4.gbps / NET_LINK.gbps <= 100.0);
        assert!(NET_LINK.api_us >= 10.0 * PCIE4.api_us);
        // at one-expert granularity (~27 MB) the pull is ~17 ms — far
        // beyond a PCIe fetch, so host adoption matters
        let b = 27e6;
        assert!(NET_LINK.copy_us(b) > 10.0 * PCIE4.copy_us(b));
        // latency-dominated: a tiny message is almost pure setup cost
        let tiny = NET_LINK.copy_us(64.0);
        assert!((tiny - NET_LINK.api_us) / tiny < 0.01, "{tiny}");
    }

    #[test]
    fn topology_node_dimension_defaults_to_single_node() {
        let t = TopologySpec::uniform(4, PCIE4);
        assert!(!t.clustered());
        assert_eq!(t.n_nodes, 1);
        assert_eq!(t.span_nodes, 1);
        for d in 0..4 {
            assert_eq!(t.node_of(d), 0);
        }
        // spanning: 4 devices over 2 nodes -> contiguous halves
        let s = TopologySpec::uniform(4, PCIE4).with_cluster_span(2);
        assert!(s.clustered());
        assert_eq!(s.span_nodes, 2);
        assert_eq!([s.node_of(0), s.node_of(1), s.node_of(2), s.node_of(3)], [0, 0, 1, 1]);
        // span is clamped to the device count; span 1 is a no-op
        assert_eq!(TopologySpec::uniform(2, PCIE4).with_cluster_span(8).span_nodes, 2);
        assert!(!TopologySpec::uniform(2, PCIE4).with_cluster_span(1).clustered());
        // member: every device on node_id, n_nodes recorded
        let m = TopologySpec::uniform(2, PCIE4).as_member(1, 3, 8.0);
        assert!(m.clustered());
        assert_eq!((m.n_nodes, m.node_id, m.span_nodes), (3, 1, 1));
        assert_eq!(m.node_of(0), 1);
        assert_eq!(m.node_of(1), 1);
        assert_eq!(m.host_ram_gb, 8.0);
    }

    #[test]
    fn fiddler_cpu_beats_fp16_transfer() {
        // the Fiddler premise: computing on CPU beats moving fp16 weights
        let cpu = EPYC64.expert_us(&MIXTRAL_8X7B);
        let transfer = PCIE4.copy_us(MIXTRAL_8X7B.expert_bytes_fp16());
        assert!(cpu < transfer, "cpu {cpu} vs transfer {transfer}");
    }
}

//! Deterministic PRNG (SplitMix64 seeding a xoshiro256**) — the vendored
//! crate set has `rand_core` but no generator implementations, so this is
//! an in-repo substrate. Used by tests, workload generators and the
//! property-testing harness; determinism across runs is load-bearing for
//! experiment reproducibility.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        let s = [
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
        ];
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    pub fn fill_normal_f32(&mut self, out: &mut [f32], std: f32) {
        for x in out.iter_mut() {
            *x = self.normal_f32() * std;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let m: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.02, "{m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "{mean}");
        assert!((var - 1.0).abs() < 0.1, "{var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}

//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, f)` runs `f` against `cases` seeded RNGs; on the
//! first failure it retries with the same seed to confirm determinism and
//! panics with the reproducing seed. Coordinator invariants (cache budget,
//! routing, batching, transfer conservation) are tested through this.

use super::rng::Rng;

pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x5EED_0000u64 ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property '{}' failed on case {} (seed {:#x}): {}",
                name, case, seed, msg
            );
        }
    }
}

/// Assert helper returning Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_good_property() {
        check("add-commutes", 50, |r| {
            let (a, b) = (r.f64(), r.f64());
            if (a + b - (b + a)).abs() < 1e-12 {
                Ok(())
            } else {
                Err("not commutative".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn reports_failures() {
        check("always-fails", 3, |_| Err("boom".into()));
    }
}

//! ASCII / markdown table rendering for experiment harnesses — every
//! paper table/figure regeneration prints through this so EXPERIMENTS.md
//! rows can be pasted directly.

pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn to_markdown(&self) -> String {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("\n### {}\n\n", self.title));
        }
        let fmt_row = |cells: &[String], w: &[usize]| {
            let mut s = String::from("|");
            for (c, width) in cells.iter().zip(w) {
                s.push_str(&format!(" {:<width$} |", c, width = width));
            }
            s.push('\n');
            s
        };
        out.push_str(&fmt_row(&self.header, &w));
        let mut sep = String::from("|");
        for width in &w {
            sep.push_str(&format!("{}|", "-".repeat(width + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&fmt_row(r, &w));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_markdown());
    }
}

/// Format helpers used across experiments.
pub fn f2(x: f64) -> String {
    format!("{:.2}", x)
}
pub fn f3(x: f64) -> String {
    format!("{:.3}", x)
}
pub fn f4(x: f64) -> String {
    format!("{:.4}", x)
}
pub fn speedup(base: f64, x: f64) -> String {
    format!("{:.2}x", base / x)
}
pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_markdown() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### T"));
        assert!(md.contains("| a | bb |"));
        assert!(md.contains("| 1 | 2  |"));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}

//! Minimal JSON parser + writer (serde is unavailable in the offline
//! vendor set, so this is an in-repo substrate).
//!
//! Supports the full JSON grammar minus exotic escapes; numbers parse to
//! f64. Accessors return `Option` so manifest lookups compose with `?`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Flatten a (possibly nested) array of numbers.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        match self {
            Json::Arr(v) => v.iter().map(|x| x.as_f64()).collect(),
            _ => None,
        }
    }
}

pub fn parse(s: &str) -> Result<Json, String> {
    let b = s.as_bytes();
    let mut p = Parser { b, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }
    fn value(&mut self) -> Result<Json, String> {
        match self.peek().ok_or("eof")? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }
    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {}", start))
    }
    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or("eof in string")? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let c = self.peek().ok_or("eof in escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u")?;
                            let n = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            self.i += 4;
                            out.push(char::from_u32(n).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                _ => {
                    // copy a run of plain bytes at once
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "bad utf8")?,
                    );
                }
            }
        }
    }
    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }
    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

/// Serialize (used by experiment harnesses to dump machine-readable results).
pub fn write(j: &Json) -> String {
    let mut s = String::new();
    write_into(j, &mut s);
    s
}

fn write_into(j: &Json, s: &mut String) {
    match j {
        Json::Null => s.push_str("null"),
        Json::Bool(b) => s.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(s, "{}", *n as i64);
            } else {
                let _ = write!(s, "{}", n);
            }
        }
        Json::Str(t) => {
            s.push('"');
            for c in t.chars() {
                match c {
                    '"' => s.push_str("\\\""),
                    '\\' => s.push_str("\\\\"),
                    '\n' => s.push_str("\\n"),
                    '\t' => s.push_str("\\t"),
                    '\r' => s.push_str("\\r"),
                    c if (c as u32) < 0x20 => {
                        let _ = write!(s, "\\u{:04x}", c as u32);
                    }
                    c => s.push(c),
                }
            }
            s.push('"');
        }
        Json::Arr(v) => {
            s.push('[');
            for (i, x) in v.iter().enumerate() {
                if i > 0 {
                    s.push(',');
                }
                write_into(x, s);
            }
            s.push(']');
        }
        Json::Obj(m) => {
            s.push('{');
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    s.push(',');
                }
                write_into(&Json::Str(k.clone()), s);
                s.push(':');
                write_into(v, s);
            }
            s.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
        assert_eq!(parse("null").unwrap(), Json::Null);
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a":[1,2,{"b":"c"}],"d":{}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x":[1,2.5,"s",true,null],"y":{"z":[[0]]}}"#;
        let j = parse(src).unwrap();
        let out = write(&j);
        assert_eq!(parse(&out).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn f64_vec() {
        let j = parse("[0.5,1,2]").unwrap();
        assert_eq!(j.as_f64_vec().unwrap(), vec![0.5, 1.0, 2.0]);
    }
}

//! In-repo substrates: JSON, PRNG, timing/bench harness, tables, property
//! testing. (The offline vendor set has no serde/criterion/proptest/rand.)

pub mod json;
pub mod prop;
pub mod rng;
pub mod table;
pub mod timing;

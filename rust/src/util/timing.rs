//! Micro-benchmark harness (criterion is unavailable offline): warmup +
//! timed iterations with robust statistics. `cargo bench` targets use this.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub min_ns: f64,
    pub p95_ns: f64,
}

impl BenchStats {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
    pub fn p50_us(&self) -> f64 {
        self.p50_ns / 1e3
    }
}

/// Run `f` for `warmup` untimed + `iters` timed iterations.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    stats_from(samples)
}

/// Time-budgeted variant: run until `budget_ms` elapsed (at least 3 iters).
pub fn bench_budget<F: FnMut()>(warmup: usize, budget_ms: u64, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let start = Instant::now();
    let mut samples = Vec::new();
    while samples.len() < 3 || start.elapsed().as_millis() < budget_ms as u128 {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
        if samples.len() > 100_000 {
            break;
        }
    }
    stats_from(samples)
}

fn stats_from(mut samples: Vec<f64>) -> BenchStats {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    BenchStats {
        iters: n,
        mean_ns: mean,
        p50_ns: samples[n / 2],
        min_ns: samples[0],
        p95_ns: samples[(n as f64 * 0.95) as usize % n],
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0u64;
        let st = bench(2, 10, || n += 1);
        assert_eq!(st.iters, 10);
        assert_eq!(n, 12);
        assert!(st.min_ns <= st.p50_ns && st.p50_ns <= st.p95_ns);
    }

    #[test]
    fn budget_runs_at_least_three() {
        let st = bench_budget(0, 0, || std::thread::sleep(std::time::Duration::from_micros(10)));
        assert!(st.iters >= 3);
    }
}

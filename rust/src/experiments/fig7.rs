//! Fig 7: DRAM→VRAM transfer latency + bandwidth utilization vs chunk
//! size, compact-vs-naive-vs-PyTorch.
//!
//! Two tables: (a) the *real* transfer engine on the in-repo model's
//! weights (real packing threads, simulated PCIe timeline); (b) the pure
//! simulation at Mixtral-8x7B scale (20% of an expert's gate/down channels
//! — the paper's setup).

use anyhow::Result;

use crate::hwsim::{EPYC64, MIXTRAL_8X7B, PCIE4};
use crate::model::Weights;
use crate::transfer::{CompactExpert, ScatteredExpert, TransferEngine};
use crate::util::table::{f2, pct, Table};

use super::{jarr, jnum, jobj, save_json};

pub const CHUNKS: [usize; 7] = [1, 5, 10, 25, 50, 100, 200];

pub fn run(art_dir: &std::path::Path) -> Result<()> {
    // ---- (a) real weights, real packing ----
    let w = Weights::load(art_dir)?;
    let ew = w.expert_native(0, 0)?;
    let (d, f) = (w.cfg.d_model, w.cfg.d_ff);
    let ce = CompactExpert::build(&ew.wg_t.data, &ew.wd.data, f, d);
    let wg_rowmajor = w.f32(&Weights::expert_name(0, 0, "wg"))?;
    let se = ScatteredExpert::build(wg_rowmajor, &ew.wd.data, d, f);
    let eng = TransferEngine::new(PCIE4, 4, 2);
    // paper setup: 20% of channels selected
    let selected: Vec<usize> = (0..f).step_by(5).collect();

    let mut t = Table::new(
        "Fig 7a — measured transfer (tiny model expert, 20% channels)",
        &["chunk (channels)", "compact us", "bus util", "naive us", "naive util"],
    );
    let naive = eng.transfer_naive(&se, &selected);
    let mut js = Vec::new();
    for chunk in CHUNKS {
        let rep = eng.transfer_compact(&ce, &selected, chunk);
        t.row(vec![
            chunk.to_string(),
            f2(rep.total_us),
            pct(rep.bus_utilization),
            f2(naive.total_us),
            pct(naive.bus_utilization),
        ]);
        js.push(jobj(vec![
            ("chunk", jnum(chunk as f64)),
            ("compact_us", jnum(rep.total_us)),
            ("util", jnum(rep.bus_utilization)),
        ]));
    }
    t.print();

    // ---- (b) Mixtral-scale simulation ----
    let m = &MIXTRAL_8X7B;
    let bytes = 0.2 * 2.0 * m.d_model as f64 * m.d_ff as f64 * 2.0; // 20% gate+down fp16
    let rec_bytes = 2.0 * m.d_model as f64 * 2.0;
    let eng_big = TransferEngine::new(PCIE4, EPYC64.threads, 4);
    let pytorch_us = eng_big.transfer_pytorch_naive_us(bytes);
    let mut t2 = Table::new(
        "Fig 7b — simulated transfer at Mixtral-8x7B scale (20% of one expert)",
        &["chunk (channels)", "compact ms", "bus util", "vs PyTorch-naive"],
    );
    let mut best: Option<(usize, f64)> = None;
    for chunk in CHUNKS {
        let us = eng_big.simulate_compact_us(
            bytes,
            chunk as f64 * rec_bytes,
            EPYC64.pack_gbps_per_thread,
        );
        let ideal = bytes / (PCIE4.gbps * 1e3);
        t2.row(vec![
            chunk.to_string(),
            f2(us / 1e3),
            pct(ideal / us),
            format!("{:.1}x", pytorch_us / us),
        ]);
        if best.map_or(true, |(_, b)| us < b) {
            best = Some((chunk, us));
        }
        js.push(jobj(vec![
            ("chunk_mixtral", jnum(chunk as f64)),
            ("compact_us", jnum(us)),
            ("util", jnum(ideal / us)),
            ("speedup_vs_pytorch", jnum(pytorch_us / us)),
        ]));
    }
    t2.print();
    let (bc, bu) = best.unwrap();
    println!(
        "\noptimal chunk = {bc} channels; best compact = {:.2} ms vs \
         PyTorch-naive {:.2} ms ({:.1}x). paper: optimum ~50, up to 88% peak \
         bandwidth, 12.6x over PyTorch.",
        bu / 1e3,
        pytorch_us / 1e3,
        pytorch_us / bu
    );
    save_json("fig7", &jarr(js))
}

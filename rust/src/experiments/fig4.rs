//! Fig 4: next-layer hidden-state cosine similarity + dual-predictor
//! quality. Two sources: (a) build-time calibration (manifest analysis),
//! (b) *live* measurement — run the FloE pipeline on real prompts and
//! report the coordinator's own precision/recall accounting.

use anyhow::{Context, Result};

use crate::coordinator::policy::{SystemConfig, SystemKind};
use crate::coordinator::serve::{Coordinator, Request};
use crate::util::json::Json;
use crate::util::table::{f3, Table};

use super::{jarr, jnum, jobj, save_json};

pub fn run(art_dir: &std::path::Path) -> Result<()> {
    // ---- (a) calibration-time measurements ----
    let w = crate::model::Weights::load(art_dir)?;
    let a = w.manifest.get("analysis").context("analysis")?;
    let cos = a
        .get("fig4_cosine_similarity")
        .and_then(Json::as_f64_vec)
        .context("cosine")?;
    let inter = a
        .get("fig4_inter_predictor_precision")
        .and_then(Json::as_f64_vec)
        .context("inter")?;
    let intra = a
        .get("fig4_intra_predictor_recall")
        .and_then(Json::as_f64_vec)
        .context("intra")?;

    let mut t = Table::new(
        "Fig 4 — next-layer similarity & predictor quality (calibration)",
        &["layer boundary", "cosine sim", "inter precision", "intra recall"],
    );
    for i in 0..cos.len() {
        t.row(vec![
            format!("{} -> {}", i, i + 1),
            f3(cos[i]),
            f3(*inter.get(i).unwrap_or(&f64::NAN)),
            f3(*intra.get(i).unwrap_or(&f64::NAN)),
        ]);
    }
    t.print();

    // ---- (b) live pipeline measurement ----
    let system = SystemConfig::new(SystemKind::Floe);
    // expert cache budget: half the compressed working set
    let budget = 512 * 1024;
    let mut coord = Coordinator::new(art_dir, system, budget)?;
    coord.calibrate_layer_time()?;
    let reqs: Vec<Request> = [
        "the miller carried a copper kettle ",
        "the capital of brint is ",
        "say fern: ",
        "3+5=",
    ]
    .iter()
    .enumerate()
    .map(|(i, p)| Request {
        id: i as u64,
        prompt: p.as_bytes().to_vec(),
        max_tokens: 24,
        temperature: 0.0,
        seed: i as u64,
        slo_us: None,
    })
    .collect();
    let _ = coord.run_batch(&reqs)?;
    let st = coord.pipeline.stats();

    let mut t2 = Table::new(
        "Fig 4 — live pipeline measurement (FloE serving 4 prompts)",
        &["metric", "value"],
    );
    t2.row(vec!["inter-predictor hit rate".into(), f3(st.inter_hit_rate())]);
    t2.row(vec!["intra-predictor recall".into(), f3(st.intra_recall())]);
    t2.row(vec!["expert cache hit rate".into(), f3(st.cache_hit_rate())]);
    t2.row(vec!["prefetches issued".into(), st.prefetches.to_string()]);
    t2.row(vec!["demand fetches (stalls)".into(), st.demand_fetches.to_string()]);
    t2.print();
    println!(
        "\npaper Fig 4: cosine sim > 0.95 (32 layers), inter precision ~0.88, \
         intra recall ~0.95. Our 4-layer model has shallower residual \
         accumulation, hence lower similarity at early boundaries — the \
         predictor quality trend (rising with depth) reproduces."
    );

    save_json(
        "fig4",
        &jobj(vec![
            ("cosine", jarr(cos.into_iter().map(jnum).collect())),
            ("inter_precision", jarr(inter.into_iter().map(jnum).collect())),
            ("intra_recall", jarr(intra.into_iter().map(jnum).collect())),
            ("live_inter_hit", jnum(st.inter_hit_rate())),
            ("live_intra_recall", jnum(st.intra_recall())),
            ("live_cache_hit", jnum(st.cache_hit_rate())),
        ]),
    )
}

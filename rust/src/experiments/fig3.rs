//! Fig 3 (+ Tables 5/6/7 appendix analogs): compression sensitivity of
//! expert parameters, measured as held-out perplexity (nats/byte) through
//! the Rust engine.
//!
//!   fig3a — sparsification sensitivity: threshold each projection's
//!           activations (gate / up / down) at 50..90% sparsity.
//!           Expected ordering (paper Thm 3.1): down ≤ up < gate.
//!   fig3b — quantization sensitivity: HQQ INT8/4/3/2/1 per projection.
//!           Expected: up least sensitive (Observation 2).

use anyhow::Result;

use crate::config::{ExpertMode, Proj};
use crate::engine::Engine;
use crate::evalsuite::{perplexity, EvalData};
use crate::util::table::{f4, Table};

use super::{jarr, jnum, jobj, jstr, save_json};

const LEVELS: [f64; 5] = [0.5, 0.6, 0.7, 0.8, 0.9];
const BITS: [u8; 5] = [8, 4, 3, 2, 1];

pub struct EvalBudget {
    pub n_bytes: usize,
    pub window: usize,
    pub burn_in: usize,
}

impl Default for EvalBudget {
    fn default() -> Self {
        // window matches the training context length (96); longer windows
        // leak out-of-distribution RoPE positions into the metric
        EvalBudget { n_bytes: 768, window: 96, burn_in: 16 }
    }
}

pub fn run_fig3a(art_dir: &std::path::Path, budget: &EvalBudget) -> Result<()> {
    let mut eng = Engine::load(art_dir)?;
    let data = EvalData::load(art_dir)?;
    let base = perplexity(&mut eng, &data, ExpertMode::Dense,
                          budget.n_bytes, budget.window, budget.burn_in)?;
    let mut t = Table::new(
        "Fig 3a / Table 5 — sparsification sensitivity (held-out nats/byte)",
        &["projection", "0%", "50%", "60%", "70%", "80%", "90%"],
    );
    let mut js = Vec::new();
    for proj in [Proj::Gate, Proj::Up, Proj::Down] {
        let mut cells = vec![proj.key().to_string(), f4(base)];
        let mut vals = vec![base];
        for level in LEVELS {
            let p = perplexity(
                &mut eng,
                &data,
                ExpertMode::SparseProj { proj, level },
                budget.n_bytes,
                budget.window,
                budget.burn_in,
            )?;
            cells.push(f4(p));
            vals.push(p);
        }
        t.row(cells);
        js.push(jobj(vec![
            ("proj", jstr(proj.key())),
            ("nll", jarr(vals.into_iter().map(jnum).collect())),
        ]));
    }
    t.print();
    println!(
        "\npaper Thm 3.1 / Fig 3a: expect nll(down) <= nll(up) < nll(gate) \
         at matched sparsity."
    );
    save_json("fig3a", &jarr(js))
}

pub fn run_fig3b(art_dir: &std::path::Path, budget: &EvalBudget) -> Result<()> {
    let mut eng = Engine::load(art_dir)?;
    let data = EvalData::load(art_dir)?;
    let base = perplexity(&mut eng, &data, ExpertMode::Dense,
                          budget.n_bytes, budget.window, budget.burn_in)?;
    let mut t = Table::new(
        "Fig 3b / Table 7 — quantization sensitivity (held-out nats/byte)",
        &["projection", "fp32", "INT8", "INT4", "INT3", "INT2", "INT1"],
    );
    let mut js = Vec::new();
    for proj in [Proj::Gate, Proj::Up, Proj::Down] {
        let mut cells = vec![proj.key().to_string(), f4(base)];
        let mut vals = vec![base];
        for bits in BITS {
            let p = perplexity(
                &mut eng,
                &data,
                ExpertMode::QuantProj { proj, bits },
                budget.n_bytes,
                budget.window,
                budget.burn_in,
            )?;
            cells.push(f4(p));
            vals.push(p);
        }
        t.row(cells);
        js.push(jobj(vec![
            ("proj", jstr(proj.key())),
            ("nll", jarr(vals.into_iter().map(jnum).collect())),
        ]));
    }
    t.print();
    println!(
        "\npaper Fig 3b / Table 7: up projection should be least sensitive at \
         ultra-low bits (INT2/INT1); down most sensitive."
    );
    save_json("fig3b", &jarr(js))
}

//! Table 3 / Figs 9-10: downstream-task performance under compression
//! methods — FloE vs CATS, CHESS, uniform HQQ — plus the FloE-Wup ablation
//! (sparsity only, fp up projection).
//!
//! Metrics: exact-match accuracy on the four seeded probe tasks (the
//! paper's seven-task analog) and held-out nats/byte.

use anyhow::Result;

use crate::config::ExpertMode;
use crate::engine::Engine;
use crate::evalsuite::{mean_accuracy, perplexity, probe_accuracy, EvalData};
use crate::util::table::{f3, f4, Table};

use super::{jarr, jnum, jobj, jstr, save_json};
use super::fig3::EvalBudget;

pub fn methods() -> Vec<(&'static str, ExpertMode)> {
    vec![
        ("base (fp32)", ExpertMode::Dense),
        ("HQQ INT3", ExpertMode::Uniform { bits: 3 }),
        ("HQQ INT2", ExpertMode::Uniform { bits: 2 }),
        ("CATS-80%", ExpertMode::CatsGate { level: 0.8 }),
        ("CHESS-80%", ExpertMode::ChessGate { level: 0.8 }),
        ("FloE-Wup-80%", ExpertMode::Sparse { level: 0.8 }),
        ("FloE-80%", ExpertMode::Floe { level: 0.8 }),
        ("CATS-90%", ExpertMode::CatsGate { level: 0.9 }),
        ("CHESS-90%", ExpertMode::ChessGate { level: 0.9 }),
        ("FloE-Wup-90%", ExpertMode::Sparse { level: 0.9 }),
        ("FloE-90%", ExpertMode::Floe { level: 0.9 }),
    ]
}

pub fn run(art_dir: &std::path::Path, budget: &EvalBudget, max_probes: usize) -> Result<()> {
    let mut eng = Engine::load(art_dir)?;
    let data = EvalData::load(art_dir)?;
    let task_names: Vec<String> = data.probes.iter().map(|(t, _)| t.clone()).collect();
    let mut header: Vec<&str> = vec!["method", "nats/byte"];
    for t in &task_names {
        header.push(t.as_str());
    }
    header.push("avg acc");
    let mut t = Table::new(
        "Table 3 / Fig 10 — downstream probes under compression methods",
        &header,
    );
    let mut js = Vec::new();
    for (name, mode) in methods() {
        let ppl = perplexity(&mut eng, &data, mode, budget.n_bytes,
                             budget.window, budget.burn_in)?;
        let scores = probe_accuracy(&mut eng, &data, mode, max_probes)?;
        let mut cells = vec![name.to_string(), f4(ppl)];
        for s in &scores {
            cells.push(f3(s.accuracy()));
        }
        cells.push(f3(mean_accuracy(&scores)));
        t.row(cells);
        js.push(jobj(vec![
            ("method", jstr(name)),
            ("nll", jnum(ppl)),
            ("avg_acc", jnum(mean_accuracy(&scores))),
            (
                "tasks",
                jarr(scores.iter().map(|s| jnum(s.accuracy())).collect()),
            ),
        ]));
    }
    t.print();
    println!(
        "\npaper Fig 10 / Table 3: FloE-Wup beats CATS/CHESS at matched \
         sparsity (esp. 90%); FloE (with INT2 up) trades a little accuracy \
         for deployability and still beats HQQ INT3/INT2 and CHESS."
    );
    save_json("table3", &jarr(js))
}

/// Fig 9a: accuracy-vs-sparsity per strategy; Fig 9b: FloE nll across up
/// bit-widths (quantization compatibility).
pub fn run_fig9(art_dir: &std::path::Path, budget: &EvalBudget, max_probes: usize) -> Result<()> {
    let mut eng = Engine::load(art_dir)?;
    let data = EvalData::load(art_dir)?;
    let levels = [0.5, 0.7, 0.8, 0.9];

    let mut t = Table::new(
        "Fig 9a — mean probe accuracy vs sparsity strategy",
        &["strategy", "50%", "70%", "80%", "90%"],
    );
    let mut js = Vec::new();
    type ModeFn = fn(f64) -> ExpertMode;
    let strategies: Vec<(&str, ModeFn)> = vec![
        ("FloE-Wup (up)", |l| ExpertMode::Sparse { level: l }),
        ("CATS (gate)", |l| ExpertMode::CatsGate { level: l }),
        ("CHESS (gate/ch)", |l| ExpertMode::ChessGate { level: l }),
        ("down-input", |l| ExpertMode::DownSparse { level: l }),
    ];
    for (name, mk) in &strategies {
        let mut cells = vec![name.to_string()];
        let mut vals = Vec::new();
        for l in levels {
            let scores = probe_accuracy(&mut eng, &data, mk(l), max_probes)?;
            let acc = mean_accuracy(&scores);
            cells.push(f3(acc));
            vals.push(jnum(acc));
        }
        t.row(cells);
        js.push(jobj(vec![("strategy", jstr(name)), ("acc", jarr(vals))]));
    }
    t.print();

    let mut t2 = Table::new(
        "Fig 9b — FloE nats/byte across up-projection bit-widths",
        &["up bits", "sparsity 50%", "70%", "80%", "90%"],
    );
    for bits in [8u8, 4, 3, 2, 1] {
        let mut cells = vec![format!("INT{bits}")];
        let mut vals = Vec::new();
        for l in levels {
            let p = perplexity(
                &mut eng,
                &data,
                ExpertMode::FloeVar { level: l, bits },
                budget.n_bytes,
                budget.window,
                budget.burn_in,
            )?;
            cells.push(f4(p));
            vals.push(jnum(p));
        }
        t2.row(cells);
        js.push(jobj(vec![("bits", jnum(bits as f64)), ("nll", jarr(vals))]));
    }
    t2.print();
    println!(
        "\npaper Fig 9b: nll curves shift in parallel across bit-widths — \
         sparsity and quantization errors are largely independent/additive."
    );
    save_json("fig9", &jarr(js))
}

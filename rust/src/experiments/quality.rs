//! `exp-quality-latency` — the quality-elastic serving frontier
//! (DESIGN.md §11). No artifacts or `pjrt` needed.
//!
//! Sweeps SLO budget × VRAM cap at the serve-load operating point
//! (skewed routing, cap-8 continuous batching, `--overlap` bus model)
//! with the big-little fallback on, against a stall-only baseline per
//! VRAM cap. Each cell reports the degradation the SLO bought — total
//! degraded boundaries, share of requests that degraded at least once —
//! next to what it paid for: p99 latency, aggregate tok/s and the
//! demand-stall share of the wall clock. Tightening the SLO moves along
//! the frontier (more little-tier resolutions, lower p99); at
//! thrash-depth VRAM the fallback also *wins throughput*, because a
//! degraded resolution skips the demand fetch that was evicting the
//! working set out from under the other sequences. At roomy VRAM
//! (14.25 GB) the carve costs more cache than degradation saves — the
//! frontier exists to make that trade visible, not to hide it.

use anyhow::Result;

use crate::config::ResidencyKind;
use crate::coordinator::sim::{simulate_serving, ServeSimReport, SimParams};
use crate::util::table::{f2, Table};
use crate::workload::{generate, TimedRequest, WorkloadSpec};

use super::serveload::sweep_params;
use super::{jarr, jnum, jobj, jstr, save_json};

/// SLO budgets swept, µs from admission (tightest first).
pub const SLO_BUDGETS_US: [f64; 4] = [1.0e6, 2.0e6, 4.0e6, 8.0e6];
/// VRAM caps swept: thrash depth, the cliff's shoulder, and the
/// serve-load default where the batch's working set fits.
pub const VRAM_CAPS_GB: [f64; 3] = [11.0, 12.5, 14.25];
/// Default little-tier carve: 10% of the device budget. At the sweep's
/// operating points that holds the sketch roster's hot majority while
/// costing few enough resident experts that thrash-depth cells win.
pub const LITTLE_FRAC: f64 = 0.10;
/// The regression-pinned cell: thrash depth, full batching.
pub const PIN_VRAM_GB: f64 = 11.0;
pub const PIN_CAP: usize = 8;
pub const PIN_SLO_US: f64 = 2.0e6;

/// The sweep's simulated system: the serve-load operating point with the
/// event-core overlap bus (where the thrash cliff is deepest) and the
/// little-tier carve at `little_frac` of each device budget.
pub fn quality_params(vram_gb: f64, little_frac: f64) -> SimParams {
    let mut p = sweep_params(ResidencyKind::Lru, vram_gb);
    p.system = p.system.clone().with_overlap(true).with_little_frac(little_frac);
    p
}

/// The serve-load workload shape with a uniform per-request SLO budget
/// (`slo_us` consumes no RNG draws, so arrivals/prompts are identical
/// across budgets — every cell sees the same trace).
pub fn workload_with_slo(
    rate_hz: f64,
    n_requests: usize,
    seed: u64,
    slo_us: Option<f64>,
) -> Vec<TimedRequest> {
    generate(&WorkloadSpec {
        n_requests,
        arrival_rate_hz: rate_hz,
        prompt_len: (8, 24),
        output_tokens: (16, 48),
        seed,
        slo_us,
    })
}

pub fn run(n_requests: usize, seed: u64, little_frac: f64) -> Result<()> {
    let cap = PIN_CAP;
    let mut t = Table::new(
        &format!(
            "Quality-latency frontier — FloE, RTX-3090, cap {cap}, overlap, \
             little carve {:.0}%, {n_requests} requests (simulated)",
            little_frac * 100.0
        ),
        &["vram GB", "slo s", "agg tok/s", "p99 latency s", "p99 gain",
          "demand share", "degraded bnd", "degraded req share"],
    );
    let mut js = Vec::new();
    for &vram in &VRAM_CAPS_GB {
        // stall-only baseline: no carve, no budget — every miss waits
        let base_wl = workload_with_slo(8.0, n_requests, seed, None);
        let base = simulate_serving(&quality_params(vram, 0.0), &base_wl, cap)?;
        t.row(row_cells(vram, None, &base, &base));
        js.push(cell_json(vram, None, &base, &base));
        for &slo in &SLO_BUDGETS_US {
            let wl = workload_with_slo(8.0, n_requests, seed, Some(slo));
            let rep = simulate_serving(&quality_params(vram, little_frac), &wl, cap)?;
            t.row(row_cells(vram, Some(slo), &rep, &base));
            js.push(cell_json(vram, Some(slo), &rep, &base));
        }
    }
    t.print();
    println!(
        "\ntightening the SLO moves along the frontier: more boundaries \
         resolve on the always-resident little tier, p99 drops. At \
         thrash-depth VRAM the skipped demand fetches also stop evicting \
         the working set, so tok/s rises with degradation; at roomy VRAM \
         the carve costs more cache than degradation saves — run \
         fallback-off there."
    );
    save_json("quality_latency", &jarr(js))
}

fn row_cells(
    vram: f64,
    slo: Option<f64>,
    rep: &ServeSimReport,
    base: &ServeSimReport,
) -> Vec<String> {
    vec![
        format!("{vram:.2}"),
        slo.map_or("off".to_string(), |s| format!("{:.0}", s / 1e6)),
        f2(rep.aggregate_tps()),
        f2(rep.p99_latency_us() / 1e6),
        f2(base.p99_latency_us() / rep.p99_latency_us().max(1e-9)),
        f2(rep.stats.stall_demand_us / rep.total_us.max(1e-9)),
        format!("{}", rep.degraded_hits()),
        f2(rep.degraded_request_share()),
    ]
}

fn cell_json(
    vram: f64,
    slo: Option<f64>,
    rep: &ServeSimReport,
    base: &ServeSimReport,
) -> crate::util::json::Json {
    jobj(vec![
        ("vram_gb", jnum(vram)),
        ("slo_us", jnum(slo.unwrap_or(0.0))),
        ("fallback", jstr(if slo.is_some() { "on" } else { "off" })),
        ("aggregate_tps", jnum(rep.aggregate_tps())),
        ("p99_latency_us", jnum(rep.p99_latency_us())),
        ("p95_latency_us", jnum(rep.p95_latency_us())),
        ("p99_gain", jnum(base.p99_latency_us() / rep.p99_latency_us().max(1e-9))),
        ("demand_stall_share", jnum(rep.stats.stall_demand_us / rep.total_us.max(1e-9))),
        ("degraded_boundaries", jnum(rep.degraded_hits() as f64)),
        ("degraded_bytes", jnum(rep.stats.degraded_bytes)),
        ("degraded_request_share", jnum(rep.degraded_request_share())),
        ("total_us", jnum(rep.total_us)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pin_reports() -> (ServeSimReport, ServeSimReport) {
        let base_wl = workload_with_slo(8.0, 12, 23, None);
        let base = simulate_serving(&quality_params(PIN_VRAM_GB, 0.0), &base_wl, PIN_CAP)
            .unwrap();
        let wl = workload_with_slo(8.0, 12, 23, Some(PIN_SLO_US));
        let on = simulate_serving(&quality_params(PIN_VRAM_GB, LITTLE_FRAC), &wl, PIN_CAP)
            .unwrap();
        (base, on)
    }

    /// The thrash-cliff regression pin (replay-measured at this exact
    /// cell: tok/s 1.3234x, p99 1.3793x, demand share 0.5764 → 0.4270,
    /// 8198 degraded boundaries, every request degraded at least once).
    #[test]
    fn fallback_beats_stall_only_at_thrash_depth() {
        let (base, on) = pin_reports();
        let tps_gain = on.aggregate_tps() / base.aggregate_tps();
        assert!(tps_gain > 1.0, "fallback-on tok/s did not beat stall-only: {tps_gain}");
        let p99_gain = base.p99_latency_us() / on.p99_latency_us();
        assert!(p99_gain >= 1.10, "p99 gain {p99_gain} below the 1.10x pin");
        let share_base = base.stats.stall_demand_us / base.total_us;
        let share_on = on.stats.stall_demand_us / on.total_us;
        assert!(
            share_on < share_base,
            "demand-stall share did not decrease: {share_on} vs {share_base}"
        );
        // the degradation the gain was bought with, visible and bounded
        assert!(on.degraded_hits() > 5_000, "degraded boundaries {}", on.degraded_hits());
        assert!(on.degraded_request_share() >= 0.9);
        assert!(base.degraded_hits() == 0, "stall-only run degraded");
    }

    /// Tighter SLO ⇒ lower p99 and no smaller degraded-request share,
    /// at every swept VRAM cap; at the pinned thrash-depth cap the
    /// degraded boundary count itself is strictly monotone.
    #[test]
    fn frontier_is_monotone_in_slo() {
        for &vram in &VRAM_CAPS_GB {
            let mut prev_p99 = f64::NEG_INFINITY;
            let mut prev_share = f64::INFINITY;
            let mut prev_hits = u64::MAX;
            for &slo in &SLO_BUDGETS_US {
                let wl = workload_with_slo(8.0, 12, 23, Some(slo));
                let rep =
                    simulate_serving(&quality_params(vram, LITTLE_FRAC), &wl, PIN_CAP)
                        .unwrap();
                assert!(
                    rep.p99_latency_us() >= prev_p99,
                    "p99 not monotone at {vram} GB / slo {slo}"
                );
                assert!(
                    rep.degraded_request_share() <= prev_share,
                    "degraded request share not monotone at {vram} GB / slo {slo}"
                );
                if vram == PIN_VRAM_GB {
                    assert!(
                        rep.degraded_hits() < prev_hits,
                        "degraded boundaries not strictly decreasing at slo {slo}"
                    );
                    prev_hits = rep.degraded_hits();
                }
                prev_p99 = rep.p99_latency_us();
                prev_share = rep.degraded_request_share();
            }
        }
    }

    /// An SLO budget without the carve never degrades and never changes
    /// a single bit: the decision is gated on `little_frac > 0`, so the
    /// protocol field alone is timing-inert.
    #[test]
    fn slo_without_carve_is_bit_exact() {
        let plain = simulate_serving(
            &quality_params(PIN_VRAM_GB, 0.0),
            &workload_with_slo(8.0, 12, 23, None),
            PIN_CAP,
        )
        .unwrap();
        let with_slo = simulate_serving(
            &quality_params(PIN_VRAM_GB, 0.0),
            &workload_with_slo(8.0, 12, 23, Some(PIN_SLO_US)),
            PIN_CAP,
        )
        .unwrap();
        assert_eq!(with_slo.total_us.to_bits(), plain.total_us.to_bits());
        assert_eq!(
            with_slo.stats.stall_demand_us.to_bits(),
            plain.stats.stall_demand_us.to_bits()
        );
        assert_eq!(
            with_slo.stats.stall_prefetch_us.to_bits(),
            plain.stats.stall_prefetch_us.to_bits()
        );
        assert_eq!(with_slo.degraded_hits(), 0);
        for (a, b) in with_slo.completions.iter().zip(plain.completions.iter()) {
            assert_eq!(a.finished_us.to_bits(), b.finished_us.to_bits());
            assert_eq!(a.stall.demand_us.to_bits(), b.stall.demand_us.to_bits());
        }
    }
}

//! Fig 6: end-to-end generation speed (TPS) under a 12 GB VRAM constraint,
//! FloE vs DeepSpeed-MII / Mixtral-Offloading / Fiddler / Mixtral-GPU,
//! across input/output length combinations — via the discrete-event
//! simulator at Mixtral-8x7B scale on RTX-3090 hardware models.
//!
//! Both legs accept an ExpertStore residency policy (`--policy`); LRU is
//! the paper configuration, LFU / sparsity-aware are comparison points.

use anyhow::Result;

use crate::config::{ResidencyKind, ShardPolicy};
use crate::coordinator::policy::{SystemConfig, SystemKind};
use crate::coordinator::sim::{simulate, SimParams};
use crate::hwsim::RTX3090;
use crate::util::table::{f2, Table};

use super::{jarr, jnum, jobj, jstr, save_json};

pub const LENGTHS: [(usize, usize); 4] = [(32, 64), (64, 128), (64, 256), (128, 512)];

/// `--devices 1` (any shard policy) leaves the system config — and the
/// JSON this writes — bit-identical to the pre-placement code
/// (`sparsity_decay` only shapes the `sparsity` residency policy).
pub fn run(
    vram_gb: f64,
    residency: ResidencyKind,
    devices: usize,
    shard: ShardPolicy,
    sparsity_decay: f64,
) -> Result<()> {
    let sharded_note = if devices > 1 {
        format!(", {} x {:.0} GB sharded ({})", devices, vram_gb, shard.name())
    } else {
        String::new()
    };
    let mut t = Table::new(
        &format!(
            "Fig 6 — decode TPS, Mixtral-8x7B on RTX-3090 @ {vram_gb:.0} GB VRAM \
             (simulated, {} residency{sharded_note})",
            residency.name()
        ),
        &["system", "in32/out64", "in64/out128", "in64/out256", "in128/out512",
          "vs GPU-resident", "vs DeepSpeed"],
    );
    let mut js = Vec::new();
    let mut results: Vec<(SystemKind, Vec<f64>)> = Vec::new();
    for kind in SystemKind::ALL {
        let mut system =
            SystemConfig::with_residency(kind, residency).with_devices(devices, shard);
        system.sparsity_decay = sparsity_decay;
        let p = SimParams::mixtral_on(RTX3090.clone(), system, vram_gb);
        let tps: Vec<f64> = LENGTHS
            .iter()
            .map(|&(i, o)| simulate(&p, i, o).tps)
            .collect();
        results.push((kind, tps));
    }
    let gpu_tps = results
        .iter()
        .find(|(k, _)| *k == SystemKind::GpuResident)
        .unwrap()
        .1[1];
    let naive_tps = results
        .iter()
        .find(|(k, _)| *k == SystemKind::NaiveOffload)
        .unwrap()
        .1[1];
    for (kind, tps) in &results {
        t.row(vec![
            kind.name().to_string(),
            f2(tps[0]),
            f2(tps[1]),
            f2(tps[2]),
            f2(tps[3]),
            format!("{:.2}", tps[1] / gpu_tps),
            format!("{:.1}x", tps[1] / naive_tps),
        ]);
        js.push(jobj(vec![
            ("system", jstr(kind.name())),
            ("policy", jstr(residency.name())),
            ("tps", jarr(tps.iter().map(|v| jnum(*v)).collect())),
        ]));
    }
    t.print();
    let floe_tps = results
        .iter()
        .find(|(k, _)| *k == SystemKind::Floe)
        .unwrap()
        .1[1];
    println!(
        "\nheadline: FloE = {:.1}x DeepSpeed-MII (paper: 48.7x), {:.0}% of \
         GPU-resident (paper: 91%), {:.2}x Mixtral-Offloading (paper: 2.60x), \
         {:.2}x Fiddler (paper: 3.14x)",
        floe_tps / naive_tps,
        100.0 * floe_tps / gpu_tps,
        floe_tps
            / results
                .iter()
                .find(|(k, _)| *k == SystemKind::AdvancedOffload)
                .unwrap()
                .1[1],
        floe_tps
            / results
                .iter()
                .find(|(k, _)| *k == SystemKind::Fiddler)
                .unwrap()
                .1[1],
    );
    save_json("fig6", &jarr(js))
}

/// The real-system counterpart: serve actual requests on the in-repo model
/// under each policy and report measured TPS (compute) + effective TPS
/// (compute + modeled PCIe stalls).
pub fn run_real(
    art_dir: &std::path::Path,
    out_tokens: usize,
    residency: ResidencyKind,
) -> Result<()> {
    use crate::coordinator::serve::{Coordinator, Request};
    let mut t = Table::new(
        &format!(
            "Fig 6 (real engine) — tiny model, measured decode TPS ({} residency)",
            residency.name()
        ),
        &["system", "compute TPS", "effective TPS", "stall ms/token", "cache hit"],
    );
    let mut js = Vec::new();
    for kind in [SystemKind::Floe, SystemKind::NaiveOffload, SystemKind::AdvancedOffload,
                 SystemKind::GpuResident] {
        let mut sys = SystemConfig::with_residency(kind, residency);
        sys.sparsity = 0.8;
        let budget = match kind {
            SystemKind::GpuResident => usize::MAX / 2,
            _ => 384 * 1024,
        };
        let mut coord = Coordinator::new(art_dir, sys, budget)?;
        coord.calibrate_layer_time()?;
        let reqs: Vec<Request> = (0..3)
            .map(|i| Request {
                id: i,
                prompt: b"the miller carried a copper kettle ".to_vec(),
                max_tokens: out_tokens,
                temperature: 0.0,
                seed: i,
                slo_us: None,
            })
            .collect();
        let done = coord.run_batch(&reqs)?;
        let tokens: usize = done.iter().map(|c| c.tokens).sum();
        let decode_s: f64 = done.iter().map(|c| c.decode_s).sum();
        let stall_s: f64 = done.iter().map(|c| c.stall_virtual_s).sum();
        let compute_tps = tokens as f64 / decode_s.max(1e-9);
        let eff_tps = tokens as f64 / (decode_s + stall_s).max(1e-9);
        t.row(vec![
            kind.name().to_string(),
            f2(compute_tps),
            f2(eff_tps),
            format!("{:.3}", 1e3 * stall_s / tokens as f64),
            f2(coord.pipeline.stats().cache_hit_rate()),
        ]);
        js.push(jobj(vec![
            ("system", jstr(kind.name())),
            ("policy", jstr(residency.name())),
            ("compute_tps", jnum(compute_tps)),
            ("effective_tps", jnum(eff_tps)),
        ]));
    }
    t.print();
    save_json("fig6_real", &jarr(js))
}

//! One module per paper table/figure (DESIGN.md §5 experiment index).
//! Every `run` prints a markdown table (paste-ready for EXPERIMENTS.md)
//! and writes machine-readable JSON under `artifacts/results/`.

pub mod chaos;
pub mod cluster;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod quality;
pub mod serveload;
pub mod shard;
pub mod table1;
pub mod table3;
pub mod table7;

use std::path::PathBuf;

use anyhow::Result;

use crate::util::json::{write, Json};

pub fn results_dir() -> PathBuf {
    let d = crate::artifacts_dir().join("results");
    let _ = std::fs::create_dir_all(&d);
    d
}

pub fn save_json(name: &str, j: &Json) -> Result<()> {
    let path = results_dir().join(format!("{name}.json"));
    std::fs::write(&path, write(j))?;
    println!("[saved {}]", path.display());
    Ok(())
}

pub fn jnum(v: f64) -> Json {
    Json::Num(v)
}

pub fn jstr(s: &str) -> Json {
    Json::Str(s.to_string())
}

pub fn jarr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

pub fn jobj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

//! `exp-shard-sweep` — the placement study over the sharded `ExpertStore`
//! (DESIGN.md §3): devices × VRAM-per-device × shard policy, comparing
//! N *independent* single-device stores (one-expert-per-call transfers,
//! no cross-device cooperation — exactly what N copies of the
//! pre-placement store would do) against the placement-aware store with
//! *coalesced* transfer plans (same-layer, same-destination prefetches
//! chunked into one bus transaction, amortizing the per-copy API overhead
//! behind the Fig-7 U-shape), the fully *cooperative* mode (coalescing
//! plus eviction spill to peer devices over the GPU↔GPU link), and the
//! *popularity* mode ("pop"): cooperative plus hot-expert replication
//! (`--replicate-top`) and per-device compute streams
//! (`--compute-streams`) — the configuration where `--devices N` scales
//! FLOPs, not just caches and buses. The shard axis includes `balanced`
//! (measured-mass re-homing); the max-device bus-busy column is the
//! load-imbalance signal (`balanced` beats `hash` outright whenever the
//! hash collides hot experts — pinned by tests/shard_store.rs; on traces
//! where hash happens to balance, `balanced` matches it and wins on tps
//! through replication + compute streams).
//!
//! Independent vs coalesced move byte-identical traffic (the routing
//! trace fixes the transfer set; asserted by the module tests), so the
//! bus-transaction and stall columns isolate the coalescing win. The
//! serving leg replays one arrival trace through the continuous-batching
//! scheduler at each device count for aggregate tokens/s.
//!
//! Simulation only — no artifacts or the `pjrt` feature needed.

use anyhow::Result;

use crate::config::{ResidencyKind, ShardPolicy};
use crate::coordinator::policy::{SystemConfig, SystemKind};
use crate::coordinator::sim::{simulate, simulate_serving, RoutingModel, SimParams};
use crate::hwsim::RTX3090;
use crate::util::table::{f2, Table};

use super::{jarr, jnum, jobj, jstr, save_json};

pub const DEVICES: [usize; 3] = [1, 2, 4];
/// Per-device budgets chosen so eviction stays active at 1-2 devices
/// (FloE's resident INT2 ups + attention/KV eat ~9 GB before the expert
/// cache sees a byte — see `cache_budget_bytes`).
pub const VRAM_PER_DEVICE_GB: [f64; 2] = [11.0, 13.0];

/// Hottest-expert replica count the sweep's "pop" rows run
/// (`--replicate-top 2` equivalent).
pub const SWEEP_REPLICATE_TOP: usize = 2;

/// Cooperation level of one sweep point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardMode {
    /// N independent single-device stores: per-expert transfers, no
    /// coalescing, no spill — the pre-placement baseline times N
    Independent,
    /// batched plans coalesce into chunked copies; eviction still drops
    Coalesced,
    /// coalescing + eviction spill over the peer link
    Cooperative,
    /// cooperative + hot-expert replication + per-device compute streams
    /// — the popularity-driven serving mode
    Popularity,
}

impl ShardMode {
    pub const ALL: [ShardMode; 4] = [
        ShardMode::Independent,
        ShardMode::Coalesced,
        ShardMode::Cooperative,
        ShardMode::Popularity,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ShardMode::Independent => "independent",
            ShardMode::Coalesced => "coalesced",
            ShardMode::Cooperative => "coop",
            ShardMode::Popularity => "pop",
        }
    }
}

/// One sweep point: FloE on a skewed, sticky routing trace (the regime
/// where placement matters), `vram_gb` per device.
pub fn sweep_point(
    residency: ResidencyKind,
    vram_gb: f64,
    devices: usize,
    shard: ShardPolicy,
    mode: ShardMode,
    seed: u64,
) -> SimParams {
    let mut system =
        SystemConfig::with_residency(SystemKind::Floe, residency).with_devices(devices, shard);
    match mode {
        ShardMode::Independent => {
            system.coalesce = false;
            system.spill = false;
        }
        ShardMode::Coalesced => {
            system.coalesce = devices > 1;
            system.spill = false;
        }
        ShardMode::Cooperative => {} // with_devices defaults
        ShardMode::Popularity => {
            system = system.with_replication(SWEEP_REPLICATE_TOP);
        }
    }
    let mut p = SimParams::mixtral_on(RTX3090.clone(), system, vram_gb);
    p.routing = RoutingModel { zipf_s: 1.2, stickiness: 0.5, seed };
    p
}

/// `sweep_point` on a heterogeneous fleet (`--hetero-fleet`): per-device
/// GEMV throughput descends across the placement
/// (`TopologySpec::heterogeneous`). Only the "pop" rows can observe it —
/// gemv_scale is consulted exclusively by per-device compute streams.
pub fn sweep_point_fleet(
    residency: ResidencyKind,
    vram_gb: f64,
    devices: usize,
    shard: ShardPolicy,
    mode: ShardMode,
    seed: u64,
    hetero: bool,
) -> SimParams {
    let mut p = sweep_point(residency, vram_gb, devices, shard, mode, seed);
    p.system.hetero_fleet = hetero;
    p
}

pub fn run(residency: ResidencyKind, seed: u64, sparsity_decay: f64) -> Result<()> {
    let mut t = Table::new(
        &format!(
            "Shard sweep — FloE, RTX-3090s, in 64 / out 256, skewed routing, \
             {} residency (simulated; VRAM per device)",
            residency.name()
        ),
        &["devices", "GB/dev", "shard", "mode", "fleet", "tps", "bus tx",
          "GB moved", "stall ms", "max bus ms", "cache hit"],
    );
    let mut js = Vec::new();
    // the headline reports, captured from the sweep loop itself
    // (same parameters — no re-simulation)
    let (mut h_one, mut h_indep, mut h_coal) = (None, None, None);
    let (mut h_hash, mut h_pop, mut h_pop_het) = (None, None, None);
    for &devices in &DEVICES {
        for &vram in &VRAM_PER_DEVICE_GB {
            let shards: &[ShardPolicy] =
                if devices == 1 { &[ShardPolicy::Layer] } else { &ShardPolicy::ALL };
            let modes: &[ShardMode] =
                if devices == 1 { &[ShardMode::Independent] } else { &ShardMode::ALL };
            for &shard in shards {
                for &mode in modes {
                    // the hetero-fleet axis rides only on the "pop" rows:
                    // gemv_scale is consulted exclusively by per-device
                    // compute streams, so every other mode would print an
                    // identical duplicate row
                    let fleets: &[bool] =
                        if mode == ShardMode::Popularity && devices > 1 {
                            &[false, true]
                        } else {
                            &[false]
                        };
                    for &hetero in fleets {
                        let mut p = sweep_point_fleet(
                            residency, vram, devices, shard, mode, seed, hetero,
                        );
                        p.system.sparsity_decay = sparsity_decay;
                        let rep = simulate(&p, 64, 256);
                        if vram == VRAM_PER_DEVICE_GB[0] {
                            match (devices, shard, mode, hetero) {
                                (1, ShardPolicy::Layer, ShardMode::Independent, false) => {
                                    h_one = Some(rep.clone())
                                }
                                (2, ShardPolicy::Layer, ShardMode::Independent, false) => {
                                    h_indep = Some(rep.clone())
                                }
                                (2, ShardPolicy::Layer, ShardMode::Coalesced, false) => {
                                    h_coal = Some(rep.clone())
                                }
                                (2, ShardPolicy::Hash, ShardMode::Cooperative, false) => {
                                    h_hash = Some(rep.clone())
                                }
                                (2, ShardPolicy::Balanced, ShardMode::Popularity, false) => {
                                    h_pop = Some(rep.clone())
                                }
                                (2, ShardPolicy::Balanced, ShardMode::Popularity, true) => {
                                    h_pop_het = Some(rep.clone())
                                }
                                _ => {}
                            }
                        }
                        let fleet = if hetero { "hetero" } else { "uniform" };
                        t.row(vec![
                            devices.to_string(),
                            format!("{vram:.0}"),
                            shard.name().to_string(),
                            mode.name().to_string(),
                            fleet.to_string(),
                            f2(rep.tps),
                            rep.bus_transactions.to_string(),
                            f2(rep.transferred_gb),
                            f2(rep.stall_us / 1e3),
                            f2(rep.max_device_bus_busy_us / 1e3),
                            f2(rep.cache_hit_rate),
                        ]);
                        js.push(jobj(vec![
                            ("devices", jnum(devices as f64)),
                            ("vram_per_device_gb", jnum(vram)),
                            ("shard", jstr(shard.name())),
                            ("mode", jstr(mode.name())),
                            ("fleet", jstr(fleet)),
                            ("policy", jstr(residency.name())),
                            ("tps", jnum(rep.tps)),
                            ("bus_transactions", jnum(rep.bus_transactions as f64)),
                            ("transferred_gb", jnum(rep.transferred_gb)),
                            ("stall_us", jnum(rep.stall_us)),
                            ("max_device_bus_busy_us", jnum(rep.max_device_bus_busy_us)),
                            ("cache_hit", jnum(rep.cache_hit_rate)),
                        ]));
                    }
                }
            }
        }
    }
    t.print();

    // ---- serving leg: aggregate tokens/s vs device count ----
    let mut ts = Table::new(
        "Shard sweep (serving) — 12 requests @ 8 req/s, batch cap 4, 11 GB/dev",
        &["devices", "shard/mode", "agg tok/s", "p95 latency ms",
          "stall demand ms", "stall prefetch ms", "cache hit"],
    );
    let wl = crate::experiments::serveload::workload_at(8.0, 12, seed);
    let mut serve_js = Vec::new();
    for &devices in &DEVICES {
        let configs: &[(ShardPolicy, ShardMode)] = if devices == 1 {
            &[(ShardPolicy::Layer, ShardMode::Cooperative)]
        } else {
            &[
                (ShardPolicy::Layer, ShardMode::Cooperative),
                (ShardPolicy::Balanced, ShardMode::Popularity),
            ]
        };
        for &(shard, mode) in configs {
            let mut p =
                sweep_point(residency, VRAM_PER_DEVICE_GB[0], devices, shard, mode, seed);
            p.system.sparsity_decay = sparsity_decay;
            let rep = simulate_serving(&p, &wl, 4)?;
            let label = format!("{}/{}", shard.name(), mode.name());
            ts.row(vec![
                devices.to_string(),
                label.clone(),
                f2(rep.aggregate_tps()),
                f2(rep.p95_latency_us() / 1e3),
                f2(rep.stats.stall_demand_us / 1e3),
                f2(rep.stats.stall_prefetch_us / 1e3),
                f2(rep.cache_hit_rate),
            ]);
            serve_js.push(jobj(vec![
                ("devices", jnum(devices as f64)),
                ("shard", jstr(shard.name())),
                ("mode", jstr(mode.name())),
                ("aggregate_tps", jnum(rep.aggregate_tps())),
                ("p95_latency_us", jnum(rep.p95_latency_us())),
                ("bus_transactions", jnum(rep.stats.bus_transactions as f64)),
                ("cache_hit", jnum(rep.cache_hit_rate)),
            ]));
        }
    }
    ts.print();

    let (one, indep, coal) = (
        h_one.expect("sweep covered 1-dev independent"),
        h_indep.expect("sweep covered 2-dev independent"),
        h_coal.expect("sweep covered 2-dev coalesced"),
    );
    println!(
        "\nheadline: at 2 devices coalescing moves the same {:.2} GB in {} bus \
         transactions instead of {} ({:.0}% fewer) for {:.2}x the single-device \
         tps; spill adds peer-link rescue on top (see coop rows).",
        coal.transferred_gb,
        coal.bus_transactions,
        indep.bus_transactions,
        100.0 * (1.0 - coal.bus_transactions as f64 / indep.bus_transactions as f64),
        coal.tps / one.tps,
    );
    let (hash, pop) = (
        h_hash.expect("sweep covered 2-dev hash coop"),
        h_pop.expect("sweep covered 2-dev balanced pop"),
    );
    println!(
        "popularity: balanced re-homing + top-{SWEEP_REPLICATE_TOP} replication + \
         per-device compute streams serves {:.2} tok/s vs {:.2} for static hash \
         ({:.2}x) — the FLOP-scaling win. Busiest-bus occupancy: {:.1} ms vs \
         {:.1} ms (on this trace hash happens to spread load evenly, so the \
         bus-balance win shows up only when hashing collides hot experts — \
         see the max-bus column across shard rows and tests/shard_store.rs).",
        pop.tps,
        hash.tps,
        pop.tps / hash.tps,
        pop.max_device_bus_busy_us / 1e3,
        hash.max_device_bus_busy_us / 1e3,
    );
    let pop_het = h_pop_het.expect("sweep covered 2-dev balanced pop hetero");
    println!(
        "hetero fleet: the same pop configuration on a flagship+older-card \
         fleet (per-device GEMV throughput descending to 65%) serves {:.2} \
         tok/s vs {:.2} uniform ({:.1}% tax) — the compute streams absorb \
         the slow devices' latency where the single-timeline modes would \
         serialize it (hetero rows exist only under streams; gemv_scale is \
         invisible elsewhere).",
        pop_het.tps,
        pop.tps,
        100.0 * (1.0 - pop_het.tps / pop.tps),
    );
    save_json(
        "shard_sweep",
        &jobj(vec![("points", jarr(js)), ("serving", jarr(serve_js))]),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The redesign's acceptance shape: coalesced multi-device prefetch
    /// beats N independent single-device stores on the same skewed trace
    /// — fewer bus transactions at bit-identical bytes moved, no
    /// throughput regression — and ≥2 devices beat one device clearly.
    #[test]
    fn coalesced_sharding_beats_independent_stores() {
        let indep = simulate(
            &sweep_point(
                ResidencyKind::Lru,
                VRAM_PER_DEVICE_GB[0],
                2,
                ShardPolicy::Layer,
                ShardMode::Independent,
                7,
            ),
            64,
            256,
        );
        let coal = simulate(
            &sweep_point(
                ResidencyKind::Lru,
                VRAM_PER_DEVICE_GB[0],
                2,
                ShardPolicy::Layer,
                ShardMode::Coalesced,
                7,
            ),
            64,
            256,
        );
        let one = simulate(
            &sweep_point(
                ResidencyKind::Lru,
                VRAM_PER_DEVICE_GB[0],
                1,
                ShardPolicy::Layer,
                ShardMode::Independent,
                7,
            ),
            64,
            256,
        );
        // the trace fixes the transfer set: coalescing must move the
        // exact same bytes in strictly fewer bus transactions
        assert_eq!(
            coal.transferred_bytes, indep.transferred_bytes,
            "coalescing changed what was moved"
        );
        assert!(
            coal.bus_transactions < indep.bus_transactions,
            "coalesced {} vs independent {} transactions",
            coal.bus_transactions,
            indep.bus_transactions
        );
        // amortized per-copy overhead can only help throughput
        assert!(
            coal.tps >= indep.tps * 0.999,
            "coalesced {} slower than independent {}",
            coal.tps,
            indep.tps
        );
        // doubling devices (cache + buses) must clearly beat one device
        // at the same per-device budget
        assert!(
            coal.tps > one.tps * 1.02,
            "2-device {} not faster than 1-device {}",
            coal.tps,
            one.tps
        );
    }

    /// The popularity acceptance shape (margins replay-verified in
    /// python/replay_sim.py): balanced re-homing + top-k replication +
    /// per-device compute streams beats static hash sharding on decode
    /// TPS at 2 and 4 devices on the skewed trace (replay, under the
    /// replica-pool carve: 1.0216x and 1.2657x).
    #[test]
    fn balanced_popularity_beats_hash_on_skewed_trace() {
        for (devices, min_ratio) in [(2usize, 1.02), (4, 1.10)] {
            let hash = simulate(
                &sweep_point(
                    ResidencyKind::Lru,
                    VRAM_PER_DEVICE_GB[0],
                    devices,
                    ShardPolicy::Hash,
                    ShardMode::Cooperative,
                    7,
                ),
                64,
                256,
            );
            let pop = simulate(
                &sweep_point(
                    ResidencyKind::Lru,
                    VRAM_PER_DEVICE_GB[0],
                    devices,
                    ShardPolicy::Balanced,
                    ShardMode::Popularity,
                    7,
                ),
                64,
                256,
            );
            assert!(
                pop.tps > hash.tps * min_ratio,
                "{devices} devices: pop {} not > {min_ratio}x hash {}",
                pop.tps,
                hash.tps
            );
        }
    }

    /// Per-device compute streams must deliver FLOP scaling beyond what
    /// placement alone gives: the same balanced+replicated config with
    /// streams on beats itself with streams off (replay, under the
    /// replica-pool carve: 1.0774x at 2 devices).
    #[test]
    fn compute_streams_scale_flops_beyond_single_timeline() {
        let with = sweep_point(
            ResidencyKind::Lru,
            VRAM_PER_DEVICE_GB[0],
            2,
            ShardPolicy::Balanced,
            ShardMode::Popularity,
            7,
        );
        let mut without = with.clone();
        without.system.compute_streams = false;
        let on = simulate(&with, 64, 256);
        let off = simulate(&without, 64, 256);
        assert!(
            on.tps > off.tps * 1.03,
            "streams on {} not > 1.03x off {}",
            on.tps,
            off.tps
        );
    }

    /// The hetero-fleet contract: with per-device compute streams on
    /// (the "pop" rows) a descending-throughput fleet pays a real,
    /// deterministic throughput tax; with streams off, `gemv_scale` is
    /// never consulted and the report stays bit-identical to uniform.
    #[test]
    fn hetero_fleet_taxes_streams_and_is_invisible_without_them() {
        let at = |mode: ShardMode, hetero: bool| {
            simulate(
                &sweep_point_fleet(
                    ResidencyKind::Lru,
                    VRAM_PER_DEVICE_GB[0],
                    2,
                    ShardPolicy::Balanced,
                    mode,
                    7,
                    hetero,
                ),
                64,
                256,
            )
        };
        // streams on (pop): the slow device's GEMVs stretch its stream
        let (uni, het) = (at(ShardMode::Popularity, false), at(ShardMode::Popularity, true));
        assert!(
            het.tps < uni.tps,
            "hetero {} not slower than uniform {} under streams",
            het.tps,
            uni.tps
        );
        // and deterministically so
        let het2 = at(ShardMode::Popularity, true);
        assert_eq!(het.tps.to_bits(), het2.tps.to_bits());
        assert_eq!(het.stall_us.to_bits(), het2.stall_us.to_bits());
        // streams off (coop): gemv_scale never read — bit-identical
        let (uni_c, het_c) =
            (at(ShardMode::Cooperative, false), at(ShardMode::Cooperative, true));
        assert_eq!(uni_c.tps.to_bits(), het_c.tps.to_bits());
        assert_eq!(uni_c.total_us.to_bits(), het_c.total_us.to_bits());
        assert_eq!(uni_c.stall_us.to_bits(), het_c.stall_us.to_bits());
        assert_eq!(uni_c.bus_transactions, het_c.bus_transactions);
    }

    #[test]
    fn serving_aggregate_tps_rises_with_devices() {
        let wl = crate::experiments::serveload::workload_at(8.0, 12, 7);
        let at = |devices| {
            let p = sweep_point(
                ResidencyKind::Lru,
                VRAM_PER_DEVICE_GB[0],
                devices,
                ShardPolicy::Layer,
                ShardMode::Cooperative,
                7,
            );
            simulate_serving(&p, &wl, 4).unwrap().aggregate_tps()
        };
        let one = at(1);
        let two = at(2);
        assert!(two > one, "2-device serving {two} <= 1-device {one}");
    }
}

//! Fig 8: generation TPS vs VRAM budget (12..24 GB), input/output 64/256,
//! Mixtral-8x7B on RTX-3090 hardware models. More VRAM → larger expert
//! cache → fewer reloads; FloE stays near the GPU-resident bound.

use anyhow::Result;

use crate::coordinator::policy::{SystemConfig, SystemKind};
use crate::coordinator::sim::{simulate, SimParams};
use crate::hwsim::RTX3090;
use crate::util::table::{f2, Table};

use super::{jarr, jnum, jobj, jstr, save_json};

pub const VRAM_GB: [f64; 5] = [12.0, 14.0, 16.0, 20.0, 24.0];

pub fn run() -> Result<()> {
    let mut t = Table::new(
        "Fig 8 — TPS vs VRAM budget (in 64 / out 256, RTX-3090, simulated)",
        &["system", "12GB", "14GB", "16GB", "20GB", "24GB", "24GB vs GPU"],
    );
    let mut js = Vec::new();
    let mut gpu_at_24 = 1.0;
    let mut rows: Vec<(SystemKind, Vec<f64>)> = Vec::new();
    for kind in SystemKind::ALL {
        let tps: Vec<f64> = VRAM_GB
            .iter()
            .map(|&v| {
                let p = SimParams::mixtral_on(
                    RTX3090.clone(),
                    SystemConfig::new(kind),
                    v,
                );
                simulate(&p, 64, 256).tps
            })
            .collect();
        if kind == SystemKind::GpuResident {
            gpu_at_24 = tps[4];
        }
        rows.push((kind, tps));
    }
    for (kind, tps) in &rows {
        t.row(vec![
            kind.name().to_string(),
            f2(tps[0]),
            f2(tps[1]),
            f2(tps[2]),
            f2(tps[3]),
            f2(tps[4]),
            format!("{:.2}", tps[4] / gpu_at_24),
        ]);
        js.push(jobj(vec![
            ("system", jstr(kind.name())),
            ("tps", jarr(tps.iter().map(|v| jnum(*v)).collect())),
        ]));
    }
    t.print();
    println!(
        "\npaper Fig 8: FloE tracks Mixtral-GPU across budgets and roughly \
         matches it at 24 GB; Mixtral-Offloading approaches it only at 21+ GB."
    );
    save_json("fig8", &jarr(js))
}

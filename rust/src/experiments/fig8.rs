//! Fig 8: generation TPS vs VRAM budget (12..24 GB), input/output 64/256,
//! Mixtral-8x7B on RTX-3090 hardware models. More VRAM → larger expert
//! cache → fewer reloads; FloE stays near the GPU-resident bound.
//!
//! `run` sweeps the systems under one ExpertStore residency policy;
//! `run_policy_sweep` fixes the system and sweeps the policies, so
//! LRU / LFU / sparsity-aware can be compared in one table.

use anyhow::Result;

use crate::config::{ResidencyKind, ShardPolicy};
use crate::coordinator::policy::{SystemConfig, SystemKind};
use crate::coordinator::sim::{simulate, SimParams};
use crate::hwsim::RTX3090;
use crate::util::table::{f2, Table};

use super::{jarr, jnum, jobj, jstr, save_json};

pub const VRAM_GB: [f64; 5] = [12.0, 14.0, 16.0, 20.0, 24.0];

/// `--devices 1` (any shard policy) leaves the system config — and the
/// JSON this writes — bit-identical to the pre-placement code
/// (`sparsity_decay` only shapes the `sparsity` residency policy).
pub fn run(
    residency: ResidencyKind,
    devices: usize,
    shard: ShardPolicy,
    sparsity_decay: f64,
) -> Result<()> {
    let sharded_note = if devices > 1 {
        format!(", {} devices sharded ({}), VRAM per device", devices, shard.name())
    } else {
        String::new()
    };
    let mut t = Table::new(
        &format!(
            "Fig 8 — TPS vs VRAM budget (in 64 / out 256, RTX-3090, simulated, \
             {} residency{sharded_note})",
            residency.name()
        ),
        &["system", "12GB", "14GB", "16GB", "20GB", "24GB", "24GB vs GPU"],
    );
    let mut js = Vec::new();
    let mut gpu_at_24 = 1.0;
    let mut rows: Vec<(SystemKind, Vec<f64>)> = Vec::new();
    for kind in SystemKind::ALL {
        let tps: Vec<f64> = VRAM_GB
            .iter()
            .map(|&v| {
                let mut system = SystemConfig::with_residency(kind, residency)
                    .with_devices(devices, shard);
                system.sparsity_decay = sparsity_decay;
                let p = SimParams::mixtral_on(RTX3090.clone(), system, v);
                simulate(&p, 64, 256).tps
            })
            .collect();
        if kind == SystemKind::GpuResident {
            gpu_at_24 = tps[4];
        }
        rows.push((kind, tps));
    }
    for (kind, tps) in &rows {
        t.row(vec![
            kind.name().to_string(),
            f2(tps[0]),
            f2(tps[1]),
            f2(tps[2]),
            f2(tps[3]),
            f2(tps[4]),
            format!("{:.2}", tps[4] / gpu_at_24),
        ]);
        js.push(jobj(vec![
            ("system", jstr(kind.name())),
            ("policy", jstr(residency.name())),
            ("tps", jarr(tps.iter().map(|v| jnum(*v)).collect())),
        ]));
    }
    t.print();
    println!(
        "\npaper Fig 8: FloE tracks Mixtral-GPU across budgets and roughly \
         matches it at 24 GB; Mixtral-Offloading approaches it only at 21+ GB."
    );
    save_json("fig8", &jarr(js))
}

/// One sweep comparing the three ExpertStore residency policies: FloE and
/// the cache-heavy AdvancedOffload baseline across the VRAM budgets, TPS
/// and expert-cache hit rate side by side. `sparsity_decay` tunes the
/// sparsity policy's activation EMA (`--sparsity-decay`).
pub fn run_policy_sweep(sparsity_decay: f64) -> Result<()> {
    let mut js = Vec::new();
    for kind in [SystemKind::Floe, SystemKind::AdvancedOffload] {
        let mut t = Table::new(
            &format!(
                "Fig 8 policy sweep — {} under lru/lfu/sparsity residency \
                 (in 64 / out 256, RTX-3090, simulated)",
                kind.name()
            ),
            &["policy", "12GB tps", "16GB tps", "24GB tps",
              "12GB hit", "16GB hit", "24GB hit"],
        );
        for residency in ResidencyKind::ALL {
            let at = |v: f64| {
                let mut system = SystemConfig::with_residency(kind, residency);
                system.sparsity_decay = sparsity_decay;
                let p = SimParams::mixtral_on(RTX3090.clone(), system, v);
                simulate(&p, 64, 256)
            };
            let (a, b, c) = (at(12.0), at(16.0), at(24.0));
            t.row(vec![
                residency.name().to_string(),
                f2(a.tps),
                f2(b.tps),
                f2(c.tps),
                f2(a.cache_hit_rate),
                f2(b.cache_hit_rate),
                f2(c.cache_hit_rate),
            ]);
            js.push(jobj(vec![
                ("system", jstr(kind.name())),
                ("policy", jstr(residency.name())),
                ("tps", jarr(vec![jnum(a.tps), jnum(b.tps), jnum(c.tps)])),
                (
                    "cache_hit",
                    jarr(vec![
                        jnum(a.cache_hit_rate),
                        jnum(b.cache_hit_rate),
                        jnum(c.cache_hit_rate),
                    ]),
                ),
            ]));
        }
        t.print();
    }
    save_json("fig8_policy_sweep", &jarr(js))
}

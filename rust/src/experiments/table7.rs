//! Table 7 analog (appendix E): quantization sensitivity per projection —
//! same sweep as Fig 3b but reported in the appendix's table form, plus
//! the compression-ratio summary behind the paper's headline 9.3x claim.

use anyhow::Result;

use crate::hwsim::MIXTRAL_8X7B;
use crate::model::Weights;
use crate::quant::fp16_bytes;
use crate::util::table::{f2, Table};

use super::{jnum, jobj, save_json};

/// Compression accounting (paper §1: 9.3x per expert; §4 memory budget).
pub fn run_compression(art_dir: &std::path::Path) -> Result<()> {
    let w = Weights::load(art_dir)?;
    let c = &w.cfg;
    let (d, f) = (c.d_model, c.d_ff);
    let fp16_full = 3 * fp16_bytes(d, f);
    let qv = w.up_q(0, 0)?;
    let up_bytes = qv.transfer_bytes();

    let mut t = Table::new(
        "Compression accounting (per expert)",
        &["config", "tiny model bytes", "ratio", "Mixtral-8x7B bytes", "ratio"],
    );
    let m = &MIXTRAL_8X7B;
    let mix_full = m.expert_bytes_fp16();
    for (name, level) in [("FloE @ 70%", 0.7), ("FloE @ 80%", 0.8), ("FloE @ 90%", 0.9)] {
        let gd = (2.0 * (1.0 - level) * (d * f) as f64 * 2.0) as usize;
        let tiny = up_bytes + gd;
        let mix = m.up_int2_bytes() + m.floe_transfer_bytes(level);
        t.row(vec![
            name.to_string(),
            tiny.to_string(),
            f2(fp16_full as f64 / tiny as f64),
            format!("{:.1} MB", mix / 1e6),
            f2(mix_full / mix),
        ]);
    }
    t.row(vec![
        "fp16 dense".to_string(),
        fp16_full.to_string(),
        "1.00".to_string(),
        format!("{:.1} MB", mix_full / 1e6),
        "1.00".to_string(),
    ]);
    t.print();

    // VRAM budget at Mixtral scale (paper: deploys in 11 GB)
    let resident_up = m.n_layers as f64 * m.n_experts as f64 * m.up_int2_bytes();
    let attn = m.n_layers as f64 * m.attn_bytes_fp16();
    let embed = 2.0 * 32000.0 * m.d_model as f64 * 2.0;
    let kv = m.n_layers as f64 * 2.0 * 2048.0 * m.d_model as f64 * 2.0;
    let cache = 2.0 * m.n_layers as f64 * m.floe_transfer_bytes(0.9);
    let total = (resident_up + attn + embed + kv + cache + 1e9) / 1e9;
    println!(
        "\nVRAM budget at Mixtral scale: INT2 up (all experts) {:.1} GB + \
         attention {:.1} GB + KV(2048) {:.1} GB + expert cache {:.1} GB + \
         1 GB workspace = {:.1} GB (paper: runs in 11 GB).",
        resident_up / 1e9,
        attn / 1e9,
        kv / 1e9,
        cache / 1e9,
        total
    );
    save_json(
        "compression",
        &jobj(vec![
            ("tiny_fp16", jnum(fp16_full as f64)),
            ("tiny_floe90", jnum((up_bytes as f64)
                + 2.0 * 0.1 * (d * f) as f64 * 2.0)),
            ("mixtral_vram_gb", jnum(total)),
        ]),
    )
}

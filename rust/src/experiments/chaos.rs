//! `exp-chaos-sweep` — deterministic fault schedules over the cluster
//! tier (DESIGN.md §12). No artifacts or `pjrt` needed.
//!
//! Sweeps fault scenario × node count × aggregate VRAM against a
//! fault-free baseline, all on the same workload trace: a mid-trace
//! cross-node NET outage window priced fail-fast and again under
//! bounded-backoff retry, a device drop that re-homes the dead
//! device's residents hottest-first, and a node drop + rejoin that
//! re-dispatches the dead node's batch and restocks the returning
//! host pool over the network.
//! Every cell reports *goodput* (tokens from requests that finished
//! clean), tail latency, and the recovery work the schedule cost —
//! retries, re-homed keys, re-dispatched requests.

use anyhow::Result;

use crate::coordinator::cluster::{
    simulate_cluster, ClusterReport, ClusterSpec, Fault,
};
use crate::store::{LinkId, RetryPolicy};
use crate::util::json::Json;
use crate::util::table::{f2, Table};
use crate::workload::TimedRequest;

use super::{cluster, serveload};
use super::{jarr, jnum, jobj, jstr, save_json};

pub const NODE_COUNTS: [usize; 2] = [2, 4];
/// Aggregate VRAM axis, as fractions of the full per-device serveload
/// budget (`DEFAULT_VRAM_GB` per device). At 1.0 every device holds a
/// real resident set worth tearing down; at 0.5 the cache budget
/// collapses to zero, every expert access demand-fetches, and the same
/// fault schedule bites much harder.
pub const VRAM_FRACTIONS: [f64; 2] = [1.0, 0.5];
/// Two devices per node so a `DeviceDown` always has a surviving peer.
pub const DEVICES_PER_NODE: usize = 2;
/// The tight host pool of the cluster sweep's failure row: re-homing
/// and rejoin restocks must move real bytes over the network link.
pub const HOST_RAM_GB: f64 = cluster::FAILURE_HOST_RAM_GB;
/// Bounded exponential backoff for the retry scenarios: 8 attempts from
/// a 10 ms base spans over 2.5 s of cumulative backoff — longer than
/// any outage window in the schedule, so retries always outlast the
/// flap and goodput is bounded by the stretch, not by errors.
pub const RETRY: RetryPolicy = RetryPolicy { max_attempts: 8, backoff_base_us: 10_000.0 };

/// Aggregate VRAM for a cell: `frac` of the full serveload per-device
/// budget across all of the cell's devices, so the per-device share is
/// independent of the node count.
pub fn vram_gb_total(n: usize, frac: f64) -> f64 {
    frac * serveload::DEFAULT_VRAM_GB * (n * DEVICES_PER_NODE) as f64
}

/// The scenario axis, in printed order. `flap` appears twice — fail-fast
/// and retried — so the retry/backoff payoff is one row-pair away.
pub const SCENARIOS: [(&str, bool); 5] = [
    ("none", false),
    ("flap", false),
    ("flap+retry", true),
    ("dev-drop", false),
    ("drop+rejoin", false),
];

/// The deterministic fault schedule for one named scenario, anchored on
/// the workload's arrival stamps so every cell stresses the middle of
/// the trace regardless of rate or length.
pub fn scenario_faults(name: &str, wl: &[TimedRequest]) -> Vec<Fault> {
    let n = wl.len();
    let q1 = wl[n / 4].arrival_us;
    let mid = wl[n / 2].arrival_us;
    let q3 = wl[(3 * n) / 4].arrival_us;
    match name {
        "none" => Vec::new(),
        // a full cross-node NET outage across the middle half of the
        // trace: with no retry policy, every demand fetch that rides
        // the network inside the window fails the request; with one,
        // it backs off and survives
        "flap" | "flap+retry" => vec![Fault::LinkDegrade {
            link: LinkId::Net,
            factor: 0.0,
            t0_us: q1 + 1.0,
            t1_us: q3 + 1.0,
        }],
        // the second device of node 0 (global index 1) drops mid-trace
        "dev-drop" => vec![Fault::DeviceDown { dev: 1, t_us: mid + 1.0 }],
        // node 1 drops mid-trace and returns before the last quarter of
        // the arrivals: its batch re-dispatches, its host pool restocks
        "drop+rejoin" => vec![
            Fault::NodeDown { node: 1, t_us: q1 + 1.0 },
            Fault::NodeRejoin { node: 1, t_us: q3 - 1.0 },
        ],
        other => panic!("unknown chaos scenario {other}"),
    }
}

/// Build the cell's spec: the named scenario's schedule over `n` nodes
/// at the given aggregate VRAM, retry armed when the scenario says so.
pub fn cell_spec(scenario: &str, retry: bool, n: usize, vram_gb: f64, wl: &[TimedRequest]) -> ClusterSpec {
    let mut spec = ClusterSpec::new(n, DEVICES_PER_NODE, vram_gb)
        .with_faults(scenario_faults(scenario, wl));
    spec.host_ram_gb = HOST_RAM_GB;
    if retry {
        spec = spec.with_retry(RETRY);
    }
    spec
}

/// Tokens from requests that finished without an error, per wall
/// second — the sweep's headline number. A fail-fast outage loses the
/// errored requests' remaining tokens; retry trades them for stall.
pub fn goodput_tps(rep: &ClusterReport) -> f64 {
    let tokens: usize = rep
        .completions()
        .filter(|(_, c)| c.error.is_none())
        .map(|(_, c)| c.tokens)
        .sum();
    tokens as f64 / (rep.total_us / 1e6).max(1e-9)
}

/// p99 of arrival→completion latency over clean completions, µs.
pub fn p99_latency_us(rep: &ClusterReport) -> f64 {
    let mut lat: Vec<f64> = rep
        .completions()
        .filter(|(_, c)| c.error.is_none())
        .map(|(_, c)| c.latency_us())
        .collect();
    if lat.is_empty() {
        return 0.0;
    }
    lat.sort_by(|a, b| a.total_cmp(b));
    lat[((lat.len() - 1) as f64 * 0.99).round() as usize]
}

pub fn run(n_requests: usize, seed: u64, rate_hz: f64, nodes: Option<usize>) -> Result<()> {
    let p = serveload::sweep_params(crate::config::ResidencyKind::Lru, serveload::DEFAULT_VRAM_GB);
    let wl = serveload::workload_at(rate_hz, n_requests, seed);
    let node_counts: Vec<usize> = nodes.map_or_else(|| NODE_COUNTS.to_vec(), |n| vec![n]);
    let mut t = Table::new(
        &format!(
            "Chaos sweep — FloE cluster, {DEVICES_PER_NODE} dev/node, host pool {HOST_RAM_GB} GB, \
             {n_requests} requests at {rate_hz} req/s (simulated)"
        ),
        &["nodes", "vram GB", "scenario", "goodput tok/s", "p99 ms",
          "retries", "rehomed", "redisp", "rejoins", "errored"],
    );
    let mut js = Vec::new();
    for &n in &node_counts {
        for &frac in &VRAM_FRACTIONS {
            let vram_gb = vram_gb_total(n, frac);
            for &(scenario, retry) in &SCENARIOS {
                let spec = cell_spec(scenario, retry, n, vram_gb, &wl);
                let rep = simulate_cluster(&p, &spec, &wl)?;
                t.row(row_cells(n, vram_gb, scenario, &rep));
                js.push(cell_json(n, vram_gb, scenario, retry, &rep));
            }
        }
    }
    t.print();
    println!(
        "\nevery schedule is deterministic on the cluster clock: the same \
         seed and schedule reproduce these rows bit-exactly. The flap \
         row-pair prices bounded-backoff retry against fail-fast on the \
         same outage window; dev-drop re-homes the dead device's experts \
         hottest-first; drop+rejoin re-dispatches the dead node's batch \
         to survivors and restocks the returning node over the network \
         — zero errored requests whenever a survivor exists."
    );
    save_json("chaos_sweep", &jarr(js))
}

fn row_cells(n: usize, vram_gb: f64, scenario: &str, rep: &ClusterReport) -> Vec<String> {
    vec![
        format!("{n}"),
        f2(vram_gb),
        scenario.to_string(),
        f2(goodput_tps(rep)),
        f2(p99_latency_us(rep) / 1e3),
        format!("{}", rep.retries()),
        format!("{}", rep.rehomed_keys + rep.dev_moved_keys),
        format!("{}", rep.redispatched),
        format!("{}", rep.rejoins),
        format!("{}", rep.errored),
    ]
}

fn cell_json(n: usize, vram_gb: f64, scenario: &str, retry: bool, rep: &ClusterReport) -> Json {
    jobj(vec![
        ("nodes", jnum(n as f64)),
        ("vram_gb_total", jnum(vram_gb)),
        ("scenario", jstr(scenario)),
        ("retry", Json::Bool(retry)),
        ("goodput_tps", jnum(goodput_tps(rep))),
        ("aggregate_tps", jnum(rep.aggregate_tps())),
        ("p99_latency_us", jnum(p99_latency_us(rep))),
        ("retries", jnum(rep.retries() as f64)),
        ("rehomed_keys", jnum(rep.rehomed_keys as f64)),
        ("dev_moved_keys", jnum(rep.dev_moved_keys as f64)),
        ("dev_dropped_keys", jnum(rep.dev_dropped_keys as f64)),
        ("redispatched", jnum(rep.redispatched as f64)),
        ("rejoins", jnum(rep.rejoins as f64)),
        ("errored", jnum(rep.errored as f64)),
        ("total_us", jnum(rep.total_us)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The CI smoke leg's cell: every scenario at 2 nodes, full budget —
    /// exactly-once retirement and zero errors whenever the scenario
    /// leaves a survivor (every scenario here does).
    #[test]
    fn sweep_smoke_cell_loses_no_request_under_any_scenario() {
        let p = serveload::sweep_params(
            crate::config::ResidencyKind::Lru,
            serveload::DEFAULT_VRAM_GB,
        );
        let wl = serveload::workload_at(8.0, 12, 7);
        for &(scenario, retry) in &SCENARIOS {
            if scenario == "flap" {
                // fail-fast on a full outage is *allowed* to error —
                // priced by the margin test below, not a loss bug
                continue;
            }
            let spec = cell_spec(scenario, retry, 2, vram_gb_total(2, 1.0), &wl);
            let rep = simulate_cluster(&p, &spec, &wl).unwrap();
            assert_eq!(rep.errored, 0, "{scenario}: errored with survivors present");
            let mut ids: Vec<u64> = rep.completions().map(|(_, c)| c.id).collect();
            ids.sort();
            assert_eq!(
                ids,
                (0..wl.len() as u64).collect::<Vec<_>>(),
                "{scenario}: every request must retire exactly once"
            );
            if scenario == "drop+rejoin" {
                assert_eq!(rep.rejoins, 1, "rejoin must have fired");
                assert!(rep.redispatched > 0 || rep.rehomed_keys > 0, "drop did nothing");
                // the rejoined node re-enters placement: it must retire
                // at least one completion after its rejoin stamp
                let t_rejoin = wl[(3 * wl.len()) / 4].arrival_us - 1.0;
                assert!(
                    rep.completions().any(|(n, c)| n == 1 && c.finished_us >= t_rejoin),
                    "rejoined node served nothing after rejoin"
                );
            }
            if scenario == "dev-drop" {
                assert!(
                    rep.dev_moved_keys + rep.dev_dropped_keys > 0,
                    "device drop tore down nothing"
                );
            }
        }
    }

    /// The acceptance margin: at the pinned link-flap cell — the
    /// thin-cache point, where every expert access demand-fetches and
    /// anything past the host pool rides the flapping NET link —
    /// bounded backoff beats fail-fast on goodput by >= 1.10x (the
    /// Python mirror pins the same point), and the retries that bought
    /// it are visible in the ledger.
    #[test]
    fn retry_goodput_beats_fail_fast_at_the_pinned_flap_cell() {
        let p = serveload::sweep_params(
            crate::config::ResidencyKind::Lru,
            serveload::DEFAULT_VRAM_GB,
        );
        let wl = serveload::workload_at(8.0, 16, 7);
        let fail_fast = simulate_cluster(
            &p,
            &cell_spec("flap", false, 2, vram_gb_total(2, 0.5), &wl),
            &wl,
        )
        .unwrap();
        let retried = simulate_cluster(
            &p,
            &cell_spec("flap+retry", true, 2, vram_gb_total(2, 0.5), &wl),
            &wl,
        )
        .unwrap();
        assert!(fail_fast.errored > 0, "the outage window never bit — move the window");
        assert_eq!(retried.errored, 0, "retry must outlast the outage window");
        assert!(retried.retries() > 0, "retry scenario must record its retries");
        assert_eq!(fail_fast.retries(), 0, "fail-fast must not retry");
        let (g_ff, g_r) = (goodput_tps(&fail_fast), goodput_tps(&retried));
        assert!(
            g_r >= 1.10 * g_ff,
            "retry goodput {g_r:.2} tok/s < 1.10x fail-fast {g_ff:.2} tok/s"
        );
    }
}

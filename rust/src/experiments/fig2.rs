//! Fig 2 analog: activation distributions inside experts (gate / up /
//! down) at shallow, middle and deep layers, from the calibration traces.
//! The paper's observation — activations concentrate around zero, which is
//! what magnitude sparsification exploits — is summarized as the fraction
//! of mass in the central bins plus distribution moments.

use anyhow::{Context, Result};

use crate::model::Weights;
use crate::util::json::Json;
use crate::util::table::{f3, pct, Table};

use super::{jnum, jobj, save_json};

pub fn run(art_dir: &std::path::Path) -> Result<()> {
    let w = Weights::load(art_dir)?;
    let h = w
        .manifest
        .get("analysis")
        .and_then(|a| a.get("fig2_histograms"))
        .context("manifest analysis.fig2_histograms")?;
    let edges: Vec<f64> = h.get("edges").and_then(Json::as_f64_vec).context("edges")?;
    let layers = h.get("layers").and_then(Json::as_obj).context("layers")?;

    let mut t = Table::new(
        "Fig 2 — activation distributions (per layer, most-visited expert)",
        &["layer", "expert", "proj", "frac |a|<0.1", "frac |a|<0.25", "std"],
    );
    let mut out_rows = Vec::new();
    for (layer, entry) in layers {
        let e = entry.get("expert").and_then(Json::as_usize).unwrap_or(0);
        for proj in ["a_gate", "a_up", "a_down"] {
            let counts: Vec<f64> = entry
                .get(proj)
                .and_then(Json::as_f64_vec)
                .context("hist counts")?;
            let total: f64 = counts.iter().sum();
            let centers: Vec<f64> = edges
                .windows(2)
                .map(|w| 0.5 * (w[0] + w[1]))
                .collect();
            let frac = |lim: f64| -> f64 {
                centers
                    .iter()
                    .zip(&counts)
                    .filter(|(c, _)| c.abs() < lim)
                    .map(|(_, n)| *n)
                    .sum::<f64>()
                    / total
            };
            let mean: f64 =
                centers.iter().zip(&counts).map(|(c, n)| c * n).sum::<f64>() / total;
            let var: f64 = centers
                .iter()
                .zip(&counts)
                .map(|(c, n)| (c - mean) * (c - mean) * n)
                .sum::<f64>()
                / total;
            t.row(vec![
                layer.clone(),
                e.to_string(),
                proj.trim_start_matches("a_").to_string(),
                pct(frac(0.1)),
                pct(frac(0.25)),
                f3(var.sqrt()),
            ]);
            out_rows.push(jobj(vec![
                ("layer", super::jstr(layer)),
                ("proj", super::jstr(proj)),
                ("frac_lt_0.1", jnum(frac(0.1))),
                ("std", jnum(var.sqrt())),
            ]));
        }
    }
    t.print();
    println!(
        "\npaper: activations concentrate near zero across shallow/middle/deep \
         layers, motivating magnitude sparsification (Observation 1)."
    );
    save_json("fig2", &super::jarr(out_rows))
}

//! `exp-serve-load` — batched-serving throughput/latency sweep over the
//! simulated coordinator (DESIGN.md §6). No artifacts or `pjrt` needed.
//!
//! Sweeps arrival rate × continuous-batching cap over a deterministic
//! workload trace (`workload::generate`) on a *skewed* routing model
//! (hot experts dominate): once concurrent requests share one
//! ExpertStore, batching multiplies expert reuse per transferred byte and
//! amortizes boundary weight reads, so aggregate tokens/s rises with the
//! cap while per-request queue wait records the cost. Per-request stall
//! attribution (demand-fetch vs prefetch-miss) comes from the store's
//! ledger and sums exactly to its global stall counters (asserted by the
//! scheduler property tests).

use anyhow::Result;

use crate::config::{ResidencyKind, ShardPolicy};
use crate::coordinator::policy::{SystemConfig, SystemKind};
use crate::coordinator::sim::{simulate_serving, RoutingModel, ServeSimReport, SimParams};
use crate::hwsim::RTX3090;
use crate::util::table::{f2, Table};
use crate::workload::{generate, WorkloadSpec};

use super::{jarr, jnum, jobj, jstr, save_json};

pub const ARRIVAL_HZ: [f64; 3] = [2.0, 4.0, 8.0];
pub const BATCH_CAPS: [usize; 4] = [1, 2, 4, 8];

/// The sweep's default VRAM budget: evictions — and so stall
/// attribution — stay active, but the batch's joint working set still
/// fits. Tighter budgets (e.g. `--vram 13`) expose the LRU-thrash cliff
/// at high caps; looser ones cache everything and show pure
/// boundary-reuse gains.
pub const DEFAULT_VRAM_GB: f64 = 14.25;

/// The sweep's simulated system: FloE with a skewed, sticky routing
/// trace (hot experts dominate, so concurrent sequences share residency).
pub fn sweep_params(residency: ResidencyKind, vram_gb: f64) -> SimParams {
    let mut p = SimParams::mixtral_on(
        RTX3090.clone(),
        SystemConfig::with_residency(SystemKind::Floe, residency),
        vram_gb,
    );
    p.routing = RoutingModel { zipf_s: 1.2, stickiness: 0.5, seed: 7 };
    p
}

/// The sweep's workload shape at `rate_hz` (also the operating point the
/// scheduler/serving tests validate, so retuning it retunes them too).
pub fn workload_at(
    rate_hz: f64,
    n_requests: usize,
    seed: u64,
) -> Vec<crate::workload::TimedRequest> {
    generate(&WorkloadSpec {
        n_requests,
        arrival_rate_hz: rate_hz,
        prompt_len: (8, 24),
        output_tokens: (16, 48),
        seed,
        slo_us: None,
    })
}

#[allow(clippy::too_many_arguments)]
pub fn run(
    residency: ResidencyKind,
    n_requests: usize,
    seed: u64,
    vram_gb: f64,
    devices: usize,
    shard: ShardPolicy,
    sparsity_decay: f64,
    overlap: bool,
) -> Result<()> {
    let mut p = sweep_params(residency, vram_gb);
    p.system = p.system.clone().with_devices(devices, shard).with_overlap(overlap);
    p.system.sparsity_decay = sparsity_decay;
    let sharded_note = if devices > 1 {
        format!(" x {devices} devices ({})", shard.name())
    } else {
        String::new()
    };
    let overlap_note = if overlap { ", overlap" } else { "" };
    let mut t = Table::new(
        &format!(
            "Serve-load sweep — FloE, RTX-3090, {vram_gb} GB{sharded_note}\
             {overlap_note}, skewed routing, {n_requests} requests, {} residency \
             (simulated)",
            residency.name()
        ),
        &["rate req/s", "batch cap", "agg tok/s", "mean wait ms",
          "p95 latency ms", "stall demand ms", "stall prefetch ms", "peak batch"],
    );
    let mut js = Vec::new();
    for &rate in &ARRIVAL_HZ {
        let wl = workload_at(rate, n_requests, seed);
        for &cap in &BATCH_CAPS {
            let rep = simulate_serving(&p, &wl, cap)?;
            t.row(row_cells(rate, cap, &rep));
            js.push(jobj(vec![
                ("rate_hz", jnum(rate)),
                ("batch_cap", jnum(cap as f64)),
                ("policy", jstr(residency.name())),
                ("overlap", jnum(overlap as usize as f64)),
                ("aggregate_tps", jnum(rep.aggregate_tps())),
                ("mean_queue_wait_us", jnum(rep.mean_queue_wait_us())),
                ("p95_latency_us", jnum(rep.p95_latency_us())),
                ("stall_demand_us", jnum(rep.stats.stall_demand_us)),
                ("stall_prefetch_us", jnum(rep.stats.stall_prefetch_us)),
                ("total_us", jnum(rep.total_us)),
                // demand-stall share of the cell's wall clock — the
                // inspector's span semantics (timeline::inspect_parts)
                ("demand_stall_share", jnum(rep.stats.stall_demand_us / rep.total_us.max(1e-9))),
                ("max_batch_seen", jnum(rep.max_batch_seen as f64)),
                ("cache_hit_rate", jnum(rep.cache_hit_rate)),
            ]));
        }
    }
    t.print();
    println!(
        "\nbatching multiplies expert reuse per transferred byte (shared \
         residency + amortized boundary weight reads), so aggregate tok/s \
         rises with the cap while queue wait records the admission cost; \
         per-request stalls decompose demand-fetch vs prefetch-miss and \
         sum exactly to the store's global counters."
    );
    save_json("serve_load", &jarr(js))
}

fn row_cells(rate: f64, cap: usize, rep: &ServeSimReport) -> Vec<String> {
    vec![
        format!("{rate:.0}"),
        format!("{cap}"),
        f2(rep.aggregate_tps()),
        f2(rep.mean_queue_wait_us() / 1e3),
        f2(rep.p95_latency_us() / 1e3),
        f2(rep.stats.stall_demand_us / 1e3),
        f2(rep.stats.stall_prefetch_us / 1e3),
        format!("{}", rep.max_batch_seen),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_throughput_rises_with_cap_at_high_load() {
        // the experiment's headline shape at its own operating point
        let p = sweep_params(ResidencyKind::Lru, DEFAULT_VRAM_GB);
        let wl = workload_at(8.0, 12, 7);
        let tps1 = simulate_serving(&p, &wl, 1).unwrap().aggregate_tps();
        let tps8 = simulate_serving(&p, &wl, 8).unwrap().aggregate_tps();
        assert!(tps8 > tps1, "cap8 {tps8} <= cap1 {tps1}");
    }
}

//! `exp-cluster-sweep` — multi-node serving over the cluster tier
//! (DESIGN.md §10). No artifacts or `pjrt` needed.
//!
//! Sweeps nodes × devices/node × placement at a *fixed aggregate* VRAM
//! budget: the cluster splits one expert-cache budget evenly across all
//! devices, so every cell answers the same question — does spreading the
//! same silicon over more admission queues buy throughput once requests
//! stop contending for one scheduler? A final scenario row injects a
//! mid-session node failure under a deliberately tight host-RAM pool:
//! survivors re-home the dead node's experts over the latency-dominated
//! network link, and the row records the error completions, re-homed
//! keys, and net traffic the recovery cost.

use anyhow::Result;

use crate::coordinator::cluster::{simulate_cluster, ClusterPlacement, ClusterReport, ClusterSpec};
use crate::util::json::Json;
use crate::util::table::{f2, Table};

use super::serveload;
use super::{jarr, jnum, jobj, jstr, save_json};

pub const NODE_COUNTS: [usize; 3] = [1, 2, 4];
pub const DEVICES_PER_NODE: [usize; 2] = [1, 2];

/// The sweep's fixed aggregate expert-cache budget: twice the serve-load
/// per-device default, so the 1-node × 1-device baseline is cache-rich
/// and every multi-node cell must win on scheduling, not on extra VRAM.
pub const AGGREGATE_VRAM_GB: f64 = 2.0 * serveload::DEFAULT_VRAM_GB;

/// Host-RAM pool for the failure scenario row: small enough that no node
/// holds the full expert roster, so re-homing (and steady-state misses)
/// must pull real bytes over the network link.
pub const FAILURE_HOST_RAM_GB: f64 = 4.0;

/// Batch cap per node coordinator (the serve-load corpus cap).
pub const BATCH_CAP: usize = 4;

pub fn run(
    n_requests: usize,
    seed: u64,
    rate_hz: f64,
    vram_gb_total: f64,
    nodes: Option<usize>,
    devices: Option<usize>,
) -> Result<()> {
    let p = serveload::sweep_params(crate::config::ResidencyKind::Lru, serveload::DEFAULT_VRAM_GB);
    let wl = serveload::workload_at(rate_hz, n_requests, seed);
    let node_counts: Vec<usize> = nodes.map_or_else(|| NODE_COUNTS.to_vec(), |n| vec![n]);
    let dev_counts: Vec<usize> = devices.map_or_else(|| DEVICES_PER_NODE.to_vec(), |d| vec![d]);
    let mut t = Table::new(
        &format!(
            "Cluster sweep — FloE, RTX-3090, {vram_gb_total} GB aggregate, cap {BATCH_CAP}, \
             {n_requests} requests at {rate_hz} req/s (simulated)"
        ),
        &["nodes", "dev/node", "placement", "agg tok/s", "mean wait ms",
          "net pulls", "net MB", "errored", "total ms"],
    );
    let mut js = Vec::new();
    for &n in &node_counts {
        for &d in &dev_counts {
            // one node has one target: placement cannot matter, so only
            // the baseline row is printed for it
            let placements: &[ClusterPlacement] =
                if n == 1 { &[ClusterPlacement::RoundRobin] } else { &ClusterPlacement::ALL };
            for &pl in placements {
                let spec = ClusterSpec::new(n, d, vram_gb_total).with_placement(pl);
                let rep = simulate_cluster(&p, &spec, &wl)?;
                t.row(row_cells(n, d, pl.name(), &rep));
                js.push(cell_json(n, d, pl.name(), "none", &rep));
            }
        }
    }
    // the failure scenario: the smallest multi-node cell of the sweep,
    // node 1 dropped after the mid-trace arrival, tight host RAM
    let fail_nodes = node_counts.iter().copied().find(|&n| n >= 2);
    if let Some(n) = fail_nodes {
        let d = dev_counts[0];
        let t_fail = wl[wl.len() / 2].arrival_us + 1.0;
        let mut spec = ClusterSpec::new(n, d, vram_gb_total).with_failure(1, t_fail);
        spec.host_ram_gb = FAILURE_HOST_RAM_GB;
        let rep = simulate_cluster(&p, &spec, &wl)?;
        t.row(row_cells(n, d, "rr+node-down", &rep));
        js.push(cell_json(n, d, "round-robin", "node1-down", &rep));
    }
    t.print();
    println!(
        "\nat fixed aggregate VRAM, extra nodes split the admission queue \
         (less head-of-line blocking) while each keeps a working cache \
         slice; cross-node pulls ride the latency-dominated network link, \
         and the failure row prices re-homing a dead node's experts from \
         survivors' host pools."
    );
    save_json("cluster_sweep", &jarr(js))
}

fn row_cells(n: usize, d: usize, placement: &str, rep: &ClusterReport) -> Vec<String> {
    let waits: Vec<f64> = rep.completions().map(|(_, c)| c.queue_wait_us).collect();
    let mean_wait = waits.iter().sum::<f64>() / waits.len().max(1) as f64;
    vec![
        format!("{n}"),
        format!("{d}"),
        placement.to_string(),
        f2(rep.aggregate_tps()),
        f2(mean_wait / 1e3),
        format!("{}", rep.net_pulls()),
        f2(rep.net_bytes() / 1e6),
        format!("{}", rep.errored),
        f2(rep.total_us / 1e3),
    ]
}

fn cell_json(n: usize, d: usize, placement: &str, scenario: &str, rep: &ClusterReport) -> Json {
    jobj(vec![
        ("nodes", jnum(n as f64)),
        ("devices_per_node", jnum(d as f64)),
        ("placement", jstr(placement)),
        ("scenario", jstr(scenario)),
        ("aggregate_tps", jnum(rep.aggregate_tps())),
        ("total_us", jnum(rep.total_us)),
        ("total_tokens", jnum(rep.total_tokens() as f64)),
        ("net_pulls", jnum(rep.net_pulls() as f64)),
        ("net_bytes", jnum(rep.net_bytes())),
        ("errored", jnum(rep.errored as f64)),
        ("rehomed_keys", jnum(rep.rehomed_keys as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_smoke_cell_runs_and_balances() {
        // the CI smoke leg's cell: 2 nodes x 2 devices, all placements
        let p = serveload::sweep_params(
            crate::config::ResidencyKind::Lru,
            serveload::DEFAULT_VRAM_GB,
        );
        let wl = serveload::workload_at(8.0, 8, 7);
        for pl in ClusterPlacement::ALL {
            let spec = ClusterSpec::new(2, 2, AGGREGATE_VRAM_GB).with_placement(pl);
            let rep = simulate_cluster(&p, &spec, &wl).unwrap();
            assert!(rep.total_tokens() > 0, "{}: no tokens", pl.name());
            assert_eq!(rep.errored, 0, "{}: errored without a failure", pl.name());
            let served: usize = rep.nodes.iter().map(|n| n.completions.len()).sum();
            assert_eq!(served, wl.len(), "{}: lost requests", pl.name());
        }
    }
}

//! Table 1: single-expert sparse-GEMV latency across sparsity levels and
//! GPUs. Two parts:
//!   (a) hwsim roofline projection at Mixtral-8x7B scale for the paper's
//!       four GPUs (ratio reproduction);
//!   (b) *measured* native Rust sparse GEMV on this machine's CPU over the
//!       in-repo expert weights — a real wall-clock speedup-vs-sparsity
//!       curve validating the kernel's skipping structure.

use anyhow::Result;

use crate::hwsim::{ALL_GPUS, MIXTRAL_8X7B};
use crate::model::Weights;
use crate::util::rng::Rng;
use crate::util::table::{f3, Table};
use crate::util::timing::{bench_budget, black_box};

use super::{jarr, jnum, jobj, jstr, save_json};

pub const SPARSITIES: [f64; 6] = [0.0, 0.5, 0.6, 0.7, 0.8, 0.9];

pub fn run(art_dir: &std::path::Path) -> Result<()> {
    // ---- (a) roofline projection, Mixtral scale ----
    let m = &MIXTRAL_8X7B;
    let mut t = Table::new(
        "Table 1a — single-expert sparse-GEMV latency, Mixtral scale (ms, modeled)",
        &["GPU", "0%", "50%", "60%", "70%", "80%", "90%"],
    );
    let mut js = Vec::new();
    for gpu in ALL_GPUS {
        let dense = gpu.expert_dense_us(m) / 1e3;
        let mut cells = vec![gpu.name.to_string(), f3(dense)];
        let mut vals = vec![dense];
        for s in &SPARSITIES[1..] {
            let us = gpu.expert_sparse_us(m, *s) / 1e3;
            cells.push(format!("{} ({:.2}x)", f3(us), dense / us));
            vals.push(us);
        }
        t.row(cells);
        js.push(jobj(vec![
            ("gpu", jstr(gpu.name)),
            ("ms", jarr(vals.into_iter().map(jnum).collect())),
        ]));
    }
    t.print();
    println!(
        "\npaper Table 1: >1.26x at 50%, >1.44x at 70%, ~2x at 90% on \
         consumer GPUs; H100/A100 saturate earlier on launch overhead."
    );

    // ---- (b) measured native sparse GEMV on this CPU ----
    let w = Weights::load(art_dir)?;
    let ew = w.expert_native(0, 0)?;
    let d = w.cfg.d_model;
    let mut rng = Rng::new(11);
    let mut x = vec![0.0f32; d];
    rng.fill_normal_f32(&mut x, 1.0);
    let mut y = vec![0.0f32; d];

    let mut t2 = Table::new(
        "Table 1b — measured native sparse GEMV (this CPU, tiny expert, us)",
        &["sparsity", "latency us", "speedup", "active channels"],
    );
    // thresholds from the calibrated table; 0% = dense
    let mut dense_us = 0.0;
    for (i, s) in SPARSITIES.iter().enumerate() {
        let thr = if *s == 0.0 {
            0.0
        } else {
            w.threshold("up", 0, 0, *s)?
        };
        let stats = bench_budget(20, 60, || {
            black_box(ew.forward_sparse(&x, thr, &mut y));
        });
        let active = ew.forward_sparse(&x, thr, &mut y);
        if i == 0 {
            dense_us = stats.p50_us();
        }
        t2.row(vec![
            format!("{:.0}%", s * 100.0),
            format!("{:.2}", stats.p50_us()),
            format!("{:.2}x", dense_us / stats.p50_us()),
            active.to_string(),
        ]);
        js.push(jobj(vec![
            ("sparsity", jnum(*s)),
            ("measured_us", jnum(stats.p50_us())),
            ("speedup", jnum(dense_us / stats.p50_us())),
        ]));
    }
    t2.print();
    save_json("table1", &jarr(js))
}

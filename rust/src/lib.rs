//! FloE: On-the-Fly MoE Inference on Memory-constrained GPUs (ICML 2025).
//!
//! Three-layer reproduction: Rust coordinator (this crate) + JAX model +
//! Pallas kernels, AOT-compiled to HLO text and executed via PJRT.
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod config;
pub mod coordinator;
pub mod engine;
pub mod evalsuite;
pub mod experiments;
pub mod hwsim;
pub mod model;
pub mod predictor;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod sparsity;
pub mod store;
pub mod tensor;
pub mod transfer;
pub mod util;
pub mod workload;

use std::path::PathBuf;

/// Default artifacts directory: `$FLOE_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("FLOE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

//! FloE: On-the-Fly MoE Inference on Memory-constrained GPUs (ICML 2025).
//!
//! Three-layer reproduction: Rust coordinator (this crate) + JAX model +
//! Pallas kernels, AOT-compiled to HLO text and executed via PJRT.
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

// Clippy posture (CI runs `cargo clippy -- -D warnings` on both feature
// configurations): correctness/suspicious lints are enforced; the style
// rewrites below are opted out because the numeric kernels and roofline
// models index several parallel arrays in lockstep, where the iterator
// form obscures the math being transcribed from the paper.
#![allow(
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    clippy::ptr_arg,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if
)]

pub mod config;
pub mod coordinator;
pub mod engine;
pub mod evalsuite;
pub mod experiments;
pub mod hwsim;
pub mod model;
pub mod predictor;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod sparsity;
pub mod store;
pub mod tensor;
pub mod transfer;
pub mod util;
pub mod workload;

use std::path::PathBuf;

/// Default artifacts directory: `$FLOE_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("FLOE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

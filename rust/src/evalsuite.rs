//! Efficacy evaluation through the Rust engine (paper §4.2): held-out
//! perplexity (nats/byte — the repo's WikiText-2 analog) and exact-match
//! accuracy on the four seeded probe tasks (the downstream-task analog).
//! Both consume artifacts exported at build time (eval.txt, probes.json),
//! so Python and Rust evaluate identical data.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::config::ExpertMode;
use crate::engine::{sampler, DecodeState, Engine, NoObserver};
use crate::util::json::{parse, Json};

pub struct EvalData {
    pub eval_bytes: Vec<u8>,
    /// task -> [(prompt, completion)]
    pub probes: Vec<(String, Vec<(String, String)>)>,
}

impl EvalData {
    pub fn load(art_dir: &Path) -> Result<Self> {
        let eval_bytes = std::fs::read(art_dir.join("eval.txt"))
            .context("artifacts/eval.txt (re-run `make artifacts`)")?;
        let text = std::fs::read_to_string(art_dir.join("probes.json"))
            .context("artifacts/probes.json")?;
        let j = parse(&text).map_err(|e| anyhow!("probes.json: {e}"))?;
        let mut probes = Vec::new();
        for (task, arr) in j.as_obj().context("probes obj")? {
            let mut insts = Vec::new();
            for inst in arr.as_arr().context("task arr")? {
                let p = inst.idx(0).and_then(Json::as_str).context("prompt")?;
                let c = inst.idx(1).and_then(Json::as_str).context("completion")?;
                insts.push((p.to_string(), c.to_string()));
            }
            probes.push((task.clone(), insts));
        }
        Ok(EvalData { eval_bytes, probes })
    }
}

/// Held-out next-byte NLL in nats/byte under `mode`.
///
/// Evaluates `n_bytes` of eval text in fresh-state windows of `window`
/// bytes, skipping the first `burn_in` positions of each window.
pub fn perplexity(
    engine: &mut Engine,
    data: &EvalData,
    mode: ExpertMode,
    n_bytes: usize,
    window: usize,
    burn_in: usize,
) -> Result<f64> {
    let bytes = &data.eval_bytes[..n_bytes.min(data.eval_bytes.len())];
    let mut total = 0.0f64;
    let mut count = 0usize;
    let mut start = 0;
    while start + burn_in + 2 < bytes.len() {
        let end = (start + window).min(bytes.len());
        let chunk = &bytes[start..end];
        let mut st = DecodeState::new(&engine.w)?;
        for i in 0..chunk.len() - 1 {
            let logits = engine.decode_token(&mut st, chunk[i], mode, &mut NoObserver)?;
            if i >= burn_in {
                total += sampler::nll(&logits, chunk[i + 1]);
                count += 1;
            }
        }
        start = end;
    }
    anyhow::ensure!(count > 0, "no eval positions");
    Ok(total / count as f64)
}

#[derive(Debug, Clone)]
pub struct ProbeScore {
    pub task: String,
    pub correct: usize,
    pub total: usize,
}

impl ProbeScore {
    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.total.max(1) as f64
    }
}

/// Exact-match accuracy of greedy completions on each probe task.
pub fn probe_accuracy(
    engine: &mut Engine,
    data: &EvalData,
    mode: ExpertMode,
    max_instances: usize,
) -> Result<Vec<ProbeScore>> {
    let mut out = Vec::new();
    for (task, insts) in &data.probes {
        let mut correct = 0;
        let n = insts.len().min(max_instances);
        for (prompt, completion) in insts.iter().take(n) {
            let gen = engine.generate(
                prompt.as_bytes(),
                completion.len(),
                mode,
                0.0,
                0,
                &mut NoObserver,
            )?;
            if gen == completion.as_bytes() {
                correct += 1;
            }
        }
        out.push(ProbeScore { task: task.clone(), correct, total: n });
    }
    Ok(out)
}

/// Mean accuracy across probe tasks (the paper's "average" column).
pub fn mean_accuracy(scores: &[ProbeScore]) -> f64 {
    if scores.is_empty() {
        return 0.0;
    }
    scores.iter().map(|s| s.accuracy()).sum::<f64>() / scores.len() as f64
}

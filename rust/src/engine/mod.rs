//! Decode engine: drives the AOT-compiled HLO graphs (attention step,
//! expert variants, logits head) token by token, with expert selection and
//! combination on the host — the computation Fig 1(c) places on the GPU.
//!
//! The engine is *pure compute*: which expert weights are "VRAM-resident",
//! what transfers cost, and when prefetches are issued are the
//! coordinator's concern (coordinator/). An observer hook exposes each
//! layer's hidden state + routing so the coordinator can drive the dual
//! predictors and the simulated clock without touching the math.
//!
//! The hot path is **boundary-synchronous batched decode**
//! (`decode_batch`, DESIGN.md §7): N sequences step through each layer in
//! lockstep, and at every MoE boundary the routed (sequence, expert)
//! pairs are grouped by expert so each activated expert is visited once —
//! the native path runs the register-blocked multi-row kernel
//! (`NativeExpert::forward_rows`; `tensor::gemm_channel_major` and
//! `forward_sparse_batch` are its public rule-free/Rule-Up mirrors, which
//! the bench measures for calibration), the HLO path resolves weight
//! buffers and the threshold argument once per group and uploads each
//! sequence's activation row once per boundary. `decode_token` is literally a batch of one, so there is no
//! sibling sequential implementation to drift from, and a batch of N is
//! bit-identical to N solo decodes (pinned by tests/batch_decode.rs).
//!
//! Perf notes (EXPERIMENTS.md §Perf): all weight tensors are uploaded to
//! device buffers once at load and executions run through `execute_b`
//! (the literal-argument `execute` path in the xla crate leaks its
//! internally created input buffers). KV caches are device-resident
//! across steps: `DecodeState` holds per-layer buffers and each step's
//! attention-output cache literals re-enter device buffers directly —
//! no host `Vec` materialization, no per-layer re-upload of host caches.
//! (The residual per-step cost is the output-tuple download `exec_b`
//! forces; the binding returns one tuple literal per execution.)
//! Sparsity-threshold scalars are uploaded once per (layer, expert,
//! level) and served from a buffer cache thereafter.

pub mod compress;
pub mod pool;
pub mod sampler;

use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::config::ExpertMode;
use crate::model::Weights;
use crate::runtime::{to_vec_f32, PjRtBuffer, Runtime};
use crate::tensor::{axpy, softmax_inplace, top_k};

/// Which compiled graph family executes the expert math.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ComputePath {
    /// plain-jnp lowered graphs (XLA-fused; the default hot path)
    Hlo,
    /// the L1 Pallas kernels lowered into HLO (validation + comparison)
    HloPallas,
    /// native Rust expert math (baseline sweeps + Fiddler CPU path)
    Native,
}

/// One layer's device-resident KV cache pair.
struct KvLayer {
    kc: PjRtBuffer,
    vc: PjRtBuffer,
}

/// Per-request decode state. KV caches live as per-layer *device buffers*
/// persisted across steps: the engine uploads zeroed caches on first use,
/// and each step's attention outputs re-enter device buffers without
/// round-tripping through host vectors.
pub struct DecodeState {
    pub x: Vec<f32>,
    pub pos: usize,
    kv_dims: [usize; 4],
    n_layers: usize,
    /// None until the engine's first step uploads the zero caches
    kv: Option<Vec<KvLayer>>,
}

impl DecodeState {
    pub fn new(w: &Weights) -> Result<Self> {
        let c = &w.cfg;
        Ok(DecodeState {
            x: vec![0.0; c.d_model],
            pos: 0,
            kv_dims: [1, c.n_heads, c.max_seq, c.head_dim],
            n_layers: c.n_layers,
            kv: None,
        })
    }
}

/// Layer-step information surfaced to the coordinator.
pub struct LayerEvent<'a> {
    pub layer: usize,
    /// index of the owning sequence within the decode batch (always 0
    /// for single-sequence decode) — serving maps it to a request id for
    /// stall attribution
    pub seq: usize,
    /// hidden state entering the MoE block (router/up-projection input)
    pub h_mid: &'a [f32],
    /// (expert, weight) pairs actually routed to
    pub routed: &'a [(usize, f32)],
}

pub trait StepObserver {
    fn on_layer(&mut self, ev: &LayerEvent<'_>);
}

/// No-op observer for plain generation.
pub struct NoObserver;
impl StepObserver for NoObserver {
    fn on_layer(&mut self, _ev: &LayerEvent<'_>) {}
}

/// Boundary-synchronous decode instrumentation: how much same-boundary
/// grouping actually shares. `group_visits` is the number of expert
/// weight-argument resolutions / kernel groups executed — it equals the
/// number of *distinct* routed experts per boundary, while `pair_visits`
/// counts routed (sequence, expert) pairs; the gap is the shared work.
#[derive(Debug, Default, Clone)]
pub struct BatchStats {
    /// MoE boundaries executed (token steps × layers)
    pub boundaries: u64,
    /// routed (sequence, expert) pairs
    pub pair_visits: u64,
    /// expert groups executed (distinct experts per boundary)
    pub group_visits: u64,
    /// threshold scalars uploaded cold
    pub threshold_uploads: u64,
    /// threshold arguments served from the buffer cache
    pub threshold_hits: u64,
}

/// Group one boundary's routed (sequence, slot) pairs by expert id.
/// BTreeMap keeps execution order deterministic (ascending expert);
/// grouping only reorders *scheduling* — each pair's math reads its own
/// activation row alone, so values cannot depend on group order.
pub(crate) fn group_by_expert(
    routed: &[Vec<(usize, f32)>],
) -> BTreeMap<usize, Vec<(usize, usize)>> {
    let mut groups: BTreeMap<usize, Vec<(usize, usize)>> = BTreeMap::new();
    for (seq, r) in routed.iter().enumerate() {
        for (slot, &(e, _)) in r.iter().enumerate() {
            groups.entry(e).or_default().push((seq, slot));
        }
    }
    groups
}

/// Threshold-cache key quantization (matches `compress::mode_key`).
fn thr_key(layer: usize, expert: usize, level: f64) -> (usize, usize, u32) {
    (layer, expert, (level * 1000.0).round() as u32)
}

/// Borrow a named weight buffer out of the upload map (free function so
/// callers can hold the reference while other engine fields are in use).
fn buf_in<'a>(
    bufs: &'a HashMap<String, PjRtBuffer>,
    name: &str,
) -> Result<&'a PjRtBuffer> {
    bufs.get(name)
        .ok_or_else(|| anyhow!("no buffer for tensor {name}"))
}

pub struct Engine {
    pub rt: Runtime,
    pub w: Arc<Weights>,
    /// all weight tensors uploaded once as device buffers. The xla
    /// crate's literal-argument `execute` leaks its internally created
    /// input buffers (~arg bytes per call); `execute_b` over pre-uploaded
    /// buffers is both leak-free and copy-free (EXPERIMENTS.md §Perf).
    bufs: HashMap<String, PjRtBuffer>,
    /// eval-mode materialized native experts
    native: compress::NativeExpertCache,
    /// sparsity-threshold scalars, uploaded once per (layer, expert,
    /// level) — batched decode resolves them once per expert *group*
    thr_bufs: HashMap<(usize, usize, u32), PjRtBuffer>,
    stats: BatchStats,
    pub path: ComputePath,
    /// kernel-pool width for the native path (`--kernel-threads`;
    /// defaults to the available cores). 1 disables parallel dispatch.
    kernel_threads: usize,
    /// lazily spawned worker pool — only native-path decodes with more
    /// than one expert group and `kernel_threads > 1` ever build it
    pool: Option<pool::KernelPool>,
}

impl Engine {
    /// Load artifacts, compile the decode graphs, prewarm weight literals.
    pub fn load(art_dir: &Path) -> Result<Self> {
        let w = Arc::new(Weights::load(art_dir)?);
        let mut rt = Runtime::new(art_dir)?;
        rt.load_all(&[
            "attn_step_b1",
            "expert_dense_b1",
            "expert_sparse_b1",
            "expert_floe_b1",
            "expert_q_b1",
            "logits_b1",
            "up_probe_b1",
        ])?;
        // Pallas variants are optional (validation path)
        let _ = rt.load("expert_sparse_pallas_b1");
        let _ = rt.load("expert_floe_pallas_b1");

        let mut bufs = HashMap::new();
        let names: Vec<String> = w.names().cloned().collect();
        for name in names {
            let shape = w.shape(&name)?.to_vec();
            let buf = match w.meta(&name)?.dtype {
                crate::model::Dtype::F32 => rt.upload_f32(w.f32(&name)?, &shape)?,
                crate::model::Dtype::U8 => rt.upload_u8(w.u8(&name)?, &shape)?,
                crate::model::Dtype::I32 => continue,
            };
            bufs.insert(name, buf);
        }
        Ok(Engine {
            rt,
            w: Arc::clone(&w),
            bufs,
            native: compress::NativeExpertCache::new(w),
            thr_bufs: HashMap::new(),
            stats: BatchStats::default(),
            path: ComputePath::Hlo,
            kernel_threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            pool: None,
        })
    }

    /// Set the native-path kernel pool width (`--kernel-threads`). 1
    /// forces sequential group execution; any width produces bit-identical
    /// outputs (the pool only changes scheduling).
    pub fn set_kernel_threads(&mut self, threads: usize) {
        let threads = threads.max(1);
        if threads != self.kernel_threads {
            self.kernel_threads = threads;
            self.pool = None; // respawn lazily at the new width
        }
    }

    pub fn kernel_threads(&self) -> usize {
        self.kernel_threads
    }

    pub fn cfg(&self) -> &crate::config::ModelConfig {
        &self.w.cfg
    }

    /// Batched-decode sharing counters (monotonic since load).
    pub fn batch_stats(&self) -> &BatchStats {
        &self.stats
    }

    /// Native experts materialized since load (see `NativeExpertCache`).
    pub fn native_materializations(&self) -> u64 {
        self.native.materialization_count()
    }

    fn buf(&self, name: &str) -> Result<&PjRtBuffer> {
        buf_in(&self.bufs, name)
    }

    /// Upload (once) and cache the "up" sparsity threshold scalar.
    fn ensure_threshold(&mut self, layer: usize, expert: usize, level: f64) -> Result<()> {
        let key = thr_key(layer, expert, level);
        if self.thr_bufs.contains_key(&key) {
            self.stats.threshold_hits += 1;
            return Ok(());
        }
        let t = self.w.threshold("up", layer, expert, level)?;
        let buf = self.rt.upload_scalar_f32(t)?;
        self.thr_bufs.insert(key, buf);
        self.stats.threshold_uploads += 1;
        Ok(())
    }

    /// Upload the zeroed KV caches for `st` once; thereafter each step's
    /// attention-output cache literals re-enter device buffers directly.
    fn ensure_kv(&self, st: &mut DecodeState) -> Result<()> {
        if st.kv.is_some() {
            return Ok(());
        }
        let n: usize = st.kv_dims.iter().product();
        let zeros = vec![0.0f32; n];
        let mut kv = Vec::with_capacity(st.n_layers);
        for _ in 0..st.n_layers {
            kv.push(KvLayer {
                kc: self.rt.upload_f32(&zeros, &st.kv_dims)?,
                vc: self.rt.upload_f32(&zeros, &st.kv_dims)?,
            });
        }
        st.kv = Some(kv);
        Ok(())
    }

    /// One expert forward through the selected compute path — the scalar
    /// eval/sweep entry point, executed as a group of one through
    /// `expert_group_forward` (the same discipline as `decode_token`:
    /// one dispatch implementation, so the eval path and the decode hot
    /// path cannot drift apart).
    pub fn expert_forward(
        &mut self,
        layer: usize,
        expert: usize,
        h: &[f32],
        mode: ExpertMode,
    ) -> Result<Vec<f32>> {
        let d = self.w.cfg.d_model;
        let h_mids = vec![h.to_vec()];
        let needs_hlo =
            self.path != ComputePath::Native && !compress::requires_native(mode);
        let h_bufs = if needs_hlo {
            vec![self.rt.upload_f32(h, &[1, d])?]
        } else {
            Vec::new()
        };
        let mut slot_y = vec![vec![vec![0.0f32; d]; 1]];
        self.expert_group_forward(layer, expert, mode, &[(0, 0)], &h_mids, &h_bufs, &mut slot_y)?;
        Ok(slot_y.swap_remove(0).swap_remove(0))
    }

    /// Execute one (boundary, expert) group: weight buffers and the
    /// threshold argument are resolved once per group, then every member
    /// row is computed against them — the native path in ONE multi-row
    /// kernel pass over the host rows (`h_mids`), the HLO path as
    /// per-row executions of the batch-1 graph over the caller's
    /// already-uploaded activation buffers (`h_bufs`, one upload per
    /// (sequence, boundary) — never per routed pair).
    fn expert_group_forward(
        &mut self,
        layer: usize,
        expert: usize,
        mode: ExpertMode,
        members: &[(usize, usize)],
        h_mids: &[Vec<f32>],
        h_bufs: &[PjRtBuffer],
        slot_y: &mut [Vec<Vec<f32>>],
    ) -> Result<()> {
        let d = self.w.cfg.d_model;
        if self.path == ComputePath::Native || compress::requires_native(mode) {
            let xs: Vec<&[f32]> =
                members.iter().map(|&(s, _)| h_mids[s].as_slice()).collect();
            let rows = self.native.forward_batch(layer, expert, &xs, mode)?;
            for (m, &(s, slot)) in members.iter().enumerate() {
                slot_y[s][slot].copy_from_slice(&rows[m * d..(m + 1) * d]);
            }
            return Ok(());
        }
        // resolve the group's graph and non-activation arguments ONCE;
        // one shared member loop below executes them per row
        let en = |t: &str| Weights::expert_name(layer, expert, t);
        let (graph, tail): (&str, Vec<&PjRtBuffer>) = match mode {
            ExpertMode::Dense => (
                "expert_dense_b1",
                vec![
                    buf_in(&self.bufs, &en("wg"))?,
                    buf_in(&self.bufs, &en("wu"))?,
                    buf_in(&self.bufs, &en("wd"))?,
                ],
            ),
            ExpertMode::Sparse { level } => {
                self.ensure_threshold(layer, expert, level)?;
                let name = if self.path == ComputePath::HloPallas
                    && self.rt.loaded("expert_sparse_pallas_b1")
                {
                    "expert_sparse_pallas_b1"
                } else {
                    "expert_sparse_b1"
                };
                (
                    name,
                    vec![
                        buf_in(&self.bufs, &en("wg"))?,
                        buf_in(&self.bufs, &en("wu"))?,
                        buf_in(&self.bufs, &en("wd"))?,
                        &self.thr_bufs[&thr_key(layer, expert, level)],
                    ],
                )
            }
            ExpertMode::Floe { level } => {
                self.ensure_threshold(layer, expert, level)?;
                let name = if self.path == ComputePath::HloPallas
                    && self.rt.loaded("expert_floe_pallas_b1")
                {
                    "expert_floe_pallas_b1"
                } else {
                    "expert_floe_b1"
                };
                (
                    name,
                    vec![
                        buf_in(&self.bufs, &en("wg"))?,
                        buf_in(&self.bufs, &en("up_q"))?,
                        buf_in(&self.bufs, &en("up_q_scale"))?,
                        buf_in(&self.bufs, &en("up_q_zero"))?,
                        buf_in(&self.bufs, &en("wd"))?,
                        &self.thr_bufs[&thr_key(layer, expert, level)],
                    ],
                )
            }
            ExpertMode::Uniform { bits } => {
                let q = |p: &str| en(&format!("q{bits}.{p}"));
                let names = [
                    q("wg"), format!("{}_scale", q("wg")), format!("{}_zero", q("wg")),
                    q("wu"), format!("{}_scale", q("wu")), format!("{}_zero", q("wu")),
                    q("wd"), format!("{}_scale", q("wd")), format!("{}_zero", q("wd")),
                ];
                let mut args = Vec::with_capacity(9);
                for nm in &names {
                    args.push(buf_in(&self.bufs, nm)?);
                }
                ("expert_q_b1", args)
            }
            // every mode the four HLO graphs don't cover satisfies
            // `requires_native` and took the native path above; a new
            // mode reaching here means `requires_native` was not updated
            other => unreachable!(
                "expert mode {other:?} has no HLO graph and is not \
                 routed native — update compress::requires_native"
            ),
        };
        for &(s, slot) in members {
            let mut call: Vec<&PjRtBuffer> = Vec::with_capacity(1 + tail.len());
            call.push(&h_bufs[s]);
            call.extend(tail.iter().copied());
            let out = self.rt.exec_b(graph, &call)?;
            slot_y[s][slot].copy_from_slice(&to_vec_f32(&out[0])?);
        }
        Ok(())
    }

    /// Step every sequence one token, layer by layer in lockstep. At each
    /// MoE boundary the routed (sequence, expert) pairs are grouped by
    /// expert and each activated expert is visited once
    /// (`expert_group_forward`); per sequence the expert outputs are then
    /// combined *in routing order*, so the accumulation order — and
    /// therefore every bit of every logit — matches N independent
    /// sequential decodes. Returns each sequence's logits.
    pub fn decode_batch(
        &mut self,
        sts: &mut [&mut DecodeState],
        tokens: &[u8],
        mode: ExpertMode,
        obs: &mut dyn StepObserver,
    ) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(!sts.is_empty(), "empty decode batch");
        anyhow::ensure!(sts.len() == tokens.len(), "batch/token length mismatch");
        let c = self.w.cfg.clone();
        let n = sts.len();
        for st in sts.iter() {
            anyhow::ensure!(st.pos < c.max_seq, "KV cache full");
        }
        for st in sts.iter_mut() {
            self.ensure_kv(st)?;
        }
        let mut xs: Vec<Vec<f32>> = tokens
            .iter()
            .map(|&t| self.w.embed_row(t).map(<[f32]>::to_vec))
            .collect::<Result<_>>()?;
        let pos_bufs: Vec<PjRtBuffer> = sts
            .iter()
            .map(|st| self.rt.upload_scalar_i32(st.pos as i32))
            .collect::<Result<_>>()?;
        // per-(sequence, routing-slot) expert outputs, reused across layers
        let mut slot_y = vec![vec![vec![0.0f32; c.d_model]; c.top_k]; n];
        let mut moe = vec![0.0f32; c.d_model];
        let mut x2s: Vec<Vec<f32>> = vec![Vec::new(); n];
        let mut h_mids: Vec<Vec<f32>> = vec![Vec::new(); n];
        let mut routed_all: Vec<Vec<(usize, f32)>> = vec![Vec::new(); n];
        for l in 0..c.n_layers {
            {
                // ---- attention pass, one sequence at a time (batch-1
                // graph); the seven layer-weight buffers resolve once ----
                let pre = format!("layer{l}.");
                let aw = [
                    buf_in(&self.bufs, &format!("{pre}wq"))?,
                    buf_in(&self.bufs, &format!("{pre}wk"))?,
                    buf_in(&self.bufs, &format!("{pre}wv"))?,
                    buf_in(&self.bufs, &format!("{pre}wo"))?,
                    buf_in(&self.bufs, &format!("{pre}norm1"))?,
                    buf_in(&self.bufs, &format!("{pre}norm2"))?,
                    buf_in(&self.bufs, &format!("{pre}router"))?,
                ];
                for i in 0..n {
                    let xl = self.rt.upload_f32(&xs[i], &[1, c.d_model])?;
                    let mut out = {
                        let kv = &sts[i].kv.as_ref().expect("kv ensured")[l];
                        self.rt.exec_b(
                            "attn_step_b1",
                            &[&xl, &kv.kc, &kv.vc, &pos_bufs[i],
                              aw[0], aw[1], aw[2], aw[3], aw[4], aw[5], aw[6]],
                        )?
                    };
                    // (x2, h_mid, router_logits, kc', vc')
                    let vc = out.pop().context("vc")?;
                    let kc = out.pop().context("kc")?;
                    let rl = to_vec_f32(&out.pop().context("rl")?)?;
                    h_mids[i] = to_vec_f32(&out.pop().context("h")?)?;
                    x2s[i] = to_vec_f32(&out.pop().context("x2")?)?;
                    // KV residency: the output cache literals go straight
                    // back to device buffers for the next step
                    let kv = &mut sts[i].kv.as_mut().expect("kv ensured")[l];
                    kv.kc = self.rt.upload_literal(&kc)?;
                    kv.vc = self.rt.upload_literal(&vc)?;

                    // Mixtral routing: softmax over the top-k logits
                    let idx = top_k(&rl, c.top_k);
                    let mut wts: Vec<f32> = idx.iter().map(|&k| rl[k]).collect();
                    softmax_inplace(&mut wts);
                    routed_all[i] = idx.into_iter().zip(wts).collect();

                    obs.on_layer(&LayerEvent {
                        layer: l,
                        seq: i,
                        h_mid: &h_mids[i],
                        routed: &routed_all[i],
                    });
                }
            }

            // ---- boundary-synchronous expert execution: group by
            // expert; each distinct expert is visited once, and each
            // sequence's activation row is uploaded once per boundary
            // (shared by all of its groups), not once per routed pair ----
            let needs_hlo =
                self.path != ComputePath::Native && !compress::requires_native(mode);
            let h_bufs: Vec<PjRtBuffer> = if needs_hlo {
                h_mids
                    .iter()
                    .map(|h| self.rt.upload_f32(h, &[1, c.d_model]))
                    .collect::<Result<_>>()?
            } else {
                Vec::new()
            };
            let groups = group_by_expert(&routed_all);
            self.stats.boundaries += 1;
            self.stats.group_visits += groups.len() as u64;
            let native = self.path == ComputePath::Native || compress::requires_native(mode);
            if native && self.kernel_threads > 1 && groups.len() > 1 {
                // parallel native dispatch: every group's expert is
                // materialized up front (cache mutation stays on this
                // thread), then disjoint groups run across the pool.
                // Outputs come back in dispatch order — ascending expert,
                // the BTreeMap's iteration order — and each row's math is
                // untouched, so results are bit-identical to the
                // sequential loop below at any thread count.
                let mut jobs: Vec<pool::KernelJob> = Vec::with_capacity(groups.len());
                for (&e, members) in &groups {
                    self.stats.pair_visits += members.len() as u64;
                    let ne = self.native.ensure(l, e, mode)?;
                    let xs: Vec<Vec<f32>> =
                        members.iter().map(|&(s, _)| h_mids[s].clone()).collect();
                    let d = c.d_model;
                    jobs.push(Box::new(move || {
                        let mut out = vec![0.0f32; xs.len() * d];
                        let x_refs: Vec<&[f32]> =
                            xs.iter().map(|x| x.as_slice()).collect();
                        let mut rows: Vec<&mut [f32]> = out.chunks_mut(d).collect();
                        ne.forward_rows(&x_refs, &mut rows);
                        out
                    }));
                }
                let pool = self
                    .pool
                    .get_or_insert_with(|| pool::KernelPool::new(self.kernel_threads));
                let outs = pool.run(jobs);
                for ((_, members), rows) in groups.iter().zip(&outs) {
                    for (m, &(s, slot)) in members.iter().enumerate() {
                        slot_y[s][slot]
                            .copy_from_slice(&rows[m * c.d_model..(m + 1) * c.d_model]);
                    }
                }
            } else {
                for (&e, members) in &groups {
                    self.stats.pair_visits += members.len() as u64;
                    self.expert_group_forward(l, e, mode, members, &h_mids, &h_bufs, &mut slot_y)?;
                }
            }

            // ---- combine per sequence in routing order (the sequential
            // accumulation order, so grouping cannot perturb sums) ----
            for i in 0..n {
                moe.iter_mut().for_each(|v| *v = 0.0);
                for (slot, &(_, wgt)) in routed_all[i].iter().enumerate() {
                    axpy(&mut moe, wgt, &slot_y[i][slot]);
                }
                for (k, x) in xs[i].iter_mut().enumerate() {
                    *x = x2s[i][k] + moe[k];
                }
            }
        }
        let mut all = Vec::with_capacity(n);
        for i in 0..n {
            let xl = self.rt.upload_f32(&xs[i], &[1, c.d_model])?;
            let out = self.rt.exec_b(
                "logits_b1",
                &[&xl, self.buf("final_norm")?, self.buf("lm_head")?],
            )?;
            all.push(to_vec_f32(&out[0])?);
        }
        // Commit per-sequence state only after every fallible step
        // succeeded: a batch error leaves pos/x untouched, so the serving
        // path's solo retry re-executes the token against unadvanced
        // state — KV writes at `pos` are overwrites of the same
        // deterministic values, which is what makes the retry
        // value-idempotent.
        for (i, st) in sts.iter_mut().enumerate() {
            st.pos += 1;
            st.x.copy_from_slice(&xs[i]);
        }
        Ok(all)
    }

    /// Run one token through all layers. Returns the logits. Literally a
    /// batch of one through `decode_batch`, so the sequential reference
    /// and the batched path cannot drift apart.
    pub fn decode_token(
        &mut self,
        st: &mut DecodeState,
        token: u8,
        mode: ExpertMode,
        obs: &mut dyn StepObserver,
    ) -> Result<Vec<f32>> {
        let mut out = self.decode_batch(&mut [st], &[token], mode, obs)?;
        Ok(out.pop().expect("batch of one"))
    }

    /// Feed a prompt; returns the logits after the last prompt token.
    pub fn prefill(
        &mut self,
        st: &mut DecodeState,
        prompt: &[u8],
        mode: ExpertMode,
        obs: &mut dyn StepObserver,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        let mut logits = Vec::new();
        for &t in prompt {
            logits = self.decode_token(st, t, mode, obs)?;
        }
        Ok(logits)
    }

    /// Greedy/temperature generation of `n_tokens` after `prompt`.
    pub fn generate(
        &mut self,
        prompt: &[u8],
        n_tokens: usize,
        mode: ExpertMode,
        temperature: f32,
        seed: u64,
        obs: &mut dyn StepObserver,
    ) -> Result<Vec<u8>> {
        let mut st = DecodeState::new(&self.w)?;
        let mut logits = self.prefill(&mut st, prompt, mode, obs)?;
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut out = Vec::with_capacity(n_tokens);
        for _ in 0..n_tokens {
            let tok = sampler::sample(&logits, temperature, &mut rng);
            out.push(tok);
            if st.pos >= self.w.cfg.max_seq {
                break;
            }
            logits = self.decode_token(&mut st, tok, mode, obs)?;
        }
        Ok(out)
    }

    /// Intra-expert reuse probe through the AOT `up_probe` graph:
    /// |h · W_up_q| for (layer, expert).
    pub fn up_probe(&mut self, layer: usize, expert: usize, h: &[f32]) -> Result<Vec<f32>> {
        let d = self.w.cfg.d_model;
        let en = |t: &str| Weights::expert_name(layer, expert, t);
        let x = self.rt.upload_f32(h, &[1, d])?;
        let out = self.rt.exec_b(
            "up_probe_b1",
            &[&x, self.buf(&en("up_q"))?, self.buf(&en("up_q_scale"))?,
              self.buf(&en("up_q_zero"))?],
        )?;
        to_vec_f32(&out[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Grouping is pure bookkeeping over the routing table — testable
    /// without a runtime: every (sequence, slot) pair lands in exactly
    /// one group, group count == distinct experts, and order is
    /// deterministic (ascending expert id).
    #[test]
    fn group_by_expert_counts_distinct_and_covers_all_pairs() {
        let routed = vec![
            vec![(3usize, 0.6f32), (1, 0.4)],
            vec![(1, 0.7), (5, 0.3)],
            vec![(3, 0.5), (1, 0.5)],
        ];
        let groups = group_by_expert(&routed);
        // distinct experts routed: {1, 3, 5}
        assert_eq!(groups.len(), 3);
        assert_eq!(groups.keys().copied().collect::<Vec<_>>(), vec![1, 3, 5]);
        let pairs: usize = groups.values().map(Vec::len).sum();
        assert_eq!(pairs, 6, "every routed pair appears in exactly one group");
        assert_eq!(groups[&1], vec![(0, 1), (1, 0), (2, 1)]);
        assert_eq!(groups[&3], vec![(0, 0), (2, 0)]);
        assert_eq!(groups[&5], vec![(1, 1)]);
        // a batch of one degenerates to one group per routed slot
        let solo = group_by_expert(&routed[..1]);
        assert_eq!(solo.len(), 2);
        assert!(solo.values().all(|m| m.len() == 1));
    }

    #[test]
    fn threshold_key_quantizes_levels_stably() {
        assert_eq!(thr_key(1, 2, 0.8), (1, 2, 800));
        assert_eq!(thr_key(1, 2, 0.85), thr_key(1, 2, 0.85));
        assert_ne!(thr_key(1, 2, 0.8), thr_key(1, 2, 0.9));
    }
}

//! Decode engine: drives the AOT-compiled HLO graphs (attention step,
//! expert variants, logits head) token by token, with expert selection and
//! combination on the host — the computation Fig 1(c) places on the GPU.
//!
//! The engine is *pure compute*: which expert weights are "VRAM-resident",
//! what transfers cost, and when prefetches are issued are the
//! coordinator's concern (coordinator/). An observer hook exposes each
//! layer's hidden state + routing so the coordinator can drive the dual
//! predictors and the simulated clock without touching the math.
//!
//! Perf notes (EXPERIMENTS.md §Perf): all weight tensors are uploaded to
//! device buffers once at load and executions run through `execute_b`
//! (the literal-argument `execute` path in the xla crate leaks its
//! internally created input buffers).

pub mod compress;
pub mod sampler;

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::config::ExpertMode;
use crate::model::Weights;
use crate::runtime::{to_vec_f32, PjRtBuffer, Runtime};
use crate::tensor::{softmax_inplace, top_k};

/// Which compiled graph family executes the expert math.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ComputePath {
    /// plain-jnp lowered graphs (XLA-fused; the default hot path)
    Hlo,
    /// the L1 Pallas kernels lowered into HLO (validation + comparison)
    HloPallas,
    /// native Rust expert math (baseline sweeps + Fiddler CPU path)
    Native,
}

/// Per-request decode state. KV caches live as host vectors, uploaded to
/// device buffers per step (CPU PJRT: the "device" is host memory, so the
/// upload is a memcpy).
pub struct DecodeState {
    pub x: Vec<f32>,
    pub pos: usize,
    kv_dims: [usize; 4],
    kc: Vec<Vec<f32>>,
    vc: Vec<Vec<f32>>,
}

impl DecodeState {
    pub fn new(w: &Weights) -> Result<Self> {
        let c = &w.cfg;
        let dims = [1, c.n_heads, c.max_seq, c.head_dim];
        let n: usize = dims.iter().product();
        Ok(DecodeState {
            x: vec![0.0; c.d_model],
            pos: 0,
            kv_dims: dims,
            kc: vec![vec![0.0; n]; c.n_layers],
            vc: vec![vec![0.0; n]; c.n_layers],
        })
    }
}

/// Layer-step information surfaced to the coordinator.
pub struct LayerEvent<'a> {
    pub layer: usize,
    /// hidden state entering the MoE block (router/up-projection input)
    pub h_mid: &'a [f32],
    /// (expert, weight) pairs actually routed to
    pub routed: &'a [(usize, f32)],
}

pub trait StepObserver {
    fn on_layer(&mut self, ev: &LayerEvent<'_>);
}

/// No-op observer for plain generation.
pub struct NoObserver;
impl StepObserver for NoObserver {
    fn on_layer(&mut self, _ev: &LayerEvent<'_>) {}
}

pub struct Engine {
    pub rt: Runtime,
    pub w: Arc<Weights>,
    /// all weight tensors uploaded once as device buffers. The xla
    /// crate's literal-argument `execute` leaks its internally created
    /// input buffers (~arg bytes per call); `execute_b` over pre-uploaded
    /// buffers is both leak-free and copy-free (EXPERIMENTS.md §Perf).
    bufs: HashMap<String, PjRtBuffer>,
    /// eval-mode materialized native experts
    native: compress::NativeExpertCache,
    pub path: ComputePath,
}

impl Engine {
    /// Load artifacts, compile the decode graphs, prewarm weight literals.
    pub fn load(art_dir: &Path) -> Result<Self> {
        let w = Arc::new(Weights::load(art_dir)?);
        let mut rt = Runtime::new(art_dir)?;
        rt.load_all(&[
            "attn_step_b1",
            "expert_dense_b1",
            "expert_sparse_b1",
            "expert_floe_b1",
            "expert_q_b1",
            "logits_b1",
            "up_probe_b1",
        ])?;
        // Pallas variants are optional (validation path)
        let _ = rt.load("expert_sparse_pallas_b1");
        let _ = rt.load("expert_floe_pallas_b1");

        let mut bufs = HashMap::new();
        let names: Vec<String> = w.names().cloned().collect();
        for name in names {
            let shape = w.shape(&name)?.to_vec();
            let buf = match w.meta(&name)?.dtype {
                crate::model::Dtype::F32 => rt.upload_f32(w.f32(&name)?, &shape)?,
                crate::model::Dtype::U8 => rt.upload_u8(w.u8(&name)?, &shape)?,
                crate::model::Dtype::I32 => continue,
            };
            bufs.insert(name, buf);
        }
        Ok(Engine {
            rt,
            w: Arc::clone(&w),
            bufs,
            native: compress::NativeExpertCache::new(w),
            path: ComputePath::Hlo,
        })
    }

    pub fn cfg(&self) -> &crate::config::ModelConfig {
        &self.w.cfg
    }

    fn buf(&self, name: &str) -> Result<&PjRtBuffer> {
        self.bufs
            .get(name)
            .ok_or_else(|| anyhow!("no buffer for tensor {name}"))
    }

    /// One expert forward through the selected compute path.
    pub fn expert_forward(
        &mut self,
        layer: usize,
        expert: usize,
        h: &[f32],
        mode: ExpertMode,
    ) -> Result<Vec<f32>> {
        if self.path == ComputePath::Native || compress::requires_native(mode) {
            return self.native.forward(layer, expert, h, mode);
        }
        let d = self.w.cfg.d_model;
        let x = self.rt.upload_f32(h, &[1, d])?;
        let en = |t: &str| Weights::expert_name(layer, expert, t);
        let out = match mode {
            ExpertMode::Dense => self.rt.exec_b(
                "expert_dense_b1",
                &[&x, self.buf(&en("wg"))?, self.buf(&en("wu"))?, self.buf(&en("wd"))?],
            )?,
            ExpertMode::Sparse { level } => {
                let t = self.rt.upload_scalar_f32(
                    self.w.threshold("up", layer, expert, level)?)?;
                let name = if self.path == ComputePath::HloPallas
                    && self.rt.loaded("expert_sparse_pallas_b1")
                {
                    "expert_sparse_pallas_b1"
                } else {
                    "expert_sparse_b1"
                };
                self.rt.exec_b(
                    name,
                    &[&x, self.buf(&en("wg"))?, self.buf(&en("wu"))?,
                      self.buf(&en("wd"))?, &t],
                )?
            }
            ExpertMode::Floe { level } => {
                let t = self.rt.upload_scalar_f32(
                    self.w.threshold("up", layer, expert, level)?)?;
                let name = if self.path == ComputePath::HloPallas
                    && self.rt.loaded("expert_floe_pallas_b1")
                {
                    "expert_floe_pallas_b1"
                } else {
                    "expert_floe_b1"
                };
                self.rt.exec_b(
                    name,
                    &[&x, self.buf(&en("wg"))?, self.buf(&en("up_q"))?,
                      self.buf(&en("up_q_scale"))?, self.buf(&en("up_q_zero"))?,
                      self.buf(&en("wd"))?, &t],
                )?
            }
            ExpertMode::Uniform { bits } => {
                let q = |p: &str| en(&format!("q{bits}.{p}"));
                self.rt.exec_b(
                    "expert_q_b1",
                    &[&x,
                      self.buf(&q("wg"))?, self.buf(&format!("{}_scale", q("wg")))?,
                      self.buf(&format!("{}_zero", q("wg")))?,
                      self.buf(&q("wu"))?, self.buf(&format!("{}_scale", q("wu")))?,
                      self.buf(&format!("{}_zero", q("wu")))?,
                      self.buf(&q("wd"))?, self.buf(&format!("{}_scale", q("wd")))?,
                      self.buf(&format!("{}_zero", q("wd")))?],
                )?
            }
            other => return self.native.forward(layer, expert, h, other),
        };
        to_vec_f32(&out[0])
    }

    /// Run one token through all layers. Returns the logits.
    pub fn decode_token(
        &mut self,
        st: &mut DecodeState,
        token: u8,
        mode: ExpertMode,
        obs: &mut dyn StepObserver,
    ) -> Result<Vec<f32>> {
        let c = self.w.cfg.clone();
        anyhow::ensure!(st.pos < c.max_seq, "KV cache full");
        let mut x = self.w.embed_row(token)?.to_vec();
        let pos = self.rt.upload_scalar_i32(st.pos as i32)?;
        for l in 0..c.n_layers {
            let pre = format!("layer{l}.");
            let xl = self.rt.upload_f32(&x, &[1, c.d_model])?;
            let kcb = self.rt.upload_f32(&st.kc[l], &st.kv_dims)?;
            let vcb = self.rt.upload_f32(&st.vc[l], &st.kv_dims)?;
            let mut out = self.rt.exec_b(
                "attn_step_b1",
                &[&xl, &kcb, &vcb, &pos,
                  self.buf(&format!("{pre}wq"))?, self.buf(&format!("{pre}wk"))?,
                  self.buf(&format!("{pre}wv"))?, self.buf(&format!("{pre}wo"))?,
                  self.buf(&format!("{pre}norm1"))?, self.buf(&format!("{pre}norm2"))?,
                  self.buf(&format!("{pre}router"))?],
            )?;
            // (x2, h_mid, router_logits, kc', vc')
            let vc = to_vec_f32(&out.pop().context("vc")?)?;
            let kc = to_vec_f32(&out.pop().context("kc")?)?;
            let rl = to_vec_f32(&out.pop().context("rl")?)?;
            let h_mid = to_vec_f32(&out.pop().context("h")?)?;
            let x2 = to_vec_f32(&out.pop().context("x2")?)?;
            st.kc[l] = kc;
            st.vc[l] = vc;

            // Mixtral routing: softmax over the top-k logits
            let idx = top_k(&rl, c.top_k);
            let mut wts: Vec<f32> = idx.iter().map(|&i| rl[i]).collect();
            softmax_inplace(&mut wts);
            let routed: Vec<(usize, f32)> =
                idx.into_iter().zip(wts.into_iter()).collect();

            obs.on_layer(&LayerEvent { layer: l, h_mid: &h_mid, routed: &routed });

            let mut moe = vec![0.0f32; c.d_model];
            for &(e, wgt) in &routed {
                let y = self.expert_forward(l, e, &h_mid, mode)?;
                for (m, yi) in moe.iter_mut().zip(&y) {
                    *m += wgt * yi;
                }
            }
            for i in 0..c.d_model {
                x[i] = x2[i] + moe[i];
            }
        }
        st.pos += 1;
        st.x.copy_from_slice(&x);
        let xl = self.rt.upload_f32(&x, &[1, c.d_model])?;
        let out = self.rt.exec_b(
            "logits_b1",
            &[&xl, self.buf("final_norm")?, self.buf("lm_head")?],
        )?;
        to_vec_f32(&out[0])
    }

    /// Feed a prompt; returns the logits after the last prompt token.
    pub fn prefill(
        &mut self,
        st: &mut DecodeState,
        prompt: &[u8],
        mode: ExpertMode,
        obs: &mut dyn StepObserver,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        let mut logits = Vec::new();
        for &t in prompt {
            logits = self.decode_token(st, t, mode, obs)?;
        }
        Ok(logits)
    }

    /// Greedy/temperature generation of `n_tokens` after `prompt`.
    pub fn generate(
        &mut self,
        prompt: &[u8],
        n_tokens: usize,
        mode: ExpertMode,
        temperature: f32,
        seed: u64,
        obs: &mut dyn StepObserver,
    ) -> Result<Vec<u8>> {
        let mut st = DecodeState::new(&self.w)?;
        let mut logits = self.prefill(&mut st, prompt, mode, obs)?;
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut out = Vec::with_capacity(n_tokens);
        for _ in 0..n_tokens {
            let tok = sampler::sample(&logits, temperature, &mut rng);
            out.push(tok);
            if st.pos >= self.w.cfg.max_seq {
                break;
            }
            logits = self.decode_token(&mut st, tok, mode, obs)?;
        }
        Ok(out)
    }

    /// Intra-expert reuse probe through the AOT `up_probe` graph:
    /// |h · W_up_q| for (layer, expert).
    pub fn up_probe(&mut self, layer: usize, expert: usize, h: &[f32]) -> Result<Vec<f32>> {
        let d = self.w.cfg.d_model;
        let en = |t: &str| Weights::expert_name(layer, expert, t);
        let x = self.rt.upload_f32(h, &[1, d])?;
        let out = self.rt.exec_b(
            "up_probe_b1",
            &[&x, self.buf(&en("up_q"))?, self.buf(&en("up_q_scale"))?,
              self.buf(&en("up_q_zero"))?],
        )?;
        to_vec_f32(&out[0])
    }
}

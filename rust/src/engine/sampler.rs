//! Token sampling: greedy (temperature 0) or softmax-with-temperature.

use crate::util::rng::Rng;

pub fn sample(logits: &[f32], temperature: f32, rng: &mut Rng) -> u8 {
    if temperature <= 0.0 {
        return argmax(logits) as u8;
    }
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut probs: Vec<f32> = logits
        .iter()
        .map(|l| ((l - m) / temperature).exp())
        .collect();
    let sum: f32 = probs.iter().sum();
    for p in probs.iter_mut() {
        *p /= sum;
    }
    let r = rng.f32();
    let mut acc = 0.0;
    for (i, p) in probs.iter().enumerate() {
        acc += p;
        if r < acc {
            return i as u8;
        }
    }
    (probs.len() - 1) as u8
}

pub fn argmax(x: &[f32]) -> usize {
    let mut best = 0;
    for (i, v) in x.iter().enumerate() {
        if *v > x[best] {
            best = i;
        }
    }
    best
}

/// Next-token negative log-likelihood (nats) from raw logits.
pub fn nll(logits: &[f32], target: u8) -> f64 {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse: f64 = logits
        .iter()
        .map(|l| ((l - m) as f64).exp())
        .sum::<f64>()
        .ln()
        + m as f64;
    lse - logits[target as usize] as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut rng = Rng::new(0);
        let logits = vec![0.0f32, 3.0, 1.0];
        assert_eq!(sample(&logits, 0.0, &mut rng), 1);
    }

    #[test]
    fn temperature_sampling_covers_support() {
        let mut rng = Rng::new(1);
        let logits = vec![1.0f32, 1.0];
        let mut seen = [false; 2];
        for _ in 0..100 {
            seen[sample(&logits, 1.0, &mut rng) as usize] = true;
        }
        assert!(seen[0] && seen[1]);
    }

    #[test]
    fn nll_uniform() {
        let logits = vec![0.0f32; 4];
        let e = nll(&logits, 2);
        assert!((e - (4f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn nll_confident() {
        let mut logits = vec![-10.0f32; 8];
        logits[3] = 10.0;
        assert!(nll(&logits, 3) < 1e-6);
    }
}

//! Native-Rust expert forward with every compression strategy the paper's
//! efficacy evaluation sweeps (Figs 3/9/10, Tables 3-7): per-projection
//! sparsification (up / gate / down), CATS and CHESS baselines, uniform
//! and per-projection HQQ quantization, and the FloE hybrid.
//!
//! The serving hot path uses the HLO graphs; these native experts exist
//! because the sweep space (projection x level x bits) is combinatorial
//! and numerics here are bit-comparable to the references (tested).
//! Materialized (dequantized, channel-major) experts are cached.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;

use crate::config::{ExpertMode, Proj};
use crate::model::Weights;
use crate::tensor::{axpy, dot, silu, ExpertWeights, Mat};

/// Sparsification rule applied inside the expert forward.
enum Rule {
    None,
    /// skip channel when |x·Wu_j| < t (paper Eq. 11)
    Up(f32),
    /// zero SiLU(x·Wg_j) when |SiLU(x·Wg_j)| < t (CATS / L_gate)
    Gate(f32),
    /// per-channel gate thresholds (CHESS)
    GateChannel(Vec<f32>),
    /// zero h_j = g_j * v_j when |h_j| < t (L_down)
    Down(f32),
}

pub(crate) struct NativeExpert {
    w: ExpertWeights,
    rule: Rule,
}

impl NativeExpert {
    /// Forward a batch of activation rows with ONE pass over the weight
    /// channels: channel j's gate/up columns and down row are loaded once
    /// and every row rides them while hot (the multi-row amortization the
    /// boundary-synchronous decode path banks on — see
    /// `tensor::gemm_channel_major` for the rule-free kernel). Per row
    /// the op order is identical to a batch of one, so each row's output
    /// is bit-identical to a solo call; the sparsity rules skip
    /// per-(row, channel), exactly as before. `&self` and plain-`Vec`
    /// weights make this safe to run from the kernel pool's workers
    /// (`engine::pool`) — one expert per core, disjoint outputs.
    pub(crate) fn forward_rows(&self, xs: &[&[f32]], ys: &mut [&mut [f32]]) {
        debug_assert_eq!(xs.len(), ys.len());
        for y in ys.iter_mut() {
            y.iter_mut().for_each(|v| *v = 0.0);
        }
        let f = self.w.f();
        for j in 0..f {
            let wu = self.w.wu_t.row(j);
            let wg = self.w.wg_t.row(j);
            let wd = self.w.wd.row(j);
            for (x, y) in xs.iter().zip(ys.iter_mut()) {
                let h = match &self.rule {
                    Rule::Up(t) => {
                        let v = dot(x, wu);
                        if v.abs() < *t {
                            continue;
                        }
                        silu(dot(x, wg)) * v
                    }
                    Rule::Gate(t) => {
                        let g = silu(dot(x, wg));
                        if g.abs() < *t {
                            continue;
                        }
                        g * dot(x, wu)
                    }
                    Rule::GateChannel(ts) => {
                        let g = silu(dot(x, wg));
                        if g.abs() < ts[j] {
                            continue;
                        }
                        g * dot(x, wu)
                    }
                    Rule::Down(t) => {
                        let g = silu(dot(x, wg));
                        let v = dot(x, wu);
                        let h = g * v;
                        if h.abs() < *t {
                            continue;
                        }
                        h
                    }
                    Rule::None => {
                        let g = silu(dot(x, wg));
                        let v = dot(x, wu);
                        g * v
                    }
                };
                axpy(y, h, wd);
            }
        }
    }
}

/// Modes the HLO graph set does not cover (evaluation-only sweeps).
pub fn requires_native(mode: ExpertMode) -> bool {
    matches!(
        mode,
        ExpertMode::CatsGate { .. }
            | ExpertMode::ChessGate { .. }
            | ExpertMode::DownSparse { .. }
            | ExpertMode::QuantProj { .. }
            | ExpertMode::SparseProj { .. }
            | ExpertMode::FloeVar { .. }
    )
}

fn mode_key(mode: ExpertMode) -> (u8, u32, u8) {
    let lv = |l: f64| (l * 1000.0).round() as u32;
    match mode {
        ExpertMode::Dense => (0, 0, 0),
        ExpertMode::Sparse { level } => (1, lv(level), 0),
        ExpertMode::Floe { level } => (2, lv(level), 0),
        ExpertMode::CatsGate { level } => (3, lv(level), 0),
        ExpertMode::ChessGate { level } => (4, lv(level), 0),
        ExpertMode::DownSparse { level } => (5, lv(level), 0),
        ExpertMode::Uniform { bits } => (6, 0, bits),
        ExpertMode::QuantProj { proj, bits } => {
            (7 + proj as u8, 0, bits)
        }
        ExpertMode::SparseProj { proj, level } => (10 + proj as u8, lv(level), 0),
        ExpertMode::FloeVar { level, bits } => (13, lv(level), bits),
    }
}

pub struct NativeExpertCache {
    w: Arc<Weights>,
    /// `Arc` so the kernel pool can hold an expert across a dispatch
    /// while the cache stays borrowable; single-owner refcount bumps are
    /// the only overhead on the sequential path
    cache: HashMap<(usize, usize, (u8, u32, u8)), Arc<NativeExpert>>,
    /// Reused output buffer: `forward_batch` hands out `batch × d_model`
    /// rows of it, so steady-state decode allocates nothing per call.
    /// (This folds the old dead per-call `scratch` resize and the old
    /// per-call `y` allocation into one live buffer.)
    scratch: Vec<f32>,
    /// Experts materialized (dequantized + channel-major transposed)
    /// since startup. Batched decode materializes once per distinct
    /// (layer, expert, mode), never per routed pair — pinned by
    /// tests/batch_decode.rs.
    materializations: u64,
}

impl NativeExpertCache {
    pub fn new(w: Arc<Weights>) -> Self {
        NativeExpertCache {
            w,
            cache: HashMap::new(),
            scratch: Vec::new(),
            materializations: 0,
        }
    }

    pub fn clear(&mut self) {
        self.cache.clear();
    }

    /// Experts materialized since startup (monotonic; survives `clear`).
    pub fn materialization_count(&self) -> u64 {
        self.materializations
    }

    fn dequant_mat(&self, layer: usize, expert: usize, proj: &str, bits: u8) -> Result<Mat> {
        let qv = self.w.proj_q(layer, expert, proj, bits)?;
        let mut out = vec![0.0f32; qv.d * qv.f];
        qv.dequant(&mut out);
        Ok(Mat::from_vec(qv.d, qv.f, out))
    }

    fn materialize(&self, layer: usize, expert: usize, mode: ExpertMode) -> Result<NativeExpert> {
        let cfg = &self.w.cfg;
        let (d, f) = (cfg.d_model, cfg.d_ff);
        let en = |t: &str| Weights::expert_name(layer, expert, t);
        let fp = |name: &str| -> Result<Mat> {
            Ok(Mat::from_vec(
                if name.ends_with("wd") { f } else { d },
                if name.ends_with("wd") { d } else { f },
                self.w.f32(name)?.to_vec(),
            ))
        };
        // start from fp32 matrices, substitute per mode
        let mut wg = fp(&en("wg"))?;
        let mut wu = fp(&en("wu"))?;
        let mut wd = fp(&en("wd"))?;
        let mut rule = Rule::None;
        match mode {
            ExpertMode::Dense => {}
            ExpertMode::Sparse { level } => {
                rule = Rule::Up(self.w.threshold("up", layer, expert, level)?);
            }
            ExpertMode::Floe { level } => {
                // INT2 HQQ up projection + contextual sparsity
                let qv = self.w.up_q(layer, expert)?;
                let mut dq = vec![0.0f32; d * f];
                qv.dequant(&mut dq);
                wu = Mat::from_vec(d, f, dq);
                rule = Rule::Up(self.w.threshold("up", layer, expert, level)?);
            }
            ExpertMode::CatsGate { level } => {
                rule = Rule::Gate(self.w.threshold("gate", layer, expert, level)?);
            }
            ExpertMode::ChessGate { level } => {
                rule = Rule::GateChannel(self.w.chess_thresholds(layer, expert, level)?);
            }
            ExpertMode::DownSparse { level } => {
                rule = Rule::Down(self.w.threshold("down", layer, expert, level)?);
            }
            ExpertMode::Uniform { bits } => {
                wg = self.dequant_mat(layer, expert, "wg", bits)?;
                wu = self.dequant_mat(layer, expert, "wu", bits)?;
                wd = self.dequant_mat(layer, expert, "wd", bits)?;
            }
            ExpertMode::QuantProj { proj, bits } => match proj {
                Proj::Gate => wg = self.dequant_mat(layer, expert, "wg", bits)?,
                Proj::Up => wu = self.dequant_mat(layer, expert, "wu", bits)?,
                Proj::Down => wd = self.dequant_mat(layer, expert, "wd", bits)?,
            },
            ExpertMode::SparseProj { proj, level } => {
                let t = self.w.threshold(proj.key(), layer, expert, level)?;
                rule = match proj {
                    Proj::Up => Rule::Up(t),
                    Proj::Gate => Rule::Gate(t),
                    Proj::Down => Rule::Down(t),
                };
            }
            ExpertMode::FloeVar { level, bits } => {
                wu = self.dequant_mat(layer, expert, "wu", bits)?;
                rule = Rule::Up(self.w.threshold("up", layer, expert, level)?);
            }
        }
        Ok(NativeExpert {
            w: ExpertWeights { wg_t: wg.t(), wu_t: wu.t(), wd },
            rule,
        })
    }

    /// Materialize-if-absent and hand out a shared reference to the
    /// expert — the kernel pool's entry point (workers compute through
    /// the `Arc` while other experts dispatch).
    pub(crate) fn ensure(
        &mut self,
        layer: usize,
        expert: usize,
        mode: ExpertMode,
    ) -> Result<Arc<NativeExpert>> {
        let key = (layer, expert, mode_key(mode));
        if !self.cache.contains_key(&key) {
            let ne = self.materialize(layer, expert, mode)?;
            self.cache.insert(key, Arc::new(ne));
            self.materializations += 1;
        }
        Ok(Arc::clone(self.cache.get(&key).unwrap()))
    }

    /// Forward a batch of rows through one materialized expert with a
    /// single pass over its weight channels. Returns `xs.len() × d_model`
    /// output rows borrowed from the reused scratch buffer (valid until
    /// the next call) — the zero-allocation hot path of batched decode.
    pub fn forward_batch(
        &mut self,
        layer: usize,
        expert: usize,
        xs: &[&[f32]],
        mode: ExpertMode,
    ) -> Result<&[f32]> {
        let ne = self.ensure(layer, expert, mode)?;
        let d = self.w.cfg.d_model;
        // forward_rows zeroes every row, so a stale prefix is harmless
        self.scratch.resize(xs.len() * d, 0.0);
        let mut rows: Vec<&mut [f32]> = self.scratch.chunks_mut(d).collect();
        ne.forward_rows(xs, &mut rows);
        Ok(&self.scratch[..xs.len() * d])
    }

    /// Single-row convenience over `forward_batch` (the allocation sits
    /// at this public boundary only; the decode hot path stays on the
    /// borrowing batch call).
    pub fn forward(
        &mut self,
        layer: usize,
        expert: usize,
        h: &[f32],
        mode: ExpertMode,
    ) -> Result<Vec<f32>> {
        Ok(self.forward_batch(layer, expert, &[h], mode)?.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Mat;
    use crate::util::rng::Rng;

    fn rand_expert(rng: &mut Rng, d: usize, f: usize, rule: Rule) -> NativeExpert {
        let mk = |rng: &mut Rng| {
            let mut m = Mat::zeros(f, d);
            rng.fill_normal_f32(&mut m.data, 0.25);
            m
        };
        NativeExpert {
            w: ExpertWeights { wg_t: mk(rng), wu_t: mk(rng), wd: mk(rng) },
            rule,
        }
    }

    /// The invariant batched decode rests on: under every sparsity rule,
    /// a batch of rows through `forward_rows` is bit-identical to each
    /// row forwarded alone (same per-row op order; the batch only changes
    /// how often weight channels are streamed).
    #[test]
    fn batched_rows_bit_identical_to_solo_under_every_rule() {
        let (d, f, b) = (24, 48, 4);
        let mut rng = Rng::new(9);
        let chess: Vec<f32> = (0..f).map(|_| rng.f32() * 0.3).collect();
        let rules: Vec<Rule> = vec![
            Rule::None,
            Rule::Up(0.2),
            Rule::Gate(0.15),
            Rule::GateChannel(chess),
            Rule::Down(0.1),
        ];
        for rule in rules {
            let ne = rand_expert(&mut rng, d, f, rule);
            let xs_store: Vec<Vec<f32>> = (0..b)
                .map(|_| {
                    let mut x = vec![0.0; d];
                    rng.fill_normal_f32(&mut x, 1.0);
                    x
                })
                .collect();
            let xs: Vec<&[f32]> = xs_store.iter().map(|x| x.as_slice()).collect();
            let mut batched = vec![vec![0.0f32; d]; b];
            {
                let mut ys: Vec<&mut [f32]> =
                    batched.iter_mut().map(|y| y.as_mut_slice()).collect();
                ne.forward_rows(&xs, &mut ys);
            }
            for (x, y) in xs_store.iter().zip(&batched) {
                let mut solo = vec![0.0f32; d];
                {
                    let mut ys: Vec<&mut [f32]> = vec![solo.as_mut_slice()];
                    ne.forward_rows(&[x.as_slice()], &mut ys);
                }
                for (a, c) in solo.iter().zip(y) {
                    assert_eq!(a.to_bits(), c.to_bits());
                }
            }
        }
    }
}

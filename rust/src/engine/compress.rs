//! Native-Rust expert forward with every compression strategy the paper's
//! efficacy evaluation sweeps (Figs 3/9/10, Tables 3-7): per-projection
//! sparsification (up / gate / down), CATS and CHESS baselines, uniform
//! and per-projection HQQ quantization, and the FloE hybrid.
//!
//! The serving hot path uses the HLO graphs; these native experts exist
//! because the sweep space (projection x level x bits) is combinatorial
//! and numerics here are bit-comparable to the references (tested).
//! Materialized (dequantized, channel-major) experts are cached.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;

use crate::config::{ExpertMode, Proj};
use crate::model::Weights;
use crate::tensor::{axpy, dot, silu, ExpertWeights, Mat};

/// Sparsification rule applied inside the expert forward.
enum Rule {
    None,
    /// skip channel when |x·Wu_j| < t (paper Eq. 11)
    Up(f32),
    /// zero SiLU(x·Wg_j) when |SiLU(x·Wg_j)| < t (CATS / L_gate)
    Gate(f32),
    /// per-channel gate thresholds (CHESS)
    GateChannel(Vec<f32>),
    /// zero h_j = g_j * v_j when |h_j| < t (L_down)
    Down(f32),
}

struct NativeExpert {
    w: ExpertWeights,
    rule: Rule,
}

impl NativeExpert {
    fn forward(&self, x: &[f32], y: &mut [f32]) {
        y.iter_mut().for_each(|v| *v = 0.0);
        let f = self.w.f();
        for j in 0..f {
            let (g, v, h) = match &self.rule {
                Rule::Up(t) => {
                    let v = dot(x, self.w.wu_t.row(j));
                    if v.abs() < *t {
                        continue;
                    }
                    let g = silu(dot(x, self.w.wg_t.row(j)));
                    (g, v, g * v)
                }
                Rule::Gate(t) => {
                    let g = silu(dot(x, self.w.wg_t.row(j)));
                    if g.abs() < *t {
                        continue;
                    }
                    let v = dot(x, self.w.wu_t.row(j));
                    (g, v, g * v)
                }
                Rule::GateChannel(ts) => {
                    let g = silu(dot(x, self.w.wg_t.row(j)));
                    if g.abs() < ts[j] {
                        continue;
                    }
                    let v = dot(x, self.w.wu_t.row(j));
                    (g, v, g * v)
                }
                Rule::Down(t) => {
                    let g = silu(dot(x, self.w.wg_t.row(j)));
                    let v = dot(x, self.w.wu_t.row(j));
                    let h = g * v;
                    if h.abs() < *t {
                        continue;
                    }
                    (g, v, h)
                }
                Rule::None => {
                    let g = silu(dot(x, self.w.wg_t.row(j)));
                    let v = dot(x, self.w.wu_t.row(j));
                    (g, v, g * v)
                }
            };
            let _ = (g, v);
            axpy(y, h, self.w.wd.row(j));
        }
    }
}

/// Modes the HLO graph set does not cover (evaluation-only sweeps).
pub fn requires_native(mode: ExpertMode) -> bool {
    matches!(
        mode,
        ExpertMode::CatsGate { .. }
            | ExpertMode::ChessGate { .. }
            | ExpertMode::DownSparse { .. }
            | ExpertMode::QuantProj { .. }
            | ExpertMode::SparseProj { .. }
            | ExpertMode::FloeVar { .. }
    )
}

fn mode_key(mode: ExpertMode) -> (u8, u32, u8) {
    let lv = |l: f64| (l * 1000.0).round() as u32;
    match mode {
        ExpertMode::Dense => (0, 0, 0),
        ExpertMode::Sparse { level } => (1, lv(level), 0),
        ExpertMode::Floe { level } => (2, lv(level), 0),
        ExpertMode::CatsGate { level } => (3, lv(level), 0),
        ExpertMode::ChessGate { level } => (4, lv(level), 0),
        ExpertMode::DownSparse { level } => (5, lv(level), 0),
        ExpertMode::Uniform { bits } => (6, 0, bits),
        ExpertMode::QuantProj { proj, bits } => {
            (7 + proj as u8, 0, bits)
        }
        ExpertMode::SparseProj { proj, level } => (10 + proj as u8, lv(level), 0),
        ExpertMode::FloeVar { level, bits } => (13, lv(level), bits),
    }
}

pub struct NativeExpertCache {
    w: Arc<Weights>,
    cache: HashMap<(usize, usize, (u8, u32, u8)), NativeExpert>,
    scratch: Vec<f32>,
}

impl NativeExpertCache {
    pub fn new(w: Arc<Weights>) -> Self {
        NativeExpertCache { w, cache: HashMap::new(), scratch: Vec::new() }
    }

    pub fn clear(&mut self) {
        self.cache.clear();
    }

    fn dequant_mat(&self, layer: usize, expert: usize, proj: &str, bits: u8) -> Result<Mat> {
        let qv = self.w.proj_q(layer, expert, proj, bits)?;
        let mut out = vec![0.0f32; qv.d * qv.f];
        qv.dequant(&mut out);
        Ok(Mat::from_vec(qv.d, qv.f, out))
    }

    fn materialize(&self, layer: usize, expert: usize, mode: ExpertMode) -> Result<NativeExpert> {
        let cfg = &self.w.cfg;
        let (d, f) = (cfg.d_model, cfg.d_ff);
        let en = |t: &str| Weights::expert_name(layer, expert, t);
        let fp = |name: &str| -> Result<Mat> {
            Ok(Mat::from_vec(
                if name.ends_with("wd") { f } else { d },
                if name.ends_with("wd") { d } else { f },
                self.w.f32(name)?.to_vec(),
            ))
        };
        // start from fp32 matrices, substitute per mode
        let mut wg = fp(&en("wg"))?;
        let mut wu = fp(&en("wu"))?;
        let mut wd = fp(&en("wd"))?;
        let mut rule = Rule::None;
        match mode {
            ExpertMode::Dense => {}
            ExpertMode::Sparse { level } => {
                rule = Rule::Up(self.w.threshold("up", layer, expert, level)?);
            }
            ExpertMode::Floe { level } => {
                // INT2 HQQ up projection + contextual sparsity
                let qv = self.w.up_q(layer, expert)?;
                let mut dq = vec![0.0f32; d * f];
                qv.dequant(&mut dq);
                wu = Mat::from_vec(d, f, dq);
                rule = Rule::Up(self.w.threshold("up", layer, expert, level)?);
            }
            ExpertMode::CatsGate { level } => {
                rule = Rule::Gate(self.w.threshold("gate", layer, expert, level)?);
            }
            ExpertMode::ChessGate { level } => {
                rule = Rule::GateChannel(self.w.chess_thresholds(layer, expert, level)?);
            }
            ExpertMode::DownSparse { level } => {
                rule = Rule::Down(self.w.threshold("down", layer, expert, level)?);
            }
            ExpertMode::Uniform { bits } => {
                wg = self.dequant_mat(layer, expert, "wg", bits)?;
                wu = self.dequant_mat(layer, expert, "wu", bits)?;
                wd = self.dequant_mat(layer, expert, "wd", bits)?;
            }
            ExpertMode::QuantProj { proj, bits } => match proj {
                Proj::Gate => wg = self.dequant_mat(layer, expert, "wg", bits)?,
                Proj::Up => wu = self.dequant_mat(layer, expert, "wu", bits)?,
                Proj::Down => wd = self.dequant_mat(layer, expert, "wd", bits)?,
            },
            ExpertMode::SparseProj { proj, level } => {
                let t = self.w.threshold(proj.key(), layer, expert, level)?;
                rule = match proj {
                    Proj::Up => Rule::Up(t),
                    Proj::Gate => Rule::Gate(t),
                    Proj::Down => Rule::Down(t),
                };
            }
            ExpertMode::FloeVar { level, bits } => {
                wu = self.dequant_mat(layer, expert, "wu", bits)?;
                rule = Rule::Up(self.w.threshold("up", layer, expert, level)?);
            }
        }
        Ok(NativeExpert {
            w: ExpertWeights { wg_t: wg.t(), wu_t: wu.t(), wd },
            rule,
        })
    }

    pub fn forward(
        &mut self,
        layer: usize,
        expert: usize,
        h: &[f32],
        mode: ExpertMode,
    ) -> Result<Vec<f32>> {
        let key = (layer, expert, mode_key(mode));
        if !self.cache.contains_key(&key) {
            let ne = self.materialize(layer, expert, mode)?;
            self.cache.insert(key, ne);
        }
        let ne = self.cache.get(&key).unwrap();
        self.scratch.resize(self.w.cfg.d_model, 0.0);
        let mut y = vec![0.0f32; self.w.cfg.d_model];
        ne.forward(h, &mut y);
        Ok(y)
    }
}

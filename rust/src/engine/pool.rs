//! Persistent kernel worker pool (DESIGN.md §8): disjoint same-boundary
//! expert groups of `Engine::decode_batch` execute concurrently, one
//! expert's weight stream per core.
//!
//! The pool is deliberately dumb: jobs are boxed closures that own their
//! inputs and return a flat output buffer, and `run` returns outputs in
//! *dispatch order* regardless of which worker finished first. All the
//! determinism therefore lives at the call site — the engine dispatches
//! groups in ascending-expert order and combines per sequence in routing
//! order, so batched decode stays bit-identical to the sequential path at
//! any thread count (pinned by tests/batch_decode.rs and the
//! decode_hotpath stub row). Workers are plain `std::thread`s over std
//! mpsc channels: no new dependencies, and the pool survives across
//! decode calls so steady-state dispatch spawns nothing.

use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// One unit of pool work: a closure computing a flat `rows × d_model`
/// output buffer. Closures own everything they touch (cloned activation
/// rows, an `Arc` of the materialized expert), so jobs are `'static` and
/// `Send` by construction.
pub type KernelJob = Box<dyn FnOnce() -> Vec<f32> + Send>;

struct Dispatch {
    idx: usize,
    job: KernelJob,
    reply: Sender<(usize, Vec<f32>)>,
}

/// Fixed-size persistent worker pool over one shared job queue.
pub struct KernelPool {
    tx: Option<Sender<Dispatch>>,
    workers: Vec<JoinHandle<()>>,
}

impl KernelPool {
    /// Spawn `threads` persistent workers (clamped to ≥ 1) sharing one
    /// job queue. Size it from `--kernel-threads` or the available
    /// cores (`Engine` does the latter by default).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Dispatch>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("kernel-pool-{i}"))
                    .spawn(move || loop {
                        // hold the queue lock only for the dequeue, never
                        // across the compute
                        let d = {
                            let q = rx.lock().expect("kernel pool queue poisoned");
                            q.recv()
                        };
                        let Ok(d) = d else { return };
                        let rows = (d.job)();
                        // the dispatcher may have bailed; dropped replies
                        // are fine
                        let _ = d.reply.send((d.idx, rows));
                    })
                    .expect("spawn kernel pool worker")
            })
            .collect();
        KernelPool { tx: Some(tx), workers }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Execute `jobs` across the workers; blocks until all complete and
    /// returns their outputs in dispatch order — NOT completion order —
    /// which is what lets the caller keep a deterministic combine order
    /// at any thread count.
    pub fn run(&self, jobs: Vec<KernelJob>) -> Vec<Vec<f32>> {
        let n = jobs.len();
        let (reply_tx, reply_rx) = channel();
        let tx = self.tx.as_ref().expect("kernel pool closed");
        for (idx, job) in jobs.into_iter().enumerate() {
            tx.send(Dispatch { idx, job, reply: reply_tx.clone() })
                .expect("kernel pool workers exited early");
        }
        drop(reply_tx);
        let mut out: Vec<Option<Vec<f32>>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (idx, rows) = reply_rx
                .recv()
                .expect("kernel pool worker died mid-dispatch");
            out[idx] = Some(rows);
        }
        out.into_iter().map(|r| r.expect("every dispatch replies once")).collect()
    }
}

impl Drop for KernelPool {
    fn drop(&mut self) {
        self.tx.take(); // close the queue: idle workers see Err and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn square_jobs(n: usize) -> Vec<KernelJob> {
        (0..n)
            .map(|i| {
                Box::new(move || vec![(i * i) as f32, i as f32]) as KernelJob
            })
            .collect()
    }

    #[test]
    fn outputs_arrive_in_dispatch_order_at_any_thread_count() {
        for threads in [1, 2, 4, 7] {
            let pool = KernelPool::new(threads);
            assert_eq!(pool.threads(), threads);
            let out = pool.run(square_jobs(16));
            for (i, rows) in out.iter().enumerate() {
                assert_eq!(rows[0], (i * i) as f32, "{threads} threads");
                assert_eq!(rows[1], i as f32);
            }
        }
    }

    #[test]
    fn pool_of_one_matches_inline_bit_exactly() {
        // the decode_hotpath stub-row invariant: a 1-thread pool is the
        // single-threaded computation, routed through a channel
        let inline: Vec<Vec<f32>> =
            square_jobs(8).into_iter().map(|j| j()).collect();
        let pooled = KernelPool::new(1).run(square_jobs(8));
        assert_eq!(inline.len(), pooled.len());
        for (a, b) in inline.iter().zip(&pooled) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn pool_survives_many_dispatch_rounds() {
        let pool = KernelPool::new(3);
        for round in 0..50usize {
            let out = pool.run(square_jobs(round % 5 + 1));
            assert_eq!(out.len(), round % 5 + 1);
        }
    }

    #[test]
    fn empty_dispatch_is_a_noop() {
        let pool = KernelPool::new(2);
        assert!(pool.run(Vec::new()).is_empty());
    }
}

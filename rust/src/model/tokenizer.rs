//! Byte-level tokenizer (vocab 256) matching the Python training setup:
//! token id == byte value. Decoding clamps to printable ASCII for display.

pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn encode(text: &str) -> Vec<u8> {
        text.as_bytes().to_vec()
    }

    pub fn decode(tokens: &[u8]) -> String {
        tokens
            .iter()
            .map(|&b| {
                if (32..127).contains(&b) || b == b'\n' {
                    b as char
                } else {
                    '\u{fffd}'
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let s = "the miller carried a copper kettle.";
        assert_eq!(ByteTokenizer::decode(&ByteTokenizer::encode(s)), s);
    }

    #[test]
    fn non_printable_replaced() {
        assert_eq!(ByteTokenizer::decode(&[0, 200]), "\u{fffd}\u{fffd}");
    }
}

//! Model artifacts: weights.bin + manifest.json loader, byte tokenizer,
//! KV-cache bookkeeping, and typed accessors for every exported tensor.

pub mod tokenizer;

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{ModelConfig, QuantInfo};
use crate::quant::QuantView;
use crate::tensor::{ExpertWeights, Mat};
use crate::util::json::{parse, Json};

#[derive(Clone, Debug)]
pub struct TensorMeta {
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    U8,
    I32,
}

/// The full artifact bundle: raw weight blob + manifest (config, tensor
/// index, thresholds, analysis) loaded once at startup.
pub struct Weights {
    blob: Vec<u8>,
    index: HashMap<String, TensorMeta>,
    pub manifest: Json,
    pub cfg: ModelConfig,
    pub quant: QuantInfo,
}

impl Weights {
    pub fn load(art_dir: &Path) -> Result<Self> {
        let man_path = art_dir.join("manifest.json");
        let text = std::fs::read_to_string(&man_path)
            .with_context(|| format!("reading {man_path:?} (run `make artifacts`)"))?;
        let manifest = parse(&text).map_err(|e| anyhow!("manifest.json: {e}"))?;
        let blob = std::fs::read(art_dir.join("weights.bin"))
            .context("reading weights.bin")?;
        let mut index = HashMap::new();
        let tensors = manifest
            .get("tensors")
            .and_then(Json::as_obj)
            .context("manifest: tensors")?;
        for (name, t) in tensors {
            let dtype = match t.get("dtype").and_then(Json::as_str) {
                Some("f32") => Dtype::F32,
                Some("u8") => Dtype::U8,
                Some("i32") => Dtype::I32,
                other => bail!("tensor {name}: bad dtype {other:?}"),
            };
            let meta = TensorMeta {
                dtype,
                shape: t
                    .get("shape")
                    .and_then(Json::as_f64_vec)
                    .context("shape")?
                    .into_iter()
                    .map(|v| v as usize)
                    .collect(),
                offset: t.get("offset").and_then(Json::as_usize).context("offset")?,
                nbytes: t.get("nbytes").and_then(Json::as_usize).context("nbytes")?,
            };
            if meta.offset + meta.nbytes > blob.len() {
                bail!("tensor {name} out of bounds");
            }
            index.insert(name.clone(), meta);
        }
        let cfg = ModelConfig::from_manifest(&manifest)?;
        let quant = QuantInfo::from_manifest(&manifest)?;
        Ok(Weights { blob, index, manifest, cfg, quant })
    }

    pub fn meta(&self, name: &str) -> Result<&TensorMeta> {
        self.index
            .get(name)
            .ok_or_else(|| anyhow!("tensor not found: {name}"))
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.index.keys()
    }

    /// Borrow an f32 tensor. Offsets are 8-aligned by the exporter.
    pub fn f32(&self, name: &str) -> Result<&[f32]> {
        let m = self.meta(name)?;
        if m.dtype != Dtype::F32 {
            bail!("{name}: not f32");
        }
        let bytes = &self.blob[m.offset..m.offset + m.nbytes];
        debug_assert_eq!(bytes.as_ptr() as usize % 4, 0);
        Ok(unsafe {
            std::slice::from_raw_parts(bytes.as_ptr() as *const f32, m.nbytes / 4)
        })
    }

    pub fn u8(&self, name: &str) -> Result<&[u8]> {
        let m = self.meta(name)?;
        if m.dtype != Dtype::U8 {
            bail!("{name}: not u8");
        }
        Ok(&self.blob[m.offset..m.offset + m.nbytes])
    }

    pub fn shape(&self, name: &str) -> Result<&[usize]> {
        Ok(&self.meta(name)?.shape)
    }

    // ----------------------------------------------------- typed helpers

    pub fn expert_name(layer: usize, expert: usize, t: &str) -> String {
        format!("layer{layer}.expert{expert}.{t}")
    }

    /// FloE INT2-packed up projection view.
    pub fn up_q(&self, layer: usize, expert: usize) -> Result<QuantView<'_>> {
        let base = Self::expert_name(layer, expert, "up_q");
        let codes = self.u8(&base)?;
        let scale = self.f32(&format!("{base}_scale"))?;
        let zero = self.f32(&format!("{base}_zero"))?;
        Ok(QuantView {
            codes,
            scale,
            zero,
            d: self.cfg.d_model,
            f: self.cfg.d_ff,
            group_size: self.quant.group_size,
            bits: 2,
            packed: true,
        })
    }

    /// Uniform-quantized projection view (Fig 3b / Table 7 sweeps).
    pub fn proj_q(&self, layer: usize, expert: usize, proj: &str, bits: u8)
                  -> Result<QuantView<'_>> {
        let base = Self::expert_name(layer, expert, &format!("q{bits}.{proj}"));
        let codes = self.u8(&base)?;
        let scale = self.f32(&format!("{base}_scale"))?;
        let zero = self.f32(&format!("{base}_zero"))?;
        let (d, f) = if proj == "wd" {
            (self.cfg.d_ff, self.cfg.d_model)
        } else {
            (self.cfg.d_model, self.cfg.d_ff)
        };
        Ok(QuantView {
            codes,
            scale,
            zero,
            d,
            f,
            group_size: self.quant.group_size,
            bits,
            packed: false,
        })
    }

    /// Channel-major (compact-layout) native expert weights.
    pub fn expert_native(&self, layer: usize, expert: usize) -> Result<ExpertWeights> {
        let (d, f) = (self.cfg.d_model, self.cfg.d_ff);
        let wg = Mat::from_vec(d, f, self.f32(&Self::expert_name(layer, expert, "wg"))?.to_vec());
        let wu = Mat::from_vec(d, f, self.f32(&Self::expert_name(layer, expert, "wu"))?.to_vec());
        let wd = Mat::from_vec(f, d, self.f32(&Self::expert_name(layer, expert, "wd"))?.to_vec());
        Ok(ExpertWeights { wg_t: wg.t(), wu_t: wu.t(), wd })
    }

    /// Per-expert threshold at a sparsity level for a projection
    /// ("up" | "gate" | "down") — paper Eq. (6), calibrated offline.
    pub fn threshold(&self, proj: &str, layer: usize, expert: usize, level: f64)
                     -> Result<f32> {
        let th = self.manifest.get("thresholds").context("thresholds")?;
        let levels = th.get("levels").and_then(Json::as_f64_vec).context("levels")?;
        let li = levels
            .iter()
            .position(|l| (l - level).abs() < 1e-9)
            .ok_or_else(|| anyhow!("no calibrated level {level}"))?;
        th.get(proj)
            .and_then(|p| p.idx(layer))
            .and_then(|p| p.idx(expert))
            .and_then(|p| p.idx(li))
            .and_then(Json::as_f64)
            .map(|v| v as f32)
            .ok_or_else(|| anyhow!("threshold {proj}[{layer}][{expert}][{li}]"))
    }

    /// CHESS per-channel thresholds for the gate projection.
    pub fn chess_thresholds(&self, layer: usize, expert: usize, level: f64)
                            -> Result<Vec<f32>> {
        let th = self.manifest.get("thresholds").context("thresholds")?;
        let levels = th.get("levels").and_then(Json::as_f64_vec).context("levels")?;
        let li = levels
            .iter()
            .position(|l| (l - level).abs() < 1e-9)
            .ok_or_else(|| anyhow!("no calibrated level {level}"))?;
        th.get("chess_gate")
            .and_then(|p| p.idx(layer))
            .and_then(|p| p.idx(expert))
            .and_then(|p| p.idx(li))
            .and_then(Json::as_f64_vec)
            .map(|v| v.into_iter().map(|x| x as f32).collect())
            .ok_or_else(|| anyhow!("chess threshold [{layer}][{expert}][{li}]"))
    }

    /// Inter-expert predictor weights for layer i -> i+1 (w [d, E], b [E]).
    pub fn predictor(&self, layer: usize) -> Result<(&[f32], &[f32])> {
        Ok((self.f32(&format!("pred{layer}.w"))?, self.f32(&format!("pred{layer}.b"))?))
    }

    pub fn embed_row(&self, token: u8) -> Result<&[f32]> {
        let e = self.f32("embed")?;
        let d = self.cfg.d_model;
        Ok(&e[token as usize * d..(token as usize + 1) * d])
    }
}

/// Fixed-capacity KV cache state for one sequence (host-side mirror; the
/// actual cache tensors live as PJRT literals fed back step to step).
#[derive(Clone, Debug)]
pub struct KvState {
    pub pos: usize,
    pub max_seq: usize,
}

impl KvState {
    pub fn new(max_seq: usize) -> Self {
        KvState { pos: 0, max_seq }
    }
    pub fn advance(&mut self) -> Result<usize> {
        if self.pos >= self.max_seq {
            bail!("KV cache full ({} tokens)", self.max_seq);
        }
        let p = self.pos;
        self.pos += 1;
        Ok(p)
    }
    pub fn remaining(&self) -> usize {
        self.max_seq - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_state_advances_and_fills() {
        let mut kv = KvState::new(3);
        assert_eq!(kv.advance().unwrap(), 0);
        assert_eq!(kv.advance().unwrap(), 1);
        assert_eq!(kv.remaining(), 1);
        assert_eq!(kv.advance().unwrap(), 2);
        assert!(kv.advance().is_err());
    }

    #[test]
    fn expert_name_format() {
        assert_eq!(Weights::expert_name(2, 5, "wg"), "layer2.expert5.wg");
    }
}

//! Rust mirror of the HQQ group-wise affine quantization layout
//! (python/compile/hqq.py): unpack INT2 4-per-byte codes, dequantize
//! arbitrary-bit codes, and account transfer bytes the way the paper's
//! compression ratios do (codes at `bits` wide + fp16 scale/zero).
//!
//! Quantization itself happens at build time in Python; the request path
//! only ever unpacks/dequantizes.

/// Group-wise affine quantized matrix view (borrowed from weights.bin).
#[derive(Clone, Copy)]
pub struct QuantView<'a> {
    /// u8 codes [d, f] (unpacked) — or packed int2 [d/4, f] via `packed`.
    pub codes: &'a [u8],
    pub scale: &'a [f32],
    pub zero: &'a [f32],
    pub d: usize,
    pub f: usize,
    pub group_size: usize,
    pub bits: u8,
    pub packed: bool,
}

/// Walk packed INT2 codes (4 per byte along the input axis) in canonical
/// order, yielding `(input_row, col, code)` to the visitor. This is the
/// single bit-unpacking code path: `unpack_int2` and the packed branch of
/// `QuantView::dequant` are both thin adapters over it, so the walk order
/// (packed row, sub-row shift, column) exists exactly once.
fn walk_int2(packed: &[u8], d: usize, f: usize, mut visit: impl FnMut(usize, usize, u8)) {
    assert_eq!(packed.len(), d / 4 * f);
    for pr in 0..d / 4 {
        for (k, shift) in [0u8, 2, 4, 6].iter().enumerate() {
            let i = pr * 4 + k;
            for j in 0..f {
                visit(i, j, (packed[pr * f + j] >> *shift) & 3);
            }
        }
    }
}

impl<'a> QuantView<'a> {
    /// Dequantize into `out` ([d, f] row-major f32).
    pub fn dequant(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.d * self.f);
        if self.packed {
            assert_eq!(self.bits, 2);
            walk_int2(self.codes, self.d, self.f, |i, j, code| {
                let gi = i / self.group_size;
                let s = self.scale[gi * self.f + j];
                let z = self.zero[gi * self.f + j];
                out[i * self.f + j] = (code as f32 - z) * s;
            });
        } else {
            assert_eq!(self.codes.len(), self.d * self.f);
            for i in 0..self.d {
                let gi = i / self.group_size;
                for j in 0..self.f {
                    let code = self.codes[i * self.f + j];
                    let s = self.scale[gi * self.f + j];
                    let z = self.zero[gi * self.f + j];
                    out[i * self.f + j] = (code as f32 - z) * s;
                }
            }
        }
    }

    /// Bytes moved over PCIe for this matrix: codes at `bits` wide plus
    /// fp16 scale and zero per (group, column).
    pub fn transfer_bytes(&self) -> usize {
        (self.d * self.f * self.bits as usize + 7) / 8 + 2 * 2 * self.scale.len()
    }
}

/// Unpack INT2 codes (4 per byte along the input axis) into u8 [d, f].
pub fn unpack_int2(packed: &[u8], d: usize, f: usize) -> Vec<u8> {
    let mut out = vec![0u8; d * f];
    walk_int2(packed, d, f, |i, j, code| out[i * f + j] = code);
    out
}

/// Transfer-size accounting for a dense fp16 matrix (the paper's baseline
/// unit: experts move as fp16 over PCIe).
pub fn fp16_bytes(rows: usize, cols: usize) -> usize {
    rows * cols * 2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn pack(codes: &[u8], d: usize, f: usize) -> Vec<u8> {
        let mut out = vec![0u8; d / 4 * f];
        for pr in 0..d / 4 {
            for j in 0..f {
                let mut b = 0u8;
                for k in 0..4 {
                    b |= codes[(pr * 4 + k) * f + j] << (2 * k);
                }
                out[pr * f + j] = b;
            }
        }
        out
    }

    #[test]
    fn unpack_roundtrip() {
        let mut rng = Rng::new(1);
        let (d, f) = (16, 8);
        let codes: Vec<u8> = (0..d * f).map(|_| rng.below(4) as u8).collect();
        let packed = pack(&codes, d, f);
        assert_eq!(unpack_int2(&packed, d, f), codes);
    }

    #[test]
    fn dequant_packed_matches_unpacked() {
        let mut rng = Rng::new(2);
        let (d, f, g) = (32, 8, 16);
        let codes: Vec<u8> = (0..d * f).map(|_| rng.below(4) as u8).collect();
        let packed = pack(&codes, d, f);
        let scale: Vec<f32> = (0..d / g * f).map(|_| rng.f32() + 0.01).collect();
        let zero: Vec<f32> = (0..d / g * f).map(|_| rng.f32() * 3.0).collect();
        let qv_p = QuantView {
            codes: &packed, scale: &scale, zero: &zero,
            d, f, group_size: g, bits: 2, packed: true,
        };
        let qv_u = QuantView {
            codes: &codes, scale: &scale, zero: &zero,
            d, f, group_size: g, bits: 2, packed: false,
        };
        let mut a = vec![0.0; d * f];
        let mut b = vec![0.0; d * f];
        qv_p.dequant(&mut a);
        qv_u.dequant(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn transfer_bytes_int2() {
        let codes = vec![0u8; 64 / 4 * 128];
        let scale = vec![0.0f32; 2 * 128];
        let zero = vec![0.0f32; 2 * 128];
        let qv = QuantView {
            codes: &codes, scale: &scale, zero: &zero,
            d: 64, f: 128, group_size: 32, bits: 2, packed: true,
        };
        assert_eq!(qv.transfer_bytes(), 64 * 128 / 4 + 2 * 2 * 2 * 128);
    }
}

//! Contextual-sparsity helpers: channel masks from up-projection
//! activations (paper Eq. 5/11) and mask statistics used by the
//! coordinator's prefetch planner.

/// mask[j] = |v[j]| >= t  (the channels that survive S_t).
pub fn mask_from_activations(v: &[f32], t: f32) -> Vec<bool> {
    v.iter().map(|x| x.abs() >= t).collect()
}

/// CHESS-style per-channel thresholds.
pub fn mask_per_channel(v: &[f32], t: &[f32]) -> Vec<bool> {
    debug_assert_eq!(v.len(), t.len());
    v.iter().zip(t).map(|(x, ti)| x.abs() >= *ti).collect()
}

pub fn active_count(mask: &[bool]) -> usize {
    mask.iter().filter(|m| **m).count()
}

pub fn density(mask: &[bool]) -> f64 {
    active_count(mask) as f64 / mask.len().max(1) as f64
}

/// Recall of a predicted mask vs the true mask (paper Fig 4 yellow line):
/// |pred ∩ true| / |true|.
pub fn mask_recall(pred: &[bool], truth: &[bool]) -> f64 {
    let inter = pred
        .iter()
        .zip(truth)
        .filter(|(p, t)| **p && **t)
        .count();
    let tot: usize = truth.iter().filter(|t| **t).count();
    if tot == 0 {
        1.0
    } else {
        inter as f64 / tot as f64
    }
}

/// Union of per-token masks — what the prefetcher must actually move when
/// several tokens in a batch hit the same expert.
pub fn mask_union(masks: &[Vec<bool>]) -> Vec<bool> {
    let n = masks[0].len();
    let mut out = vec![false; n];
    for m in masks {
        for (o, v) in out.iter_mut().zip(m) {
            *o |= *v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_mask() {
        let v = [0.1f32, -0.5, 0.3, -0.05];
        let m = mask_from_activations(&v, 0.3);
        assert_eq!(m, vec![false, true, true, false]);
        assert_eq!(active_count(&m), 2);
        assert!((density(&m) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn per_channel_mask() {
        let v = [0.1f32, -0.5];
        let t = [0.2f32, 0.6];
        assert_eq!(mask_per_channel(&v, &t), vec![false, false]);
    }

    #[test]
    fn recall_bounds() {
        let truth = vec![true, true, false, false];
        assert_eq!(mask_recall(&[true, true, true, true], &truth), 1.0);
        assert_eq!(mask_recall(&[false, true, false, false], &truth), 0.5);
        assert_eq!(mask_recall(&[false; 4], &[false; 4]), 1.0);
    }

    #[test]
    fn union() {
        let u = mask_union(&[vec![true, false], vec![false, false], vec![false, true]]);
        assert_eq!(u, vec![true, true]);
    }
}

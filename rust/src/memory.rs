//! VRAM accounting + expert cache (paper Fig 1(b)/(c) "expert cache").
//!
//! The cache is byte-budgeted (VRAM minus resident weights/KV), keyed by
//! (layer, expert), with LRU eviction and prediction-aware pinning: entries
//! pinned by the prefetcher for the imminent layer are never evicted.
//! Invariants (enforced + property-tested): used <= budget at all times;
//! pinned entries survive eviction; hit/miss accounting is exact.

use std::collections::HashMap;

pub type ExpertKey = (usize, usize); // (layer, expert)

#[derive(Debug, Clone)]
struct Entry {
    bytes: usize,
    pinned: bool,
    /// LRU clock stamp
    last_use: u64,
}

#[derive(Debug, Default, Clone)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub inserted_bytes: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let tot = self.hits + self.misses;
        if tot == 0 {
            0.0
        } else {
            self.hits as f64 / tot as f64
        }
    }
}

pub struct ExpertCache {
    budget: usize,
    used: usize,
    clock: u64,
    entries: HashMap<ExpertKey, Entry>,
    pub stats: CacheStats,
}

impl ExpertCache {
    pub fn new(budget_bytes: usize) -> Self {
        ExpertCache {
            budget: budget_bytes,
            used: 0,
            clock: 0,
            entries: HashMap::new(),
            stats: CacheStats::default(),
        }
    }

    pub fn budget(&self) -> usize {
        self.budget
    }
    pub fn used(&self) -> usize {
        self.used
    }
    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
    pub fn contains(&self, key: ExpertKey) -> bool {
        self.entries.contains_key(&key)
    }

    /// Record an access; returns true on hit (and refreshes LRU position).
    pub fn access(&mut self, key: ExpertKey) -> bool {
        self.clock += 1;
        if let Some(e) = self.entries.get_mut(&key) {
            e.last_use = self.clock;
            self.stats.hits += 1;
            true
        } else {
            self.stats.misses += 1;
            false
        }
    }

    /// Insert (or resize) an entry, evicting LRU unpinned entries as
    /// needed. Returns false if the entry cannot fit even after evicting
    /// everything unpinned.
    pub fn insert(&mut self, key: ExpertKey, bytes: usize) -> bool {
        self.clock += 1;
        if let Some(old) = self.entries.remove(&key) {
            self.used -= old.bytes;
        }
        if bytes > self.budget {
            return false;
        }
        while self.used + bytes > self.budget {
            if !self.evict_lru() {
                return false;
            }
        }
        self.used += bytes;
        self.stats.inserted_bytes += bytes as u64;
        self.entries.insert(
            key,
            Entry { bytes, pinned: false, last_use: self.clock },
        );
        true
    }

    /// Pin/unpin an entry (prefetched-for-imminent-use protection).
    pub fn set_pinned(&mut self, key: ExpertKey, pinned: bool) {
        if let Some(e) = self.entries.get_mut(&key) {
            e.pinned = pinned;
        }
    }

    pub fn unpin_all(&mut self) {
        for e in self.entries.values_mut() {
            e.pinned = false;
        }
    }

    fn evict_lru(&mut self) -> bool {
        let victim = self
            .entries
            .iter()
            .filter(|(_, e)| !e.pinned)
            .min_by_key(|(_, e)| e.last_use)
            .map(|(k, _)| *k);
        match victim {
            Some(k) => {
                let e = self.entries.remove(&k).unwrap();
                self.used -= e.bytes;
                self.stats.evictions += 1;
                true
            }
            None => false,
        }
    }

    pub fn keys(&self) -> Vec<ExpertKey> {
        self.entries.keys().copied().collect()
    }
}

/// Simulated pinned staging-buffer pool for the transfer engine: fixed
/// number of fixed-size buffers, blocking acquire models back-pressure.
pub struct PinnedPool {
    buf_bytes: usize,
    free: Vec<usize>,
    total: usize,
}

impl PinnedPool {
    pub fn new(n_buffers: usize, buf_bytes: usize) -> Self {
        PinnedPool { buf_bytes, free: (0..n_buffers).collect(), total: n_buffers }
    }
    pub fn buf_bytes(&self) -> usize {
        self.buf_bytes
    }
    pub fn try_acquire(&mut self) -> Option<usize> {
        self.free.pop()
    }
    pub fn release(&mut self, id: usize) {
        debug_assert!(id < self.total && !self.free.contains(&id));
        self.free.push(id);
    }
    pub fn available(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn hit_miss_and_lru() {
        let mut c = ExpertCache::new(300);
        assert!(!c.access((0, 0)));
        assert!(c.insert((0, 0), 100));
        assert!(c.insert((0, 1), 100));
        assert!(c.insert((0, 2), 100));
        assert!(c.access((0, 0))); // refresh 0 → LRU victim is (0,1)
        assert!(c.insert((1, 0), 100));
        assert!(c.contains((0, 0)));
        assert!(!c.contains((0, 1)));
        assert_eq!(c.stats.evictions, 1);
    }

    #[test]
    fn pinned_survives() {
        let mut c = ExpertCache::new(200);
        c.insert((0, 0), 100);
        c.set_pinned((0, 0), true);
        c.insert((0, 1), 100);
        assert!(c.insert((0, 2), 100)); // must evict (0,1), not pinned (0,0)
        assert!(c.contains((0, 0)));
        assert!(!c.contains((0, 1)));
    }

    #[test]
    fn cannot_fit_oversize() {
        let mut c = ExpertCache::new(100);
        assert!(!c.insert((0, 0), 101));
        assert_eq!(c.used(), 0);
    }

    #[test]
    fn all_pinned_blocks_insert() {
        let mut c = ExpertCache::new(100);
        c.insert((0, 0), 100);
        c.set_pinned((0, 0), true);
        assert!(!c.insert((0, 1), 50));
        assert!(c.contains((0, 0)));
    }

    #[test]
    fn prop_budget_never_exceeded() {
        check("cache-budget", 50, |rng: &mut Rng| {
            let budget = rng.range(100, 2000);
            let mut c = ExpertCache::new(budget);
            for _ in 0..200 {
                let key = (rng.below(4), rng.below(8));
                match rng.below(4) {
                    0 => {
                        c.access(key);
                    }
                    1 => {
                        c.insert(key, rng.range(1, budget / 2 + 2));
                    }
                    2 => c.set_pinned(key, rng.f64() < 0.5),
                    _ => c.unpin_all(),
                }
                prop_assert!(
                    c.used() <= c.budget(),
                    "used {} > budget {}",
                    c.used(),
                    c.budget()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_used_equals_sum_of_entries() {
        check("cache-used-sum", 30, |rng: &mut Rng| {
            let mut c = ExpertCache::new(1000);
            let mut shadow: std::collections::HashMap<ExpertKey, usize> =
                Default::default();
            for _ in 0..100 {
                let key = (rng.below(3), rng.below(4));
                let bytes = rng.range(1, 300);
                if c.insert(key, bytes) {
                    shadow.insert(key, bytes);
                }
                // drop shadow entries evicted by the cache
                shadow.retain(|k, _| c.contains(*k));
                let sum: usize = shadow.values().sum();
                prop_assert!(
                    sum == c.used(),
                    "shadow {} != used {}",
                    sum,
                    c.used()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn pinned_pool_cycle() {
        let mut p = PinnedPool::new(2, 64);
        let a = p.try_acquire().unwrap();
        let b = p.try_acquire().unwrap();
        assert!(p.try_acquire().is_none());
        p.release(a);
        assert_eq!(p.available(), 1);
        p.release(b);
        assert_eq!(p.available(), 2);
    }
}

//! Minimal f32 tensor math for the native (non-PJRT) compute paths:
//! the Fiddler-style CPU expert, the Table-1 sparse-GEMV measurements,
//! predictors, and cross-checks against the HLO executables.
//!
//! The expert weight layout here *is* the paper's compact layout (Fig 5):
//! every matrix is stored channel-major — row `j` holds channel `j`'s
//! d-vector — so gate column j, up column j and down row j are each
//! contiguous, and a channel's bytes can be packed/transferred as a unit.

/// Dense row-major matrix [rows, cols].
#[derive(Clone, Debug)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Transpose into a new matrix.
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: ~2x over the naive loop on 1 core,
    // and deterministic summation order (perf pass, EXPERIMENTS.md §Perf).
    let n = a.len();
    let mut s0 = 0.0f32;
    let mut s1 = 0.0f32;
    let mut s2 = 0.0f32;
    let mut s3 = 0.0f32;
    let chunks = n / 4;
    for i in 0..chunks {
        let k = i * 4;
        s0 += a[k] * b[k];
        s1 += a[k + 1] * b[k + 1];
        s2 += a[k + 2] * b[k + 2];
        s3 += a[k + 3] * b[k + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for k in chunks * 4..n {
        s += a[k] * b[k];
    }
    s
}

#[inline]
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// y[j] = dot(x, W.row(j)) for all rows — a GEMV against a channel-major
/// matrix ("every output channel's weights contiguous").
pub fn gemv_channel_major(x: &[f32], w: &Mat, out: &mut [f32]) {
    debug_assert_eq!(w.cols, x.len());
    debug_assert_eq!(w.rows, out.len());
    for (j, o) in out.iter_mut().enumerate() {
        *o = dot(x, w.row(j));
    }
}

/// Multi-row batched GEMV against a channel-major matrix — the rule-free
/// primitive of boundary-synchronous batched decode (the decode path
/// itself runs `engine::compress::NativeExpert::forward_rows`, the same
/// blocking with the sparsity rules folded in; this is the public mirror
/// the benches measure): each weight row is streamed once per *batch* and
/// every activation row rides it while it is hot, instead of re-streaming
/// the whole matrix per row. Row `b`'s outputs are bit-identical to
/// `gemv_channel_major(xs[b], w, outs[b])` — the inner accumulation is
/// the same 4-way-unrolled `dot` in the same channel order, so batching
/// changes scheduling, never values.
pub fn gemm_channel_major(xs: &[&[f32]], w: &Mat, outs: &mut [&mut [f32]]) {
    debug_assert_eq!(xs.len(), outs.len());
    for j in 0..w.rows {
        let row = w.row(j);
        for (x, out) in xs.iter().zip(outs.iter_mut()) {
            debug_assert_eq!(x.len(), w.cols);
            out[j] = dot(x, row);
        }
    }
}

/// Channel-major expert weights (the compact layout of paper Fig 5).
#[derive(Clone)]
pub struct ExpertWeights {
    /// gate columns as rows: [f, d]
    pub wg_t: Mat,
    /// up columns as rows: [f, d]
    pub wu_t: Mat,
    /// down rows: [f, d] (already channel-major in the model)
    pub wd: Mat,
}

// The kernel-pool contract (engine::pool): materialized expert weights
// are plain owned buffers, so a shared expert may be read from worker
// threads while other experts dispatch. A field that broke this (an Rc,
// a raw device handle) would fail here at compile time, not at 3am.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Mat>();
    assert_send_sync::<ExpertWeights>();
};

impl ExpertWeights {
    pub fn d(&self) -> usize {
        self.wg_t.cols
    }
    pub fn f(&self) -> usize {
        self.wg_t.rows
    }

    /// Paper Eq. (1), dense: y = (SiLU(x Wg) ⊙ (x Wu)) Wd.
    pub fn forward_dense(&self, x: &[f32], y: &mut [f32]) {
        y.iter_mut().for_each(|v| *v = 0.0);
        for j in 0..self.f() {
            let v = dot(x, self.wu_t.row(j));
            let g = silu(dot(x, self.wg_t.row(j)));
            axpy(y, g * v, self.wd.row(j));
        }
    }

    /// Dense forward over a batch of activation rows: channel j's gate/up
    /// columns and down row are streamed once per *batch* and applied to
    /// every row while hot (same-boundary GEMV sharing). Per row the op
    /// order matches `forward_dense` exactly, so each row's output is
    /// bit-identical to a solo call — the invariant batched decode pins.
    pub fn forward_dense_batch(&self, xs: &[&[f32]], ys: &mut [&mut [f32]]) {
        debug_assert_eq!(xs.len(), ys.len());
        for y in ys.iter_mut() {
            y.iter_mut().for_each(|v| *v = 0.0);
        }
        for j in 0..self.f() {
            let wu = self.wu_t.row(j);
            let wg = self.wg_t.row(j);
            let wd = self.wd.row(j);
            for (x, y) in xs.iter().zip(ys.iter_mut()) {
                let v = dot(x, wu);
                let g = silu(dot(x, wg));
                axpy(y, g * v, wd);
            }
        }
    }

    /// Paper Algorithm 1 with *real* channel skipping: channels whose
    /// |x·Wu_j| < t skip the gate GEMV and the down accumulation entirely.
    /// Returns the number of active channels.
    pub fn forward_sparse(&self, x: &[f32], t: f32, y: &mut [f32]) -> usize {
        y.iter_mut().for_each(|v| *v = 0.0);
        let mut active = 0;
        for j in 0..self.f() {
            let v = dot(x, self.wu_t.row(j));
            if v.abs() < t {
                continue; // skipped: no gate column load, no down row load
            }
            active += 1;
            let g = silu(dot(x, self.wg_t.row(j)));
            axpy(y, g * v, self.wd.row(j));
        }
        active
    }

    /// Sparse forward over a batch of rows (paper Algorithm 1 / Rule-Up —
    /// the FloE-path rule): channel j's gate/up columns and down row
    /// stream once per batch, and each row applies its own
    /// |x·Wu_j| < t skip. Per row the op order matches `forward_sparse`
    /// exactly, so each row's output is bit-identical to a solo call.
    /// Returns the number of active (row, channel) pairs. This is the
    /// public mirror of `NativeExpert::forward_rows`'s Up rule, measured
    /// by benches/decode_hotpath.rs for the reuse calibration.
    pub fn forward_sparse_batch(
        &self,
        xs: &[&[f32]],
        t: f32,
        ys: &mut [&mut [f32]],
    ) -> usize {
        debug_assert_eq!(xs.len(), ys.len());
        for y in ys.iter_mut() {
            y.iter_mut().for_each(|v| *v = 0.0);
        }
        let mut active = 0;
        for j in 0..self.f() {
            let wu = self.wu_t.row(j);
            let wg = self.wg_t.row(j);
            let wd = self.wd.row(j);
            for (x, y) in xs.iter().zip(ys.iter_mut()) {
                let v = dot(x, wu);
                if v.abs() < t {
                    continue;
                }
                active += 1;
                let g = silu(dot(x, wg));
                axpy(y, g * v, wd);
            }
        }
        active
    }

    /// Sparse forward with a *precomputed* channel mask (the intra-expert
    /// predictor path: mask known before the weights even arrive).
    pub fn forward_masked(&self, x: &[f32], mask: &[bool], y: &mut [f32]) -> usize {
        y.iter_mut().for_each(|v| *v = 0.0);
        let mut active = 0;
        for j in 0..self.f() {
            if !mask[j] {
                continue;
            }
            active += 1;
            let v = dot(x, self.wu_t.row(j));
            let g = silu(dot(x, self.wg_t.row(j)));
            axpy(y, g * v, self.wd.row(j));
        }
        active
    }
}

pub fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (ms + eps).sqrt();
    for ((o, xi), wi) in out.iter_mut().zip(x).zip(w) {
        *o = xi * r * wi;
    }
}

pub fn softmax_inplace(x: &mut [f32]) {
    let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut s = 0.0;
    for v in x.iter_mut() {
        *v = (*v - m).exp();
        s += *v;
    }
    for v in x.iter_mut() {
        *v /= s;
    }
}

/// Indices of the k largest values (ties broken by lower index).
pub fn top_k(x: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..x.len()).collect();
    idx.sort_by(|&a, &b| x[b].partial_cmp(&x[a]).unwrap().then(a.cmp(&b)));
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_expert(rng: &mut Rng, d: usize, f: usize) -> (Vec<f32>, ExpertWeights) {
        let mut x = vec![0.0; d];
        rng.fill_normal_f32(&mut x, 1.0);
        let mk = |rng: &mut Rng| {
            let mut m = Mat::zeros(f, d);
            rng.fill_normal_f32(&mut m.data, 0.2);
            m
        };
        (x, ExpertWeights { wg_t: mk(rng), wu_t: mk(rng), wd: mk(rng) })
    }

    #[test]
    fn sparse_t0_equals_dense() {
        let mut rng = Rng::new(1);
        let (x, ew) = rand_expert(&mut rng, 32, 64);
        let mut yd = vec![0.0; 32];
        let mut ys = vec![0.0; 32];
        ew.forward_dense(&x, &mut yd);
        let active = ew.forward_sparse(&x, 0.0, &mut ys);
        assert_eq!(active, 64);
        for (a, b) in yd.iter().zip(&ys) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn sparse_huge_t_zero() {
        let mut rng = Rng::new(2);
        let (x, ew) = rand_expert(&mut rng, 16, 32);
        let mut y = vec![1.0; 16];
        let active = ew.forward_sparse(&x, 1e9, &mut y);
        assert_eq!(active, 0);
        assert!(y.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn masked_matches_sparse() {
        let mut rng = Rng::new(3);
        let (x, ew) = rand_expert(&mut rng, 32, 64);
        let t = 0.25;
        let mut ys = vec![0.0; 32];
        ew.forward_sparse(&x, t, &mut ys);
        let mask: Vec<bool> = (0..64)
            .map(|j| dot(&x, ew.wu_t.row(j)).abs() >= t)
            .collect();
        let mut ym = vec![0.0; 32];
        ew.forward_masked(&x, &mask, &mut ym);
        for (a, b) in ys.iter().zip(&ym) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn gemm_rows_bit_identical_to_gemv() {
        let mut rng = Rng::new(11);
        let (d, f, b) = (48, 96, 5);
        let mut w = Mat::zeros(f, d);
        rng.fill_normal_f32(&mut w.data, 0.3);
        let xs_store: Vec<Vec<f32>> = (0..b)
            .map(|_| {
                let mut x = vec![0.0; d];
                rng.fill_normal_f32(&mut x, 1.0);
                x
            })
            .collect();
        let xs: Vec<&[f32]> = xs_store.iter().map(|x| x.as_slice()).collect();
        let mut batched = vec![vec![0.0f32; f]; b];
        {
            let mut outs: Vec<&mut [f32]> =
                batched.iter_mut().map(|o| o.as_mut_slice()).collect();
            gemm_channel_major(&xs, &w, &mut outs);
        }
        for (x, out) in xs.iter().zip(&batched) {
            let mut solo = vec![0.0f32; f];
            gemv_channel_major(x, &w, &mut solo);
            for (a, c) in solo.iter().zip(out) {
                assert_eq!(a.to_bits(), c.to_bits());
            }
        }
    }

    #[test]
    fn dense_batch_bit_identical_to_solo_forward() {
        let mut rng = Rng::new(12);
        let (d, f, b) = (32, 64, 4);
        let mk = |rng: &mut Rng| {
            let mut m = Mat::zeros(f, d);
            rng.fill_normal_f32(&mut m.data, 0.2);
            m
        };
        let ew = ExpertWeights { wg_t: mk(&mut rng), wu_t: mk(&mut rng), wd: mk(&mut rng) };
        let xs_store: Vec<Vec<f32>> = (0..b)
            .map(|_| {
                let mut x = vec![0.0; d];
                rng.fill_normal_f32(&mut x, 1.0);
                x
            })
            .collect();
        let xs: Vec<&[f32]> = xs_store.iter().map(|x| x.as_slice()).collect();
        let mut batched = vec![vec![0.0f32; d]; b];
        {
            let mut ys: Vec<&mut [f32]> =
                batched.iter_mut().map(|y| y.as_mut_slice()).collect();
            ew.forward_dense_batch(&xs, &mut ys);
        }
        for (x, y) in xs.iter().zip(&batched) {
            let mut solo = vec![0.0f32; d];
            ew.forward_dense(x, &mut solo);
            for (a, c) in solo.iter().zip(y) {
                assert_eq!(a.to_bits(), c.to_bits(), "batched row diverged from solo");
            }
        }
        // batch of one is exactly the solo kernel too
        let mut one = vec![0.0f32; d];
        {
            let mut ys: Vec<&mut [f32]> = vec![one.as_mut_slice()];
            ew.forward_dense_batch(&xs[..1], &mut ys);
        }
        assert_eq!(one, batched[0]);
    }

    #[test]
    fn sparse_batch_bit_identical_to_solo_and_counts_active() {
        let mut rng = Rng::new(13);
        let (d, f, b) = (32, 64, 4);
        let mk = |rng: &mut Rng| {
            let mut m = Mat::zeros(f, d);
            rng.fill_normal_f32(&mut m.data, 0.2);
            m
        };
        let ew = ExpertWeights { wg_t: mk(&mut rng), wu_t: mk(&mut rng), wd: mk(&mut rng) };
        let xs_store: Vec<Vec<f32>> = (0..b)
            .map(|_| {
                let mut x = vec![0.0; d];
                rng.fill_normal_f32(&mut x, 1.0);
                x
            })
            .collect();
        let xs: Vec<&[f32]> = xs_store.iter().map(|x| x.as_slice()).collect();
        let t = 0.3;
        let mut batched = vec![vec![0.0f32; d]; b];
        let active_batch = {
            let mut ys: Vec<&mut [f32]> =
                batched.iter_mut().map(|y| y.as_mut_slice()).collect();
            ew.forward_sparse_batch(&xs, t, &mut ys)
        };
        let mut active_solo = 0;
        for (x, y) in xs_store.iter().zip(&batched) {
            let mut solo = vec![0.0f32; d];
            active_solo += ew.forward_sparse(x, t, &mut solo);
            for (a, c) in solo.iter().zip(y) {
                assert_eq!(a.to_bits(), c.to_bits());
            }
        }
        assert_eq!(active_batch, active_solo);
        assert!(active_batch > 0 && active_batch < b * f, "threshold inert: {active_batch}");
    }

    #[test]
    fn topk_and_softmax() {
        let mut v = vec![1.0f32, 3.0, 2.0];
        assert_eq!(top_k(&v, 2), vec![1, 2]);
        softmax_inplace(&mut v);
        assert!((v.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(v[1] > v[2] && v[2] > v[0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let tt = m.t().t();
        assert_eq!(tt.data, m.data);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = vec![3.0f32, -3.0, 3.0, -3.0];
        let w = vec![1.0f32; 4];
        let mut out = vec![0.0; 4];
        rmsnorm(&x, &w, 0.0, &mut out);
        for v in out {
            assert!((v.abs() - 1.0).abs() < 1e-6);
        }
    }
}

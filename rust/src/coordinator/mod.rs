//! L3 coordination — the paper's system contribution.
//!
//! * `policy` — the system design space: FloE vs the four baselines
//!   (DeepSpeed-MII-style naive offload, Mixtral-Offloading-style advanced
//!   offload, Fiddler CPU co-execution, fully GPU-resident INT2).
//! * `sim` — discrete-event end-to-end decode simulation at arbitrary
//!   model scale over the hwsim hardware models; regenerates Figs 6/8.
//! * `serve` — the *real* serving pipeline on the in-repo model: request
//!   queue, interleaved continuous batching, FloE prefetch pipeline
//!   (dual predictors + expert cache + compact transfers) driving the
//!   PJRT engine, with a simulated PCIe clock accounted alongside real
//!   compute time.

pub mod policy;
pub mod serve;
pub mod sim;

//! L3 coordination — the paper's system contribution.
//!
//! * `policy` — the system design space: FloE vs the four baselines
//!   (DeepSpeed-MII-style naive offload, Mixtral-Offloading-style advanced
//!   offload, Fiddler CPU co-execution, fully GPU-resident INT2).
//! * `events` — the discrete-event core: a deterministic time-ordered
//!   heap (transfer-complete, gemv-complete, boundary-barrier,
//!   request-arrival) the simulator produces into and consumes from.
//! * `sim` — discrete-event end-to-end decode simulation at arbitrary
//!   model scale over the hwsim hardware models; regenerates Figs 6/8,
//!   and hosts the batched-serving simulator behind `exp-serve-load`.
//! * `sched` — the continuous-batching scheduler (FIFO admission queue,
//!   token-boundary joins, per-request stall/queue accounting) shared by
//!   the real serving path and the simulator via the `SeqBackend` trait.
//! * `serve` — the *real* serving pipeline on the in-repo model: the
//!   FloE prefetch pipeline (dual predictors + expert cache + compact
//!   transfers) driving the PJRT engine one token at a time, with a
//!   simulated PCIe clock accounted alongside real compute time.
//! * `timeline` — deterministic record/replay of serving sessions as
//!   versioned byte artifacts (scheduler decisions + event-core pops +
//!   per-request accounting), plus the per-request inspector behind the
//!   server's `stats` command and `floe record`/`floe replay`.
//! * `cluster` — the multi-node tier above the store (DESIGN.md §10): a
//!   deterministic router spreading workload arrivals across N node
//!   coordinators with pluggable placement, cross-node expert pulls
//!   over the latency-dominated network link, and failure re-homing.

pub mod cluster;
pub mod events;
pub mod policy;
pub mod sched;
pub mod serve;
pub mod sim;
pub mod timeline;

//! Discrete-event core for the simulator (DESIGN.md §8).
//!
//! A time-ordered min-heap of simulation events. The decode simulator
//! (`coordinator::sim`) produces events — transfer completions, GEMV
//! completions, layer-boundary barriers — and consumes them in time
//! order; the serving driver feeds request arrivals through the same
//! structure. With overlap modeling off the producers push and pop one
//! event at a time, so the event core replays the busy-until timelines
//! it replaced *bit-exactly*; with `--overlap` on, a transfer that
//! completes mid-boundary pops before later-ready work and releases its
//! waiting expert GEMV early instead of charging the full stall at the
//! barrier.
//!
//! Determinism: events are ordered by `f64::total_cmp` on their time
//! stamp, ties broken by push order (a monotonic sequence number), so a
//! heap fed the same events in the same order pops the same sequence —
//! there is no hash-map or pointer-identity iteration anywhere. An
//! opt-in byte log records every popped event (kind tag + time bits +
//! payload id); two runs with the same seed and config must produce
//! byte-identical logs, which the determinism tests assert.

use std::collections::BinaryHeap;

/// What kind of simulated completion an event marks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// An expert transfer (prefetch, demand fetch or intra top-up)
    /// finished landing on its destination device.
    TransferComplete,
    /// An expert GEMV finished on its execution device.
    GemvComplete,
    /// A layer boundary barrier: every routed expert's output is ready
    /// and the token clock advances past the slowest stream.
    BoundaryBarrier,
    /// A serving request reached its workload arrival time.
    RequestArrival,
    /// Failure injection (cluster tier, DESIGN.md §10): the node hosting
    /// this event core dropped out of the cluster at `t_us`. `id` is the
    /// cluster-level `NodeId`.
    NodeDown,
    /// Quality-elastic fallback (DESIGN.md §11): a routed expert
    /// resolved to its degraded little-tier variant instead of stalling
    /// for the full bytes. `id` is the packed expert key (`key_id`);
    /// `t_us` is the decision time. Only ever produced with the
    /// fallback on, so fallback-off event logs are byte-identical to
    /// pre-fallback builds.
    Degraded,
    /// Fault schedule (DESIGN.md §12): a single device on this node
    /// dropped at `t_us` — in-flight transfers torn down, resident
    /// experts re-homed to survivors. `id` is the local `DeviceId`.
    DeviceDown,
    /// Fault schedule (DESIGN.md §12): a transfer link's bandwidth
    /// window opened at `t_us` (degrade or full outage). `id` packs the
    /// link tag so two links flapping at the same instant stay ordered.
    LinkDegrade,
    /// Fault schedule (DESIGN.md §12): a previously-failed node came
    /// back at `t_us`, re-seeded its host pool over the network and
    /// re-entered the placement rotation. `id` is the cluster `NodeId`.
    NodeRejoin,
}

impl EventKind {
    fn tag(self) -> u8 {
        match self {
            EventKind::TransferComplete => 0,
            EventKind::GemvComplete => 1,
            EventKind::BoundaryBarrier => 2,
            EventKind::RequestArrival => 3,
            EventKind::NodeDown => 4,
            EventKind::Degraded => 5,
            EventKind::DeviceDown => 6,
            EventKind::LinkDegrade => 7,
            EventKind::NodeRejoin => 8,
        }
    }
}

/// One scheduled event. `id` is consumer-defined: the work-item index
/// within a layer, a packed (layer, expert) key, or a request index.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub t_us: f64,
    pub kind: EventKind,
    pub id: u64,
}

/// Pack an expert key into an event id (layer in the high word).
pub fn key_id(key: (usize, usize)) -> u64 {
    ((key.0 as u64) << 32) | key.1 as u64
}

/// Heap entry: ordered so `BinaryHeap` (a max-heap) pops the EARLIEST
/// time first, ties broken by insertion order.
struct HeapItem {
    ev: Event,
    seq: u64,
}

impl PartialEq for HeapItem {
    fn eq(&self, other: &Self) -> bool {
        self.ev.t_us.total_cmp(&other.ev.t_us).is_eq() && self.seq == other.seq
    }
}
impl Eq for HeapItem {}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed on both keys: earliest time wins, then lowest seq
        other
            .ev
            .t_us
            .total_cmp(&self.ev.t_us)
            .then(other.seq.cmp(&self.seq))
    }
}

/// The event heap plus its optional pop log.
pub struct EventCore {
    heap: BinaryHeap<HeapItem>,
    seq: u64,
    log: Option<Vec<u8>>,
}

impl EventCore {
    pub fn new() -> Self {
        EventCore { heap: BinaryHeap::new(), seq: 0, log: None }
    }

    /// An event core that records every popped event into a byte log
    /// (17 bytes per event: kind tag, `t_us.to_bits()` LE, id LE) for
    /// the determinism pins.
    pub fn recording() -> Self {
        EventCore { heap: BinaryHeap::new(), seq: 0, log: Some(Vec::new()) }
    }

    pub fn push(&mut self, t_us: f64, kind: EventKind, id: u64) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(HeapItem { ev: Event { t_us, kind, id }, seq });
    }

    /// Pop the earliest event (push order breaks time ties), recording
    /// it when the log is on.
    pub fn pop(&mut self) -> Option<Event> {
        let ev = self.heap.pop()?.ev;
        if let Some(log) = self.log.as_mut() {
            log.push(ev.kind.tag());
            log.extend_from_slice(&ev.t_us.to_bits().to_le_bytes());
            log.extend_from_slice(&ev.id.to_le_bytes());
        }
        Some(ev)
    }

    /// Earliest pending event time, without popping.
    pub fn next_time(&self) -> Option<f64> {
        self.heap.peek().map(|h| h.ev.t_us)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// The recorded pop log so far (empty when recording is off).
    pub fn log_bytes(&self) -> &[u8] {
        self.log.as_deref().unwrap_or(&[])
    }
}

impl Default for EventCore {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_with_stable_ties() {
        let mut core = EventCore::new();
        core.push(5.0, EventKind::GemvComplete, 1);
        core.push(3.0, EventKind::TransferComplete, 2);
        core.push(3.0, EventKind::TransferComplete, 3); // same time: push order
        core.push(4.0, EventKind::BoundaryBarrier, 4);
        let order: Vec<u64> = std::iter::from_fn(|| core.pop()).map(|e| e.id).collect();
        assert_eq!(order, vec![2, 3, 4, 1]);
        assert!(core.is_empty());
    }

    #[test]
    fn next_time_peeks_without_popping() {
        let mut core = EventCore::new();
        assert_eq!(core.next_time(), None);
        core.push(7.5, EventKind::RequestArrival, 0);
        assert_eq!(core.next_time(), Some(7.5));
        assert_eq!(core.len(), 1);
    }

    #[test]
    fn recorded_logs_are_byte_identical_across_identical_runs() {
        let run = || {
            let mut core = EventCore::recording();
            for i in 0..50u64 {
                // deterministic scatter of times, including exact ties
                core.push(((i * 37) % 11) as f64, EventKind::TransferComplete, i);
            }
            while core.pop().is_some() {}
            core.log_bytes().to_vec()
        };
        let (a, b) = (run(), run());
        assert!(!a.is_empty());
        assert_eq!(a, b);
    }

    #[test]
    fn key_id_packs_layer_and_expert() {
        assert_eq!(key_id((3, 7)), (3u64 << 32) | 7);
        assert_eq!(key_id((0, 0)), 0);
    }
}

//! Discrete-event end-to-end decode simulator (paper Figs 6 & 8, §4.1).
//!
//! Replays a routing trace through a timeline with two resources — the GPU
//! compute stream and the PCIe bus — under each system policy. Compute and
//! transfer latencies come from hwsim's roofline models; expert residency
//! (cache, eviction policy, in-flight prefetches, stall attribution) from
//! `store::ExpertStore` — the same subsystem the real serving path runs,
//! so Fig-6's "sim vs real" comparison exercises one residency code path.
//! Prediction quality comes from the calibrated hit rates (our measured
//! inter-predictor ~0.87, paper 0.88).
//!
//! The point of the simulation is the paper's *structure*: FloE overlaps
//! compressed transfers with compute via next-layer prediction, so its
//! decode stalls shrink toward zero, while the baselines either move too
//! many bytes (naive fp16), can't overlap (same-layer prefetch), or trade
//! bandwidth for slow CPU GEMVs (Fiddler).

use crate::hwsim::{CpuSpec, GpuSpec, ModelDims, PcieSpec};
use crate::store::ExpertStore;
use crate::util::rng::Rng;

use super::policy::{SystemConfig, SystemKind};

/// Synthetic routing-trace generator: per-layer Zipf popularity with
/// token-to-token stickiness (both observable in real MoE traces; our
/// tiny-model measured stickiness is ~0.3-0.45 — see exp-fig4 output).
#[derive(Clone, Debug)]
pub struct RoutingModel {
    pub zipf_s: f64,
    pub stickiness: f64,
    pub seed: u64,
}

impl Default for RoutingModel {
    fn default() -> Self {
        RoutingModel { zipf_s: 0.6, stickiness: 0.35, seed: 7 }
    }
}

impl RoutingModel {
    /// experts[layer][slot] for one token, updating `prev` in place.
    fn sample(
        &self,
        rng: &mut Rng,
        n_experts: usize,
        top_k: usize,
        prev: &mut Vec<Vec<usize>>,
        weights: &[f64],
    ) -> Vec<Vec<usize>> {
        let n_layers = prev.len();
        let mut out = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let mut chosen: Vec<usize> = Vec::with_capacity(top_k);
            for slot in 0..top_k {
                let e = if !prev[l].is_empty() && rng.f64() < self.stickiness {
                    prev[l][slot]
                } else {
                    // Zipf-weighted draw without replacement
                    loop {
                        let r = rng.f64() * weights[n_experts - 1];
                        let e = weights.partition_point(|w| *w < r).min(n_experts - 1);
                        if !chosen.contains(&e) {
                            break e;
                        }
                    }
                };
                if chosen.contains(&e) {
                    // stickiness collision: pick any other expert
                    let alt = (e + 1 + rng.below(n_experts - 1)) % n_experts;
                    chosen.push(alt);
                } else {
                    chosen.push(e);
                }
            }
            prev[l] = chosen.clone();
            out.push(chosen);
        }
        out
    }

    fn zipf_cdf(&self, n: usize) -> Vec<f64> {
        let mut w: Vec<f64> = (1..=n).map(|k| 1.0 / (k as f64).powf(self.zipf_s)).collect();
        for i in 1..n {
            w[i] += w[i - 1];
        }
        w
    }
}

#[derive(Clone, Debug)]
pub struct SimParams {
    pub gpu: GpuSpec,
    pub pcie: PcieSpec,
    pub cpu: CpuSpec,
    pub dims: ModelDims,
    pub system: SystemConfig,
    /// total VRAM budget in GB (paper Fig 8 sweeps 12..24)
    pub vram_gb: f64,
    /// inter-expert predictor hit rate (calibrated)
    pub inter_hit: f64,
    /// intra-expert (channel) predictor recall (calibrated)
    pub intra_recall: f64,
    pub routing: RoutingModel,
    /// AdvancedOffload speculative prefetch accuracy
    pub adv_prefetch_hit: f64,
}

impl SimParams {
    pub fn mixtral_on(gpu: GpuSpec, system: SystemConfig, vram_gb: f64) -> Self {
        SimParams {
            gpu,
            pcie: crate::hwsim::PCIE4,
            cpu: crate::hwsim::EPYC64,
            dims: crate::hwsim::MIXTRAL_8X7B,
            system,
            vram_gb,
            inter_hit: 0.88,    // paper Fig 4 / our calibration ~0.87
            intra_recall: 0.95, // paper Fig 4 (ours is lower at 4 layers; see EXPERIMENTS.md)
            routing: RoutingModel::default(),
            adv_prefetch_hit: 0.75,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct SimReport {
    pub tokens: usize,
    pub total_us: f64,
    pub prefill_us: f64,
    pub compute_us: f64,
    pub stall_us: f64,
    pub transferred_gb: f64,
    pub cache_hit_rate: f64,
    pub tps: f64,
}

/// Per-expert transfer bytes under each policy.
fn transfer_bytes(p: &SimParams) -> f64 {
    match p.system.kind {
        SystemKind::Floe => p.dims.floe_transfer_bytes(p.system.sparsity)
            * (1.0 + p.system.intra_margin),
        SystemKind::NaiveOffload => p.dims.expert_bytes_fp16(),
        SystemKind::AdvancedOffload => {
            p.dims.expert_bytes_quant(p.system.quant_bits as f64)
        }
        SystemKind::Fiddler => 0.0,
        SystemKind::GpuResident => 0.0,
    }
}

/// Per-expert cached size in VRAM (what the ExpertStore accounts).
fn cached_bytes(p: &SimParams) -> usize {
    match p.system.kind {
        SystemKind::Floe => p.dims.floe_transfer_bytes(p.system.sparsity) as usize,
        SystemKind::NaiveOffload => p.dims.expert_bytes_fp16() as usize,
        SystemKind::AdvancedOffload => {
            p.dims.expert_bytes_quant(p.system.quant_bits as f64) as usize
        }
        SystemKind::Fiddler => p.dims.expert_bytes_fp16() as usize,
        SystemKind::GpuResident => p.dims.expert_bytes_quant(2.0) as usize,
    }
}

/// Expert compute latency on the GPU under each policy, microseconds.
fn expert_compute_us(p: &SimParams) -> f64 {
    match p.system.kind {
        SystemKind::Floe => p.gpu.expert_floe_us(&p.dims, p.system.sparsity),
        SystemKind::NaiveOffload => p.gpu.expert_dense_us(&p.dims),
        SystemKind::AdvancedOffload => {
            p.gpu.expert_quant_us(&p.dims, p.system.quant_bits as f64)
        }
        SystemKind::Fiddler => p.gpu.expert_dense_us(&p.dims),
        SystemKind::GpuResident => p.gpu.expert_quant_us(&p.dims, 2.0),
    }
}

/// VRAM bytes available for the expert cache after resident allocations.
fn cache_budget_bytes(p: &SimParams, kv_tokens: usize) -> f64 {
    let d = &p.dims;
    let attn = d.n_layers as f64 * d.attn_bytes_fp16();
    let embed = 2.0 * 32000.0 * d.d_model as f64 * 2.0; // embed + lm head fp16
    let kv = d.n_layers as f64 * 2.0 * kv_tokens as f64 * d.d_model as f64 * 2.0;
    let mut resident = attn + embed + kv + 1e9; // +1GB activations/workspace
    if p.system.kind == SystemKind::Floe {
        // all INT2 up projections stay resident (enables the reuse predictor)
        resident += d.n_layers as f64 * d.n_experts as f64 * d.up_int2_bytes();
    }
    (p.vram_gb * 1e9 - resident).max(0.0)
}

pub fn simulate(p: &SimParams, input_len: usize, output_len: usize) -> SimReport {
    let mut rng = Rng::new(p.routing.seed);
    let d = &p.dims;
    let n_slots = d.top_k;
    let zipf = p.routing.zipf_cdf(d.n_experts);
    let mut prev: Vec<Vec<usize>> = vec![Vec::new(); d.n_layers];

    let budget = cache_budget_bytes(p, input_len + output_len);
    // all residency state — cache, policy, in-flight prefetches, bus
    // timeline, stall attribution — lives in the store
    let mut store: ExpertStore =
        ExpertStore::with_virtual_clock(budget as usize, p.system.residency);
    let per_expert_cached = cached_bytes(p);
    let per_expert_bytes = transfer_bytes(p);
    let exp_compute = expert_compute_us(p);

    // GpuResident requires everything to fit; if not, it degrades to
    // AdvancedOffload-like streaming of INT2 experts.
    let resident_fits = p.system.kind == SystemKind::GpuResident
        && budget >= (d.n_layers * d.n_experts * per_expert_cached) as f64;

    let mut compute_us = 0.0;
    let prefill_us;

    // ---- prefill: batched, all experts touched per layer ----
    {
        let t0 = store.now_us();
        for _l in 0..d.n_layers {
            // attention over the whole prompt (compute-bound, batched)
            let flops = 12.0 * input_len as f64 * (d.d_model as f64).powi(2);
            store.tick(flops / (p.gpu.fp16_tflops * 1e6) + 4.0 * p.gpu.launch_us);
            match p.system.kind {
                SystemKind::GpuResident if resident_fits => {
                    store.tick(exp_compute * d.n_experts as f64 * 0.5);
                }
                SystemKind::Fiddler => {
                    // prefill experts computed on GPU from streamed weights
                    // (Fiddler streams during prefill; decode is CPU-side)
                    let bytes = d.n_experts as f64 * d.expert_bytes_fp16();
                    let done = store.bus_copy(p.pcie.copy_us(bytes), bytes);
                    store.advance_to(done);
                    store.tick(exp_compute * d.n_experts as f64 * 0.5);
                }
                _ => {
                    let bytes = d.n_experts as f64 * per_expert_bytes.max(
                        if p.system.kind == SystemKind::GpuResident {
                            d.expert_bytes_quant(2.0)
                        } else {
                            0.0
                        },
                    );
                    if bytes > 0.0 {
                        let done = store.bus_copy(p.pcie.copy_us(bytes), bytes);
                        store.advance_to(done);
                    }
                    store.tick(exp_compute * d.n_experts as f64 * 0.5);
                }
            }
        }
        prefill_us = store.now_us() - t0;
    }

    // warm the cache with the most popular experts that fit
    {
        let mut order: Vec<(usize, usize)> = (0..d.n_layers)
            .flat_map(|l| (0..d.n_experts).map(move |e| (l, e)))
            .collect();
        order.sort_by_key(|(_, e)| *e); // Zipf rank order
        for key in order {
            if !store.admit(key, per_expert_cached) {
                break;
            }
        }
    }

    for tok in 0..output_len {
        let _ = tok;
        let routing = p.routing.sample(&mut rng, d.n_experts, n_slots, &mut prev, &zipf);
        for l in 0..d.n_layers {
            // attention (always resident)
            let attn = p.gpu.attn_layer_us(d, input_len + tok);
            store.tick(attn);
            compute_us += attn;

            // FloE / Advanced issue prefetches for layer l+1 *now*
            if l + 1 < d.n_layers && per_expert_bytes > 0.0 {
                let (hit_rate, overlap) = match p.system.kind {
                    SystemKind::Floe => (p.inter_hit, true),
                    SystemKind::AdvancedOffload => (p.adv_prefetch_hit, false),
                    _ => (0.0, false),
                };
                if hit_rate > 0.0 {
                    for &e in &routing[l + 1] {
                        let predicted = rng.f64() < hit_rate;
                        if predicted && !store.contains((l + 1, e)) {
                            let dur = p.pcie.copy_us(per_expert_bytes);
                            if overlap {
                                store.begin_prefetch(
                                    (l + 1, e),
                                    dur,
                                    per_expert_bytes,
                                    (),
                                );
                            } else {
                                // same-layer prefetch blocks compute (§2)
                                let done = store.begin_prefetch_blocking(
                                    (l + 1, e),
                                    dur,
                                    per_expert_bytes,
                                    (),
                                );
                                store.stall_until(done);
                            }
                        }
                    }
                }
            }

            // expert execution at layer l
            for &e in &routing[l] {
                let key = (l, e);
                let resident = resident_fits || store.access(key);
                let ready_at = if resident {
                    store.now_us()
                } else if let Some((t_done, ())) = store.take_inflight(key) {
                    store.admit(key, per_expert_cached);
                    t_done
                } else if p.system.kind == SystemKind::Fiddler {
                    // compute on CPU instead of transferring
                    let t = p.cpu.expert_us(d);
                    store.tick(t);
                    compute_us += t;
                    continue;
                } else {
                    // demand fetch
                    let done = store.demand_fetch(
                        p.pcie.copy_us(per_expert_bytes.max(1.0)),
                        per_expert_bytes,
                    );
                    store.admit(key, per_expert_cached);
                    done
                };
                store.stall_until(ready_at);
                // intra-predictor misses force a small on-demand top-up
                if p.system.kind == SystemKind::Floe && !resident {
                    let miss = (1.0 - p.intra_recall).max(0.0);
                    if miss > 0.0 {
                        let extra = per_expert_bytes * miss * 0.5;
                        let done = store.bus_copy(p.pcie.copy_us(extra), extra);
                        store.stall_until(done);
                    }
                }
                store.tick(exp_compute);
                compute_us += exp_compute;
            }
        }
    }

    let total = store.now_us();
    SimReport {
        tokens: output_len,
        total_us: total,
        prefill_us,
        compute_us,
        stall_us: store.stats().stall_us,
        transferred_gb: store.stats().transferred_bytes / 1e9,
        cache_hit_rate: store.cache_stats().hit_rate(),
        tps: output_len as f64 / (total / 1e6),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ResidencyKind;
    use crate::hwsim::RTX3090;

    fn run(kind: SystemKind, vram: f64) -> SimReport {
        let p = SimParams::mixtral_on(RTX3090.clone(), SystemConfig::new(kind), vram);
        simulate(&p, 64, 128)
    }

    #[test]
    fn ordering_matches_paper_fig6() {
        // GpuResident >= FloE > Fiddler/Advanced > Naive, on a 3090-class
        // budget where everything INT2 fits (24 GB).
        let floe = run(SystemKind::Floe, 24.0).tps;
        let naive = run(SystemKind::NaiveOffload, 24.0).tps;
        let adv = run(SystemKind::AdvancedOffload, 24.0).tps;
        let fid = run(SystemKind::Fiddler, 24.0).tps;
        let gpu = run(SystemKind::GpuResident, 24.0).tps;
        assert!(floe > adv, "floe {floe} adv {adv}");
        assert!(floe > fid, "floe {floe} fid {fid}");
        assert!(adv > naive, "adv {adv} naive {naive}");
        assert!(floe > 10.0 * naive, "floe {floe} naive {naive}");
        assert!(floe > 0.5 * gpu, "floe {floe} gpu {gpu}");
    }

    #[test]
    fn more_vram_helps_floe() {
        let lo = run(SystemKind::Floe, 12.0).tps;
        let hi = run(SystemKind::Floe, 24.0).tps;
        assert!(hi >= lo * 0.99, "lo {lo} hi {hi}");
    }

    #[test]
    fn longer_outputs_amortize() {
        let p = SimParams::mixtral_on(
            RTX3090.clone(),
            SystemConfig::new(SystemKind::Floe),
            12.0,
        );
        let short = simulate(&p, 64, 32);
        let long = simulate(&p, 64, 512);
        assert!(
            long.tps > short.tps,
            "short {} long {}",
            short.tps,
            long.tps
        );
    }

    #[test]
    fn floe_moves_fewer_bytes() {
        let floe = run(SystemKind::Floe, 12.0);
        let naive = run(SystemKind::NaiveOffload, 12.0);
        assert!(floe.transferred_gb < naive.transferred_gb / 4.0);
    }

    #[test]
    fn routing_model_is_deterministic() {
        let a = run(SystemKind::Floe, 12.0).tps;
        let b = run(SystemKind::Floe, 12.0).tps;
        assert_eq!(a, b);
    }

    #[test]
    fn every_policy_simulates_and_stays_deterministic() {
        // the routing trace consumes the RNG identically under every
        // eviction policy, so reports are reproducible policy-by-policy
        for kind in ResidencyKind::ALL {
            let p = SimParams::mixtral_on(
                RTX3090.clone(),
                SystemConfig::with_residency(SystemKind::Floe, kind),
                14.0,
            );
            let a = simulate(&p, 64, 128);
            let b = simulate(&p, 64, 128);
            assert_eq!(a.tps, b.tps, "{}", kind.name());
            assert!(a.tps.is_finite() && a.tps > 0.0, "{}", kind.name());
            assert!(a.cache_hit_rate >= 0.0 && a.cache_hit_rate <= 1.0);
        }
    }

    #[test]
    fn sparsity_policy_hit_rate_not_worse_at_tight_vram() {
        // at a budget where eviction actually happens, the activation-
        // frequency policy should match or beat LRU on the Zipf trace
        let at = |kind: ResidencyKind| {
            let p = SimParams::mixtral_on(
                RTX3090.clone(),
                SystemConfig::with_residency(SystemKind::NaiveOffload, kind),
                14.0,
            );
            simulate(&p, 64, 128).cache_hit_rate
        };
        let lru = at(ResidencyKind::Lru);
        let sparsity = at(ResidencyKind::Sparsity);
        assert!(
            sparsity >= lru - 0.02,
            "sparsity {sparsity:.3} well below lru {lru:.3}"
        );
    }
}

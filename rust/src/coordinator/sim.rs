//! Discrete-event end-to-end decode simulator (paper Figs 6 & 8, §4.1)
//! and the batched-serving simulator behind `exp-serve-load`.
//!
//! Replays a routing trace through the deterministic event core
//! (`coordinator::events`, DESIGN.md §8): transfer completions, GEMV
//! completions, layer-boundary barriers and serving request arrivals pop
//! off one time-ordered heap. Compute and transfer latencies come from
//! hwsim's roofline models; expert residency (cache, eviction policy,
//! in-flight prefetches, stall attribution) from `store::ExpertStore` —
//! the same subsystem the real serving path runs, so Fig-6's "sim vs
//! real" comparison exercises one residency code path. With overlap
//! modeling off (the default) each expert pushes and pops its own events
//! in routing order, which replays the frozen busy-until reference
//! (`simulate_busyuntil_reference`) *bit-exactly*; with
//! `SystemConfig::overlap` on, a layer's fetches are resolved *before*
//! its attention tick (demand copies ride the store's priority demand
//! lane, ahead of speculative prefetch, and stream under compute) and
//! each transfer completion releases its waiting GEMV in readiness
//! order, charging only the residual stall instead of the full wait at
//! the barrier. In serving mode the release is batch-wide:
//! `SimServeBackend::step_batch` runs the whole boundary
//! layer-synchronously (`sim_decode_boundary`), so one sequence's
//! in-flight transfer hides under the other sequences' attention and
//! GEMVs. Prediction quality comes from the calibrated hit rates (our
//! measured inter-predictor ~0.87, paper 0.88).
//!
//! The point of the simulation is the paper's *structure*: FloE overlaps
//! compressed transfers with compute via next-layer prediction, so its
//! decode stalls shrink toward zero, while the baselines either move too
//! many bytes (naive fp16), can't overlap (same-layer prefetch), or trade
//! bandwidth for slow CPU GEMVs (Fiddler).
//!
//! Two drivers share the per-token decode model:
//! * `simulate` — one request, fixed input/output lengths (Figs 6/8).
//! * `SimServeBackend` + `simulate_serving` — a `SeqBackend` for the
//!   continuous-batching `Scheduler` (coordinator::sched): concurrent
//!   requests from a `workload` arrival trace share one ExpertStore, so
//!   batching multiplies expert reuse per transferred byte and amortizes
//!   weight reads at each token boundary — the serving win `exp-serve-load`
//!   sweeps (DESIGN.md §6).

use std::collections::HashSet;

use anyhow::Result;

use crate::hwsim::{CpuSpec, GpuSpec, ModelDims, PcieSpec};
use crate::store::{
    DegradeCount, DeviceDownReport, ExpertStore, FaultCause, LinkId, Lookup, PlanMode,
    StallCause, StallSplit, StoreStats, TransferPlan,
};
use crate::util::rng::Rng;
use crate::workload::TimedRequest;

use super::events::{key_id, EventCore, EventKind};
use super::policy::{SystemConfig, SystemKind};
use super::sched::{BackendSnapshot, Scheduler, SeqBackend, SeqStep, ServeCompletion};
use super::serve::Request;

/// Synthetic routing-trace generator: per-layer Zipf popularity with
/// token-to-token stickiness (both observable in real MoE traces; our
/// tiny-model measured stickiness is ~0.3-0.45 — see exp-fig4 output).
#[derive(Clone, Debug)]
pub struct RoutingModel {
    pub zipf_s: f64,
    pub stickiness: f64,
    pub seed: u64,
}

impl Default for RoutingModel {
    fn default() -> Self {
        RoutingModel { zipf_s: 0.6, stickiness: 0.35, seed: 7 }
    }
}

impl RoutingModel {
    /// experts[layer][slot] for one token, updating `prev` in place.
    fn sample(
        &self,
        rng: &mut Rng,
        n_experts: usize,
        top_k: usize,
        prev: &mut Vec<Vec<usize>>,
        weights: &[f64],
    ) -> Vec<Vec<usize>> {
        let n_layers = prev.len();
        let mut out = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let mut chosen: Vec<usize> = Vec::with_capacity(top_k);
            for slot in 0..top_k {
                let e = if !prev[l].is_empty() && rng.f64() < self.stickiness {
                    prev[l][slot]
                } else {
                    // Zipf-weighted draw without replacement
                    loop {
                        let r = rng.f64() * weights[n_experts - 1];
                        let e = weights.partition_point(|w| *w < r).min(n_experts - 1);
                        if !chosen.contains(&e) {
                            break e;
                        }
                    }
                };
                if chosen.contains(&e) {
                    // stickiness collision: pick any other expert
                    let alt = (e + 1 + rng.below(n_experts - 1)) % n_experts;
                    chosen.push(alt);
                } else {
                    chosen.push(e);
                }
            }
            prev[l] = chosen.clone();
            out.push(chosen);
        }
        out
    }

    fn zipf_cdf(&self, n: usize) -> Vec<f64> {
        let mut w: Vec<f64> = (1..=n).map(|k| 1.0 / (k as f64).powf(self.zipf_s)).collect();
        for i in 1..n {
            w[i] += w[i - 1];
        }
        w
    }
}

/// The first expert (layer 0, slot 0) a request seeded with `seed` will
/// route to — the affinity signal `ClusterPlacement::ExpertAffinity`
/// steers on (coordinator::cluster). Mirrors the first draw of
/// `RoutingModel::sample` exactly: with empty stickiness history the
/// very first consumption of `Rng::new(seed)` is one Zipf draw, so the
/// prediction is the true first routed expert, not a heuristic.
pub fn predicted_first_expert(routing: &RoutingModel, n_experts: usize, seed: u64) -> usize {
    let w = routing.zipf_cdf(n_experts);
    let mut rng = Rng::new(seed);
    let r = rng.f64() * w[n_experts - 1];
    w.partition_point(|x| *x < r).min(n_experts - 1)
}

#[derive(Clone, Debug)]
pub struct SimParams {
    pub gpu: GpuSpec,
    pub pcie: PcieSpec,
    pub cpu: CpuSpec,
    pub dims: ModelDims,
    pub system: SystemConfig,
    /// total VRAM budget in GB (paper Fig 8 sweeps 12..24)
    pub vram_gb: f64,
    /// inter-expert predictor hit rate (calibrated)
    pub inter_hit: f64,
    /// intra-expert (channel) predictor recall (calibrated)
    pub intra_recall: f64,
    pub routing: RoutingModel,
    /// AdvancedOffload speculative prefetch accuracy
    pub adv_prefetch_hit: f64,
}

impl SimParams {
    pub fn mixtral_on(gpu: GpuSpec, system: SystemConfig, vram_gb: f64) -> Self {
        SimParams {
            gpu,
            pcie: crate::hwsim::PCIE4,
            cpu: crate::hwsim::EPYC64,
            dims: crate::hwsim::MIXTRAL_8X7B,
            system,
            vram_gb,
            inter_hit: 0.88,    // paper Fig 4 / our calibration ~0.87
            intra_recall: 0.95, // paper Fig 4 (ours is lower at 4 layers; see EXPERIMENTS.md)
            routing: RoutingModel::default(),
            adv_prefetch_hit: 0.75,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct SimReport {
    pub tokens: usize,
    pub total_us: f64,
    pub prefill_us: f64,
    pub compute_us: f64,
    pub stall_us: f64,
    pub transferred_gb: f64,
    /// exact bus bytes (the shard sweep's equal-bytes comparisons)
    pub transferred_bytes: f64,
    /// individual bus copies issued — coalescing merges whole plans
    pub bus_transactions: u64,
    /// busiest device's total bus occupancy, µs — the load-imbalance
    /// signal the balanced shard policy is judged on (max over devices
    /// of `DeviceStats::bus_busy_us`; equals total busy at one device)
    pub max_device_bus_busy_us: f64,
    pub cache_hit_rate: f64,
    pub tps: f64,
}

/// Busiest device's bus occupancy for the report.
fn max_device_busy(store: &ExpertStore) -> f64 {
    store
        .stats()
        .per_device
        .iter()
        .map(|d| d.bus_busy_us)
        .fold(0.0, f64::max)
}

/// Per-expert transfer bytes under each policy.
fn transfer_bytes(p: &SimParams) -> f64 {
    match p.system.kind {
        SystemKind::Floe => p.dims.floe_transfer_bytes(p.system.sparsity)
            * (1.0 + p.system.intra_margin),
        SystemKind::NaiveOffload => p.dims.expert_bytes_fp16(),
        SystemKind::AdvancedOffload => {
            p.dims.expert_bytes_quant(p.system.quant_bits as f64)
        }
        SystemKind::Fiddler => 0.0,
        SystemKind::GpuResident => 0.0,
    }
}

/// Per-expert cached size in VRAM (what the ExpertStore accounts).
fn cached_bytes(p: &SimParams) -> usize {
    match p.system.kind {
        SystemKind::Floe => p.dims.floe_transfer_bytes(p.system.sparsity) as usize,
        SystemKind::NaiveOffload => p.dims.expert_bytes_fp16() as usize,
        SystemKind::AdvancedOffload => {
            p.dims.expert_bytes_quant(p.system.quant_bits as f64) as usize
        }
        SystemKind::Fiddler => p.dims.expert_bytes_fp16() as usize,
        SystemKind::GpuResident => p.dims.expert_bytes_quant(2.0) as usize,
    }
}

/// Expert compute latency on the GPU under each policy, microseconds.
fn expert_compute_us(p: &SimParams) -> f64 {
    match p.system.kind {
        SystemKind::Floe => p.gpu.expert_floe_us(&p.dims, p.system.sparsity),
        SystemKind::NaiveOffload => p.gpu.expert_dense_us(&p.dims),
        SystemKind::AdvancedOffload => {
            p.gpu.expert_quant_us(&p.dims, p.system.quant_bits as f64)
        }
        SystemKind::Fiddler => p.gpu.expert_dense_us(&p.dims),
        SystemKind::GpuResident => p.gpu.expert_quant_us(&p.dims, 2.0),
    }
}

/// VRAM bytes available for the expert cache after resident allocations.
fn cache_budget_bytes(p: &SimParams, kv_tokens: usize) -> f64 {
    let d = &p.dims;
    let attn = d.n_layers as f64 * d.attn_bytes_fp16();
    let embed = 2.0 * 32000.0 * d.d_model as f64 * 2.0; // embed + lm head fp16
    let kv = d.n_layers as f64 * 2.0 * kv_tokens as f64 * d.d_model as f64 * 2.0;
    let mut resident = attn + embed + kv + 1e9; // +1GB activations/workspace
    if p.system.kind == SystemKind::Floe {
        // all INT2 up projections stay resident (enables the reuse predictor)
        resident += d.n_layers as f64 * d.n_experts as f64 * d.up_int2_bytes();
    }
    (p.vram_gb * 1e9 - resident).max(0.0)
}

/// Same-boundary compute-reuse ratio: what a batched *repeat* of an
/// expert GEMV costs relative to the boundary's first visit.
///
/// The engine's boundary-synchronous `decode_batch` groups a boundary's
/// routed pairs by expert and runs one multi-row kernel per group
/// (`NativeExpert::forward_rows`), so only
/// the first visit streams the expert's weights; each extra row pays its
/// own FLOPs at compute peak, its activation traffic, and one launch —
/// never the weight movement. This derivation prices exactly that from
/// the run's roofline specs (it replaces the former flat 0.15 constant,
/// which overcharged repeats of memory-bound experts and undercharged
/// compute-dense ones). `benches/decode_hotpath.rs` measures the native
/// sparse Rule-Up kernel's realized marginal-row ratio — the same rule
/// the Floe decode path runs — into BENCH_decode.json
/// (`measured_reuse`) so the calibration is tracked against measurement
/// across PRs; the serving margins downstream of this constant are
/// replay-verified (python/replay_sim.py).
pub fn boundary_compute_reuse(p: &SimParams) -> f64 {
    let full = expert_compute_us(p);
    let d = &p.dims;
    // marginal batched row: the SYSTEM's per-row FLOPs at compute peak —
    // FloE's kernel runs the up GEMV dense (INT2) but skips `sparsity`
    // of the gate/down channels per row, so its repeat row computes
    // 2·d·f·(1 + 2(1-s)) flops, not the dense 6·d·f ...
    let flops = match p.system.kind {
        SystemKind::Floe => {
            2.0 * d.d_model as f64
                * d.d_ff as f64
                * (1.0 + 2.0 * (1.0 - p.system.sparsity))
        }
        _ => d.expert_flops(),
    };
    let flops_us = flops / (p.gpu.fp16_tflops * 1e6);
    // ... + activation traffic (x in, y out, gate/up intermediates) ...
    let act_bytes = (2 * d.d_model + 2 * d.d_ff) as f64 * 2.0;
    let act_us = act_bytes / (p.gpu.hbm_gbps * p.gpu.efficiency * 1e3);
    // ... + one extra kernel launch for the row block
    ((flops_us + act_us + p.gpu.launch_us) / full).clamp(0.02, 1.0)
}

/// Per-token-boundary expert-sharing state for batched serving: which
/// experts already paid the full weight-bound GEMV at this boundary,
/// plus the visit accounting the scheduler-level tests pin (full-cost
/// visits per boundary == distinct routed experts, not routed pairs).
#[derive(Debug, Default, Clone)]
pub struct BoundaryShare {
    seen: HashSet<(usize, usize)>,
    /// GEMVs that streamed their expert's weights (first visit at the
    /// boundary) — cumulative across boundaries
    pub full_visits: u64,
    /// GEMVs amortized against an earlier same-boundary visit
    pub reused_visits: u64,
}

impl BoundaryShare {
    /// New token boundary: everyone pays full price again.
    pub fn reset(&mut self) {
        self.seen.clear();
    }
    /// Distinct experts visited at the current boundary so far.
    pub fn distinct_this_boundary(&self) -> usize {
        self.seen.len()
    }
    /// Record a visit; returns true when this is the boundary's first
    /// visit of `key` (full-cost GEMV).
    fn visit(&mut self, key: (usize, usize)) -> bool {
        if self.seen.insert(key) {
            self.full_visits += 1;
            true
        } else {
            self.reused_visits += 1;
            false
        }
    }
}

/// Per-run constants derived from `SimParams` + the resolved cache budget,
/// shared by the single-request and batched-serving drivers.
struct SimCtx {
    zipf: Vec<f64>,
    per_expert_cached: usize,
    per_expert_bytes: f64,
    exp_compute: f64,
    resident_fits: bool,
    /// serving mode: skip prefetches already in flight (the real
    /// coordinator's dedup). Off for the legacy single-stream figures so
    /// their calibrated numbers are untouched.
    dedup_inflight: bool,
    /// coalesce same-destination prefetch plans into chunked copies
    /// (from `SystemConfig`; off single-device by default, so the
    /// pre-placement numbers are untouched)
    coalesce: bool,
    /// per-device compute streams (from `SystemConfig.compute_streams`):
    /// expert GEMVs occupy their execution device's own compute timeline
    /// and the token clock advances at the layer barrier. Off keeps the
    /// single-compute-timeline op sequence bit-exact.
    streams: bool,
    /// calibrated same-boundary repeat-GEMV cost ratio (serving mode
    /// only — consulted when a `BoundaryShare` is threaded through)
    boundary_reuse: f64,
    /// event-driven compute/transfer overlap (from
    /// `SystemConfig.overlap`): resolve a layer's fetches upfront and
    /// dispatch GEMVs in readiness order off the event heap. Off keeps
    /// the lockstep op sequence bit-exact with the frozen reference.
    overlap: bool,
}

impl SimCtx {
    fn new(p: &SimParams, budget: f64, dedup_inflight: bool) -> Self {
        let d = &p.dims;
        let per_expert_cached = cached_bytes(p);
        // GpuResident requires everything to fit (per-device budgets sum
        // across the placement); if not, it degrades to
        // AdvancedOffload-like streaming of INT2 experts.
        let resident_fits = p.system.kind == SystemKind::GpuResident
            && budget * p.system.devices.max(1) as f64
                >= (d.n_layers * d.n_experts * per_expert_cached) as f64;
        SimCtx {
            zipf: p.routing.zipf_cdf(d.n_experts),
            per_expert_cached,
            per_expert_bytes: transfer_bytes(p),
            exp_compute: expert_compute_us(p),
            resident_fits,
            dedup_inflight,
            coalesce: p.system.coalesce,
            streams: p.system.compute_streams && p.system.devices > 1,
            boundary_reuse: boundary_compute_reuse(p),
            overlap: p.system.overlap,
        }
    }
}

/// Per-device compute busy-until timelines — the FLOP half of the
/// placement dimension. One expert GEMV occupies its execution device's
/// stream (throughput-scaled via `TopologySpec::gemv_us`); experts routed
/// to different devices at one layer overlap, and the token timeline
/// advances to the slowest stream at the layer barrier (the router needs
/// every expert's output). Transfer waits are charged as stalls on the
/// waiting stream (`ExpertStore::charge_stall`) without advancing the
/// token clock.
pub struct ComputeStreams {
    free_us: Vec<f64>,
}

impl ComputeStreams {
    pub fn new(n_devices: usize) -> Self {
        ComputeStreams { free_us: vec![0.0; n_devices.max(1)] }
    }
}

/// Build the run's store from the system's placement: one `budget` of
/// expert-cache bytes per device (the non-expert reservation is
/// replicated tensor-parallel-style, so `cache_budget_bytes` applies
/// per device).
fn build_store(p: &SimParams, budget: f64) -> ExpertStore {
    let mut store = ExpertStore::with_placement(
        p.system.placement(p.pcie.clone()),
        budget as usize,
        p.system.residency,
        p.system.sparsity_decay,
    );
    // overlap mode switches the store's critical copies onto the
    // priority demand lane and bounds the speculative prefetch backlog;
    // off, both degrade to the plain FIFO bus (bit-exact with the
    // frozen reference)
    store.set_overlap(p.system.overlap);
    store
}

/// Stream one prefill layer's expert bytes, split across the home
/// devices of the layer's experts (each device's share rides its own
/// host link; the wait to the slowest link is free, not a stall). With
/// one device this is a single bus transaction — exactly the
/// pre-placement behavior.
fn prefill_stream_layer(
    p: &SimParams,
    store: &mut ExpertStore,
    layer: usize,
    per_expert_bytes: f64,
) {
    let d = &p.dims;
    let n_dev = store.n_devices();
    let mut counts = vec![0usize; n_dev];
    for e in 0..d.n_experts {
        counts[store.home((layer, e))] += 1;
    }
    let mut slowest = f64::NEG_INFINITY;
    for (dev, &count) in counts.iter().enumerate() {
        if count == 0 {
            continue;
        }
        let bytes = count as f64 * per_expert_bytes;
        let done = store.bus_copy_to(dev, p.pcie.copy_us(bytes), bytes);
        slowest = slowest.max(done);
    }
    store.advance_to(slowest);
}

/// Prefill: batched, all experts touched per layer. Advances the store's
/// clock; waits are free (`advance_to`), not decode stalls.
fn sim_prefill(p: &SimParams, c: &SimCtx, store: &mut ExpertStore, input_len: usize) {
    let d = &p.dims;
    for l in 0..d.n_layers {
        // attention over the whole prompt (compute-bound, batched)
        let flops = 12.0 * input_len as f64 * (d.d_model as f64).powi(2);
        store.tick(flops / (p.gpu.fp16_tflops * 1e6) + 4.0 * p.gpu.launch_us);
        match p.system.kind {
            SystemKind::GpuResident if c.resident_fits => {
                store.tick(c.exp_compute * d.n_experts as f64 * 0.5);
            }
            SystemKind::Fiddler => {
                // prefill experts computed on GPU from streamed weights
                // (Fiddler streams during prefill; decode is CPU-side)
                prefill_stream_layer(p, store, l, d.expert_bytes_fp16());
                store.tick(c.exp_compute * d.n_experts as f64 * 0.5);
            }
            _ => {
                let per_expert = c.per_expert_bytes.max(
                    if p.system.kind == SystemKind::GpuResident {
                        d.expert_bytes_quant(2.0)
                    } else {
                        0.0
                    },
                );
                if per_expert > 0.0 {
                    prefill_stream_layer(p, store, l, per_expert);
                }
                store.tick(c.exp_compute * d.n_experts as f64 * 0.5);
            }
        }
    }
}

/// Warm each device's cache by admitting the full expert roster in Zipf
/// rank order (warmup bypasses the admission filter — there is no
/// activation history yet). Because admits evict to make room, a full
/// device keeps the *last* keys of the cycle, and the per-device `full`
/// flags trip only when a single expert exceeds the device budget —
/// this warm distribution is the seed behavior the bit-exactness
/// acceptance pins, so it is preserved verbatim; smarter warm policies
/// belong behind a flag (ROADMAP: popularity-proportional placement).
fn warm_cache(p: &SimParams, c: &SimCtx, store: &mut ExpertStore) {
    let d = &p.dims;
    let mut order: Vec<(usize, usize)> = (0..d.n_layers)
        .flat_map(|l| (0..d.n_experts).map(move |e| (l, e)))
        .collect();
    order.sort_by_key(|(_, e)| *e); // Zipf rank order
    let mut full = vec![false; store.n_devices()];
    for key in order {
        let dev = store.home(key);
        if full[dev] {
            continue;
        }
        if !store.warm_admit(key, c.per_expert_cached) {
            full[dev] = true;
            if full.iter().all(|f| *f) {
                break;
            }
        }
    }
}

/// Byte size of one expert's degraded little-tier variant (DESIGN.md
/// §11): the rank-8 low-rank sketch of the INT2 expert — about 1/20th
/// of the compressed expert bytes. The carve holds as many sketches as
/// fit (key order, layer-major); at thrash-depth VRAM that is a partial
/// roster, and `little_resident` gates the fallback per key.
fn little_sketch_bytes(c: &SimCtx) -> usize {
    (c.per_expert_bytes / 20.0).ceil().max(1.0) as usize
}

/// Pin every expert's little-tier sketch on its home device, in key
/// order, until each device's carve fills (no-op with the carve off).
fn seed_little_pools(p: &SimParams, c: &SimCtx, store: &mut ExpertStore) {
    if p.system.little_frac <= 0.0 {
        return;
    }
    let d = &p.dims;
    let keys: Vec<(usize, usize)> = (0..d.n_layers)
        .flat_map(|l| (0..d.n_experts).map(move |e| (l, e)))
        .collect();
    store.seed_little_pool(&keys, little_sketch_bytes(c));
}

/// Stage the expert roster into the per-node host pools (cluster tier,
/// DESIGN.md §10): each node's host RAM adopts its own shard of the
/// roster first (experts it would home under an expert-mod split across
/// the cluster), then the remainder, until `host_ram_gb` fills. With
/// roomy host RAM every node holds a full copy and demand fetches price
/// PCIe exactly like a single-node run; under tight host RAM the pools
/// diverge and foreign demand fetches pay the network link — which is
/// what the failure re-homing scenario measures. Never called for
/// unclustered topologies (the pools are never consulted there).
fn seed_cluster_host_pools(p: &SimParams, c: &SimCtx, store: &mut ExpertStore) {
    let topo = store.placement().topo.clone();
    let span = topo.span_nodes.max(1);
    let total = topo.n_nodes.max(topo.node_id + span);
    let bytes = c.per_expert_bytes.max(1.0) as usize;
    let d = &p.dims;
    for local in 0..span {
        let node = topo.node_id + local;
        let (mut own, mut rest) = (Vec::new(), Vec::new());
        for l in 0..d.n_layers {
            for e in 0..d.n_experts {
                if e % total == node % total {
                    own.push((l, e));
                } else {
                    rest.push((l, e));
                }
            }
        }
        store.seed_host_pool(local, &own, bytes);
        store.seed_host_pool(local, &rest, bytes);
    }
}

/// One routed expert, resolved: where its usable bytes are (or will
/// land), when they land, and what its GEMV costs at this boundary.
struct ExpertWork {
    key: (usize, usize),
    ready_at: f64,
    cause: StallCause,
    /// where the GEMV runs: home, or the bus-free-soonest replica holder
    exec_dev: usize,
    resident: bool,
    t_exp: f64,
}

/// Resolve one routed expert's residency into a work item. Fiddler's CPU
/// fallback computes inline (there is nothing to wait for) and returns
/// `None`. No RNG is consumed here, so resolving a whole layer upfront
/// (overlap mode) draws the same stream as resolving one expert at a
/// time (lockstep mode).
///
/// `deadline_us` is the owning request's SLO deadline on the virtual
/// timeline (`f64::INFINITY` outside serving, or for requests without a
/// budget). When the little tier is carved (`--little-frac > 0`), the
/// deadline is finite and a demand fetch's predicted completion would
/// bust it, the expert resolves to its always-resident degraded variant
/// instead of stalling (DESIGN.md §11): no bytes move, no cache churn,
/// the GEMV runs immediately on the home device. With the fallback off
/// (either gate) this function is bit-exact with pre-quality builds.
#[allow(clippy::too_many_arguments)]
fn resolve_expert(
    p: &SimParams,
    c: &SimCtx,
    store: &mut ExpertStore,
    core: &mut EventCore,
    key: (usize, usize),
    deadline_us: f64,
    boundary: &mut Option<&mut BoundaryShare>,
    compute_us: &mut f64,
) -> Option<ExpertWork> {
    let looked = if c.resident_fits {
        // everything-resident fast path: execute on the key's home
        // device (the placeholder index was never read before compute
        // streams consumed it as exec_dev)
        Lookup::Local(store.home(key))
    } else {
        store.lookup(key)
    };
    let mut degraded = false;
    let (ready_at, cause, exec_dev) = match looked {
        Lookup::Local(dev) => (store.now_us(), StallCause::Demand, dev),
        Lookup::Remote(from) => {
            // resident on a peer device (spilled there): pull it over
            // the GPU↔GPU link instead of refetching from the host
            (store.peer_fetch(key, from), StallCause::Demand, store.home(key))
        }
        Lookup::RemoteNode(from) => {
            // resident only on a device of another node (spanning
            // topologies, DESIGN.md §10): pull it over the
            // latency-dominated network link and migrate it home
            (store.net_fetch(key, from), StallCause::Demand, store.home(key))
        }
        Lookup::Degraded(_) => unreachable!("lookup never returns Degraded"),
        Lookup::Miss => {
            if let Some((t_done, ())) = store.take_inflight(key) {
                store.admit(key, c.per_expert_cached);
                (t_done, StallCause::PrefetchMiss, store.home(key))
            } else if p.system.kind == SystemKind::Fiddler {
                // compute on CPU instead of transferring
                let t = p.cpu.expert_us(&p.dims);
                store.tick(t);
                *compute_us += t;
                core.push(store.now_us(), EventKind::GemvComplete, key_id(key));
                core.pop();
                return None;
            } else {
                // quality-elastic fallback first: predict (side-effect
                // free) when the full fetch would land, and if that
                // busts the SLO, execute the little-tier variant that
                // is already resident. The avoided demand bytes are
                // charged to the request's degraded ledger, and the
                // decision lands in the event log (push+pop at `now`:
                // every pending completion is strictly later, the
                // `note_node_down` pattern) so replay re-derives it.
                if p.system.little_frac > 0.0
                    && deadline_us.is_finite()
                    && store.little_resident(key)
                    && store.predict_demand_ready(
                        key,
                        store.peek_demand_link_us(key, c.per_expert_bytes.max(1.0)),
                    ) > deadline_us
                {
                    let hit = store.degraded_hit(key, c.per_expert_bytes);
                    debug_assert!(matches!(hit, Lookup::Degraded(_)));
                    core.push(store.now_us(), EventKind::Degraded, key_id(key));
                    let ev = core.pop().expect("degraded event vanished from the heap");
                    debug_assert_eq!(ev.kind, EventKind::Degraded);
                    degraded = true;
                    (store.now_us(), StallCause::Demand, store.home(key))
                } else {
                    // demand fetch toward the home device, priced by the
                    // link the bytes actually cross: the home node's host
                    // PCIe when its host pool holds a copy, the network
                    // link otherwise (unclustered topologies always price
                    // PCIe — `demand_link_us` degenerates to `h2d.copy_us`).
                    // A full outage on that link (DESIGN.md §12) gates the
                    // fetch start through the bounded-backoff retry loop:
                    // probe k waits `base·2^k` after the block; the first
                    // probe past every outage window issues the fetch with
                    // the wait folded into its duration. Exhaustion falls
                    // back to the little tier when it holds the key, else
                    // rides out the outage as a charged stall. With no
                    // retry policy the outage is fail-fast: the cause is
                    // recorded and the serving backend errors the request.
                    let now = store.now_us();
                    let link = store.demand_link_of(key);
                    let mut extra_wait = 0.0;
                    if let Some(end) = store.outage_until(link, now) {
                        match store.retry_policy() {
                            Some(rp) => {
                                let mut cleared = None;
                                for k in 0..rp.max_attempts {
                                    let t_k = now + rp.backoff_base_us * 2f64.powi(k as i32);
                                    if store.outage_until(link, t_k).is_none() {
                                        cleared = Some((u64::from(k) + 1, t_k));
                                        break;
                                    }
                                }
                                match cleared {
                                    Some((probes, t_k)) => {
                                        store.charge_retries(probes);
                                        extra_wait = t_k - now;
                                    }
                                    None => {
                                        store.charge_retries(u64::from(rp.max_attempts));
                                        store.record_fault(FaultCause::RetryExhausted);
                                        if p.system.little_frac > 0.0
                                            && store.little_resident(key)
                                        {
                                            let hit =
                                                store.degraded_hit(key, c.per_expert_bytes);
                                            debug_assert!(matches!(hit, Lookup::Degraded(_)));
                                            core.push(
                                                store.now_us(),
                                                EventKind::Degraded,
                                                key_id(key),
                                            );
                                            core.pop();
                                            degraded = true;
                                        } else {
                                            extra_wait = end - now;
                                        }
                                    }
                                }
                            }
                            None => {
                                store.record_fault(FaultCause::LinkOutage);
                                return None;
                            }
                        }
                    }
                    if degraded {
                        (store.now_us(), StallCause::Demand, store.home(key))
                    } else {
                        let dur = store.demand_link_us(key, c.per_expert_bytes.max(1.0));
                        let done =
                            store.demand_fetch_for(key, extra_wait + dur, c.per_expert_bytes);
                        store.admit(key, c.per_expert_cached);
                        (done, StallCause::Demand, store.home(key))
                    }
                }
            }
        }
    };
    // the little variant counts as resident: it is pinned on-device, so
    // no intra-predictor top-up applies to a degraded resolution
    let resident = !matches!(looked, Lookup::Miss) || degraded;
    let t_exp = match boundary.as_deref_mut() {
        // first GEMV of this expert at this boundary pays the
        // weight-bound cost; batched repeats ride the streamed weights
        // at the calibrated marginal-row ratio
        Some(share) => {
            if share.visit(key) {
                c.exp_compute
            } else {
                c.exp_compute * c.boundary_reuse
            }
        }
        None => c.exp_compute,
    };
    Some(ExpertWork { key, ready_at, cause, exec_dev, resident, t_exp })
}

/// Execute one resolved expert GEMV: charge the (residual) transfer
/// wait, pay the FloE intra-predictor top-up when the expert was not
/// resident, run the GEMV on its compute stream (or the token timeline)
/// and return the completion time for its gemv-complete event. Shared by
/// the lockstep and readiness-ordered dispatch paths — with overlap off
/// the store-call sequence is identical to the frozen busy-until
/// reference, which is what the bit-exactness pins assert.
fn exec_expert(
    p: &SimParams,
    c: &SimCtx,
    store: &mut ExpertStore,
    streams: &mut Option<&mut ComputeStreams>,
    w: &ExpertWork,
    layer_end: &mut f64,
    compute_us: &mut f64,
) -> f64 {
    if let Some(st) = streams.as_deref_mut() {
        // per-device compute streams: the GEMV occupies exec_dev's own
        // timeline; waits are stalls on that stream and the token clock
        // catches up at the layer barrier
        let mut start = st.free_us[w.exec_dev].max(store.now_us());
        if w.ready_at > start {
            store.charge_stall(w.cause, w.ready_at - start);
            start = w.ready_at;
        }
        if p.system.kind == SystemKind::Floe && !w.resident {
            let miss = (1.0 - p.intra_recall).max(0.0);
            if miss > 0.0 {
                let extra = c.per_expert_bytes * miss * 0.5;
                let done = store.critical_copy_to(
                    store.home(w.key),
                    p.pcie.copy_us(extra),
                    extra,
                );
                if done > start {
                    store.charge_stall(StallCause::Demand, done - start);
                    start = done;
                }
            }
        }
        let t_dev = store.placement().topo.gemv_us(w.exec_dev, w.t_exp);
        let end = start + t_dev;
        st.free_us[w.exec_dev] = end;
        *layer_end = (*layer_end).max(end);
        *compute_us += t_dev;
        end
    } else {
        store.stall_until_for(w.ready_at, w.cause);
        // intra-predictor misses force a small on-demand top-up (rides
        // the priority demand lane in overlap mode; identical to
        // `bus_copy_to` otherwise)
        if p.system.kind == SystemKind::Floe && !w.resident {
            let miss = (1.0 - p.intra_recall).max(0.0);
            if miss > 0.0 {
                let extra = c.per_expert_bytes * miss * 0.5;
                let done = store.critical_copy_to(
                    store.home(w.key),
                    p.pcie.copy_us(extra),
                    extra,
                );
                store.stall_until_for(done, StallCause::Demand);
            }
        }
        store.tick(w.t_exp);
        *compute_us += w.t_exp;
        store.now_us()
    }
}

/// One token through all layers: attention, next-layer prefetch issue,
/// expert execution with residency/stall accounting, all time
/// progression flowing through the event `core`. Returns this token's
/// compute µs. `boundary` (serving mode) tracks experts already computed
/// at this token boundary by other sequences in the batch — repeats cost
/// `SimCtx::boundary_reuse` of the full GEMV. `streams` (multi-device,
/// `--compute-streams`) carries the per-device compute timelines. With
/// `SimCtx::overlap` off, every expert pushes and pops its own events in
/// routing order — bit-exact with `simulate_busyuntil_reference` (and
/// the older scalar/sharded references); with it on, the layer's fetches
/// are resolved upfront and transfer completions release their GEMVs in
/// readiness order, charging only the residual wait.
#[allow(clippy::too_many_arguments)]
fn sim_decode_token(
    p: &SimParams,
    c: &SimCtx,
    store: &mut ExpertStore,
    core: &mut EventCore,
    rng: &mut Rng,
    prev: &mut Vec<Vec<usize>>,
    kv_len: usize,
    deadline_us: f64,
    mut boundary: Option<&mut BoundaryShare>,
    mut streams: Option<&mut ComputeStreams>,
) -> f64 {
    let d = &p.dims;
    let routing = p.routing.sample(rng, d.n_experts, d.top_k, prev, &c.zipf);
    let mut compute_us = 0.0;
    for l in 0..d.n_layers {
        // layer boundary: let the store act on measured popularity
        // (no-op unless the placement is Balanced / replicating)
        store.rebalance_tick();

        // overlap: resolve the layer's routed experts *before* the
        // attention tick and the l+1 prefetch plans — demand fetches
        // take bus priority over next-layer speculative traffic and
        // their transfers stream under the attention compute. Resolving
        // consumes no RNG, so the draw stream matches lockstep exactly.
        let mut work: Vec<ExpertWork> = Vec::new();
        if c.overlap {
            work.reserve(routing[l].len());
            for &e in &routing[l] {
                let key = (l, e);
                if let Some(w) = resolve_expert(
                    p,
                    c,
                    store,
                    core,
                    key,
                    deadline_us,
                    &mut boundary,
                    &mut compute_us,
                ) {
                    work.push(w);
                }
            }
        }

        // attention (always resident)
        let attn = p.gpu.attn_layer_us(d, kv_len);
        store.tick(attn);
        compute_us += attn;

        // FloE / Advanced issue prefetch *plans* for layer l+1 now: one
        // plan per destination device, coalesced into a chunked copy when
        // the placement allows it
        if l + 1 < d.n_layers && c.per_expert_bytes > 0.0 {
            let (hit_rate, overlap) = match p.system.kind {
                SystemKind::Floe => (p.inter_hit, true),
                SystemKind::AdvancedOffload => (p.adv_prefetch_hit, false),
                _ => (0.0, false),
            };
            if hit_rate > 0.0 {
                let mode = if !overlap {
                    // same-layer prefetch blocks compute (§2)
                    PlanMode::Blocking
                } else if c.coalesce {
                    PlanMode::Coalesced
                } else {
                    PlanMode::Overlapped
                };
                let mut plans: Vec<TransferPlan<()>> = (0..store.n_devices())
                    .map(|dst| TransferPlan::to(dst, mode))
                    .collect();
                for &e in &routing[l + 1] {
                    let key = (l + 1, e);
                    let predicted = rng.f64() < hit_rate;
                    if predicted
                        && !store.contains(key)
                        && !(c.dedup_inflight && store.inflight(key))
                    {
                        let dur = p.pcie.copy_us(c.per_expert_bytes);
                        plans[store.home(key)].push(
                            key,
                            c.per_expert_bytes,
                            dur,
                            p.pcie.api_us,
                            (),
                        );
                    }
                }
                for plan in plans {
                    if !plan.is_empty() {
                        store.submit(plan);
                    }
                }
            }
        }

        // expert execution at layer l, dispatched through the event core
        let mut layer_end = store.now_us();
        if !c.overlap {
            // lockstep: resolve → execute one expert at a time in
            // routing order (push-one/pop-one) — the frozen busy-until
            // op sequence, replayed through the heap
            for &e in &routing[l] {
                let key = (l, e);
                let Some(w) = resolve_expert(
                    p,
                    c,
                    store,
                    core,
                    key,
                    deadline_us,
                    &mut boundary,
                    &mut compute_us,
                ) else {
                    continue;
                };
                core.push(w.ready_at, EventKind::TransferComplete, key_id(key));
                core.pop();
                let end = exec_expert(
                    p,
                    c,
                    store,
                    &mut streams,
                    &w,
                    &mut layer_end,
                    &mut compute_us,
                );
                core.push(end, EventKind::GemvComplete, key_id(key));
                core.pop();
            }
        } else {
            // overlap: the layer's experts were resolved before the
            // attention tick (demand fetches queued at layer start, so
            // they stream under attention and never finish later than
            // under lockstep); pop transfer completions in readiness
            // order — resident experts compute while fetches are in
            // flight and each released GEMV pays only the residual wait
            for (i, w) in work.iter().enumerate() {
                core.push(w.ready_at, EventKind::TransferComplete, i as u64);
            }
            // exactly 2N pops (N transfer completions, each scheduling
            // one GEMV completion) — bounded so serving-level events
            // (request arrivals) pending in the shared heap are left
            // for their own consumer
            for _ in 0..2 * work.len() {
                let ev = core.pop().expect("layer event vanished from the heap");
                match ev.kind {
                    EventKind::TransferComplete => {
                        let w = &work[ev.id as usize];
                        let end = exec_expert(
                            p,
                            c,
                            store,
                            &mut streams,
                            w,
                            &mut layer_end,
                            &mut compute_us,
                        );
                        core.push(end, EventKind::GemvComplete, key_id(w.key));
                    }
                    EventKind::GemvComplete => {}
                    _ => unreachable!("decode layers schedule only transfer/gemv events"),
                }
            }
        }
        if streams.is_some() {
            // layer barrier: the router needs every expert output before
            // layer l+1 — waiting for the slowest stream is free time on
            // the token clock, not a stall
            store.advance_to(layer_end);
        }
        core.push(store.now_us(), EventKind::BoundaryBarrier, l as u64);
        core.pop();
    }
    compute_us
}

/// One token for the whole in-flight batch, layer-synchronously —
/// `SimServeBackend::step_batch` under `--overlap`. Each layer resolves
/// the *batch's* routed experts first (demand fetches hit the bus before
/// the next layer's speculative prefetch), runs every sequence's
/// attention, issues the batch's l+1 prefetch plans, then releases GEMVs
/// across the whole boundary in readiness order off the event heap — one
/// sequence's in-flight transfer hides under the other sequences'
/// compute instead of charging a full stall on its own lane. Per-seq RNG
/// streams see the exact lockstep draw order (routing sampled at token
/// start per sequence, prefetch draws in layer order per sequence), so
/// routing and prediction are identical to the per-sequence path.
/// Returns per-sequence compute µs, indexed like `seqs`.
fn sim_decode_boundary(
    p: &SimParams,
    c: &SimCtx,
    store: &mut ExpertStore,
    core: &mut EventCore,
    seqs: &mut [&mut SimSeq],
    boundary: &mut BoundaryShare,
    mut streams: Option<&mut ComputeStreams>,
) -> Vec<f64> {
    let d = &p.dims;
    let mut computes = vec![0.0; seqs.len()];
    let routings: Vec<Vec<Vec<usize>>> = seqs
        .iter_mut()
        .map(|s| p.routing.sample(&mut s.rng, d.n_experts, d.top_k, &mut s.prev, &c.zipf))
        .collect();
    let kv_lens: Vec<usize> = seqs.iter().map(|s| s.input_len + s.emitted).collect();
    for l in 0..d.n_layers {
        store.rebalance_tick();

        // resolve the whole batch's layer-l experts before any attention
        // tick or speculative traffic (boundary-share visits happen here,
        // in (sequence, routing) order — same as the lockstep path)
        let mut work: Vec<(ExpertWork, usize)> = Vec::new();
        {
            let mut share = Some(&mut *boundary);
            for si in 0..seqs.len() {
                store.set_attribution(seqs[si].id);
                for &e in &routings[si][l] {
                    let key = (l, e);
                    if let Some(w) = resolve_expert(
                        p,
                        c,
                        store,
                        core,
                        key,
                        seqs[si].deadline_us,
                        &mut share,
                        &mut computes[si],
                    ) {
                        work.push((w, si));
                    }
                }
            }
        }

        // every sequence's attention at this layer (always resident)
        for si in 0..seqs.len() {
            let attn = p.gpu.attn_layer_us(d, kv_lens[si]);
            store.tick(attn);
            computes[si] += attn;
        }

        // the batch's l+1 prefetch plans — one plan per destination
        // device across the whole batch, each sequence drawing from its
        // own RNG in batch order
        if l + 1 < d.n_layers && c.per_expert_bytes > 0.0 {
            let (hit_rate, ov) = match p.system.kind {
                SystemKind::Floe => (p.inter_hit, true),
                SystemKind::AdvancedOffload => (p.adv_prefetch_hit, false),
                _ => (0.0, false),
            };
            if hit_rate > 0.0 {
                let mode = if !ov {
                    PlanMode::Blocking
                } else if c.coalesce {
                    PlanMode::Coalesced
                } else {
                    PlanMode::Overlapped
                };
                let mut plans: Vec<TransferPlan<()>> = (0..store.n_devices())
                    .map(|dst| TransferPlan::to(dst, mode))
                    .collect();
                for si in 0..seqs.len() {
                    for &e in &routings[si][l + 1] {
                        let key = (l + 1, e);
                        let predicted = seqs[si].rng.f64() < hit_rate;
                        if predicted
                            && !store.contains(key)
                            && !(c.dedup_inflight && store.inflight(key))
                        {
                            let dur = p.pcie.copy_us(c.per_expert_bytes);
                            plans[store.home(key)].push(
                                key,
                                c.per_expert_bytes,
                                dur,
                                p.pcie.api_us,
                                (),
                            );
                        }
                    }
                }
                for plan in plans {
                    if !plan.is_empty() {
                        store.submit(plan);
                    }
                }
            }
        }

        // release GEMVs across the batch in readiness order: the heap's
        // time-then-sequence order is a stable sort on ready time, ties
        // keeping (sequence, routing) push order
        let mut layer_end = store.now_us();
        for (i, (w, _)) in work.iter().enumerate() {
            core.push(w.ready_at, EventKind::TransferComplete, i as u64);
        }
        for _ in 0..2 * work.len() {
            let ev = core.pop().expect("boundary event vanished from the heap");
            match ev.kind {
                EventKind::TransferComplete => {
                    let (w, si) = &work[ev.id as usize];
                    store.set_attribution(seqs[*si].id);
                    let end = exec_expert(
                        p,
                        c,
                        store,
                        &mut streams,
                        w,
                        &mut layer_end,
                        &mut computes[*si],
                    );
                    core.push(end, EventKind::GemvComplete, key_id(w.key));
                }
                EventKind::GemvComplete => {}
                _ => unreachable!("decode layers schedule only transfer/gemv events"),
            }
        }
        if streams.is_some() {
            store.advance_to(layer_end);
        }
        core.push(store.now_us(), EventKind::BoundaryBarrier, l as u64);
        core.pop();
    }
    computes
}

fn simulate_core(
    p: &SimParams,
    input_len: usize,
    output_len: usize,
    trace: bool,
) -> (SimReport, Vec<u8>) {
    let mut rng = Rng::new(p.routing.seed);
    let d = &p.dims;
    let mut prev: Vec<Vec<usize>> = vec![Vec::new(); d.n_layers];

    let budget = cache_budget_bytes(p, input_len + output_len);
    // all residency state — per-device caches, policies, in-flight
    // prefetches, bus timelines, stall attribution — lives in the store
    let mut store = build_store(p, budget);
    let c = SimCtx::new(p, budget, false);
    let mut core = if trace { EventCore::recording() } else { EventCore::new() };
    let mut streams =
        if c.streams { Some(ComputeStreams::new(store.n_devices())) } else { None };

    let mut compute_us = 0.0;
    let prefill_us = {
        let t0 = store.now_us();
        sim_prefill(p, &c, &mut store, input_len);
        store.now_us() - t0
    };

    warm_cache(p, &c, &mut store);
    seed_little_pools(p, &c, &mut store);
    if store.placement().topo.clustered() {
        seed_cluster_host_pools(p, &c, &mut store);
    }

    for tok in 0..output_len {
        compute_us += sim_decode_token(
            p,
            &c,
            &mut store,
            &mut core,
            &mut rng,
            &mut prev,
            input_len + tok,
            f64::INFINITY,
            None,
            streams.as_mut(),
        );
    }

    let total = store.now_us();
    let report = SimReport {
        tokens: output_len,
        total_us: total,
        prefill_us,
        compute_us,
        stall_us: store.stats().stall_us,
        transferred_gb: store.stats().transferred_bytes / 1e9,
        transferred_bytes: store.stats().transferred_bytes,
        bus_transactions: store.stats().bus_transactions,
        max_device_bus_busy_us: max_device_busy(&store),
        cache_hit_rate: store.cache_stats().hit_rate(),
        tps: output_len as f64 / (total / 1e6),
    };
    (report, core.log_bytes().to_vec())
}

pub fn simulate(p: &SimParams, input_len: usize, output_len: usize) -> SimReport {
    simulate_core(p, input_len, output_len, false).0
}

/// `simulate` plus the event core's popped-event byte log. The
/// determinism pins run a configuration twice and compare logs
/// byte-for-byte (17 bytes per popped event). Not public API.
#[doc(hidden)]
pub fn simulate_traced(
    p: &SimParams,
    input_len: usize,
    output_len: usize,
) -> (SimReport, Vec<u8>) {
    simulate_core(p, input_len, output_len, true)
}

/// The PRE-event-core decode token: per-device busy-until arithmetic
/// inlined in one loop, kept verbatim from before the event-core
/// redesign. `simulate_busyuntil_reference` drives it; the sim tests and
/// `tests/shard_store.rs` pin `simulate` (overlap off) to it bit-exactly
/// across systems × VRAM × devices × shard policies — the guarantee that
/// routing time through the event heap changed no observable number.
#[allow(clippy::too_many_arguments)]
fn busyuntil_decode_token(
    p: &SimParams,
    c: &SimCtx,
    store: &mut ExpertStore,
    rng: &mut Rng,
    prev: &mut Vec<Vec<usize>>,
    kv_len: usize,
    mut boundary: Option<&mut BoundaryShare>,
    mut streams: Option<&mut ComputeStreams>,
) -> f64 {
    let d = &p.dims;
    let routing = p.routing.sample(rng, d.n_experts, d.top_k, prev, &c.zipf);
    let mut compute_us = 0.0;
    for l in 0..d.n_layers {
        store.rebalance_tick();
        let attn = p.gpu.attn_layer_us(d, kv_len);
        store.tick(attn);
        compute_us += attn;

        if l + 1 < d.n_layers && c.per_expert_bytes > 0.0 {
            let (hit_rate, overlap) = match p.system.kind {
                SystemKind::Floe => (p.inter_hit, true),
                SystemKind::AdvancedOffload => (p.adv_prefetch_hit, false),
                _ => (0.0, false),
            };
            if hit_rate > 0.0 {
                let mode = if !overlap {
                    PlanMode::Blocking
                } else if c.coalesce {
                    PlanMode::Coalesced
                } else {
                    PlanMode::Overlapped
                };
                let mut plans: Vec<TransferPlan<()>> = (0..store.n_devices())
                    .map(|dst| TransferPlan::to(dst, mode))
                    .collect();
                for &e in &routing[l + 1] {
                    let key = (l + 1, e);
                    let predicted = rng.f64() < hit_rate;
                    if predicted
                        && !store.contains(key)
                        && !(c.dedup_inflight && store.inflight(key))
                    {
                        let dur = p.pcie.copy_us(c.per_expert_bytes);
                        plans[store.home(key)].push(
                            key,
                            c.per_expert_bytes,
                            dur,
                            p.pcie.api_us,
                            (),
                        );
                    }
                }
                for plan in plans {
                    if !plan.is_empty() {
                        store.submit(plan);
                    }
                }
            }
        }

        let mut layer_end = store.now_us();
        for &e in &routing[l] {
            let key = (l, e);
            let looked = if c.resident_fits {
                Lookup::Local(store.home(key))
            } else {
                store.lookup(key)
            };
            let resident = !matches!(looked, Lookup::Miss);
            let (ready_at, cause, exec_dev) = match looked {
                Lookup::Local(dev) => (store.now_us(), StallCause::Demand, dev),
                Lookup::Remote(from) => {
                    (store.peer_fetch(key, from), StallCause::Demand, store.home(key))
                }
                Lookup::RemoteNode(_) => {
                    unreachable!("the frozen reference runs single-node topologies only")
                }
                Lookup::Degraded(_) => {
                    unreachable!("lookup never returns Degraded")
                }
                Lookup::Miss => {
                    if let Some((t_done, ())) = store.take_inflight(key) {
                        store.admit(key, c.per_expert_cached);
                        (t_done, StallCause::PrefetchMiss, store.home(key))
                    } else if p.system.kind == SystemKind::Fiddler {
                        let t = p.cpu.expert_us(d);
                        store.tick(t);
                        compute_us += t;
                        continue;
                    } else {
                        let done = store.demand_fetch_for(
                            key,
                            p.pcie.copy_us(c.per_expert_bytes.max(1.0)),
                            c.per_expert_bytes,
                        );
                        store.admit(key, c.per_expert_cached);
                        (done, StallCause::Demand, store.home(key))
                    }
                }
            };
            let t_exp = match boundary.as_deref_mut() {
                Some(share) => {
                    if share.visit(key) {
                        c.exp_compute
                    } else {
                        c.exp_compute * c.boundary_reuse
                    }
                }
                None => c.exp_compute,
            };
            if let Some(st) = streams.as_deref_mut() {
                let mut start = st.free_us[exec_dev].max(store.now_us());
                if ready_at > start {
                    store.charge_stall(cause, ready_at - start);
                    start = ready_at;
                }
                if p.system.kind == SystemKind::Floe && !resident {
                    let miss = (1.0 - p.intra_recall).max(0.0);
                    if miss > 0.0 {
                        let extra = c.per_expert_bytes * miss * 0.5;
                        let done = store.bus_copy_to(
                            store.home(key),
                            p.pcie.copy_us(extra),
                            extra,
                        );
                        if done > start {
                            store.charge_stall(StallCause::Demand, done - start);
                            start = done;
                        }
                    }
                }
                let t_dev = store.placement().topo.gemv_us(exec_dev, t_exp);
                let end = start + t_dev;
                st.free_us[exec_dev] = end;
                layer_end = layer_end.max(end);
                compute_us += t_dev;
            } else {
                store.stall_until_for(ready_at, cause);
                if p.system.kind == SystemKind::Floe && !resident {
                    let miss = (1.0 - p.intra_recall).max(0.0);
                    if miss > 0.0 {
                        let extra = c.per_expert_bytes * miss * 0.5;
                        let done = store.bus_copy_to(
                            store.home(key),
                            p.pcie.copy_us(extra),
                            extra,
                        );
                        store.stall_until_for(done, StallCause::Demand);
                    }
                }
                store.tick(t_exp);
                compute_us += t_exp;
            }
        }
        if streams.is_some() {
            store.advance_to(layer_end);
        }
    }
    compute_us
}

/// Executable specification of the PRE-event-core simulator: the same
/// single-request driver over `busyuntil_decode_token` — the scattered
/// busy-until timeline arithmetic the event heap replaced. `simulate`
/// with overlap off is pinned to this bit-exactly (every SimReport f64
/// compared via `to_bits`) across the full configuration matrix. Not
/// part of the public API surface.
#[doc(hidden)]
pub fn simulate_busyuntil_reference(
    p: &SimParams,
    input_len: usize,
    output_len: usize,
) -> SimReport {
    assert!(!p.system.overlap, "the busy-until reference predates overlap");
    let mut rng = Rng::new(p.routing.seed);
    let d = &p.dims;
    let mut prev: Vec<Vec<usize>> = vec![Vec::new(); d.n_layers];

    let budget = cache_budget_bytes(p, input_len + output_len);
    let mut store = build_store(p, budget);
    let c = SimCtx::new(p, budget, false);
    let mut streams =
        if c.streams { Some(ComputeStreams::new(store.n_devices())) } else { None };

    let mut compute_us = 0.0;
    let prefill_us = {
        let t0 = store.now_us();
        sim_prefill(p, &c, &mut store, input_len);
        store.now_us() - t0
    };

    warm_cache(p, &c, &mut store);

    for tok in 0..output_len {
        compute_us += busyuntil_decode_token(
            p,
            &c,
            &mut store,
            &mut rng,
            &mut prev,
            input_len + tok,
            None,
            streams.as_mut(),
        );
    }

    let total = store.now_us();
    SimReport {
        tokens: output_len,
        total_us: total,
        prefill_us,
        compute_us,
        stall_us: store.stats().stall_us,
        transferred_gb: store.stats().transferred_bytes / 1e9,
        transferred_bytes: store.stats().transferred_bytes,
        bus_transactions: store.stats().bus_transactions,
        max_device_bus_busy_us: max_device_busy(&store),
        cache_hit_rate: store.cache_stats().hit_rate(),
        tps: output_len as f64 / (total / 1e6),
    }
}

/// Executable specification of the PRE-placement simulator: the
/// one-expert-per-call scalar store API (single device, single bus, no
/// plans, no coalescing), kept verbatim from before the `TransferPlan`
/// redesign. `tests/shard_store.rs` pins `simulate` at `--devices 1
/// --policy lru` to this reference *bit-exactly* — the guarantee that the
/// redesign reproduces the old Fig-6/Fig-8 JSON byte-for-byte. Not part
/// of the public API surface.
#[doc(hidden)]
pub fn simulate_scalar_reference(
    p: &SimParams,
    input_len: usize,
    output_len: usize,
) -> SimReport {
    assert_eq!(p.system.devices, 1, "the scalar reference is single-device");
    assert!(!p.system.coalesce, "the scalar reference predates coalescing");
    let mut rng = Rng::new(p.routing.seed);
    let d = &p.dims;
    let mut prev: Vec<Vec<usize>> = vec![Vec::new(); d.n_layers];

    let budget = cache_budget_bytes(p, input_len + output_len);
    let mut store: ExpertStore =
        ExpertStore::with_virtual_clock(budget as usize, p.system.residency);
    let c = SimCtx::new(p, budget, false);

    // ---- prefill (pre-redesign body) ----
    let mut compute_us = 0.0;
    let prefill_us = {
        let t0 = store.now_us();
        for _l in 0..d.n_layers {
            let flops = 12.0 * input_len as f64 * (d.d_model as f64).powi(2);
            store.tick(flops / (p.gpu.fp16_tflops * 1e6) + 4.0 * p.gpu.launch_us);
            match p.system.kind {
                SystemKind::GpuResident if c.resident_fits => {
                    store.tick(c.exp_compute * d.n_experts as f64 * 0.5);
                }
                SystemKind::Fiddler => {
                    let bytes = d.n_experts as f64 * d.expert_bytes_fp16();
                    let done = store.bus_copy(p.pcie.copy_us(bytes), bytes);
                    store.advance_to(done);
                    store.tick(c.exp_compute * d.n_experts as f64 * 0.5);
                }
                _ => {
                    let bytes = d.n_experts as f64 * c.per_expert_bytes.max(
                        if p.system.kind == SystemKind::GpuResident {
                            d.expert_bytes_quant(2.0)
                        } else {
                            0.0
                        },
                    );
                    if bytes > 0.0 {
                        let done = store.bus_copy(p.pcie.copy_us(bytes), bytes);
                        store.advance_to(done);
                    }
                    store.tick(c.exp_compute * d.n_experts as f64 * 0.5);
                }
            }
        }
        store.now_us() - t0
    };

    // ---- warm cache (pre-redesign body; admission filter bypassed
    // exactly as the old unfiltered admit did) ----
    {
        let mut order: Vec<(usize, usize)> = (0..d.n_layers)
            .flat_map(|l| (0..d.n_experts).map(move |e| (l, e)))
            .collect();
        order.sort_by_key(|(_, e)| *e);
        for key in order {
            if !store.warm_admit(key, c.per_expert_cached) {
                break;
            }
        }
    }

    // ---- decode (pre-redesign body, scalar calls) ----
    for tok in 0..output_len {
        let kv_len = input_len + tok;
        let routing = p.routing.sample(&mut rng, d.n_experts, d.top_k, &mut prev, &c.zipf);
        for l in 0..d.n_layers {
            let attn = p.gpu.attn_layer_us(d, kv_len);
            store.tick(attn);
            compute_us += attn;

            if l + 1 < d.n_layers && c.per_expert_bytes > 0.0 {
                let (hit_rate, overlap) = match p.system.kind {
                    SystemKind::Floe => (p.inter_hit, true),
                    SystemKind::AdvancedOffload => (p.adv_prefetch_hit, false),
                    _ => (0.0, false),
                };
                if hit_rate > 0.0 {
                    for &e in &routing[l + 1] {
                        let predicted = rng.f64() < hit_rate;
                        if predicted && !store.contains((l + 1, e)) {
                            let dur = p.pcie.copy_us(c.per_expert_bytes);
                            if overlap {
                                store.begin_prefetch(
                                    (l + 1, e),
                                    dur,
                                    c.per_expert_bytes,
                                    (),
                                );
                            } else {
                                let done = store.begin_prefetch_blocking(
                                    (l + 1, e),
                                    dur,
                                    c.per_expert_bytes,
                                    (),
                                );
                                store.stall_until_for(done, StallCause::PrefetchMiss);
                            }
                        }
                    }
                }
            }

            for &e in &routing[l] {
                let key = (l, e);
                let resident = c.resident_fits || store.access(key);
                let (ready_at, cause) = if resident {
                    (store.now_us(), StallCause::Demand)
                } else if let Some((t_done, ())) = store.take_inflight(key) {
                    store.admit(key, c.per_expert_cached);
                    (t_done, StallCause::PrefetchMiss)
                } else if p.system.kind == SystemKind::Fiddler {
                    let t = p.cpu.expert_us(d);
                    store.tick(t);
                    compute_us += t;
                    continue;
                } else {
                    let done = store.demand_fetch(
                        p.pcie.copy_us(c.per_expert_bytes.max(1.0)),
                        c.per_expert_bytes,
                    );
                    store.admit(key, c.per_expert_cached);
                    (done, StallCause::Demand)
                };
                store.stall_until_for(ready_at, cause);
                if p.system.kind == SystemKind::Floe && !resident {
                    let miss = (1.0 - p.intra_recall).max(0.0);
                    if miss > 0.0 {
                        let extra = c.per_expert_bytes * miss * 0.5;
                        let done = store.bus_copy(p.pcie.copy_us(extra), extra);
                        store.stall_until_for(done, StallCause::Demand);
                    }
                }
                store.tick(c.exp_compute);
                compute_us += c.exp_compute;
            }
        }
    }

    let total = store.now_us();
    SimReport {
        tokens: output_len,
        total_us: total,
        prefill_us,
        compute_us,
        stall_us: store.stats().stall_us,
        transferred_gb: store.stats().transferred_bytes / 1e9,
        transferred_bytes: store.stats().transferred_bytes,
        bus_transactions: store.stats().bus_transactions,
        max_device_bus_busy_us: max_device_busy(&store),
        cache_hit_rate: store.cache_stats().hit_rate(),
        tps: output_len as f64 / (total / 1e6),
    }
}

/// Executable specification of the PRE-popularity placement simulator
/// (PR 3): the plan-based multi-device decode path kept verbatim from
/// before the popularity redesign — no rebalancing, no replicas, no
/// per-device compute streams. `tests/shard_store.rs` pins `simulate`
/// under every static shard policy (`layer`/`expert`/`hash`, replication
/// off, streams off) to this reference *bit-exactly*, which is the claim
/// that the popularity machinery is observationally free until opted
/// into. Shares `sim_prefill`/`warm_cache`/`SimCtx` (unchanged by the
/// redesign); only the decode body is frozen. Not public API.
#[doc(hidden)]
pub fn simulate_sharded_reference(
    p: &SimParams,
    input_len: usize,
    output_len: usize,
) -> SimReport {
    assert_eq!(p.system.replicate_top, 0, "the sharded reference predates replication");
    assert!(!p.system.compute_streams, "the sharded reference predates compute streams");
    let mut rng = Rng::new(p.routing.seed);
    let d = &p.dims;
    let mut prev: Vec<Vec<usize>> = vec![Vec::new(); d.n_layers];

    let budget = cache_budget_bytes(p, input_len + output_len);
    let mut store = build_store(p, budget);
    let c = SimCtx::new(p, budget, false);

    let mut compute_us = 0.0;
    let prefill_us = {
        let t0 = store.now_us();
        sim_prefill(p, &c, &mut store, input_len);
        store.now_us() - t0
    };

    warm_cache(p, &c, &mut store);

    // ---- decode (PR 3 plan-based body, kept verbatim) ----
    for tok in 0..output_len {
        let kv_len = input_len + tok;
        let routing = p.routing.sample(&mut rng, d.n_experts, d.top_k, &mut prev, &c.zipf);
        for l in 0..d.n_layers {
            let attn = p.gpu.attn_layer_us(d, kv_len);
            store.tick(attn);
            compute_us += attn;

            if l + 1 < d.n_layers && c.per_expert_bytes > 0.0 {
                let (hit_rate, overlap) = match p.system.kind {
                    SystemKind::Floe => (p.inter_hit, true),
                    SystemKind::AdvancedOffload => (p.adv_prefetch_hit, false),
                    _ => (0.0, false),
                };
                if hit_rate > 0.0 {
                    let mode = if !overlap {
                        PlanMode::Blocking
                    } else if c.coalesce {
                        PlanMode::Coalesced
                    } else {
                        PlanMode::Overlapped
                    };
                    let mut plans: Vec<TransferPlan<()>> = (0..store.n_devices())
                        .map(|dst| TransferPlan::to(dst, mode))
                        .collect();
                    for &e in &routing[l + 1] {
                        let key = (l + 1, e);
                        let predicted = rng.f64() < hit_rate;
                        if predicted
                            && !store.contains(key)
                            && !(c.dedup_inflight && store.inflight(key))
                        {
                            let dur = p.pcie.copy_us(c.per_expert_bytes);
                            plans[store.home(key)].push(
                                key,
                                c.per_expert_bytes,
                                dur,
                                p.pcie.api_us,
                                (),
                            );
                        }
                    }
                    for plan in plans {
                        if !plan.is_empty() {
                            store.submit(plan);
                        }
                    }
                }
            }

            for &e in &routing[l] {
                let key = (l, e);
                let looked = if c.resident_fits {
                    Lookup::Local(0)
                } else {
                    store.lookup(key)
                };
                let resident = !matches!(looked, Lookup::Miss);
                let (ready_at, cause) = match looked {
                    Lookup::Local(_) => (store.now_us(), StallCause::Demand),
                    Lookup::Remote(from) => {
                        (store.peer_fetch(key, from), StallCause::Demand)
                    }
                    Lookup::RemoteNode(_) => {
                        unreachable!("the frozen reference runs single-node topologies only")
                    }
                    Lookup::Degraded(_) => {
                        unreachable!("lookup never returns Degraded")
                    }
                    Lookup::Miss => {
                        if let Some((t_done, ())) = store.take_inflight(key) {
                            store.admit(key, c.per_expert_cached);
                            (t_done, StallCause::PrefetchMiss)
                        } else if p.system.kind == SystemKind::Fiddler {
                            let t = p.cpu.expert_us(d);
                            store.tick(t);
                            compute_us += t;
                            continue;
                        } else {
                            let done = store.demand_fetch_for(
                                key,
                                p.pcie.copy_us(c.per_expert_bytes.max(1.0)),
                                c.per_expert_bytes,
                            );
                            store.admit(key, c.per_expert_cached);
                            (done, StallCause::Demand)
                        }
                    }
                };
                store.stall_until_for(ready_at, cause);
                if p.system.kind == SystemKind::Floe && !resident {
                    let miss = (1.0 - p.intra_recall).max(0.0);
                    if miss > 0.0 {
                        let extra = c.per_expert_bytes * miss * 0.5;
                        let done =
                            store.bus_copy_to(store.home(key), p.pcie.copy_us(extra), extra);
                        store.stall_until_for(done, StallCause::Demand);
                    }
                }
                store.tick(c.exp_compute);
                compute_us += c.exp_compute;
            }
        }
    }

    let total = store.now_us();
    SimReport {
        tokens: output_len,
        total_us: total,
        prefill_us,
        compute_us,
        stall_us: store.stats().stall_us,
        transferred_gb: store.stats().transferred_bytes / 1e9,
        transferred_bytes: store.stats().transferred_bytes,
        bus_transactions: store.stats().bus_transactions,
        max_device_bus_busy_us: max_device_busy(&store),
        cache_hit_rate: store.cache_stats().hit_rate(),
        tps: output_len as f64 / (total / 1e6),
    }
}

// ------------------------------------------------------- batched serving

/// Per-sequence state in the batched serving simulator: its own routing
/// RNG (seeded from the request) and stickiness history, so completions
/// are deterministic regardless of how arrivals interleave.
pub struct SimSeq {
    id: u64,
    rng: Rng,
    prev: Vec<Vec<usize>>,
    input_len: usize,
    emitted: usize,
    max_tokens: usize,
    /// SLO deadline on the virtual timeline: admission time + the
    /// request's `slo_us` budget (`f64::INFINITY` when no budget was
    /// set, which disables the quality-elastic fallback for this
    /// sequence regardless of the little-tier carve)
    deadline_us: f64,
}

/// `SeqBackend` over the discrete-event model: the continuous-batching
/// scheduler drives concurrent simulated requests through one shared
/// `ExpertStore` on the virtual timeline. Used by `exp-serve-load`, the
/// scheduler property tests and the loopback server integration test —
/// none of which need artifacts or the `pjrt` feature.
pub struct SimServeBackend {
    p: SimParams,
    ctx: SimCtx,
    store: ExpertStore,
    /// same-boundary expert sharing: seen-set + full/reused visit counts
    boundary: BoundaryShare,
    /// per-device compute timelines (multi-device `--compute-streams`),
    /// shared by every sequence in the batch
    streams: Option<ComputeStreams>,
    /// the shared event heap: decode layers and request arrivals all
    /// route their time progression through it
    core: EventCore,
    /// monotone arrival counter — the `RequestArrival` event payload
    arrivals: u64,
}

impl SimServeBackend {
    /// `kv_tokens` sizes the KV-cache VRAM reservation (batch cap × the
    /// longest request context — bigger batches shrink the expert cache).
    pub fn new(p: SimParams, kv_tokens: usize) -> Self {
        Self::build(p, kv_tokens, false)
    }

    /// A backend whose event core records every popped event — the
    /// serving determinism pins compare two runs' logs byte-for-byte.
    #[doc(hidden)]
    pub fn new_traced(p: SimParams, kv_tokens: usize) -> Self {
        Self::build(p, kv_tokens, true)
    }

    fn build(p: SimParams, kv_tokens: usize, trace: bool) -> Self {
        let budget = cache_budget_bytes(&p, kv_tokens);
        let mut store = build_store(&p, budget);
        let ctx = SimCtx::new(&p, budget, true);
        warm_cache(&p, &ctx, &mut store);
        seed_little_pools(&p, &ctx, &mut store);
        if store.placement().topo.clustered() {
            seed_cluster_host_pools(&p, &ctx, &mut store);
        }
        let streams =
            if ctx.streams { Some(ComputeStreams::new(store.n_devices())) } else { None };
        let core = if trace { EventCore::recording() } else { EventCore::new() };
        SimServeBackend {
            p,
            ctx,
            store,
            boundary: BoundaryShare::default(),
            streams,
            core,
            arrivals: 0,
        }
    }

    pub fn store(&self) -> &ExpertStore {
        &self.store
    }

    /// Mutable store access for the cluster router (host-pool seeding
    /// and failure re-homing — `coordinator::cluster`).
    pub fn store_mut(&mut self) -> &mut ExpertStore {
        &mut self.store
    }

    /// Failure injection (cluster tier, DESIGN.md §10): advance this
    /// node's clock to the failure instant through the event heap, so a
    /// recorded event log carries the `NodeDown` pop at its exact time.
    /// `node` is the cluster-level id of the node that dropped.
    pub fn note_node_down(&mut self, t_us: f64, node: u64) {
        let t = t_us.max(self.store.now_us());
        self.core.push(t, EventKind::NodeDown, node);
        let ev = self.core.pop().expect("node-down event vanished from the heap");
        debug_assert_eq!(ev.kind, EventKind::NodeDown);
        self.store.advance_to(ev.t_us);
    }

    /// Fault schedule (DESIGN.md §12): one of this node's devices dropped
    /// at `t_us`. The `DeviceDown` pop lands in the event log at its
    /// exact time, then the store tears down the device — in-flight
    /// transfers voided, partial migrations rolled back, residents
    /// re-homed to survivors hottest-first. Returns the conservation
    /// report the property suite checks.
    pub fn note_device_down(&mut self, t_us: f64, dev: usize) -> DeviceDownReport {
        let t = t_us.max(self.store.now_us());
        self.core.push(t, EventKind::DeviceDown, dev as u64);
        let ev = self.core.pop().expect("device-down event vanished from the heap");
        debug_assert_eq!(ev.kind, EventKind::DeviceDown);
        self.store.advance_to(ev.t_us);
        self.store.device_down(dev)
    }

    /// Fault schedule (DESIGN.md §12): a link-degrade window opened at
    /// `t_us`. The window itself was installed into the store at session
    /// setup (pricing is a pure function of the schedule and the clock);
    /// this only stamps the `LinkDegrade` pop into the event log so two
    /// runs' logs carry the flap at the same byte offset.
    pub fn note_link_degrade(&mut self, t_us: f64, link: LinkId) {
        let t = t_us.max(self.store.now_us());
        self.core.push(t, EventKind::LinkDegrade, u64::from(link.tag()));
        let ev = self.core.pop().expect("link-degrade event vanished from the heap");
        debug_assert_eq!(ev.kind, EventKind::LinkDegrade);
        self.store.advance_to(ev.t_us);
    }

    /// Fault schedule (DESIGN.md §12): cluster node `node` rejoined at
    /// `t_us` — stamp the `NodeRejoin` pop and advance the clock. The
    /// driver re-seeds the returning node's pools and host copies over
    /// the network around this call (it owns the key lists).
    pub fn note_node_rejoin(&mut self, t_us: f64, node: u64) {
        let t = t_us.max(self.store.now_us());
        self.core.push(t, EventKind::NodeRejoin, node);
        let ev = self.core.pop().expect("node-rejoin event vanished from the heap");
        debug_assert_eq!(ev.kind, EventKind::NodeRejoin);
        self.store.advance_to(ev.t_us);
    }

    /// Rejoin protocol (DESIGN.md §12): the node lost its memory while
    /// down, so every pool is wiped and rebuilt from scratch — the
    /// little-tier sketches re-pin locally (they ship with the node
    /// image), and the host pool restocks its own-shard-first stageable
    /// list over the network as *full* pulls (`net_restore` — nothing is
    /// host-resident after the wipe, so every key pays real bytes),
    /// truncated to the host budget exactly like the boot seeding. VRAM
    /// resident sets stay cold: demand fetches refill them against the
    /// restocked host pool. Returns when the last restore plan lands.
    pub fn rejoin_restock(&mut self) -> f64 {
        self.store.wipe_for_rejoin();
        seed_little_pools(&self.p, &self.ctx, &mut self.store);
        let topo = self.store.placement().topo.clone();
        let span = topo.span_nodes.max(1);
        let total = topo.n_nodes.max(topo.node_id + span);
        let node = topo.node_id;
        let d = &self.p.dims;
        let (mut own, mut rest) = (Vec::new(), Vec::new());
        for l in 0..d.n_layers {
            for e in 0..d.n_experts {
                if e % total == node % total {
                    own.push((l, e));
                } else {
                    rest.push((l, e));
                }
            }
        }
        own.extend(rest);
        let bytes = self.ctx.per_expert_bytes.max(1.0) as usize;
        let budget = self.store.host_budget();
        let mut used = 0usize;
        let mut take = Vec::new();
        for key in own {
            if used + bytes > budget {
                break;
            }
            used += bytes;
            take.push(key);
        }
        self.store.net_restore(&take, bytes)
    }

    /// Bytes one expert transfer moves under this system's compression
    /// (the cluster router sizes failure re-homing copies with this).
    pub fn per_expert_bytes(&self) -> f64 {
        self.ctx.per_expert_bytes.max(1.0)
    }

    /// Same-boundary sharing counters (full vs amortized GEMV visits).
    pub fn boundary_stats(&self) -> &BoundaryShare {
        &self.boundary
    }

    /// The event core's popped-event byte log (empty unless built with
    /// `new_traced`).
    #[doc(hidden)]
    pub fn event_log(&self) -> &[u8] {
        self.core.log_bytes()
    }

}

impl SeqBackend for SimServeBackend {
    type Seq = SimSeq;

    fn now_us(&self) -> f64 {
        self.store.now_us()
    }

    fn on_boundary(&mut self) {
        self.boundary.reset();
    }

    fn start(&mut self, r: &Request) -> Result<(SimSeq, f64)> {
        // no stale-ledger drop needed: the scheduler retires every id's
        // attribution entry when its request completes (`retire`)
        self.store.set_attribution(r.id);
        let input_len = r.prompt.len().max(1);
        let t0 = self.store.now_us();
        // the SLO clock starts at admission, before prefill spends any
        // of the budget — a long prefill tightens every decode boundary
        let deadline_us = r.slo_us.map_or(f64::INFINITY, |slo| t0 + slo);
        sim_prefill(&self.p, &self.ctx, &mut self.store, input_len);
        Ok((
            SimSeq {
                id: r.id,
                rng: Rng::new(r.seed),
                prev: vec![Vec::new(); self.p.dims.n_layers],
                input_len,
                emitted: 0,
                max_tokens: r.max_tokens.max(1),
                deadline_us,
            },
            self.store.now_us() - t0,
        ))
    }

    fn step(&mut self, s: &mut SimSeq) -> Result<SeqStep> {
        self.store.set_attribution(s.id);
        let compute_us = sim_decode_token(
            &self.p,
            &self.ctx,
            &mut self.store,
            &mut self.core,
            &mut s.rng,
            &mut s.prev,
            s.input_len + s.emitted,
            s.deadline_us,
            Some(&mut self.boundary),
            self.streams.as_mut(),
        );
        // fail-fast outage (no retry policy): the store recorded the
        // structured cause mid-token; the step errors and the scheduler
        // retires the request with its pre-fault tokens attached
        if let Some(cause) = self.store.fault_of(s.id) {
            anyhow::bail!("transfer fault: {}", cause.as_str());
        }
        s.emitted += 1;
        Ok(SeqStep {
            token: Some(b'.'),
            finished: s.emitted >= s.max_tokens,
            compute_us,
        })
    }

    /// Mid-boundary overlap: with `--overlap` on, the whole batch steps
    /// through `sim_decode_boundary` layer-synchronously, so an in-flight
    /// transfer for one sequence releases its GEMV while the other
    /// sequences' attention and GEMVs run — instead of charging a full
    /// stall on the owning sequence's lane. Overlap off keeps the default
    /// per-sequence semantics (one `step` per sequence, in batch order),
    /// bit-exact with the frozen reference.
    fn step_batch(&mut self, seqs: &mut [&mut SimSeq]) -> Vec<Result<SeqStep>> {
        if !self.ctx.overlap {
            return seqs.iter_mut().map(|s| self.step(s)).collect();
        }
        let computes = sim_decode_boundary(
            &self.p,
            &self.ctx,
            &mut self.store,
            &mut self.core,
            seqs,
            &mut self.boundary,
            self.streams.as_mut(),
        );
        seqs.iter_mut()
            .zip(computes)
            .map(|(s, compute_us)| {
                if let Some(cause) = self.store.fault_of(s.id) {
                    anyhow::bail!("transfer fault: {}", cause.as_str());
                }
                s.emitted += 1;
                Ok(SeqStep {
                    token: Some(b'.'),
                    finished: s.emitted >= s.max_tokens,
                    compute_us,
                })
            })
            .collect()
    }

    /// Idle until `t_us` (waiting for the next arrival) — free time, not
    /// a stall. The arrival is an event like any other: pushed onto the
    /// heap, popped in time order (the heap is empty between token
    /// boundaries, so it pops immediately), and only then does the store
    /// clock jump.
    fn idle_until(&mut self, t_us: f64) {
        let id = self.arrivals;
        self.arrivals += 1;
        self.core.push(t_us, EventKind::RequestArrival, id);
        let ev = self.core.pop().expect("arrival event vanished from the heap");
        debug_assert_eq!(ev.kind, EventKind::RequestArrival);
        self.store.advance_to(ev.t_us);
    }

    fn stalls_of(&self, id: u64) -> StallSplit {
        self.store.stall_split_of(id)
    }

    fn retire(&mut self, id: u64) -> StallSplit {
        // fold the finished request's ledger entry into `retired` so the
        // attribution map stays bounded by the in-flight batch
        self.store.take_attribution(id)
    }

    fn degraded_of(&self, id: u64) -> DegradeCount {
        self.store.degraded_of(id)
    }

    fn take_degraded(&mut self, id: u64) -> DegradeCount {
        // the degraded ledger retires exactly like the stall ledger
        self.store.take_degraded_attribution(id)
    }

    fn take_fault_cause(&mut self, id: u64) -> Option<FaultCause> {
        self.store.take_fault(id)
    }

    fn snapshot(&self) -> Option<BackendSnapshot> {
        Some(BackendSnapshot {
            stats: self.store.stats().clone(),
            cache_hit_rate: self.store.cache_stats().hit_rate(),
        })
    }

    fn event_log_bytes(&self) -> &[u8] {
        self.core.log_bytes()
    }
}

/// Everything `exp-serve-load` (and the scheduler tests) read back from
/// one batched-serving run.
#[derive(Debug, Clone)]
pub struct ServeSimReport {
    pub completions: Vec<ServeCompletion>,
    pub total_us: f64,
    pub max_batch_seen: usize,
    pub admitted_order: Vec<u64>,
    pub stats: StoreStats,
    pub cache_hit_rate: f64,
}

impl ServeSimReport {
    pub fn total_tokens(&self) -> usize {
        self.completions.iter().map(|c| c.tokens).sum()
    }
    /// Aggregate decode throughput over the whole run, tokens/s.
    pub fn aggregate_tps(&self) -> f64 {
        self.total_tokens() as f64 / (self.total_us / 1e6).max(1e-9)
    }
    pub fn mean_queue_wait_us(&self) -> f64 {
        if self.completions.is_empty() {
            return 0.0;
        }
        self.completions.iter().map(|c| c.queue_wait_us).sum::<f64>()
            / self.completions.len() as f64
    }
    pub fn p95_latency_us(&self) -> f64 {
        self.latency_quantile(0.95)
    }
    pub fn p99_latency_us(&self) -> f64 {
        self.latency_quantile(0.99)
    }
    fn latency_quantile(&self, q: f64) -> f64 {
        if self.completions.is_empty() {
            return 0.0;
        }
        let mut lat: Vec<f64> = self.completions.iter().map(|c| c.latency_us()).collect();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        lat[((lat.len() - 1) as f64 * q).round() as usize]
    }
    /// Share of requests that resolved at least one boundary degraded.
    pub fn degraded_request_share(&self) -> f64 {
        if self.completions.is_empty() {
            return 0.0;
        }
        self.completions.iter().filter(|c| c.degraded.hits > 0).count() as f64
            / self.completions.len() as f64
    }
    /// Total degraded boundaries across the run.
    pub fn degraded_hits(&self) -> u64 {
        self.completions.iter().map(|c| c.degraded.hits).sum()
    }
}

/// Replay a workload arrival trace through the continuous-batching
/// scheduler over the simulated coordinator. The whole trace is enqueued
/// up front as `(request, arrival)` stamps; `Scheduler::step` observes
/// each arrival at the first token boundary at or after its stamp and
/// idles the event heap to the queue head (a `RequestArrival` event)
/// when the system drains before the next arrival — admission is
/// event-timed, not polled by this driver (bit-exact with the old lazy
/// per-boundary enqueue loop, pinned in the tests below).
pub fn simulate_serving(
    p: &SimParams,
    workload: &[TimedRequest],
    max_batch: usize,
) -> Result<ServeSimReport> {
    let max_ctx = workload
        .iter()
        .map(|t| t.req.prompt.len() + t.req.max_tokens)
        .max()
        .unwrap_or(512);
    let kv_tokens = max_batch.max(1) * max_ctx;
    let backend = SimServeBackend::new(p.clone(), kv_tokens);
    let mut sched = Scheduler::new(backend, max_batch);
    for t in workload {
        sched.enqueue_at(t.req.clone(), t.arrival_us);
    }
    let completions = sched.drain();
    let total_us = sched.backend().now_us();
    let max_batch_seen = sched.max_batch_seen();
    let admitted_order = sched.admitted_order().to_vec();
    let backend = sched.into_backend();
    Ok(ServeSimReport {
        completions,
        total_us,
        max_batch_seen,
        admitted_order,
        stats: backend.store().stats().clone(),
        cache_hit_rate: backend.store().cache_stats().hit_rate(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ResidencyKind;
    use crate::hwsim::RTX3090;

    fn run(kind: SystemKind, vram: f64) -> SimReport {
        let p = SimParams::mixtral_on(RTX3090.clone(), SystemConfig::new(kind), vram);
        simulate(&p, 64, 128)
    }

    #[test]
    fn ordering_matches_paper_fig6() {
        // GpuResident >= FloE > Fiddler/Advanced > Naive, on a 3090-class
        // budget where everything INT2 fits (24 GB).
        let floe = run(SystemKind::Floe, 24.0).tps;
        let naive = run(SystemKind::NaiveOffload, 24.0).tps;
        let adv = run(SystemKind::AdvancedOffload, 24.0).tps;
        let fid = run(SystemKind::Fiddler, 24.0).tps;
        let gpu = run(SystemKind::GpuResident, 24.0).tps;
        assert!(floe > adv, "floe {floe} adv {adv}");
        assert!(floe > fid, "floe {floe} fid {fid}");
        assert!(adv > naive, "adv {adv} naive {naive}");
        assert!(floe > 10.0 * naive, "floe {floe} naive {naive}");
        assert!(floe > 0.5 * gpu, "floe {floe} gpu {gpu}");
    }

    #[test]
    fn more_vram_helps_floe() {
        let lo = run(SystemKind::Floe, 12.0).tps;
        let hi = run(SystemKind::Floe, 24.0).tps;
        assert!(hi >= lo * 0.99, "lo {lo} hi {hi}");
    }

    #[test]
    fn longer_outputs_amortize() {
        let p = SimParams::mixtral_on(
            RTX3090.clone(),
            SystemConfig::new(SystemKind::Floe),
            12.0,
        );
        let short = simulate(&p, 64, 32);
        let long = simulate(&p, 64, 512);
        assert!(
            long.tps > short.tps,
            "short {} long {}",
            short.tps,
            long.tps
        );
    }

    #[test]
    fn floe_moves_fewer_bytes() {
        let floe = run(SystemKind::Floe, 12.0);
        let naive = run(SystemKind::NaiveOffload, 12.0);
        assert!(floe.transferred_gb < naive.transferred_gb / 4.0);
    }

    #[test]
    fn routing_model_is_deterministic() {
        let a = run(SystemKind::Floe, 12.0).tps;
        let b = run(SystemKind::Floe, 12.0).tps;
        assert_eq!(a, b);
    }

    #[test]
    fn every_policy_simulates_and_stays_deterministic() {
        // the routing trace consumes the RNG identically under every
        // eviction policy, so reports are reproducible policy-by-policy
        for kind in ResidencyKind::ALL {
            let p = SimParams::mixtral_on(
                RTX3090.clone(),
                SystemConfig::with_residency(SystemKind::Floe, kind),
                14.0,
            );
            let a = simulate(&p, 64, 128);
            let b = simulate(&p, 64, 128);
            assert_eq!(a.tps, b.tps, "{}", kind.name());
            assert!(a.tps.is_finite() && a.tps > 0.0, "{}", kind.name());
            assert!(a.cache_hit_rate >= 0.0 && a.cache_hit_rate <= 1.0);
        }
    }

    #[test]
    fn sharded_simulation_is_deterministic_and_spreads_traffic() {
        use crate::config::ShardPolicy;
        let mut p = SimParams::mixtral_on(
            RTX3090.clone(),
            SystemConfig::new(SystemKind::Floe).with_devices(2, ShardPolicy::Layer),
            12.0,
        );
        p.routing = RoutingModel { zipf_s: 1.2, stickiness: 0.5, seed: 7 };
        let a = simulate(&p, 64, 128);
        let b = simulate(&p, 64, 128);
        assert_eq!(a.tps, b.tps);
        assert_eq!(a.transferred_bytes, b.transferred_bytes);
        assert_eq!(a.bus_transactions, b.bus_transactions);
        assert!(a.tps.is_finite() && a.tps > 0.0);
    }

    #[test]
    fn sparsity_policy_hit_rate_not_worse_at_tight_vram() {
        // at a budget where eviction actually happens, the activation-
        // frequency policy should match or beat LRU on the Zipf trace
        let at = |kind: ResidencyKind| {
            let p = SimParams::mixtral_on(
                RTX3090.clone(),
                SystemConfig::with_residency(SystemKind::NaiveOffload, kind),
                14.0,
            );
            simulate(&p, 64, 128).cache_hit_rate
        };
        let lru = at(ResidencyKind::Lru);
        let sparsity = at(ResidencyKind::Sparsity);
        assert!(
            sparsity >= lru - 0.02,
            "sparsity {sparsity:.3} well below lru {lru:.3}"
        );
    }

    // ---------------------------------------------- batched serving sims

    // the exp-serve-load operating point (skewed routing, eviction-active
    // VRAM) — shared so retuning the experiment retunes these tests
    use crate::experiments::serveload::{sweep_params, workload_at, DEFAULT_VRAM_GB};

    #[test]
    fn serving_completes_all_requests_deterministically() {
        let p = sweep_params(ResidencyKind::Lru, DEFAULT_VRAM_GB);
        let wl = workload_at(4.0, 8, 11);
        let a = simulate_serving(&p, &wl, 4).unwrap();
        let b = simulate_serving(&p, &wl, 4).unwrap();
        assert_eq!(a.completions.len(), wl.len());
        assert_eq!(a.total_us, b.total_us);
        assert_eq!(a.aggregate_tps(), b.aggregate_tps());
        assert_eq!(a.stats.stall_us, b.stats.stall_us);
        // FIFO admission in arrival order
        let ids: Vec<u64> = wl.iter().map(|t| t.req.id).collect();
        assert_eq!(a.admitted_order, ids);
    }

    #[test]
    fn batching_increases_throughput_on_skewed_trace() {
        // the acceptance criterion: with a backlog of concurrent requests
        // on a skewed trace, a larger batch cap shares residency and
        // amortizes boundary weight reads → higher aggregate tokens/s.
        // The 1.05x floor at cap 4 is the PR-5 acceptance margin under
        // the calibrated reuse ratio (replay-verified: cap4/cap1 ≈ 1.075,
        // cap8/cap1 ≈ 1.103 on this trace). The default budget keeps evictions (and so
        // stalls) active without LRU thrash: past ~cap 6 at tighter
        // budgets the joint working set of the batch outgrows the cache
        // and throughput falls again — the expected capacity/concurrency
        // U-shape, visible by lowering --vram on exp-serve-load.
        let p = sweep_params(ResidencyKind::Lru, DEFAULT_VRAM_GB);
        let wl = workload_at(8.0, 12, 23);
        let tps1 = simulate_serving(&p, &wl, 1).unwrap().aggregate_tps();
        let tps4 = simulate_serving(&p, &wl, 4).unwrap().aggregate_tps();
        let tps8 = simulate_serving(&p, &wl, 8).unwrap().aggregate_tps();
        assert!(tps4 > tps1 * 1.05, "cap4 {tps4} vs cap1 {tps1}");
        assert!(tps8 > tps1 * 1.05, "cap8 {tps8} vs cap1 {tps1}");
    }

    #[test]
    fn calibrated_boundary_reuse_tracks_the_roofline() {
        // the repeat-row ratio prices FLOPs + activations + one launch
        // against the full weight-bound GEMV: memory-bound experts
        // amortize hard (dense fp16 repeats are nearly free), FloE's
        // compressed experts less so, and the ratio is a proper fraction
        let floe = SimParams::mixtral_on(
            RTX3090.clone(),
            SystemConfig::new(SystemKind::Floe),
            14.0,
        );
        let naive = SimParams::mixtral_on(
            RTX3090.clone(),
            SystemConfig::new(SystemKind::NaiveOffload),
            14.0,
        );
        let rf = boundary_compute_reuse(&floe);
        let rn = boundary_compute_reuse(&naive);
        assert!(rf > 0.02 && rf < 0.5, "floe reuse {rf}");
        assert!(rn > 0.0 && rn < rf, "dense reuse {rn} must amortize harder");
        // replay-pinned operating point: ~0.108 on the 3090 (the flat
        // 0.15 the sim used to hardcode both overpriced FloE repeats —
        // whose sparse kernel skips most gate/down FLOPs per row — and
        // was not derived from anything)
        assert!((rf - 0.108).abs() < 0.02, "floe/3090 reuse drifted: {rf}");
    }

    /// The scheduler-level sharing pin: at every token boundary the
    /// number of FULL-price expert GEMVs equals the number of *distinct*
    /// routed experts — never the number of routed (sequence, expert)
    /// pairs — and with batch > 1 on a skewed trace some pairs actually
    /// ride the amortized path.
    #[test]
    fn boundary_full_visits_equal_distinct_routed_experts() {
        let p = sweep_params(ResidencyKind::Lru, DEFAULT_VRAM_GB);
        let wl = workload_at(16.0, 8, 11);
        let max_ctx = wl
            .iter()
            .map(|t| t.req.prompt.len() + t.req.max_tokens)
            .max()
            .unwrap();
        let backend = SimServeBackend::new(p, 4 * max_ctx);
        let mut sched = Scheduler::new(backend, 4);
        for t in &wl {
            sched.enqueue_at(t.req.clone(), t.arrival_us);
        }
        let (mut saw_batch, mut saw_reuse) = (false, false);
        while sched.has_work() {
            let before = sched.backend().boundary_stats().clone();
            let batch = sched.active_len().max(1);
            let _ = sched.step();
            let bs = sched.backend().boundary_stats();
            let full_delta = bs.full_visits - before.full_visits;
            let pair_delta =
                full_delta + (bs.reused_visits - before.reused_visits);
            assert_eq!(
                full_delta,
                bs.distinct_this_boundary() as u64,
                "full-price visits must equal distinct routed experts"
            );
            assert!(full_delta <= pair_delta);
            if batch > 1 {
                saw_batch = true;
            }
            if pair_delta > full_delta {
                saw_reuse = true;
            }
        }
        assert!(saw_batch, "trace never batched");
        assert!(saw_reuse, "batched boundaries never shared an expert");
    }

    #[test]
    fn serving_stall_attribution_sums_exactly() {
        let p = sweep_params(ResidencyKind::Lru, 12.0);
        let wl = workload_at(6.0, 6, 5);
        let rep = simulate_serving(&p, &wl, 3).unwrap();
        // every stall is attributed to some request — no unattributed slop
        assert!(!rep
            .stats
            .attributed
            .contains_key(&crate::store::StoreStats::UNATTRIBUTED));
        // every completed request's ledger entry was retired on
        // completion, so the live ledger drained to empty...
        assert!(
            rep.stats.attributed.is_empty(),
            "finished requests left ledger entries: {:?}",
            rep.stats.attributed.keys().collect::<Vec<_>>()
        );
        // ...and the retired bucket plus the (empty) ledger reproduces
        // the globals bit-exactly
        let (mut demand, mut prefetch) =
            (rep.stats.retired.demand_us, rep.stats.retired.prefetch_us);
        for s in rep.stats.attributed.values() {
            demand += s.demand_us;
            prefetch += s.prefetch_us;
        }
        assert_eq!(demand, rep.stats.stall_demand_us);
        assert_eq!(prefetch, rep.stats.stall_prefetch_us);
        assert_eq!(rep.stats.stall_us, rep.stats.stall_demand_us + rep.stats.stall_prefetch_us);
        // per-completion splits, folded in retirement order, reproduce
        // the retired bucket bit-exactly (same op order as `retire`)
        let (mut demand, mut prefetch) = (0.0, 0.0);
        for c in &rep.completions {
            demand += c.stall.demand_us;
            prefetch += c.stall.prefetch_us;
        }
        assert_eq!(demand, rep.stats.retired.demand_us);
        assert_eq!(prefetch, rep.stats.retired.prefetch_us);
    }

    /// The ledger-leak regression pin: drive the scheduler through many
    /// short requests and assert at every token boundary that the live
    /// attribution ledger holds only in-flight requests (the bug was
    /// globally-unique server ids accumulating forever).
    #[test]
    fn attribution_ledger_is_bounded_by_inflight_batch() {
        let p = sweep_params(ResidencyKind::Lru, DEFAULT_VRAM_GB);
        let wl = workload_at(16.0, 24, 13);
        let max_batch = 3usize;
        let max_ctx = wl
            .iter()
            .map(|t| t.req.prompt.len() + t.req.max_tokens)
            .max()
            .unwrap();
        let backend = SimServeBackend::new(p, max_batch * max_ctx);
        let mut sched = Scheduler::new(backend, max_batch);
        let mut next = 0;
        let mut served = 0usize;
        loop {
            while next < wl.len() && wl[next].arrival_us <= sched.backend().now_us() {
                sched.enqueue_at(wl[next].req.clone(), wl[next].arrival_us);
                next += 1;
            }
            if !sched.has_work() {
                if next >= wl.len() {
                    break;
                }
                let t = wl[next].arrival_us;
                sched.backend_mut().idle_until(t);
                continue;
            }
            served += sched.step().len();
            let ledger = sched.backend().store().stats().attributed.len();
            assert!(
                ledger <= sched.active_len(),
                "ledger {} entries > {} in flight after {} served",
                ledger,
                sched.active_len(),
                served
            );
        }
        assert_eq!(served, wl.len());
        assert!(sched.backend().store().stats().attributed.is_empty());
    }

    // ------------------------------------------ event core & overlap

    fn assert_matches_reference(p: &SimParams, io: (usize, usize), ctx: &str) {
        let new = simulate(p, io.0, io.1);
        let old = simulate_busyuntil_reference(p, io.0, io.1);
        assert_eq!(new.tps.to_bits(), old.tps.to_bits(), "tps diverged: {ctx}");
        assert_eq!(
            new.total_us.to_bits(),
            old.total_us.to_bits(),
            "total_us diverged: {ctx}"
        );
        assert_eq!(
            new.compute_us.to_bits(),
            old.compute_us.to_bits(),
            "compute_us diverged: {ctx}"
        );
        assert_eq!(
            new.stall_us.to_bits(),
            old.stall_us.to_bits(),
            "stall_us diverged: {ctx}"
        );
        assert_eq!(
            new.transferred_bytes.to_bits(),
            old.transferred_bytes.to_bits(),
            "transferred_bytes diverged: {ctx}"
        );
        assert_eq!(
            new.bus_transactions, old.bus_transactions,
            "bus_transactions diverged: {ctx}"
        );
        assert_eq!(
            new.cache_hit_rate.to_bits(),
            old.cache_hit_rate.to_bits(),
            "cache_hit_rate diverged: {ctx}"
        );
    }

    /// The event-core acceptance pin (single-device corners; the
    /// devices × shard-policy corners live in tests/shard_store.rs):
    /// with overlap off, routing all time progression through the event
    /// heap changes no observable number vs the frozen busy-until
    /// reference — every SimReport f64 compared via `to_bits`.
    #[test]
    fn event_core_matches_busyuntil_reference_bit_exactly() {
        for kind in SystemKind::ALL {
            for vram in [12.0, 14.0, 24.0] {
                let p = SimParams::mixtral_on(
                    RTX3090.clone(),
                    SystemConfig::with_residency(kind, ResidencyKind::Lru),
                    vram,
                );
                assert_matches_reference(
                    &p,
                    (64, 128),
                    &format!("{} @ {vram} GB", kind.name()),
                );
            }
        }
    }

    /// Same seed + config ⇒ byte-identical popped-event log (17 bytes
    /// per event: kind tag, time bits, payload id), with overlap off and
    /// on.
    #[test]
    fn event_log_is_deterministic_and_well_formed() {
        let mut p = SimParams::mixtral_on(
            RTX3090.clone(),
            SystemConfig::with_residency(SystemKind::Floe, ResidencyKind::Lru),
            14.0,
        );
        let (ra, la) = simulate_traced(&p, 64, 64);
        let (rb, lb) = simulate_traced(&p, 64, 64);
        assert!(!la.is_empty() && la.len() % 17 == 0, "malformed log: {} bytes", la.len());
        assert_eq!(la, lb, "same seed+config must replay a byte-identical event log");
        assert_eq!(ra.tps.to_bits(), rb.tps.to_bits());
        p.system.overlap = true;
        let (oa, loa) = simulate_traced(&p, 64, 64);
        let (ob, lob) = simulate_traced(&p, 64, 64);
        assert!(!loa.is_empty() && loa.len() % 17 == 0);
        assert_eq!(loa, lob, "overlap event log diverged between identical runs");
        assert_eq!(oa.tps.to_bits(), ob.tps.to_bits());
    }

    /// Drive a traced serving backend through the scheduler exactly like
    /// `simulate_serving` and return the popped-event log + store stats.
    fn traced_serving(
        p: &SimParams,
        wl: &[TimedRequest],
        cap: usize,
    ) -> (Vec<u8>, StoreStats) {
        let max_ctx = wl
            .iter()
            .map(|t| t.req.prompt.len() + t.req.max_tokens)
            .max()
            .unwrap();
        let backend = SimServeBackend::new_traced(p.clone(), cap.max(1) * max_ctx);
        let mut sched = Scheduler::new(backend, cap);
        let mut next = 0;
        loop {
            while next < wl.len() && wl[next].arrival_us <= sched.backend().now_us() {
                sched.enqueue_at(wl[next].req.clone(), wl[next].arrival_us);
                next += 1;
            }
            if !sched.has_work() {
                if next >= wl.len() {
                    break;
                }
                let t = wl[next].arrival_us;
                sched.backend_mut().idle_until(t);
                continue;
            }
            let _ = sched.step();
        }
        let backend = sched.into_backend();
        (backend.event_log().to_vec(), backend.store().stats().clone())
    }

    /// Serving determinism: same seed + config ⇒ byte-identical event
    /// log and identical StoreStats — including under `--overlap` and
    /// `--compute-streams`.
    #[test]
    fn serving_event_log_is_deterministic() {
        let wl = workload_at(8.0, 8, 23);
        for overlap in [false, true] {
            let mut p = sweep_params(ResidencyKind::Lru, DEFAULT_VRAM_GB);
            p.system.overlap = overlap;
            let (la, sa) = traced_serving(&p, &wl, 4);
            let (lb, sb) = traced_serving(&p, &wl, 4);
            assert!(!la.is_empty() && la.len() % 17 == 0);
            assert_eq!(la, lb, "serving event log diverged (overlap {overlap})");
            assert_eq!(sa.stall_us.to_bits(), sb.stall_us.to_bits());
            assert_eq!(sa.stall_demand_us.to_bits(), sb.stall_demand_us.to_bits());
            assert_eq!(sa.stall_prefetch_us.to_bits(), sb.stall_prefetch_us.to_bits());
            assert_eq!(sa.transferred_bytes.to_bits(), sb.transferred_bytes.to_bits());
            assert_eq!(sa.bus_transactions, sb.bus_transactions);
            assert_eq!(sa.demand_fetches, sb.demand_fetches);
            assert_eq!(sa.prefetches, sb.prefetches);
        }
        for overlap in [false, true] {
            use crate::config::ShardPolicy;
            let mut p = sweep_params(ResidencyKind::Lru, DEFAULT_VRAM_GB);
            p.system = p.system.clone().with_devices(2, ShardPolicy::Balanced);
            p.system.compute_streams = true;
            p.system.overlap = overlap;
            let (la, sa) = traced_serving(&p, &wl, 3);
            let (lb, sb) = traced_serving(&p, &wl, 3);
            assert_eq!(la, lb, "streams event log diverged (overlap {overlap})");
            assert_eq!(sa.stall_us.to_bits(), sb.stall_us.to_bits());
            assert_eq!(sa.transferred_bytes.to_bits(), sb.transferred_bytes.to_bits());
        }
    }

    /// Satellite (event-timed admission): enqueueing the whole trace up
    /// front and letting `Scheduler::step` observe arrivals itself —
    /// idling the event heap to the queue head when the batch drains —
    /// reproduces the old lazy per-boundary enqueue drive bit-exactly:
    /// same popped-event log (`RequestArrival` pops at the same stamps),
    /// same store stats.
    #[test]
    fn upfront_enqueue_matches_lazy_drive_bit_exactly() {
        // 4 Hz over 10 requests drains the batch between arrivals, so
        // the empty-batch idle path is actually exercised
        let wl = workload_at(4.0, 10, 23);
        for overlap in [false, true] {
            let mut p = sweep_params(ResidencyKind::Lru, DEFAULT_VRAM_GB);
            p.system.overlap = overlap;
            let (lazy_log, lazy_stats) = traced_serving(&p, &wl, 3);
            let max_ctx = wl
                .iter()
                .map(|t| t.req.prompt.len() + t.req.max_tokens)
                .max()
                .unwrap();
            let backend = SimServeBackend::new_traced(p.clone(), 3 * max_ctx);
            let mut sched = Scheduler::new(backend, 3);
            for t in &wl {
                sched.enqueue_at(t.req.clone(), t.arrival_us);
            }
            let done = sched.drain();
            assert_eq!(done.len(), wl.len());
            let backend = sched.into_backend();
            assert_eq!(
                backend.event_log(),
                &lazy_log[..],
                "event logs diverged (overlap {overlap})"
            );
            let s = backend.store().stats();
            assert_eq!(s.stall_us.to_bits(), lazy_stats.stall_us.to_bits());
            assert_eq!(
                s.transferred_bytes.to_bits(),
                lazy_stats.transferred_bytes.to_bits()
            );
            assert_eq!(s.bus_transactions, lazy_stats.bus_transactions);
            assert_eq!(s.demand_fetches, lazy_stats.demand_fetches);
            assert_eq!(s.prefetches, lazy_stats.prefetches);
        }
    }

    /// Cluster tier: re-timing the intra-store links as a spanning
    /// 2-node topology changes WHEN bytes move (cross-node pulls ride
    /// the network link) but never WHAT moves — transferred bytes and
    /// bus transactions stay bit-identical to the single-node run with
    /// the same devices, across shard policies, and the slower link can
    /// only cost throughput.
    #[test]
    fn spanning_cluster_moves_bit_identical_bytes() {
        use crate::config::ShardPolicy;
        for shard in [ShardPolicy::Layer, ShardPolicy::Expert, ShardPolicy::Hash] {
            let flat_p = SimParams::mixtral_on(
                RTX3090.clone(),
                SystemConfig::with_residency(SystemKind::Floe, ResidencyKind::Lru)
                    .with_devices(4, shard),
                12.0,
            );
            let mut span_p = flat_p.clone();
            span_p.system = span_p.system.with_cluster_span(2);
            let flat = simulate(&flat_p, 64, 128);
            let span = simulate(&span_p, 64, 128);
            assert_eq!(
                span.transferred_bytes.to_bits(),
                flat.transferred_bytes.to_bits(),
                "{shard:?}: span re-timing changed what moves"
            );
            assert_eq!(
                span.bus_transactions, flat.bus_transactions,
                "{shard:?}: span re-timing changed transaction count"
            );
            assert!(
                span.tps <= flat.tps * (1.0 + 1e-12),
                "{shard:?}: the slower cross-node link cannot raise tps \
                 ({} vs {})",
                span.tps,
                flat.tps
            );
            assert!(span.tps.is_finite() && span.tps > 0.0);
        }
    }

    /// Member-form backends stage the roster into their host pool at
    /// build time, own expert-mod shard first, until host RAM fills —
    /// so demand fetches price PCIe while a host copy exists and the
    /// network link once the pool diverges.
    #[test]
    fn member_backend_seeds_host_pool_own_shard_first() {
        // ~1 GB of host pool holds a fraction of one node's 128-key
        // shard at FloE's ~27 MB compressed experts
        let p = SimParams::mixtral_on(
            RTX3090.clone(),
            SystemConfig::with_residency(SystemKind::Floe, ResidencyKind::Lru)
                .as_cluster_member(1, 2, 1.0),
            14.0,
        );
        let backend = SimServeBackend::new(p.clone(), 512);
        let store = backend.store();
        assert!(store.host_bytes_of(0) > 0, "host pool never seeded");
        assert!(
            store.host_bytes_of(0) <= store.host_budget(),
            "host pool overran its budget"
        );
        // node 1's own shard (odd experts) is staged first
        assert!(store.host_resident(0, (0, 1)));
        assert!(
            !store.host_resident(0, (0, 0)),
            "foreign-shard key staged before the pool filled with own-shard keys"
        );
        // a roomy pool holds the full roster, foreign shard included
        let roomy = SimParams::mixtral_on(
            RTX3090.clone(),
            SystemConfig::with_residency(SystemKind::Floe, ResidencyKind::Lru)
                .as_cluster_member(1, 2, 64.0),
            14.0,
        );
        let backend = SimServeBackend::new(roomy, 512);
        assert!(backend.store().host_resident(0, (0, 0)));
        assert!(backend.store().host_resident(0, (0, 1)));
    }

    /// The overlap acceptance at the exp-serve-load operating point:
    /// mid-boundary GEMV release (batch-level `sim_decode_boundary` with
    /// the priority demand lane) lifts tokens/s ≥ 1.03x at cap 4 and the
    /// replay-verified demand-fetch stall share strictly decreases —
    /// here and at caps 1 and 8 (replay: 1.0095x / 1.0927x / 1.1259x,
    /// shares 0.0251→0.0135 / 0.0382→0.0089 / 0.0438→0.0098).
    #[test]
    fn overlap_improves_serving_throughput_at_the_operating_point() {
        let wl = workload_at(8.0, 12, 23);
        let base_p = sweep_params(ResidencyKind::Lru, DEFAULT_VRAM_GB);
        let mut ov_p = base_p.clone();
        ov_p.system.overlap = true;
        let share = |r: &ServeSimReport| r.stats.stall_demand_us / r.total_us;
        let base = simulate_serving(&base_p, &wl, 4).unwrap();
        let ov = simulate_serving(&ov_p, &wl, 4).unwrap();
        let ratio = ov.aggregate_tps() / base.aggregate_tps();
        assert!(
            ratio >= 1.03,
            "overlap speedup {ratio:.4} below the 1.03 floor at cap 4"
        );
        assert!(
            share(&ov) < share(&base),
            "demand-stall share must strictly decrease: {:.4} -> {:.4}",
            share(&base),
            share(&ov)
        );
        for cap in [1usize, 8] {
            let b = simulate_serving(&base_p, &wl, cap).unwrap();
            let o = simulate_serving(&ov_p, &wl, cap).unwrap();
            assert!(
                o.aggregate_tps() > b.aggregate_tps(),
                "cap {cap}: overlap tps {} not above {}",
                o.aggregate_tps(),
                b.aggregate_tps()
            );
            assert!(
                share(&o) < share(&b),
                "cap {cap}: demand-stall share must strictly decrease"
            );
        }
    }

    /// Single-request overlap: demand fetches resolved before attention
    /// stream under compute, so total stall drops and tokens/s improves
    /// (replay: 1.1673x at this corner) — while moving byte-identical
    /// traffic in the same number of bus transactions.
    #[test]
    fn overlap_hides_demand_fetches_single_shot() {
        let mut p = SimParams::mixtral_on(
            RTX3090.clone(),
            SystemConfig::with_residency(SystemKind::Floe, ResidencyKind::Lru),
            11.0,
        );
        p.routing = RoutingModel { zipf_s: 1.2, stickiness: 0.5, seed: 7 };
        let base = simulate(&p, 64, 256);
        p.system.overlap = true;
        let ov = simulate(&p, 64, 256);
        let ratio = ov.tps / base.tps;
        assert!(ratio >= 1.10, "single-shot overlap {ratio:.4} below 1.10");
        assert!(
            ov.stall_us < base.stall_us,
            "overlap must reduce total stall: {} -> {}",
            base.stall_us,
            ov.stall_us
        );
        assert_eq!(
            ov.transferred_bytes.to_bits(),
            base.transferred_bytes.to_bits(),
            "overlap re-times transfers, it must not change what moves"
        );
        assert_eq!(ov.bus_transactions, base.bus_transactions);
    }

    #[test]
    fn balanced_popularity_simulation_is_deterministic() {
        use crate::config::ShardPolicy;
        let mut p = SimParams::mixtral_on(
            RTX3090.clone(),
            SystemConfig::new(SystemKind::Floe)
                .with_devices(2, ShardPolicy::Balanced)
                .with_replication(2),
            11.0,
        );
        p.routing = RoutingModel { zipf_s: 1.2, stickiness: 0.5, seed: 7 };
        let a = simulate(&p, 64, 256);
        let b = simulate(&p, 64, 256);
        assert_eq!(a.tps.to_bits(), b.tps.to_bits());
        assert_eq!(a.transferred_bytes.to_bits(), b.transferred_bytes.to_bits());
        assert_eq!(a.bus_transactions, b.bus_transactions);
        assert_eq!(a.stall_us.to_bits(), b.stall_us.to_bits());
        assert!(a.tps.is_finite() && a.tps > 0.0);
    }
}

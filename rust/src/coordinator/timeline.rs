//! Deterministic serving record/replay + per-request inspector
//! (DESIGN.md §9).
//!
//! A serving session on the simulator backend is a pure function of its
//! [`SessionSpec`]: hardware preset, `SystemConfig`, routing model,
//! scheduler cap and workload. The **recorder** ([`record`]) drives the
//! exact `simulate_serving` loop through a transparent
//! [`RecordingBackend`] wrapper and captures everything the session
//! produced — scheduler-level arrival/admission/retirement entries, the
//! event core's 17-byte-per-pop log, per-request completion accounting
//! and the final `StoreStats` — as a versioned, byte-serializable
//! [`Timeline`] artifact. The **replayer** ([`replay`]) re-runs the spec
//! from nothing and asserts bit-exact reproduction (`f64::to_bits` on
//! every float, byte-identical event logs); any divergence reports the
//! first mismatching entry with both causal histories. The **inspector**
//! ([`inspect`]) re-derives per-request queue-wait percentiles, the
//! stall-cause split, batch occupancy and per-device bus busy share from
//! the recorded timeline, and checks that the per-request ledger sums
//! reproduce the store's global counters bit-exactly.
//!
//! Per-boundary routing and `TransferPlan` issue are deliberately *not*
//! stored: both are pure functions of the spec (seeded per-sequence RNGs,
//! deterministic cache state), and their effects are cross-checked
//! through the `GemvComplete`/`TransferComplete` pops in the
//! byte-compared event log. See DESIGN.md §9 for the byte schema and the
//! determinism contract.
//!
//! Cluster sessions (DESIGN.md §10) extend the same artifact: a
//! [`ClusterExt`] section — gated by `FLAG_CLUSTER`, appended after the
//! single-node sections so pre-cluster artifacts stay byte-identical —
//! records the cluster shape (nodes × devices, placement, aggregate
//! VRAM, the failure scenario) and per-node observations (each node's
//! event log, admissions, completions and store stats, plus the
//! router's request→node assignments). [`record_cluster`] drives
//! `simulate_cluster_traced`; [`replay_cluster`] re-runs it from the
//! spec and asserts bit-exact reproduction node by node.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::Result;

use crate::config::{ResidencyKind, ShardPolicy};
use crate::hwsim::RTX3090;
use crate::store::{
    DegradeCount, DeviceStats, FaultCause, LinkId, RetryPolicy, StallSplit, StoreStats,
};
use crate::util::json::Json;
use crate::workload::{self, TimedRequest, WorkloadSpec};

use super::cluster::{
    simulate_cluster_traced, ClusterPlacement, ClusterReport, ClusterSpec, Fault,
    NodeFailure, NodeObs,
};
use super::policy::{SystemConfig, SystemKind};
use super::sched::{BackendSnapshot, Scheduler, SeqBackend, SeqStep, ServeCompletion};
use super::serve::Request;
use super::sim::{RoutingModel, SimParams, SimServeBackend};

/// Artifact magic bytes.
pub const MAGIC: [u8; 4] = *b"FLTL";
/// Current artifact format version.
pub const VERSION: u32 = 1;

const FLAG_OBSERVATIONS: u32 = 1 << 0;
const FLAG_REPLAYABLE: u32 = 1 << 1;
/// The artifact carries a cluster section (shape + per-node
/// observations) appended after the single-node sections.
const FLAG_CLUSTER: u32 = 1 << 2;
/// The artifact carries a quality-elastic section (the little-tier
/// carve fraction + per-request SLO budgets, DESIGN.md §11) appended
/// after every other section. Only set when the spec actually uses the
/// fallback, so pre-quality artifacts stay byte-identical.
const FLAG_QUALITY: u32 = 1 << 3;
/// The artifact carries a fault-schedule section (DESIGN.md §12):
/// the cluster's timed `Fault` list, the retry/backoff policy and the
/// fault-recovery counters, appended after every other section. Only
/// set when the shape actually schedules faults or arms retries, so
/// fault-free artifacts — the committed corpus included — stay
/// byte-identical.
const FLAG_FAULTS: u32 = 1 << 4;
/// Every flag bit this build understands. `from_bytes` rejects unknown
/// bits outright: an unknown bit means an appended section this decoder
/// would misparse as trailing garbage (or worse, silently drop), so
/// failing loudly is the forward-compatibility contract.
const KNOWN_FLAGS: u32 =
    FLAG_OBSERVATIONS | FLAG_REPLAYABLE | FLAG_CLUSTER | FLAG_QUALITY | FLAG_FAULTS;

/// Hardware preset a spec's `SimParams` are rebuilt from. Only the
/// RTX 3090 host model is recordable today — the preset every serving
/// experiment and the server's sim backend use — but the tag keeps the
/// byte format extensible without a version bump.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HwPreset {
    Rtx3090,
}

/// Where the arrival trace comes from.
#[derive(Clone, Debug)]
pub enum WorkloadSource {
    /// Compact seeded form: the replayer re-expands it through
    /// `workload::generate`, so the artifact stores only exactly
    /// representable constants (committed corpus artifacts use this —
    /// no cross-language float generation).
    Spec(WorkloadSpec),
    /// Fully expanded arrival trace (live server recordings, where
    /// arrivals came off the wire rather than from a generator).
    Trace(Vec<TimedRequest>),
}

impl WorkloadSource {
    /// Expand to the concrete arrival trace.
    pub fn trace(&self) -> Vec<TimedRequest> {
        match self {
            WorkloadSource::Spec(spec) => workload::generate(spec),
            WorkloadSource::Trace(t) => t.clone(),
        }
    }
}

/// Everything needed to re-create a serving session from nothing.
#[derive(Clone, Debug)]
pub struct SessionSpec {
    pub hw: HwPreset,
    pub system: SystemConfig,
    pub vram_gb: f64,
    pub routing: RoutingModel,
    pub inter_hit: f64,
    pub intra_recall: f64,
    pub adv_prefetch_hit: f64,
    pub max_batch: usize,
    pub workload: WorkloadSource,
}

impl SessionSpec {
    /// Capture the recordable knobs of `p`. The GPU is assumed to be the
    /// RTX 3090 host model (`SimParams::mixtral_on`) — the only preset
    /// the serving paths use; custom `GpuSpec`s are not captured.
    pub fn from_params(p: &SimParams, max_batch: usize, workload: WorkloadSource) -> Self {
        SessionSpec {
            hw: HwPreset::Rtx3090,
            system: p.system.clone(),
            vram_gb: p.vram_gb,
            routing: p.routing.clone(),
            inter_hit: p.inter_hit,
            intra_recall: p.intra_recall,
            adv_prefetch_hit: p.adv_prefetch_hit,
            max_batch,
            workload,
        }
    }

    /// Reconstruct the simulator parameters bit-exactly.
    pub fn params(&self) -> SimParams {
        let HwPreset::Rtx3090 = self.hw;
        let mut p = SimParams::mixtral_on(RTX3090.clone(), self.system.clone(), self.vram_gb);
        p.routing = self.routing.clone();
        p.inter_hit = self.inter_hit;
        p.intra_recall = self.intra_recall;
        p.adv_prefetch_hit = self.adv_prefetch_hit;
        p
    }

    /// The concrete arrival trace this spec drives.
    pub fn trace(&self) -> Vec<TimedRequest> {
        self.workload.trace()
    }
}

/// Scheduler-level decision kinds in the recorded timeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EntryKind {
    /// A request entered the admission queue (`t_us` = arrival time in
    /// the backend time base, `ord` = arrival index).
    Arrival,
    /// The scheduler admitted a request into the decode batch (`t_us` =
    /// backend clock when prefill started, `ord` = admission index).
    Admit,
    /// The request retired (`t_us` = backend clock at retirement,
    /// `ord` = retirement index).
    Retire,
}

impl EntryKind {
    pub fn name(self) -> &'static str {
        match self {
            EntryKind::Arrival => "Arrival",
            EntryKind::Admit => "Admit",
            EntryKind::Retire => "Retire",
        }
    }

    fn code(self) -> u8 {
        match self {
            EntryKind::Arrival => 0,
            EntryKind::Admit => 1,
            EntryKind::Retire => 2,
        }
    }

    fn from_code(c: u8) -> Result<Self, String> {
        match c {
            0 => Ok(EntryKind::Arrival),
            1 => Ok(EntryKind::Admit),
            2 => Ok(EntryKind::Retire),
            _ => Err(format!("bad timeline entry kind {c}")),
        }
    }
}

/// One scheduler-level decision on the recorded timeline.
#[derive(Clone, Copy, Debug)]
pub struct TimelineEntry {
    pub kind: EntryKind,
    pub t_us: f64,
    pub id: u64,
    pub ord: u64,
}

impl TimelineEntry {
    fn render(&self) -> String {
        format!("{} #{} t={}us id={}", self.kind.name(), self.ord, self.t_us, self.id)
    }

    fn bits(&self) -> (u8, u64, u64, u64) {
        (self.kind.code(), self.t_us.to_bits(), self.id, self.ord)
    }
}

/// The numeric accounting of one `ServeCompletion`. Sampled text is
/// omitted: the sim backend emits none, and byte-identical text on the
/// real backend is already covered by the engine bit-exactness tests.
#[derive(Clone, Debug, Default)]
pub struct CompletionRecord {
    pub id: u64,
    pub tokens: u64,
    pub batch_peak: u64,
    pub arrival_us: f64,
    pub queue_wait_us: f64,
    pub prefill_us: f64,
    pub decode_us: f64,
    pub stall: StallSplit,
    pub finished_us: f64,
    /// quality-elastic boundaries this request resolved degraded
    /// (zero everywhere with the fallback off)
    pub degraded: DegradeCount,
}

impl CompletionRecord {
    pub fn of(c: &ServeCompletion) -> Self {
        CompletionRecord {
            id: c.id,
            tokens: c.tokens as u64,
            batch_peak: c.batch_peak as u64,
            arrival_us: c.arrival_us,
            queue_wait_us: c.queue_wait_us,
            prefill_us: c.prefill_us,
            decode_us: c.decode_us,
            stall: c.stall,
            finished_us: c.finished_us,
            degraded: c.degraded,
        }
    }

    fn render(&self) -> String {
        format!(
            "id={} tokens={} wait={}us stall=({},{})us degraded={} finished={}us",
            self.id,
            self.tokens,
            self.queue_wait_us,
            self.stall.demand_us,
            self.stall.prefetch_us,
            self.degraded.hits,
            self.finished_us
        )
    }

    fn bits(&self) -> [u64; 12] {
        [
            self.id,
            self.tokens,
            self.batch_peak,
            self.arrival_us.to_bits(),
            self.queue_wait_us.to_bits(),
            self.prefill_us.to_bits(),
            self.decode_us.to_bits(),
            self.stall.demand_us.to_bits(),
            self.stall.prefetch_us.to_bits(),
            self.finished_us.to_bits(),
            self.degraded.hits,
            self.degraded.bytes.to_bits(),
        ]
    }
}

/// Final `StoreStats` snapshot: globals, the retired stall bucket and
/// per-device movement sums. The live attribution ledger is not stored —
/// a quiescent session has drained it into `retired`.
#[derive(Clone, Debug, Default)]
pub struct StatsRecord {
    pub demand_fetches: u64,
    pub prefetches: u64,
    pub bus_transactions: u64,
    pub transferred_bytes: f64,
    pub bus_busy_us: f64,
    pub stall_us: f64,
    pub stall_demand_us: f64,
    pub stall_prefetch_us: f64,
    pub retired: StallSplit,
    /// global quality-elastic counters + the retired bucket of the
    /// degraded ledger (all zero with the fallback off)
    pub degraded_hits: u64,
    pub degraded_bytes: f64,
    pub retired_degraded: DegradeCount,
    /// bounded-backoff transfer retries (DESIGN.md §12): the global
    /// counter and the retired bucket of the retry ledger (equal at
    /// quiescence; both zero for every retry-free session)
    pub retries: u64,
    pub retired_retries: u64,
    pub per_device: Vec<DeviceStats>,
}

impl StatsRecord {
    pub fn of(s: &StoreStats) -> Self {
        StatsRecord {
            demand_fetches: s.demand_fetches,
            prefetches: s.prefetches,
            bus_transactions: s.bus_transactions,
            transferred_bytes: s.transferred_bytes,
            bus_busy_us: s.bus_busy_us,
            stall_us: s.stall_us,
            stall_demand_us: s.stall_demand_us,
            stall_prefetch_us: s.stall_prefetch_us,
            retired: s.retired,
            degraded_hits: s.degraded_hits,
            degraded_bytes: s.degraded_bytes,
            retired_degraded: s.retired_degraded,
            retries: s.retries,
            retired_retries: s.retired_retries,
            per_device: s.per_device.clone(),
        }
    }
}

/// Everything a recorded session *produced*, as opposed to what defines
/// it (the spec).
#[derive(Clone, Debug)]
pub struct Observations {
    pub entries: Vec<TimelineEntry>,
    /// the event core's 17-byte-per-pop log (`EventCore::log_bytes`)
    pub event_log: Vec<u8>,
    /// per-request accounting, in retirement order
    pub completions: Vec<CompletionRecord>,
    pub stats: StatsRecord,
    pub total_us: f64,
    pub max_batch_seen: u64,
    pub cache_hit_rate: f64,
}

/// The cluster shape a [`ClusterExt`] artifact re-derives per-node
/// configurations from: everything `simulate_cluster` needs beyond the
/// base session spec (whose `max_batch` doubles as the per-node cap and
/// whose `system`/`routing` seed every node's parameters).
#[derive(Clone, Debug)]
pub struct ClusterShape {
    pub n_nodes: usize,
    pub devices_per_node: usize,
    /// intra-node expert→device assignment (multi-device nodes).
    pub shard: ShardPolicy,
    pub placement: ClusterPlacement,
    /// aggregate expert-cache VRAM across the whole cluster, GB.
    pub vram_gb_total: f64,
    /// per-node host RAM pool, GB.
    pub host_ram_gb: f64,
    pub failure: Option<NodeFailure>,
    /// deterministic fault schedule (DESIGN.md §12), carried in the
    /// appended `FLAG_FAULTS` section so fault-free artifacts keep
    /// their pre-fault bytes.
    pub faults: Vec<Fault>,
    /// bounded-backoff retry policy for outage-blocked demand fetches.
    pub retry: Option<RetryPolicy>,
}

impl ClusterShape {
    /// The concrete `ClusterSpec` this shape drives (per-node batching
    /// cap comes from the base session spec).
    pub fn cluster_spec(&self, max_batch: usize) -> ClusterSpec {
        ClusterSpec {
            n_nodes: self.n_nodes,
            devices_per_node: self.devices_per_node,
            shard: self.shard,
            placement: self.placement,
            vram_gb_total: self.vram_gb_total,
            host_ram_gb: self.host_ram_gb,
            max_batch,
            failure: self.failure,
            faults: self.faults.clone(),
            retry: self.retry,
        }
    }
}

/// One node's recorded observations in a cluster artifact — the
/// cluster-tier analogue of [`Observations`], with the scheduler channel
/// reduced to the admission order (per-node arrival stamps live in the
/// router's assignment list) and the cross-node traffic counters added.
#[derive(Clone, Debug)]
pub struct NodeRecord {
    pub admitted_order: Vec<u64>,
    pub event_log: Vec<u8>,
    pub completions: Vec<CompletionRecord>,
    pub stats: StatsRecord,
    pub cache_hit_rate: f64,
    pub total_us: f64,
    pub max_batch_seen: u64,
    pub net_pulls: u64,
    pub net_bytes: f64,
    pub alive: bool,
}

impl NodeRecord {
    pub fn of(n: &NodeObs) -> Self {
        NodeRecord {
            admitted_order: n.admitted_order.clone(),
            event_log: n.event_log.clone(),
            completions: n.completions.iter().map(CompletionRecord::of).collect(),
            stats: StatsRecord::of(&n.stats),
            cache_hit_rate: n.cache_hit_rate,
            total_us: n.total_us,
            max_batch_seen: n.max_batch_seen as u64,
            net_pulls: n.net_pulls,
            net_bytes: n.net_bytes,
            alive: n.alive,
        }
    }
}

/// Everything a recorded cluster session produced.
#[derive(Clone, Debug)]
pub struct ClusterObservations {
    /// request id → node, in routing order (re-routed requests record
    /// their final survivor node).
    pub assignments: Vec<(u64, u32)>,
    pub nodes: Vec<NodeRecord>,
    pub total_us: f64,
    pub errored: u64,
    pub rehomed_keys: u64,
    /// fault-recovery counters (DESIGN.md §12), serialized in the
    /// appended `FLAG_FAULTS` section; all zero for fault-free runs.
    pub redispatched: u64,
    pub rejoins: u64,
    pub dev_moved_keys: u64,
    pub dev_dropped_keys: u64,
}

impl ClusterObservations {
    pub fn of(r: &ClusterReport) -> Self {
        ClusterObservations {
            assignments: r.assignments.iter().map(|&(id, n)| (id, n as u32)).collect(),
            nodes: r.nodes.iter().map(NodeRecord::of).collect(),
            total_us: r.total_us,
            errored: r.errored as u64,
            rehomed_keys: r.rehomed_keys as u64,
            redispatched: r.redispatched as u64,
            rejoins: r.rejoins as u64,
            dev_moved_keys: r.dev_moved_keys as u64,
            dev_dropped_keys: r.dev_dropped_keys as u64,
        }
    }
}

/// The cluster section of an artifact (`FLAG_CLUSTER`).
#[derive(Clone, Debug)]
pub struct ClusterExt {
    pub shape: ClusterShape,
    pub obs: Option<ClusterObservations>,
}

/// A serving session as a byte-serializable artifact.
#[derive(Clone, Debug)]
pub struct Timeline {
    pub spec: SessionSpec,
    pub obs: Option<Observations>,
    /// cluster sessions append their shape and per-node observations
    /// here; `None` for single-node artifacts (whose bytes are unchanged
    /// by the cluster extension).
    pub cluster: Option<ClusterExt>,
    /// true when the session is a pure function of the spec (recorded by
    /// the deterministic driver): the replayer asserts bit-exact
    /// reproduction. Live server recordings are *not* replayable —
    /// wall-clock arrival interleaving is outside the spec — but still
    /// carry a full observation section for offline inspection.
    pub replayable: bool,
}

// ---------------------------------------------------------------------------
// byte serialization (schema: DESIGN.md §9)

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new() -> Self {
        Enc { buf: Vec::new() }
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn bytes(&mut self, b: &[u8]) {
        self.u64(b.len() as u64);
        self.buf.extend_from_slice(b);
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.buf.len() - self.at < n {
            return Err(format!("timeline truncated at byte {} (need {n} more)", self.at));
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn bytes(&mut self) -> Result<Vec<u8>, String> {
        let n = self.u64()? as usize;
        Ok(self.take(n)?.to_vec())
    }
    fn done(&self) -> Result<(), String> {
        if self.at == self.buf.len() {
            Ok(())
        } else {
            Err(format!("{} trailing bytes after timeline", self.buf.len() - self.at))
        }
    }
}

fn enum_code<T: PartialEq + Copy>(all: &[T], v: T) -> u8 {
    all.iter().position(|x| *x == v).expect("enum variant missing from ALL") as u8
}

fn enum_at<T: Copy>(all: &[T], code: u8, what: &str) -> Result<T, String> {
    all.get(code as usize).copied().ok_or_else(|| format!("bad {what} code {code}"))
}

fn put_spec(e: &mut Enc, s: &SessionSpec) {
    e.u8(match s.hw {
        HwPreset::Rtx3090 => 0,
    });
    let sys = &s.system;
    e.u8(enum_code(&SystemKind::ALL, sys.kind));
    e.f64(sys.sparsity);
    e.u8(sys.quant_bits);
    e.f64(sys.intra_margin);
    e.u64(sys.chunk_channels as u64);
    e.u8(enum_code(&ResidencyKind::ALL, sys.residency));
    e.f64(sys.sparsity_decay);
    e.u64(sys.devices as u64);
    e.u8(enum_code(&ShardPolicy::ALL, sys.shard));
    e.u8(sys.coalesce as u8);
    e.u8(sys.spill as u8);
    e.u64(sys.replicate_top as u64);
    e.u8(sys.compute_streams as u8);
    e.u8(sys.overlap as u8);
    e.u8(sys.hetero_fleet as u8);
    e.f64(s.vram_gb);
    e.f64(s.routing.zipf_s);
    e.f64(s.routing.stickiness);
    e.u64(s.routing.seed);
    e.f64(s.inter_hit);
    e.f64(s.intra_recall);
    e.f64(s.adv_prefetch_hit);
    e.u64(s.max_batch as u64);
    match &s.workload {
        WorkloadSource::Spec(w) => {
            e.u8(0);
            e.u64(w.n_requests as u64);
            e.f64(w.arrival_rate_hz);
            e.u64(w.prompt_len.0 as u64);
            e.u64(w.prompt_len.1 as u64);
            e.u64(w.output_tokens.0 as u64);
            e.u64(w.output_tokens.1 as u64);
            e.u64(w.seed);
        }
        WorkloadSource::Trace(trace) => {
            e.u8(1);
            e.u64(trace.len() as u64);
            for t in trace {
                e.f64(t.arrival_us);
                e.u64(t.req.id);
                e.u64(t.req.max_tokens as u64);
                e.u32(t.req.temperature.to_bits());
                e.u64(t.req.seed);
                e.bytes(&t.req.prompt);
            }
        }
    }
}

fn get_spec(d: &mut Dec) -> Result<SessionSpec, String> {
    let hw = match d.u8()? {
        0 => HwPreset::Rtx3090,
        c => return Err(format!("bad hardware preset code {c}")),
    };
    let kind = enum_at(&SystemKind::ALL, d.u8()?, "system kind")?;
    let sparsity = d.f64()?;
    let quant_bits = d.u8()?;
    let intra_margin = d.f64()?;
    let chunk_channels = d.u64()? as usize;
    let residency = enum_at(&ResidencyKind::ALL, d.u8()?, "residency")?;
    let sparsity_decay = d.f64()?;
    let devices = d.u64()? as usize;
    let shard = enum_at(&ShardPolicy::ALL, d.u8()?, "shard policy")?;
    let coalesce = d.u8()? != 0;
    let spill = d.u8()? != 0;
    let replicate_top = d.u64()? as usize;
    let compute_streams = d.u8()? != 0;
    let overlap = d.u8()? != 0;
    let hetero_fleet = d.u8()? != 0;
    // the cluster dimension (span, node id, host pool) is deliberately
    // NOT part of the spec schema: cluster artifacts carry the shape in
    // their `ClusterExt` section and re-derive per-node configs from it,
    // so the defaults here keep pre-cluster artifacts byte-identical
    let system = SystemConfig {
        kind,
        sparsity,
        quant_bits,
        intra_margin,
        chunk_channels,
        residency,
        sparsity_decay,
        devices,
        shard,
        coalesce,
        spill,
        replicate_top,
        compute_streams,
        overlap,
        hetero_fleet,
        ..SystemConfig::new(kind)
    };
    let vram_gb = d.f64()?;
    let routing = RoutingModel { zipf_s: d.f64()?, stickiness: d.f64()?, seed: d.u64()? };
    let inter_hit = d.f64()?;
    let intra_recall = d.f64()?;
    let adv_prefetch_hit = d.f64()?;
    let max_batch = d.u64()? as usize;
    let workload = match d.u8()? {
        0 => WorkloadSource::Spec(WorkloadSpec {
            n_requests: d.u64()? as usize,
            arrival_rate_hz: d.f64()?,
            prompt_len: (d.u64()? as usize, d.u64()? as usize),
            output_tokens: (d.u64()? as usize, d.u64()? as usize),
            seed: d.u64()?,
            // patched from the quality section when FLAG_QUALITY is set
            slo_us: None,
        }),
        1 => {
            let n = d.u64()? as usize;
            let mut trace = Vec::new();
            for _ in 0..n {
                let arrival_us = d.f64()?;
                let id = d.u64()?;
                let max_tokens = d.u64()? as usize;
                let temperature = f32::from_bits(d.u32()?);
                let seed = d.u64()?;
                let prompt = d.bytes()?;
                trace.push(TimedRequest {
                    arrival_us,
                    req: Request { id, prompt, max_tokens, temperature, seed, slo_us: None },
                });
            }
            WorkloadSource::Trace(trace)
        }
        c => return Err(format!("bad workload tag {c}")),
    };
    Ok(SessionSpec {
        hw,
        system,
        vram_gb,
        routing,
        inter_hit,
        intra_recall,
        adv_prefetch_hit,
        max_batch,
        workload,
    })
}

fn put_completions(e: &mut Enc, completions: &[CompletionRecord]) {
    e.u64(completions.len() as u64);
    for c in completions {
        e.u64(c.id);
        e.u64(c.tokens);
        e.u64(c.batch_peak);
        e.f64(c.arrival_us);
        e.f64(c.queue_wait_us);
        e.f64(c.prefill_us);
        e.f64(c.decode_us);
        e.f64(c.stall.demand_us);
        e.f64(c.stall.prefetch_us);
        e.f64(c.finished_us);
        e.u64(c.degraded.hits);
        e.f64(c.degraded.bytes);
    }
}

fn get_completions(d: &mut Dec) -> Result<Vec<CompletionRecord>, String> {
    let n = d.u64()? as usize;
    let mut completions = Vec::new();
    for _ in 0..n {
        completions.push(CompletionRecord {
            id: d.u64()?,
            tokens: d.u64()?,
            batch_peak: d.u64()?,
            arrival_us: d.f64()?,
            queue_wait_us: d.f64()?,
            prefill_us: d.f64()?,
            decode_us: d.f64()?,
            stall: StallSplit { demand_us: d.f64()?, prefetch_us: d.f64()? },
            finished_us: d.f64()?,
            degraded: DegradeCount { hits: d.u64()?, bytes: d.f64()? },
        });
    }
    Ok(completions)
}

fn put_stats(e: &mut Enc, s: &StatsRecord) {
    e.u64(s.demand_fetches);
    e.u64(s.prefetches);
    e.u64(s.bus_transactions);
    e.f64(s.transferred_bytes);
    e.f64(s.bus_busy_us);
    e.f64(s.stall_us);
    e.f64(s.stall_demand_us);
    e.f64(s.stall_prefetch_us);
    e.f64(s.retired.demand_us);
    e.f64(s.retired.prefetch_us);
    e.u64(s.degraded_hits);
    e.f64(s.degraded_bytes);
    e.u64(s.retired_degraded.hits);
    e.f64(s.retired_degraded.bytes);
    e.u64(s.retries);
    e.u64(s.retired_retries);
    e.u64(s.per_device.len() as u64);
    for dev in &s.per_device {
        e.u64(dev.demand_fetches);
        e.u64(dev.prefetches);
        e.u64(dev.bus_transactions);
        e.f64(dev.transferred_bytes);
        e.f64(dev.bus_busy_us);
    }
}

fn get_stats(d: &mut Dec) -> Result<StatsRecord, String> {
    let mut stats = StatsRecord {
        demand_fetches: d.u64()?,
        prefetches: d.u64()?,
        bus_transactions: d.u64()?,
        transferred_bytes: d.f64()?,
        bus_busy_us: d.f64()?,
        stall_us: d.f64()?,
        stall_demand_us: d.f64()?,
        stall_prefetch_us: d.f64()?,
        retired: StallSplit { demand_us: d.f64()?, prefetch_us: d.f64()? },
        degraded_hits: d.u64()?,
        degraded_bytes: d.f64()?,
        retired_degraded: DegradeCount { hits: d.u64()?, bytes: d.f64()? },
        retries: d.u64()?,
        retired_retries: d.u64()?,
        per_device: Vec::new(),
    };
    let n = d.u64()? as usize;
    for _ in 0..n {
        stats.per_device.push(DeviceStats {
            demand_fetches: d.u64()?,
            prefetches: d.u64()?,
            bus_transactions: d.u64()?,
            transferred_bytes: d.f64()?,
            bus_busy_us: d.f64()?,
        });
    }
    Ok(stats)
}

fn put_obs(e: &mut Enc, o: &Observations) {
    e.u64(o.entries.len() as u64);
    for t in &o.entries {
        e.u8(t.kind.code());
        e.f64(t.t_us);
        e.u64(t.id);
        e.u64(t.ord);
    }
    e.bytes(&o.event_log);
    put_completions(e, &o.completions);
    put_stats(e, &o.stats);
    e.f64(o.total_us);
    e.u64(o.max_batch_seen);
    e.f64(o.cache_hit_rate);
}

fn get_obs(d: &mut Dec) -> Result<Observations, String> {
    let n = d.u64()? as usize;
    let mut entries = Vec::new();
    for _ in 0..n {
        entries.push(TimelineEntry {
            kind: EntryKind::from_code(d.u8()?)?,
            t_us: d.f64()?,
            id: d.u64()?,
            ord: d.u64()?,
        });
    }
    let event_log = d.bytes()?;
    let completions = get_completions(d)?;
    let stats = get_stats(d)?;
    Ok(Observations {
        entries,
        event_log,
        completions,
        stats,
        total_us: d.f64()?,
        max_batch_seen: d.u64()?,
        cache_hit_rate: d.f64()?,
    })
}

fn put_cluster(e: &mut Enc, c: &ClusterExt) {
    let s = &c.shape;
    e.u32(s.n_nodes as u32);
    e.u32(s.devices_per_node as u32);
    e.u8(enum_code(&ShardPolicy::ALL, s.shard));
    e.u8(s.placement.tag());
    e.f64(s.vram_gb_total);
    e.f64(s.host_ram_gb);
    match &s.failure {
        Some(f) => {
            e.u8(1);
            e.u32(f.node as u32);
            e.f64(f.t_us);
        }
        None => e.u8(0),
    }
    match &c.obs {
        Some(o) => {
            e.u8(1);
            e.u64(o.assignments.len() as u64);
            for &(id, node) in &o.assignments {
                e.u64(id);
                e.u32(node);
            }
            e.f64(o.total_us);
            e.u64(o.errored);
            e.u64(o.rehomed_keys);
            e.u64(o.nodes.len() as u64);
            for n in &o.nodes {
                e.u64(n.admitted_order.len() as u64);
                for &id in &n.admitted_order {
                    e.u64(id);
                }
                e.bytes(&n.event_log);
                put_completions(e, &n.completions);
                put_stats(e, &n.stats);
                e.f64(n.cache_hit_rate);
                e.f64(n.total_us);
                e.u64(n.max_batch_seen);
                e.u64(n.net_pulls);
                e.f64(n.net_bytes);
                e.u8(n.alive as u8);
            }
        }
        None => e.u8(0),
    }
}

fn get_cluster(d: &mut Dec) -> Result<ClusterExt, String> {
    let n_nodes = d.u32()? as usize;
    let devices_per_node = d.u32()? as usize;
    let shard = enum_at(&ShardPolicy::ALL, d.u8()?, "cluster shard policy")?;
    let placement = {
        let tag = d.u8()?;
        ClusterPlacement::from_tag(tag)
            .ok_or_else(|| format!("bad cluster placement tag {tag}"))?
    };
    let vram_gb_total = d.f64()?;
    let host_ram_gb = d.f64()?;
    let failure = match d.u8()? {
        0 => None,
        1 => Some(NodeFailure { node: d.u32()? as usize, t_us: d.f64()? }),
        c => return Err(format!("bad failure tag {c}")),
    };
    let shape = ClusterShape {
        n_nodes,
        devices_per_node,
        shard,
        placement,
        vram_gb_total,
        host_ram_gb,
        failure,
        // patched from the faults section when FLAG_FAULTS is set
        faults: Vec::new(),
        retry: None,
    };
    let obs = match d.u8()? {
        0 => None,
        1 => {
            let n = d.u64()? as usize;
            let mut assignments = Vec::new();
            for _ in 0..n {
                assignments.push((d.u64()?, d.u32()?));
            }
            let total_us = d.f64()?;
            let errored = d.u64()?;
            let rehomed_keys = d.u64()?;
            let n = d.u64()? as usize;
            let mut nodes = Vec::new();
            for _ in 0..n {
                let k = d.u64()? as usize;
                let mut admitted_order = Vec::new();
                for _ in 0..k {
                    admitted_order.push(d.u64()?);
                }
                let event_log = d.bytes()?;
                let completions = get_completions(d)?;
                let stats = get_stats(d)?;
                nodes.push(NodeRecord {
                    admitted_order,
                    event_log,
                    completions,
                    stats,
                    cache_hit_rate: d.f64()?,
                    total_us: d.f64()?,
                    max_batch_seen: d.u64()?,
                    net_pulls: d.u64()?,
                    net_bytes: d.f64()?,
                    alive: d.u8()? != 0,
                });
            }
            Some(ClusterObservations {
                assignments,
                nodes,
                total_us,
                errored,
                rehomed_keys,
                // patched from the faults section when FLAG_FAULTS is set
                redispatched: 0,
                rejoins: 0,
                dev_moved_keys: 0,
                dev_dropped_keys: 0,
            })
        }
        c => return Err(format!("bad cluster observations tag {c}")),
    };
    Ok(ClusterExt { shape, obs })
}

/// Whether the cluster shape exercises the fault machinery and therefore
/// needs the appended `FLAG_FAULTS` section to round-trip.
fn faults_needed(cluster: Option<&ClusterExt>) -> bool {
    cluster.map_or(false, |c| !c.shape.faults.is_empty() || c.shape.retry.is_some())
}

/// The fault-schedule section (DESIGN.md §12): the retry policy, the
/// timed fault list (one fixed-width record per fault: tag, node-or-dev,
/// aux link tag, degrade factor, window start/end — unused fields encode
/// as zero) and, when observations are present, the fault-recovery
/// counters the base cluster section omits.
fn put_faults(e: &mut Enc, c: &ClusterExt) {
    match &c.shape.retry {
        Some(r) => {
            e.u8(1);
            e.u32(r.max_attempts);
            e.f64(r.backoff_base_us);
        }
        None => e.u8(0),
    }
    e.u32(c.shape.faults.len() as u32);
    for f in &c.shape.faults {
        e.u8(f.tag());
        let (node, aux, factor, t0, t1) = match *f {
            Fault::DeviceDown { dev, t_us } => (dev as u32, 0u32, 0.0, t_us, 0.0),
            Fault::LinkDegrade { link, factor, t0_us, t1_us } => {
                (0, u32::from(link.tag()), factor, t0_us, t1_us)
            }
            Fault::NodeDown { node, t_us } => (node as u32, 0, 0.0, t_us, 0.0),
            Fault::NodeRejoin { node, t_us } => (node as u32, 0, 0.0, t_us, 0.0),
        };
        e.u32(node);
        e.u32(aux);
        e.f64(factor);
        e.f64(t0);
        e.f64(t1);
    }
    match &c.obs {
        Some(o) => {
            e.u8(1);
            e.u64(o.redispatched);
            e.u64(o.rejoins);
            e.u64(o.dev_moved_keys);
            e.u64(o.dev_dropped_keys);
        }
        None => e.u8(0),
    }
}

fn get_faults(d: &mut Dec, c: &mut ClusterExt) -> Result<(), String> {
    c.shape.retry = match d.u8()? {
        0 => None,
        1 => Some(RetryPolicy { max_attempts: d.u32()?, backoff_base_us: d.f64()? }),
        t => return Err(format!("bad retry presence tag {t}")),
    };
    let n = d.u32()? as usize;
    for _ in 0..n {
        let tag = d.u8()?;
        let node = d.u32()? as usize;
        let aux = d.u32()?;
        let factor = d.f64()?;
        let t0 = d.f64()?;
        let t1 = d.f64()?;
        c.shape.faults.push(match tag {
            0 => Fault::DeviceDown { dev: node, t_us: t0 },
            1 => {
                let link = LinkId::from_tag(aux as u8)
                    .ok_or_else(|| format!("bad link tag {aux}"))?;
                Fault::LinkDegrade { link, factor, t0_us: t0, t1_us: t1 }
            }
            2 => Fault::NodeDown { node, t_us: t0 },
            3 => Fault::NodeRejoin { node, t_us: t0 },
            t => return Err(format!("bad fault tag {t}")),
        });
    }
    match d.u8()? {
        0 => Ok(()),
        1 => {
            let (redispatched, rejoins, moved, dropped) =
                (d.u64()?, d.u64()?, d.u64()?, d.u64()?);
            let Some(o) = &mut c.obs else {
                return Err("fault counters without cluster observations".to_string());
            };
            o.redispatched = redispatched;
            o.rejoins = rejoins;
            o.dev_moved_keys = moved;
            o.dev_dropped_keys = dropped;
            Ok(())
        }
        t => Err(format!("bad fault counters tag {t}")),
    }
}

/// Whether the spec exercises the quality-elastic fallback and therefore
/// needs the appended `FLAG_QUALITY` section to round-trip.
fn quality_needed(spec: &SessionSpec) -> bool {
    spec.system.little_frac > 0.0
        || match &spec.workload {
            WorkloadSource::Spec(w) => w.slo_us.is_some(),
            WorkloadSource::Trace(t) => t.iter().any(|r| r.req.slo_us.is_some()),
        }
}

/// The quality section (DESIGN.md §11): the little-tier carve fraction
/// followed by the SLO budgets the base workload encoding omits — one
/// presence-tagged f64 for a `Spec` workload (its uniform budget), one
/// per request for a `Trace` (the trace length is already fixed by the
/// base section, so no count is repeated here).
fn put_quality(e: &mut Enc, spec: &SessionSpec) {
    e.f64(spec.system.little_frac);
    let put_slo = |e: &mut Enc, slo: Option<f64>| match slo {
        Some(s) => {
            e.u8(1);
            e.f64(s);
        }
        None => e.u8(0),
    };
    match &spec.workload {
        WorkloadSource::Spec(w) => put_slo(e, w.slo_us),
        WorkloadSource::Trace(t) => {
            for r in t {
                put_slo(e, r.req.slo_us);
            }
        }
    }
}

fn get_quality(d: &mut Dec, spec: &mut SessionSpec) -> Result<(), String> {
    spec.system.little_frac = d.f64()?;
    let get_slo = |d: &mut Dec| -> Result<Option<f64>, String> {
        match d.u8()? {
            0 => Ok(None),
            1 => Ok(Some(d.f64()?)),
            c => Err(format!("bad slo presence tag {c}")),
        }
    };
    match &mut spec.workload {
        WorkloadSource::Spec(w) => w.slo_us = get_slo(d)?,
        WorkloadSource::Trace(t) => {
            for r in t.iter_mut() {
                r.req.slo_us = get_slo(d)?;
            }
        }
    }
    Ok(())
}

impl Timeline {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut e = Enc::new();
        e.buf.extend_from_slice(&MAGIC);
        e.u32(VERSION);
        let mut flags = 0;
        if self.obs.is_some() {
            flags |= FLAG_OBSERVATIONS;
        }
        if self.replayable {
            flags |= FLAG_REPLAYABLE;
        }
        if self.cluster.is_some() {
            flags |= FLAG_CLUSTER;
        }
        let quality = quality_needed(&self.spec);
        if quality {
            flags |= FLAG_QUALITY;
        }
        let faults = faults_needed(self.cluster.as_ref());
        if faults {
            flags |= FLAG_FAULTS;
        }
        e.u32(flags);
        put_spec(&mut e, &self.spec);
        if let Some(o) = &self.obs {
            put_obs(&mut e, o);
        }
        if let Some(c) = &self.cluster {
            put_cluster(&mut e, c);
        }
        if quality {
            put_quality(&mut e, &self.spec);
        }
        if faults {
            put_faults(&mut e, self.cluster.as_ref().expect("faults imply cluster"));
        }
        e.buf
    }

    pub fn from_bytes(buf: &[u8]) -> Result<Self, String> {
        let mut d = Dec { buf, at: 0 };
        if d.take(4)? != MAGIC.as_slice() {
            return Err("not a timeline artifact (bad magic)".to_string());
        }
        let version = d.u32()?;
        if version != VERSION {
            return Err(format!("unsupported timeline version {version} (have {VERSION})"));
        }
        let flags = d.u32()?;
        if flags & !KNOWN_FLAGS != 0 {
            return Err(format!(
                "unknown timeline flag bits {:#x} (this build understands {:#x}) — \
                 the artifact was written by a newer format revision; refusing to \
                 misparse its appended sections",
                flags & !KNOWN_FLAGS,
                KNOWN_FLAGS
            ));
        }
        let mut spec = get_spec(&mut d)?;
        let obs = if flags & FLAG_OBSERVATIONS != 0 {
            Some(get_obs(&mut d)?)
        } else {
            None
        };
        let mut cluster = if flags & FLAG_CLUSTER != 0 {
            Some(get_cluster(&mut d)?)
        } else {
            None
        };
        if flags & FLAG_QUALITY != 0 {
            get_quality(&mut d, &mut spec)?;
        }
        if flags & FLAG_FAULTS != 0 {
            match &mut cluster {
                Some(c) => get_faults(&mut d, c)?,
                None => {
                    return Err("fault section without a cluster section".to_string());
                }
            }
        }
        d.done()?;
        Ok(Timeline { spec, obs, cluster, replayable: flags & FLAG_REPLAYABLE != 0 })
    }
}

// ---------------------------------------------------------------------------
// recorder

/// Transparent `SeqBackend` wrapper that records scheduler-level
/// decisions (arrival / admission / retirement) as [`TimelineEntry`]s.
/// Every call delegates 1:1 to the inner backend — a recorded session is
/// bit-exact with an unrecorded one.
pub struct RecordingBackend<B: SeqBackend> {
    inner: B,
    entries: Vec<TimelineEntry>,
    trace: Vec<TimedRequest>,
    arrivals: u64,
    admits: u64,
    retires: u64,
}

impl<B: SeqBackend> RecordingBackend<B> {
    pub fn new(inner: B) -> Self {
        RecordingBackend {
            inner,
            entries: Vec::new(),
            trace: Vec::new(),
            arrivals: 0,
            admits: 0,
            retires: 0,
        }
    }

    /// Record a request entering the admission queue. The drive loop (or
    /// the server's admit path) calls this right before
    /// `Scheduler::enqueue_at` — arrivals are an input to the scheduler,
    /// not a backend callback, so they cannot be observed from inside
    /// the trait.
    pub fn note_arrival(&mut self, arrival_us: f64, req: &Request) {
        self.entries.push(TimelineEntry {
            kind: EntryKind::Arrival,
            t_us: arrival_us,
            id: req.id,
            ord: self.arrivals,
        });
        self.arrivals += 1;
        self.trace.push(TimedRequest { arrival_us, req: req.clone() });
    }

    pub fn inner(&self) -> &B {
        &self.inner
    }

    /// Tear down into (inner backend, recorded entries, arrival trace).
    pub fn finish(self) -> (B, Vec<TimelineEntry>, Vec<TimedRequest>) {
        (self.inner, self.entries, self.trace)
    }
}

impl<B: SeqBackend> SeqBackend for RecordingBackend<B> {
    type Seq = B::Seq;
    fn now_us(&self) -> f64 {
        self.inner.now_us()
    }
    fn on_boundary(&mut self) {
        self.inner.on_boundary();
    }
    fn start(&mut self, req: &Request) -> Result<(Self::Seq, f64)> {
        self.entries.push(TimelineEntry {
            kind: EntryKind::Admit,
            t_us: self.inner.now_us(),
            id: req.id,
            ord: self.admits,
        });
        self.admits += 1;
        self.inner.start(req)
    }
    fn step(&mut self, seq: &mut Self::Seq) -> Result<SeqStep> {
        self.inner.step(seq)
    }
    fn idle_until(&mut self, t_us: f64) {
        self.inner.idle_until(t_us);
    }
    fn step_batch(&mut self, seqs: &mut [&mut Self::Seq]) -> Vec<Result<SeqStep>> {
        self.inner.step_batch(seqs)
    }
    fn stalls_of(&self, id: u64) -> StallSplit {
        self.inner.stalls_of(id)
    }
    fn retire(&mut self, id: u64) -> StallSplit {
        let split = self.inner.retire(id);
        self.entries.push(TimelineEntry {
            kind: EntryKind::Retire,
            t_us: self.inner.now_us(),
            id,
            ord: self.retires,
        });
        self.retires += 1;
        split
    }
    fn degraded_of(&self, id: u64) -> DegradeCount {
        self.inner.degraded_of(id)
    }
    fn take_degraded(&mut self, id: u64) -> DegradeCount {
        self.inner.take_degraded(id)
    }
    fn take_fault_cause(&mut self, id: u64) -> Option<FaultCause> {
        self.inner.take_fault_cause(id)
    }
    fn snapshot(&self) -> Option<BackendSnapshot> {
        self.inner.snapshot()
    }
    fn event_log_bytes(&self) -> &[u8] {
        self.inner.event_log_bytes()
    }
}

/// Record a serving session: drive the spec through the *exact*
/// `simulate_serving` loop (whole trace enqueued up front, admission
/// event-timed by `Scheduler::step` itself) over an event-logging sim
/// backend wrapped in a [`RecordingBackend`], and capture everything it
/// produced. Arrival entries therefore lead the recorded timeline in
/// arrival order — they carry their own stamps, so causal rendering
/// stays honest — followed by the interleaved admit/retire entries.
pub fn record(spec: &SessionSpec) -> Timeline {
    let workload = spec.trace();
    let max_ctx = workload
        .iter()
        .map(|t| t.req.prompt.len() + t.req.max_tokens)
        .max()
        .unwrap_or(512);
    let kv_tokens = spec.max_batch.max(1) * max_ctx;
    let backend = SimServeBackend::new_traced(spec.params(), kv_tokens);
    let mut sched = Scheduler::new(RecordingBackend::new(backend), spec.max_batch);
    for t in &workload {
        sched.backend_mut().note_arrival(t.arrival_us, &t.req);
        sched.enqueue_at(t.req.clone(), t.arrival_us);
    }
    let completions: Vec<CompletionRecord> =
        sched.drain().iter().map(CompletionRecord::of).collect();
    let total_us = sched.backend().now_us();
    let max_batch_seen = sched.max_batch_seen() as u64;
    let (backend, entries, _trace) = sched.into_backend().finish();
    let snap = backend.snapshot().expect("sim backend always snapshots");
    Timeline {
        spec: spec.clone(),
        obs: Some(Observations {
            entries,
            event_log: backend.event_log().to_vec(),
            completions,
            stats: StatsRecord::of(&snap.stats),
            total_us,
            max_batch_seen,
            cache_hit_rate: snap.cache_hit_rate,
        }),
        cluster: None,
        replayable: true,
    }
}

/// Record a cluster session (DESIGN.md §10): run the deterministic
/// cluster router over traced per-node backends and capture the shape,
/// the router's assignments and every node's observations.
pub fn record_cluster(base: &SessionSpec, shape: &ClusterShape) -> Result<Timeline, String> {
    let workload = base.trace();
    let spec = shape.cluster_spec(base.max_batch);
    let report = simulate_cluster_traced(&base.params(), &spec, &workload)
        .map_err(|e| format!("{e:#}"))?;
    Ok(Timeline {
        spec: base.clone(),
        obs: None,
        cluster: Some(ClusterExt {
            shape: shape.clone(),
            obs: Some(ClusterObservations::of(&report)),
        }),
        replayable: true,
    })
}

/// What the server's recording-enabled loop hands back at teardown; the
/// listener assembles it into a (non-replayable) [`Timeline`] via
/// [`server_timeline`].
#[derive(Clone, Debug)]
pub struct SessionRecording {
    pub entries: Vec<TimelineEntry>,
    pub trace: Vec<TimedRequest>,
    pub completions: Vec<CompletionRecord>,
    pub event_log: Vec<u8>,
    pub snapshot: Option<BackendSnapshot>,
    pub total_us: f64,
    pub max_batch_seen: u64,
}

/// Wrap a live server recording as an inspect-only artifact: the
/// workload is the observed arrival trace, and the replayable flag stays
/// off (wall-clock arrival interleaving is not a pure function of the
/// spec).
pub fn server_timeline(p: &SimParams, max_batch: usize, rec: &SessionRecording) -> Timeline {
    Timeline {
        spec: SessionSpec::from_params(p, max_batch, WorkloadSource::Trace(rec.trace.clone())),
        obs: Some(Observations {
            entries: rec.entries.clone(),
            event_log: rec.event_log.clone(),
            completions: rec.completions.clone(),
            stats: rec.snapshot.as_ref().map(|s| StatsRecord::of(&s.stats)).unwrap_or_default(),
            total_us: rec.total_us,
            max_batch_seen: rec.max_batch_seen,
            cache_hit_rate: rec.snapshot.as_ref().map(|s| s.cache_hit_rate).unwrap_or(0.0),
        }),
        cluster: None,
        replayable: false,
    }
}

// ---------------------------------------------------------------------------
// replayer

/// First mismatching timeline position, with both causal histories.
/// Cluster replays prefix the channel with the node (`"node 1: event
/// log"`); cluster-global channels carry no prefix.
#[derive(Debug)]
pub struct Divergence {
    pub channel: String,
    pub index: usize,
    pub recorded: String,
    pub replayed: String,
    pub recorded_context: Vec<String>,
    pub replayed_context: Vec<String>,
}

impl fmt::Display for Divergence {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut lines = vec![
            format!("replay diverged in {} at index {}:", self.channel, self.index),
            format!("  recorded: {}", self.recorded),
            format!("  replayed: {}", self.replayed),
        ];
        if !self.recorded_context.is_empty() {
            lines.push("  recorded causal history:".to_string());
            lines.extend(self.recorded_context.iter().map(|l| format!("    {l}")));
        }
        if !self.replayed_context.is_empty() {
            lines.push("  replayed causal history:".to_string());
            lines.extend(self.replayed_context.iter().map(|l| format!("    {l}")));
        }
        write!(f, "{}", lines.join("\n"))
    }
}

/// Why a replay did not verify.
#[derive(Debug)]
pub enum ReplayError {
    /// The artifact was recorded live (wall-clock arrivals): inspectable,
    /// but not a pure function of its spec.
    NotReplayable,
    /// `replay_cluster` was handed an artifact without a cluster section
    /// (or `replay` was handed one whose session is cluster-only).
    NotCluster,
    /// The artifact's cluster shape cannot be simulated (e.g. a failure
    /// node out of range) — a malformed artifact, not a divergence.
    Invalid(String),
    Diverged(Box<Divergence>),
}

impl fmt::Display for ReplayError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ReplayError::NotReplayable => {
                write!(f, "artifact is a live recording; inspect-only (not replayable)")
            }
            ReplayError::NotCluster => {
                write!(f, "artifact carries no cluster section (replay it with `replay`)")
            }
            ReplayError::Invalid(e) => write!(f, "cluster shape is not simulatable: {e}"),
            ReplayError::Diverged(d) => write!(f, "{d}"),
        }
    }
}

fn context(lines: &[String], idx: usize) -> Vec<String> {
    let lo = idx.saturating_sub(3);
    let hi = (idx + 4).min(lines.len());
    lines[lo..hi].iter().enumerate().map(|(k, l)| format!("[{}] {}", lo + k, l)).collect()
}

fn end_or(lines: &[String], idx: usize) -> &str {
    lines.get(idx).map(|s| s.as_str()).unwrap_or("<end of log>")
}

fn diverge(
    channel: impl Into<String>,
    idx: usize,
    recorded: &[String],
    replayed: &[String],
) -> Box<Divergence> {
    Box::new(Divergence {
        channel: channel.into(),
        index: idx,
        recorded: end_or(recorded, idx).to_string(),
        replayed: end_or(replayed, idx).to_string(),
        recorded_context: context(recorded, idx),
        replayed_context: context(replayed, idx),
    })
}

/// Decode the event core's 17-byte pop records into one line per pop.
fn decode_event_log(log: &[u8]) -> Vec<String> {
    let mut out = Vec::new();
    for rec in log.chunks(17) {
        if rec.len() < 17 {
            out.push(format!("<truncated {}-byte record>", rec.len()));
            break;
        }
        let kind = match rec[0] {
            0 => "TransferComplete".to_string(),
            1 => "GemvComplete".to_string(),
            2 => "BoundaryBarrier".to_string(),
            3 => "RequestArrival".to_string(),
            4 => "NodeDown".to_string(),
            5 => "Degraded".to_string(),
            6 => "DeviceDown".to_string(),
            7 => "LinkDegrade".to_string(),
            8 => "NodeRejoin".to_string(),
            k => format!("Unknown({k})"),
        };
        let t = f64::from_bits(u64::from_le_bytes(rec[1..9].try_into().unwrap()));
        let id = u64::from_le_bytes(rec[9..17].try_into().unwrap());
        out.push(format!("{kind} t={t}us id={id}"));
    }
    out
}

type ScalarRow = (String, u64, String);

fn int_row(rows: &mut Vec<ScalarRow>, name: &str, v: u64) {
    rows.push((name.to_string(), v, v.to_string()));
}

fn f64_row(rows: &mut Vec<ScalarRow>, name: &str, v: f64) {
    rows.push((name.to_string(), v.to_bits(), format!("{v}")));
}

fn stats_rows(rows: &mut Vec<ScalarRow>, s: &StatsRecord) {
    int_row(rows, "demand_fetches", s.demand_fetches);
    int_row(rows, "prefetches", s.prefetches);
    int_row(rows, "bus_transactions", s.bus_transactions);
    f64_row(rows, "transferred_bytes", s.transferred_bytes);
    f64_row(rows, "bus_busy_us", s.bus_busy_us);
    f64_row(rows, "stall_us", s.stall_us);
    f64_row(rows, "stall_demand_us", s.stall_demand_us);
    f64_row(rows, "stall_prefetch_us", s.stall_prefetch_us);
    f64_row(rows, "retired.demand_us", s.retired.demand_us);
    f64_row(rows, "retired.prefetch_us", s.retired.prefetch_us);
    int_row(rows, "retries", s.retries);
    int_row(rows, "retired_retries", s.retired_retries);
    for (i, dev) in s.per_device.iter().enumerate() {
        int_row(rows, &format!("dev{i}.demand_fetches"), dev.demand_fetches);
        int_row(rows, &format!("dev{i}.prefetches"), dev.prefetches);
        int_row(rows, &format!("dev{i}.bus_transactions"), dev.bus_transactions);
        f64_row(rows, &format!("dev{i}.transferred_bytes"), dev.transferred_bytes);
        f64_row(rows, &format!("dev{i}.bus_busy_us"), dev.bus_busy_us);
    }
}

fn scalar_rows(o: &Observations) -> Vec<ScalarRow> {
    let mut rows = Vec::new();
    stats_rows(&mut rows, &o.stats);
    f64_row(&mut rows, "total_us", o.total_us);
    int_row(&mut rows, "max_batch_seen", o.max_batch_seen);
    f64_row(&mut rows, "cache_hit_rate", o.cache_hit_rate);
    rows
}

fn node_scalar_rows(n: &NodeRecord) -> Vec<ScalarRow> {
    let mut rows = Vec::new();
    stats_rows(&mut rows, &n.stats);
    f64_row(&mut rows, "cache_hit_rate", n.cache_hit_rate);
    f64_row(&mut rows, "total_us", n.total_us);
    int_row(&mut rows, "max_batch_seen", n.max_batch_seen);
    int_row(&mut rows, "net_pulls", n.net_pulls);
    f64_row(&mut rows, "net_bytes", n.net_bytes);
    int_row(&mut rows, "alive", n.alive as u64);
    rows
}

/// Bit-exact comparison of two observation sets, channel by channel in
/// causal order: scheduler entries, event-core log, per-request
/// completions, then the store-stats scalars.
pub fn diff_observations(
    recorded: &Observations,
    replayed: &Observations,
) -> Result<(), Box<Divergence>> {
    let n = recorded.entries.len().max(replayed.entries.len());
    for i in 0..n {
        let a = recorded.entries.get(i).map(TimelineEntry::bits);
        let b = replayed.entries.get(i).map(TimelineEntry::bits);
        if a != b {
            let ra: Vec<String> = recorded.entries.iter().map(TimelineEntry::render).collect();
            let rb: Vec<String> = replayed.entries.iter().map(TimelineEntry::render).collect();
            return Err(diverge("scheduler entries", i, &ra, &rb));
        }
    }
    if recorded.event_log != replayed.event_log {
        let ra = decode_event_log(&recorded.event_log);
        let rb = decode_event_log(&replayed.event_log);
        let n = ra.len().max(rb.len());
        let i = (0..n).find(|&i| ra.get(i) != rb.get(i)).unwrap_or(0);
        return Err(diverge("event log", i, &ra, &rb));
    }
    let n = recorded.completions.len().max(replayed.completions.len());
    for i in 0..n {
        let a = recorded.completions.get(i).map(CompletionRecord::bits);
        let b = replayed.completions.get(i).map(CompletionRecord::bits);
        if a != b {
            let ra: Vec<String> =
                recorded.completions.iter().map(CompletionRecord::render).collect();
            let rb: Vec<String> =
                replayed.completions.iter().map(CompletionRecord::render).collect();
            return Err(diverge("completions", i, &ra, &rb));
        }
    }
    let ra = scalar_rows(recorded);
    let rb = scalar_rows(replayed);
    let n = ra.len().max(rb.len());
    for i in 0..n {
        let a = ra.get(i).map(|(name, bits, _)| (name.clone(), *bits));
        let b = rb.get(i).map(|(name, bits, _)| (name.clone(), *bits));
        if a != b {
            let la: Vec<String> = ra.iter().map(|(n, _, v)| format!("{n}={v}")).collect();
            let lb: Vec<String> = rb.iter().map(|(n, _, v)| format!("{n}={v}")).collect();
            return Err(diverge("store stats", i, &la, &lb));
        }
    }
    Ok(())
}

/// Re-drive a recorded session from its spec and assert bit-exact
/// reproduction. Spec-only artifacts (no observation section) are
/// replayed twice — a pure determinism check. Returns the freshly
/// replayed observations on success.
pub fn replay(tl: &Timeline) -> Result<Observations, ReplayError> {
    if !tl.replayable {
        return Err(ReplayError::NotReplayable);
    }
    if tl.cluster.is_some() {
        return Err(ReplayError::NotCluster);
    }
    let fresh = record(&tl.spec).obs.expect("record always attaches observations");
    let reference = match &tl.obs {
        Some(o) => o.clone(),
        None => record(&tl.spec).obs.expect("record always attaches observations"),
    };
    diff_observations(&reference, &fresh).map_err(ReplayError::Diverged)?;
    Ok(fresh)
}

fn first_mismatch(a: &[String], b: &[String]) -> usize {
    let n = a.len().max(b.len());
    (0..n).find(|&i| a.get(i) != b.get(i)).unwrap_or(0)
}

fn diff_node(j: usize, a: &NodeRecord, b: &NodeRecord) -> Result<(), Box<Divergence>> {
    if a.admitted_order != b.admitted_order {
        let ra: Vec<String> =
            a.admitted_order.iter().map(|id| format!("admit id={id}")).collect();
        let rb: Vec<String> =
            b.admitted_order.iter().map(|id| format!("admit id={id}")).collect();
        let i = first_mismatch(&ra, &rb);
        return Err(diverge(format!("node {j}: admitted order"), i, &ra, &rb));
    }
    if a.event_log != b.event_log {
        let ra = decode_event_log(&a.event_log);
        let rb = decode_event_log(&b.event_log);
        let i = first_mismatch(&ra, &rb);
        return Err(diverge(format!("node {j}: event log"), i, &ra, &rb));
    }
    let n = a.completions.len().max(b.completions.len());
    for i in 0..n {
        let ca = a.completions.get(i).map(CompletionRecord::bits);
        let cb = b.completions.get(i).map(CompletionRecord::bits);
        if ca != cb {
            let ra: Vec<String> = a.completions.iter().map(CompletionRecord::render).collect();
            let rb: Vec<String> = b.completions.iter().map(CompletionRecord::render).collect();
            return Err(diverge(format!("node {j}: completions"), i, &ra, &rb));
        }
    }
    let ra = node_scalar_rows(a);
    let rb = node_scalar_rows(b);
    for i in 0..ra.len().max(rb.len()) {
        let va = ra.get(i).map(|(name, bits, _)| (name.clone(), *bits));
        let vb = rb.get(i).map(|(name, bits, _)| (name.clone(), *bits));
        if va != vb {
            let la: Vec<String> = ra.iter().map(|(n, _, v)| format!("{n}={v}")).collect();
            let lb: Vec<String> = rb.iter().map(|(n, _, v)| format!("{n}={v}")).collect();
            return Err(diverge(format!("node {j}: store stats"), i, &la, &lb));
        }
    }
    Ok(())
}

/// Bit-exact comparison of two cluster observation sets, in causal
/// order: routing assignments first (they decide everything
/// downstream), then each node's channels, then the cluster totals.
pub fn diff_cluster(
    recorded: &ClusterObservations,
    replayed: &ClusterObservations,
) -> Result<(), Box<Divergence>> {
    if recorded.assignments != replayed.assignments {
        let ra: Vec<String> = recorded
            .assignments
            .iter()
            .map(|(id, n)| format!("req {id} -> node {n}"))
            .collect();
        let rb: Vec<String> = replayed
            .assignments
            .iter()
            .map(|(id, n)| format!("req {id} -> node {n}"))
            .collect();
        let i = first_mismatch(&ra, &rb);
        return Err(diverge("assignments", i, &ra, &rb));
    }
    if recorded.nodes.len() != replayed.nodes.len() {
        let row = |nodes: &[NodeRecord]| {
            nodes
                .iter()
                .enumerate()
                .map(|(j, n)| format!("node {j}: {} completions", n.completions.len()))
                .collect::<Vec<_>>()
        };
        let (ra, rb) = (row(&recorded.nodes), row(&replayed.nodes));
        let i = first_mismatch(&ra, &rb);
        return Err(diverge("node count", i, &ra, &rb));
    }
    for (j, (a, b)) in recorded.nodes.iter().zip(&replayed.nodes).enumerate() {
        diff_node(j, a, b)?;
    }
    let totals = |o: &ClusterObservations| {
        let mut rows = Vec::new();
        f64_row(&mut rows, "total_us", o.total_us);
        int_row(&mut rows, "errored", o.errored);
        int_row(&mut rows, "rehomed_keys", o.rehomed_keys);
        int_row(&mut rows, "redispatched", o.redispatched);
        int_row(&mut rows, "rejoins", o.rejoins);
        int_row(&mut rows, "dev_moved_keys", o.dev_moved_keys);
        int_row(&mut rows, "dev_dropped_keys", o.dev_dropped_keys);
        rows
    };
    let (ra, rb) = (totals(recorded), totals(replayed));
    for i in 0..ra.len() {
        if ra[i].1 != rb[i].1 {
            let la: Vec<String> = ra.iter().map(|(n, _, v)| format!("{n}={v}")).collect();
            let lb: Vec<String> = rb.iter().map(|(n, _, v)| format!("{n}={v}")).collect();
            return Err(diverge("cluster totals", i, &la, &lb));
        }
    }
    Ok(())
}

/// Re-drive a recorded cluster session from its spec and shape, and
/// assert bit-exact reproduction node by node. Shape-only artifacts (no
/// cluster observations) are replayed twice — a pure determinism check.
/// Returns the freshly replayed cluster observations on success.
pub fn replay_cluster(tl: &Timeline) -> Result<ClusterObservations, ReplayError> {
    if !tl.replayable {
        return Err(ReplayError::NotReplayable);
    }
    let Some(ext) = &tl.cluster else {
        return Err(ReplayError::NotCluster);
    };
    let run = || -> Result<ClusterObservations, ReplayError> {
        Ok(record_cluster(&tl.spec, &ext.shape)
            .map_err(ReplayError::Invalid)?
            .cluster
            .expect("record_cluster always attaches a cluster section")
            .obs
            .expect("record_cluster always attaches cluster observations"))
    };
    let fresh = run()?;
    let reference = match &ext.obs {
        Some(o) => o.clone(),
        None => run()?,
    };
    diff_cluster(&reference, &fresh).map_err(ReplayError::Diverged)?;
    Ok(fresh)
}

// ---------------------------------------------------------------------------
// inspector

/// Per-request serving report derived from a recorded timeline (or from
/// the same accounting live, before the artifact is written). Every field
/// is re-derived from the per-request records; `ledger_exact` asserts the
/// re-derivation reproduces the store's global `StoreStats` counters
/// bit-exactly (true at quiescence — it reads false while requests are
/// still in flight, when the globals include live ledger entries).
#[derive(Clone, Debug)]
pub struct InspectorReport {
    pub requests: u64,
    pub tokens: u64,
    pub total_us: f64,
    pub aggregate_tps: f64,
    pub queue_wait_p50_us: f64,
    pub queue_wait_p95_us: f64,
    pub queue_wait_p99_us: f64,
    pub stall_demand_us: f64,
    pub stall_prefetch_us: f64,
    pub demand_stall_share: f64,
    pub mean_batch_peak: f64,
    pub max_batch_seen: u64,
    pub cache_hit_rate: f64,
    pub device_busy_share: Vec<f64>,
    /// Quality-elastic fallback (DESIGN.md §11): degraded boundaries
    /// across the session, the full-fetch bytes they avoided, and the
    /// share of requests that resolved at least one boundary degraded.
    /// All zero for every fallback-off session.
    pub degraded_hits: u64,
    pub degraded_bytes: f64,
    pub degraded_request_share: f64,
    /// Bounded-backoff transfer retries charged across the session
    /// (DESIGN.md §12); zero for every retry-free run.
    pub retries: u64,
    pub ledger_exact: bool,
}

fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() - 1) as f64 * q).round() as usize]
}

/// Inspect a recorded observation section.
pub fn inspect(obs: &Observations) -> InspectorReport {
    inspect_parts(
        &obs.completions,
        Some(&obs.stats),
        obs.cache_hit_rate,
        obs.total_us,
        obs.max_batch_seen,
    )
}

/// Inspector over the raw parts — the live server's `stats` command and
/// the offline artifact path both go through here, so their numbers agree
/// bit-for-bit on the same inputs.
pub fn inspect_parts(
    completions: &[CompletionRecord],
    stats: Option<&StatsRecord>,
    cache_hit_rate: f64,
    total_us: f64,
    max_batch_seen: u64,
) -> InspectorReport {
    let mut waits: Vec<f64> = completions.iter().map(|c| c.queue_wait_us).collect();
    waits.sort_by(f64::total_cmp);
    let tokens: u64 = completions.iter().map(|c| c.tokens).sum();
    // Fold per-request stalls in retirement order — the same order (and
    // the same f64 additions) the store's ledger used to fold them into
    // `retired`, so the sums agree bit-for-bit.
    let mut demand = 0.0;
    let mut prefetch = 0.0;
    let mut deg_hits: u64 = 0;
    let mut deg_bytes = 0.0;
    for c in completions {
        demand += c.stall.demand_us;
        prefetch += c.stall.prefetch_us;
        deg_hits += c.degraded.hits;
        deg_bytes += c.degraded.bytes;
    }
    let ledger_exact = match stats {
        Some(s) => {
            demand.to_bits() == s.retired.demand_us.to_bits()
                && prefetch.to_bits() == s.retired.prefetch_us.to_bits()
                && s.stall_demand_us.to_bits() == s.retired.demand_us.to_bits()
                && s.stall_prefetch_us.to_bits() == s.retired.prefetch_us.to_bits()
                // the degraded ledger retires exactly like the stall
                // ledger: per-request counts re-sum to the globals
                && deg_hits == s.retired_degraded.hits
                && deg_bytes.to_bits() == s.retired_degraded.bytes.to_bits()
                && s.degraded_hits == s.retired_degraded.hits
                && s.degraded_bytes.to_bits() == s.retired_degraded.bytes.to_bits()
                // the retry ledger retires the same way: at quiescence
                // the global equals the retired bucket exactly
                && s.retries == s.retired_retries
        }
        None => false,
    };
    let span = total_us.max(1e-9);
    let (stall_demand_us, stall_prefetch_us) = match stats {
        Some(s) => (s.stall_demand_us, s.stall_prefetch_us),
        None => (demand, prefetch),
    };
    let n = completions.len() as f64;
    InspectorReport {
        requests: completions.len() as u64,
        tokens,
        total_us,
        aggregate_tps: tokens as f64 / (total_us / 1e6).max(1e-9),
        queue_wait_p50_us: pct(&waits, 0.50),
        queue_wait_p95_us: pct(&waits, 0.95),
        queue_wait_p99_us: pct(&waits, 0.99),
        stall_demand_us,
        stall_prefetch_us,
        demand_stall_share: stall_demand_us / span,
        mean_batch_peak: if completions.is_empty() {
            0.0
        } else {
            completions.iter().map(|c| c.batch_peak as f64).sum::<f64>() / n
        },
        max_batch_seen,
        cache_hit_rate,
        device_busy_share: stats
            .map(|s| s.per_device.iter().map(|d| d.bus_busy_us / span).collect())
            .unwrap_or_default(),
        degraded_hits: deg_hits,
        degraded_bytes: deg_bytes,
        degraded_request_share: if completions.is_empty() {
            0.0
        } else {
            completions.iter().filter(|c| c.degraded.hits > 0).count() as f64 / n
        },
        retries: stats.map(|s| s.retries).unwrap_or(0),
        ledger_exact,
    }
}

impl InspectorReport {
    /// JSON form — the server's `stats` protocol response and the
    /// offline CLI both serialize through this (and through
    /// `util::json::write`'s shortest-roundtrip float formatting), so
    /// live and artifact-derived reports compare exactly.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("requests".to_string(), Json::Num(self.requests as f64));
        m.insert("tokens".to_string(), Json::Num(self.tokens as f64));
        m.insert("total_us".to_string(), Json::Num(self.total_us));
        m.insert("aggregate_tps".to_string(), Json::Num(self.aggregate_tps));
        m.insert("queue_wait_p50_us".to_string(), Json::Num(self.queue_wait_p50_us));
        m.insert("queue_wait_p95_us".to_string(), Json::Num(self.queue_wait_p95_us));
        m.insert("queue_wait_p99_us".to_string(), Json::Num(self.queue_wait_p99_us));
        m.insert("stall_demand_us".to_string(), Json::Num(self.stall_demand_us));
        m.insert("stall_prefetch_us".to_string(), Json::Num(self.stall_prefetch_us));
        m.insert("demand_stall_share".to_string(), Json::Num(self.demand_stall_share));
        m.insert("mean_batch_peak".to_string(), Json::Num(self.mean_batch_peak));
        m.insert("max_batch_seen".to_string(), Json::Num(self.max_batch_seen as f64));
        m.insert("cache_hit_rate".to_string(), Json::Num(self.cache_hit_rate));
        m.insert(
            "device_busy_share".to_string(),
            Json::Arr(self.device_busy_share.iter().map(|&v| Json::Num(v)).collect()),
        );
        m.insert("degraded_hits".to_string(), Json::Num(self.degraded_hits as f64));
        m.insert("degraded_bytes".to_string(), Json::Num(self.degraded_bytes));
        m.insert(
            "degraded_request_share".to_string(),
            Json::Num(self.degraded_request_share),
        );
        m.insert("retries".to_string(), Json::Num(self.retries as f64));
        m.insert("ledger_exact".to_string(), Json::Bool(self.ledger_exact));
        Json::Obj(m)
    }

    /// Human-readable table for the CLI.
    pub fn render(&self) -> String {
        let busy = self
            .device_busy_share
            .iter()
            .map(|v| format!("{v:.3}"))
            .collect::<Vec<_>>()
            .join(", ");
        let lines = [
            format!("{:<22}{}", "requests", self.requests),
            format!("{:<22}{}", "tokens", self.tokens),
            format!("{:<22}{:.1}", "total_us", self.total_us),
            format!("{:<22}{:.2}", "aggregate_tps", self.aggregate_tps),
            format!("{:<22}{:.1}", "queue_wait_p50_us", self.queue_wait_p50_us),
            format!("{:<22}{:.1}", "queue_wait_p95_us", self.queue_wait_p95_us),
            format!("{:<22}{:.1}", "queue_wait_p99_us", self.queue_wait_p99_us),
            format!("{:<22}{:.1}", "stall_demand_us", self.stall_demand_us),
            format!("{:<22}{:.1}", "stall_prefetch_us", self.stall_prefetch_us),
            format!("{:<22}{:.4}", "demand_stall_share", self.demand_stall_share),
            format!("{:<22}{:.2}", "mean_batch_peak", self.mean_batch_peak),
            format!("{:<22}{}", "max_batch_seen", self.max_batch_seen),
            format!("{:<22}{:.4}", "cache_hit_rate", self.cache_hit_rate),
            format!("{:<22}[{}]", "device_busy_share", busy),
            format!("{:<22}{}", "degraded_hits", self.degraded_hits),
            format!("{:<22}{:.1}", "degraded_bytes", self.degraded_bytes),
            format!(
                "{:<22}{:.4}",
                "degraded_request_share", self.degraded_request_share
            ),
            format!("{:<22}{}", "retries", self.retries),
            format!("{:<22}{}", "ledger_exact", self.ledger_exact),
        ];
        lines.join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sim::simulate_serving;

    fn tiny_spec(overlap: bool, seed: u64) -> SessionSpec {
        let system = SystemConfig::new(SystemKind::Floe).with_overlap(overlap);
        let mut p = SimParams::mixtral_on(RTX3090.clone(), system, 14.25);
        p.routing = RoutingModel { zipf_s: 1.2, stickiness: 0.5, seed: 7 };
        SessionSpec::from_params(
            &p,
            2,
            WorkloadSource::Spec(WorkloadSpec {
                n_requests: 4,
                arrival_rate_hz: 8.0,
                prompt_len: (4, 10),
                output_tokens: (4, 10),
                seed,
                slo_us: None,
            }),
        )
    }

    #[test]
    fn spec_roundtrips_through_bytes() {
        let spec = tiny_spec(true, 11);
        let tl = Timeline { spec, obs: None, cluster: None, replayable: true };
        let bytes = tl.to_bytes();
        let back = Timeline::from_bytes(&bytes).unwrap();
        assert!(back.replayable);
        assert!(back.obs.is_none());
        assert_eq!(back.spec.max_batch, 2);
        assert!(back.spec.system.overlap);
        assert_eq!(back.to_bytes(), bytes);

        // expanded-trace form
        let trace = tl.spec.trace();
        let spec2 = SessionSpec { workload: WorkloadSource::Trace(trace.clone()), ..tl.spec };
        let tl2 = Timeline { spec: spec2, obs: None, cluster: None, replayable: false };
        let bytes2 = tl2.to_bytes();
        let back2 = Timeline::from_bytes(&bytes2).unwrap();
        assert_eq!(back2.spec.trace(), trace);
        assert_eq!(back2.to_bytes(), bytes2);
    }

    /// Forward compatibility is refusal, not tolerance: a flag bit this
    /// build does not know marks an appended section it would misparse,
    /// so `from_bytes` must fail loudly — and artifacts written by this
    /// build must not set the quality bit unless the spec needs it,
    /// keeping the committed v1 corpus byte-identical.
    #[test]
    fn unknown_flag_bits_are_rejected() {
        let tl = Timeline { spec: tiny_spec(true, 11), obs: None, cluster: None, replayable: true };
        let mut bytes = tl.to_bytes();
        // flags live at offset 8..12, little-endian; bit 5 is unassigned
        assert_eq!(bytes[8] & (1 << 3), 0, "fallback-off spec set FLAG_QUALITY");
        assert_eq!(bytes[8] & (1 << 4), 0, "fault-free spec set FLAG_FAULTS");
        bytes[8] |= 1 << 5;
        let err = Timeline::from_bytes(&bytes).unwrap_err();
        assert!(
            err.contains("unknown timeline flag bits"),
            "unhelpful unknown-flag error: {err}"
        );
    }

    /// The quality section (FLAG_QUALITY) round-trips the little-tier
    /// carve and the SLO budgets in both workload encodings.
    #[test]
    fn quality_section_roundtrips() {
        let mut spec = tiny_spec(true, 11);
        spec.system = spec.system.clone().with_little_frac(0.1);
        if let WorkloadSource::Spec(w) = &mut spec.workload {
            w.slo_us = Some(2.0e6);
        }
        let tl = Timeline { spec, obs: None, cluster: None, replayable: true };
        let bytes = tl.to_bytes();
        assert_ne!(bytes[8] & (1 << 3), 0, "quality spec did not set FLAG_QUALITY");
        let back = Timeline::from_bytes(&bytes).unwrap();
        assert_eq!(back.spec.system.little_frac, 0.1);
        match &back.spec.workload {
            WorkloadSource::Spec(w) => assert_eq!(w.slo_us, Some(2.0e6)),
            WorkloadSource::Trace(_) => panic!("workload form changed"),
        }
        assert_eq!(back.to_bytes(), bytes);

        // trace form: per-request budgets, only some requests bounded
        let mut trace = tl.spec.trace();
        trace[0].req.slo_us = Some(1.5e6);
        let spec2 = SessionSpec { workload: WorkloadSource::Trace(trace.clone()), ..tl.spec };
        let tl2 = Timeline { spec: spec2, obs: None, cluster: None, replayable: false };
        let back2 = Timeline::from_bytes(&tl2.to_bytes()).unwrap();
        assert_eq!(back2.spec.trace(), trace);
        assert_eq!(back2.to_bytes(), tl2.to_bytes());
    }

    #[test]
    fn truncated_or_corrupt_bytes_error() {
        let tl = record(&tiny_spec(false, 3));
        let bytes = tl.to_bytes();
        assert!(Timeline::from_bytes(&bytes[..bytes.len() - 3]).is_err());
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(Timeline::from_bytes(&bad).is_err());
        let mut vers = bytes;
        vers[4] = 99;
        assert!(Timeline::from_bytes(&vers).is_err());
    }

    #[test]
    fn record_replay_roundtrip_is_bit_exact() {
        for overlap in [false, true] {
            let tl = record(&tiny_spec(overlap, 5));
            let obs = tl.obs.as_ref().unwrap();
            assert!(!obs.entries.is_empty());
            assert!(!obs.event_log.is_empty());
            assert_eq!(obs.event_log.len() % 17, 0);
            assert_eq!(obs.completions.len(), 4);
            // full byte round-trip, then bit-exact replay
            let back = Timeline::from_bytes(&tl.to_bytes()).unwrap();
            let fresh = replay(&back).unwrap();
            assert_eq!(fresh.event_log, obs.event_log);
            // spec-only artifact: replay is a pure determinism check
            let spec_only =
                Timeline { spec: tl.spec.clone(), obs: None, cluster: None, replayable: true };
            replay(&spec_only).unwrap();
        }
    }

    #[test]
    fn recording_wrapper_is_transparent() {
        // the recorded session must be bit-exact with the plain
        // (unrecorded) serving simulation — recording off is today's
        // behavior
        let spec = tiny_spec(true, 9);
        let rep = simulate_serving(&spec.params(), &spec.trace(), spec.max_batch).unwrap();
        let obs = record(&spec).obs.unwrap();
        assert_eq!(obs.total_us.to_bits(), rep.total_us.to_bits());
        assert_eq!(obs.completions.len(), rep.completions.len());
        assert_eq!(obs.max_batch_seen as usize, rep.max_batch_seen);
        assert_eq!(obs.cache_hit_rate.to_bits(), rep.cache_hit_rate.to_bits());
        assert_eq!(obs.stats.stall_us.to_bits(), rep.stats.stall_us.to_bits());
        assert_eq!(obs.stats.transferred_bytes.to_bits(), rep.stats.transferred_bytes.to_bits());
        assert_eq!(obs.stats.bus_transactions, rep.stats.bus_transactions);
        for (a, b) in obs.completions.iter().zip(&rep.completions) {
            assert_eq!(a.bits(), CompletionRecord::of(b).bits());
        }
    }

    #[test]
    fn tampered_artifact_reports_divergence() {
        let mut tl = record(&tiny_spec(true, 5));
        {
            let obs = tl.obs.as_mut().unwrap();
            let n = obs.event_log.len();
            obs.event_log[n - 1] ^= 1;
        }
        match replay(&tl) {
            Err(ReplayError::Diverged(d)) => {
                assert_eq!(d.channel, "event log");
                assert!(!d.recorded_context.is_empty());
            }
            other => panic!("expected divergence, got {other:?}"),
        }
        let live = Timeline { replayable: false, ..tl };
        assert!(matches!(replay(&live), Err(ReplayError::NotReplayable)));
    }

    #[test]
    fn tampered_completion_field_reports_divergence_with_both_histories() {
        // corrupt one numeric field of one completion record: the
        // replayer must surface the completions channel, point at the
        // exact entry and render both causal histories
        let mut tl = record(&tiny_spec(true, 5));
        let idx = {
            let obs = tl.obs.as_mut().unwrap();
            let idx = obs.completions.len() / 2;
            obs.completions[idx].queue_wait_us += 1.0;
            idx
        };
        match replay(&tl) {
            Err(ReplayError::Diverged(d)) => {
                assert_eq!(d.channel, "completions");
                assert_eq!(d.index, idx);
                assert!(!d.recorded_context.is_empty());
                assert!(!d.replayed_context.is_empty());
                assert_ne!(d.recorded, d.replayed);
                // the report renders end to end
                assert!(format!("{d}").contains("completions"));
            }
            other => panic!("expected divergence, got {other:?}"),
        }

        // an integer-field corruption diverges just the same
        let mut tl = record(&tiny_spec(false, 5));
        tl.obs.as_mut().unwrap().completions[0].tokens += 1;
        match replay(&tl) {
            Err(ReplayError::Diverged(d)) => {
                assert_eq!(d.channel, "completions");
                assert_eq!(d.index, 0);
            }
            other => panic!("expected divergence, got {other:?}"),
        }
    }

    fn tiny_cluster_shape(failure: Option<NodeFailure>) -> ClusterShape {
        ClusterShape {
            n_nodes: 2,
            devices_per_node: 1,
            shard: ShardPolicy::Layer,
            placement: ClusterPlacement::RoundRobin,
            vram_gb_total: 28.5,
            host_ram_gb: 64.0,
            failure,
            faults: Vec::new(),
            retry: None,
        }
    }

    #[test]
    fn cluster_artifact_roundtrips_and_replays_bit_exactly() {
        let base = tiny_spec(false, 5);
        let shape = tiny_cluster_shape(None);
        let tl = record_cluster(&base, &shape).unwrap();
        let bytes = tl.to_bytes();
        let back = Timeline::from_bytes(&bytes).unwrap();
        assert_eq!(back.to_bytes(), bytes);
        let ext = back.cluster.as_ref().unwrap();
        assert_eq!(ext.shape.n_nodes, 2);
        let obs = ext.obs.as_ref().unwrap();
        assert_eq!(obs.nodes.len(), 2);
        assert_eq!(obs.assignments.len(), 4);
        assert!(obs.nodes.iter().all(|n| !n.event_log.is_empty()));

        let fresh = replay_cluster(&back).unwrap();
        assert_eq!(fresh.total_us.to_bits(), obs.total_us.to_bits());

        // shape-only artifact: replay is a pure determinism check
        let shape_only = Timeline {
            spec: base,
            obs: None,
            cluster: Some(ClusterExt { shape, obs: None }),
            replayable: true,
        };
        let back = Timeline::from_bytes(&shape_only.to_bytes()).unwrap();
        replay_cluster(&back).unwrap();
        // the single-node replayer refuses cluster artifacts
        assert!(matches!(replay(&back), Err(ReplayError::NotCluster)));
        // and the cluster replayer refuses single-node ones
        let plain = record(&tiny_spec(false, 5));
        assert!(matches!(replay_cluster(&plain), Err(ReplayError::NotCluster)));
    }

    #[test]
    fn tampered_cluster_artifact_names_the_divergent_node() {
        let base = tiny_spec(false, 7);
        let mut tl = record_cluster(&base, &tiny_cluster_shape(None)).unwrap();
        {
            let obs = tl.cluster.as_mut().unwrap().obs.as_mut().unwrap();
            let log = &mut obs.nodes[1].event_log;
            let n = log.len();
            log[n - 1] ^= 1;
        }
        match replay_cluster(&tl) {
            Err(ReplayError::Diverged(d)) => {
                assert_eq!(d.channel, "node 1: event log");
                assert!(!d.recorded_context.is_empty());
                assert!(!d.replayed_context.is_empty());
            }
            other => panic!("expected divergence, got {other:?}"),
        }
    }

    #[test]
    fn failure_scenario_replays_bit_exactly() {
        let base = tiny_spec(false, 13);
        // drop node 1 early enough that it still holds work
        let t_fail = base.trace()[1].arrival_us + 1.0;
        let shape = tiny_cluster_shape(Some(NodeFailure { node: 1, t_us: t_fail }));
        let tl = record_cluster(&base, &shape).unwrap();
        let back = Timeline::from_bytes(&tl.to_bytes()).unwrap();
        let fresh = replay_cluster(&back).unwrap();
        let obs = back.cluster.unwrap().obs.unwrap();
        assert_eq!(fresh.errored, obs.errored);
        assert_eq!(fresh.rehomed_keys, obs.rehomed_keys);
        assert!(fresh.rehomed_keys > 0);
        assert!(!fresh.nodes[1].alive);
        // the dead node's log carries the NodeDown pop at its exact time
        let lines = super::decode_event_log(&fresh.nodes[1].event_log);
        assert!(lines.iter().any(|l| l.starts_with("NodeDown")), "{lines:?}");
        // an out-of-range failure node is malformed, not divergent
        let bad = Timeline {
            cluster: Some(ClusterExt {
                shape: tiny_cluster_shape(Some(NodeFailure { node: 9, t_us: 1.0 })),
                obs: None,
            }),
            ..record_cluster(&base, &tiny_cluster_shape(None)).unwrap()
        };
        assert!(matches!(replay_cluster(&bad), Err(ReplayError::Invalid(_))));
    }

    /// The fault-schedule section (FLAG_FAULTS) round-trips the fault
    /// list, the retry policy and the recovery counters, and a recorded
    /// fault schedule replays bit-exactly from the artifact alone.
    #[test]
    fn fault_schedule_roundtrips_and_replays_bit_exactly() {
        let base = tiny_spec(false, 13);
        let trace = base.trace();
        let mut shape = tiny_cluster_shape(None);
        let t_down = trace[1].arrival_us + 1.0;
        shape.faults = vec![
            Fault::LinkDegrade {
                link: LinkId::Pcie,
                factor: 0.25,
                t0_us: trace[0].arrival_us + 1.0,
                t1_us: t_down,
            },
            Fault::NodeDown { node: 1, t_us: t_down },
            Fault::NodeRejoin { node: 1, t_us: t_down + 500_000.0 },
        ];
        shape.retry = Some(RetryPolicy { max_attempts: 4, backoff_base_us: 25_000.0 });
        let tl = record_cluster(&base, &shape).unwrap();
        let bytes = tl.to_bytes();
        assert_ne!(bytes[8] & (1 << 4), 0, "fault schedule did not set FLAG_FAULTS");

        let back = Timeline::from_bytes(&bytes).unwrap();
        assert_eq!(back.to_bytes(), bytes);
        let ext = back.cluster.as_ref().unwrap();
        assert_eq!(ext.shape.faults, shape.faults);
        assert_eq!(ext.shape.retry, shape.retry);
        let obs = ext.obs.as_ref().unwrap();
        assert_eq!(obs.rejoins, 1);
        assert_eq!(obs.errored, 0, "a survivor existed: re-dispatch, not errors");

        // replays bit-exactly from the decoded artifact, counters
        // included (diff_cluster compares the recovery totals)
        let fresh = replay_cluster(&back).unwrap();
        assert_eq!(fresh.total_us.to_bits(), obs.total_us.to_bits());
        assert_eq!(fresh.redispatched, obs.redispatched);
        assert_eq!(fresh.rejoins, obs.rejoins);
        // the dead node's log carries the rejoin pop by name
        let lines = super::decode_event_log(&fresh.nodes[1].event_log);
        assert!(lines.iter().any(|l| l.starts_with("NodeRejoin")), "{lines:?}");

        // a malformed schedule is Invalid, not divergent
        let mut bad_shape = tiny_cluster_shape(None);
        bad_shape.faults = vec![Fault::NodeRejoin { node: 0, t_us: 1.0 }];
        let bad = Timeline {
            cluster: Some(ClusterExt { shape: bad_shape, obs: None }),
            ..record_cluster(&base, &tiny_cluster_shape(None)).unwrap()
        };
        assert!(matches!(replay_cluster(&bad), Err(ReplayError::Invalid(_))));
    }

    #[test]
    fn inspector_rederives_ledger_bit_exactly() {
        let tl = record(&tiny_spec(true, 5));
        let obs = tl.obs.unwrap();
        let rep = inspect(&obs);
        assert!(rep.ledger_exact, "completion fold must reproduce StoreStats globals");
        assert_eq!(rep.requests, 4);
        assert!(rep.tokens > 0);
        assert!(rep.aggregate_tps > 0.0);
        assert!(rep.queue_wait_p50_us <= rep.queue_wait_p95_us);
        assert!(rep.queue_wait_p95_us <= rep.queue_wait_p99_us);
        assert_eq!(rep.stall_demand_us.to_bits(), obs.stats.stall_demand_us.to_bits());
        assert_eq!(rep.device_busy_share.len(), obs.stats.per_device.len());
        // serializes through the shared JSON path without panicking
        let j = crate::util::json::write(&rep.to_json());
        assert!(j.contains("\"ledger_exact\":true"));
        assert!(!rep.render().is_empty());
    }
}

//! The real serving pipeline on the in-repo model (the paper's Fig 1(c)
//! wiring): request queue → interleaved continuous batching → per-layer
//! decode with the FloE prefetch pipeline.
//!
//! Compute is *real* (PJRT executions, wall-clock measured). The PCIe bus
//! does not exist on this box, so transfers run through the TransferEngine:
//! packing is real host work, the bus leg advances the ExpertStore's
//! virtual microsecond clock (hwsim::PCIE4). Reported decode time = real
//! compute + virtual stalls; both components are also reported separately.
//!
//! All expert residency — the byte-budgeted cache, eviction policy,
//! in-flight prefetch tracking, pinning and stall attribution — lives in
//! `store::ExpertStore` (DESIGN.md §3); this module only decides *what*
//! to move (via the dual predictors) and *how long* moves take (via the
//! TransferEngine), then reads the merged accounting back out.

use std::collections::HashMap;
use std::path::Path;

use anyhow::Result;

use crate::config::ExpertMode;
use crate::engine::{DecodeState, Engine, LayerEvent, StepObserver};
use crate::hwsim::PCIE4;
use crate::predictor::{InterPredictor, IntraPredictor};
use crate::sparsity;
use crate::store::{
    CacheStats, ExpertStore, Lookup, PlanMode, StallCause, StallSplit, TransferPlan,
    WallClock,
};
use crate::transfer::{CompactExpert, TransferEngine};

use super::policy::{SystemConfig, SystemKind};
use super::sched::{BackendSnapshot, Scheduler, SeqBackend, SeqStep, ServeCompletion};

/// Merged running statistics of the FloE pipeline: predictor quality
/// (tracked here) + residency/movement accounting (tracked by the store).
#[derive(Debug, Default, Clone)]
pub struct PipelineStats {
    pub inter_hits: u64,
    pub inter_total: u64,
    pub intra_recall_sum: f64,
    pub intra_recall_n: u64,
    pub demand_fetches: u64,
    pub prefetches: u64,
    pub stall_us: f64,
    pub stall_demand_us: f64,
    pub stall_prefetch_us: f64,
    pub transferred_bytes: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
}

impl PipelineStats {
    pub fn inter_hit_rate(&self) -> f64 {
        if self.inter_total == 0 {
            0.0
        } else {
            self.inter_hits as f64 / self.inter_total as f64
        }
    }
    pub fn intra_recall(&self) -> f64 {
        if self.intra_recall_n == 0 {
            0.0
        } else {
            self.intra_recall_sum / self.intra_recall_n as f64
        }
    }
    pub fn cache_hit_rate(&self) -> f64 {
        let t = self.cache_hits + self.cache_misses;
        if t == 0 {
            0.0
        } else {
            self.cache_hits as f64 / t as f64
        }
    }
}

/// Predictor-quality counters (the non-residency half of PipelineStats).
#[derive(Debug, Default, Clone)]
struct PredictorStats {
    inter_hits: u64,
    inter_total: u64,
    intra_recall_sum: f64,
    intra_recall_n: u64,
}

/// The FloE coordination state threaded through decode as a StepObserver.
pub struct FloePipeline {
    system: SystemConfig,
    n_layers: usize,
    top_k: usize,
    /// per-boundary inter-expert predictors (layer i -> i+1)
    inter: Vec<InterPredictor>,
    /// lazily built per-(layer, expert) reuse predictors
    intra: HashMap<(usize, usize), IntraPredictor>,
    /// compact-layout transferable weights per expert
    compact: HashMap<(usize, usize), CompactExpert>,
    /// per-(layer, expert) thresholds at the configured level
    thresholds: HashMap<(usize, usize), f32>,
    /// residency: cache + prefetch pipeline + virtual clock. Payload is
    /// the predicted channel mask, scored for recall on consumption.
    store: ExpertStore<Vec<bool>>,
    xfer: TransferEngine,
    /// what we predicted for each layer (for precision accounting)
    predicted: Vec<Vec<usize>>,
    /// measured average per-layer compute, used to advance the clock
    pub layer_compute_us: f64,
    pred: PredictorStats,
}

impl FloePipeline {
    pub fn new(
        engine: &Engine,
        system: SystemConfig,
        vram_expert_budget_bytes: usize,
    ) -> Result<Self> {
        let w = &engine.w;
        let c = &w.cfg;
        let mut inter = Vec::new();
        for l in 0..c.n_layers - 1 {
            inter.push(InterPredictor::from_weights(w, l)?);
        }
        let mut thresholds = HashMap::new();
        let mut compact = HashMap::new();
        for l in 0..c.n_layers {
            for e in 0..c.n_experts {
                thresholds.insert(
                    (l, e),
                    w.threshold("up", l, e, system.sparsity)?,
                );
                let ew = w.expert_native(l, e)?;
                compact.insert(
                    (l, e),
                    CompactExpert::build(&ew.wg_t.data, &ew.wd.data, c.d_ff, c.d_model),
                );
            }
        }
        Ok(FloePipeline {
            n_layers: c.n_layers,
            top_k: c.top_k,
            inter,
            intra: HashMap::new(),
            compact,
            thresholds,
            // placement-aware: per-device budgets/buses from the system's
            // --devices/--shard-policy configuration (1 device default)
            store: ExpertStore::with_placement(
                system.placement(PCIE4),
                vram_expert_budget_bytes,
                system.residency,
                system.sparsity_decay,
            ),
            // 1 packing thread: inline packing avoids per-call thread-spawn
            // overhead at tiny-model transfer sizes (see transfer.rs)
            xfer: TransferEngine::new(PCIE4, 1, 2),
            predicted: vec![Vec::new(); c.n_layers],
            layer_compute_us: 200.0,
            pred: PredictorStats::default(),
            system,
        })
    }

    fn intra_predictor<'a>(
        intra: &'a mut HashMap<(usize, usize), IntraPredictor>,
        w: &crate::model::Weights,
        key: (usize, usize),
    ) -> &'a IntraPredictor {
        intra.entry(key).or_insert_with(|| {
            IntraPredictor::from_quant(&w.up_q(key.0, key.1).unwrap())
        })
    }

    /// Bytes a compact transfer of `n_channels` records moves.
    fn record_bytes(&self, key: (usize, usize)) -> usize {
        self.compact[&key].record_bytes()
    }

    pub fn observe(&mut self, w: &crate::model::Weights, ev: &LayerEvent<'_>) {
        let l = ev.layer;
        // layer boundary: let the store act on measured popularity
        // (no-op unless the placement is Balanced / replicating)
        self.store.rebalance_tick();
        // ---- account inter-predictor precision for this layer ----
        if !self.predicted[l].is_empty() {
            for (e, _) in ev.routed {
                self.pred.inter_total += 1;
                if self.predicted[l].contains(e) {
                    self.pred.inter_hits += 1;
                }
            }
        }

        // ---- charge this layer's experts (cache / inflight / demand) ----
        let is_floe = self.system.kind == SystemKind::Floe;
        for &(e, _) in ev.routed {
            let key = (l, e);
            if !is_floe {
                // baseline transfer semantics: full expert at the policy's
                // precision, no channel selection, no next-layer overlap
                match self.store.lookup(key) {
                    Lookup::Local(_) => {}
                    Lookup::Remote(from) => {
                        // a spilled copy on a peer device: pull it over
                        // the GPU↔GPU link instead of refetching
                        let ready = self.store.peer_fetch(key, from);
                        self.store.stall_until_for(ready, StallCause::Demand);
                    }
                    Lookup::RemoteNode(from) => {
                        // resident only on a device of another node: pull
                        // over the network link (a single-node serving box
                        // never resolves here)
                        let ready = self.store.net_fetch(key, from);
                        self.store.stall_until_for(ready, StallCause::Demand);
                    }
                    Lookup::Degraded(_) => {
                        unreachable!("lookup never returns Degraded")
                    }
                    Lookup::Miss => {
                        let dm = self.compact[&key].record_len / 2;
                        let f = self.compact[&key].f;
                        let bytes = match self.system.kind {
                            SystemKind::NaiveOffload | SystemKind::Fiddler => {
                                3.0 * (dm * f) as f64 * 2.0
                            }
                            SystemKind::AdvancedOffload => {
                                3.0 * (dm * f) as f64 * self.system.quant_bits as f64
                                    / 8.0
                            }
                            SystemKind::GpuResident => 3.0 * (dm * f) as f64 * 0.25,
                            SystemKind::Floe => unreachable!(),
                        };
                        if self.system.kind == SystemKind::GpuResident {
                            self.store.record_demand_for(key);
                        } else {
                            let ready = self
                                .store
                                .demand_fetch_for(key, PCIE4.copy_us(bytes), bytes);
                            self.store.stall_until_for(ready, StallCause::Demand);
                        }
                        self.store.admit(key, bytes as usize);
                    }
                }
                continue;
            }
            let t = self.thresholds[&key];
            // true channel mask from the *current* hidden state
            let truth = {
                let ip = Self::intra_predictor(&mut self.intra, w, key);
                let v = ip.channel_magnitudes(ev.h_mid);
                sparsity::mask_from_activations(&v, t)
            };
            match self.store.lookup(key) {
                Lookup::Local(_) => {}
                Lookup::Remote(from) => {
                    // full cached copy on a peer device — no channel
                    // subset approximation, just the p2p move
                    let ready = self.store.peer_fetch(key, from);
                    self.store.stall_until_for(ready, StallCause::Demand);
                }
                Lookup::RemoteNode(from) => {
                    // cross-node copy: the network pull is the whole
                    // story — no channel-subset approximation either
                    let ready = self.store.net_fetch(key, from);
                    self.store.stall_until_for(ready, StallCause::Demand);
                }
                Lookup::Degraded(_) => {
                    unreachable!("lookup never returns Degraded")
                }
                Lookup::Miss => {
                    let taken = self.store.take_inflight(key);
                    let (ready_at, prefetched_mask) = match taken {
                        Some((done, mask)) => (done, Some(mask)),
                        None => {
                            // demand fetch of the true channels (stalling)
                            let sel: Vec<usize> = truth
                                .iter()
                                .enumerate()
                                .filter(|(_, m)| **m)
                                .map(|(j, _)| j)
                                .collect();
                            let rep = self.xfer.transfer_compact(
                                &self.compact[&key],
                                &sel,
                                self.system.chunk_channels,
                            );
                            let done = self
                                .store
                                .demand_fetch_for(key, rep.total_us, rep.bytes as f64);
                            (done, None)
                        }
                    };
                    let cause = if let Some(mask) = prefetched_mask {
                        // intra-recall accounting. Per the paper (§3.3.2)
                        // the kernel proceeds with the *prefetched*
                        // channel set — missed channels are an
                        // approximation, not a reload; the recall stat
                        // quantifies it (paper: ~0.95).
                        let rec = sparsity::mask_recall(&mask, &truth);
                        self.pred.intra_recall_sum += rec;
                        self.pred.intra_recall_n += 1;
                        // predicted right, but the transfer landed late
                        StallCause::PrefetchMiss
                    } else {
                        StallCause::Demand
                    };
                    self.store.stall_until_for(ready_at, cause);
                    let bytes = sparsity::active_count(&truth) * self.record_bytes(key);
                    self.store.admit(key, bytes);
                }
            }
        }

        // ---- predict + prefetch layer l+1 (FloE only): one transfer
        // plan per destination device, coalesced when the placement
        // allows it ----
        if is_floe && l + 1 < self.n_layers {
            let preds = self.inter[l].predict(ev.h_mid, self.top_k);
            self.predicted[l + 1] = preds.clone();
            let mode = if self.system.coalesce {
                PlanMode::Coalesced
            } else {
                PlanMode::Overlapped
            };
            let mut plans: Vec<TransferPlan<Vec<bool>>> = (0..self.store.n_devices())
                .map(|dst| TransferPlan::to(dst, mode))
                .collect();
            for e in preds {
                let key = (l + 1, e);
                if self.store.contains(key) || self.store.inflight(key) {
                    continue;
                }
                let t = self.thresholds[&key];
                let mask = {
                    let ip = Self::intra_predictor(&mut self.intra, w, key);
                    ip.predict_mask(ev.h_mid, t, self.system.intra_margin as f32)
                };
                let sel: Vec<usize> = mask
                    .iter()
                    .enumerate()
                    .filter(|(_, m)| **m)
                    .map(|(j, _)| j)
                    .collect();
                let rep = self.xfer.transfer_compact(
                    &self.compact[&key],
                    &sel,
                    self.system.chunk_channels,
                );
                // overlaps with compute: queue on the destination bus,
                // track in flight, pin any resident copy until consumed
                plans[self.store.home(key)].push(
                    key,
                    rep.bytes as f64,
                    rep.total_us,
                    PCIE4.api_us,
                    mask,
                );
            }
            for plan in plans {
                if !plan.is_empty() {
                    self.store.submit(plan);
                }
            }
        }

        // advance the virtual clock by this layer's compute
        self.store.tick(self.layer_compute_us);
    }

    /// Merged predictor + residency statistics.
    pub fn stats(&self) -> PipelineStats {
        let st = self.store.stats();
        let cs = self.store.cache_stats();
        PipelineStats {
            inter_hits: self.pred.inter_hits,
            inter_total: self.pred.inter_total,
            intra_recall_sum: self.pred.intra_recall_sum,
            intra_recall_n: self.pred.intra_recall_n,
            demand_fetches: st.demand_fetches,
            prefetches: st.prefetches,
            stall_us: st.stall_us,
            stall_demand_us: st.stall_demand_us,
            stall_prefetch_us: st.stall_prefetch_us,
            transferred_bytes: st.transferred_bytes as u64,
            cache_hits: cs.hits,
            cache_misses: cs.misses,
        }
    }

    /// Accumulated virtual stall time, microseconds.
    pub fn stall_us(&self) -> f64 {
        self.store.stats().stall_us
    }

    /// Charge subsequent stalls to request `id` (serving attribution).
    pub fn set_attribution(&mut self, id: u64) {
        self.store.set_attribution(id);
    }

    /// Attributed stall decomposition for request `id`.
    pub fn stall_split_of(&self, id: u64) -> StallSplit {
        self.store.stall_split_of(id)
    }

    /// Retire request `id`'s attribution entry (see ExpertStore).
    pub fn take_attribution(&mut self, id: u64) -> StallSplit {
        self.store.take_attribution(id)
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.store.cache_stats()
    }
    pub fn store(&self) -> &ExpertStore<Vec<bool>> {
        &self.store
    }
    pub fn virtual_time_us(&self) -> f64 {
        self.store.now_us()
    }
}

/// The pipeline as a StepObserver: `LayerEvent::seq` indexes the decode
/// batch, so each event is charged to its owning request's attribution
/// id before the pipeline acts on it. The single adapter serves both
/// prefill (a batch of one, `ids = [request id]`) and batched decode.
struct BatchObserver<'a> {
    pipeline: &'a mut FloePipeline,
    weights: &'a std::sync::Arc<crate::model::Weights>,
    ids: &'a [u64],
}

impl StepObserver for BatchObserver<'_> {
    fn on_layer(&mut self, ev: &LayerEvent<'_>) {
        self.pipeline.set_attribution(self.ids[ev.seq]);
        self.pipeline.observe(self.weights, ev);
    }
}

// ---------------------------------------------------------------- serving

#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub max_tokens: usize,
    pub temperature: f32,
    pub seed: u64,
    /// Per-request latency budget in virtual µs (SLO), measured from
    /// admission. When set *and* the little tier is carved
    /// (`--little-frac > 0`), a boundary whose predicted demand-fetch
    /// completion would bust the budget resolves to the degraded
    /// little-tier variant instead of stalling (DESIGN.md §11). `None`
    /// (the default everywhere) keeps every path bit-exact with
    /// pre-quality builds.
    pub slo_us: Option<f64>,
}

#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub text: Vec<u8>,
    /// real wall-clock seconds spent in prefill / decode
    pub prefill_s: f64,
    pub decode_s: f64,
    /// virtual stall time charged by the transfer model, seconds
    pub stall_virtual_s: f64,
    pub tokens: usize,
}

impl Completion {
    /// decode TPS counting real compute + modeled PCIe stalls
    pub fn effective_tps(&self) -> f64 {
        self.tokens as f64 / (self.decode_s + self.stall_virtual_s).max(1e-9)
    }
    pub fn compute_tps(&self) -> f64 {
        self.tokens as f64 / self.decode_s.max(1e-9)
    }

    fn from_serve(c: ServeCompletion) -> Completion {
        Completion {
            id: c.id,
            tokens: c.tokens,
            text: c.text,
            prefill_s: c.prefill_us / 1e6,
            decode_s: c.decode_us / 1e6,
            stall_virtual_s: c.stall.total_us() / 1e6,
        }
    }
}

/// The coordinator: owns the engine + pipeline and executes sequences
/// one token at a time through the `SeqBackend` interface, so the
/// continuous-batching `Scheduler` (sched.rs) can interleave any number
/// of in-flight requests over the single non-`Send` PJRT engine.
pub struct Coordinator {
    pub engine: Engine,
    pub pipeline: FloePipeline,
    mode: ExpertMode,
    /// wall epoch for the scheduler's time base (queue waits, latencies)
    epoch: std::time::Instant,
}

impl Coordinator {
    pub fn new(art_dir: &Path, system: SystemConfig, vram_budget_bytes: usize) -> Result<Self> {
        let engine = Engine::load(art_dir)?;
        let pipeline = FloePipeline::new(&engine, system.clone(), vram_budget_bytes)?;
        let mode = system.expert_mode();
        Ok(Coordinator {
            engine,
            pipeline,
            mode,
            epoch: std::time::Instant::now(),
        })
    }

    /// Calibrate the virtual clock's per-layer compute from a real run.
    pub fn calibrate_layer_time(&mut self) -> Result<()> {
        let mut st = DecodeState::new(&self.engine.w)?;
        let wall = WallClock::start();
        let n = 8;
        for i in 0..n {
            self.engine.decode_token(
                &mut st,
                b'a' + (i as u8 % 26),
                self.mode,
                &mut crate::engine::NoObserver,
            )?;
        }
        let us = wall.elapsed_s() * 1e6 / (n * self.engine.w.cfg.n_layers) as f64;
        self.pipeline.layer_compute_us = us;
        Ok(())
    }

    /// Serve a set of requests with interleaved decoding (one scheduler
    /// batch admitting everything at once). Returns completions in
    /// arrival order.
    pub fn run_batch(&mut self, requests: &[Request]) -> Result<Vec<Completion>> {
        let mut sched = Scheduler::new(&mut *self, requests.len().max(1));
        for r in requests {
            sched.enqueue(r.clone());
        }
        let served = sched.drain();
        if let Some(c) = served.iter().find(|c| c.error.is_some()) {
            anyhow::bail!(
                "request {} failed: {}",
                c.id,
                c.error.as_deref().unwrap_or("unknown")
            );
        }
        let mut done: Vec<Completion> =
            served.into_iter().map(Completion::from_serve).collect();
        done.sort_by_key(|c| c.id);
        Ok(done)
    }
}

/// Per-request decode state for the real engine: KV cache + last logits
/// + the request's sampler RNG.
pub struct EngineSeq {
    id: u64,
    st: DecodeState,
    logits: Vec<f32>,
    rng: crate::util::rng::Rng,
    max_tokens: usize,
    temperature: f32,
    n_out: usize,
}

impl SeqBackend for Coordinator {
    type Seq = EngineSeq;

    fn now_us(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64() * 1e6
    }

    fn start(&mut self, r: &Request) -> Result<(EngineSeq, f64)> {
        // no stale-ledger drop needed: the scheduler retires every id's
        // attribution entry when its request completes (`retire`), so
        // repeated run_batch calls reusing ids 0..n start clean
        self.pipeline.set_attribution(r.id);
        let mut st = DecodeState::new(&self.engine.w)?;
        let wall = WallClock::start();
        let weights = std::sync::Arc::clone(&self.engine.w);
        let mut obs = BatchObserver {
            pipeline: &mut self.pipeline,
            weights: &weights,
            ids: std::slice::from_ref(&r.id),
        };
        let logits = self.engine.prefill(&mut st, &r.prompt, self.mode, &mut obs)?;
        Ok((
            EngineSeq {
                id: r.id,
                st,
                logits,
                rng: crate::util::rng::Rng::new(r.seed),
                max_tokens: r.max_tokens,
                temperature: r.temperature,
                n_out: 0,
            },
            wall.elapsed_s() * 1e6,
        ))
    }

    fn step(&mut self, a: &mut EngineSeq) -> Result<SeqStep> {
        // a batch of one through the boundary-synchronous path: one code
        // path for sequential and batched decode, no drift
        self.step_batch(&mut [a]).pop().expect("batch of one")
    }

    /// One token boundary for the whole batch: every continuing sequence
    /// steps through ONE `Engine::decode_batch` call, so same-boundary
    /// expert GEMVs are grouped and each distinct expert's weights are
    /// uploaded/materialized once per boundary instead of once per
    /// request. The boundary's wall compute is attributed evenly across
    /// the participating sequences (the work is genuinely shared — a
    /// per-sequence split of a fused kernel is not observable); virtual
    /// stalls keep exact per-request attribution via `LayerEvent::seq`.
    fn step_batch(&mut self, seqs: &mut [&mut EngineSeq]) -> Vec<Result<SeqStep>> {
        let max_seq = self.engine.w.cfg.max_seq;
        // sequential semantics per slot: the token emitted at this
        // boundary is sampled from last boundary's logits
        let sampled: Vec<(u8, bool)> = seqs
            .iter_mut()
            .map(|a| {
                let tok =
                    crate::engine::sampler::sample(&a.logits, a.temperature, &mut a.rng);
                a.n_out += 1;
                let finished = a.n_out >= a.max_tokens || a.st.pos + 1 >= max_seq;
                (tok, finished)
            })
            .collect();
        let cont: Vec<usize> =
            (0..seqs.len()).filter(|&i| !sampled[i].1).collect();
        if cont.is_empty() {
            return sampled
                .into_iter()
                .map(|(tok, finished)| {
                    Ok(SeqStep { token: Some(tok), finished, compute_us: 0.0 })
                })
                .collect();
        }
        let ids: Vec<u64> = cont.iter().map(|&i| seqs[i].id).collect();
        let toks: Vec<u8> = cont.iter().map(|&i| sampled[i].0).collect();
        let weights = std::sync::Arc::clone(&self.engine.w);
        let wall = WallClock::start();
        let decoded = {
            let mut obs = BatchObserver {
                pipeline: &mut self.pipeline,
                weights: &weights,
                ids: &ids,
            };
            let mut states: Vec<&mut DecodeState> = seqs
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| !sampled[*i].1)
                .map(|(_, a)| &mut a.st)
                .collect();
            self.engine.decode_batch(&mut states, &toks, self.mode, &mut obs)
        };
        let per_seq_us = wall.elapsed_s() * 1e6 / cont.len() as f64;
        match decoded {
            Ok(mut logits) => {
                for (k, &i) in cont.iter().enumerate() {
                    seqs[i].logits = std::mem::take(&mut logits[k]);
                }
                sampled
                    .into_iter()
                    .map(|(tok, finished)| {
                        Ok(SeqStep {
                            token: Some(tok),
                            finished,
                            compute_us: if finished { 0.0 } else { per_seq_us },
                        })
                    })
                    .collect()
            }
            Err(e) => {
                // Engine-level batch failure: one bad sequence must never
                // take its batchmates down (the scheduler invariant). With
                // a single continuing sequence there is no batchmate to
                // protect — surface the error directly instead of
                // re-executing the deterministic failure.
                if cont.len() == 1 {
                    let mut e = Some(e);
                    return sampled
                        .into_iter()
                        .map(|(tok, finished)| {
                            if finished {
                                Ok(SeqStep {
                                    token: Some(tok),
                                    finished,
                                    compute_us: 0.0,
                                })
                            } else {
                                Err(e.take().expect("single continuing slot"))
                            }
                        })
                        .collect();
                }
                // Otherwise re-step each continuing sequence ALONE and let
                // only the faulty one surface its own error. Re-execution
                // of a partially-decoded token is value-idempotent (pos/x
                // commit only after full success; KV writes at `pos`
                // overwrite the same deterministic values). Caveat:
                // re-observed layers re-charge the SHARED virtual
                // transfer clock, so stall/queue-wait accounting for
                // in-flight requests is perturbed at this boundary — a
                // bounded accounting distortion accepted to preserve
                // request isolation on a failure path.
                let mut out: Vec<Result<SeqStep>> = Vec::with_capacity(seqs.len());
                for (i, &(tok, finished)) in sampled.iter().enumerate() {
                    if finished {
                        out.push(Ok(SeqStep {
                            token: Some(tok),
                            finished,
                            compute_us: 0.0,
                        }));
                        continue;
                    }
                    let id = seqs[i].id;
                    let wall = WallClock::start();
                    let solo = {
                        let mut obs = BatchObserver {
                            pipeline: &mut self.pipeline,
                            weights: &weights,
                            ids: std::slice::from_ref(&id),
                        };
                        self.engine.decode_batch(
                            &mut [&mut seqs[i].st],
                            &[tok],
                            self.mode,
                            &mut obs,
                        )
                    };
                    out.push(match solo {
                        Ok(mut l) => {
                            seqs[i].logits = l.pop().expect("batch of one");
                            Ok(SeqStep {
                                token: Some(tok),
                                finished: false,
                                compute_us: wall.elapsed_s() * 1e6,
                            })
                        }
                        Err(e) => Err(e),
                    });
                }
                out
            }
        }
    }

    fn stalls_of(&self, id: u64) -> StallSplit {
        self.pipeline.stall_split_of(id)
    }

    fn retire(&mut self, id: u64) -> StallSplit {
        // fold the finished request's ledger entry into `retired` so the
        // attribution map stays bounded by the in-flight batch
        self.pipeline.take_attribution(id)
    }

    fn snapshot(&self) -> Option<BackendSnapshot> {
        let store = self.pipeline.store();
        Some(BackendSnapshot {
            stats: store.stats().clone(),
            cache_hit_rate: store.cache_stats().hit_rate(),
        })
    }
}

#[cfg(test)]
mod tests {
    // FloePipeline logic tests that need no artifacts live in
    // rust/tests/integration_coordinator.rs (they need real weights).
    // Store/residency behavior is unit-tested policy-by-policy in
    // src/store/.
}

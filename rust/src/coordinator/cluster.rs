//! Cluster tier: multi-node serving above the ExpertStore (DESIGN.md §10).
//!
//! A cluster is N node coordinators — each an independent
//! `SimServeBackend` with its own event heap, expert store and host RAM
//! pool — joined by a deterministic cluster clock. Requests are
//! data-parallel: the `ClusterRouter` assigns each workload arrival to
//! exactly one node (round-robin, least-loaded, or expert-affinity via
//! the store's popularity tracker) and that node serves the request end
//! to end. Nodes never share GPU state; what crosses the network link is
//! expert weights — cross-node demand pulls (`Lookup::RemoteNode`, a
//! store concern) and failure re-homing copies (driven from here).
//!
//! Determinism contract: nodes are stepped in a fixed merge order — the
//! alive node with the earliest virtual clock, ties broken by the lowest
//! node id — and cluster-level events (arrivals, the failure instant)
//! partition the timeline into windows inside which nodes advance
//! independently. Because node backends share nothing, per-node results
//! are invariant to interleaving; the merge order only pins *placement*
//! decisions, which read cluster state (queue depths, popularity mass)
//! at the event instant. Two runs of the same spec and workload produce
//! byte-identical per-node event logs, completions and store stats —
//! the FLTL cluster extension records and replays exactly these.
//!
//! Fault schedules (DESIGN.md §12): a `ClusterSpec` carries a list of
//! timed `Fault`s — `NodeDown` (generalizing the single legacy
//! `NodeFailure`), `NodeRejoin`, `DeviceDown` (one device of one node,
//! global index) and `LinkDegrade` (a PCIe/NET bandwidth window) — that
//! fire on the deterministic cluster clock exactly like arrivals. A
//! `NodeDown` with survivors *re-dispatches* the dead node's in-flight
//! requests: sequences are aborted without completions and the original
//! requests re-enqueue on survivors with their original arrival stamps,
//! restarting value-idempotently from their per-request seeds — every
//! request retires exactly once and nothing errors. Only when no
//! survivor exists do actives retire as error completions (with their
//! pre-fault tokens and a structured `FaultCause`). Still-queued
//! requests re-route round-robin; the dead node's host-pool shard is
//! re-homed: survivors split its stageable keys round-robin in sorted
//! key order and pull them over the network link
//! (`ExpertStore::net_restore`) so later demand fetches pay PCIe, not
//! the 10-100x slower cross-node link. A `NodeRejoin` wipes the
//! returning node (its memory died with it), restocks its host pool
//! over the network and re-enters it into the placement rotation.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::store::{FaultCause, LinkId, LinkWindow, RetryPolicy, ShardPolicy, StoreStats};
use crate::workload::TimedRequest;

use super::sched::{Scheduler, SeqBackend, ServeCompletion};
use super::sim::{predicted_first_expert, SimParams, SimServeBackend};

/// How the cluster router assigns an arriving request to a node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterPlacement {
    /// Arrival order modulo the alive-node count.
    RoundRobin,
    /// The node with the fewest in-flight plus queued requests.
    LeastLoaded,
    /// The node whose popularity tracker carries the most mass for the
    /// request's predicted first routed expert (ties fall back to
    /// least-loaded): requests chase the node already hot for their
    /// experts, so cross-node pulls and cold demand fetches shrink.
    ExpertAffinity,
}

impl ClusterPlacement {
    pub const ALL: [ClusterPlacement; 3] = [
        ClusterPlacement::RoundRobin,
        ClusterPlacement::LeastLoaded,
        ClusterPlacement::ExpertAffinity,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ClusterPlacement::RoundRobin => "round-robin",
            ClusterPlacement::LeastLoaded => "least-loaded",
            ClusterPlacement::ExpertAffinity => "expert-affinity",
        }
    }

    /// Serialization tag (FLTL cluster extension).
    pub fn tag(self) -> u8 {
        match self {
            ClusterPlacement::RoundRobin => 0,
            ClusterPlacement::LeastLoaded => 1,
            ClusterPlacement::ExpertAffinity => 2,
        }
    }

    pub fn from_tag(tag: u8) -> Option<Self> {
        Some(match tag {
            0 => ClusterPlacement::RoundRobin,
            1 => ClusterPlacement::LeastLoaded,
            2 => ClusterPlacement::ExpertAffinity,
            _ => return None,
        })
    }
}

/// Failure injection: `node` drops out of the cluster at `t_us`.
/// Legacy single-fault form — translated into `Fault::NodeDown` by the
/// driver; `ClusterSpec::faults` is the general schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeFailure {
    pub node: usize,
    pub t_us: f64,
}

/// One timed fault in a deterministic schedule (DESIGN.md §12). Times
/// are absolute on the cluster clock; faults fire at the first token
/// boundary at or after their stamp, exactly like arrivals.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fault {
    /// Cluster node `node` drops: in-flight requests re-dispatch to
    /// survivors (or error with `FaultCause::NodeDown` when none exist),
    /// queued requests re-route, the host-pool shard re-homes.
    NodeDown { node: usize, t_us: f64 },
    /// A previously-dropped node returns: its memory is wiped, the host
    /// pool restocks over the network, and placement resumes routing to
    /// it. Must follow a `NodeDown` of the same node at an earlier time.
    NodeRejoin { node: usize, t_us: f64 },
    /// One device drops, by *global* index (`node = dev / devices_per_node`,
    /// local id `dev % devices_per_node`): its in-flight transfers are
    /// torn down and its resident experts re-home to surviving peer
    /// devices hottest-first. Requires `devices_per_node >= 2`.
    DeviceDown { dev: usize, t_us: f64 },
    /// A bandwidth window on a transfer link, cluster-wide: every node's
    /// demand fetches over `link` stretch by `1/factor` while
    /// `t0_us <= t < t1_us`; `factor == 0` is a full outage gated by the
    /// retry/backoff policy.
    LinkDegrade { link: LinkId, factor: f64, t0_us: f64, t1_us: f64 },
}

impl Fault {
    /// When the fault activates on the cluster clock (a window's start).
    pub fn t_us(&self) -> f64 {
        match self {
            Fault::NodeDown { t_us, .. }
            | Fault::NodeRejoin { t_us, .. }
            | Fault::DeviceDown { t_us, .. } => *t_us,
            Fault::LinkDegrade { t0_us, .. } => *t0_us,
        }
    }

    /// Serialization tag (FLTL faults section).
    pub fn tag(&self) -> u8 {
        match self {
            Fault::DeviceDown { .. } => 0,
            Fault::LinkDegrade { .. } => 1,
            Fault::NodeDown { .. } => 2,
            Fault::NodeRejoin { .. } => 3,
        }
    }
}

/// One cluster configuration: N identical nodes of `devices_per_node`
/// devices each, splitting `vram_gb_total` evenly across every device in
/// the cluster (the fixed-aggregate-VRAM comparisons hold this constant
/// while varying the node count).
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub n_nodes: usize,
    pub devices_per_node: usize,
    /// intra-node expert→device assignment (multi-device nodes).
    pub shard: ShardPolicy,
    pub placement: ClusterPlacement,
    /// aggregate expert-cache VRAM across the whole cluster, GB.
    pub vram_gb_total: f64,
    /// per-node host RAM pool for staged expert copies, GB.
    pub host_ram_gb: f64,
    /// per-node continuous-batching cap.
    pub max_batch: usize,
    pub failure: Option<NodeFailure>,
    /// deterministic fault schedule (DESIGN.md §12); fires in time
    /// order, ties broken by list position. Empty = fault-free, and the
    /// session is bit-identical to a spec without the field.
    pub faults: Vec<Fault>,
    /// bounded-backoff retry policy for demand fetches blocked by a link
    /// outage; `None` (default) is fail-fast.
    pub retry: Option<RetryPolicy>,
}

impl ClusterSpec {
    pub fn new(n_nodes: usize, devices_per_node: usize, vram_gb_total: f64) -> Self {
        ClusterSpec {
            n_nodes: n_nodes.max(1),
            devices_per_node: devices_per_node.max(1),
            shard: ShardPolicy::Layer,
            placement: ClusterPlacement::RoundRobin,
            vram_gb_total,
            host_ram_gb: 64.0,
            max_batch: 4,
            failure: None,
            faults: Vec::new(),
            retry: None,
        }
    }

    pub fn with_placement(mut self, placement: ClusterPlacement) -> Self {
        self.placement = placement;
        self
    }

    pub fn with_failure(mut self, node: usize, t_us: f64) -> Self {
        self.failure = Some(NodeFailure { node, t_us });
        self
    }

    pub fn with_faults(mut self, faults: Vec<Fault>) -> Self {
        self.faults = faults;
        self
    }

    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = Some(retry);
        self
    }
}

/// Everything one node reports back from a cluster session — the unit
/// the FLTL cluster extension records per node and replay compares.
#[derive(Debug, Clone)]
pub struct NodeObs {
    pub node: usize,
    /// completions this node retired, in retirement order (error
    /// completions from a failure included).
    pub completions: Vec<ServeCompletion>,
    pub admitted_order: Vec<u64>,
    /// event-core pop log (non-empty only on traced runs).
    pub event_log: Vec<u8>,
    pub stats: StoreStats,
    pub cache_hit_rate: f64,
    /// this node's final virtual clock. A dead node freezes at the
    /// boundary that observed its failure: like arrivals, failures take
    /// effect at the first token boundary at or after their stamp.
    pub total_us: f64,
    pub max_batch_seen: usize,
    pub net_pulls: u64,
    pub net_bytes: f64,
    pub alive: bool,
}

/// A finished cluster session.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub nodes: Vec<NodeObs>,
    /// request id → node that finally served it (re-routed requests
    /// record their survivor node).
    pub assignments: Vec<(u64, usize)>,
    /// cluster makespan: the latest alive node clock.
    pub total_us: f64,
    /// error completions retired by faults: fail-fast transfer faults
    /// (a link outage with no retry policy) and node drops with no
    /// survivor — with survivors, actives re-dispatch instead.
    pub errored: usize,
    /// dead-node host-pool keys re-homed onto survivors.
    pub rehomed_keys: usize,
    /// in-flight requests re-dispatched to survivors by node drops.
    pub redispatched: usize,
    /// nodes that returned through `Fault::NodeRejoin`.
    pub rejoins: usize,
    /// resident experts device drops re-homed onto surviving peers.
    pub dev_moved_keys: usize,
    /// resident experts device drops lost (no surviving free capacity).
    pub dev_dropped_keys: usize,
}

impl ClusterReport {
    /// Tokens decoded across the cluster (error completions count the
    /// tokens they emitted before the failure).
    pub fn total_tokens(&self) -> usize {
        self.nodes
            .iter()
            .flat_map(|n| n.completions.iter())
            .map(|c| c.tokens)
            .sum()
    }

    /// Aggregate decode throughput over the cluster makespan, tokens/s.
    pub fn aggregate_tps(&self) -> f64 {
        self.total_tokens() as f64 / (self.total_us / 1e6).max(1e-9)
    }

    /// Cross-node messages over the network link, summed over nodes.
    pub fn net_pulls(&self) -> u64 {
        self.nodes.iter().map(|n| n.net_pulls).sum()
    }

    /// Bytes moved over the network link, summed over nodes.
    pub fn net_bytes(&self) -> f64 {
        self.nodes.iter().map(|n| n.net_bytes).sum()
    }

    /// Bounded-backoff retries charged across the cluster (DESIGN.md
    /// §12) — the ledger-exact sum over per-node store stats.
    pub fn retries(&self) -> u64 {
        self.nodes.iter().map(|n| n.stats.retries).sum()
    }

    pub fn completions(&self) -> impl Iterator<Item = (usize, &ServeCompletion)> {
        self.nodes
            .iter()
            .flat_map(|n| n.completions.iter().map(move |c| (n.node, c)))
    }
}

/// One cluster-level event on the deterministic cluster clock.
enum ClusterEvent<'a> {
    Arrival(&'a TimedRequest),
    Fault(Fault),
}

/// Merge the legacy single failure with the general schedule, sort by
/// activation time (stable: ties keep list order, legacy failure
/// first), and validate every fault against the cluster shape. The
/// alive-set is simulated across the sorted schedule so a `NodeRejoin`
/// without an earlier `NodeDown`, or a schedule that kills the last
/// alive node, is rejected up front instead of wedging the driver.
fn validate_faults(spec: &ClusterSpec, n: usize) -> Result<Vec<Fault>> {
    let mut faults: Vec<Fault> = Vec::new();
    if let Some(f) = &spec.failure {
        faults.push(Fault::NodeDown { node: f.node, t_us: f.t_us });
    }
    faults.extend(spec.faults.iter().copied());
    faults.sort_by(|a, b| a.t_us().total_cmp(&b.t_us()));

    let mut alive = vec![true; n];
    for f in &faults {
        if !f.t_us().is_finite() || f.t_us() < 0.0 {
            bail!("fault instant must be a finite non-negative time");
        }
        match *f {
            Fault::NodeDown { node, .. } => {
                if node >= n {
                    bail!("failure node {} out of range ({} nodes)", node, n);
                }
                if n < 2 {
                    bail!("a 1-node cluster has no survivors to re-home onto");
                }
                if alive[node] && alive.iter().filter(|a| **a).count() == 1 {
                    bail!(
                        "fault schedule leaves no alive node at t={} us",
                        f.t_us()
                    );
                }
                alive[node] = false;
            }
            Fault::NodeRejoin { node, .. } => {
                if node >= n {
                    bail!("rejoin node {} out of range ({} nodes)", node, n);
                }
                if alive[node] {
                    bail!("rejoin of node {} without an earlier NodeDown", node);
                }
                alive[node] = true;
            }
            Fault::DeviceDown { dev, .. } => {
                let total = n * spec.devices_per_node;
                if dev >= total {
                    bail!("device {} out of range ({} devices)", dev, total);
                }
                if spec.devices_per_node < 2 {
                    bail!(
                        "a device drop needs devices_per_node >= 2 so the \
                         node keeps surviving devices"
                    );
                }
            }
            Fault::LinkDegrade { factor, t0_us, t1_us, .. } => {
                if !t1_us.is_finite() || t0_us >= t1_us {
                    bail!("link window needs finite t0 < t1");
                }
                if !factor.is_finite() || !(0.0..1.0).contains(&factor) {
                    bail!("link degrade factor must be in [0, 1), got {factor}");
                }
            }
        }
    }
    Ok(faults)
}

/// Run `workload` through an N-node cluster. Untraced (no event logs).
pub fn simulate_cluster(
    p_base: &SimParams,
    spec: &ClusterSpec,
    workload: &[TimedRequest],
) -> Result<ClusterReport> {
    simulate_cluster_inner(p_base, spec, workload, false)
}

/// Traced variant: every node's event core records its pop log — the
/// determinism pins and the FLTL cluster extension compare these
/// byte-for-byte.
pub fn simulate_cluster_traced(
    p_base: &SimParams,
    spec: &ClusterSpec,
    workload: &[TimedRequest],
) -> Result<ClusterReport> {
    simulate_cluster_inner(p_base, spec, workload, true)
}

fn simulate_cluster_inner(
    p_base: &SimParams,
    spec: &ClusterSpec,
    workload: &[TimedRequest],
    trace: bool,
) -> Result<ClusterReport> {
    let n = spec.n_nodes.max(1);
    let faults = validate_faults(spec, n)?;
    debug_assert!(
        workload.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us),
        "workload must be sorted by arrival"
    );

    // every node sizes its KV reservation off the full workload, so a
    // 1-node cluster builds the exact backend `simulate_serving` builds
    // (pinned bit-exact in the tests below)
    let max_ctx = workload
        .iter()
        .map(|t| t.req.prompt.len() + t.req.max_tokens)
        .max()
        .unwrap_or(512);
    let kv_tokens = spec.max_batch.max(1) * max_ctx;
    let vram_per_device = spec.vram_gb_total / (n * spec.devices_per_node) as f64;

    let mut scheds: Vec<Scheduler<SimServeBackend>> = (0..n)
        .map(|j| {
            let mut p = p_base.clone();
            p.system = p_base
                .system
                .clone()
                .with_devices(spec.devices_per_node, spec.shard)
                .as_cluster_member(j, n, spec.host_ram_gb);
            p.vram_gb = vram_per_device;
            let backend = if trace {
                SimServeBackend::new_traced(p, kv_tokens)
            } else {
                SimServeBackend::new(p, kv_tokens)
            };
            Scheduler::new(backend, spec.max_batch)
        })
        .collect();

    // link windows and the retry policy are part of the pricing model,
    // not runtime state: every node's store gets the full schedule up
    // front so link costs are a pure function of (schedule, clock) and
    // replay needs no mid-session mutation
    for sched in scheds.iter_mut() {
        let store = sched.backend_mut().store_mut();
        store.set_retry_policy(spec.retry);
        for f in &faults {
            if let Fault::LinkDegrade { link, factor, t0_us, t1_us } = *f {
                store.install_link_window(LinkWindow { link, factor, t0_us, t1_us });
            }
        }
    }

    // originals for value-idempotent re-dispatch: a NodeDown restarts
    // the dead node's in-flight requests from these, keyed by id
    let req_by_id: BTreeMap<u64, &TimedRequest> =
        workload.iter().map(|t| (t.req.id, t)).collect();

    let mut alive = vec![true; n];
    let mut node_completions: Vec<Vec<ServeCompletion>> = vec![Vec::new(); n];
    let mut assignments: Vec<(u64, usize)> = Vec::new();
    let mut rr = 0usize;
    let mut errored = 0usize;
    let mut rehomed_keys = 0usize;
    let mut redispatched = 0usize;
    let mut rejoins = 0usize;
    let mut dev_moved_keys = 0usize;
    let mut dev_dropped_keys = 0usize;
    let mut fi = 0usize;
    let mut idx = 0usize;

    loop {
        // next cluster-level event: the earlier of the next unplaced
        // arrival and the next scheduled fault; the fault wins exact
        // ties (the tied arrival then routes around the new topology)
        let t_arr = workload.get(idx).map(|t| t.arrival_us);
        let t_fault = faults.get(fi).map(|f| f.t_us());
        let horizon = match (t_arr, t_fault) {
            (Some(a), Some(f)) => a.min(f),
            (Some(a), None) => a,
            (None, Some(f)) => f,
            (None, None) => f64::INFINITY,
        };

        // advance the cluster to the event: step the alive node with the
        // earliest clock (ties: lowest id) until every working node's
        // clock reached the horizon or the cluster drained
        while let Some(j) = next_node(&scheds, &alive, horizon) {
            for c in scheds[j].step() {
                if c.error.is_some() {
                    errored += 1;
                }
                node_completions[j].push(c);
            }
        }

        let ev = match (t_arr, t_fault) {
            (None, None) => break,
            (Some(_), None) => ClusterEvent::Arrival(&workload[idx]),
            (None, Some(_)) => {
                fi += 1;
                ClusterEvent::Fault(faults[fi - 1])
            }
            (Some(a), Some(f)) => {
                if f <= a {
                    fi += 1;
                    ClusterEvent::Fault(faults[fi - 1])
                } else {
                    ClusterEvent::Arrival(&workload[idx])
                }
            }
        };
        match ev {
            ClusterEvent::Arrival(t) => {
                idx += 1;
                let j = place(spec.placement, p_base, &scheds, &alive, &mut rr, t);
                assignments.push((t.req.id, j));
                scheds[j].enqueue_at(t.req.clone(), t.arrival_us);
            }
            ClusterEvent::Fault(Fault::NodeDown { node, t_us }) => {
                if !alive[node] {
                    continue;
                }
                // the dead node's clock pops NodeDown at the exact
                // failure instant (recorded in its event log)
                scheds[node].backend_mut().note_node_down(t_us, node as u64);
                alive[node] = false;
                let survivors: Vec<usize> = (0..n).filter(|&j| alive[j]).collect();

                if survivors.is_empty() {
                    // unreachable through validate_faults, kept as the
                    // documented no-survivor semantics: actives retire
                    // as error completions carrying their pre-fault
                    // tokens and a structured cause (DESIGN.md §12)
                    let errs = scheds[node].fail_active(
                        &format!("node {node} down"),
                        FaultCause::NodeDown,
                    );
                    errored += errs.len();
                    node_completions[node].extend(errs);
                    continue;
                }

                // 1. in-flight requests abort without completions and
                //    re-dispatch to survivors round-robin: decoding is
                //    value-idempotent (tokens derive from the request
                //    seed), so restarting from the original request
                //    yields the same text and every id retires exactly
                //    once cluster-wide
                for id in scheds[node].abort_active() {
                    let t = req_by_id[&id];
                    let j = survivors[rr % survivors.len()];
                    rr += 1;
                    if let Some(a) = assignments.iter_mut().find(|(aid, _)| *aid == id) {
                        a.1 = j;
                    }
                    scheds[j].enqueue_at(t.req.clone(), t.arrival_us);
                    redispatched += 1;
                }

                // 2. still-queued requests re-route to survivors
                //    round-robin with their original arrival stamps
                for (req, arrival_us) in scheds[node].drain_pending() {
                    let j = survivors[rr % survivors.len()];
                    rr += 1;
                    if let Some(a) = assignments.iter_mut().find(|(id, _)| *id == req.id) {
                        a.1 = j;
                    }
                    scheds[j].enqueue_at(req, arrival_us);
                }

                // 3. re-home the dead node's stageable shard: survivors
                //    split its host-pool keys round-robin in sorted key
                //    order and pull their share over the network link
                let keys = scheds[node].backend().store().host_pool_keys(0);
                rehomed_keys += keys.len();
                let bytes = scheds[node].backend().per_expert_bytes() as usize;
                let mut shares: Vec<Vec<_>> = vec![Vec::new(); survivors.len()];
                for (i, key) in keys.into_iter().enumerate() {
                    shares[i % survivors.len()].push(key);
                }
                for (&j, share) in survivors.iter().zip(&shares) {
                    scheds[j]
                        .backend_mut()
                        .store_mut()
                        .net_restore(share, bytes);
                }
            }
            ClusterEvent::Fault(Fault::NodeRejoin { node, t_us }) => {
                if alive[node] {
                    continue;
                }
                // the returning node's memory died with it: stamp the
                // rejoin on its clock, wipe and restock the host pool
                // over the network, then re-enter placement rotation
                scheds[node].backend_mut().note_node_rejoin(t_us, node as u64);
                scheds[node].backend_mut().rejoin_restock();
                alive[node] = true;
                rejoins += 1;
            }
            ClusterEvent::Fault(Fault::DeviceDown { dev, t_us }) => {
                let node = dev / spec.devices_per_node;
                if !alive[node] {
                    continue;
                }
                let rep = scheds[node]
                    .backend_mut()
                    .note_device_down(t_us, dev % spec.devices_per_node);
                dev_moved_keys += rep.moved_keys;
                dev_dropped_keys += rep.dropped_keys;
            }
            ClusterEvent::Fault(Fault::LinkDegrade { link, t0_us, .. }) => {
                // pricing was installed at setup; this only stamps the
                // window's activation into every alive node's event log
                for (j, sched) in scheds.iter_mut().enumerate() {
                    if alive[j] {
                        sched.backend_mut().note_link_degrade(t0_us, link);
                    }
                }
            }
        }
    }

    let total_us = scheds
        .iter()
        .zip(&alive)
        .filter(|(_, a)| **a)
        .map(|(s, _)| s.backend().now_us())
        .fold(0.0f64, f64::max);

    let nodes = scheds
        .into_iter()
        .zip(node_completions)
        .zip(alive)
        .enumerate()
        .map(|(j, ((sched, completions), alive))| {
            let admitted_order = sched.admitted_order().to_vec();
            let max_batch_seen = sched.max_batch_seen();
            let backend = sched.into_backend();
            let store = backend.store();
            NodeObs {
                node: j,
                completions,
                admitted_order,
                event_log: backend.event_log().to_vec(),
                stats: store.stats().clone(),
                cache_hit_rate: store.cache_stats().hit_rate(),
                total_us: store.now_us(),
                max_batch_seen,
                net_pulls: store.net_pulls(),
                net_bytes: store.net_bytes(),
                alive,
            }
        })
        .collect();

    Ok(ClusterReport {
        nodes,
        assignments,
        total_us,
        errored,
        rehomed_keys,
        redispatched,
        rejoins,
        dev_moved_keys,
        dev_dropped_keys,
    })
}

/// The alive node with the earliest clock (ties: lowest id) that still
/// has work and has not reached the horizon.
fn next_node(
    scheds: &[Scheduler<SimServeBackend>],
    alive: &[bool],
    horizon: f64,
) -> Option<usize> {
    let mut best: Option<(f64, usize)> = None;
    for (j, s) in scheds.iter().enumerate() {
        if !alive[j] || !s.has_work() {
            continue;
        }
        let now = s.backend().now_us();
        if now >= horizon {
            continue;
        }
        let better = match best {
            None => true,
            Some((bn, _)) => now.total_cmp(&bn).is_lt(),
        };
        if better {
            best = Some((now, j));
        }
    }
    best.map(|(_, j)| j)
}

/// Pick the node for one arriving request. Reads cluster state at the
/// arrival instant; every rule breaks ties toward the lowest node id so
/// placement is deterministic.
fn place(
    placement: ClusterPlacement,
    p_base: &SimParams,
    scheds: &[Scheduler<SimServeBackend>],
    alive: &[bool],
    rr: &mut usize,
    t: &TimedRequest,
) -> usize {
    let survivors: Vec<usize> = (0..scheds.len()).filter(|&j| alive[j]).collect();
    debug_assert!(!survivors.is_empty(), "placement with no alive nodes");
    let load = |j: usize| scheds[j].active_len() + scheds[j].pending_len();
    match placement {
        ClusterPlacement::RoundRobin => {
            let j = survivors[*rr % survivors.len()];
            *rr += 1;
            j
        }
        ClusterPlacement::LeastLoaded => {
            let mut best = survivors[0];
            for &j in &survivors[1..] {
                if load(j) < load(best) {
                    best = j;
                }
            }
            best
        }
        ClusterPlacement::ExpertAffinity => {
            let e = predicted_first_expert(
                &p_base.routing,
                p_base.dims.n_experts,
                t.req.seed,
            );
            let mass = |j: usize| -> f64 {
                let store = scheds[j].backend().store();
                (0..p_base.dims.n_layers)
                    .map(|l| store.popularity_mass((l, e)))
                    .sum()
            };
            let mut best = survivors[0];
            let mut best_mass = mass(best);
            for &j in &survivors[1..] {
                let m = mass(j);
                if m.total_cmp(&best_mass).is_gt()
                    || (m.total_cmp(&best_mass).is_eq() && load(j) < load(best))
                {
                    best = j;
                    best_mass = m;
                }
            }
            best
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::{SystemConfig, SystemKind};
    use crate::coordinator::sim::simulate_serving;
    use crate::hwsim::RTX3090;
    use crate::workload::{generate, WorkloadSpec};

    fn base_params() -> SimParams {
        SimParams::mixtral_on(
            RTX3090.clone(),
            SystemConfig::new(SystemKind::Floe),
            14.25,
        )
    }

    fn workload_at(rate_hz: f64, n: usize, seed: u64) -> Vec<TimedRequest> {
        generate(&WorkloadSpec {
            n_requests: n,
            arrival_rate_hz: rate_hz,
            seed,
            ..Default::default()
        })
    }

    #[test]
    fn one_node_cluster_matches_simulate_serving_bit_exactly() {
        let p = base_params();
        let workload = workload_at(4.0, 10, 23);
        let spec = ClusterSpec::new(1, 1, 14.25);

        // the exact per-node params the cluster driver constructs
        let mut p_node = p.clone();
        p_node.system = p
            .system
            .clone()
            .with_devices(1, spec.shard)
            .as_cluster_member(0, 1, spec.host_ram_gb);
        p_node.vram_gb = 14.25;
        let flat = simulate_serving(&p_node, &workload, spec.max_batch).unwrap();

        let cluster = simulate_cluster(&p, &spec, &workload).unwrap();
        assert_eq!(cluster.nodes.len(), 1);
        let node = &cluster.nodes[0];
        assert_eq!(node.completions.len(), flat.completions.len());
        for (a, b) in node.completions.iter().zip(&flat.completions) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens);
            assert_eq!(a.arrival_us.to_bits(), b.arrival_us.to_bits());
            assert_eq!(a.queue_wait_us.to_bits(), b.queue_wait_us.to_bits());
            assert_eq!(a.finished_us.to_bits(), b.finished_us.to_bits());
            assert_eq!(
                a.stall.total_us().to_bits(),
                b.stall.total_us().to_bits()
            );
            assert!(a.error.is_none());
        }
        assert_eq!(node.admitted_order, flat.admitted_order);
        assert_eq!(cluster.total_us.to_bits(), flat.total_us.to_bits());
        assert_eq!(
            node.stats.transferred_bytes.to_bits(),
            flat.stats.transferred_bytes.to_bits()
        );
        assert_eq!(node.stats.bus_transactions, flat.stats.bus_transactions);
        // one node, no peers: nothing ever crosses the network link
        assert_eq!(node.net_pulls, 0);
    }

    #[test]
    fn cluster_driver_is_deterministic() {
        let p = base_params();
        let workload = workload_at(8.0, 12, 41);
        let spec = ClusterSpec::new(2, 1, 28.5)
            .with_placement(ClusterPlacement::LeastLoaded)
            .with_failure(1, 1_500_000.0);
        let a = simulate_cluster_traced(&p, &spec, &workload).unwrap();
        let b = simulate_cluster_traced(&p, &spec, &workload).unwrap();
        assert_eq!(a.assignments, b.assignments);
        assert_eq!(a.total_us.to_bits(), b.total_us.to_bits());
        assert_eq!(a.errored, b.errored);
        assert_eq!(a.rehomed_keys, b.rehomed_keys);
        for (na, nb) in a.nodes.iter().zip(&b.nodes) {
            assert!(!na.event_log.is_empty());
            assert_eq!(na.event_log, nb.event_log);
            assert_eq!(na.completions.len(), nb.completions.len());
            for (ca, cb) in na.completions.iter().zip(&nb.completions) {
                assert_eq!(ca.id, cb.id);
                assert_eq!(ca.finished_us.to_bits(), cb.finished_us.to_bits());
            }
            assert_eq!(na.net_pulls, nb.net_pulls);
            assert_eq!(na.net_bytes.to_bits(), nb.net_bytes.to_bits());
        }
    }

    #[test]
    fn cross_node_pulls_move_whole_experts_under_every_placement() {
        let p = base_params();
        let workload = workload_at(8.0, 10, 19);
        // a tight host pool: each node stages its own shard but not the
        // full roster, so cold fetches of foreign-shard experts cross
        // the network link
        let mut per_pull_bits: Vec<u64> = Vec::new();
        for placement in ClusterPlacement::ALL {
            let mut spec = ClusterSpec::new(2, 1, 28.5).with_placement(placement);
            spec.host_ram_gb = 4.0;
            let r = simulate_cluster(&p, &spec, &workload).unwrap();
            // every request served, none errored
            let mut ids: Vec<u64> = r.completions().map(|(_, c)| c.id).collect();
            ids.sort_unstable();
            assert_eq!(
                ids,
                (0..workload.len() as u64).collect::<Vec<_>>(),
                "{}",
                placement.name()
            );
            assert!(
                r.completions().all(|(_, c)| c.error.is_none()),
                "{}",
                placement.name()
            );
            // without a failure there are no zero-byte handshakes: every
            // cross-node pull moves exactly one whole compressed expert
            for node in &r.nodes {
                assert!(node.net_bytes.is_finite());
                if node.net_pulls > 0 {
                    per_pull_bits.push((node.net_bytes / node.net_pulls as f64).to_bits());
                }
            }
        }
        // ...and the per-pull payload is bit-identical across placements
        assert!(!per_pull_bits.is_empty(), "no placement exercised the network link");
        assert!(per_pull_bits.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn node_failure_rehomes_and_loses_no_queued_request() {
        let p = base_params();
        let workload = workload_at(8.0, 14, 77);
        // fail node 1 while requests are still arriving and in flight
        let t_fail = workload[6].arrival_us + 1.0;
        let spec = ClusterSpec::new(2, 1, 28.5)
            .with_placement(ClusterPlacement::RoundRobin)
            .with_failure(1, t_fail);
        let r = simulate_cluster(&p, &spec, &workload).unwrap();

        assert!(!r.nodes[1].alive);
        assert!(r.nodes[0].alive);
        // the dead node's clock froze at the boundary that observed the
        // failure — at or after the stamp, never before
        assert!(r.nodes[1].total_us >= t_fail);
        assert!(r.total_us > r.nodes[1].total_us, "survivor outlived the dead node");
        // a survivor exists, so the dead node's in-flight batch
        // re-dispatched instead of erroring (DESIGN.md §12):
        // zero error completions anywhere in the cluster
        assert_eq!(r.errored, 0);
        assert!(r.redispatched > 0, "failure hit an idle node");
        assert!(r.completions().all(|(_, c)| c.error.is_none()));
        // what the dead node did retire, it retired before the failure
        assert!(r.nodes[1]
            .completions
            .iter()
            .all(|c| c.finished_us <= t_fail + 1e-9));
        // ...and every request id retired exactly once cluster-wide:
        // zero lost requests after re-dispatch and re-homing
        let mut ids: Vec<u64> = r.completions().map(|(_, c)| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..workload.len() as u64).collect::<Vec<_>>());
        // the dead node's stageable shard re-homed over the network
        assert!(r.rehomed_keys > 0);
        assert!(r.nodes[0].net_pulls >= r.rehomed_keys as u64);
        // re-dispatched and re-routed requests record their survivor
        // node: every assignment points at the node that served it
        for (id, node) in &r.assignments {
            let (served_by, _) = r
                .completions()
                .find(|(_, c)| c.id == *id)
                .expect("assigned request never completed");
            assert_eq!(served_by, *node, "request {id}");
        }
    }

    /// The acceptance pin: a 2-node drop + rejoin point loses nothing.
    /// Node 1 drops mid-flight, its actives restart on node 0
    /// value-idempotently, and after the rejoin the returning node takes
    /// a non-zero share of placement again. Mirrored in
    /// `python/replay_sim.py` (chaos section).
    #[test]
    fn node_drop_and_rejoin_retires_every_request_exactly_once() {
        let p = base_params();
        let workload = workload_at(8.0, 16, 77);
        let t_down = workload[4].arrival_us + 1.0;
        let t_rejoin = workload[8].arrival_us - 1.0;
        let spec = ClusterSpec::new(2, 1, 28.5)
            .with_placement(ClusterPlacement::RoundRobin)
            .with_faults(vec![
                Fault::NodeDown { node: 1, t_us: t_down },
                Fault::NodeRejoin { node: 1, t_us: t_rejoin },
            ]);
        let r = simulate_cluster(&p, &spec, &workload).unwrap();

        assert_eq!(r.rejoins, 1);
        assert!(r.nodes[1].alive, "node 1 must be back after the rejoin");
        // zero lost requests, zero error completions, exactly-once
        assert_eq!(r.errored, 0);
        assert!(r.completions().all(|(_, c)| c.error.is_none()));
        let mut ids: Vec<u64> = r.completions().map(|(_, c)| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..workload.len() as u64).collect::<Vec<_>>());
        // the rejoined node re-entered placement: arrivals after the
        // rejoin land on it again
        let post_rejoin_share = r
            .assignments
            .iter()
            .filter(|(id, node)| {
                *node == 1 && workload[*id as usize].arrival_us > t_rejoin
            })
            .count();
        assert!(post_rejoin_share > 0, "rejoined node got no placement share");
        // ...and it retired work after coming back
        assert!(r.nodes[1]
            .completions
            .iter()
            .any(|c| c.finished_us > t_rejoin));
        // the restock crossed the network link
        assert!(r.nodes[1].net_pulls > 0);
    }

    /// Mixed fault schedule (device drop + link window + node drop +
    /// rejoin) is deterministic to the bit and still retires every id
    /// exactly once — the random-schedule property, pinned on three
    /// derived schedules.
    #[test]
    fn mixed_fault_schedules_stay_deterministic_and_exactly_once() {
        let p = base_params();
        for seed in [3u64, 11, 29] {
            let workload = workload_at(8.0, 12, seed);
            let t0 = workload[2].arrival_us + 0.5;
            let t1 = workload[5].arrival_us + 0.5;
            let t2 = workload[9].arrival_us + 0.5;
            let spec = ClusterSpec::new(2, 2, 28.5)
                .with_placement(ClusterPlacement::LeastLoaded)
                .with_faults(vec![
                    Fault::DeviceDown { dev: (seed % 4) as usize, t_us: t0 },
                    // slowdown, not outage: no retry policy needed and
                    // nothing fail-fasts
                    Fault::LinkDegrade {
                        link: LinkId::Pcie,
                        factor: 0.3,
                        t0_us: t0,
                        t1_us: t1,
                    },
                    Fault::NodeDown { node: (seed % 2) as usize, t_us: t1 },
                    Fault::NodeRejoin { node: (seed % 2) as usize, t_us: t2 },
                ]);
            let a = simulate_cluster_traced(&p, &spec, &workload).unwrap();
            let b = simulate_cluster_traced(&p, &spec, &workload).unwrap();
            assert_eq!(a.assignments, b.assignments, "seed {seed}");
            assert_eq!(a.total_us.to_bits(), b.total_us.to_bits(), "seed {seed}");
            assert_eq!(a.redispatched, b.redispatched, "seed {seed}");
            assert_eq!(a.dev_moved_keys, b.dev_moved_keys, "seed {seed}");
            assert_eq!(a.dev_dropped_keys, b.dev_dropped_keys, "seed {seed}");
            for (na, nb) in a.nodes.iter().zip(&b.nodes) {
                assert_eq!(na.event_log, nb.event_log, "seed {seed}");
                assert_eq!(
                    na.stats.transferred_bytes.to_bits(),
                    nb.stats.transferred_bytes.to_bits(),
                    "seed {seed}"
                );
            }
            // exactly-once retirement under every schedule
            assert_eq!(a.errored, 0, "seed {seed}");
            assert_eq!(a.rejoins, 1, "seed {seed}");
            let mut ids: Vec<u64> = a.completions().map(|(_, c)| c.id).collect();
            ids.sort_unstable();
            assert_eq!(
                ids,
                (0..workload.len() as u64).collect::<Vec<_>>(),
                "seed {seed}"
            );
            // the device drop conserved its resident set: everything it
            // held either moved to a surviving peer or was dropped
            // (store-level byte conservation is property-tested in
            // store::tests; here the cluster-level counters must agree
            // across runs and be visible in the report)
            assert_eq!(
                a.dev_moved_keys + a.dev_dropped_keys > 0,
                b.dev_moved_keys + b.dev_dropped_keys > 0,
                "seed {seed}"
            );
        }
    }

    /// Double-opt-in identity: a retry policy with no outage windows
    /// changes nothing — event logs and stats stay bit-identical to the
    /// policy-free run (the empty-schedule half of the §12 determinism
    /// contract; the store-level halves are pinned in store::tests).
    #[test]
    fn retry_policy_without_outages_is_bit_identical() {
        let p = base_params();
        let workload = workload_at(8.0, 12, 41);
        let plain = ClusterSpec::new(2, 1, 28.5);
        let armed = ClusterSpec::new(2, 1, 28.5).with_retry(RetryPolicy {
            max_attempts: 6,
            backoff_base_us: 50_000.0,
        });
        let a = simulate_cluster_traced(&p, &plain, &workload).unwrap();
        let b = simulate_cluster_traced(&p, &armed, &workload).unwrap();
        assert_eq!(a.total_us.to_bits(), b.total_us.to_bits());
        assert_eq!(b.retries(), 0);
        for (na, nb) in a.nodes.iter().zip(&b.nodes) {
            assert_eq!(na.event_log, nb.event_log);
            assert_eq!(
                na.stats.transferred_bytes.to_bits(),
                nb.stats.transferred_bytes.to_bits()
            );
            assert_eq!(na.completions.len(), nb.completions.len());
            for (ca, cb) in na.completions.iter().zip(&nb.completions) {
                assert_eq!(ca.finished_us.to_bits(), cb.finished_us.to_bits());
            }
        }
    }

    #[test]
    fn fault_schedule_validation_rejects_malformed_schedules() {
        let p = base_params();
        let workload = workload_at(4.0, 4, 5);
        // rejoin without an earlier down
        let r = simulate_cluster(
            &p,
            &ClusterSpec::new(2, 1, 28.5)
                .with_faults(vec![Fault::NodeRejoin { node: 1, t_us: 10.0 }]),
            &workload,
        );
        assert!(r.is_err());
        // device drop with a single device per node
        let r = simulate_cluster(
            &p,
            &ClusterSpec::new(2, 1, 28.5)
                .with_faults(vec![Fault::DeviceDown { dev: 0, t_us: 10.0 }]),
            &workload,
        );
        assert!(r.is_err());
        // schedule that kills the last alive node
        let r = simulate_cluster(
            &p,
            &ClusterSpec::new(2, 1, 28.5).with_faults(vec![
                Fault::NodeDown { node: 0, t_us: 10.0 },
                Fault::NodeDown { node: 1, t_us: 20.0 },
            ]),
            &workload,
        );
        assert!(r.is_err());
        // inverted link window
        let r = simulate_cluster(
            &p,
            &ClusterSpec::new(2, 1, 28.5).with_faults(vec![Fault::LinkDegrade {
                link: LinkId::Net,
                factor: 0.5,
                t0_us: 100.0,
                t1_us: 50.0,
            }]),
            &workload,
        );
        assert!(r.is_err());
        // degrade factor of exactly 1.0 is a no-op and rejected
        let r = simulate_cluster(
            &p,
            &ClusterSpec::new(2, 1, 28.5).with_faults(vec![Fault::LinkDegrade {
                link: LinkId::Net,
                factor: 1.0,
                t0_us: 50.0,
                t1_us: 100.0,
            }]),
            &workload,
        );
        assert!(r.is_err());
    }

    /// The acceptance margin: at a *fixed aggregate* expert-cache budget,
    /// two nodes out-serve one. Each node keeps the same per-device slice
    /// (28.5 GB / 2 = the serve-load default), so the win comes from
    /// splitting the admission queue, not from extra VRAM. The ratio is
    /// replay-verified: the Python mirror (`python/replay_sim.py`) pins
    /// 1.5437x on this exact spec and workload.
    #[test]
    fn two_nodes_beat_one_at_fixed_aggregate_vram() {
        let p = base_params();
        let workload = workload_at(16.0, 24, 7);
        let one = simulate_cluster(&p, &ClusterSpec::new(1, 1, 28.5), &workload).unwrap();
        let two = simulate_cluster(&p, &ClusterSpec::new(2, 1, 28.5), &workload).unwrap();
        assert_eq!(one.errored + two.errored, 0);
        assert_eq!(two.completions().count(), workload.len());
        let ratio = two.aggregate_tps() / one.aggregate_tps();
        assert!(
            ratio > 1.4,
            "2 nodes {:.2} tok/s not > 1.4x 1 node {:.2} tok/s at 28.5 GB aggregate \
             (replay pins 1.5437x)",
            two.aggregate_tps(),
            one.aggregate_tps()
        );
    }

    #[test]
    fn affinity_placement_spreads_or_concentrates_deterministically() {
        let p = base_params();
        let workload = workload_at(8.0, 16, 11);
        let spec =
            ClusterSpec::new(2, 1, 28.5).with_placement(ClusterPlacement::ExpertAffinity);
        let a = simulate_cluster(&p, &spec, &workload).unwrap();
        let b = simulate_cluster(&p, &spec, &workload).unwrap();
        assert_eq!(a.assignments, b.assignments);
        // affinity must still serve everything
        assert_eq!(a.completions().count(), workload.len());
    }
}

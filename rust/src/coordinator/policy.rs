//! The offloading-system design space (paper §4.1 baselines + FloE).

use crate::config::{ExpertMode, ResidencyKind, ShardPolicy};
use crate::hwsim::{PcieSpec, TopologySpec};
use crate::store::{Placement, DEFAULT_SPARSITY_DECAY};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    /// FloE (paper): INT2 up resident, contextual-sparse gate/down
    /// streamed via dual predictors + compact async transfer.
    Floe,
    /// DeepSpeed-MII-style: fp16 experts streamed on demand, no
    /// prediction, no expert cache beyond what trivially fits.
    NaiveOffload,
    /// Mixtral-Offloading-style: uniformly INT3-quantized experts, LRU
    /// GPU cache, speculative same-hidden-state prefetch (no overlap
    /// with next-layer compute — the paper's §2 criticism).
    AdvancedOffload,
    /// Fiddler-style: missing experts are computed on the CPU from DRAM
    /// weights instead of being transferred.
    Fiddler,
    /// Upper bound: everything INT2, fully VRAM-resident (Mixtral-GPU).
    GpuResident,
}

impl SystemKind {
    pub fn name(&self) -> &'static str {
        match self {
            SystemKind::Floe => "FloE",
            SystemKind::NaiveOffload => "DeepSpeed-MII (naive)",
            SystemKind::AdvancedOffload => "Mixtral-Offloading",
            SystemKind::Fiddler => "Fiddler",
            SystemKind::GpuResident => "Mixtral-GPU (resident)",
        }
    }

    pub const ALL: [SystemKind; 5] = [
        SystemKind::Floe,
        SystemKind::NaiveOffload,
        SystemKind::AdvancedOffload,
        SystemKind::Fiddler,
        SystemKind::GpuResident,
    ];
}

#[derive(Clone, Debug)]
pub struct SystemConfig {
    pub kind: SystemKind,
    /// FloE contextual-sparsity level (paper default 0.7-0.9)
    pub sparsity: f64,
    /// uniform quant bits for AdvancedOffload
    pub quant_bits: u8,
    /// intra-predictor safety margin (fraction below threshold prefetched)
    pub intra_margin: f64,
    /// transfer chunk size in channels (paper Fig 7 optimum ≈ 50)
    pub chunk_channels: usize,
    /// ExpertStore eviction policy (paper baseline: LRU)
    pub residency: ResidencyKind,
    /// decay constant for the sparsity policy's activation EMA
    /// (`--sparsity-decay`; other policies ignore it)
    pub sparsity_decay: f64,
    /// devices expert residency shards across (`--devices`, default 1 —
    /// the paper's single-GPU configuration)
    pub devices: usize,
    /// expert → device placement function (`--shard-policy`)
    pub shard: ShardPolicy,
    /// coalesce same-destination prefetch plans into chunked copies (on
    /// by default when sharded; off single-device so `--devices 1`
    /// reproduces the pre-placement numbers bit-exactly)
    pub coalesce: bool,
    /// spill eviction victims to peer devices with spare capacity
    pub spill: bool,
    /// replicate the top-k hottest experts (by measured activation mass)
    /// onto peer devices under the popularity-proportional replica
    /// budget (`--replicate-top`, default 0 = off — off keeps every
    /// pre-replication configuration bit-exact)
    pub replicate_top: usize,
    /// per-device compute streams: expert GEMVs occupy their execution
    /// device's own compute timeline, overlapping across devices inside
    /// a layer (`--compute-streams`; off by default so `--devices N`
    /// without it reproduces the single-compute-timeline numbers
    /// bit-exactly)
    pub compute_streams: bool,
    /// event-driven compute/transfer overlap (`--overlap`): a layer's
    /// expert fetches are resolved upfront and their completions release
    /// waiting GEMVs mid-boundary in readiness order, so resident
    /// experts compute while demand fetches are in flight instead of
    /// charging the full stall at the barrier. Off by default — off
    /// keeps the event core bit-exact with the frozen busy-until
    /// reference (DESIGN.md §8)
    pub overlap: bool,
    /// heterogeneous fleet: per-device GEMV throughput descends across
    /// the placement (`TopologySpec::heterogeneous`) instead of being
    /// uniform — exercised by `exp-shard-sweep`'s hetero rows. Only
    /// observable with compute streams on (the single compute timeline
    /// never consults per-device scale)
    pub hetero_fleet: bool,
    /// spanning cluster form (DESIGN.md §10): this store's devices
    /// partition into `cluster_span` node groups joined by the network
    /// link, so cross-group peer hits resolve as `Lookup::RemoteNode`.
    /// Default 1 (single node) keeps every existing configuration —
    /// including the serialized FLTL spec — untouched
    pub cluster_span: usize,
    /// member cluster form: (node_id, n_nodes) when this store serves as
    /// one node of a `ClusterRouter` fleet; (0, 1) = single-node world
    pub node_id: usize,
    pub n_nodes: usize,
    /// per-node host RAM pool in GB (expert residency decoupled from the
    /// serving node); only consulted when a cluster form is active
    pub host_ram_gb: f64,
    /// quality-elastic serving (`--little-frac`, DESIGN.md §11): the
    /// fraction of each device's byte budget carved into the always-
    /// resident little-tier pool of degraded expert variants. A routed
    /// expert that would stall past a request's SLO deadline executes
    /// the little variant instead of waiting for the full bytes.
    /// Default 0.0 = fallback off — every pre-fallback configuration
    /// (and every committed FLTL artifact) stays bit-exact
    pub little_frac: f64,
}

impl SystemConfig {
    pub fn new(kind: SystemKind) -> Self {
        SystemConfig {
            kind,
            // the paper's deployment operating point (Fig 6/8, 9.3x)
            sparsity: 0.9,
            quant_bits: 3,
            intra_margin: 0.15,
            chunk_channels: 50,
            residency: ResidencyKind::Lru,
            sparsity_decay: DEFAULT_SPARSITY_DECAY,
            devices: 1,
            shard: ShardPolicy::Layer,
            coalesce: false,
            spill: false,
            replicate_top: 0,
            compute_streams: false,
            overlap: false,
            hetero_fleet: false,
            cluster_span: 1,
            node_id: 0,
            n_nodes: 1,
            host_ram_gb: 64.0,
            little_frac: 0.0,
        }
    }

    pub fn with_residency(kind: SystemKind, residency: ResidencyKind) -> Self {
        let mut c = Self::new(kind);
        c.residency = residency;
        c
    }

    /// Shard expert residency across `devices` under `shard`, turning the
    /// cooperative behaviors (plan coalescing, eviction spill) on whenever
    /// there is more than one device.
    pub fn with_devices(mut self, devices: usize, shard: ShardPolicy) -> Self {
        self.devices = devices.max(1);
        self.shard = shard;
        self.coalesce = self.devices > 1;
        self.spill = self.devices > 1;
        self
    }

    /// Replicate the `k` hottest experts across devices and run
    /// per-device compute streams — the popularity-driven serving mode
    /// (`exp-shard-sweep`'s "pop" rows). No-op at one device.
    pub fn with_replication(mut self, k: usize) -> Self {
        if self.devices > 1 {
            self.replicate_top = k;
            self.compute_streams = true;
        }
        self
    }

    /// Event-driven compute/transfer overlap (`--overlap`).
    pub fn with_overlap(mut self, on: bool) -> Self {
        self.overlap = on;
        self
    }

    /// Heterogeneous per-device GEMV throughput (`exp-shard-sweep`'s
    /// hetero rows). No observable effect at one device.
    pub fn with_hetero_fleet(mut self, on: bool) -> Self {
        self.hetero_fleet = on;
        self
    }

    /// Spanning cluster form: partition this store's devices into `span`
    /// node groups over the network link (DESIGN.md §10). `span = 1` is
    /// the single-node no-op.
    pub fn with_cluster_span(mut self, span: usize) -> Self {
        self.cluster_span = span.max(1);
        self
    }

    /// Quality-elastic big-little fallback (`--little-frac`): carve
    /// `frac` of each device's budget into the always-resident little
    /// tier. 0.0 keeps the fallback machinery off entirely.
    pub fn with_little_frac(mut self, frac: f64) -> Self {
        self.little_frac = frac.clamp(0.0, 0.5);
        self
    }

    /// Member cluster form: this configuration serves as node `node_id`
    /// of an `n_nodes` cluster with `host_ram_gb` of host expert pool.
    pub fn as_cluster_member(mut self, node_id: usize, n_nodes: usize, host_ram_gb: f64) -> Self {
        self.n_nodes = n_nodes.max(1);
        self.node_id = node_id.min(self.n_nodes - 1);
        self.host_ram_gb = host_ram_gb;
        self
    }

    /// The store placement this configuration selects, over per-device
    /// host links of spec `h2d`.
    pub fn placement(&self, h2d: PcieSpec) -> Placement {
        let mut topo = if self.hetero_fleet {
            TopologySpec::heterogeneous(self.devices, h2d)
        } else {
            TopologySpec::uniform(self.devices, h2d)
        };
        if self.cluster_span > 1 {
            topo = topo.with_cluster_span(self.cluster_span);
            topo.host_ram_gb = self.host_ram_gb;
        }
        if self.n_nodes > 1 {
            topo = topo.as_member(self.node_id, self.n_nodes, self.host_ram_gb);
        }
        Placement {
            shard: self.shard,
            topo,
            coalesce: self.coalesce,
            spill: self.spill,
            replicate_top: if self.devices > 1 { self.replicate_top } else { 0 },
            little_frac: self.little_frac,
        }
    }

    /// The ExpertMode the engine computes with under this system.
    pub fn expert_mode(&self) -> ExpertMode {
        match self.kind {
            SystemKind::Floe => ExpertMode::Floe { level: self.sparsity },
            SystemKind::NaiveOffload => ExpertMode::Dense,
            SystemKind::AdvancedOffload => ExpertMode::Uniform { bits: self.quant_bits },
            SystemKind::Fiddler => ExpertMode::Dense,
            SystemKind::GpuResident => ExpertMode::Uniform { bits: 2 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residency_defaults_to_lru() {
        assert_eq!(
            SystemConfig::new(SystemKind::Floe).residency,
            ResidencyKind::Lru
        );
        assert_eq!(
            SystemConfig::with_residency(SystemKind::Floe, ResidencyKind::Sparsity)
                .residency,
            ResidencyKind::Sparsity
        );
    }

    #[test]
    fn with_devices_turns_cooperation_on_only_when_sharded() {
        let single = SystemConfig::new(SystemKind::Floe);
        assert_eq!(single.devices, 1);
        assert!(!single.coalesce && !single.spill);
        let p1 = single.placement(crate::hwsim::PCIE4);
        assert_eq!(p1.n_devices(), 1);
        let sharded = SystemConfig::new(SystemKind::Floe).with_devices(3, ShardPolicy::Expert);
        assert!(sharded.coalesce && sharded.spill);
        assert_eq!(sharded.replicate_top, 0, "replication stays opt-in");
        assert!(!sharded.compute_streams, "streams stay opt-in");
        let p3 = sharded.placement(crate::hwsim::PCIE4);
        assert_eq!(p3.n_devices(), 3);
        assert_eq!(p3.home((0, 4)), 1);
        // degenerate sharding stays single-device semantics
        let one = SystemConfig::new(SystemKind::Floe).with_devices(1, ShardPolicy::Hash);
        assert!(!one.coalesce && !one.spill);
        // replication threads into the placement, but never at one device
        let pop = SystemConfig::new(SystemKind::Floe)
            .with_devices(2, ShardPolicy::Balanced)
            .with_replication(2);
        assert_eq!(pop.replicate_top, 2);
        assert!(pop.compute_streams);
        assert_eq!(pop.placement(crate::hwsim::PCIE4).replicate_top, 2);
        let solo = SystemConfig::new(SystemKind::Floe).with_replication(2);
        assert_eq!(solo.replicate_top, 0);
        assert_eq!(solo.placement(crate::hwsim::PCIE4).replicate_top, 0);
    }

    #[test]
    fn overlap_and_hetero_stay_opt_in() {
        let base = SystemConfig::new(SystemKind::Floe);
        assert!(!base.overlap && !base.hetero_fleet);
        let on = SystemConfig::new(SystemKind::Floe)
            .with_devices(2, ShardPolicy::Balanced)
            .with_overlap(true)
            .with_hetero_fleet(true);
        assert!(on.overlap && on.hetero_fleet);
        let topo = on.placement(crate::hwsim::PCIE4).topo;
        assert_eq!(topo.gemv_scale.len(), 2);
        assert!(
            topo.gemv_scale[1] < topo.gemv_scale[0],
            "hetero fleets descend in GEMV throughput"
        );
    }

    #[test]
    fn cluster_forms_stay_opt_in_and_thread_into_the_topology() {
        let base = SystemConfig::new(SystemKind::Floe).with_devices(2, ShardPolicy::Layer);
        assert_eq!((base.cluster_span, base.n_nodes, base.node_id), (1, 1, 0));
        assert!(!base.placement(crate::hwsim::PCIE4).topo.clustered());
        let span = base.clone().with_cluster_span(2);
        let t = span.placement(crate::hwsim::PCIE4).topo;
        assert_eq!(t.span_nodes, 2);
        assert_eq!(t.node_of(1), 1);
        let member = base.as_cluster_member(1, 3, 8.0);
        let t = member.placement(crate::hwsim::PCIE4).topo;
        assert_eq!((t.n_nodes, t.node_id, t.span_nodes), (3, 1, 1));
        assert_eq!(t.host_ram_gb, 8.0);
    }

    #[test]
    fn modes_match_systems() {
        assert_eq!(
            SystemConfig::new(SystemKind::Floe).expert_mode(),
            ExpertMode::Floe { level: 0.9 }
        );
        assert_eq!(
            SystemConfig::new(SystemKind::GpuResident).expert_mode(),
            ExpertMode::Uniform { bits: 2 }
        );
    }
}

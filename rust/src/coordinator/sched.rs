//! Continuous-batching scheduler (DESIGN.md §6) — the serving control
//! loop shared by the real coordinator and the discrete-event simulator.
//!
//! Requests enter a FIFO admission queue; at every token boundary the
//! scheduler tops the in-flight decode batch up to `max_batch` (strictly
//! in arrival order — no starvation), steps the whole batch through ONE
//! boundary-synchronous `SeqBackend::step_batch` call, and retires
//! finished sequences immediately so their slot is reusable at the very
//! next boundary. The backend abstraction (`SeqBackend`) is what lets one
//! scheduler drive both execution substrates:
//! `coordinator::serve::Coordinator` (real PJRT compute on a wall
//! timeline, batch-stepped through `Engine::decode_batch` so
//! same-boundary expert GEMVs share real compute) and
//! `coordinator::sim::SimServeBackend` (roofline latencies on a virtual
//! timeline), so scheduler behavior — and its tests — cover the serving
//! path without artifacts.
//!
//! Per-request accounting: queue wait (arrival → admission, in the
//! backend's time base), prefill/decode compute, the attributed stall
//! decomposition (demand-fetch vs prefetch-miss, read back from
//! `ExpertStore`'s per-requester ledger), and the peak batch size the
//! request decoded in.

use std::collections::VecDeque;

use anyhow::Result;

use crate::store::{DegradeCount, FaultCause, StallSplit, StoreStats};

use super::serve::Request;

/// Read-only view of a backend's store accounting, used by the `stats`
/// protocol command and by timeline artifacts (`coordinator::timeline`).
/// `None` for backends without an expert store.
#[derive(Debug, Clone)]
pub struct BackendSnapshot {
    pub stats: StoreStats,
    pub cache_hit_rate: f64,
}

/// Outcome of decoding one token for one sequence.
#[derive(Debug, Clone)]
pub struct SeqStep {
    /// byte emitted by the sampler (None when the backend has no text,
    /// e.g. the simulator)
    pub token: Option<u8>,
    pub finished: bool,
    /// compute time for this token, µs (excludes attributed stalls)
    pub compute_us: f64,
}

/// One decode substrate the scheduler can drive. All methods run on the
/// single coordinator thread — backends need not be `Send`.
pub trait SeqBackend {
    /// Per-sequence decode state.
    type Seq;

    /// The scheduler's time base, µs: wall time for the real coordinator,
    /// the store's virtual timeline for the simulator.
    fn now_us(&self) -> f64;

    /// Called once per token boundary, before the batch steps (the
    /// simulator uses it to reset same-boundary expert reuse tracking).
    fn on_boundary(&mut self) {}

    /// Admit a request: process its prompt and return the sequence state
    /// plus prefill compute µs. Stalls charged during prefill must be
    /// attributed to `req.id`.
    fn start(&mut self, req: &Request) -> Result<(Self::Seq, f64)>;

    /// Decode one token for `seq`, attributing stalls to its request.
    fn step(&mut self, seq: &mut Self::Seq) -> Result<SeqStep>;

    /// The system drained before the next request arrives: advance the
    /// backend's time base to `t_us` as *idle* time (never a stall).
    /// Wall-clock backends ignore this (time passes on its own); virtual
    /// timelines (the simulator) jump their clock — the event-driven
    /// backend routes the jump through its heap as a `RequestArrival`
    /// event so idle gaps appear in the event log like any other wait.
    fn idle_until(&mut self, _t_us: f64) {}

    /// Decode one token for EVERY sequence at a token boundary. Backends
    /// that can share work across the batch override this — the real
    /// coordinator steps the whole batch through `Engine::decode_batch`,
    /// so same-boundary expert GEMVs are grouped and each distinct
    /// expert's weights are touched once. The default preserves the
    /// sequential semantics exactly: one `step` per sequence, in batch
    /// order, each failure isolated to its own slot.
    fn step_batch(&mut self, seqs: &mut [&mut Self::Seq]) -> Vec<Result<SeqStep>> {
        seqs.iter_mut().map(|s| self.step(s)).collect()
    }

    /// Cumulative attributed stall decomposition for request `id`.
    fn stalls_of(&self, id: u64) -> StallSplit;

    /// Request `id` finished: return its final stall decomposition and
    /// release any per-request accounting (store-backed backends fold
    /// the attribution-ledger entry into the retired bucket via
    /// `take_attribution`, so the ledger stays bounded by the in-flight
    /// batch on long-running servers). Defaults to a plain read for
    /// backends without per-request state.
    fn retire(&mut self, id: u64) -> StallSplit {
        self.stalls_of(id)
    }

    /// Cumulative degraded-boundary accounting for request `id`
    /// (quality-elastic fallback, DESIGN.md §11). Zero for backends
    /// without a little tier.
    fn degraded_of(&self, _id: u64) -> DegradeCount {
        DegradeCount::default()
    }

    /// Request `id` finished: return its degraded-boundary accounting
    /// and release the ledger entry — the degraded-ledger mirror of
    /// `retire`. Defaults to a plain read for backends without
    /// per-request state.
    fn take_degraded(&mut self, id: u64) -> DegradeCount {
        self.degraded_of(id)
    }

    /// Request `id` finished: drain the structured fault cause the
    /// backend recorded for it, if any (DESIGN.md §12 — link outage
    /// under fail-fast, exhausted retries). `None` for backends without
    /// fault injection, and for every request that never hit a fault.
    fn take_fault_cause(&mut self, _id: u64) -> Option<FaultCause> {
        None
    }

    /// Snapshot of the backend's store accounting (globals + per-device
    /// sums + cache hit rate) for the inspector. Defaults to `None` for
    /// backends without a store.
    fn snapshot(&self) -> Option<BackendSnapshot> {
        None
    }

    /// The event core's popped-event byte log (17 bytes per pop; empty
    /// unless the backend was built with event logging on).
    fn event_log_bytes(&self) -> &[u8] {
        &[]
    }
}

impl<'a, B: SeqBackend> SeqBackend for &'a mut B {
    type Seq = B::Seq;
    fn now_us(&self) -> f64 {
        (**self).now_us()
    }
    fn on_boundary(&mut self) {
        (**self).on_boundary();
    }
    fn start(&mut self, req: &Request) -> Result<(Self::Seq, f64)> {
        (**self).start(req)
    }
    fn step(&mut self, seq: &mut Self::Seq) -> Result<SeqStep> {
        (**self).step(seq)
    }
    fn idle_until(&mut self, t_us: f64) {
        (**self).idle_until(t_us)
    }
    fn step_batch(&mut self, seqs: &mut [&mut Self::Seq]) -> Vec<Result<SeqStep>> {
        (**self).step_batch(seqs)
    }
    fn stalls_of(&self, id: u64) -> StallSplit {
        (**self).stalls_of(id)
    }
    fn retire(&mut self, id: u64) -> StallSplit {
        (**self).retire(id)
    }
    fn degraded_of(&self, id: u64) -> DegradeCount {
        (**self).degraded_of(id)
    }
    fn take_degraded(&mut self, id: u64) -> DegradeCount {
        (**self).take_degraded(id)
    }
    fn take_fault_cause(&mut self, id: u64) -> Option<FaultCause> {
        (**self).take_fault_cause(id)
    }
    fn snapshot(&self) -> Option<BackendSnapshot> {
        (**self).snapshot()
    }
    fn event_log_bytes(&self) -> &[u8] {
        (**self).event_log_bytes()
    }
}

/// A finished request with its full serving accounting.
#[derive(Debug, Clone)]
pub struct ServeCompletion {
    pub id: u64,
    pub text: Vec<u8>,
    pub tokens: usize,
    /// when the request entered the admission queue, backend µs
    pub arrival_us: f64,
    /// arrival → admission (prefill start)
    pub queue_wait_us: f64,
    /// prefill compute µs
    pub prefill_us: f64,
    /// decode compute µs (stalls excluded)
    pub decode_us: f64,
    /// attributed stall decomposition (demand-fetch vs prefetch-miss)
    pub stall: StallSplit,
    /// degraded-boundary accounting (quality-elastic fallback,
    /// DESIGN.md §11): boundaries this request resolved on the
    /// little tier, and the demand bytes those resolutions avoided
    pub degraded: DegradeCount,
    /// the request's SLO budget, echoed back for the client
    pub slo_us: Option<f64>,
    /// largest decode batch this request was part of
    pub batch_peak: usize,
    pub finished_us: f64,
    /// backend failure (bad prompt, engine error): the request retired
    /// without finishing; accounting covers work done up to the failure
    pub error: Option<String>,
    /// structured cause when the failure was an injected fault
    /// (DESIGN.md §12) — echoed in the protocol response alongside the
    /// partial `text`/`tokens` emitted before the fault
    pub fault_cause: Option<FaultCause>,
}

impl ServeCompletion {
    pub fn stall_us(&self) -> f64 {
        self.stall.total_us()
    }
    /// decode TPS counting compute only.
    pub fn compute_tps(&self) -> f64 {
        self.tokens as f64 / (self.decode_us / 1e6).max(1e-9)
    }
    /// decode TPS counting compute + attributed stalls.
    pub fn effective_tps(&self) -> f64 {
        self.tokens as f64 / ((self.decode_us + self.stall.total_us()) / 1e6).max(1e-9)
    }
    /// arrival → completion.
    pub fn latency_us(&self) -> f64 {
        self.finished_us - self.arrival_us
    }
}

struct ActiveSeq<S> {
    id: u64,
    seq: S,
    out: Vec<u8>,
    tokens: usize,
    arrival_us: f64,
    admitted_us: f64,
    prefill_us: f64,
    decode_us: f64,
    batch_peak: usize,
    slo_us: Option<f64>,
}

/// The continuous-batching scheduler over one `SeqBackend`.
pub struct Scheduler<B: SeqBackend> {
    backend: B,
    pending: VecDeque<(Request, f64)>,
    active: Vec<ActiveSeq<B::Seq>>,
    max_batch: usize,
    admitted_order: Vec<u64>,
    max_batch_seen: usize,
}

impl<B: SeqBackend> Scheduler<B> {
    pub fn new(backend: B, max_batch: usize) -> Self {
        Scheduler {
            backend,
            pending: VecDeque::new(),
            active: Vec::new(),
            max_batch: max_batch.max(1),
            admitted_order: Vec::new(),
            max_batch_seen: 0,
        }
    }

    /// Queue a request arriving now.
    pub fn enqueue(&mut self, req: Request) {
        let now = self.backend.now_us();
        self.enqueue_at(req, now);
    }

    /// Queue a request with an explicit arrival stamp (load replay: the
    /// arrival may predate the token boundary that observes it).
    pub fn enqueue_at(&mut self, req: Request, arrival_us: f64) {
        self.pending.push_back((req, arrival_us));
    }

    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || !self.active.is_empty()
    }
    pub fn has_active(&self) -> bool {
        !self.active.is_empty()
    }
    pub fn active_len(&self) -> usize {
        self.active.len()
    }
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }
    /// Arrival stamp of the queue head — the earliest still-pending
    /// arrival when requests were enqueued in arrival order. Drivers
    /// idle the backend to this stamp when the batch is empty.
    pub fn next_pending_arrival(&self) -> Option<f64> {
        self.pending.front().map(|(_, t)| *t)
    }
    /// Largest batch any boundary decoded.
    pub fn max_batch_seen(&self) -> usize {
        self.max_batch_seen
    }
    /// Request ids in the order they were admitted (FIFO check).
    pub fn admitted_order(&self) -> &[u64] {
        &self.admitted_order
    }
    pub fn backend(&self) -> &B {
        &self.backend
    }
    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }
    pub fn into_backend(self) -> B {
        self.backend
    }

    /// One token boundary: admit *ripe* pending requests (FIFO) up to
    /// the batch cap, then decode one token for every active sequence.
    /// Finished sequences retire immediately and are returned. Backend
    /// failures retire the affected sequence as an error completion —
    /// one bad request must never take the batch (or the server) down.
    ///
    /// Ripeness: a request whose arrival stamp is still in the future is
    /// not admitted — whole traces can be enqueued up front and the
    /// scheduler observes each arrival at the first boundary at or after
    /// its stamp. The gate captures `now` once, *before* any admission:
    /// a prefill advancing the clock past a later request's arrival must
    /// not pull that request into the same boundary (it was not in the
    /// queue yet under lazy per-boundary enqueueing, which this
    /// reproduces bit-exactly). When the batch has drained and the queue
    /// head has not arrived yet, the boundary idles the backend to the
    /// head's stamp first (a `RequestArrival` event on event-driven
    /// backends) — arrival→admission latency is event-timed, not polled
    /// by the driver.
    pub fn step(&mut self) -> Vec<ServeCompletion> {
        let mut done = Vec::new();
        if self.active.is_empty() {
            if let Some(t) = self.next_pending_arrival() {
                if t > self.backend.now_us() {
                    self.backend.idle_until(t);
                }
            }
        }
        let ripe_before = self.backend.now_us();
        while self.active.len() < self.max_batch {
            match self.pending.front() {
                Some((_, arrival_us)) if *arrival_us > ripe_before => break,
                None => break,
                Some(_) => {}
            }
            let Some((req, arrival_us)) = self.pending.pop_front() else {
                break;
            };
            let admitted_us = self.backend.now_us();
            let id = req.id;
            let slo_us = req.slo_us;
            let (seq, prefill_us) = match self.backend.start(&req) {
                Ok(v) => v,
                Err(e) => {
                    done.push(self.retired(
                        id,
                        Vec::new(),
                        0,
                        arrival_us,
                        admitted_us,
                        0.0,
                        0.0,
                        0,
                        slo_us,
                        Some(format!("{e:#}")),
                    ));
                    continue;
                }
            };
            self.admitted_order.push(id);
            self.active.push(ActiveSeq {
                id,
                seq,
                out: Vec::new(),
                tokens: 0,
                arrival_us,
                admitted_us,
                prefill_us,
                decode_us: 0.0,
                batch_peak: 0,
                slo_us,
            });
        }
        let batch = self.active.len();
        self.max_batch_seen = self.max_batch_seen.max(batch);
        self.backend.on_boundary();
        // one boundary-synchronous step for the whole batch: the backend
        // decides how much work the sequences share (the real coordinator
        // groups same-boundary expert GEMVs; the simulator's default
        // sequential stepping models the sharing on its virtual timeline).
        // results[k] corresponds to active[k] — admission order.
        let results = {
            let mut refs: Vec<&mut B::Seq> = self
                .active
                .iter_mut()
                .map(|a| {
                    a.batch_peak = a.batch_peak.max(batch);
                    &mut a.seq
                })
                .collect();
            self.backend.step_batch(&mut refs)
        };
        debug_assert_eq!(results.len(), self.active.len());
        // retire finished/failed sequences in batch order. finished_us is
        // stamped after the whole batch stepped — under layer-lockstep
        // execution a token completes at the batch's boundary barrier.
        let mut removed = 0;
        for (k, res) in results.into_iter().enumerate() {
            let idx = k - removed;
            let error = match res {
                Ok(st) => {
                    let a = &mut self.active[idx];
                    if let Some(t) = st.token {
                        a.out.push(t);
                    }
                    a.tokens += 1;
                    a.decode_us += st.compute_us;
                    if !st.finished {
                        continue;
                    }
                    None
                }
                Err(e) => Some(format!("{e:#}")),
            };
            let a = self.active.remove(idx);
            removed += 1;
            done.push(self.retired(
                a.id,
                a.out,
                a.tokens,
                a.arrival_us,
                a.admitted_us,
                a.prefill_us,
                a.decode_us,
                a.batch_peak,
                a.slo_us,
                error,
            ));
        }
        done
    }

    #[allow(clippy::too_many_arguments)]
    fn retired(
        &mut self,
        id: u64,
        text: Vec<u8>,
        tokens: usize,
        arrival_us: f64,
        admitted_us: f64,
        prefill_us: f64,
        decode_us: f64,
        batch_peak: usize,
        slo_us: Option<f64>,
        error: Option<String>,
    ) -> ServeCompletion {
        ServeCompletion {
            id,
            text,
            tokens,
            arrival_us,
            queue_wait_us: (admitted_us - arrival_us).max(0.0),
            prefill_us,
            decode_us,
            // retire, don't just read: the backend's attribution-ledger
            // entry folds into its retired bucket so long-running servers
            // never accumulate entries for finished requests
            stall: self.backend.retire(id),
            degraded: self.backend.take_degraded(id),
            slo_us,
            batch_peak,
            finished_us: self.backend.now_us(),
            error,
            // drained unconditionally so the backend's per-request fault
            // ledger stays bounded, like the stall/degraded ledgers
            fault_cause: self.backend.take_fault_cause(id),
        }
    }

    /// Node failure with NO survivors (cluster tier, DESIGN.md §10/§12):
    /// retire every in-flight sequence as an error completion through
    /// the standard retirement path — accounting and the partial `text`
    /// cover the work done up to the failure, and `cause` is attached as
    /// the structured `fault_cause` (unless the backend recorded a more
    /// specific one). The pending queue is untouched (survivor nodes
    /// re-admit it via `drain_pending`).
    pub fn fail_active(&mut self, error: &str, cause: FaultCause) -> Vec<ServeCompletion> {
        let mut done = Vec::new();
        while !self.active.is_empty() {
            let a = self.active.remove(0);
            let mut c = self.retired(
                a.id,
                a.out,
                a.tokens,
                a.arrival_us,
                a.admitted_us,
                a.prefill_us,
                a.decode_us,
                a.batch_peak,
                a.slo_us,
                Some(error.to_string()),
            );
            c.fault_cause.get_or_insert(cause);
            done.push(c);
        }
        done
    }

    /// Node failure WITH survivors (DESIGN.md §12): abort every
    /// in-flight sequence *without* producing completions — the cluster
    /// driver re-dispatches the original requests to surviving nodes,
    /// where they restart value-idempotently (per-request seeds) and
    /// retire exactly once. Per-request backend ledgers (stall,
    /// degraded, fault, retry) are drained and discarded here: the
    /// aborted partial work died with the node and must not leak into
    /// the survivor's accounting of the restarted run. Returns the
    /// aborted request ids in batch order.
    pub fn abort_active(&mut self) -> Vec<u64> {
        let mut ids = Vec::new();
        while !self.active.is_empty() {
            let a = self.active.remove(0);
            let _ = self.backend.retire(a.id);
            let _ = self.backend.take_degraded(a.id);
            let _ = self.backend.take_fault_cause(a.id);
            ids.push(a.id);
        }
        ids
    }

    /// Remove and return every still-queued request with its arrival
    /// stamp (failure re-routing: survivor nodes re-admit these with
    /// their original arrivals so queue-wait accounting stays honest).
    pub fn drain_pending(&mut self) -> Vec<(Request, f64)> {
        self.pending.drain(..).collect()
    }

    /// Step until the queue and the batch are empty. `step` itself idles
    /// an empty batch to the queue head's arrival stamp, so a whole
    /// trace enqueued up front drains without the driver polling the
    /// clock — backends whose `idle_until` is a no-op (wall clocks) only
    /// reach that idle when time genuinely passes on its own.
    pub fn drain(&mut self) -> Vec<ServeCompletion> {
        let mut out = Vec::new();
        while self.has_work() {
            out.extend(self.step());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fake backend: each token advances a virtual clock by 10µs; a
    /// request's length is its `max_tokens`; stalls are 1µs per token
    /// charged as demand. Requests with `seed == POISON` fail at start;
    /// `seed == POISON_STEP` fail at their first decode step.
    const POISON: u64 = u64::MAX;
    const POISON_STEP: u64 = u64::MAX - 1;

    struct Fake {
        now: f64,
        stalls: std::collections::BTreeMap<u64, StallSplit>,
        boundaries: usize,
    }
    struct FakeSeq {
        id: u64,
        left: usize,
        poisoned: bool,
    }
    impl SeqBackend for Fake {
        type Seq = FakeSeq;
        fn now_us(&self) -> f64 {
            self.now
        }
        fn on_boundary(&mut self) {
            self.boundaries += 1;
        }
        fn start(&mut self, req: &Request) -> Result<(FakeSeq, f64)> {
            if req.seed == POISON {
                anyhow::bail!("poisoned prompt");
            }
            self.now += 5.0;
            Ok((
                FakeSeq {
                    id: req.id,
                    left: req.max_tokens,
                    poisoned: req.seed == POISON_STEP,
                },
                5.0,
            ))
        }
        fn step(&mut self, s: &mut FakeSeq) -> Result<SeqStep> {
            if s.poisoned {
                anyhow::bail!("poisoned step");
            }
            self.now += 10.0;
            self.stalls.entry(s.id).or_default().demand_us += 1.0;
            s.left -= 1;
            Ok(SeqStep {
                token: Some(b'a'),
                finished: s.left == 0,
                compute_us: 10.0,
            })
        }
        fn stalls_of(&self, id: u64) -> StallSplit {
            self.stalls.get(&id).copied().unwrap_or_default()
        }
    }

    fn req(id: u64, tokens: usize) -> Request {
        Request {
            id,
            prompt: vec![b'x'],
            max_tokens: tokens,
            temperature: 0.0,
            seed: id,
            slo_us: None,
        }
    }

    #[test]
    fn fifo_admission_and_cap() {
        let fake = Fake { now: 0.0, stalls: Default::default(), boundaries: 0 };
        let mut s = Scheduler::new(fake, 2);
        for i in 0..4 {
            s.enqueue(req(i, 3));
        }
        let done = s.drain();
        assert_eq!(done.len(), 4);
        assert_eq!(s.admitted_order(), &[0, 1, 2, 3]);
        assert_eq!(s.max_batch_seen(), 2);
        assert!(s.backend().boundaries >= 6, "{}", s.backend().boundaries);
        for c in &done {
            assert_eq!(c.tokens, 3);
            assert_eq!(c.text, b"aaa");
            assert!(c.batch_peak <= 2 && c.batch_peak >= 1);
            assert_eq!(c.stall.demand_us, 3.0);
            assert_eq!(c.decode_us, 30.0);
            assert!(c.error.is_none());
        }
    }

    #[test]
    fn retired_slot_reused_at_next_boundary() {
        let fake = Fake { now: 0.0, stalls: Default::default(), boundaries: 0 };
        let mut s = Scheduler::new(fake, 2);
        s.enqueue(req(0, 1)); // finishes at the first boundary
        s.enqueue(req(1, 4));
        s.enqueue(req(2, 4)); // must join as soon as 0 retires
        let first = s.step();
        assert_eq!(first.len(), 1);
        assert_eq!(first[0].id, 0);
        assert_eq!(s.active_len(), 1);
        let _ = s.step();
        assert_eq!(s.active_len(), 2, "freed slot not refilled");
        let rest = s.drain();
        assert_eq!(rest.len(), 2);
    }

    #[test]
    fn queue_wait_counts_time_before_admission() {
        let fake = Fake { now: 0.0, stalls: Default::default(), boundaries: 0 };
        let mut s = Scheduler::new(fake, 1);
        s.enqueue(req(0, 2));
        s.enqueue(req(1, 2));
        let done = s.drain();
        let c1 = done.iter().find(|c| c.id == 1).unwrap();
        // request 1 waited through request 0's prefill + 2 tokens
        assert!(c1.queue_wait_us >= 25.0, "{}", c1.queue_wait_us);
        let c0 = done.iter().find(|c| c.id == 0).unwrap();
        assert_eq!(c0.queue_wait_us, 0.0);
    }

    #[test]
    fn zero_cap_is_clamped_to_one() {
        let fake = Fake { now: 0.0, stalls: Default::default(), boundaries: 0 };
        let mut s = Scheduler::new(fake, 0);
        s.enqueue(req(0, 1));
        assert_eq!(s.drain().len(), 1);
        assert_eq!(s.max_batch_seen(), 1);
    }

    /// Backend that overrides `step_batch` (like the real coordinator):
    /// the scheduler must hand it the whole active batch at once, and
    /// per-slot failures must still retire only their own sequence.
    struct BatchingFake {
        inner: Fake,
        batch_sizes: Vec<usize>,
    }
    impl SeqBackend for BatchingFake {
        type Seq = FakeSeq;
        fn now_us(&self) -> f64 {
            self.inner.now_us()
        }
        fn on_boundary(&mut self) {
            self.inner.on_boundary();
        }
        fn start(&mut self, req: &Request) -> Result<(FakeSeq, f64)> {
            self.inner.start(req)
        }
        fn step(&mut self, s: &mut FakeSeq) -> Result<SeqStep> {
            self.inner.step(s)
        }
        fn step_batch(&mut self, seqs: &mut [&mut FakeSeq]) -> Vec<Result<SeqStep>> {
            self.batch_sizes.push(seqs.len());
            seqs.iter_mut().map(|s| self.inner.step(s)).collect()
        }
        fn stalls_of(&self, id: u64) -> StallSplit {
            self.inner.stalls_of(id)
        }
    }

    #[test]
    fn scheduler_steps_the_whole_batch_through_step_batch() {
        let fake = Fake { now: 0.0, stalls: Default::default(), boundaries: 0 };
        let mut s = Scheduler::new(BatchingFake { inner: fake, batch_sizes: Vec::new() }, 3);
        s.enqueue(req(0, 1)); // retires at the first boundary
        s.enqueue(req(1, 3));
        s.enqueue(Request { seed: POISON_STEP, ..req(2, 3) }); // fails at step
        s.enqueue(req(3, 3)); // joins once a slot frees
        let done = s.drain();
        assert_eq!(done.len(), 4);
        let sizes = &s.backend().batch_sizes;
        assert_eq!(sizes[0], 3, "first boundary must batch all co-admitted seqs");
        assert!(sizes.iter().all(|&b| b >= 1 && b <= 3));
        let by_id = |id: u64| done.iter().find(|c| c.id == id).unwrap();
        assert!(by_id(2).error.is_some(), "poisoned slot retires with its error");
        for id in [0, 1, 3] {
            assert!(by_id(id).error.is_none(), "healthy seqs unaffected by slot failure");
        }
        assert_eq!(by_id(1).tokens, 3);
    }

    #[test]
    fn backend_errors_retire_only_the_failing_request() {
        let fake = Fake { now: 0.0, stalls: Default::default(), boundaries: 0 };
        let mut s = Scheduler::new(fake, 3);
        s.enqueue(req(0, 2));
        s.enqueue(Request { seed: POISON, ..req(1, 2) }); // fails at start
        s.enqueue(Request { seed: POISON_STEP, ..req(2, 2) }); // fails at step
        s.enqueue(req(3, 2));
        let done = s.drain();
        assert_eq!(done.len(), 4, "failures must still produce completions");
        let by_id = |id: u64| done.iter().find(|c| c.id == id).unwrap();
        assert!(by_id(1).error.as_deref().unwrap().contains("poisoned prompt"));
        assert!(by_id(2).error.as_deref().unwrap().contains("poisoned step"));
        // the healthy requests finished untouched
        for id in [0, 3] {
            let c = by_id(id);
            assert!(c.error.is_none());
            assert_eq!(c.tokens, 2);
        }
    }

    #[test]
    fn fail_active_carries_partial_output_and_fault_cause() {
        let fake = Fake { now: 0.0, stalls: Default::default(), boundaries: 0 };
        let mut s = Scheduler::new(fake, 2);
        s.enqueue(req(0, 5));
        s.enqueue(req(1, 5));
        let _ = s.step(); // both decoded one token before the fault
        let done = s.fail_active("node 1 failed", FaultCause::NodeDown);
        assert_eq!(done.len(), 2);
        for c in &done {
            assert!(c.error.is_some());
            assert_eq!(c.fault_cause, Some(FaultCause::NodeDown));
            assert_eq!(c.tokens, 1, "pre-fault tokens survive in the completion");
            assert_eq!(c.text, b"a");
        }
        // ordinary (non-fault) completions carry no cause
        s.enqueue(req(2, 1));
        let ok = s.drain();
        assert_eq!(ok.len(), 1);
        assert!(ok[0].error.is_none() && ok[0].fault_cause.is_none());
    }

    #[test]
    fn abort_active_releases_sequences_without_completions() {
        let fake = Fake { now: 0.0, stalls: Default::default(), boundaries: 0 };
        let mut s = Scheduler::new(fake, 2);
        s.enqueue(req(7, 5));
        s.enqueue(req(8, 5));
        s.enqueue(req(9, 5)); // still pending at the fault
        let _ = s.step();
        let ids = s.abort_active();
        assert_eq!(ids, vec![7, 8], "aborted in batch order, no completions");
        assert_eq!(s.active_len(), 0);
        assert_eq!(s.pending_len(), 1, "the queue survives for drain_pending");
        let rest = s.drain();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].id, 9);
    }
}

//! floe — CLI for the FloE reproduction.
//!
//! Subcommands:
//!   generate   one-off generation through the engine
//!   serve      line-JSON TCP server (see server.rs)
//!   record     record a simulated serving session as a timeline artifact
//!   replay     re-drive a recorded artifact, assert bit-exact, inspect
//!   eval       perplexity + probe accuracy for one compression mode
//!   exp-*      regenerate a paper table/figure (DESIGN.md §5 index)
//!   exp-all    everything (EXPERIMENTS.md source of truth)

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use floe::config::{ExpertMode, ResidencyKind, ShardPolicy};
use floe::coordinator::policy::{SystemConfig, SystemKind};
use floe::coordinator::timeline::{self, ReplayError, SessionSpec, Timeline, WorkloadSource};
use floe::engine::{ComputePath, Engine, NoObserver};
use floe::experiments as exp;
use floe::experiments::fig3::EvalBudget;
use floe::model::tokenizer::ByteTokenizer;

struct Args {
    cmd: String,
    flags: HashMap<String, String>,
}

fn parse_args() -> Args {
    let mut it = std::env::args().skip(1);
    let cmd = it.next().unwrap_or_else(|| "help".to_string());
    let mut flags = HashMap::new();
    let mut key: Option<String> = None;
    for a in it {
        if let Some(k) = a.strip_prefix("--") {
            if let Some(prev) = key.take() {
                flags.insert(prev, "true".to_string());
            }
            key = Some(k.to_string());
        } else if let Some(k) = key.take() {
            flags.insert(k, a);
        }
    }
    if let Some(prev) = key.take() {
        flags.insert(prev, "true".to_string());
    }
    Args { cmd, flags }
}

impl Args {
    fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }
    fn usize(&self, k: &str, default: usize) -> usize {
        self.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    fn f64(&self, k: &str, default: f64) -> f64 {
        self.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    fn mode(&self) -> Result<ExpertMode> {
        let level = self.f64("level", 0.8);
        let bits = self.usize("bits", 2) as u8;
        Ok(match self.get("mode").unwrap_or("floe") {
            "dense" => ExpertMode::Dense,
            "sparse" | "floe-wup" => ExpertMode::Sparse { level },
            "floe" => ExpertMode::Floe { level },
            "cats" => ExpertMode::CatsGate { level },
            "chess" => ExpertMode::ChessGate { level },
            "down" => ExpertMode::DownSparse { level },
            "uniform" | "hqq" => ExpertMode::Uniform { bits },
            "floe-var" => ExpertMode::FloeVar { level, bits },
            other => bail!("unknown mode {other}"),
        })
    }
    fn residency(&self) -> Result<ResidencyKind> {
        ResidencyKind::parse(self.get("policy").unwrap_or("lru"))
    }
    fn devices(&self) -> usize {
        self.usize("devices", 1).max(1)
    }
    fn shard(&self) -> Result<ShardPolicy> {
        ShardPolicy::parse(self.get("shard-policy").unwrap_or("layer"))
    }
    fn sparsity_decay(&self) -> f64 {
        self.f64("sparsity-decay", floe::store::DEFAULT_SPARSITY_DECAY)
    }
    fn replicate_top(&self) -> usize {
        self.usize("replicate-top", 0)
    }
    fn compute_streams(&self) -> bool {
        self.get("compute-streams").is_some()
    }
    fn overlap(&self) -> bool {
        self.get("overlap").is_some()
    }
    fn hetero_fleet(&self) -> bool {
        self.get("hetero-fleet").is_some()
    }
    /// `--kernel-threads N`: size of the engine's native kernel pool
    /// (None = leave the engine at its available-cores default).
    fn kernel_threads(&self) -> Option<usize> {
        self.get("kernel-threads").and_then(|v| v.parse().ok())
    }
    /// `--slo-us N`: uniform per-request SLO budget, µs from admission
    /// (None = no budget; the quality-elastic fallback never fires).
    fn slo_us(&self) -> Option<f64> {
        self.get("slo-us").and_then(|v| v.parse().ok()).filter(|s: &f64| *s > 0.0)
    }
    /// `--little-frac F`: fraction of each device budget carved into the
    /// always-resident little-tier pool (0 = fallback off).
    fn little_frac(&self) -> f64 {
        self.f64("little-frac", 0.0)
    }
    fn budget(&self) -> EvalBudget {
        EvalBudget {
            n_bytes: self.usize("eval-bytes", 768),
            window: self.usize("window", 96),
            burn_in: self.usize("burn-in", 16),
        }
    }
}

fn main() -> Result<()> {
    let args = parse_args();
    let art = floe::artifacts_dir();
    match args.cmd.as_str() {
        "generate" => {
            let mut eng = Engine::load(&art)?;
            if args.get("pallas").is_some() {
                eng.path = ComputePath::HloPallas;
            } else if args.get("native").is_some() {
                eng.path = ComputePath::Native;
            }
            if let Some(t) = args.kernel_threads() {
                eng.set_kernel_threads(t);
            }
            let prompt = args.get("prompt").unwrap_or("the miller ").to_string();
            let mode = args.mode()?;
            let t0 = std::time::Instant::now();
            let out = eng.generate(
                prompt.as_bytes(),
                args.usize("tokens", 48),
                mode,
                args.f64("temperature", 0.0) as f32,
                args.usize("seed", 0) as u64,
                &mut NoObserver,
            )?;
            let dt = t0.elapsed().as_secs_f64();
            println!("{}{}", prompt, ByteTokenizer::decode(&out));
            eprintln!(
                "[{} tokens in {:.2}s = {:.1} tok/s, mode {:?}]",
                out.len(),
                dt,
                out.len() as f64 / dt,
                mode
            );
        }
        "serve" => {
            let kind = match args.get("system").unwrap_or("floe") {
                "floe" => SystemKind::Floe,
                "naive" => SystemKind::NaiveOffload,
                "advanced" => SystemKind::AdvancedOffload,
                "fiddler" => SystemKind::Fiddler,
                "resident" => SystemKind::GpuResident,
                other => bail!("unknown system {other}"),
            };
            let mut system = SystemConfig::with_residency(kind, args.residency()?)
                .with_devices(args.devices(), args.shard()?)
                .with_overlap(args.overlap());
            system.sparsity = args.f64("level", 0.8);
            system.sparsity_decay = args.sparsity_decay();
            system = system.with_little_frac(args.little_frac());
            if args.devices() > 1 {
                system.replicate_top = args.replicate_top();
                system.compute_streams = args.compute_streams();
                system = system.with_hetero_fleet(args.hetero_fleet());
            }
            let opts = floe::server::ServerOpts {
                port: args.usize("port", 7399) as u16,
                system,
                vram_budget_bytes: args.usize("vram-kb", 512) * 1024,
                max_requests: args.usize("max-requests", 0),
                max_batch: args.usize("max-batch", 8),
                gather_ms: args.usize("gather-ms", 0) as u64,
                record: args.get("record").map(PathBuf::from),
                read_timeout_ms: args.usize("read-timeout-ms", 30_000) as u64,
            };
            match args.get("backend").unwrap_or("real") {
                // full TCP path over the simulated coordinator: no
                // artifacts or pjrt needed (virtual timeline, Mixtral dims)
                "sim" => {
                    let params = floe::coordinator::sim::SimParams::mixtral_on(
                        floe::hwsim::RTX3090.clone(),
                        opts.system.clone(),
                        args.f64("vram", 14.0),
                    );
                    floe::server::serve_sim(params, opts)?;
                }
                "real" => floe::server::serve(&art, opts)?,
                other => bail!("unknown backend {other} (real|sim)"),
            }
        }
        // record a simulated serving session (the exp-serve-load system
        // shape) as a replayable timeline artifact, then print the
        // per-request inspector report over it
        "record" => {
            let mut p = exp::serveload::sweep_params(
                args.residency()?,
                args.f64("vram", exp::serveload::DEFAULT_VRAM_GB),
            );
            p.system = p
                .system
                .clone()
                .with_devices(args.devices(), args.shard()?)
                .with_overlap(args.overlap())
                .with_little_frac(args.little_frac());
            let spec = SessionSpec::from_params(
                &p,
                args.usize("cap", 4),
                WorkloadSource::Spec(floe::workload::WorkloadSpec {
                    n_requests: args.usize("requests", 12),
                    arrival_rate_hz: args.f64("rate", 8.0),
                    prompt_len: (8, 24),
                    output_tokens: (16, 48),
                    seed: args.usize("seed", 23) as u64,
                    slo_us: args.slo_us(),
                }),
            );
            let tl = timeline::record(&spec);
            let bytes = tl.to_bytes();
            let out = PathBuf::from(args.get("out").unwrap_or("serveload_timeline.fltl"));
            std::fs::write(&out, &bytes).with_context(|| format!("write {}", out.display()))?;
            println!("recorded {} bytes -> {}", bytes.len(), out.display());
            let obs = tl.obs.as_ref().expect("record attaches observations");
            println!("{}", timeline::inspect(obs).render());
        }
        // re-drive a recorded artifact through the simulator and assert
        // bit-exact reproduction; print the inspector report either way
        "replay" => {
            let path = PathBuf::from(
                args.get("artifact").context("replay requires --artifact <path>")?,
            );
            let bytes = std::fs::read(&path).with_context(|| format!("read {}", path.display()))?;
            let tl = Timeline::from_bytes(&bytes).map_err(|e| anyhow::anyhow!("{e}"))?;
            if tl.cluster.is_some() {
                // cluster artifact: re-drive the whole cluster session and
                // assert every node reproduced bit-exactly
                match timeline::replay_cluster(&tl) {
                    Ok(obs) => {
                        println!(
                            "cluster replay OK — bit-exact across {} nodes ({})",
                            obs.nodes.len(),
                            path.display()
                        );
                        let tokens: u64 = obs
                            .nodes
                            .iter()
                            .flat_map(|n| n.completions.iter())
                            .map(|c| c.tokens)
                            .sum();
                        println!(
                            "  {} requests, {tokens} tokens in {:.1} ms; errored {}, \
                             re-homed keys {}",
                            obs.assignments.len(),
                            obs.total_us / 1e3,
                            obs.errored,
                            obs.rehomed_keys
                        );
                        for (j, n) in obs.nodes.iter().enumerate() {
                            println!(
                                "  node {j}: {} completions, {} net pulls ({:.1} MB), {}",
                                n.completions.len(),
                                n.net_pulls,
                                n.net_bytes / 1e6,
                                if n.alive { "alive" } else { "down" }
                            );
                        }
                    }
                    Err(ReplayError::Diverged(d)) => {
                        eprintln!("{d}");
                        bail!("cluster replay diverged from the recorded session");
                    }
                    Err(e) => bail!("{}: {e}", path.display()),
                }
                return Ok(());
            }
            match timeline::replay(&tl) {
                Ok(obs) => {
                    println!("replay OK — bit-exact ({})", path.display());
                    println!("{}", timeline::inspect(&obs).render());
                }
                Err(ReplayError::NotReplayable) => match &tl.obs {
                    Some(obs) => {
                        println!(
                            "{}: live recording (not replayable); inspecting observations",
                            path.display()
                        );
                        println!("{}", timeline::inspect(obs).render());
                    }
                    None => bail!("{}: no observations to inspect", path.display()),
                },
                Err(ReplayError::Diverged(d)) => {
                    eprintln!("{d}");
                    bail!("replay diverged from the recorded session");
                }
                Err(e) => bail!("{}: {e}", path.display()),
            }
        }
        "eval" => {
            let mut eng = Engine::load(&art)?;
            if let Some(t) = args.kernel_threads() {
                eng.set_kernel_threads(t);
            }
            let data = floe::evalsuite::EvalData::load(&art)?;
            let mode = args.mode()?;
            let b = args.budget();
            let ppl = floe::evalsuite::perplexity(
                &mut eng, &data, mode, b.n_bytes, b.window, b.burn_in,
            )?;
            println!("mode {:?}: {:.4} nats/byte", mode, ppl);
            let scores = floe::evalsuite::probe_accuracy(
                &mut eng, &data, mode, args.usize("probes", 20),
            )?;
            for s in &scores {
                println!("  {:8} {:2}/{:2} = {:.2}", s.task, s.correct, s.total, s.accuracy());
            }
            println!("  mean accuracy {:.3}", floe::evalsuite::mean_accuracy(&scores));
        }
        "exp-fig2" => exp::fig2::run(&art)?,
        "exp-fig3a" => exp::fig3::run_fig3a(&art, &args.budget())?,
        "exp-fig3b" => exp::fig3::run_fig3b(&art, &args.budget())?,
        "exp-fig4" => exp::fig4::run(&art)?,
        "exp-fig6" => {
            exp::fig6::run(
                args.f64("vram", 12.0),
                args.residency()?,
                args.devices(),
                args.shard()?,
                args.sparsity_decay(),
            )?;
            if args.get("real").is_some() {
                exp::fig6::run_real(&art, args.usize("tokens", 48), args.residency()?)?;
            }
        }
        "exp-fig7" => exp::fig7::run(&art)?,
        "exp-fig8" => exp::fig8::run(
            args.residency()?,
            args.devices(),
            args.shard()?,
            args.sparsity_decay(),
        )?,
        "exp-policy-sweep" => exp::fig8::run_policy_sweep(args.sparsity_decay())?,
        "exp-serve-load" => exp::serveload::run(
            args.residency()?,
            args.usize("requests", 16),
            args.usize("seed", 7) as u64,
            args.f64("vram", exp::serveload::DEFAULT_VRAM_GB),
            args.devices(),
            args.shard()?,
            args.sparsity_decay(),
            args.overlap(),
        )?,
        "exp-chaos-sweep" => exp::chaos::run(
            args.usize("requests", 16),
            args.usize("seed", 7) as u64,
            args.f64("rate", 8.0),
            args.get("nodes").and_then(|v| v.parse().ok()),
        )?,
        "exp-cluster-sweep" => exp::cluster::run(
            args.usize("requests", 16),
            args.usize("seed", 7) as u64,
            args.f64("rate", 8.0),
            args.f64("vram-total", exp::cluster::AGGREGATE_VRAM_GB),
            args.get("nodes").and_then(|v| v.parse().ok()),
            args.get("devices").and_then(|v| v.parse().ok()),
        )?,
        "exp-quality-latency" => exp::quality::run(
            args.usize("requests", 12),
            args.usize("seed", 23) as u64,
            args.f64("little-frac", exp::quality::LITTLE_FRAC),
        )?,
        "exp-shard-sweep" => exp::shard::run(
            args.residency()?,
            args.usize("seed", 7) as u64,
            args.sparsity_decay(),
        )?,
        "exp-fig9" => exp::table3::run_fig9(&art, &args.budget(), args.usize("probes", 12))?,
        "exp-table1" => exp::table1::run(&art)?,
        "exp-table3" => exp::table3::run(&art, &args.budget(), args.usize("probes", 20))?,
        "exp-compression" => exp::table7::run_compression(&art)?,
        "exp-all" => {
            let b = args.budget();
            let decay = floe::store::DEFAULT_SPARSITY_DECAY;
            exp::fig2::run(&art)?;
            exp::table1::run(&art)?;
            exp::fig7::run(&art)?;
            exp::fig6::run(12.0, ResidencyKind::Lru, 1, ShardPolicy::Layer, decay)?;
            exp::fig6::run_real(&art, 32, ResidencyKind::Lru)?;
            exp::fig8::run(ResidencyKind::Lru, 1, ShardPolicy::Layer, decay)?;
            exp::fig8::run_policy_sweep(decay)?;
            exp::shard::run(ResidencyKind::Lru, 7, decay)?;
            exp::cluster::run(16, 7, 8.0, exp::cluster::AGGREGATE_VRAM_GB, None, None)?;
            exp::chaos::run(16, 7, 8.0, None)?;
            exp::quality::run(12, 23, exp::quality::LITTLE_FRAC)?;
            exp::serveload::run(
                ResidencyKind::Lru, 16, 7, exp::serveload::DEFAULT_VRAM_GB,
                1, ShardPolicy::Layer, decay, false,
            )?;
            exp::serveload::run(
                ResidencyKind::Lru, 16, 7, exp::serveload::DEFAULT_VRAM_GB,
                1, ShardPolicy::Layer, decay, true,
            )?;
            exp::fig4::run(&art)?;
            exp::table7::run_compression(&art)?;
            exp::fig3::run_fig3a(&art, &b)?;
            exp::fig3::run_fig3b(&art, &b)?;
            exp::table3::run(&art, &b, args.usize("probes", 20))?;
            exp::table3::run_fig9(&art, &b, args.usize("probes", 12))?;
        }
        _ => {
            println!(
                "floe — FloE (ICML 2025) reproduction\n\n\
                 usage: floe <cmd> [--flag value]...\n\n\
                 cmds: generate serve record replay eval exp-fig2 exp-fig3a \
                 exp-fig3b exp-fig4 exp-fig6 exp-fig7 exp-fig8 exp-fig9 \
                 exp-policy-sweep exp-quality-latency exp-serve-load \
                 exp-shard-sweep exp-cluster-sweep exp-chaos-sweep \
                 exp-table1 exp-table3 \
                 exp-compression exp-all\n\n\
                 common flags: --mode dense|sparse|floe|cats|chess|uniform \
                 --level 0.8 --bits 2 --policy lru|lfu|sparsity \
                 --sparsity-decay 0.999 --prompt '...' --tokens 48\n\
                 placement flags (serve, exp-fig6/8, exp-serve-load): \
                 --devices 1 --shard-policy layer|expert|hash|balanced \
                 (VRAM budgets are per device; --devices 1 reproduces the \
                 single-GPU numbers exactly; balanced re-homes experts by \
                 measured popularity)\n\
                 popularity flags (serve, --devices > 1): --replicate-top K \
                 (replicate the K hottest experts across devices) \
                 --compute-streams (per-device compute timelines — FLOP \
                 scaling, not just cache/bus scaling) \
                 --hetero-fleet (descending per-device GEMV throughput)\n\
                 event-core flags: --overlap (serve, exp-serve-load: \
                 transfer completions release waiting expert GEMVs \
                 mid-boundary instead of stalling at the barrier)\n\
                 engine flags (generate, eval): --kernel-threads N \
                 (native kernel pool size; default = available cores; \
                 1 reproduces single-threaded output bit-exactly)\n\
                 serve flags: --backend real|sim --max-batch 8 --gather-ms 0 \
                 --port 7399 --max-requests 0 --read-timeout-ms 30000 \
                 (drop a connection silent this long; 0 = never) \
                 --record session.fltl (write \
                 the session as a timeline artifact at exit; protocol cmd \
                 {{\"cmd\":\"stats\"}} returns the live inspector report, \
                 {{\"cmd\":\"shutdown\"}} drains in-flight requests, flushes \
                 the recording and exits 0)\n\
                 record flags: --out serveload_timeline.fltl --cap 4 \
                 --rate 8 --requests 12 --seed 23 --overlap (records the \
                 exp-serve-load system shape as a replayable artifact)\n\
                 replay flags: --artifact <path> (re-drives the recorded \
                 session and asserts bit-exact reproduction, then prints \
                 the per-request inspector report; cluster artifacts \
                 re-drive every node and cross-check per-node logs)\n\
                 cluster flags (exp-cluster-sweep): --nodes N --devices D \
                 (restrict the sweep to one cell) --requests 16 --rate 8 \
                 --vram-total 28.5 (aggregate expert-cache VRAM split \
                 evenly across all nodes x devices)\n\
                 chaos flags (exp-chaos-sweep): --nodes N (restrict to one \
                 node count) --requests 16 --rate 8 --seed 7 (deterministic \
                 fault schedules: link flap priced fail-fast vs retried, \
                 device drop, node drop + rejoin)\n\
                 quality flags (serve, record, exp-quality-latency): \
                 --slo-us N (per-request latency budget, us from \
                 admission) --little-frac 0.1 (device-budget fraction \
                 carved into the always-resident degraded tier; 0 turns \
                 the big-little fallback off and keeps runs bit-exact)\n\
                 env: FLOE_ARTIFACTS (default ./artifacts)"
            );
        }
    }
    Ok(())
}

//! Deterministic serving load generator: seeded Poisson arrival traces
//! with per-request prompt/output length draws, for `exp-serve-load`
//! sweeps and the scheduler property tests.
//!
//! Determinism is load-bearing (experiment reproducibility, property-test
//! shrinking): every draw threads through `util::rng::Rng` from
//! `WorkloadSpec::seed` — no `SystemTime`, no global state — so the same
//! spec reproduces a byte-identical trace on every run and platform
//! (`trace_bytes` is the canonical serialization the replay test hashes).

use crate::coordinator::serve::Request;
use crate::util::rng::Rng;

/// One request plus its arrival stamp on the serving timeline, µs.
#[derive(Clone, Debug, PartialEq)]
pub struct TimedRequest {
    pub arrival_us: f64,
    pub req: Request,
}

/// Generator parameters. Length ranges are half-open `[lo, hi)`.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub n_requests: usize,
    /// mean Poisson arrival rate, requests per second of serving time
    pub arrival_rate_hz: f64,
    pub prompt_len: (usize, usize),
    pub output_tokens: (usize, usize),
    pub seed: u64,
    /// Uniform per-request SLO budget stamped on every generated
    /// request (DESIGN.md §11). `None` (the default) leaves `slo_us`
    /// unset and consumes no RNG draws, so traces are byte-identical
    /// to pre-quality builds.
    pub slo_us: Option<f64>,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            n_requests: 16,
            arrival_rate_hz: 4.0,
            prompt_len: (8, 32),
            output_tokens: (16, 64),
            seed: 7,
            slo_us: None,
        }
    }
}

/// Generate the arrival trace: exponential inter-arrival gaps at
/// `arrival_rate_hz`, uniform length draws, lowercase-letter prompts.
/// Request ids are the arrival indices (the FIFO oracle of the scheduler
/// tests); sampler seeds derive from the spec seed so two specs differing
/// only in seed produce fully decorrelated traces.
pub fn generate(spec: &WorkloadSpec) -> Vec<TimedRequest> {
    assert!(spec.arrival_rate_hz > 0.0, "arrival rate must be positive");
    let mut rng = Rng::new(spec.seed);
    let mut t_us = 0.0f64;
    (0..spec.n_requests)
        .map(|i| {
            // exponential inter-arrival: -ln(1-u)/λ  (u in [0,1))
            t_us += -(1.0 - rng.f64()).ln() / spec.arrival_rate_hz * 1e6;
            let plen = draw(&mut rng, spec.prompt_len);
            let prompt: Vec<u8> =
                (0..plen).map(|_| b'a' + rng.below(26) as u8).collect();
            let max_tokens = draw(&mut rng, spec.output_tokens);
            TimedRequest {
                arrival_us: t_us,
                req: Request {
                    id: i as u64,
                    prompt,
                    max_tokens,
                    temperature: 0.0,
                    seed: spec.seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    slo_us: spec.slo_us,
                },
            }
        })
        .collect()
}

fn draw(rng: &mut Rng, (lo, hi): (usize, usize)) -> usize {
    assert!(lo < hi, "empty range {lo}..{hi}");
    rng.range(lo, hi)
}

/// Canonical byte serialization of a trace (replay/determinism checks):
/// arrival bits, id, lengths, sampler seed, prompt bytes — everything the
/// scheduler consumes.
pub fn trace_bytes(trace: &[TimedRequest]) -> Vec<u8> {
    let mut out = Vec::new();
    for t in trace {
        out.extend_from_slice(&t.arrival_us.to_bits().to_le_bytes());
        out.extend_from_slice(&t.req.id.to_le_bytes());
        out.extend_from_slice(&(t.req.max_tokens as u64).to_le_bytes());
        out.extend_from_slice(&t.req.seed.to_le_bytes());
        out.extend_from_slice(&(t.req.prompt.len() as u64).to_le_bytes());
        out.extend_from_slice(&t.req.prompt);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replay_is_byte_identical() {
        let spec = WorkloadSpec::default();
        let a = generate(&spec);
        let b = generate(&spec);
        assert_eq!(a, b);
        assert_eq!(trace_bytes(&a), trace_bytes(&b));
    }

    #[test]
    fn seeds_decorrelate_traces() {
        let a = generate(&WorkloadSpec { seed: 1, ..Default::default() });
        let b = generate(&WorkloadSpec { seed: 2, ..Default::default() });
        assert_ne!(trace_bytes(&a), trace_bytes(&b));
    }

    #[test]
    fn arrivals_are_ordered_and_rates_scale() {
        let fast = generate(&WorkloadSpec {
            n_requests: 64,
            arrival_rate_hz: 100.0,
            ..Default::default()
        });
        let slow = generate(&WorkloadSpec {
            n_requests: 64,
            arrival_rate_hz: 1.0,
            ..Default::default()
        });
        for w in fast.windows(2) {
            assert!(w[0].arrival_us <= w[1].arrival_us);
        }
        assert!(
            fast.last().unwrap().arrival_us < slow.last().unwrap().arrival_us,
            "higher rate must compress the trace"
        );
    }

    #[test]
    fn draws_respect_ranges_and_ids_are_arrival_indices() {
        let spec = WorkloadSpec {
            n_requests: 40,
            prompt_len: (3, 9),
            output_tokens: (5, 6),
            ..Default::default()
        };
        for (i, t) in generate(&spec).iter().enumerate() {
            assert_eq!(t.req.id, i as u64);
            assert!(t.req.prompt.len() >= 3 && t.req.prompt.len() < 9);
            assert_eq!(t.req.max_tokens, 5);
            assert!(t.req.prompt.iter().all(|b| b.is_ascii_lowercase()));
        }
    }
}

//! Model / quantization configuration parsed from artifacts/manifest.json
//! plus the serving-system configuration (CLI / TOML-subset file).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};

#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub max_seq: usize,
    pub rope_theta: f64,
    pub rms_eps: f64,
}

impl ModelConfig {
    pub fn from_manifest(m: &Json) -> Result<Self> {
        let c = m.get("config").ok_or_else(|| anyhow!("manifest: no config"))?;
        let u = |k: &str| -> Result<usize> {
            c.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("config.{k}"))
        };
        let f = |k: &str| -> Result<f64> {
            c.get(k).and_then(Json::as_f64).ok_or_else(|| anyhow!("config.{k}"))
        };
        Ok(ModelConfig {
            name: c.get("name").and_then(Json::as_str).unwrap_or("tiny").into(),
            vocab: u("vocab")?,
            d_model: u("d_model")?,
            n_layers: u("n_layers")?,
            n_heads: u("n_heads")?,
            head_dim: u("head_dim")?,
            d_ff: u("d_ff")?,
            n_experts: u("n_experts")?,
            top_k: u("top_k")?,
            max_seq: u("max_seq")?,
            rope_theta: f("rope_theta")?,
            rms_eps: f("rms_eps")?,
        })
    }
}

#[derive(Clone, Debug)]
pub struct QuantInfo {
    pub bits: u8,
    pub group_size: usize,
    pub uniform_bits: Vec<u8>,
}

impl QuantInfo {
    pub fn from_manifest(m: &Json) -> Result<Self> {
        let q = m.get("quant").context("manifest: no quant")?;
        Ok(QuantInfo {
            bits: q.get("bits").and_then(Json::as_usize).context("quant.bits")? as u8,
            group_size: q
                .get("group_size")
                .and_then(Json::as_usize)
                .context("quant.group_size")?,
            uniform_bits: q
                .get("uniform_bits")
                .and_then(Json::as_f64_vec)
                .context("quant.uniform_bits")?
                .into_iter()
                .map(|b| b as u8)
                .collect(),
        })
    }
}

/// Which eviction policy the `ExpertStore` residency cache runs
/// (store::policy builds the implementation). Selected per sweep via the
/// `--policy` CLI flag; LRU is the paper baseline, LFU and the
/// sparsity-aware activation-frequency policy (MoE-Infinity-style) are the
/// comparison points.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResidencyKind {
    Lru,
    Lfu,
    Sparsity,
}

impl ResidencyKind {
    pub const ALL: [ResidencyKind; 3] =
        [ResidencyKind::Lru, ResidencyKind::Lfu, ResidencyKind::Sparsity];

    pub fn name(&self) -> &'static str {
        match self {
            ResidencyKind::Lru => "lru",
            ResidencyKind::Lfu => "lfu",
            ResidencyKind::Sparsity => "sparsity",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "lru" => ResidencyKind::Lru,
            "lfu" => ResidencyKind::Lfu,
            "sparsity" | "sparse" | "freq" => ResidencyKind::Sparsity,
            other => bail!("unknown residency policy '{other}' (lru|lfu|sparsity)"),
        })
    }
}

/// How expert keys map to devices when the `ExpertStore` shards residency
/// across more than one GPU (`--devices N --shard-policy ...`). With one
/// device every policy degenerates to device 0, so the single-GPU paths
/// are untouched by the placement dimension.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardPolicy {
    /// layer `l` lives on device `l % N` — whole expert layers co-locate,
    /// so same-layer prefetch plans coalesce into one chunked copy
    Layer,
    /// expert id `e` lives on device `e % N` — hot expert ids spread, so
    /// per-device load balances under skewed routing
    Expert,
    /// mixed hash of (layer, expert) — decorrelates both axes
    Hash,
    /// measured-popularity bin-packing: the `ExpertStore` tracks each
    /// expert's exponentially-decayed activation mass and periodically
    /// re-homes keys by greedy least-loaded assignment, so hot experts'
    /// bus traffic spreads across devices instead of piling onto one
    /// (the MoE-Infinity observation applied to placement). `place` is
    /// only the cold-start seed (expert-style); live homes come from the
    /// store's rebalance overlay.
    Balanced,
}

impl ShardPolicy {
    pub const ALL: [ShardPolicy; 4] = [
        ShardPolicy::Layer,
        ShardPolicy::Expert,
        ShardPolicy::Hash,
        ShardPolicy::Balanced,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ShardPolicy::Layer => "layer",
            ShardPolicy::Expert => "expert",
            ShardPolicy::Hash => "hash",
            ShardPolicy::Balanced => "balanced",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "layer" => ShardPolicy::Layer,
            "expert" => ShardPolicy::Expert,
            "hash" => ShardPolicy::Hash,
            "balanced" | "popularity" => ShardPolicy::Balanced,
            other => bail!("unknown shard policy '{other}' (layer|expert|hash|balanced)"),
        })
    }

    /// Home device for `(layer, expert)` among `n_devices`. For
    /// `Balanced` this is only the cold-start seed — the store overlays
    /// it with the measured-mass assignment once traffic exists.
    pub fn place(&self, key: (usize, usize), n_devices: usize) -> usize {
        if n_devices <= 1 {
            return 0;
        }
        match self {
            ShardPolicy::Layer => key.0 % n_devices,
            // Balanced seeds like Expert until the first rebalance
            ShardPolicy::Expert | ShardPolicy::Balanced => key.1 % n_devices,
            ShardPolicy::Hash => {
                let (l, e) = key;
                l.wrapping_mul(0x9E37_79B1)
                    .wrapping_add(e.wrapping_mul(0x85EB_CA77))
                    % n_devices
            }
        }
    }
}

/// How an expert's weights are compressed for transfer + compute.
/// This is the policy axis the paper's Figures 3/9/10 sweep.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ExpertMode {
    /// fp32 compute, fp16-accounted transfer (DeepSpeed-MII-style naive).
    Dense,
    /// Eq. (11): contextual sparsity on gate/down at `level`, fp up.
    Sparse { level: f64 },
    /// FloE hybrid: INT2 HQQ up + contextual sparse gate/down.
    Floe { level: f64 },
    /// CATS baseline: threshold on SiLU(gate) output.
    CatsGate { level: f64 },
    /// CHESS baseline: per-channel thresholds on the gate output.
    ChessGate { level: f64 },
    /// Threshold on the down-projection input (paper's L_down variant).
    DownSparse { level: f64 },
    /// Uniform HQQ quantization of all three matrices (Mixtral-Offloading).
    Uniform { bits: u8 },
    /// Per-projection quantization sweep (Fig 3b / Table 7).
    QuantProj { proj: Proj, bits: u8 },
    /// Per-projection sparsification sweep (Fig 3a / Table 5).
    SparseProj { proj: Proj, level: f64 },
    /// FloE with a variable up-projection bit width (Fig 9b): HQQ-`bits`
    /// up projection + contextual sparsity at `level`.
    FloeVar { level: f64, bits: u8 },
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Proj {
    Gate,
    Up,
    Down,
}

impl Proj {
    pub fn key(&self) -> &'static str {
        match self {
            Proj::Gate => "gate",
            Proj::Up => "up",
            Proj::Down => "down",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn parse_config() {
        let j = parse(
            r#"{"config":{"name":"t","vocab":256,"d_model":64,"n_layers":4,
                "n_heads":4,"head_dim":16,"d_ff":128,"n_experts":8,"top_k":2,
                "max_seq":512,"rope_theta":10000.0,"rms_eps":1e-5},
                "quant":{"bits":2,"group_size":32,"uniform_bits":[8,4,3,2,1]}}"#,
        )
        .unwrap();
        let c = ModelConfig::from_manifest(&j).unwrap();
        assert_eq!(c.d_model, 64);
        assert_eq!(c.n_experts, 8);
        let q = QuantInfo::from_manifest(&j).unwrap();
        assert_eq!(q.bits, 2);
        assert_eq!(q.uniform_bits, vec![8, 4, 3, 2, 1]);
    }

    #[test]
    fn missing_field_is_error() {
        let j = parse(r#"{"config":{"vocab":256}}"#).unwrap();
        assert!(ModelConfig::from_manifest(&j).is_err());
    }

    #[test]
    fn residency_kind_round_trips() {
        for kind in ResidencyKind::ALL {
            assert_eq!(ResidencyKind::parse(kind.name()).unwrap(), kind);
        }
        assert!(ResidencyKind::parse("mru").is_err());
    }

    #[test]
    fn shard_policy_round_trips_and_places_in_range() {
        for shard in ShardPolicy::ALL {
            assert_eq!(ShardPolicy::parse(shard.name()).unwrap(), shard);
            for n in 1..5usize {
                for l in 0..8 {
                    for e in 0..8 {
                        assert!(shard.place((l, e), n) < n);
                    }
                }
            }
            // one device: every key is home on device 0
            assert_eq!(shard.place((3, 5), 1), 0);
        }
        assert!(ShardPolicy::parse("ring").is_err());
        // layer / expert policies shard on their respective axis
        assert_eq!(ShardPolicy::Layer.place((3, 0), 2), 1);
        assert_eq!(ShardPolicy::Expert.place((0, 3), 2), 1);
        // balanced seeds like expert before the first rebalance
        assert_eq!(ShardPolicy::Balanced.place((0, 3), 2), 1);
        assert_eq!(ShardPolicy::parse("popularity").unwrap(), ShardPolicy::Balanced);
    }
}

//! Dual sparsity predictors (paper §3.3).
//!
//! * Inter-expert (§3.3.1): a learned probe — trained at build time in
//!   Python on activation traces — mapping the hidden state entering layer
//!   i's MoE block to the experts layer i+1 will route to. Native Rust
//!   matmul (d x E is tiny); runs while layer i computes, driving prefetch.
//! * Intra-expert (§3.3.2): parameter-free reuse predictor — multiply the
//!   same hidden state with layer i+1's VRAM-resident INT2 up projection
//!   to estimate |v| and hence the channel mask, so only surviving gate
//!   columns / down rows are transferred.

use anyhow::Result;

use crate::model::Weights;
use crate::quant::QuantView;
use crate::sparsity;
use crate::tensor::top_k;

/// Inter-expert predictor for one layer boundary (i -> i+1).
pub struct InterPredictor {
    w: Vec<f32>, // [d, E] row-major
    b: Vec<f32>, // [E]
    d: usize,
    e: usize,
}

impl InterPredictor {
    pub fn from_weights(wts: &Weights, layer: usize) -> Result<Self> {
        let (w, b) = wts.predictor(layer)?;
        Ok(InterPredictor {
            w: w.to_vec(),
            b: b.to_vec(),
            d: wts.cfg.d_model,
            e: wts.cfg.n_experts,
        })
    }

    pub fn from_raw(w: Vec<f32>, b: Vec<f32>, d: usize, e: usize) -> Self {
        InterPredictor { w, b, d, e }
    }

    /// Scores per expert for the *next* layer given this layer's h_mid.
    pub fn scores(&self, h: &[f32]) -> Vec<f32> {
        debug_assert_eq!(h.len(), self.d);
        let mut s = self.b.clone();
        for (i, hi) in h.iter().enumerate() {
            let row = &self.w[i * self.e..(i + 1) * self.e];
            for (sj, wj) in s.iter_mut().zip(row) {
                *sj += hi * wj;
            }
        }
        s
    }

    /// Predicted top-k experts for the next layer.
    pub fn predict(&self, h: &[f32], k: usize) -> Vec<usize> {
        top_k(&self.scores(h), k)
    }
}

/// Intra-expert reuse predictor: channel mask for (layer+1, expert) from
/// this layer's hidden state and the resident INT2 up projection.
pub struct IntraPredictor {
    /// dequantized up projection [d, f] (cached per expert; the INT2 bytes
    /// are the resident representation, dequant is cheap and one-time)
    wu_dq: Vec<f32>,
    d: usize,
    f: usize,
}

impl IntraPredictor {
    pub fn from_quant(q: &QuantView<'_>) -> Self {
        let mut wu_dq = vec![0.0; q.d * q.f];
        q.dequant(&mut wu_dq);
        IntraPredictor { wu_dq, d: q.d, f: q.f }
    }

    /// |h · W_up_q| per channel.
    pub fn channel_magnitudes(&self, h: &[f32]) -> Vec<f32> {
        debug_assert_eq!(h.len(), self.d);
        let mut v = vec![0.0f32; self.f];
        for (i, hi) in h.iter().enumerate() {
            if *hi == 0.0 {
                continue;
            }
            let row = &self.wu_dq[i * self.f..(i + 1) * self.f];
            for (vj, wj) in v.iter_mut().zip(row) {
                *vj += hi * wj;
            }
        }
        v.iter_mut().for_each(|x| *x = x.abs());
        v
    }

    /// Predicted channel mask at threshold t, padded by `margin` (a small
    /// safety factor lowers the threshold to trade extra bytes for recall).
    pub fn predict_mask(&self, h: &[f32], t: f32, margin: f32) -> Vec<bool> {
        let v = self.channel_magnitudes(h);
        sparsity::mask_from_activations(&v, t * (1.0 - margin))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn inter_predictor_linear() {
        // w selects expert = argmax over first E coords of h
        let (d, e) = (4, 3);
        let mut w = vec![0.0; d * e];
        for j in 0..e {
            w[j * e + j] = 1.0; // h[j] feeds expert j
        }
        let p = InterPredictor::from_raw(w, vec![0.0; e], d, e);
        let pred = p.predict(&[0.1, 5.0, 0.2, 0.0], 2);
        assert_eq!(pred[0], 1);
    }

    #[test]
    fn intra_predictor_matches_direct_matmul() {
        let mut rng = Rng::new(4);
        let (d, f, g) = (16, 8, 8);
        let codes: Vec<u8> = (0..d * f).map(|_| rng.below(4) as u8).collect();
        // pack
        let mut packed = vec![0u8; d / 4 * f];
        for pr in 0..d / 4 {
            for j in 0..f {
                let mut b = 0u8;
                for k in 0..4 {
                    b |= codes[(pr * 4 + k) * f + j] << (2 * k);
                }
                packed[pr * f + j] = b;
            }
        }
        let scale: Vec<f32> = (0..d / g * f).map(|_| rng.f32() + 0.1).collect();
        let zero: Vec<f32> = (0..d / g * f).map(|_| rng.f32()).collect();
        let qv = QuantView {
            codes: &packed, scale: &scale, zero: &zero,
            d, f, group_size: g, bits: 2, packed: true,
        };
        let ip = IntraPredictor::from_quant(&qv);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let v = ip.channel_magnitudes(&h);
        // direct: dequant then |h @ w|
        let mut w = vec![0.0; d * f];
        qv.dequant(&mut w);
        for j in 0..f {
            let mut s = 0.0;
            for i in 0..d {
                s += h[i] * w[i * f + j];
            }
            assert!((v[j] - s.abs()).abs() < 1e-4);
        }
    }

    #[test]
    fn margin_expands_mask() {
        let mut rng = Rng::new(5);
        let (d, f, g) = (16, 16, 8);
        let packed = vec![0b00_01_10_11u8; d / 4 * f];
        let scale: Vec<f32> = (0..d / g * f).map(|_| rng.f32() + 0.1).collect();
        let zero = vec![0.0f32; d / g * f];
        let qv = QuantView {
            codes: &packed, scale: &scale, zero: &zero,
            d, f, group_size: g, bits: 2, packed: true,
        };
        let ip = IntraPredictor::from_quant(&qv);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let m0 = ip.predict_mask(&h, 0.5, 0.0);
        let m1 = ip.predict_mask(&h, 0.5, 0.3);
        let c0 = m0.iter().filter(|x| **x).count();
        let c1 = m1.iter().filter(|x| **x).count();
        assert!(c1 >= c0);
    }
}

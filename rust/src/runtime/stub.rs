//! Stub runtime compiled when the `pjrt` feature is off (DESIGN.md §4).
//!
//! Mirrors the API surface of `pjrt.rs` so the engine and everything above
//! it type-check without the `xla` native dependency. `Runtime::new` is
//! the single entry point and always errors; the remaining methods are
//! therefore unreachable and say so if a refactor ever violates that.

use std::path::Path;

use anyhow::{bail, Result};

/// Opaque stand-ins for the xla types referenced in signatures.
pub struct PjRtClient(());
pub struct PjRtBuffer(());
#[derive(Debug)]
pub struct Literal(());

pub fn lit_f32(_data: &[f32], _dims: &[usize]) -> Result<Literal> {
    unreachable!("pjrt stub: no runtime was constructed")
}

pub fn lit_u8(_data: &[u8], _dims: &[usize]) -> Result<Literal> {
    unreachable!("pjrt stub: no runtime was constructed")
}

pub fn lit_scalar_f32(_v: f32) -> Literal {
    unreachable!("pjrt stub: no runtime was constructed")
}

pub fn lit_scalar_i32(_v: i32) -> Literal {
    unreachable!("pjrt stub: no runtime was constructed")
}

pub fn lit_zeros_f32(_dims: &[usize]) -> Result<Literal> {
    unreachable!("pjrt stub: no runtime was constructed")
}

pub fn to_vec_f32(_l: &Literal) -> Result<Vec<f32>> {
    unreachable!("pjrt stub: no runtime was constructed")
}

/// Stub of the compiled-executable registry. Construction always fails.
pub struct Runtime {
    /// count of PJRT executions, for the metrics/perf pass
    pub exec_count: std::cell::Cell<u64>,
}

impl Runtime {
    pub fn new(_art_dir: &Path) -> Result<Self> {
        bail!(
            "FloE was built without the `pjrt` feature: PJRT execution \
             (engine, eval, serving) is unavailable. Rebuild with \
             `--features pjrt` and the xla dependency (DESIGN.md §4); the \
             store/transfer/sim layers work without it."
        )
    }

    pub fn load(&mut self, _name: &str) -> Result<()> {
        unreachable!("pjrt stub: no runtime was constructed")
    }

    pub fn load_all(&mut self, _names: &[&str]) -> Result<()> {
        unreachable!("pjrt stub: no runtime was constructed")
    }

    pub fn loaded(&self, _name: &str) -> bool {
        unreachable!("pjrt stub: no runtime was constructed")
    }

    pub fn exec(&self, _name: &str, _args: &[&Literal]) -> Result<Vec<Literal>> {
        unreachable!("pjrt stub: no runtime was constructed")
    }

    pub fn exec_b(&self, _name: &str, _args: &[&PjRtBuffer]) -> Result<Vec<Literal>> {
        unreachable!("pjrt stub: no runtime was constructed")
    }

    pub fn client(&self) -> &PjRtClient {
        unreachable!("pjrt stub: no runtime was constructed")
    }

    pub fn upload_f32(&self, _data: &[f32], _dims: &[usize]) -> Result<PjRtBuffer> {
        unreachable!("pjrt stub: no runtime was constructed")
    }

    pub fn upload_u8(&self, _data: &[u8], _dims: &[usize]) -> Result<PjRtBuffer> {
        unreachable!("pjrt stub: no runtime was constructed")
    }

    pub fn upload_scalar_f32(&self, _v: f32) -> Result<PjRtBuffer> {
        unreachable!("pjrt stub: no runtime was constructed")
    }

    pub fn upload_literal(&self, _lit: &Literal) -> Result<PjRtBuffer> {
        unreachable!("pjrt stub: no runtime was constructed")
    }

    pub fn upload_scalar_i32(&self, _v: i32) -> Result<PjRtBuffer> {
        unreachable!("pjrt stub: no runtime was constructed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_runtime_errors_cleanly() {
        let err = Runtime::new(Path::new("/nonexistent")).err().unwrap();
        let msg = format!("{err}");
        assert!(msg.contains("pjrt"), "{msg}");
    }
}

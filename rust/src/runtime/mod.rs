//! PJRT runtime facade.
//!
//! The real runtime (`pjrt.rs`) loads AOT-compiled HLO text artifacts,
//! compiles them once on the CPU PJRT client and executes them from the
//! L3 hot path. It needs the `xla` native crate (xla_extension 0.5.1),
//! which is not available everywhere, so it is gated behind the `pjrt`
//! cargo feature (DESIGN.md §4). Without the feature a stub with the same
//! API surface is compiled instead: everything type-checks, and
//! `Runtime::new` returns a descriptive error at runtime, so the pure-Rust
//! layers (store, transfer, predictors, hwsim, coordinator sim) remain
//! fully usable and testable.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::*;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::*;

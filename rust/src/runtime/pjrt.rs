//! PJRT runtime: load AOT-compiled HLO text artifacts, compile them once on
//! the CPU PJRT client, and execute them from the L3 hot path.
//!
//! Interchange is HLO *text* (see DESIGN.md): jax >= 0.5 serialized protos
//! carry 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids. All graphs are lowered with `return_tuple=True`,
//! so execution unwraps one tuple layer.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};
use xla::{ElementType, PjRtLoadedExecutable};

pub use xla::{Literal, PjRtBuffer, PjRtClient};

/// Literal construction helpers --------------------------------------------

pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Literal::create_from_shape_and_untyped_data(ElementType::F32, dims, bytes)
        .map_err(|e| anyhow!("lit_f32: {e:?}"))
}

pub fn lit_u8(data: &[u8], dims: &[usize]) -> Result<Literal> {
    Literal::create_from_shape_and_untyped_data(ElementType::U8, dims, data)
        .map_err(|e| anyhow!("lit_u8: {e:?}"))
}

pub fn lit_scalar_f32(v: f32) -> Literal {
    Literal::scalar(v)
}

pub fn lit_scalar_i32(v: i32) -> Literal {
    Literal::scalar(v)
}

pub fn lit_zeros_f32(dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    lit_f32(&vec![0.0; n], dims)
}

pub fn to_vec_f32(l: &Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))
}

/// A compiled-executable registry over an artifacts directory.
pub struct Runtime {
    client: PjRtClient,
    exes: HashMap<String, PjRtLoadedExecutable>,
    art_dir: PathBuf,
    /// count of PJRT executions, for the metrics/perf pass
    pub exec_count: std::cell::Cell<u64>,
}

impl Runtime {
    pub fn new(art_dir: &Path) -> Result<Self> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            exes: HashMap::new(),
            art_dir: art_dir.to_path_buf(),
            exec_count: std::cell::Cell::new(0),
        })
    }

    /// Compile (and cache) the named HLO module from `<art_dir>/<name>.hlo.txt`.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let path = self.art_dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("path utf8")?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn load_all(&mut self, names: &[&str]) -> Result<()> {
        for n in names {
            self.load(n)?;
        }
        Ok(())
    }

    pub fn loaded(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    /// Execute a loaded module; returns the flattened tuple of outputs.
    /// Arguments are borrowed — no literal deep-copies on the hot path.
    pub fn exec(&self, name: &str, args: &[&Literal]) -> Result<Vec<Literal>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("executable not loaded: {name}"))?;
        self.exec_count.set(self.exec_count.get() + 1);
        let result = exe
            .execute::<&Literal>(args)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal {name}: {e:?}"))?;
        // graphs are lowered with return_tuple=True
        lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }
}

impl Runtime {
    /// Upload host data to a device buffer (freed on drop — unlike the
    /// literal-argument `execute` path in the xla crate, which leaks its
    /// internally created input buffers; see EXPERIMENTS.md §Perf).
    ///
    /// Uses `buffer_from_host_buffer::<T>`: `buffer_from_host_literal`
    /// aborts on rank-1/rank-0 literals in xla_extension 0.5.1, and
    /// `buffer_from_host_raw_bytes` passes the wrong dtype enum.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload f32: {e:?}"))
    }

    pub fn upload_u8(&self, data: &[u8], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload u8: {e:?}"))
    }

    pub fn upload_scalar_f32(&self, v: f32) -> Result<xla::PjRtBuffer> {
        self.upload_f32(&[v], &[])
    }

    /// Re-enter an execution-output literal as a device buffer without
    /// materializing a host `Vec` (KV-cache residency: the attention
    /// step's output caches flow straight back into the next step's
    /// arguments). `buffer_from_host_literal` aborts on rank-0/1 literals
    /// in xla_extension 0.5.1 — only the rank-4 KV caches come through
    /// here, so the abort path is unreachable from the engine.
    pub fn upload_literal(&self, lit: &Literal) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .map_err(|e| anyhow!("upload literal: {e:?}"))
    }

    pub fn upload_scalar_i32(&self, v: i32) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(&[v], &[], None)
            .map_err(|e| anyhow!("upload i32: {e:?}"))
    }

    /// Execute with device-buffer arguments; returns the flattened tuple.
    pub fn exec_b(&self, name: &str, args: &[&xla::PjRtBuffer]) -> Result<Vec<Literal>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("executable not loaded: {name}"))?;
        self.exec_count.set(self.exec_count.get() + 1);
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(args)
            .map_err(|e| anyhow!("execute_b {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal {name}: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))
    }
}

//! Pluggable residency (eviction) policies for the expert cache.
//!
//! The paper runs plain LRU; MoE-Infinity (PAPERS.md) shows that
//! sparsity-aware priority — rank experts by their decayed activation
//! frequency rather than recency — beats LRU on skewed MoE routing, where
//! a burst of cold experts can flush the globally hot ones out of an LRU
//! cache. Three implementations share one trait so `ResidentSet` (and the
//! shadow-map property tests over it) treat them interchangeably:
//!
//! * `LruPolicy`      — exact port of the seed `ExpertCache` behavior;
//!   `--policy lru` reproduces the pre-refactor Fig-6/8 numbers.
//! * `LfuPolicy`      — evict the least-frequently-hit resident,
//!   LRU tie-break; frequency resets when an entry leaves the cache.
//! * `SparsityPolicy` — MoE-Infinity-style: every *routing activation*
//!   (hit or miss) feeds a per-expert exponentially-decayed counter, so
//!   popularity ages out and victims are the experts the router has
//!   stopped choosing.

use std::collections::{BTreeMap, HashMap};

use crate::config::ResidencyKind;

use super::ExpertKey;

pub trait ResidencyPolicy {
    fn name(&self) -> &'static str;
    /// The router selected `key` this step (hit or miss) — the popularity
    /// signal sparsity-aware policies rank by. Recency policies ignore it.
    fn on_activation(&mut self, key: ExpertKey, now: u64);
    /// `key` was found resident and touched.
    fn on_hit(&mut self, key: ExpertKey, now: u64);
    /// `key` entered the resident set (insert or resize).
    fn on_insert(&mut self, key: ExpertKey, now: u64);
    /// `key` left the resident set (eviction or overwrite).
    fn on_remove(&mut self, key: ExpertKey);
    /// Pick the eviction victim among the evictable (unpinned) residents.
    fn victim(&self, candidates: &[ExpertKey]) -> Option<ExpertKey>;
    /// Admission filter (MoE-Infinity): is `key` popular enough to be
    /// *cached* after use? Recency/frequency policies admit everything;
    /// the sparsity policy rejects one-off experts so a cold scan cannot
    /// flush the hot set. Consulted by `ExpertStore::admit` on the
    /// post-transfer caching path only — warm/pinned inserts bypass it.
    fn admits(&self, _key: ExpertKey) -> bool {
        true
    }
}

/// Default decay for the sparsity policy's per-expert activation EMA:
/// half-life ~700 activations — long enough to span many tokens at
/// Mixtral depth, short enough that yesterday's hot set ages out.
/// Overridden per run via `--sparsity-decay`.
pub const DEFAULT_SPARSITY_DECAY: f64 = 0.999;

/// Minimum decayed activation count before the sparsity policy caches an
/// expert (the admission filter): a second activation inside the decay
/// horizon qualifies, a single cold touch never does.
pub const SPARSITY_MIN_ADMIT: f64 = 1.5;

/// Build the policy implementation a `ResidencyKind` selects.
/// `sparsity_decay` parameterizes the sparsity policy's activation EMA
/// (the `--sparsity-decay` flag); recency/frequency policies ignore it.
pub fn build_policy(kind: ResidencyKind, sparsity_decay: f64) -> Box<dyn ResidencyPolicy> {
    match kind {
        ResidencyKind::Lru => Box::new(LruPolicy::new()),
        ResidencyKind::Lfu => Box::new(LfuPolicy::new()),
        ResidencyKind::Sparsity => Box::new(SparsityPolicy::new(sparsity_decay)),
    }
}

// ------------------------------------------------------------------- LRU

#[derive(Debug, Default)]
pub struct LruPolicy {
    last_use: HashMap<ExpertKey, u64>,
}

impl LruPolicy {
    pub fn new() -> Self {
        Self::default()
    }
}

impl ResidencyPolicy for LruPolicy {
    fn name(&self) -> &'static str {
        "lru"
    }
    fn on_activation(&mut self, _key: ExpertKey, _now: u64) {}
    fn on_hit(&mut self, key: ExpertKey, now: u64) {
        self.last_use.insert(key, now);
    }
    fn on_insert(&mut self, key: ExpertKey, now: u64) {
        self.last_use.insert(key, now);
    }
    fn on_remove(&mut self, key: ExpertKey) {
        self.last_use.remove(&key);
    }
    fn victim(&self, candidates: &[ExpertKey]) -> Option<ExpertKey> {
        candidates
            .iter()
            .copied()
            .min_by_key(|k| self.last_use.get(k).copied().unwrap_or(0))
    }
}

// ------------------------------------------------------------------- LFU

#[derive(Debug, Default)]
pub struct LfuPolicy {
    freq: HashMap<ExpertKey, u64>,
    last_use: HashMap<ExpertKey, u64>,
}

impl LfuPolicy {
    pub fn new() -> Self {
        Self::default()
    }
}

impl ResidencyPolicy for LfuPolicy {
    fn name(&self) -> &'static str {
        "lfu"
    }
    fn on_activation(&mut self, _key: ExpertKey, _now: u64) {}
    fn on_hit(&mut self, key: ExpertKey, now: u64) {
        *self.freq.entry(key).or_insert(0) += 1;
        self.last_use.insert(key, now);
    }
    fn on_insert(&mut self, key: ExpertKey, now: u64) {
        *self.freq.entry(key).or_insert(0) += 1;
        self.last_use.insert(key, now);
    }
    fn on_remove(&mut self, key: ExpertKey) {
        self.freq.remove(&key);
        self.last_use.remove(&key);
    }
    fn victim(&self, candidates: &[ExpertKey]) -> Option<ExpertKey> {
        candidates.iter().copied().min_by_key(|k| {
            (
                self.freq.get(k).copied().unwrap_or(0),
                self.last_use.get(k).copied().unwrap_or(0),
            )
        })
    }
}

// ----------------------------------- decayed activation mass (shared EMA)

/// Per-expert exponentially-decayed activation mass — the popularity
/// signal behind both the sparsity eviction policy and the store's
/// measured-load placement (`ShardPolicy::Balanced` bin-packing, hot-
/// expert replication). Lazily decayed: the stored value is the EMA as of
/// `stamp[key]` activation steps; `mass` decays it to the current step on
/// read. Keys live in a `BTreeMap` so `masses()` iterates in a
/// deterministic order (the rebalance assignment depends on it).
#[derive(Debug, Clone)]
pub struct PopularityTracker {
    decay: f64,
    step: u64,
    ema: BTreeMap<ExpertKey, f64>,
    stamp: BTreeMap<ExpertKey, u64>,
}

impl PopularityTracker {
    pub fn new(decay: f64) -> Self {
        assert!(decay > 0.0 && decay <= 1.0);
        PopularityTracker { decay, step: 0, ema: BTreeMap::new(), stamp: BTreeMap::new() }
    }

    /// The router selected `key` (one activation step).
    pub fn note(&mut self, key: ExpertKey) {
        self.step += 1;
        let decayed = self.mass(key);
        self.ema.insert(key, decayed + 1.0);
        self.stamp.insert(key, self.step);
    }

    /// Activation mass decayed to the current step. powf, not powi: the
    /// step gap is unbounded in a long-running server and an i32 cast
    /// would wrap negative past 2^31, exploding the coldest score.
    pub fn mass(&self, key: ExpertKey) -> f64 {
        match (self.ema.get(&key), self.stamp.get(&key)) {
            (Some(v), Some(s)) => v * self.decay.powf((self.step - s) as f64),
            _ => 0.0,
        }
    }

    /// Every tracked key with its current mass, hottest first (ties break
    /// by key order — deterministic for the greedy bin-packer).
    pub fn masses(&self) -> Vec<(ExpertKey, f64)> {
        let mut out: Vec<(ExpertKey, f64)> =
            self.ema.keys().map(|k| (*k, self.mass(*k))).collect();
        out.sort_by(|a, b| {
            b.1.partial_cmp(&a.1)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| a.0.cmp(&b.0))
        });
        out
    }

    pub fn len(&self) -> usize {
        self.ema.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ema.is_empty()
    }
}

// ------------------------------------------- sparsity-aware (MoE-Infinity)

pub struct SparsityPolicy {
    /// shared decayed-activation-mass machinery (see `PopularityTracker`)
    mass: PopularityTracker,
    /// admission threshold on the decayed count (see `SPARSITY_MIN_ADMIT`)
    min_admit: f64,
    last_use: HashMap<ExpertKey, u64>,
}

impl SparsityPolicy {
    pub fn new(decay: f64) -> Self {
        SparsityPolicy {
            mass: PopularityTracker::new(decay),
            min_admit: SPARSITY_MIN_ADMIT,
            last_use: HashMap::new(),
        }
    }

    fn score(&self, key: ExpertKey) -> f64 {
        self.mass.mass(key)
    }
}

impl ResidencyPolicy for SparsityPolicy {
    fn name(&self) -> &'static str {
        "sparsity"
    }
    fn on_activation(&mut self, key: ExpertKey, _now: u64) {
        self.mass.note(key);
    }
    fn on_hit(&mut self, key: ExpertKey, now: u64) {
        self.last_use.insert(key, now);
    }
    fn on_insert(&mut self, key: ExpertKey, now: u64) {
        self.last_use.insert(key, now);
    }
    fn on_remove(&mut self, key: ExpertKey) {
        // activation history deliberately survives eviction: it is a
        // property of the routing distribution, not of residency
        self.last_use.remove(&key);
    }
    fn victim(&self, candidates: &[ExpertKey]) -> Option<ExpertKey> {
        candidates.iter().copied().min_by(|a, b| {
            self.score(*a)
                .partial_cmp(&self.score(*b))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| {
                    let la = self.last_use.get(a).copied().unwrap_or(0);
                    let lb = self.last_use.get(b).copied().unwrap_or(0);
                    la.cmp(&lb)
                })
        })
    }
    fn admits(&self, key: ExpertKey) -> bool {
        self.score(key) >= self.min_admit
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_picks_oldest() {
        let mut p = LruPolicy::new();
        p.on_insert((0, 0), 1);
        p.on_insert((0, 1), 2);
        p.on_hit((0, 0), 3);
        assert_eq!(p.victim(&[(0, 0), (0, 1)]), Some((0, 1)));
    }

    #[test]
    fn lfu_prefers_cold_and_breaks_ties_by_recency() {
        let mut p = LfuPolicy::new();
        p.on_insert((0, 0), 1);
        p.on_insert((0, 1), 2);
        p.on_hit((0, 0), 3); // freq: (0,0)=2, (0,1)=1
        assert_eq!(p.victim(&[(0, 0), (0, 1)]), Some((0, 1)));
        p.on_insert((0, 2), 4); // freq 1, newer than (0,1)
        assert_eq!(p.victim(&[(0, 0), (0, 1), (0, 2)]), Some((0, 1)));
        // eviction resets frequency
        p.on_remove((0, 0));
        p.on_insert((0, 0), 5);
        assert_eq!(
            p.victim(&[(0, 0), (0, 2)]),
            Some((0, 2)),
            "both freq 1 -> older wins"
        );
    }

    #[test]
    fn sparsity_ranks_by_decayed_activations() {
        let mut p = SparsityPolicy::new(0.9);
        for _ in 0..10 {
            p.on_activation((0, 0), 0);
        }
        p.on_activation((0, 1), 0);
        p.on_insert((0, 0), 1);
        p.on_insert((0, 1), 2);
        // (0,1) has far fewer activations -> victim despite being newer
        assert_eq!(p.victim(&[(0, 0), (0, 1)]), Some((0, 1)));
        // hammer (0,1) long enough and the decayed score flips
        for _ in 0..60 {
            p.on_activation((0, 1), 3);
        }
        assert_eq!(p.victim(&[(0, 0), (0, 1)]), Some((0, 0)));
    }

    #[test]
    fn sparsity_history_survives_eviction() {
        let mut p = SparsityPolicy::new(1.0);
        p.on_activation((0, 0), 0);
        p.on_activation((0, 0), 0);
        p.on_insert((0, 0), 1);
        p.on_remove((0, 0));
        p.on_insert((0, 0), 2);
        p.on_activation((0, 1), 0);
        p.on_insert((0, 1), 3);
        assert_eq!(p.victim(&[(0, 0), (0, 1)]), Some((0, 1)));
    }

    #[test]
    fn build_policy_names_match_kind() {
        for kind in ResidencyKind::ALL {
            assert_eq!(build_policy(kind, DEFAULT_SPARSITY_DECAY).name(), kind.name());
        }
    }

    #[test]
    fn recency_policies_admit_everything() {
        assert!(LruPolicy::new().admits((0, 0)));
        assert!(LfuPolicy::new().admits((3, 7)));
    }

    #[test]
    fn popularity_tracker_masses_decay_and_rank_deterministically() {
        let mut t = PopularityTracker::new(0.9);
        for _ in 0..5 {
            t.note((0, 0));
        }
        t.note((0, 1));
        let m = t.masses();
        assert_eq!(m[0].0, (0, 0), "hottest first");
        assert!(m[0].1 > m[1].1);
        assert_eq!(t.len(), 2);
        // unrelated steps decay (0,0)'s mass toward zero
        let before = t.mass((0, 0));
        for _ in 0..50 {
            t.note((3, 3));
        }
        assert!(t.mass((0, 0)) < before * 0.1);
        // equal-mass keys tie-break by key order
        let mut tie = PopularityTracker::new(1.0);
        tie.note((1, 1));
        tie.note((0, 2));
        let m = tie.masses();
        assert_eq!(m[0].0, (0, 2));
        assert_eq!(m[1].0, (1, 1));
    }

    #[test]
    fn sparsity_admission_filter_rejects_one_offs() {
        let mut p = SparsityPolicy::new(0.999);
        // never activated / activated once: not cache-worthy
        assert!(!p.admits((0, 0)));
        p.on_activation((0, 0), 0);
        assert!(!p.admits((0, 0)), "a single cold touch must not qualify");
        // a second activation inside the decay horizon qualifies
        p.on_activation((0, 0), 0);
        assert!(p.admits((0, 0)));
        // under a harsh decay the score collapses between bursts and
        // admission lapses again: 1.9 * 0.9^6 ~ 1.01 < 1.5
        let mut harsh = SparsityPolicy::new(0.9);
        harsh.on_activation((1, 0), 0);
        harsh.on_activation((1, 0), 0);
        assert!(harsh.admits((1, 0)));
        for _ in 0..6 {
            harsh.on_activation((9, 9), 0); // unrelated steps decay (1,0)
        }
        assert!(!harsh.admits((1, 0)), "stale popularity must age out");
    }
}

//! Byte-budgeted resident set (paper Fig 1(b)/(c) "expert cache") with a
//! pluggable eviction policy — the storage half of `ExpertStore`.
//!
//! Absorbs the old `memory::ExpertCache` (which hardcoded LRU): keyed by
//! (layer, expert), byte-accounted against a VRAM budget, with
//! prediction-aware pinning so entries staged for the imminent layer are
//! never evicted. Invariants (enforced + property-tested across *all*
//! policies): used <= budget at all times; pinned entries survive
//! eviction; hit/miss accounting is exact.

use std::collections::HashMap;

use crate::config::ResidencyKind;

use super::policy::{build_policy, ResidencyPolicy, DEFAULT_SPARSITY_DECAY};
use super::ExpertKey;

#[derive(Debug, Clone)]
struct Entry {
    bytes: usize,
    pinned: bool,
}

#[derive(Debug, Default, Clone)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub inserted_bytes: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let tot = self.hits + self.misses;
        if tot == 0 {
            0.0
        } else {
            self.hits as f64 / tot as f64
        }
    }
}

pub struct ResidentSet {
    budget: usize,
    used: usize,
    /// logical op counter handed to the policy as `now`
    clock: u64,
    entries: HashMap<ExpertKey, Entry>,
    policy: Box<dyn ResidencyPolicy>,
    pub stats: CacheStats,
}

impl ResidentSet {
    pub fn new(budget_bytes: usize, kind: ResidencyKind) -> Self {
        Self::with_policy(budget_bytes, build_policy(kind, DEFAULT_SPARSITY_DECAY))
    }

    /// `new` with an explicit sparsity-policy decay constant
    /// (`--sparsity-decay`); other policies ignore it.
    pub fn new_tuned(budget_bytes: usize, kind: ResidencyKind, sparsity_decay: f64) -> Self {
        Self::with_policy(budget_bytes, build_policy(kind, sparsity_decay))
    }

    pub fn with_policy(budget_bytes: usize, policy: Box<dyn ResidencyPolicy>) -> Self {
        ResidentSet {
            budget: budget_bytes,
            used: 0,
            clock: 0,
            entries: HashMap::new(),
            policy,
            stats: CacheStats::default(),
        }
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }
    pub fn budget(&self) -> usize {
        self.budget
    }
    pub fn used(&self) -> usize {
        self.used
    }
    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
    pub fn contains(&self, key: ExpertKey) -> bool {
        self.entries.contains_key(&key)
    }
    /// Resident size of `key`, if resident.
    pub fn bytes_of(&self, key: ExpertKey) -> Option<usize> {
        self.entries.get(&key).map(|e| e.bytes)
    }
    /// Unused budget, bytes.
    pub fn free_bytes(&self) -> usize {
        self.budget - self.used
    }
    /// The policy's admission filter: is `key` cache-worthy right now?
    /// (`insert` itself never consults this — warm/pinned paths bypass
    /// the filter; `ExpertStore::admit` applies it.)
    pub fn would_admit(&self, key: ExpertKey) -> bool {
        self.policy.admits(key)
    }

    /// Routing selected `key` this step — popularity signal for
    /// sparsity-aware policies. Does not touch hit/miss accounting.
    pub fn note_activation(&mut self, key: ExpertKey) {
        self.policy.on_activation(key, self.clock);
    }

    /// Record an access; returns true on hit (and refreshes the policy's
    /// recency/frequency state).
    pub fn access(&mut self, key: ExpertKey) -> bool {
        self.clock += 1;
        if self.entries.contains_key(&key) {
            self.policy.on_hit(key, self.clock);
            self.stats.hits += 1;
            true
        } else {
            self.stats.misses += 1;
            false
        }
    }

    /// Insert (or resize) an entry, evicting policy-chosen unpinned
    /// entries as needed. Returns false if the entry cannot fit even
    /// after evicting everything unpinned.
    pub fn insert(&mut self, key: ExpertKey, bytes: usize) -> bool {
        self.insert_evicting(key, bytes).0
    }

    /// `insert`, also returning the (key, bytes) of every entry evicted
    /// to make room — the hook the sharded store uses to spill victims to
    /// peer devices instead of dropping them.
    pub fn insert_evicting(
        &mut self,
        key: ExpertKey,
        bytes: usize,
    ) -> (bool, Vec<(ExpertKey, usize)>) {
        self.clock += 1;
        let mut evicted = Vec::new();
        if let Some(old) = self.entries.remove(&key) {
            self.used -= old.bytes;
            self.policy.on_remove(key);
        }
        if bytes > self.budget {
            return (false, evicted);
        }
        while self.used + bytes > self.budget {
            match self.evict_one() {
                Some(victim) => evicted.push(victim),
                None => return (false, evicted),
            }
        }
        self.used += bytes;
        self.stats.inserted_bytes += bytes as u64;
        self.entries.insert(key, Entry { bytes, pinned: false });
        self.policy.on_insert(key, self.clock);
        (true, evicted)
    }

    /// Remove `key` without counting an eviction (cross-device migration).
    pub fn remove(&mut self, key: ExpertKey) -> Option<usize> {
        let e = self.entries.remove(&key)?;
        self.used -= e.bytes;
        self.policy.on_remove(key);
        Some(e.bytes)
    }

    /// Pin/unpin an entry (prefetched-for-imminent-use protection).
    pub fn set_pinned(&mut self, key: ExpertKey, pinned: bool) {
        if let Some(e) = self.entries.get_mut(&key) {
            e.pinned = pinned;
        }
    }

    /// Is `key` resident *and* pinned? (The rebalancer never migrates
    /// pinned entries — they are staged for imminent use here.)
    pub fn is_pinned(&self, key: ExpertKey) -> bool {
        self.entries.get(&key).is_some_and(|e| e.pinned)
    }

    /// Count a hit served by a *replica* copy on this device. Replicas
    /// live outside the policy-managed resident set, so only the hit
    /// counter moves — exactly one hit or miss is still recorded per
    /// `ExpertStore::lookup`.
    pub fn record_replica_hit(&mut self) {
        self.stats.hits += 1;
    }

    /// Refresh `key`'s policy recency/frequency without recording a hit
    /// or miss (no-op if not resident). Used when a *replica* holder
    /// serves an access: the home copy is still the hottest entry on its
    /// device and must not age into eviction just because its bus was
    /// busy — evicting it would invalidate every replica on the next
    /// refresh and thrash exactly the experts replication protects.
    pub fn touch(&mut self, key: ExpertKey) {
        if self.entries.contains_key(&key) {
            self.clock += 1;
            self.policy.on_hit(key, self.clock);
        }
    }

    pub fn unpin_all(&mut self) {
        for e in self.entries.values_mut() {
            e.pinned = false;
        }
    }

    fn evict_one(&mut self) -> Option<(ExpertKey, usize)> {
        let candidates: Vec<ExpertKey> = self
            .entries
            .iter()
            .filter(|(_, e)| !e.pinned)
            .map(|(k, _)| *k)
            .collect();
        match self.policy.victim(&candidates) {
            Some(k) => {
                let e = self.entries.remove(&k).expect("victim must be resident");
                self.used -= e.bytes;
                self.policy.on_remove(k);
                self.stats.evictions += 1;
                Some((k, e.bytes))
            }
            None => None,
        }
    }

    pub fn keys(&self) -> Vec<ExpertKey> {
        self.entries.keys().copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;
    use crate::util::rng::Rng;
    use std::collections::HashSet;

    #[test]
    fn hit_miss_and_lru() {
        let mut c = ResidentSet::new(300, ResidencyKind::Lru);
        assert!(!c.access((0, 0)));
        assert!(c.insert((0, 0), 100));
        assert!(c.insert((0, 1), 100));
        assert!(c.insert((0, 2), 100));
        assert!(c.access((0, 0))); // refresh 0 → LRU victim is (0,1)
        assert!(c.insert((1, 0), 100));
        assert!(c.contains((0, 0)));
        assert!(!c.contains((0, 1)));
        assert_eq!(c.stats.evictions, 1);
    }

    #[test]
    fn pinned_survives() {
        for kind in ResidencyKind::ALL {
            let mut c = ResidentSet::new(200, kind);
            c.insert((0, 0), 100);
            c.set_pinned((0, 0), true);
            c.insert((0, 1), 100);
            assert!(c.insert((0, 2), 100)); // must evict (0,1), not pinned (0,0)
            assert!(c.contains((0, 0)), "{}", c.policy_name());
            assert!(!c.contains((0, 1)), "{}", c.policy_name());
        }
    }

    #[test]
    fn insert_evicting_reports_victims_and_remove_is_not_an_eviction() {
        let mut c = ResidentSet::new(200, ResidencyKind::Lru);
        assert!(c.insert((0, 0), 100));
        assert!(c.insert((0, 1), 100));
        assert_eq!(c.bytes_of((0, 0)), Some(100));
        assert_eq!(c.free_bytes(), 0);
        let (ok, evicted) = c.insert_evicting((0, 2), 150);
        assert!(ok);
        // LRU evicts both older entries to fit 150
        assert_eq!(evicted, vec![((0, 0), 100), ((0, 1), 100)]);
        assert_eq!(c.stats.evictions, 2);
        assert_eq!(c.remove((0, 2)), Some(150));
        assert_eq!(c.remove((0, 2)), None);
        assert_eq!(c.used(), 0);
        assert_eq!(c.stats.evictions, 2, "remove must not count as eviction");
        // non-sparsity policies admit anything
        assert!(c.would_admit((9, 9)));
    }

    #[test]
    fn cannot_fit_oversize() {
        let mut c = ResidentSet::new(100, ResidencyKind::Lfu);
        assert!(!c.insert((0, 0), 101));
        assert_eq!(c.used(), 0);
    }

    #[test]
    fn all_pinned_blocks_insert() {
        let mut c = ResidentSet::new(100, ResidencyKind::Sparsity);
        c.insert((0, 0), 100);
        c.set_pinned((0, 0), true);
        assert!(!c.insert((0, 1), 50));
        assert!(c.contains((0, 0)));
    }

    /// The shadow-map property harness, run identically against every
    /// residency policy: byte accounting is exact, the budget is never
    /// exceeded, pinned entries survive eviction, and hit/miss counts
    /// match an independent oracle.
    fn residency_invariants(kind: ResidencyKind) {
        let name = format!("store-invariants-{}", kind.name());
        check(&name, 40, |rng: &mut Rng| {
            let budget = rng.range(100, 2000);
            let mut c = ResidentSet::new(budget, kind);
            let mut shadow: std::collections::HashMap<ExpertKey, usize> =
                Default::default();
            let mut pinned: HashSet<ExpertKey> = HashSet::new();
            let (mut hits, mut misses) = (0u64, 0u64);
            for _ in 0..200 {
                let key = (rng.below(4), rng.below(8));
                match rng.below(6) {
                    0 | 1 => {
                        let expect = c.contains(key);
                        let got = c.access(key);
                        prop_assert!(
                            expect == got,
                            "access({key:?}) = {got}, contains said {expect}"
                        );
                        if got {
                            hits += 1;
                        } else {
                            misses += 1;
                        }
                    }
                    2 => {
                        let bytes = rng.range(1, budget / 2 + 2);
                        if c.insert(key, bytes) {
                            shadow.insert(key, bytes);
                        } else {
                            shadow.remove(&key);
                        }
                        // (re)inserted or failed: either way no longer pinned
                        pinned.remove(&key);
                    }
                    3 => {
                        let p = rng.f64() < 0.5;
                        c.set_pinned(key, p);
                        if c.contains(key) {
                            if p {
                                pinned.insert(key);
                            } else {
                                pinned.remove(&key);
                            }
                        }
                    }
                    4 => {
                        c.unpin_all();
                        pinned.clear();
                    }
                    _ => c.note_activation(key),
                }
                // drop shadow entries the cache evicted
                shadow.retain(|k, _| c.contains(*k));
                prop_assert!(
                    c.used() <= c.budget(),
                    "used {} > budget {}",
                    c.used(),
                    c.budget()
                );
                let sum: usize = shadow.values().sum();
                prop_assert!(sum == c.used(), "shadow {} != used {}", sum, c.used());
                for k in &pinned {
                    prop_assert!(c.contains(*k), "pinned {k:?} was evicted");
                }
                prop_assert!(
                    c.stats.hits == hits && c.stats.misses == misses,
                    "hit/miss accounting drifted: cache {}h/{}m oracle {}h/{}m",
                    c.stats.hits,
                    c.stats.misses,
                    hits,
                    misses
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_invariants_lru() {
        residency_invariants(ResidencyKind::Lru);
    }

    #[test]
    fn prop_invariants_lfu() {
        residency_invariants(ResidencyKind::Lfu);
    }

    #[test]
    fn prop_invariants_sparsity() {
        residency_invariants(ResidencyKind::Sparsity);
    }

    /// Skewed synthetic routing trace: Zipf popularity over the experts
    /// with periodic cold scans — the access pattern MoE-Infinity argues
    /// defeats plain LRU. The sparsity-aware policy must match or beat
    /// LRU's hit rate.
    #[test]
    fn store_policy_sweep() {
        let n_experts = 32usize;
        let expert_bytes = 100usize;
        let fits = 4usize;
        let run = |kind: ResidencyKind| -> f64 {
            let mut c = ResidentSet::new(fits * expert_bytes, kind);
            let mut rng = Rng::new(42);
            // Zipf(1.5) CDF over expert popularity
            let mut cdf: Vec<f64> = (1..=n_experts)
                .map(|k| 1.0 / (k as f64).powf(1.5))
                .collect();
            for i in 1..n_experts {
                cdf[i] += cdf[i - 1];
            }
            let total = cdf[n_experts - 1];
            for step in 0..6000usize {
                let e = if step % 40 < 6 {
                    // cold scan burst: one-off experts LRU caches anyway
                    n_experts - 1 - (step % 40) - (step / 40) % 8
                } else {
                    let r = rng.f64() * total;
                    cdf.partition_point(|w| *w < r).min(n_experts - 1)
                };
                let key = (0usize, e);
                c.note_activation(key);
                if !c.access(key) {
                    c.insert(key, expert_bytes);
                }
            }
            c.stats.hit_rate()
        };
        let lru = run(ResidencyKind::Lru);
        let lfu = run(ResidencyKind::Lfu);
        let sparsity = run(ResidencyKind::Sparsity);
        assert!(
            sparsity >= lru,
            "sparsity-aware {sparsity:.3} < lru {lru:.3} on skewed trace"
        );
        assert!(sparsity > 0.3, "sparsity hit rate implausibly low: {sparsity}");
        assert!(lfu > 0.3, "lfu hit rate implausibly low: {lfu}");
    }
}

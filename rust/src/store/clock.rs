//! Clock abstraction for the timelines residency code runs on
//! (DESIGN.md §3). `ExpertStore` is written against the trait, so the
//! cache, prefetch pipeline and stall attribution are byte-for-byte the
//! same code regardless of where time comes from — the property the
//! Fig-6 "sim vs real" comparison rests on.
//!
//! Today both store clients drive a `VirtualClock`: the simulator
//! advances it with modeled latencies, the serving path with *measured*
//! per-layer PJRT compute (calibrated via `WallClock` stopwatches, which
//! also time prefill/decode in `coordinator::serve`). Installing a
//! `WallClock` as the store clock (`ExpertStore::with_wall_clock`) makes
//! real elapsed time advance the timeline by itself, with modeled stalls
//! charged on top as a virtual offset.

use std::time::Instant;

pub trait Clock {
    /// Current position on the timeline, microseconds.
    fn now_us(&self) -> f64;
    /// Push the timeline forward by `us` (modeled compute or stall time).
    fn advance(&mut self, us: f64);
}

/// Pure virtual timeline: time moves only when `advance` is called.
#[derive(Debug, Default, Clone)]
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    pub fn new() -> Self {
        VirtualClock { now: 0.0 }
    }
}

impl Clock for VirtualClock {
    fn now_us(&self) -> f64 {
        self.now
    }
    fn advance(&mut self, us: f64) {
        self.now += us;
    }
}

/// Wall-anchored timeline: real elapsed time plus a virtual offset. The
/// offset accumulates modeled time that did not actually pass on this
/// machine (simulated PCIe stalls), so `now_us` reads as "what the wall
/// clock would show if the modeled hardware existed".
#[derive(Debug, Clone)]
pub struct WallClock {
    t0: Instant,
    offset_us: f64,
}

impl WallClock {
    pub fn start() -> Self {
        WallClock { t0: Instant::now(), offset_us: 0.0 }
    }

    /// Real (un-offset) seconds since `start`.
    pub fn elapsed_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    /// The accumulated virtual (modeled) component, microseconds.
    pub fn virtual_offset_us(&self) -> f64 {
        self.offset_us
    }
}

impl Clock for WallClock {
    fn now_us(&self) -> f64 {
        self.t0.elapsed().as_nanos() as f64 / 1e3 + self.offset_us
    }
    fn advance(&mut self, us: f64) {
        self.offset_us += us;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_advances() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now_us(), 0.0);
        c.advance(12.5);
        c.advance(0.5);
        assert!((c.now_us() - 13.0).abs() < 1e-12);
    }

    #[test]
    fn wall_clock_is_monotone_and_carries_offset() {
        let mut c = WallClock::start();
        let a = c.now_us();
        c.advance(1000.0);
        let b = c.now_us();
        assert!(b >= a + 1000.0, "{a} {b}");
        assert_eq!(c.virtual_offset_us(), 1000.0);
        assert!(c.elapsed_s() >= 0.0);
    }
}

//! Placement — the device dimension of the `ExpertStore` (DESIGN.md §3).
//!
//! A `Placement` fixes where expert bytes may live: how many devices there
//! are, which device is *home* for each `ExpertKey` (the `ShardPolicy`),
//! what the links cost (`hwsim::TopologySpec` — per-device host links plus
//! a GPU↔GPU peer link), and which cooperative behaviors are on
//! (`coalesce` batched prefetch plans into chunked copies; `spill`
//! eviction victims into spare peer capacity instead of dropping them).
//!
//! `TransferPlan` is the batched movement request that replaced the
//! one-expert-per-call prefetch surface: a set of same-destination items,
//! each carrying its solo-copy duration and the per-copy API-overhead
//! share a coalesced chunk pays only once (the Fig-7 U-shape comes from
//! exactly that overhead). With one device and coalescing off, a plan
//! executes item-by-item — operation-for-operation identical to the old
//! scalar API, which is what keeps `--devices 1 --policy lru`
//! bit-reproducible.

use crate::config::ShardPolicy;
use crate::hwsim::{TopologySpec, PCIE4};

use super::ExpertKey;

/// Index of a device in the store's placement (0-based, dense).
pub type DeviceId = usize;

/// Index of a node in the cluster tier above the devices (0-based,
/// dense — DESIGN.md §10). `TopologySpec::node_of` maps a `DeviceId`
/// into this space.
pub type NodeId = usize;

/// Fraction of each device's expert-cache budget reserved for *replicas*
/// of the hottest experts (popularity-proportional copy counts — see
/// `ExpertStore::rebalance_tick`). The pool is *carved out of* the
/// per-device byte budget: when `replicate_top > 0` the resident set
/// runs on `budget - replica_budget` bytes, so resident + replica bytes
/// never exceed the configured device budget (property-tested in
/// tests/shard_store.rs). With replication off the resident set keeps
/// the full budget — bit-exact with every pre-replication
/// configuration. The carve costs the replicated configs cache capacity
/// but keeps the VRAM accounting honest; the sweep's tps win still
/// comes from compute streams spreading replica-resolved GEMVs, not
/// from extra modeled memory. 5% keeps the popularity margins of
/// experiments/shard.rs above their floors (replay-pinned: pop/hash
/// 1.0216x at 2 devices, 1.2657x at 4) while fitting several copies of
/// the hottest compressed experts per device.
pub const REPLICA_BUDGET_FRAC: f64 = 0.05;

/// Layer boundaries between popularity rebalances: `rebalance_tick` is
/// called once per *processed* layer boundary by both coordinators, so
/// the cadence follows work, not wall time — 128 boundaries ≈ 4 decode
/// tokens single-stream at Mixtral depth, proportionally more often
/// under batching (each sequence's layers count). That is safe because a
/// rebalance that finds the placement within `REBALANCE_SLACK` migrates
/// nothing — post-convergence rebalances are cheap no-ops — while the
/// first rebalances land early enough to act on the warmed Zipf mass.
pub const REBALANCE_INTERVAL: u64 = 128;

/// Hysteresis slack for `Balanced` re-homing: keys migrate only while
/// the busiest-vs-idlest device mass gap exceeds this fraction of total
/// mass. Without the slack, near-equal-mass keys (all layers of one
/// expert look alike) reshuffle on every rebalance and the migration /
/// peer-fetch churn swamps the balance win — the replay measured 3x the
/// bytes moved under naive full re-packing.
pub const REBALANCE_SLACK: f64 = 0.02;

/// Where expert bytes may live and how they move between devices.
#[derive(Clone, Debug)]
pub struct Placement {
    pub shard: ShardPolicy,
    pub topo: TopologySpec,
    /// coalesce same-destination transfer plans into one chunked copy
    /// (one per-copy API overhead per plan instead of per expert)
    pub coalesce: bool,
    /// on eviction, spill victims to a peer device with spare capacity
    /// (over the p2p link) instead of dropping them
    pub spill: bool,
    /// replicate the `replicate_top` hottest experts (by measured
    /// activation mass) onto peer devices, under a popularity-
    /// proportional slice of each device's `REPLICA_BUDGET_FRAC` pool
    /// (0 = replication off — the pre-replication behavior exactly)
    pub replicate_top: usize,
    /// fraction of each device's byte budget carved into the *little
    /// tier* (DESIGN.md §11): an always-resident low-rank/INT2-only
    /// degraded variant per home expert, seeded at build time and never
    /// evicted, so a saturated bus can resolve to `Lookup::Degraded`
    /// instead of stalling the batch. Carved exactly like the replica
    /// pool: when `little_frac > 0` the resident set runs on
    /// `budget - replica - little` bytes (resident + replica + little
    /// ≤ budget, property-tested). 0.0 = quality-elastic serving off —
    /// bit-exact with every pre-fallback configuration
    pub little_frac: f64,
}

impl Placement {
    /// The pre-placement single-GPU world: one device, no coalescing, no
    /// spill — every key homes on device 0.
    pub fn single() -> Self {
        Placement {
            shard: ShardPolicy::Layer,
            topo: TopologySpec::single(PCIE4),
            coalesce: false,
            spill: false,
            replicate_top: 0,
            little_frac: 0.0,
        }
    }

    /// `n` devices under `shard`, cooperative behaviors on when there is
    /// anything to cooperate across (replication stays opt-in).
    pub fn sharded(n: usize, shard: ShardPolicy) -> Self {
        Placement {
            shard,
            topo: TopologySpec::uniform(n, PCIE4),
            coalesce: n > 1,
            spill: n > 1,
            replicate_top: 0,
            little_frac: 0.0,
        }
    }

    pub fn n_devices(&self) -> usize {
        self.topo.n_devices
    }

    /// Static home device of `key` under the shard policy (for
    /// `Balanced` this is only the cold-start seed — use
    /// `ExpertStore::home`, which overlays the measured-mass assignment).
    pub fn home(&self, key: ExpertKey) -> DeviceId {
        self.shard.place(key, self.topo.n_devices)
    }
}

/// Outcome of a routed residency probe (`ExpertStore::lookup`), in
/// resolution order (DESIGN.md §10): the expert is usable in place on a
/// device (its home, or — with replication on — the replica holder whose
/// bus frees soonest); resident on a *same-node* peer as a spilled copy
/// (reachable over the p2p link via `peer_fetch`); resident only on a
/// device of *another node* of a spanning topology (reachable over the
/// network link via `net_fetch`); or not resident anywhere. Single-node
/// topologies never produce `RemoteNode`, so every pre-cluster
/// configuration resolves exactly as before.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lookup {
    Local(DeviceId),
    Remote(DeviceId),
    RemoteNode(DeviceId),
    Miss,
    /// The full expert is not affordable in time, but the little-tier
    /// degraded variant is resident on this device (DESIGN.md §11).
    /// `lookup` itself never returns this — a plain residency probe has
    /// no SLO to weigh — only `ExpertStore::degraded_hit`, called by a
    /// coordinator whose deadline says stalling would bust the budget,
    /// resolves here. That split is what keeps every fallback-off
    /// configuration bit-exact.
    Degraded(DeviceId),
}

/// How a `TransferPlan` occupies its destination device's bus.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanMode {
    /// overlapped with compute; one bus transaction per item (the
    /// pre-redesign semantics — exact for `--devices 1`)
    Overlapped,
    /// overlapped and chunk-coalesced: one bus transaction for the whole
    /// plan, the per-copy overhead paid once, items admitted as their
    /// chunk completes (partial completion)
    Coalesced,
    /// compute blocks until each item lands (the AdvancedOffload
    /// same-layer scheme the paper criticizes in §2); never coalesced
    Blocking,
}

/// One expert's slice of a batched transfer plan.
#[derive(Debug)]
pub struct TransferItem<P> {
    pub key: ExpertKey,
    /// bytes this item moves over the bus
    pub bytes: f64,
    /// full solo-copy duration (bus time + per-copy overhead [+ packing])
    pub duration_us: f64,
    /// the per-copy API-overhead share of `duration_us` that a coalesced
    /// chunk pays once for the whole plan instead of once per item
    pub overhead_us: f64,
    pub payload: P,
}

/// Which physical link a `TransferPlan` rides. The link class does not
/// change how the plan is charged — item durations are priced by the
/// caller against the matching `PcieSpec` — it classifies the traffic so
/// the store can account network pulls separately from PCIe/P2P moves
/// (cluster tier, DESIGN.md §10).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkClass {
    /// host → device over the destination's dedicated PCIe lanes
    H2d,
    /// device ↔ device over the peer link
    P2p,
    /// node ↔ node over the latency-dominated network link
    Net,
}

/// A batched transfer toward one destination device. Build with
/// [`TransferPlan::to`] (host link) or rebind with [`TransferPlan::via`],
/// fill with [`TransferPlan::push`], execute with `ExpertStore::submit`.
#[derive(Debug)]
pub struct TransferPlan<P> {
    pub dst: DeviceId,
    pub mode: PlanMode,
    pub link: LinkClass,
    pub items: Vec<TransferItem<P>>,
}

impl<P> TransferPlan<P> {
    pub fn to(dst: DeviceId, mode: PlanMode) -> Self {
        TransferPlan { dst, mode, link: LinkClass::H2d, items: Vec::new() }
    }

    /// Rebind the plan to another link class (e.g. `Net` for cluster
    /// re-homing pulls).
    pub fn via(mut self, link: LinkClass) -> Self {
        self.link = link;
        self
    }

    pub fn push(
        &mut self,
        key: ExpertKey,
        bytes: f64,
        duration_us: f64,
        overhead_us: f64,
        payload: P,
    ) {
        self.items.push(TransferItem { key, bytes, duration_us, overhead_us, payload });
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// Total bytes the plan moves.
    pub fn bytes(&self) -> f64 {
        self.items.iter().map(|it| it.bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_placement_homes_everything_on_device_zero() {
        let p = Placement::single();
        assert_eq!(p.n_devices(), 1);
        assert!(!p.coalesce && !p.spill);
        for l in 0..4 {
            for e in 0..8 {
                assert_eq!(p.home((l, e)), 0);
            }
        }
    }

    #[test]
    fn sharded_placement_spreads_and_cooperates() {
        let p = Placement::sharded(3, ShardPolicy::Layer);
        assert_eq!(p.n_devices(), 3);
        assert!(p.coalesce && p.spill);
        assert_eq!(p.home((4, 0)), 1);
        // sharded(1) degenerates to the single-device behavior
        let one = Placement::sharded(1, ShardPolicy::Expert);
        assert_eq!(one.n_devices(), 1);
        assert!(!one.coalesce && !one.spill);
    }

    #[test]
    fn plan_accumulates_items() {
        let mut plan: TransferPlan<()> = TransferPlan::to(2, PlanMode::Coalesced);
        assert!(plan.is_empty());
        assert_eq!(plan.link, LinkClass::H2d, "plans default to the host link");
        plan.push((0, 1), 100.0, 10.0, 2.0, ());
        plan.push((0, 2), 50.0, 6.0, 2.0, ());
        assert_eq!(plan.len(), 2);
        assert_eq!(plan.bytes(), 150.0);
        assert_eq!(plan.dst, 2);
        let net = TransferPlan::<()>::to(0, PlanMode::Coalesced).via(LinkClass::Net);
        assert_eq!(net.link, LinkClass::Net);
    }
}

//! ExpertStore — the expert-residency subsystem (DESIGN.md §3).
//!
//! Owns everything between "the router picked expert e" and "expert e's
//! bytes are in VRAM": the byte-budgeted resident set with pluggable
//! eviction policies (`cache`/`policy`), the shared prefetch pipeline
//! with in-flight tracking and stall attribution over a busy-until PCIe
//! timeline (`prefetch`), and the clock abstraction that lets the same
//! code run on the simulator's virtual timeline and the serving path's
//! wall-anchored one (`clock`).
//!
//! Both coordinators — `coordinator::serve` (real PJRT compute) and
//! `coordinator::sim` (discrete-event Figs 6/8) — are thin clients of
//! this store, so the paper's residency mechanism is exercised by one
//! code path everywhere. Predictors stay outside: callers decide *what*
//! to prefetch; the store decides what is resident, what is in flight,
//! and who pays for waiting.

pub mod cache;
pub mod clock;
pub mod policy;
pub mod prefetch;

pub use cache::{CacheStats, ResidentSet};
pub use clock::{Clock, VirtualClock, WallClock};
pub use policy::{build_policy, LfuPolicy, LruPolicy, ResidencyPolicy, SparsityPolicy};
pub use prefetch::{PinnedPool, PrefetchPipeline, StallCause, StallSplit, StoreStats};

pub use crate::config::ResidencyKind;

pub type ExpertKey = (usize, usize); // (layer, expert)

/// Unified residency facade: resident set + prefetch pipeline + clock.
/// `P` is the per-transfer payload attached to in-flight prefetches.
pub struct ExpertStore<P = ()> {
    cache: ResidentSet,
    prefetch: PrefetchPipeline<P>,
    clock: Box<dyn Clock>,
    /// requester id stalls are currently attributed to (serving: the
    /// request being decoded; sim/warmup: `StoreStats::UNATTRIBUTED`)
    attr: u64,
}

impl<P> ExpertStore<P> {
    pub fn new(budget_bytes: usize, kind: ResidencyKind, clock: Box<dyn Clock>) -> Self {
        ExpertStore {
            cache: ResidentSet::new(budget_bytes, kind),
            prefetch: PrefetchPipeline::new(),
            clock,
            attr: StoreStats::UNATTRIBUTED,
        }
    }

    /// Store over a fresh virtual microsecond timeline (sim, and the
    /// serving pipeline's modeled PCIe/stall accounting).
    pub fn with_virtual_clock(budget_bytes: usize, kind: ResidencyKind) -> Self {
        Self::new(budget_bytes, kind, Box::new(VirtualClock::new()))
    }

    /// Store over a wall-anchored timeline: real elapsed time advances it,
    /// `tick`/`stall_until` add modeled time on top. Not used by the
    /// in-repo clients yet (serve feeds a VirtualClock with measured
    /// compute — see store::clock); intended for drivers that want the
    /// store's accounting over genuinely passing time.
    pub fn with_wall_clock(budget_bytes: usize, kind: ResidencyKind) -> Self {
        Self::new(budget_bytes, kind, Box::new(WallClock::start()))
    }

    // ---------------------------------------------------------- timeline

    pub fn now_us(&self) -> f64 {
        self.clock.now_us()
    }

    /// Compute time passing (modeled or measured).
    pub fn tick(&mut self, us: f64) {
        self.clock.advance(us);
    }

    /// Jump forward to `t_us` without charging a stall (prefill waits,
    /// warmup). No-op if `t_us` is in the past.
    pub fn advance_to(&mut self, t_us: f64) {
        let now = self.clock.now_us();
        if t_us > now {
            self.clock.advance(t_us - now);
        }
    }

    /// Wait for `t_us` (a transfer completion), attributing the wait as a
    /// demand-fetch decode stall. No-op if the bytes already landed.
    pub fn stall_until(&mut self, t_us: f64) {
        self.stall_until_for(t_us, StallCause::Demand);
    }

    /// `stall_until` with an explicit cause: demand fetch (nothing was in
    /// flight) vs prefetch-miss (the predicted transfer landed late). The
    /// stall is charged to the current attribution requester.
    pub fn stall_until_for(&mut self, t_us: f64, cause: StallCause) {
        let now = self.clock.now_us();
        if t_us > now {
            self.prefetch.stats.charge_stall(self.attr, cause, t_us - now);
            self.clock.advance(t_us - now);
        }
    }

    // ------------------------------------------------------- attribution

    /// Charge subsequent stalls to requester `id` (a serving request).
    pub fn set_attribution(&mut self, id: u64) {
        self.attr = id;
    }

    /// Back to the unattributed bucket (warmup, calibration).
    pub fn clear_attribution(&mut self) {
        self.attr = StoreStats::UNATTRIBUTED;
    }

    /// Cumulative stall decomposition charged to requester `id`.
    pub fn stall_split_of(&self, id: u64) -> StallSplit {
        self.prefetch
            .stats
            .attributed
            .get(&id)
            .copied()
            .unwrap_or_default()
    }

    /// Remove and return requester `id`'s attribution entry (retiring a
    /// finished request on long-running servers). Global totals keep the
    /// retired stall time via the `retired` bucket.
    pub fn take_attribution(&mut self, id: u64) -> StallSplit {
        self.prefetch.stats.retire(id)
    }

    // ---------------------------------------------------------- residency

    /// Routed access to `key`: feeds the policy's popularity signal and
    /// records the cache hit/miss. Returns true if resident.
    pub fn access(&mut self, key: ExpertKey) -> bool {
        self.cache.note_activation(key);
        self.cache.access(key)
    }

    pub fn contains(&self, key: ExpertKey) -> bool {
        self.cache.contains(key)
    }

    /// Admit `key` at `bytes` into the resident set (after its transfer
    /// lands, or at warmup). Returns false if it cannot fit.
    pub fn admit(&mut self, key: ExpertKey, bytes: usize) -> bool {
        self.cache.insert(key, bytes)
    }

    pub fn set_pinned(&mut self, key: ExpertKey, pinned: bool) {
        self.cache.set_pinned(key, pinned);
    }

    pub fn unpin_all(&mut self) {
        self.cache.unpin_all();
    }

    // ---------------------------------------------------------- transfers

    pub fn inflight(&self, key: ExpertKey) -> bool {
        self.prefetch.inflight(key)
    }

    /// Overlapped prefetch: queues behind in-flight bus work and pins any
    /// resident copy of `key` against eviction until consumed.
    pub fn begin_prefetch(
        &mut self,
        key: ExpertKey,
        duration_us: f64,
        bytes: f64,
        payload: P,
    ) -> f64 {
        let now = self.clock.now_us();
        let done = self.prefetch.begin(key, duration_us, bytes, now, payload);
        self.cache.set_pinned(key, true);
        done
    }

    /// Non-overlapped prefetch (same-layer speculation, paper §2): the
    /// caller must stall to the returned completion time.
    pub fn begin_prefetch_blocking(
        &mut self,
        key: ExpertKey,
        duration_us: f64,
        bytes: f64,
        payload: P,
    ) -> f64 {
        let now = self.clock.now_us();
        self.prefetch.begin_blocking(key, duration_us, bytes, now, payload)
    }

    /// Demand fetch of a missing expert; returns when the bytes land.
    pub fn demand_fetch(&mut self, duration_us: f64, bytes: f64) -> f64 {
        let now = self.clock.now_us();
        self.prefetch.demand(duration_us, bytes, now)
    }

    /// Count a demand fetch that moves nothing (GPU-resident systems).
    pub fn record_demand(&mut self) {
        self.prefetch.record_demand();
    }

    /// Raw bus occupancy (prefill streaming, recall top-ups).
    pub fn bus_copy(&mut self, duration_us: f64, bytes: f64) -> f64 {
        let now = self.clock.now_us();
        self.prefetch.bus_copy(duration_us, bytes, now)
    }

    /// Consume the in-flight transfer for `key`: (completion time, payload).
    /// Releases the prefetch pin taken by `begin_prefetch` so a resident
    /// copy becomes evictable again (re-admitting also resets the pin).
    pub fn take_inflight(&mut self, key: ExpertKey) -> Option<(f64, P)> {
        let taken = self.prefetch.take(key);
        if taken.is_some() {
            self.cache.set_pinned(key, false);
        }
        taken
    }

    // ---------------------------------------------------------- accounting

    pub fn stats(&self) -> &StoreStats {
        &self.prefetch.stats
    }

    pub fn cache_stats(&self) -> &CacheStats {
        &self.cache.stats
    }

    pub fn policy_name(&self) -> &'static str {
        self.cache.policy_name()
    }

    pub fn budget(&self) -> usize {
        self.cache.budget()
    }

    pub fn used(&self) -> usize {
        self.cache.used()
    }

    pub fn resident(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefetch_then_consume_charges_no_stall() {
        let mut s: ExpertStore = ExpertStore::with_virtual_clock(1000, ResidencyKind::Lru);
        let done = s.begin_prefetch((0, 0), 50.0, 100.0, ());
        assert_eq!(done, 50.0);
        s.tick(80.0); // compute overlapped past the transfer
        assert!(!s.access((0, 0)), "not admitted yet");
        let (ready, ()) = s.take_inflight((0, 0)).unwrap();
        s.stall_until(ready);
        assert_eq!(s.stats().stall_us, 0.0);
        assert!(s.admit((0, 0), 100));
        assert!(s.access((0, 0)));
        assert_eq!(s.now_us(), 80.0);
    }

    #[test]
    fn demand_fetch_stalls_exactly_the_gap() {
        let mut s: ExpertStore = ExpertStore::with_virtual_clock(1000, ResidencyKind::Lfu);
        s.tick(10.0);
        let ready = s.demand_fetch(30.0, 64.0);
        assert_eq!(ready, 40.0);
        s.stall_until(ready);
        assert_eq!(s.now_us(), 40.0);
        assert_eq!(s.stats().stall_us, 30.0);
        assert_eq!(s.stats().demand_fetches, 1);
        assert_eq!(s.stats().transferred_bytes, 64.0);
    }

    #[test]
    fn advance_to_does_not_count_as_stall() {
        let mut s: ExpertStore = ExpertStore::with_virtual_clock(100, ResidencyKind::Lru);
        let done = s.bus_copy(25.0, 10.0);
        s.advance_to(done);
        assert_eq!(s.now_us(), 25.0);
        assert_eq!(s.stats().stall_us, 0.0);
    }

    #[test]
    fn prefetch_pins_resident_copy() {
        let mut s: ExpertStore = ExpertStore::with_virtual_clock(200, ResidencyKind::Lru);
        assert!(s.admit((0, 0), 100));
        s.begin_prefetch((0, 0), 10.0, 50.0, ());
        assert!(s.admit((0, 1), 100));
        // (0,0) is pinned and LRU-oldest: eviction must take (0,1) instead
        assert!(s.admit((0, 2), 100));
        assert!(s.contains((0, 0)), "pinned entry evicted by admit");
        assert!(!s.contains((0, 1)));
    }

    #[test]
    fn stall_attribution_splits_by_cause_and_requester() {
        let mut s: ExpertStore = ExpertStore::with_virtual_clock(1000, ResidencyKind::Lru);
        s.set_attribution(7);
        let ready = s.demand_fetch(30.0, 64.0);
        s.stall_until_for(ready, StallCause::Demand);
        s.set_attribution(9);
        let done = s.begin_prefetch((0, 1), 20.0, 32.0, ());
        s.stall_until_for(done, StallCause::PrefetchMiss);
        s.clear_attribution();
        let late = s.demand_fetch(5.0, 8.0);
        s.stall_until(late);
        let st = s.stats();
        assert_eq!(s.stall_split_of(7), StallSplit { demand_us: 30.0, prefetch_us: 0.0 });
        assert_eq!(s.stall_split_of(9).prefetch_us, 20.0);
        assert_eq!(st.attributed[&StoreStats::UNATTRIBUTED].demand_us, 5.0);
        // globals are exactly the key-order sums over the attribution map
        let (mut demand, mut prefetch) = (0.0, 0.0);
        for v in st.attributed.values() {
            demand += v.demand_us;
            prefetch += v.prefetch_us;
        }
        assert_eq!(demand, st.stall_demand_us);
        assert_eq!(prefetch, st.stall_prefetch_us);
        assert_eq!(st.stall_us, st.stall_demand_us + st.stall_prefetch_us);
    }

    #[test]
    fn retiring_attribution_keeps_global_totals() {
        let mut s: ExpertStore = ExpertStore::with_virtual_clock(1000, ResidencyKind::Lru);
        s.set_attribution(1);
        let ready = s.demand_fetch(10.0, 1.0);
        s.stall_until(ready);
        let taken = s.take_attribution(1);
        assert_eq!(taken.demand_us, 10.0);
        assert_eq!(s.stall_split_of(1), StallSplit::default());
        // another charge must not lose the retired 10us
        s.set_attribution(2);
        let ready = s.demand_fetch(4.0, 1.0);
        s.stall_until(ready);
        assert_eq!(s.stats().stall_demand_us, 14.0);
        assert_eq!(s.stats().stall_us, 14.0);
    }

    #[test]
    fn wall_clock_store_advances_on_its_own() {
        let mut s: ExpertStore =
            ExpertStore::with_wall_clock(100, ResidencyKind::Sparsity);
        let a = s.now_us();
        s.stall_until(a + 500.0);
        assert!(s.now_us() >= a + 500.0);
        let stall = s.stats().stall_us;
        assert!(stall > 0.0 && stall <= 500.0, "stall {stall}");
    }
}
